"""Prototype: selective-head flash-attention decode kernel (Pallas, interpret)
lowered to HLO text, to validate the python->rust interchange early.

Run: cd python && python proto_sha.py /tmp/sha_hlo.txt
Then: cargo run --bin proto_load /tmp/sha_hlo.txt
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc
from jax.experimental import pallas as pl


def sha_decode_kernel(hi_ref, len_ref, q_ref, k_ref, v_ref, o_ref):
    b = pl.program_id(0)
    t = pl.program_id(1)
    h = hi_ref[b, t]
    n = len_ref[b]
    q = pl.load(q_ref, (b, h, slice(None)))  # [dh]
    N = k_ref.shape[2]
    dh = q_ref.shape[2]
    scale = 1.0 / (dh ** 0.5)

    BLK = 32
    nblk = N // BLK

    def body(j, carry):
        o_acc, l_acc, m_acc = carry
        kj = pl.load(k_ref, (b, h, pl.ds(j * BLK, BLK), slice(None)))  # [BLK, dh]
        vj = pl.load(v_ref, (b, h, pl.ds(j * BLK, BLK), slice(None)))
        s = jnp.dot(kj, q) * scale  # [BLK]
        pos = j * BLK + jax.lax.iota(jnp.int32, BLK)
        s = jnp.where(pos < n, s, -jnp.inf)
        m_new = jnp.maximum(m_acc, jnp.max(s))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_acc - m_new)
        l_new = alpha * l_acc + jnp.sum(p)
        o_new = alpha * o_acc + jnp.dot(p, vj)
        return o_new, l_new, m_new

    o, l, m = jax.lax.fori_loop(
        0, nblk, body,
        (jnp.zeros((dh,), jnp.float32), jnp.float32(0.0), jnp.float32(-1e30)),
    )
    pl.store(o_ref, (b, t, slice(None)), o / l)


def sha_decode(q, k, v, head_idx, lengths):
    B, H, dh = q.shape
    topk = head_idx.shape[1]
    return pl.pallas_call(
        sha_decode_kernel,
        out_shape=jax.ShapeDtypeStruct((B, topk, dh), jnp.float32),
        grid=(B, topk),
        interpret=True,
    )(head_idx, lengths, q, k, v)


def ref_sha(q, k, v, head_idx, lengths):
    B, H, dh = q.shape
    N = k.shape[2]
    scale = 1.0 / (dh ** 0.5)
    qs = jnp.take_along_axis(q, head_idx[:, :, None], axis=1)  # [B,topk,dh]
    ks = jnp.take_along_axis(k, head_idx[:, :, None, None], axis=1)
    vs = jnp.take_along_axis(v, head_idx[:, :, None, None], axis=1)
    s = jnp.einsum("btd,btnd->btn", qs, ks) * scale
    mask = jnp.arange(N)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("btn,btnd->btd", p, vs)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main():
    B, H, N, dh, topk = 2, 4, 64, 16, 2
    rng = np.random.default_rng(0)
    q = rng.standard_normal((B, H, dh), dtype=np.float32)
    k = rng.standard_normal((B, H, N, dh), dtype=np.float32)
    v = rng.standard_normal((B, H, N, dh), dtype=np.float32)
    head_idx = np.array([[0, 2], [1, 3]], dtype=np.int32)
    lengths = np.array([40, 64], dtype=np.int32)

    out = sha_decode(q, k, v, head_idx, lengths)
    ref = ref_sha(q, k, v, head_idx, lengths)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    print("pallas vs ref OK", np.asarray(out).ravel()[:4])

    fn = lambda hi, ln, q, k, v: (sha_decode(q, k, v, hi, ln),)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((B, topk), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
        jax.ShapeDtypeStruct((B, H, N, dh), jnp.float32),
        jax.ShapeDtypeStruct((B, H, N, dh), jnp.float32),
    )
    text = to_hlo_text(lowered)
    out_path = sys.argv[1] if len(sys.argv) > 1 else "/tmp/sha_hlo.txt"
    with open(out_path, "w") as f:
        f.write(text)
    np.save("/tmp/sha_expected.npy", np.asarray(out))
    np.save("/tmp/sha_q.npy", q)
    np.save("/tmp/sha_k.npy", k)
    np.save("/tmp/sha_v.npy", v)
    print(f"wrote {len(text)} chars to {out_path}")


if __name__ == "__main__":
    main()
