"""Router training (paper §4.1, §4.2, Appendix C) — build-time only.

Collects supervision from dense forward passes over the corpus, then trains

  * per-layer MLP routers: 2-layer bottleneck FFN, labels = ground-truth
    neuron activations (pre-ReLU > 0)                        [ReLU models]
  * per-layer attention head/group routers: 1-layer FFN, labels = top-50 %
    heads/groups by attention-output L2 norm                 [all models]

as binary classifiers with BCE + Adam (LLM frozen), exactly the Appendix C
recipe (batch 64, lr 1e-4, early stopping, <=20 epochs). Router weights are
merged into artifacts/<model>/model.npz; quality metrics go to
router_metrics.json.

Usage: python -m compile.routers --model opt-tiny --out ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model
from .configs import CONFIGS, get_config
from .optim import adam_init, adam_update

COLLECT_SEED = 90210
COLLECT_BATCHES = 12          # x train_batch x train_seq tokens of supervision
VAL_FRAC = 0.1
LABEL_HEAD_FRAC = 0.5         # top-50% by norm == "active" (§4.2)


def collect(cfg, params, n_batches: int = COLLECT_BATCHES, seed: int = COLLECT_SEED):
    """Supervision tensors from dense forward passes.

    Returns dict with, per layer stacked on axis 0:
      h_attn [L,n,d], h_mlp [L,n,d], head_norms [L,n,H],
      mlp_active [L,n,Dff] (ReLU models only)
    """
    B, T = cfg.train_batch, cfg.train_seq
    stream = corpus.training_stream(seed, n_tokens=n_batches * B * T + 1)
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    fwd = jax.jit(
        lambda toks, lens: model.forward_full(cfg, jp, toks, lens, collect=True)[2]
    )
    outs = {"h_attn": [], "h_mlp": [], "head_norms": [], "mlp_active": []}
    for i in range(n_batches):
        toks = stream[i * B * T : (i + 1) * B * T].reshape(B, T)
        lens = jnp.full((B,), T, jnp.int32)
        aux = fwd(jnp.asarray(toks), lens)
        L = cfg.n_layers
        outs["h_attn"].append(np.asarray(aux["h_attn"]).reshape(L, -1, cfg.d_model))
        outs["h_mlp"].append(np.asarray(aux["h_mlp"]).reshape(L, -1, cfg.d_model))
        outs["head_norms"].append(
            np.asarray(aux["head_norms"]).reshape(L, -1, cfg.n_heads)
        )
        if aux["mlp_active"] is not None:
            outs["mlp_active"].append(
                np.asarray(aux["mlp_active"]).reshape(L, -1, cfg.d_ff)
            )
    return {
        k: np.concatenate(v, axis=1) if v else None for k, v in outs.items()
    }


def group_labels(cfg, head_norms):
    """Binary head/group activity labels from output norms. [L,n,H]->[L,n,G]."""
    L, n, H = head_norms.shape
    g = head_norms.reshape(L, n, cfg.n_groups, cfg.q_per_group).mean(axis=-1)
    k = max(1, int(round(cfg.n_groups * LABEL_HEAD_FRAC)))
    kth = np.sort(g, axis=-1)[..., -k][..., None]
    return (g >= kth).astype(np.float32), g


@functools.partial(jax.jit, static_argnames=("apply",))
def _bce_loss(w, x, y, apply):
    logits = apply(w, x)
    z = jax.nn.log_sigmoid(logits)
    zn = jax.nn.log_sigmoid(-logits)
    return -jnp.mean(y * z + (1 - y) * zn)


def _train_binary(apply, w, x, y, *, lr=1e-4, epochs=20, batch=64, seed=0,
                  patience=3):
    """Generic BCE trainer with early stopping on a held-out split."""
    n = x.shape[0]
    n_val = max(1, int(n * VAL_FRAC))
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    xv, yv = x[perm[:n_val]], y[perm[:n_val]]
    xt, yt = x[perm[n_val:]], y[perm[n_val:]]
    w = {k: jnp.asarray(v) for k, v in w.items()}
    opt = adam_init(w)
    loss_grad = jax.jit(
        lambda w_, xb, yb: jax.value_and_grad(
            lambda ww: _bce_loss(ww, xb, yb, apply)
        )(w_)
    )
    best, best_w, bad = np.inf, w, 0
    steps = max(1, len(xt) // batch)
    for ep in range(epochs):
        order = rng.permutation(len(xt))
        for s in range(steps):
            idx = order[s * batch : (s + 1) * batch]
            _, g = loss_grad(w, jnp.asarray(xt[idx]), jnp.asarray(yt[idx]))
            w, opt = adam_update(w, g, opt, lr)
        vl = float(_bce_loss(w, jnp.asarray(xv), jnp.asarray(yv), apply))
        if vl < best - 1e-5:
            best, best_w, bad = vl, w, 0
        else:
            bad += 1
            if bad >= patience:
                break
    return {k: np.asarray(v) for k, v in best_w.items()}, best


def mlp_router_apply(w, x):
    return jax.nn.relu(x @ w["w1"] + w["b1"]) @ w["w2"] + w["b2"]


def attn_router_apply(w, x):
    return x @ w["w"] + w["b"]


def recall_at_k(logits, labels, k):
    """E[|topk(pred) ∩ active| / |active|] — router quality metric."""
    order = np.argsort(-logits, axis=-1)[:, :k]
    hit = np.take_along_axis(labels > 0, order, axis=-1).sum(axis=-1)
    tot = np.maximum((labels > 0).sum(axis=-1), 1)
    return float(np.mean(hit / tot))


def train_routers(cfg, params, data, seed: int = 0):
    """Train all routers; returns (router params to merge, metrics)."""
    rng = np.random.default_rng(seed)
    d, rh, Dff, G = cfg.d_model, cfg.mlp_router_hidden, cfg.d_ff, cfg.n_groups
    merged, metrics = {}, {"mlp": [], "attn": []}

    if cfg.mlp_sparsity and data["mlp_active"] is not None:
        mw1 = np.zeros((cfg.n_layers, d, rh), np.float32)
        mb1 = np.zeros((cfg.n_layers, rh), np.float32)
        mw2 = np.zeros((cfg.n_layers, rh, Dff), np.float32)
        mb2 = np.zeros((cfg.n_layers, Dff), np.float32)
        for l in range(cfg.n_layers):
            w0 = {
                "w1": rng.standard_normal((d, rh)).astype(np.float32) * 0.05,
                "b1": np.zeros(rh, np.float32),
                "w2": rng.standard_normal((rh, Dff)).astype(np.float32) * 0.05,
                "b2": np.zeros(Dff, np.float32),
            }
            x, y = data["h_mlp"][l], data["mlp_active"][l].astype(np.float32)
            w, vl = _train_binary(mlp_router_apply, w0, x, y, seed=seed + l)
            mw1[l], mb1[l], mw2[l], mb2[l] = w["w1"], w["b1"], w["w2"], w["b2"]
            logits = np.asarray(mlp_router_apply(
                {k: jnp.asarray(v) for k, v in w.items()}, jnp.asarray(x)))
            mean_active = float(y.mean())
            k = max(1, int(Dff * mean_active))
            metrics["mlp"].append({
                "layer": l, "val_bce": vl, "mean_active_frac": mean_active,
                "recall_at_mean_k": recall_at_k(logits, y, k),
            })
        merged.update({"mr_w1": mw1, "mr_b1": mb1, "mr_w2": mw2, "mr_b2": mb2})

    labels, _ = group_labels(cfg, data["head_norms"])
    aw = np.zeros((cfg.n_layers, d, G), np.float32)
    ab = np.zeros((cfg.n_layers, G), np.float32)
    k_half = max(1, int(round(G * LABEL_HEAD_FRAC)))
    for l in range(cfg.n_layers):
        w0 = {
            "w": rng.standard_normal((d, G)).astype(np.float32) * 0.05,
            "b": np.zeros(G, np.float32),
        }
        x, y = data["h_attn"][l], labels[l]
        w, vl = _train_binary(attn_router_apply, w0, x, y, seed=seed + 100 + l)
        aw[l], ab[l] = w["w"], w["b"]
        logits = np.asarray(attn_router_apply(
            {k2: jnp.asarray(v) for k2, v in w.items()}, jnp.asarray(x)))
        metrics["attn"].append({
            "layer": l, "val_bce": vl,
            "recall_at_half": recall_at_k(logits, y, k_half),
        })
    merged.update({"ar_w": aw, "ar_b": ab})
    return merged, metrics


def export_fixture(out_dir: str, seed: int = 7):
    """Committed cross-language fixture (rust/tests/fixtures/): tiny
    attention-router weights, inputs and ground-truth labels plus the
    python-side recall numbers, in the router_metrics.json shape. The
    rust runtime router (rust/src/runtime/router.rs) must reproduce the
    recalls from the same npz within tolerance — the contract that both
    sides rank heads identically.

    Written with uncompressed ``np.savez`` (the vendored npz reader does
    not inflate), float32 throughout.
    """
    L, d, G, n = 2, 8, 4, 48
    rng = np.random.default_rng(seed)
    w_true = (rng.standard_normal((L, d, G)) * 0.7).astype(np.float32)
    h = rng.standard_normal((L, n, d)).astype(np.float32)
    noise = (rng.standard_normal((L, n, G)) * 0.35).astype(np.float32)
    scores = np.einsum("lnd,ldg->lng", h, w_true) + noise
    k = G // 2
    kth = np.sort(scores, axis=-1)[..., -k][..., None]
    labels = (scores >= kth).astype(np.float32)
    # an imperfect router: true weights + perturbation, so recall lands
    # strictly between chance and 1.0
    ar_w = (w_true + (rng.standard_normal((L, d, G)) * 0.25).astype(np.float32))
    ar_b = (rng.standard_normal((L, G)) * 0.1).astype(np.float32)
    logits = np.einsum("lnd,ldg->lng", h, ar_w) + ar_b[:, None, :]
    metrics = {
        "k": k,
        "attn": [
            {"layer": l, "recall_at_half": recall_at_k(logits[l], labels[l], k)}
            for l in range(L)
        ],
    }
    os.makedirs(out_dir, exist_ok=True)
    np.savez(os.path.join(out_dir, "router_fixture.npz"),
             ar_w=ar_w, ar_b=ar_b, h=h, labels=labels)
    with open(os.path.join(out_dir, "router_fixture.json"), "w") as f:
        json.dump(metrics, f, indent=1)
    print(f"[fixture] wrote {out_dir}/router_fixture.{{npz,json}}:",
          [round(m["recall_at_half"], 4) for m in metrics["attn"]])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="all")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fixture", default=None, metavar="DIR",
                    help="write the committed rust router fixture and exit")
    args = ap.parse_args()
    if args.fixture:
        export_fixture(args.fixture)
        return
    names = list(CONFIGS) if args.model == "all" else [args.model]
    for name in names:
        cfg = get_config(name)
        path = os.path.join(args.out, name, "model.npz")
        params = dict(np.load(path))
        data = collect(cfg, params)
        routers, metrics = train_routers(cfg, params, data)
        np.savez(path, **params, **routers)
        with open(os.path.join(args.out, name, "router_metrics.json"), "w") as f:
            json.dump(metrics, f, indent=1)
        print(f"[{name}] routers trained:",
              {k: round(m[-1].get("recall_at_half", m[-1].get("recall_at_mean_k", 0)), 3)
               for k, m in metrics.items() if m})
        # persist supervision features for calibrate.py / analysis.py reuse
        np.savez_compressed(
            os.path.join(args.out, name, "supervision.npz"),
            **{k: v for k, v in data.items() if v is not None},
        )


if __name__ == "__main__":
    main()
