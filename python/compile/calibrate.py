"""Greedy dynamic top-k calibration (paper Algorithm 2, §4.1).

For every (recall target, batch size, layer) find the minimal top-k such
that the router's batch-union top-k captures >= target recall of the true
union activation set. This is the "dynamic top-k mechanism that adapts the
number of active neurons per layer" — the per-layer k grows with batch size
because the union of active neurons grows (Fig 1b), which is exactly the
effect Polar Sparsity exploits/avoids.

Output: artifacts/<model>/topk_table.json
  {"recall_targets": {"0.99": {"1": [k per layer], "2": [...], ...}},
   "union_stats": {...}}   (union_stats feeds Figs 1b/7/8)

Usage: python -m compile.calibrate --model opt-tiny --out ../artifacts
"""

import argparse
import json
import os

import numpy as np

from .configs import BATCH_BUCKETS, CONFIGS, RECALL_TARGETS, get_config
from .routers import mlp_router_apply

DELTA = 8            # Algorithm 2 step size
K0 = 8               # Algorithm 2 initial top-k
N_TRIALS = 64        # batches sampled per (B, layer) estimate


def router_logits_np(params, l, x):
    z = np.maximum(x @ params["mr_w1"][l] + params["mr_b1"][l], 0.0)
    return z @ params["mr_w2"][l] + params["mr_b2"][l]


def union_recall_curve(logits, active, batch_idx):
    """Mean recall of batch-union top-k for every k (vectorised Alg. 2).

    logits: [n, Dff] router outputs; active: [n, Dff] bool ground truth;
    batch_idx: [trials, B] sample indices forming synthetic batches.
    Returns (recall[k] for k=1..Dff, mean union fraction).
    """
    Dff = logits.shape[1]
    recalls = np.zeros(Dff, np.float64)
    union_frac = 0.0
    for rows in batch_idx:
        agg = logits[rows].max(axis=0)            # aggregate predicted logits
        union = active[rows].any(axis=0)          # ground-truth union set
        n_union = max(int(union.sum()), 1)
        order = np.argsort(-agg)
        hits = np.cumsum(union[order])            # recall numerator for all k
        recalls += hits / n_union
        union_frac += n_union / Dff
    return recalls / len(batch_idx), union_frac / len(batch_idx)


def greedy_topk(recall_curve, target, k0=K0, delta=DELTA):
    """Algorithm 2: smallest k (on the k0 + i*delta grid) meeting target."""
    Dff = len(recall_curve)
    k = k0
    while k < Dff and recall_curve[k - 1] < target:
        k += delta
    return min(k, Dff)


def calibrate(cfg, params, sup, seed: int = 0):
    rng = np.random.default_rng(seed)
    h = sup["h_mlp"]            # [L, n, d]
    active = sup["mlp_active"]  # [L, n, Dff]
    n = h.shape[1]
    table = {f"{t}": {} for t in RECALL_TARGETS}
    union_stats = {}
    for B in BATCH_BUCKETS:
        batch_idx = rng.integers(0, n, size=(N_TRIALS, B))
        ks = {f"{t}": [] for t in RECALL_TARGETS}
        fracs = []
        for l in range(cfg.n_layers):
            logits = router_logits_np(params, l, h[l])
            curve, frac = union_recall_curve(logits, active[l], batch_idx)
            fracs.append(frac)
            for t in RECALL_TARGETS:
                ks[f"{t}"].append(int(greedy_topk(curve, t)))
        for t in RECALL_TARGETS:
            table[f"{t}"][str(B)] = ks[f"{t}"]
        union_stats[str(B)] = [round(float(f), 4) for f in fracs]
    return {"recall_targets": table, "union_stats": union_stats,
            "d_ff": cfg.d_ff, "batch_buckets": BATCH_BUCKETS}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="all")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    names = list(CONFIGS) if args.model == "all" else [args.model]
    for name in names:
        cfg = get_config(name)
        if not cfg.mlp_sparsity:
            continue
        mdir = os.path.join(args.out, name)
        params = dict(np.load(os.path.join(mdir, "model.npz")))
        sup = dict(np.load(os.path.join(mdir, "supervision.npz")))
        out = calibrate(cfg, params, sup)
        with open(os.path.join(mdir, "topk_table.json"), "w") as f:
            json.dump(out, f, indent=1)
        print(f"[{name}] topk@0.99:",
              {b: ks for b, ks in out["recall_targets"]["0.99"].items()})


if __name__ == "__main__":
    main()
