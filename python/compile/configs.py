"""Model zoo configuration.

Four small decoder-only LMs spanning the paper's model axes:

  * opt-tiny   -- OPT-6.7b analogue:   ReLU MLP, MHA      (MLP + head sparsity)
  * opt-small  -- OPT-66b analogue:    ReLU MLP, MHA, deeper/wider
  * llama-tiny -- LLaMA-2-7b analogue: SwiGLU MLP, MHA    (head sparsity only)
  * llama-gqa  -- LLaMA-3.1-70b analogue: SwiGLU MLP, GQA (group sparsity)

All are char-level (vocab = 256 bytes + PAD/BOS/EOS) with learned positional
embeddings (OPT family) or RoPE (LLaMA family) and pre-LayerNorm.
"""

from dataclasses import dataclass, field


PAD, BOS, EOS = 256, 257, 258
VOCAB = 259

# Static-shape buckets (must match rust/src/coordinator/batcher.rs).
BATCH_BUCKETS = [1, 2, 4, 8, 16]
SEQ_BUCKETS = [64, 128, 256]
# Chunked-prefill token width: each prefill_b{B}_s{S} entry appends one
# chunk of up to this many prompt tokens at a per-slot position offset.
# Long prompts stream through successive chunks (no truncation); prompts
# longer than the largest seq bucket are rejected by the serving protocol.
PREFILL_LEN = 64

# Paged KV cache geometry. The pool is ONE tensor
# [L, 2, KV_POOL_BLOCKS, G, KV_BLOCK, dh] shared by every paged entry of
# a model (its shape is entry-static, the CUDA-graph analogue of vLLM's
# preallocated block pool); per-slot block tables [B, S // KV_BLOCK]
# address it. Block 0 is reserved as the null block: padding slots point
# every table entry at it, so their blind decode writes can never land in
# a live request's block. 16 tokens is small enough that a shared system
# prompt shards into many reusable full blocks, large enough that the
# table stays a few dozen entries at the largest seq bucket.
KV_BLOCK = 16

# Pair width of the AOT `copy_blocks` entry (on-device COW: one call
# copies up to this many (src, dst) block pairs inside the resident pool).
# The engine chunks longer pair lists across calls and pads short ones
# with (0, 0) — the null block copied onto itself, an identity write.
COPY_BLOCKS_PAIRS = 8


def kv_pool_blocks(batch_buckets, seq_buckets, block: int = KV_BLOCK) -> int:
    """Pool size covering the no-sharing worst case (every slot of the
    largest batch bucket at the largest seq bucket) plus the null block.
    Prefix sharing only ever *lowers* real occupancy below this bound."""
    return 1 + max(batch_buckets) * max(seq_buckets) // block

# Attention-density sweep used by the accuracy benches (Fig 2a / Fig 4).
DENSITY_SWEEP = [0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0]

# Densities for which end-to-end decode entries are AOT-compiled.
THROUGHPUT_DENSITIES = [0.25, 0.5, 0.625]

# MLP dynamic-top-k recall targets (Algorithm 2 calibration).
RECALL_TARGETS = [0.9, 0.95, 0.99]
DEFAULT_RECALL = 0.99


@dataclass(frozen=True)
class ModelConfig:
    name: str
    analogue: str          # which paper model this stands in for
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int        # == n_heads for MHA; < n_heads for GQA
    d_ff: int
    mlp: str               # "relu" | "swiglu"
    pos: str               # "learned" | "rope"
    max_seq: int = 256
    vocab: int = VOCAB
    # router hyper-parameters (Appendix C)
    mlp_router_hidden: int = 64
    # training (single-core CPU budget)
    train_steps: int = 400
    train_batch: int = 12
    train_seq: int = 80
    lr: float = 3e-4
    # paper-style critical attention density (Table 1 analogues; validated
    # empirically by `bench fig4` -- see EXPERIMENTS.md)
    critical_density: float = 0.5

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def q_per_group(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def n_groups(self) -> int:
        """Routable attention units: heads for MHA, KV groups for GQA."""
        return self.n_kv_heads

    @property
    def mlp_sparsity(self) -> bool:
        """Paper sparsifies MLP only for the (ReLU) OPT family."""
        return self.mlp == "relu"


CONFIGS = {
    "opt-tiny": ModelConfig(
        name="opt-tiny", analogue="OPT-6.7b",
        d_model=128, n_layers=4, n_heads=8, n_kv_heads=8,
        d_ff=512, mlp="relu", pos="learned",
        train_steps=400, critical_density=0.5,
    ),
    "opt-small": ModelConfig(
        name="opt-small", analogue="OPT-66b",
        d_model=192, n_layers=5, n_heads=8, n_kv_heads=8,
        d_ff=768, mlp="relu", pos="learned",
        train_steps=250, critical_density=0.25,
    ),
    "llama-tiny": ModelConfig(
        name="llama-tiny", analogue="LLaMA-2-7b",
        d_model=128, n_layers=4, n_heads=8, n_kv_heads=8,
        d_ff=384, mlp="swiglu", pos="rope",
        train_steps=400, critical_density=0.5,
    ),
    # ReLUfication baseline (Table 2 row / Fig 8a): LLaMA geometry, ReLU MLP.
    "llama-relu": ModelConfig(
        name="llama-relu", analogue="ReLUfied LLaMA-2-7b",
        d_model=128, n_layers=4, n_heads=8, n_kv_heads=8,
        d_ff=384, mlp="relu", pos="rope",
        train_steps=400, critical_density=0.5,
    ),
    "llama-gqa": ModelConfig(
        name="llama-gqa", analogue="LLaMA-3.1-70b",
        d_model=128, n_layers=4, n_heads=8, n_kv_heads=2,
        d_ff=384, mlp="swiglu", pos="rope",
        train_steps=400, critical_density=0.625,
    ),
}

DEFAULT_MODEL = "opt-tiny"


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown model {name!r}; have {sorted(CONFIGS)}")
    return CONFIGS[name]


def heads_for_density(cfg: ModelConfig, density: float) -> int:
    """Active heads/groups per sparse layer at a given attention density."""
    k = max(1, round(cfg.n_groups * density))
    return min(cfg.n_groups, k)
