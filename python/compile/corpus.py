"""Deterministic synthetic corpus + zero-shot task suite.

Stands in for Wikitext-2 (router supervision, perplexity) and for the
lm-eval-harness 9-task suite (COPA ... ARC) in the paper's evaluation.
Nine task families, each scored by exact-match greedy continuation after
the '=' delimiter; a small embedded natural-language block provides the
held-out perplexity corpus.

The eval split is exported to artifacts/eval_tasks.jsonl so the rust
coordinator evaluates the *same* instances at serving time.
"""

import json
import string

import numpy as np

from .configs import PAD, BOS, EOS

# ---------------------------------------------------------------------------
# Natural-ish text block (perplexity corpus; author-written, license-free).
# ---------------------------------------------------------------------------

TEXT = """
the river moves slowly through the valley and the light falls on the water.
every machine in the old workshop had a purpose and a place on the wall.
to serve many requests at once the scheduler groups them into batches.
a cache remembers what was computed so the answer returns without work.
the attention of the reader moves from word to word and line to line.
sparse forests grow where the soil is thin and the wind is strong.
when the batch grows large the union of active neurons approaches all.
each head of attention watches a different part of the long sentence.
the cost of memory movement often exceeds the cost of arithmetic.
small models learn simple rules quickly and forget them slowly.
a router decides which worker receives the next unit of work.
throughput rises when idle time falls and the pipeline stays full.
the key and the value wait in the cache for the query to arrive.
profiles reveal where the time goes and where the effort should go.
the first layer reads the raw signal and the last layer writes the answer.
latency hides in queues and appears only when the clock is watched.
""".strip().replace("\n", " ")

LOWER = string.ascii_lowercase
DIGITS = string.digits

TASK_FAMILIES = [
    "copy", "rev", "succ", "add", "maj", "cmp", "srt", "kv", "pat",
]


def _sample(rng: np.random.Generator, family: str) -> tuple[str, str]:
    """Return (prompt, answer); the training line is prompt + answer."""
    if family == "copy":
        n = rng.integers(2, 6)
        s = "".join(rng.choice(list(LOWER[:10]), n))
        return f"copy:{s}=", s
    if family == "rev":
        n = rng.integers(2, 5)
        s = "".join(rng.choice(list(LOWER[:8]), n))
        return f"rev:{s}=", s[::-1]
    if family == "succ":
        c = LOWER[rng.integers(0, 25)]
        return f"succ:{c}=", LOWER[LOWER.index(c) + 1]
    if family == "add":
        a, b = int(rng.integers(0, 50)), int(rng.integers(0, 50))
        return f"add:{a}+{b}=", str(a + b)
    if family == "maj":
        n = 5
        a, b = rng.choice(list(LOWER[:6]), 2, replace=False)
        na = int(rng.integers(3, 6))  # majority count
        s = [a] * na + [b] * (n - na)
        rng.shuffle(s)
        return f"maj:{''.join(s)}=", a
    if family == "cmp":
        a, b = int(rng.integers(0, 10)), int(rng.integers(0, 10))
        while a == b:
            b = int(rng.integers(0, 10))
        return f"cmp:{a},{b}=", "<" if a < b else ">"
    if family == "srt":
        s = rng.choice(list(LOWER[:8]), 3, replace=False)
        return f"srt:{''.join(s)}=", "".join(sorted(s))
    if family == "kv":
        keys = rng.choice(list(LOWER[:8]), 3, replace=False)
        vals = rng.choice(list(DIGITS), 3, replace=False)
        q = int(rng.integers(0, 3))
        ctx = " ".join(f"{k}{v}" for k, v in zip(keys, vals))
        return f"kv:{ctx}?{keys[q]}=", str(vals[q])
    if family == "pat":
        unit = "".join(rng.choice(list(LOWER[:6]), int(rng.integers(1, 3))))
        reps = int(rng.integers(2, 4))
        s = unit * reps
        return f"pat:{s}*=", unit
    raise ValueError(family)


def task_line(rng: np.random.Generator, family: str) -> str:
    p, a = _sample(rng, family)
    return p + a


def encode(s: str) -> list[int]:
    return [min(b, 255) for b in s.encode("utf-8", errors="replace")]


def decode(ids) -> str:
    return bytes(int(i) for i in ids if int(i) < 256).decode(
        "utf-8", errors="replace"
    )


def training_stream(seed: int, n_tokens: int, task_frac: float = 0.7) -> np.ndarray:
    """Packed token stream: task lines and text snippets joined by newline."""
    rng = np.random.default_rng(seed)
    out: list[int] = [BOS]
    words = TEXT.split(" ")
    while len(out) < n_tokens:
        if rng.random() < task_frac:
            fam = TASK_FAMILIES[int(rng.integers(0, len(TASK_FAMILIES)))]
            line = task_line(rng, fam)
        else:
            i = int(rng.integers(0, max(1, len(words) - 12)))
            line = " ".join(words[i : i + int(rng.integers(6, 13))])
        out.extend(encode(line))
        out.append(ord("\n"))
    return np.array(out[:n_tokens], dtype=np.int32)


def heldout_text_tokens(n_tokens: int = 4096) -> np.ndarray:
    """Held-out perplexity corpus (text only, fixed)."""
    ids = [BOS] + encode(TEXT)
    reps = 1 + n_tokens // len(ids)
    return np.array((ids * reps)[:n_tokens], dtype=np.int32)


def eval_suite(seed: int = 1234, per_family: int = 50) -> list[dict]:
    """Fixed zero-shot eval set (disjoint seed from training)."""
    rng = np.random.default_rng(seed)
    items = []
    for fam in TASK_FAMILIES:
        for _ in range(per_family):
            p, a = _sample(rng, fam)
            items.append({"family": fam, "prompt": p, "answer": a})
    return items


def write_eval_suite(path: str, seed: int = 1234, per_family: int = 50) -> None:
    with open(path, "w") as f:
        for item in eval_suite(seed, per_family):
            f.write(json.dumps(item) + "\n")
