"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth for pytest/hypothesis sweeps and
double as the *XLA-path* implementations used inside the end-to-end decode
entries: on the CPU PJRT substrate, interpret-mode Pallas executes its grid
serially, so the e2e artifacts lower the same selective computation through
XLA's vectorizer while the Pallas kernels (Alg. 1 / Alg. 3) are exercised
and benchmarked by the kernel-level entries (Fig 3). See DESIGN.md
§Hardware-Adaptation.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def sha_decode_ref(q, k, v, head_index, lengths, q_per_group: int = 1):
    """Selective head/group attention, decode step (one query per sequence).

    q:          [B, H, dh]        query for the new token, all H query heads
    k, v:       [B, G, N, dh]     KV cache (G = kv heads/groups)
    head_index: [B, T]  int32     active group ids per sequence (T = top-k)
    lengths:    [B]     int32     valid KV length per sequence
    returns:    [B, T * q_per_group, dh]  outputs of the *selected* heads,
                in head_index order (caller scatters into the full layout).
    """
    B, H, dh = q.shape
    G, N = k.shape[1], k.shape[2]
    T = head_index.shape[1]
    assert H == G * q_per_group
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))

    qg = q.reshape(B, G, q_per_group, dh)
    qs = jnp.take_along_axis(qg, head_index[:, :, None, None], axis=1)
    ks = jnp.take_along_axis(k, head_index[:, :, None, None], axis=1)
    vs = jnp.take_along_axis(v, head_index[:, :, None, None], axis=1)

    s = jnp.einsum("btqd,btnd->btqn", qs, ks) * scale
    mask = jnp.arange(N)[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("btqn,btnd->btqd", p, vs)
    return o.reshape(B, T * q_per_group, dh)


def dense_decode_attention_ref(q, k, v, lengths, q_per_group: int = 1):
    """Dense decode attention == SHA with the identity head index."""
    B = q.shape[0]
    G = k.shape[1]
    idx = jnp.broadcast_to(jnp.arange(G, dtype=jnp.int32)[None, :], (B, G))
    return sha_decode_ref(q, k, v, idx, lengths, q_per_group)


def sel_gemm_nt_ref(a, w, index, activation: str = "none"):
    """C = act(a @ gather(w, index).T)  -- the up-projection of Alg. 3.

    a:     [M, K]   activations
    w:     [D, K]   weights stored *neuron-major* (row per neuron)
    index: [S] int32 active neuron ids
    returns [M, S]
    """
    ws = jnp.take(w, index, axis=0)  # [S, K]
    c = a @ ws.T
    if activation == "relu":
        c = jax.nn.relu(c)
    elif activation != "none":
        raise ValueError(activation)
    return c


def sel_gemm_nn_ref(h, w, index):
    """C = h @ gather(w, index)  -- the down-projection of Alg. 3.

    h:     [M, S]   sparse hidden activations
    w:     [D, K]   weights, row per neuron
    index: [S] int32
    returns [M, K]
    """
    ws = jnp.take(w, index, axis=0)  # [S, K]
    return h @ ws


def sparse_mlp_ref(x, w1, b1, w2, b2, index):
    """Full selective MLP block (OPT/ReLU): both GEMMs restricted to index."""
    h = sel_gemm_nt_ref(x, w1, index) + jnp.take(b1, index)[None, :]
    h = jax.nn.relu(h)
    return sel_gemm_nn_ref(h, w2, index) + b2[None, :]
