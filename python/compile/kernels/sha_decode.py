"""Selective Head/Group FlashAttention, decode step (paper Algorithm 1).

Pallas kernel. Grid = (B, top_k): each program owns one (sequence, selected
head/group) pair — the TPU analogue of the paper's one-CUDA-threadblock-per
(batch, head) mapping. The KV stream is tiled in BLK-row blocks (the
``Bc = M_SRAM / 4d`` tiling of Alg. 1) with the classic online-softmax
accumulator carried across tiles.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
  * ``batch_head_index`` is read at program start; on a real TPU this is a
    scalar-prefetch operand (``PrefetchScalarGridSpec``) so the DMA engine
    can issue the gathered KV tile addresses ahead of compute. In interpret
    mode it is a dynamic ref index, which lowers to the same gather.
  * Inactive heads are never touched: HBM->VMEM traffic scales with
    top_k / H exactly as the paper's kernel scales SRAM traffic.
  * GQA: one program computes all q_per_group query heads of the selected
    group against the group's single KV stream (paper §4.2 "group sparsity").

Kernel runs under ``interpret=True`` — the CPU PJRT client cannot execute
Mosaic custom-calls; correctness is asserted against ``ref.sha_decode_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLK = 32


def _sha_kernel(hi_ref, len_ref, q_ref, k_ref, v_ref, o_ref, *, blk, q_per_group):
    b = pl.program_id(0)
    t = pl.program_id(1)
    g = hi_ref[b, t]            # selected head/group id for this program
    n = len_ref[b]              # valid KV length for this sequence
    dh = q_ref.shape[2]
    N = k_ref.shape[2]
    scale = 1.0 / (dh ** 0.5)

    # All query heads that share this KV group: rows g*qpg .. (g+1)*qpg.
    q = q_ref[b, pl.ds(g * q_per_group, q_per_group), :]  # [qpg, dh]

    nblk = (N + blk - 1) // blk

    def body(j, carry):
        o_acc, l_acc, m_acc = carry
        # Clamp the final (possibly partial) tile back into bounds; rows the
        # clamped window re-reads from the previous tile are masked below so
        # nothing is double-counted. Aligned tiles have start == j*blk and the
        # extra mask term is vacuously true — bitwise identical to before.
        start = jnp.minimum(j * blk, N - blk)
        kj = k_ref[b, g, pl.ds(start, blk), :]    # [blk, dh]
        vj = v_ref[b, g, pl.ds(start, blk), :]
        s = jnp.dot(q, kj.T) * scale              # [qpg, blk]
        pos = start + jax.lax.iota(jnp.int32, blk)
        s = jnp.where(((pos >= j * blk) & (pos < n))[None, :], s, -jnp.inf)
        m_new = jnp.maximum(m_acc, jnp.max(s, axis=1))      # [qpg]
        p = jnp.exp(s - m_new[:, None])                     # [qpg, blk]
        alpha = jnp.exp(m_acc - m_new)                      # [qpg]
        l_new = alpha * l_acc + jnp.sum(p, axis=1)
        o_new = alpha[:, None] * o_acc + jnp.dot(p, vj)     # [qpg, dh]
        return o_new, l_new, m_new

    qpg = q_per_group
    o, l, _ = jax.lax.fori_loop(
        0, nblk, body,
        (
            jnp.zeros((qpg, dh), jnp.float32),
            jnp.zeros((qpg,), jnp.float32),
            jnp.full((qpg,), -jnp.inf, jnp.float32),
        ),
    )
    o_ref[b, pl.ds(t * qpg, qpg), :] = o / l[:, None]


@functools.partial(jax.jit, static_argnames=("q_per_group", "blk"))
def sha_decode(q, k, v, head_index, lengths, q_per_group: int = 1,
               blk: int = DEFAULT_BLK):
    """Selective head/group flash-attention decode. Shapes as in ref.py.

    Returns [B, top_k * q_per_group, dh]: outputs of the selected heads in
    head_index order (compact layout; callers scatter into [B, H, dh]).
    """
    B, H, dh = q.shape
    G, N = k.shape[1], k.shape[2]
    T = head_index.shape[1]
    if H != G * q_per_group:
        raise ValueError(f"H={H} != G={G} * q_per_group={q_per_group}")
    # N need not divide blk: the kernel masks a clamped partial final tile.
    blk = min(blk, N)
    kernel = functools.partial(_sha_kernel, blk=blk, q_per_group=q_per_group)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((B, T * q_per_group, dh), jnp.float32),
        grid=(B, T),
        interpret=True,
    )(head_index, lengths, q, k, v)


def _sha_paged_kernel(hi_ref, len_ref, tbl_ref, q_ref, kpool_ref, vpool_ref,
                      o_init_ref, o_ref, *, q_per_group):
    del o_init_ref  # aliased to o_ref; unselected head rows keep its zeros
    b = pl.program_id(0)
    t = pl.program_id(1)
    g = hi_ref[b, t]            # selected head/group id for this program
    n = len_ref[b]              # valid KV length for this sequence
    dh = q_ref.shape[2]
    bs = kpool_ref.shape[2]     # pool block size (rows per KV block)
    nblk = tbl_ref.shape[1]
    scale = 1.0 / (dh ** 0.5)
    qpg = q_per_group

    q = q_ref[b, pl.ds(g * qpg, qpg), :]          # [qpg, dh]

    def body(j, carry):
        o_acc, l_acc, m_acc = carry
        # The block table IS the address computation: tile j of this
        # sequence's KV stream lives in pool block tbl[b, j]. Null blocks
        # (id 0) past the valid length are fully masked by pos < n.
        bid = tbl_ref[b, j]
        kj = kpool_ref[bid, g]                    # [bs, dh]
        vj = vpool_ref[bid, g]
        s = jnp.dot(q, kj.T) * scale              # [qpg, bs]
        pos = j * bs + jax.lax.iota(jnp.int32, bs)
        s = jnp.where((pos < n)[None, :], s, -jnp.inf)
        m_new = jnp.maximum(m_acc, jnp.max(s, axis=1))      # [qpg]
        p = jnp.exp(s - m_new[:, None])                     # [qpg, bs]
        alpha = jnp.exp(m_acc - m_new)                      # [qpg]
        l_new = alpha * l_acc + jnp.sum(p, axis=1)
        o_new = alpha[:, None] * o_acc + jnp.dot(p, vj)     # [qpg, dh]
        return o_new, l_new, m_new

    o, l, _ = jax.lax.fori_loop(
        0, nblk, body,
        (
            jnp.zeros((qpg, dh), jnp.float32),
            jnp.zeros((qpg,), jnp.float32),
            jnp.full((qpg,), -jnp.inf, jnp.float32),
        ),
    )
    o_ref[b, pl.ds(g * qpg, qpg), :] = o / l[:, None]


@functools.partial(jax.jit, static_argnames=("q_per_group",))
def sha_decode_paged(q, k_pool, v_pool, block_table, head_index, lengths,
                     q_per_group: int = 1):
    """Fused paged selective-head decode: table-indexed KV, dense output.

    Each (b, t) program resolves its KV tile addresses through the block
    table (the scalar-prefetch pattern from the module notes) instead of
    reading a pre-gathered dense cache, and writes its query-head rows
    straight into the dense [B, H, dh] layout via an aliased zero-filled
    output — no gathered [B, G, N, dh] intermediate, no compact->dense
    scatter afterwards.

    q: [B, H, dh]; k_pool/v_pool: [P, G, bs, dh] (one layer, one of k/v);
    block_table: [B, nblk] int32; head_index: [B, T]; lengths: [B].
    Returns [B, H, dh] with unselected head rows zero.
    """
    B, H, dh = q.shape
    G = k_pool.shape[1]
    T = head_index.shape[1]
    if H != G * q_per_group:
        raise ValueError(f"H={H} != G={G} * q_per_group={q_per_group}")
    kernel = functools.partial(_sha_paged_kernel, q_per_group=q_per_group)
    o_init = jnp.zeros((B, H, dh), jnp.float32)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
        grid=(B, T),
        interpret=True,
        input_output_aliases={6: 0},
    )(head_index, lengths, block_table, q, k_pool, v_pool, o_init)


def _prefill_paged_kernel(off_ref, tbl_ref, q_ref, kpool_ref, vpool_ref,
                          o_ref, *, q_per_group):
    b = pl.program_id(0)
    g = pl.program_id(1)        # prefill is dense over groups: every g runs
    off = off_ref[b]            # absolute position of this slot's chunk start
    C = q_ref.shape[1]
    dh = q_ref.shape[3]
    bs = kpool_ref.shape[2]     # pool block size (rows per KV block)
    nblk = tbl_ref.shape[1]
    scale = 1.0 / (dh ** 0.5)
    qpg = q_per_group

    # All chunk queries of this slot for the q heads of group g, flattened to
    # rows r = c*qpg + u so one dot covers the whole chunk per KV tile.
    q = q_ref[b, :, pl.ds(g * qpg, qpg), :].reshape(C * qpg, dh)
    # Absolute query position of each row (rows of one chunk index c share it).
    rpos = off + jax.lax.iota(jnp.int32, C * qpg) // qpg

    def body(j, carry):
        o_acc, l_acc, m_acc = carry
        # The block table IS the address computation: tile j of this slot's
        # KV stream lives in pool block tbl[b, j]. The chunk's own rows were
        # written before this kernel runs, so causal masking alone decides
        # visibility — no separate new-vs-prior split.
        bid = tbl_ref[b, j]
        kj = kpool_ref[bid, g]                    # [bs, dh]
        vj = vpool_ref[bid, g]
        s = jnp.dot(q, kj.T) * scale              # [C*qpg, bs]
        kpos = j * bs + jax.lax.iota(jnp.int32, bs)
        s = jnp.where(kpos[None, :] <= rpos[:, None], s, -jnp.inf)
        m_new = jnp.maximum(m_acc, jnp.max(s, axis=1))      # [C*qpg]
        p = jnp.exp(s - m_new[:, None])                     # [C*qpg, bs]
        alpha = jnp.exp(m_acc - m_new)                      # [C*qpg]
        l_new = alpha * l_acc + jnp.sum(p, axis=1)
        o_new = alpha[:, None] * o_acc + jnp.dot(p, vj)     # [C*qpg, dh]
        return o_new, l_new, m_new

    rows = C * qpg
    o, l, _ = jax.lax.fori_loop(
        0, nblk, body,
        (
            jnp.zeros((rows, dh), jnp.float32),
            jnp.zeros((rows,), jnp.float32),
            jnp.full((rows,), -jnp.inf, jnp.float32),
        ),
    )
    o_ref[b, :, pl.ds(g * qpg, qpg), :] = (o / l[:, None]).reshape(C, qpg, dh)


@functools.partial(jax.jit, static_argnames=("q_per_group",))
def prefill_attention_paged(q, k_pool, v_pool, block_table, offset,
                            q_per_group: int = 1):
    """Fused paged prefill-chunk attention: table-indexed KV, causal mask.

    Each (b, g) program attends every chunk query of slot b against group
    g's KV stream, resolving tile addresses through the block table (the
    same scalar-prefetch pattern as ``_sha_paged_kernel``) — no dense
    [B, G, N, dh] gather before, no scatter after. The chunk's new K/V
    rows must already be in the pool; the causal mask
    ``key_pos <= offset[b] + c`` then covers every case at once: prior
    context, intra-chunk causality, and future/null tiles.

    Tiles are whole pool blocks, so N == nblk * bs exactly and the
    ``N % blk != 0`` trailing-tile truncation fixed in ``_sha_kernel``
    cannot arise here; a chunk *ending* mid-block is handled by the causal
    mask alone (partially occupied final blocks, mid-block offsets).

    q: [B, C, H, dh] (C = chunk length); k_pool/v_pool: [P, G, bs, dh];
    block_table: [B, nblk] int32; offset: [B] int32 (absolute start
    position of each slot's chunk). Returns [B, C, H, dh].
    """
    B, C, H, dh = q.shape
    G = k_pool.shape[1]
    if H != G * q_per_group:
        raise ValueError(f"H={H} != G={G} * q_per_group={q_per_group}")
    kernel = functools.partial(_prefill_paged_kernel, q_per_group=q_per_group)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((B, C, H, dh), jnp.float32),
        grid=(B, G),
        interpret=True,
    )(offset, block_table, q, k_pool, v_pool)


def dense_decode_attention(q, k, v, lengths, q_per_group: int = 1,
                           blk: int = DEFAULT_BLK):
    """Dense baseline through the *same* kernel (identity head index).

    This is the "standard FlashAttention" the paper compares against: the
    identical inner loop, all G groups active, so kernel-level speedup
    reflects head sparsity alone (Fig 3b protocol).
    """
    B = q.shape[0]
    G = k.shape[1]
    idx = jnp.broadcast_to(jnp.arange(G, dtype=jnp.int32)[None, :], (B, G))
    return sha_decode(q, k, v, idx, lengths, q_per_group, blk)
