"""Sparse fused GEMM kernels (paper Algorithm 3).

Two Pallas kernels covering both halves of the selective MLP block:

  * ``sel_gemm_nt``: C[M,S] = act(A[M,K] @ gather(W[D,K], I).T)  (up-proj)
  * ``sel_gemm_nn``: C[M,K] = H[M,S] @ gather(W[D,K], I)          (down-proj)

The gather of active-neuron rows is fused with the block-wise matmul — no
separate gather-scatter pass, no [S,K] temporary in HBM (the paper's core
kernel claim). Weights are stored neuron-major ([D, K], one contiguous row
per neuron) so each gathered row is a single coalesced read — on TPU, one
contiguous HBM->VMEM DMA per neuron row.

Grid layout: (M-blocks, S-blocks) for nt; (M-blocks,) with an S-loop for nn
(the down-projection reduces *over* the sparse dimension, so one program
owns a full output row-block to avoid cross-program accumulation).

interpret=True as everywhere (CPU PJRT has no Mosaic); correctness vs
``ref.sel_gemm_*_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 16   # M tile
DEFAULT_BS = 32   # sparse-neuron tile


def _nt_kernel(i_ref, a_ref, w_ref, o_ref, *, bm, bs, activation):
    mi = pl.program_id(0)
    si = pl.program_id(1)
    K = a_ref.shape[1]

    a = a_ref[pl.ds(mi * bm, bm), :]                  # [bm, K]
    idx = i_ref[pl.ds(si * bs, bs)]                   # [bs]

    # Fused gather: pull the bs active neuron rows straight into the tile.
    def gather_row(j, acc):
        acc = acc.at[j, :].set(w_ref[idx[j], :])
        return acc

    w = jax.lax.fori_loop(0, bs, gather_row, jnp.zeros((bs, K), jnp.float32))
    c = jnp.dot(a, w.T)                               # [bm, bs]
    if activation == "relu":
        c = jnp.maximum(c, 0.0)
    o_ref[pl.ds(mi * bm, bm), pl.ds(si * bs, bs)] = c


def _nn_kernel(i_ref, h_ref, w_ref, o_ref, *, bm, bs):
    mi = pl.program_id(0)
    S = h_ref.shape[1]
    K = w_ref.shape[1]
    h = h_ref[pl.ds(mi * bm, bm), :]                  # [bm, S]
    nblk = S // bs

    def outer(si, acc):
        idx = i_ref[pl.ds(si * bs, bs)]

        def gather_row(j, wacc):
            return wacc.at[j, :].set(w_ref[idx[j], :])

        w = jax.lax.fori_loop(0, bs, gather_row, jnp.zeros((bs, K), jnp.float32))
        hs = jax.lax.dynamic_slice(h, (0, si * bs), (bm, bs))  # [bm, bs]
        return acc + jnp.dot(hs, w)

    o = jax.lax.fori_loop(0, nblk, outer, jnp.zeros((bm, K), jnp.float32))
    o_ref[pl.ds(mi * bm, bm), :] = o


def _nt_bias_kernel(i_ref, b_ref, a_ref, w_ref, o_ref, *, bm, bs, activation):
    mi = pl.program_id(0)
    si = pl.program_id(1)
    K = a_ref.shape[1]

    a = a_ref[pl.ds(mi * bm, bm), :]                  # [bm, K]
    idx = i_ref[pl.ds(si * bs, bs)]                   # [bs]

    # Gather the active neuron rows AND their biases in one pass; bias add
    # and activation happen on the tile while it is still in registers —
    # no elementwise shell over an [M, S] temporary.
    def gather_row(j, carry):
        w, bias = carry
        return (w.at[j, :].set(w_ref[idx[j], :]),
                bias.at[j].set(b_ref[idx[j]]))

    w, bias = jax.lax.fori_loop(
        0, bs, gather_row,
        (jnp.zeros((bs, K), jnp.float32), jnp.zeros((bs,), jnp.float32)))
    c = jnp.dot(a, w.T) + bias[None, :]               # [bm, bs]
    if activation == "relu":
        c = jnp.maximum(c, 0.0)
    o_ref[pl.ds(mi * bm, bm), pl.ds(si * bs, bs)] = c


def _nn_bias_kernel(i_ref, b_ref, h_ref, w_ref, o_ref, *, bm, bs):
    mi = pl.program_id(0)
    S = h_ref.shape[1]
    K = w_ref.shape[1]
    h = h_ref[pl.ds(mi * bm, bm), :]                  # [bm, S]
    nblk = S // bs

    def outer(si, acc):
        idx = i_ref[pl.ds(si * bs, bs)]

        def gather_row(j, wacc):
            return wacc.at[j, :].set(w_ref[idx[j], :])

        w = jax.lax.fori_loop(0, bs, gather_row, jnp.zeros((bs, K), jnp.float32))
        hs = jax.lax.dynamic_slice(h, (0, si * bs), (bm, bs))  # [bm, bs]
        return acc + jnp.dot(hs, w)

    o = jax.lax.fori_loop(0, nblk, outer, jnp.zeros((bm, K), jnp.float32))
    # Output bias is dense over K: add it as the row-block is written out.
    o_ref[pl.ds(mi * bm, bm), :] = o + b_ref[:][None, :]


def _check(m, s, bm, bs):
    if m % bm != 0:
        raise ValueError(f"M={m} not a multiple of bm={bm}")
    if s % bs != 0:
        raise ValueError(f"S={s} not a multiple of bs={bs}")


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bs"))
def sel_gemm_nt(a, w, index, activation: str = "none",
                bm: int = DEFAULT_BM, bs: int = DEFAULT_BS):
    """C = act(a @ gather(w, index).T); a:[M,K], w:[D,K], index:[S] -> [M,S]."""
    M, K = a.shape
    S = index.shape[0]
    bm = min(bm, M)
    bs = min(bs, S)
    _check(M, S, bm, bs)
    kernel = functools.partial(_nt_kernel, bm=bm, bs=bs, activation=activation)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((M, S), jnp.float32),
        grid=(M // bm, S // bs),
        interpret=True,
    )(index, a, w)


@functools.partial(jax.jit, static_argnames=("bm", "bs"))
def sel_gemm_nn(h, w, index, bm: int = DEFAULT_BM, bs: int = DEFAULT_BS):
    """C = h @ gather(w, index); h:[M,S], w:[D,K], index:[S] -> [M,K]."""
    M, S = h.shape
    bm = min(bm, M)
    bs = min(bs, S)
    _check(M, S, bm, bs)
    kernel = functools.partial(_nn_kernel, bm=bm, bs=bs)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((M, w.shape[1]), jnp.float32),
        grid=(M // bm,),
        interpret=True,
    )(index, h, w)


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bs"))
def sel_gemm_nt_bias(a, w, b, index, activation: str = "none",
                     bm: int = DEFAULT_BM, bs: int = DEFAULT_BS):
    """C = act(a @ gather(w, index).T + gather(b, index)); bias fused."""
    M, K = a.shape
    S = index.shape[0]
    bm = min(bm, M)
    bs = min(bs, S)
    _check(M, S, bm, bs)
    kernel = functools.partial(_nt_bias_kernel, bm=bm, bs=bs,
                               activation=activation)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((M, S), jnp.float32),
        grid=(M // bm, S // bs),
        interpret=True,
    )(index, b, a, w)


@functools.partial(jax.jit, static_argnames=("bm", "bs"))
def sel_gemm_nn_bias(h, w, b, index, bm: int = DEFAULT_BM,
                     bs: int = DEFAULT_BS):
    """C = h @ gather(w, index) + b; dense output bias fused."""
    M, S = h.shape
    bm = min(bm, M)
    bs = min(bs, S)
    _check(M, S, bm, bs)
    kernel = functools.partial(_nn_bias_kernel, bm=bm, bs=bs)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((M, w.shape[1]), jnp.float32),
        grid=(M // bm,),
        interpret=True,
    )(index, b, h, w)


def sparse_mlp(x, w1, b1, w2, b2, index, bm: int = DEFAULT_BM,
               bs: int = DEFAULT_BS):
    """Full selective MLP block via the fused kernels (OPT/ReLU path)."""
    h = sel_gemm_nt(x, w1, index, activation="none", bm=bm, bs=bs)
    h = jnp.maximum(h + jnp.take(b1, index)[None, :], 0.0)
    return sel_gemm_nn(h, w2, index, bm=bm, bs=bs) + b2[None, :]


def sparse_mlp_fused(x, w1, b1, w2, b2, index, bm: int = DEFAULT_BM,
                     bs: int = DEFAULT_BS):
    """Selective MLP with biases and activation fused into the kernels.

    Same math as ``sparse_mlp`` but the selected rows are computed and
    written in place: no elementwise shells between the two GEMMs, no
    second pass over the [M, S] hidden tile.
    """
    h = sel_gemm_nt_bias(x, w1, b1, index, activation="relu", bm=bm, bs=bs)
    return sel_gemm_nn_bias(h, w2, b2, index, bm=bm, bs=bs)
