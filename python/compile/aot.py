"""AOT lowering: every serving entry point -> HLO text + manifest.json.

Interchange is HLO *text* (not serialized HloModuleProto): jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Every entry is lowered with ``keep_unused=True`` so the parameter list is
always: data inputs (entry-specific, in order) followed by the full weight
set sorted by name — one calling convention for the whole runtime.

Entries per model (static shapes = the CUDA-graph analogue, DESIGN.md):
  prefill_b{B}_s{S}                  chunked prompt pass: appends one chunk
                                     (up to PREFILL_LEN tokens/slot) into a
                                     [*,S] cache at a per-slot offset
  prefill_b{B}_s{S}_paged_fused      fused paged prefill chunk: resolves
                                     prior-context KV through a per-slot
                                     block table and writes the chunk's new
                                     K/V rows straight into their pool
                                     blocks — no dense [*,S] intermediate
  decode_{tag}_b{B}_n{N}             tag in dense | dejavu | polar_dXXXX |
                                     teal_dXXXX | cats_dXXXX
  decode_{tag}_b{B}_n{N}_paged_fused fused paged decode (tokens, lengths,
                                     block_table, kv-pool[, head_idx[,
                                     mlp_idx]]): the kernel indexes the
                                     block table itself and only the new KV
                                     row is written — no dense intermediate
  copy_blocks                        on-device COW: copies fixed-width
                                     (src, dst) block-pair lists inside the
                                     resident pool ((0,0) pads are identity)
  micro_* (opt-small)                Fig 1a / Fig 3 / Fig 10 module benches
  pp2_stage{0,1}_*_paged_fused       pipeline-parallel stages over per-stage
                                     pool slices + block tables (Fig 11)
  tp{S}_attn_s{s}_*_paged_fused      TP attention shards over per-shard pool
                                     slices; dense | sha (local head_idx,
                                     sentinel-dropped) | kvw (KV-write-only
                                     dispatch for router-skipped shards)
  tp{S}_mlp_s{s}_*                   biasless TP MLP shards (k* takes local
                                     mlp_idx, sentinel-masked)
  tp{S}_{attn,mlp}_reduce_b{B}       on-device all-reduce: residual + Σ
                                     partials + the bias shards omit

Usage: python -m compile.aot [--models a,b] [--sets core,micro,pp,tp]
       [--out ../artifacts]
"""

import argparse
import json
import os
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .configs import (
    BATCH_BUCKETS, CONFIGS, COPY_BLOCKS_PAIRS, DEFAULT_RECALL, DENSITY_SWEEP,
    KV_BLOCK, PREFILL_LEN, SEQ_BUCKETS, get_config, heads_for_density,
    kv_pool_blocks,
)
from .kernels import ref as kref
from .kernels import sel_gemm, sha_decode

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}
MICRO_LAYER = 1  # the layer micro-entries exercise


@dataclass
class Entry:
    name: str
    kind: str
    fn: object
    data: list          # [{"name","shape","dtype"}...] in call order
    outputs: list       # [{"name","shape","dtype"}...] of the result tuple
    meta: dict = field(default_factory=dict)


def dshape(cfg, B, N):
    return [cfg.n_layers, 2, B, cfg.n_kv_heads, N, cfg.d_head]


def pool_shape(cfg, P):
    """Paged KV pool [L,2,P,G,KV_BLOCK,dh] — one shape per model, shared
    by every paged entry (block tables address it per call)."""
    return list(model.kv_pool_shape(cfg, P, KV_BLOCK))


def serving_buckets(cfg):
    """(batch, seq) bucket lists the serving entries cover. The
    accuracy-only model compiles a single bucket pair."""
    small = cfg.name == "llama-relu"
    return ([1] if small else BATCH_BUCKETS), ([128] if small else SEQ_BUCKETS)


def dtag(density):
    return f"d{int(round(density * 1000)):04d}"


def load_topk(out_dir, cfg, B):
    path = os.path.join(out_dir, cfg.name, "topk_table.json")
    if not cfg.mlp_sparsity or not os.path.exists(path):
        return ()
    with open(path) as f:
        table = json.load(f)
    return tuple(table["recall_targets"][str(DEFAULT_RECALL)][str(B)])


# ---------------------------------------------------------------------------
# Entry builders
# ---------------------------------------------------------------------------


def core_entries(cfg, out_dir):
    """prefill + decode matrix."""
    V, L, G, dh = cfg.vocab, cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    entries = []
    batches, seqs = serving_buckets(cfg)
    P = kv_pool_blocks(batches, seqs)

    # chunked prefill: one entry per (batch, seq) bucket. Each call appends
    # up to PREFILL_LEN prompt tokens per slot into the group cache at a
    # per-slot position offset, so a long prompt streams chunk by chunk
    # while co-resident requests keep decoding between chunks. The fused
    # paged variant addresses the shared block pool through a per-slot
    # block table — chunk K/V rows land straight in their pool blocks and
    # prior context is read through the table, never a dense [*, S] view.
    for B in batches:
        for S in seqs:
            entries.append(Entry(
                name=f"prefill_b{B}_s{S}", kind="prefill",
                fn=(lambda cfg_: lambda toks, lens, off, kv, params:
                    model.prefill_chunk(cfg_, params, toks, lens, off, kv))(cfg),
                data=[
                    {"name": "tokens", "shape": [B, PREFILL_LEN], "dtype": "i32"},
                    {"name": "lengths", "shape": [B], "dtype": "i32"},
                    {"name": "offset", "shape": [B], "dtype": "i32"},
                    {"name": "kv", "shape": dshape(cfg, B, S), "dtype": "f32"},
                ],
                outputs=[
                    {"name": "logits", "shape": [B, V], "dtype": "f32"},
                    {"name": "kv", "shape": dshape(cfg, B, S), "dtype": "f32"},
                ],
                meta={"batch": B, "seq_bucket": S, "chunk": PREFILL_LEN},
            ))
            entries.append(Entry(
                name=f"prefill_b{B}_s{S}_paged_fused", kind="prefill_paged_fused",
                fn=(lambda cfg_: lambda toks, lens, off, table, kv, params:
                    model.prefill_chunk_paged_fused(
                        cfg_, params, toks, lens, off, table, kv))(cfg),
                data=[
                    {"name": "tokens", "shape": [B, PREFILL_LEN], "dtype": "i32"},
                    {"name": "lengths", "shape": [B], "dtype": "i32"},
                    {"name": "offset", "shape": [B], "dtype": "i32"},
                    {"name": "block_table", "shape": [B, S // KV_BLOCK],
                     "dtype": "i32"},
                    {"name": "kv", "shape": pool_shape(cfg, P), "dtype": "f32"},
                ],
                outputs=[
                    {"name": "logits", "shape": [B, V], "dtype": "f32"},
                    {"name": "kv", "shape": pool_shape(cfg, P), "dtype": "f32"},
                ],
                meta={"batch": B, "seq_bucket": S, "chunk": PREFILL_LEN,
                      "kv_block": KV_BLOCK, "kv_pool_blocks": P,
                      "fused": True},
            ))

    def decode_entry(B, N, mode, density, mlp_topk, tag, paged=False):
        # polar entries are *index-taking*: the runtime routing subsystem
        # (rust/src/runtime/router.rs) computes per-request top-k head
        # groups and the batch-union MLP neuron set each step and feeds
        # them in as data inputs, so the contextual selection lives in
        # the serving loop (and is measurable there), not in the graph.
        # Kh = heads per request at `density`; Km = the union capacity
        # (max calibrated per-layer top-k — a superset only improves
        # recall, and one static width keeps the entry shape fixed).
        routed = mode == "polar"
        Kh = heads_for_density(cfg, density) if routed else 0
        Km = int(max(mlp_topk)) if (routed and cfg.mlp_sparsity and mlp_topk) else 0
        kvshape = pool_shape(cfg, P) if paged else dshape(cfg, B, N)
        data = [
            {"name": "tokens", "shape": [B], "dtype": "i32"},
            {"name": "lengths", "shape": [B], "dtype": "i32"},
        ]
        if paged:
            data.append({"name": "block_table", "shape": [B, N // KV_BLOCK],
                         "dtype": "i32"})
        data.append({"name": "kv", "shape": kvshape, "dtype": "f32"})
        if routed:
            data.append({"name": "head_idx", "shape": [L, B, Kh], "dtype": "i32"})
            if Km:
                data.append({"name": "mlp_idx", "shape": [L, Km], "dtype": "i32"})

        def mk_fn(cfg_, m, d, tk):
            kw = dict(mode=m, density=d, mlp_topk=tk)
            if paged:
                # paged decode is fused-only: the kernel indexes the block
                # table itself and only the new KV row is written — no
                # dense intermediate, no scatter.
                step = model.decode_step_paged_fused
                if routed and Km:
                    return lambda toks, lens, table, kv, hi, mi, params: \
                        step(cfg_, params, toks, lens, kv,
                             table, head_idx=hi, mlp_idx=mi, **kw)
                if routed:
                    return lambda toks, lens, table, kv, hi, params: \
                        step(cfg_, params, toks, lens, kv,
                             table, head_idx=hi, **kw)
                return lambda toks, lens, table, kv, params: \
                    step(cfg_, params, toks, lens, kv, table, **kw)
            if routed and Km:
                return lambda toks, lens, kv, hi, mi, params: \
                    model.decode_step(cfg_, params, toks, lens, kv,
                                      head_idx=hi, mlp_idx=mi, **kw)
            if routed:
                return lambda toks, lens, kv, hi, params: \
                    model.decode_step(cfg_, params, toks, lens, kv,
                                      head_idx=hi, **kw)
            return lambda toks, lens, kv, params: \
                model.decode_step(cfg_, params, toks, lens, kv, **kw)

        meta = {"batch": B, "seq_bucket": N, "mode": mode,
                "density": density, "mlp_topk": list(mlp_topk),
                "routed": routed, "head_k": Kh, "mlp_idx_k": Km}
        if paged:
            meta.update({"kv_block": KV_BLOCK, "kv_pool_blocks": P,
                         "fused": True})
        suffix = "_paged_fused" if paged else ""
        kind = "decode_paged_fused" if paged else "decode"
        return Entry(
            name=f"decode_{tag}_b{B}_n{N}" + suffix,
            kind=kind,
            fn=mk_fn(cfg, mode, density, mlp_topk),
            data=data,
            outputs=[
                {"name": "logits", "shape": [B, V], "dtype": "f32"},
                {"name": "kv", "shape": kvshape, "dtype": "f32"},
            ],
            meta=meta,
        )

    for B in batches:
        topk = load_topk(out_dir, cfg, B)
        for N in seqs:
            # each serving tag lands twice: the contiguous entry (A/B
            # baseline, eval and the pp/tp drivers) and the fused paged
            # entry the scheduler serves from
            for paged in (False, True):
                entries.append(decode_entry(B, N, "dense", 1.0, (), "dense",
                                            paged=paged))
                entries.append(decode_entry(
                    B, N, "polar", cfg.critical_density, topk,
                    f"polar_{dtag(cfg.critical_density)}", paged=paged))
                if cfg.mlp_sparsity:
                    entries.append(decode_entry(B, N, "dejavu", 1.0, topk,
                                                "dejavu", paged=paged))

    # on-device COW: one fixed-width block-pair copy entry per model. The
    # engine chunks a COW batch into COPY_BLOCKS_PAIRS-wide calls (padding
    # with (0,0) identity pairs), so the pool never round-trips the host.
    entries.append(Entry(
        name="copy_blocks", kind="copy_blocks",
        fn=lambda src, dst, kv, params: (model.copy_blocks(kv, src, dst),),
        data=[
            {"name": "src", "shape": [COPY_BLOCKS_PAIRS], "dtype": "i32"},
            {"name": "dst", "shape": [COPY_BLOCKS_PAIRS], "dtype": "i32"},
            {"name": "kv", "shape": pool_shape(cfg, P), "dtype": "f32"},
        ],
        outputs=[
            {"name": "kv", "shape": pool_shape(cfg, P), "dtype": "f32"},
        ],
        meta={"pairs": COPY_BLOCKS_PAIRS, "kv_block": KV_BLOCK,
              "kv_pool_blocks": P},
    ))

    # accuracy sweep at B=1, N=128
    if cfg.name != "llama-relu":
        topk1 = load_topk(out_dir, cfg, 1)
        for d in DENSITY_SWEEP:
            if abs(d - cfg.critical_density) < 1e-9:
                continue  # already built
            entries.append(decode_entry(1, 128, "polar", d, topk1,
                                        f"polar_{dtag(d)}"))
        if cfg.name == "llama-tiny":
            for m in ("teal", "cats"):
                for d in (0.25, 0.5, 0.75):
                    entries.append(decode_entry(1, 128, m, d, (),
                                                f"{m}_{dtag(d)}"))
    return entries


def micro_entries(cfg, out_dir):
    """Module-level entries for Figs 1a / 3 / 10 (layer MICRO_LAYER)."""
    d, H, G, dh, Dff, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                           cfg.d_head, cfg.d_ff, cfg.n_layers)
    qpg = cfg.q_per_group
    l = MICRO_LAYER
    N = 256
    entries = []

    def data(**kw):
        return [{"name": k, "shape": list(v[0]), "dtype": v[1]}
                for k, v in kw.items()]

    for B in (1, 4, 16):
        xB = ([B, d], "f32")
        entries.append(Entry(
            f"micro_qkv_b{B}", "micro",
            (lambda c: lambda x, params: (
                x @ params["wq"][l] + params["bq"][l],
                x @ params["wk"][l] + params["bk"][l],
                x @ params["wv"][l] + params["bv"][l],
            ))(cfg),
            data(x=xB),
            [{"name": "q", "shape": [B, H * dh], "dtype": "f32"},
             {"name": "k", "shape": [B, G * dh], "dtype": "f32"},
             {"name": "v", "shape": [B, G * dh], "dtype": "f32"}],
            {"batch": B},
        ))
        entries.append(Entry(
            f"micro_out_proj_b{B}", "micro",
            (lambda c: lambda o, params: (o @ params["wo"][l] + params["bo"][l],))(cfg),
            data(o=([B, H * dh], "f32")),
            [{"name": "out", "shape": [B, d], "dtype": "f32"}],
            {"batch": B},
        ))
        entries.append(Entry(
            f"micro_mlp_dense_b{B}", "micro",
            (lambda c: lambda x, params: (model.mlp_dense(c, params, l, x),))(cfg),
            data(x=xB),
            [{"name": "out", "shape": [B, d], "dtype": "f32"}],
            {"batch": B},
        ))
        entries.append(Entry(
            f"micro_router_mlp_b{B}", "micro",
            (lambda c: lambda x, params: (model.mlp_router_logits(params, l, x),))(cfg),
            data(x=xB),
            [{"name": "logits", "shape": [B, Dff], "dtype": "f32"}],
            {"batch": B},
        ))
        entries.append(Entry(
            f"micro_router_attn_b{B}", "micro",
            (lambda c: lambda x, params: (model.attn_router_logits(params, l, x),))(cfg),
            data(x=xB),
            [{"name": "logits", "shape": [B, G], "dtype": "f32"}],
            {"batch": B},
        ))
        # dense attention core (xla) for Fig 1a breakdown
        entries.append(Entry(
            f"micro_attn_dense_b{B}_n{N}", "micro",
            (lambda c: lambda q, k, v, lens, params: (
                kref.dense_decode_attention_ref(q, k, v, lens, c.q_per_group),))(cfg),
            data(q=([B, H, dh], "f32"), k=([B, G, N, dh], "f32"),
                 v=([B, G, N, dh], "f32"), lengths=([B], "i32")),
            [{"name": "o", "shape": [B, H, dh], "dtype": "f32"}],
            {"batch": B, "seq_bucket": N},
        ))

    # Fig 3 kernel sweeps at B=16
    B = 16
    for K in sorted({max(1, G // 4), max(1, G // 2), max(1, 3 * G // 4), G}):
        for impl, tag in (("xla", "xla"), ("pallas", "pallas")):
            fn = (lambda c, im: lambda q, k, v, lens, hi, params: (
                (sha_decode.sha_decode if im == "pallas" else kref.sha_decode_ref)(
                    q, k, v, hi, lens, c.q_per_group),))(cfg, impl)
            entries.append(Entry(
                f"micro_attn_sha_{tag}_k{K}_b{B}_n{N}", "micro", fn,
                data(q=([B, H, dh], "f32"), k=([B, G, N, dh], "f32"),
                     v=([B, G, N, dh], "f32"), lengths=([B], "i32"),
                     head_index=([B, K], "i32")),
                [{"name": "o", "shape": [B, K * qpg, dh], "dtype": "f32"}],
                {"batch": B, "seq_bucket": N, "top_k": K, "impl": tag},
            ))
    for K in sorted({Dff // 8, Dff // 4, Dff // 2, 3 * Dff // 4, Dff}):
        for impl, tag in (("xla", "xla"), ("pallas", "pallas")):
            fn = (lambda c, im, kk: lambda x, idx, params: (
                (sel_gemm.sparse_mlp if im == "pallas" else kref.sparse_mlp_ref)(
                    x, params["w1"][l], params["b1"][l],
                    params["w2"][l], params["b2"][l], idx),))(cfg, impl, K)
            entries.append(Entry(
                f"micro_mlp_sparse_{tag}_k{K}_b{B}", "micro", fn,
                data(x=([B, d], "f32"), index=([K], "i32")),
                [{"name": "out", "shape": [B, d], "dtype": "f32"}],
                {"batch": B, "top_k": K, "impl": tag},
            ))
    return entries


def pp_entries(cfg, out_dir):
    """Two-stage pipeline-parallel decode over per-stage pool slices
    (Fig 11). Each stage owns a resident pool [Lstage,2,P,G,bs,dh] — the
    layer split of the single-device pool — addressed by the same block
    tables; the stage-0 -> 1 activation x [B,d] stays a device buffer.
    Polar stages are index-taking like the core decode entries: the full
    head_idx [L,B,Kh] (+ mlp_idx [L,Km]) rides to both stages, each reads
    its own layers' rows."""
    V, L, G, dh, d = cfg.vocab, cfg.n_layers, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    Lh = L // 2
    N = 256
    batches, seqs = serving_buckets(cfg)
    P = kv_pool_blocks(batches, seqs)
    W = N // KV_BLOCK
    entries = []
    modes = [("dense", 1.0), ("polar", cfg.critical_density)]
    for B in BATCH_BUCKETS:
        topk = load_topk(out_dir, cfg, B)
        for mode, density in modes:
            routed = mode == "polar"
            Kh = heads_for_density(cfg, density) if routed else 0
            Km = int(max(topk)) if (routed and cfg.mlp_sparsity and topk) else 0
            tag = "dense" if mode == "dense" else f"polar_{dtag(density)}"
            kv0 = [Lh, 2, P, G, KV_BLOCK, dh]
            kv1 = [L - Lh, 2, P, G, KV_BLOCK, dh]
            idx_data = []
            if routed:
                idx_data.append({"name": "head_idx", "shape": [L, B, Kh],
                                 "dtype": "i32"})
                if Km:
                    idx_data.append({"name": "mlp_idx", "shape": [L, Km],
                                     "dtype": "i32"})

            def mk_stage(c, m, dn, tk, begin, end, stage):
                kw = dict(layer_begin=begin, layer_end=end, mode=m,
                          density=dn, mlp_topk=tk)

                def core(x, lens, table, kv, hi, mi, params):
                    x, kv = model.decode_core_paged(
                        c, params, x, lens, kv, table,
                        head_idx=hi, mlp_idx=mi, **kw)
                    if stage == 1:
                        return model.final_logits(c, params, x), kv
                    return x, kv

                def stage0(toks, lens, table, kv, hi, mi, params):
                    x = model._embed(c, params, toks, lens - 1)
                    return core(x, lens, table, kv, hi, mi, params)

                inner = stage0 if stage == 0 else core
                if m == "polar" and Km:
                    return lambda a, lens, table, kv, hi, mi, params: \
                        inner(a, lens, table, kv, hi, mi, params)
                if m == "polar":
                    return lambda a, lens, table, kv, hi, params: \
                        inner(a, lens, table, kv, hi, None, params)
                return lambda a, lens, table, kv, params: \
                    inner(a, lens, table, kv, None, None, params)

            meta = {"batch": B, "seq_bucket": N, "mode": mode,
                    "density": density, "routed": routed, "head_k": Kh,
                    "mlp_idx_k": Km, "kv_block": KV_BLOCK,
                    "kv_pool_blocks": P, "fused": True}
            entries.append(Entry(
                f"pp2_stage0_{tag}_b{B}_n{N}_paged_fused",
                "pp_stage0_paged_fused",
                mk_stage(cfg, mode, density, topk, 0, Lh, 0),
                [{"name": "tokens", "shape": [B], "dtype": "i32"},
                 {"name": "lengths", "shape": [B], "dtype": "i32"},
                 {"name": "block_table", "shape": [B, W], "dtype": "i32"},
                 {"name": "kv", "shape": kv0, "dtype": "f32"}] + idx_data,
                [{"name": "x", "shape": [B, d], "dtype": "f32"},
                 {"name": "kv", "shape": kv0, "dtype": "f32"}],
                dict(meta, stage=0, layers=[0, Lh]),
            ))
            entries.append(Entry(
                f"pp2_stage1_{tag}_b{B}_n{N}_paged_fused",
                "pp_stage1_paged_fused",
                mk_stage(cfg, mode, density, topk, Lh, L, 1),
                [{"name": "x", "shape": [B, d], "dtype": "f32"},
                 {"name": "lengths", "shape": [B], "dtype": "i32"},
                 {"name": "block_table", "shape": [B, W], "dtype": "i32"},
                 {"name": "kv", "shape": kv1, "dtype": "f32"}] + idx_data,
                [{"name": "logits", "shape": [B, V], "dtype": "f32"},
                 {"name": "kv", "shape": kv1, "dtype": "f32"}],
                dict(meta, stage=1, layers=[Lh, L]),
            ))
    return entries


def tp_entries(cfg, out_dir, n_shards: int):
    """Megatron-style TP shard entries over per-shard pool slices (Fig 12).

    Each shard owns a resident pool [L,2,P,Gs,bs,dh] — the group-axis
    split of the single-device pool — addressed by the shared block
    tables. Shard entries are biasless; the per-layer reduce entries own
    the residual + bias, so a router-skipped shard contributes a zero
    buffer and only runs the KV-write-only ``kvw`` entry. ``sha``/``k*``
    entries take per-shard LOCAL indices (sentinel Gs/Ds = unselected).
    """
    V, L, G, dh, d, H = (cfg.vocab, cfg.n_layers, cfg.n_kv_heads, cfg.d_head,
                         cfg.d_model, cfg.n_heads)
    if G % n_shards or H % n_shards or cfg.d_ff % n_shards:
        return []
    Gs = G // n_shards
    Ds = cfg.d_ff // n_shards
    Ks = min(heads_for_density(cfg, cfg.critical_density), Gs)
    N = 256
    batches, seqs = serving_buckets(cfg)
    P = kv_pool_blocks(batches, seqs)
    W = N // KV_BLOCK
    pshape = [L, 2, P, Gs, KV_BLOCK, dh]
    entries = []
    for B in (1, 4, 16):
        topk = load_topk(out_dir, cfg, B)
        Kms = min(int(max(topk)), Ds) if (cfg.mlp_sparsity and topk) else 0
        entries.append(Entry(
            f"tp{n_shards}_embed_b{B}", "tp_embed",
            (lambda c: lambda toks, lens, params: (model.tp_embed(c, params, toks, lens),))(cfg),
            [{"name": "tokens", "shape": [B], "dtype": "i32"},
             {"name": "lengths", "shape": [B], "dtype": "i32"}],
            [{"name": "x", "shape": [B, d], "dtype": "f32"}],
            {"batch": B, "n_shards": n_shards},
        ))
        entries.append(Entry(
            f"tp{n_shards}_final_b{B}", "tp_final",
            (lambda c: lambda x, params: (model.tp_final(c, params, x),))(cfg),
            [{"name": "x", "shape": [B, d], "dtype": "f32"}],
            [{"name": "logits", "shape": [B, V], "dtype": "f32"}],
            {"batch": B, "n_shards": n_shards},
        ))
        for op in ("attn", "mlp"):
            fn = (lambda c, o: lambda layer, x, *rest: (
                (model.tp_attn_reduce if o == "attn" else model.tp_mlp_reduce)(
                    c, rest[-1], layer, x, list(rest[:-1])),))(cfg, op)
            entries.append(Entry(
                f"tp{n_shards}_{op}_reduce_b{B}", "tp_reduce", fn,
                [{"name": "layer", "shape": [], "dtype": "i32"},
                 {"name": "x", "shape": [B, d], "dtype": "f32"}]
                + [{"name": f"p{s}", "shape": [B, d], "dtype": "f32"}
                   for s in range(n_shards)],
                [{"name": "x", "shape": [B, d], "dtype": "f32"}],
                {"batch": B, "n_shards": n_shards, "op": op},
            ))
        for s in range(n_shards):
            attn_modes = [
                ("dense", "dense", 1.0, 0),
                ("sha", f"sha_{dtag(cfg.critical_density)}",
                 cfg.critical_density, Ks),
                ("kvw", "kvw", 0.0, 0),
            ]
            for amode, tag, dens, kk in attn_modes:
                def _mk(c, sh, md, ns):
                    def fn(layer, x, lens, table, kv, *rest):
                        hi = rest[0] if md == "sha" else None
                        params = rest[-1]
                        out = model.tp_attn_shard_paged(
                            c, params, layer, x, lens, table, kv,
                            shard=sh, n_shards=ns, mode=md, head_idx=hi)
                        return (out,) if md == "kvw" else out
                    return fn
                data = [{"name": "layer", "shape": [], "dtype": "i32"},
                        {"name": "x", "shape": [B, d], "dtype": "f32"},
                        {"name": "lengths", "shape": [B], "dtype": "i32"},
                        {"name": "block_table", "shape": [B, W], "dtype": "i32"},
                        {"name": "kv", "shape": pshape, "dtype": "f32"}]
                if amode == "sha":
                    data.append({"name": "head_idx", "shape": [B, Ks],
                                 "dtype": "i32"})
                outputs = ([] if amode == "kvw" else
                           [{"name": "partial", "shape": [B, d], "dtype": "f32"}])
                outputs.append({"name": "kv", "shape": pshape, "dtype": "f32"})
                entries.append(Entry(
                    f"tp{n_shards}_attn_s{s}_{tag}_b{B}_n{N}_paged_fused",
                    "tp_attn", _mk(cfg, s, amode, n_shards), data, outputs,
                    {"batch": B, "seq_bucket": N, "shard": s,
                     "n_shards": n_shards, "mode": amode, "density": dens,
                     "head_k": kk, "kv_block": KV_BLOCK, "kv_pool_blocks": P,
                     "fused": True},
                ))
            mlp_modes = [("dense", 0)]
            if Kms:
                mlp_modes.append((f"k{Kms}", Kms))
            for k_mode, kk in mlp_modes:
                def _mk_mlp(c, sh, kk_, ns):
                    def fn(layer, x, *rest):
                        mi = rest[0] if kk_ else None
                        params = rest[-1]
                        return (model.tp_mlp_shard(
                            c, params, layer, x, shard=sh, n_shards=ns,
                            mlp_idx=mi),)
                    return fn
                data = [{"name": "layer", "shape": [], "dtype": "i32"},
                        {"name": "x", "shape": [B, d], "dtype": "f32"}]
                if kk:
                    data.append({"name": "mlp_idx", "shape": [kk],
                                 "dtype": "i32"})
                entries.append(Entry(
                    f"tp{n_shards}_mlp_s{s}_{k_mode}_b{B}", "tp_mlp",
                    _mk_mlp(cfg, s, kk, n_shards), data,
                    [{"name": "partial", "shape": [B, d], "dtype": "f32"}],
                    {"batch": B, "shard": s, "n_shards": n_shards,
                     "top_k": kk},
                ))
    return entries


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def lower(cfg, entry: Entry, param_avals):
    data_avals = [
        jax.ShapeDtypeStruct(tuple(d["shape"]), DTYPES[d["dtype"]])
        for d in entry.data
    ]
    lowered = jax.jit(entry.fn, keep_unused=True).lower(*data_avals, param_avals)
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")),
        use_tuple_args=False, return_tuple=True,
    )
    return comp.as_hlo_text()


def build_model(name: str, out_root: str, sets: list):
    cfg = get_config(name)
    mdir = os.path.join(out_root, name)
    weights = dict(np.load(os.path.join(mdir, "model.npz")))
    param_names = sorted(weights)
    param_avals = {
        k: jax.ShapeDtypeStruct(v.shape, jnp.dtype(v.dtype)) for k, v in weights.items()
    }

    entries = []
    if "core" in sets:
        entries += core_entries(cfg, out_root)
    if "micro" in sets and name == "opt-small":
        entries += micro_entries(cfg, out_root)
    if "pp" in sets and name in ("opt-small", "llama-tiny"):
        entries += pp_entries(cfg, out_root)
    if "tp" in sets and name == "opt-small":
        entries += tp_entries(cfg, out_root, 2)
        entries += tp_entries(cfg, out_root, 4)

    hlo_dir = os.path.join(mdir, "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    manifest = {
        "model": name,
        "analogue": cfg.analogue,
        "config": {
            "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
            "d_ff": cfg.d_ff, "mlp": cfg.mlp, "pos": cfg.pos,
            "vocab": cfg.vocab, "max_seq": cfg.max_seq,
            "d_head": cfg.d_head, "critical_density": cfg.critical_density,
        },
        "params": [
            {"name": n, "shape": list(weights[n].shape),
             "dtype": str(weights[n].dtype)} for n in param_names
        ],
        # "prefill_chunk" is the chunk token width of the prefill_b{B}_s{S}
        # matrix; "prefill" is kept as a legacy alias for older runtimes.
        # "kv_block"/"kv_pool_blocks" pin the paged entries' pool geometry
        # ([L,2,kv_pool_blocks,G,kv_block,dh], block 0 reserved as null);
        # "copy_pairs" is the fixed (src, dst) width of the copy_blocks
        # entry (on-device COW).
        "buckets": {"batch": BATCH_BUCKETS, "seq": SEQ_BUCKETS,
                    "prefill": PREFILL_LEN, "prefill_chunk": PREFILL_LEN,
                    "kv_block": KV_BLOCK,
                    "kv_pool_blocks": kv_pool_blocks(*serving_buckets(cfg)),
                    "copy_pairs": COPY_BLOCKS_PAIRS},
        "entries": [],
    }
    t_total = time.time()
    for i, e in enumerate(entries):
        path = os.path.join(hlo_dir, f"{e.name}.hlo.txt")
        if not os.path.exists(path):
            t0 = time.time()
            text = lower(cfg, e, param_avals)
            with open(path, "w") as f:
                f.write(text)
            dt = time.time() - t0
        else:
            dt = 0.0
        manifest["entries"].append({
            "name": e.name, "kind": e.kind, "file": f"hlo/{e.name}.hlo.txt",
            "data": e.data, "outputs": e.outputs, "meta": e.meta,
        })
        if dt > 0:
            print(f"  [{name}] {i + 1}/{len(entries)} {e.name} ({dt:.1f}s)")
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[{name}] {len(entries)} entries in {time.time() - t_total:.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="all")
    ap.add_argument("--sets", default="core,micro,pp,tp")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    names = list(CONFIGS) if args.models == "all" else args.models.split(",")
    sets = args.sets.split(",")
    for name in names:
        build_model(name, args.out, sets)


if __name__ == "__main__":
    main()
