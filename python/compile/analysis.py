"""Model-analysis figures (build-time, like the paper's offline studies).

Emits results/*.csv for:
  fig1b — union MLP activation vs layer/batch (opt-small)       [§3.1]
  fig2a — perplexity vs oracle head sparsity (zoo)              [§3.2]
  fig2b — per-layer attention importance (zoo)                  [§3.2, [22]]
  fig7  — OPT-family union activations vs batch                 [App. B.1]
  fig8  — ReLUfied-LLaMA union activations vs batch             [App. B.1]
  fig9  — head-activation heat map counts                       [App. B.2]

Usage: python -m compile.analysis --out ../artifacts --results ../results
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model
from .configs import get_config

BATCHES = [1, 4, 16, 64]
N_TRIALS = 48


def write_csv(path, header, rows):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    print(f"[analysis] wrote {path} ({len(rows)} rows)")


def load_model(out, name):
    cfg = get_config(name)
    params = {k: jnp.asarray(v) for k, v in
              np.load(os.path.join(out, name, "model.npz")).items()}
    return cfg, params


def load_supervision(out, name):
    return dict(np.load(os.path.join(out, name, "supervision.npz")))


# ---------------------------------------------------------------------------
# Union activation studies (Figs 1b, 7, 8)
# ---------------------------------------------------------------------------


def union_rows(name, sup, rng):
    """Rows (model, batch, layer, union_frac_mean, union_frac_std)."""
    act = sup["mlp_active"]  # [L, n, Dff]
    L, n, dff = act.shape
    rows = []
    for b in BATCHES:
        idx = rng.integers(0, n, size=(N_TRIALS, b))
        for l in range(L):
            fr = act[l][idx].any(axis=1).mean(axis=1)  # [trials]
            rows.append((name, b, l, round(float(fr.mean()), 4),
                         round(float(fr.std()), 4)))
    return rows


# ---------------------------------------------------------------------------
# Fig 2a — perplexity vs oracle head sparsity
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "top_k"))
def _loss_headmask(cfg, params, tokens, top_k: int):
    """Full forward with only the top-k heads (by per-token output L2 norm)
    kept per layer (>0); layer 0 dense. Returns mean next-token NLL."""
    B, S1 = tokens.shape
    S = S1 - 1
    toks, targets = tokens[:, :-1], tokens[:, 1:]
    lengths = jnp.full((B,), S, jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = model._embed(cfg, params, toks, positions)
    for l in range(cfg.n_layers):
        h = model.layer_norm(x, params["ln1_g"][l], params["ln1_b"][l])
        q = (h @ params["wq"][l] + params["bq"][l]).reshape(B, S, cfg.n_heads, cfg.d_head)
        k = (h @ params["wk"][l] + params["bk"][l]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
        v = (h @ params["wv"][l] + params["bv"][l]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
        if cfg.pos == "rope":
            q = model.rope(q, positions, cfg.d_head)
            k = model.rope(k, positions, cfg.d_head)
        o = model._causal_attention(cfg, q, k, v, lengths)  # [B,S,H,dh]
        if l > 0 and top_k < cfg.n_heads:
            norms = jnp.linalg.norm(o, axis=-1)              # [B,S,H]
            kth = jnp.sort(norms, axis=-1)[..., -top_k][..., None]
            o = jnp.where((norms >= kth)[..., None], o, 0.0)
        x = x + o.reshape(B, S, -1) @ params["wo"][l] + params["bo"][l]
        h2 = model.layer_norm(x, params["ln2_g"][l], params["ln2_b"][l])
        x = x + model.mlp_dense(cfg, params, l, h2)
    x = model.layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["tok_emb"].T
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def fig2a_rows(name, cfg, params):
    ids = corpus.heldout_text_tokens(8 * 96 + 1)
    toks = ids[: 8 * 96 + 1]
    batch = np.stack([toks[i * 96:(i + 1) * 96 + 1] for i in range(8)])
    rows = []
    base = None
    for k in range(cfg.n_heads, 0, -1):
        nll = float(_loss_headmask(cfg, params, jnp.asarray(batch), k))
        ppl = float(np.exp(nll))
        if k == cfg.n_heads:
            base = ppl
        rows.append((name, k, round(k / cfg.n_heads, 3), round(ppl, 4),
                     round(ppl / base - 1.0, 4)))
    return rows


# ---------------------------------------------------------------------------
# Fig 2b — attention layer importance (score of [22]: 1 - cos(x, x+attn))
# ---------------------------------------------------------------------------


def fig2b_rows(name, cfg, params):
    stream = corpus.training_stream(424242, 4 * 96 + 1)
    batch = np.stack([stream[i * 96:(i + 1) * 96] for i in range(4)])
    lengths = jnp.full((4,), 96, jnp.int32)
    _, _, aux = model.forward_full(cfg, params, jnp.asarray(batch), lengths,
                                   collect=True)
    cos = np.asarray(aux["attn_cos"])  # [L,B,S]
    rows = []
    for l in range(cfg.n_layers):
        imp = 1.0 - float(cos[l].mean())
        rows.append((name, l, round(imp, 5)))
    return rows


# ---------------------------------------------------------------------------
# Fig 9 — head activation heat map
# ---------------------------------------------------------------------------


def fig9_rows(name, cfg, sup):
    norms = sup["head_norms"]  # [L, n, H]
    L, n, H = norms.shape
    k = max(1, H // 2)
    kth = np.sort(norms, axis=-1)[..., -k][..., None]
    active = norms >= kth
    rows = []
    for l in range(L):
        for h in range(H):
            rows.append((name, l, h, int(active[l, :, h].sum()), n))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--results", default="../results")
    args = ap.parse_args()
    rng = np.random.default_rng(0)
    R = args.results

    # Fig 1b (opt-small) + Fig 7 (OPT family) + Fig 8 (ReLUfied llama)
    write_csv(os.path.join(R, "fig1b.csv"),
              ["model", "batch", "layer", "union_frac", "union_std"],
              union_rows("opt-small", load_supervision(args.out, "opt-small"), rng))
    rows7 = []
    for m in ("opt-tiny", "opt-small"):
        rows7 += union_rows(m, load_supervision(args.out, m), rng)
    write_csv(os.path.join(R, "fig7.csv"),
              ["model", "batch", "layer", "union_frac", "union_std"], rows7)
    write_csv(os.path.join(R, "fig8.csv"),
              ["model", "batch", "layer", "union_frac", "union_std"],
              union_rows("llama-relu", load_supervision(args.out, "llama-relu"), rng))

    # Fig 2a + 2b across the zoo
    rows2a, rows2b = [], []
    for m in ("opt-tiny", "opt-small", "llama-tiny", "llama-gqa"):
        cfg, params = load_model(args.out, m)
        rows2a += fig2a_rows(m, cfg, params)
        rows2b += fig2b_rows(m, cfg, params)
    write_csv(os.path.join(R, "fig2a.csv"),
              ["model", "top_k", "density", "ppl", "ppl_increase"], rows2a)
    write_csv(os.path.join(R, "fig2b.csv"),
              ["model", "layer", "importance"], rows2b)

    # Fig 9 heat maps
    rows9 = []
    for m in ("opt-tiny", "llama-tiny"):
        cfg, _ = load_model(args.out, m)
        rows9 += fig9_rows(m, cfg, load_supervision(args.out, m))
    write_csv(os.path.join(R, "fig9.csv"),
              ["model", "layer", "head", "active_count", "n_tokens"], rows9)


if __name__ == "__main__":
    main()
