"""L2: decoder-only transformer with Polar-Sparsity decode paths.

Pure-functional JAX. Three entry families, all lowered AOT by aot.py:

  * ``forward_train`` — full causal pass (training + activation collection)
  * ``prefill``       — prompt pass producing last-position logits + KV cache
  * ``decode_step``   — one batched decode step; modes:
        dense   : full MLP + full attention
        dejavu  : union-router MLP sparsity only (DejaVu-style baseline §5.2)
        polar   : SHA head/group sparsity (dense layer 0, §3.2) + dynamic
                  per-layer top-k MLP sparsity for ReLU models (§4.1)

Routers (Appendix C) execute *inside* the graph, so the rust coordinator
never sees python at serving time.

Weight layout: every per-layer tensor is stacked to [L, ...]; MLP weights
are neuron-major [L, D_ff, d] (one contiguous row per neuron — Alg. 3).
KV cache is one tensor [L, 2, B, G, N, dh].
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import ref as kref
from .kernels import sel_gemm, sha_decode

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_spec(cfg: ModelConfig, with_routers: bool = True):
    """Canonical (name, shape) list — the AOT manifest's parameter order."""
    L, d, H, G, dh, Dff, V, S = (
        cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
        cfg.d_head, cfg.d_ff, cfg.vocab, cfg.max_seq,
    )
    spec = [
        ("tok_emb", (V, d)),
        ("pos_emb", (S, d)),          # zeros for rope models
        ("ln1_g", (L, d)), ("ln1_b", (L, d)),
        ("ln2_g", (L, d)), ("ln2_b", (L, d)),
        ("lnf_g", (d,)), ("lnf_b", (d,)),
        ("wq", (L, d, H * dh)), ("bq", (L, H * dh)),
        ("wk", (L, d, G * dh)), ("bk", (L, G * dh)),
        ("wv", (L, d, G * dh)), ("bv", (L, G * dh)),
        ("wo", (L, H * dh, d)), ("bo", (L, d)),
        ("w1", (L, Dff, d)), ("b1", (L, Dff)),
        ("w2", (L, Dff, d)), ("b2", (L, d)),
    ]
    if cfg.mlp == "swiglu":
        spec.append(("w3", (L, Dff, d)))
    if with_routers:
        rh = cfg.mlp_router_hidden
        if cfg.mlp_sparsity:
            spec += [
                ("mr_w1", (L, d, rh)), ("mr_b1", (L, rh)),
                ("mr_w2", (L, rh, Dff)), ("mr_b2", (L, Dff)),
            ]
        spec += [("ar_w", (L, d, cfg.n_groups)), ("ar_b", (L, cfg.n_groups))]
    return spec


def init_params(cfg: ModelConfig, seed: int = 0, with_routers: bool = True):
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_spec(cfg, with_routers):
        if name.endswith(("_g",)):
            params[name] = np.ones(shape, np.float32)
        elif name.endswith(("_b", "b1", "b2")) or name.startswith("b"):
            params[name] = np.zeros(shape, np.float32)
        else:
            scale = 0.02
            if name in ("wo", "w2"):
                scale = 0.02 / np.sqrt(2.0 * cfg.n_layers)
            params[name] = (rng.standard_normal(shape) * scale).astype(np.float32)
    if cfg.pos == "rope":
        params["pos_emb"] = np.zeros_like(params["pos_emb"])
    return params


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------


def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def rope(x, positions, dh: int):
    """Rotary embedding. x: [..., n_heads, dh], positions broadcastable to x[..., 0, 0]."""
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _embed(cfg, params, tokens, positions):
    x = jnp.take(params["tok_emb"], tokens, axis=0)
    if cfg.pos == "learned":
        x = x + jnp.take(params["pos_emb"], positions, axis=0)
    return x


def mlp_dense(cfg, params, l, h):
    """Dense MLP block on normed input h: [..., d] -> [..., d]."""
    w1, w2 = params["w1"][l], params["w2"][l]
    b1, b2 = params["b1"][l], params["b2"][l]
    if cfg.mlp == "relu":
        a = jax.nn.relu(h @ w1.T + b1)
    else:
        a = jax.nn.silu(h @ w1.T) * (h @ params["w3"][l].T)
    return a @ w2 + b2


def mlp_router_logits(params, l, h):
    """Two-layer bottleneck MLP router (Appendix C)."""
    z = jax.nn.relu(h @ params["mr_w1"][l] + params["mr_b1"][l])
    return z @ params["mr_w2"][l] + params["mr_b2"][l]


def attn_router_logits(params, l, h):
    """Single-layer head/group router (§4.2)."""
    return h @ params["ar_w"][l] + params["ar_b"][l]



def top_k_desc(x, k: int):
    """Sort-based top-k (descending) along the last axis.

    Used instead of lax.top_k because jax lowers that one to the TopK HLO
    custom op with a `largest=` attribute that xla_extension 0.5.1's HLO
    text parser rejects; sort/gather round-trips cleanly.
    """
    idx = jnp.argsort(-x, axis=-1)[..., :k].astype(jnp.int32)
    vals = jnp.take_along_axis(x, idx, axis=-1)
    return vals, idx

def mlp_masked(cfg, params, l, h, mode: str, density: float):
    """Training-free magnitude baselines for Table 2.

    ``teal``: per-token top-k masking by |activation| (TEAL-style).
    ``cats``: per-token top-k masking by |gate| only (CATS-style threshold
    on the silu gate). Accuracy baselines — per-token masks give no batched
    wall-clock win (the paper's point); they exist to reproduce Table 2.
    """
    w1, w2 = params["w1"][l], params["w2"][l]
    b1, b2 = params["b1"][l], params["b2"][l]
    k = max(1, int(round(cfg.d_ff * density)))
    if cfg.mlp == "relu":
        a = jax.nn.relu(h @ w1.T + b1)
        mag = jnp.abs(a)
    else:
        g = jax.nn.silu(h @ w1.T)
        a = g * (h @ params["w3"][l].T)
        mag = jnp.abs(g) if mode == "cats" else jnp.abs(a)
    kth = top_k_desc(mag, k)[0][:, -1:]
    a = jnp.where(mag >= kth, a, 0.0)
    return a @ w2 + b2


def mlp_sparse(cfg, params, l, h, top_k: int, impl: str = "xla", idx=None):
    """Selective MLP: batch-union router top-k (§4.1). h: [B, d].

    ``idx`` (i32 [S]) overrides the in-graph union router: the rust
    runtime's router subsystem computes each step's batch union outside
    the graph and feeds the neuron index tensor in as a data input.
    """
    if idx is None:
        logits = mlp_router_logits(params, l, h)      # [B, Dff]
        union = jnp.max(logits, axis=0)               # union across batch
        _, idx = top_k_desc(union, top_k)             # neuron index tensor
        idx = idx.astype(jnp.int32)
    args = (h, params["w1"][l], params["b1"][l], params["w2"][l],
            params["b2"][l], idx)
    if impl == "pallas-fused":
        return sel_gemm.sparse_mlp_fused(*args)
    if impl == "pallas":
        return sel_gemm.sparse_mlp(*args)
    return kref.sparse_mlp_ref(*args)


# ---------------------------------------------------------------------------
# Full causal pass (training / prefill core)
# ---------------------------------------------------------------------------


def _causal_attention(cfg, q, k, v, lengths):
    """q,k,v: [B,S,{H|G},dh]; returns [B,S,H,dh]. Dense, masked."""
    B, S = q.shape[0], q.shape[1]
    G, qpg = cfg.n_groups, cfg.q_per_group
    scale = 1.0 / jnp.sqrt(jnp.float32(cfg.d_head))
    qg = q.reshape(B, S, G, qpg, cfg.d_head)
    s = jnp.einsum("bigqd,bjgd->bgqij", qg, k) * scale  # [B,G,qpg,S,S]
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    causal = j <= i
    valid = j[None, :, :] < lengths[:, None, None]
    mask = causal[None, :, :] & valid
    s = jnp.where(mask[:, None, None, :, :], s, kref.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqij,bjgd->bigqd", p, v)
    return o.reshape(B, S, cfg.n_heads, cfg.d_head)


def forward_full(cfg: ModelConfig, params, tokens, lengths, collect: bool = False):
    """Full causal forward. tokens: [B,S], lengths: [B].

    Returns (logits [B,S,V], caches (k,v each [L,B,G,S,dh]), aux dict).
    aux (collect=True): mlp_active [L,B,S,Dff] bool, head_norms [L,B,S,H],
    attn_cos [L,B,S] (layer-importance score of Fig 2b: cos(x, x+attn(x))).
    """
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = _embed(cfg, params, tokens, positions)
    ks, vs = [], []
    aux = {"mlp_active": [], "head_norms": [], "attn_cos": [],
           "h_attn": [], "h_mlp": []}
    for l in range(cfg.n_layers):
        h = layer_norm(x, params["ln1_g"][l], params["ln1_b"][l])
        q = (h @ params["wq"][l] + params["bq"][l]).reshape(B, S, cfg.n_heads, cfg.d_head)
        k = (h @ params["wk"][l] + params["bk"][l]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
        v = (h @ params["wv"][l] + params["bv"][l]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
        if cfg.pos == "rope":
            q = rope(q, positions, cfg.d_head)
            k = rope(k, positions, cfg.d_head)
        o = _causal_attention(cfg, q, k, v, lengths)   # [B,S,H,dh]
        attn_out = o.reshape(B, S, -1) @ params["wo"][l] + params["bo"][l]
        if collect:
            aux["h_attn"].append(h)                                # router input
            aux["head_norms"].append(jnp.linalg.norm(o, axis=-1))  # [B,S,H]
            num = jnp.sum(x * (x + attn_out), axis=-1)
            den = jnp.linalg.norm(x, axis=-1) * jnp.linalg.norm(x + attn_out, axis=-1) + 1e-6
            aux["attn_cos"].append(num / den)
        x = x + attn_out
        h2 = layer_norm(x, params["ln2_g"][l], params["ln2_b"][l])
        if collect:
            aux["h_mlp"].append(h2)                                # router input
            if cfg.mlp == "relu":
                pre = h2 @ params["w1"][l].T + params["b1"][l]
                aux["mlp_active"].append(pre > 0)
        x = x + mlp_dense(cfg, params, l, h2)
        ks.append(k)
        vs.append(v)
    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["tok_emb"].T
    caches = (jnp.stack([k.swapaxes(1, 2) for k in ks]),   # [L,B,G,S,dh]
              jnp.stack([v.swapaxes(1, 2) for v in vs]))
    if collect:
        aux = {k2: jnp.stack(v2) if v2 else None for k2, v2 in aux.items()}
    return logits, caches, aux


def prefill(cfg: ModelConfig, params, tokens, lengths, n_bucket: int):
    """Prompt pass. tokens [B,S] padded, lengths [B] (1..S).

    Returns (last_logits [B,V], kv [L,2,B,G,N,dh]) with N = n_bucket >= S.
    """
    B, S = tokens.shape
    logits, (k, v), _ = forward_full(cfg, params, tokens, lengths)
    last = jnp.take_along_axis(
        logits, (lengths - 1)[:, None, None], axis=1
    )[:, 0, :]
    pad = n_bucket - S
    if pad < 0:
        raise ValueError(f"prompt bucket {S} > kv bucket {n_bucket}")
    k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    return last, jnp.stack([k, v], axis=1)


def prefill_chunk(cfg: ModelConfig, params, tokens, lengths, offset, kv):
    """One chunked-prefill step: append each slot's next prompt chunk into
    the group KV cache at a per-slot position offset.

    tokens [B,C] (chunk, padded), lengths [B] (valid tokens in THIS chunk;
    0 marks an inactive slot whose cache row is left untouched), offset [B]
    (absolute position where the chunk starts), kv [L,2,B,G,S,dh] with the
    positions [0, offset) already filled by earlier chunks.

    Cache writes are masked per position — ``where(offset <= j < offset+len)``
    — never a blind dynamic slice, so inactive slots and the region past a
    short final chunk cannot clobber live KV of co-resident requests. Chunk
    queries attend causally to the whole cache (prior chunks + the
    intra-chunk prefix), which makes successive chunks bit-compatible with
    one monolithic :func:`prefill` over the same prompt.

    Returns (logits [B,V] at each slot's position offset+len-1 — the
    first-token logits when this is the prompt's final chunk — and the
    updated cache [L,2,B,G,S,dh]).
    """
    B, C = tokens.shape
    S = kv.shape[4]
    G, qpg, dh = cfg.n_groups, cfg.q_per_group, cfg.d_head
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    pos = offset[:, None] + jnp.arange(C)[None, :]          # [B,C] absolute
    x = _embed(cfg, params, tokens, jnp.clip(pos, 0, cfg.max_seq - 1))
    j = jnp.arange(S)[None, :]                              # [1,S]
    write = (j >= offset[:, None]) & (j < (offset + lengths)[:, None])  # [B,S]
    src = jnp.clip(j - offset[:, None], 0, C - 1)           # [B,S] chunk idx

    def scatter_chunk(new, cache_l):
        """new [B,C,G,dh] -> masked into cache_l [B,G,S,dh]."""
        nt = new.transpose(0, 2, 1, 3)                      # [B,G,C,dh]
        idx = jnp.broadcast_to(src[:, None, :, None], (B, G, S, dh))
        gat = jnp.take_along_axis(nt, idx, axis=2)          # [B,G,S,dh]
        return jnp.where(write[:, None, :, None], gat, cache_l)

    ks, vs = [], []
    for l in range(cfg.n_layers):
        h = layer_norm(x, params["ln1_g"][l], params["ln1_b"][l])
        q = (h @ params["wq"][l] + params["bq"][l]).reshape(B, C, cfg.n_heads, dh)
        k_new = (h @ params["wk"][l] + params["bk"][l]).reshape(B, C, G, dh)
        v_new = (h @ params["wv"][l] + params["bv"][l]).reshape(B, C, G, dh)
        if cfg.pos == "rope":
            q = rope(q, pos, dh)
            k_new = rope(k_new, pos, dh)
        k_l = scatter_chunk(k_new, kv[l, 0])                # [B,G,S,dh]
        v_l = scatter_chunk(v_new, kv[l, 1])
        # chunk queries vs the full cache: key j is visible to the query at
        # absolute position p iff j <= p (all such keys are real prompt
        # positions — prior chunks or the just-written intra-chunk prefix)
        qg = q.reshape(B, C, G, qpg, dh)
        s = jnp.einsum("bigqd,bgjd->bgqij", qg, k_l) * scale  # [B,G,qpg,C,S]
        mask = j[:, None, :] <= pos[:, :, None]             # [B,C,S]
        s = jnp.where(mask[:, None, None, :, :], s, kref.NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgqij,bgjd->bigqd", p, v_l).reshape(B, C, -1)
        x = x + o @ params["wo"][l] + params["bo"][l]
        h2 = layer_norm(x, params["ln2_g"][l], params["ln2_b"][l])
        x = x + mlp_dense(cfg, params, l, h2)
        ks.append(k_l)
        vs.append(v_l)
    kv_new = jnp.stack([jnp.stack(ks), jnp.stack(vs)], axis=1)
    last_idx = jnp.clip(lengths - 1, 0, C - 1)              # [B]
    x_last = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)[:, 0, :]
    return final_logits(cfg, params, x_last), kv_new


# ---------------------------------------------------------------------------
# Paged KV cache (block-pool layout)
#
# The pool is one tensor [L, 2, P, G, bs, dh] (P physical blocks of bs
# token positions each); a request's cache is the concatenation of the
# blocks its table names, in order. Paged entries gather the table's
# blocks into the dense [L, 2, B, G, N, dh] view, run the *same* decode /
# prefill-chunk computation as the contiguous entries, and scatter the
# result back through the table — pure data movement around an unchanged
# core, so paged logits match the contiguous path bit for bit.
#
# Aliasing contract (enforced by the rust block manager, not here): a
# block shared by several tables is never inside any caller's write
# window — the scheduler copy-on-writes a block before the first
# divergent write. Under that contract every duplicate scatter writes
# bit-identical rows (gathered content of an unwritten shared block, or
# the null block's don't-care rows), so the scatter order XLA picks for
# duplicate indices cannot matter.
# ---------------------------------------------------------------------------


def kv_pool_shape(cfg: ModelConfig, num_blocks: int, block: int):
    """Shape of the paged KV pool tensor."""
    return (cfg.n_layers, 2, num_blocks, cfg.n_kv_heads, block, cfg.d_head)


def gather_block_kv(kv_pool, block_table):
    """kv_pool [L,2,P,G,bs,dh], block_table [B,NB] i32 -> dense
    [L,2,B,G,NB*bs,dh] view of each request's logical cache."""
    L, two, _, G, bs, dh = kv_pool.shape
    B, NB = block_table.shape
    flat = jnp.take(kv_pool, block_table.reshape(-1), axis=2)
    g = flat.reshape(L, two, B, NB, G, bs, dh)
    g = jnp.moveaxis(g, 3, 4)                    # [L,2,B,G,NB,bs,dh]
    return g.reshape(L, two, B, G, NB * bs, dh)


def scatter_block_kv(kv_pool, block_table, kv_dense):
    """Inverse of :func:`gather_block_kv`: write the dense view back into
    the pool through the table (see the aliasing contract above)."""
    L, two, _, G, bs, dh = kv_pool.shape
    B, NB = block_table.shape
    d = kv_dense.reshape(L, two, B, G, NB, bs, dh)
    d = jnp.moveaxis(d, 4, 3).reshape(L, two, B * NB, G, bs, dh)
    return kv_pool.at[:, :, block_table.reshape(-1)].set(d)


def decode_step_paged(cfg: ModelConfig, params, tokens, lengths, kv_pool,
                      block_table, **kw):
    """One decode step over the block pool: gather the tables' dense view,
    run the unchanged :func:`decode_step`, scatter the update back.
    Returns (logits [B,V], kv_pool')."""
    kv = gather_block_kv(kv_pool, block_table)
    logits, kv_new = decode_step(cfg, params, tokens, lengths, kv, **kw)
    return logits, scatter_block_kv(kv_pool, block_table, kv_new)


def prefill_chunk_paged(cfg: ModelConfig, params, tokens, lengths, offset,
                        block_table, kv_pool):
    """One chunked-prefill step over the block pool (same contract as
    :func:`prefill_chunk`, addressed through `block_table` [B,NB]).
    Chunk queries attend over the whole gathered cache, so a request
    whose table shares prefix blocks with an earlier request attends to
    the cached prefix without ever recomputing its chunks."""
    kv = gather_block_kv(kv_pool, block_table)
    logits, kv_new = prefill_chunk(cfg, params, tokens, lengths, offset, kv)
    return logits, scatter_block_kv(kv_pool, block_table, kv_new)


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def _decode_qkv(cfg, params, l, h, pos):
    """Projections + rope for one decode position. h: normed [B,d].
    Returns (q [B,H,dh], k_new [B,G,dh], v_new [B,G,dh])."""
    B = h.shape[0]
    G, dh = cfg.n_groups, cfg.d_head
    q = (h @ params["wq"][l] + params["bq"][l]).reshape(B, cfg.n_heads, dh)
    k_new = (h @ params["wk"][l] + params["bk"][l]).reshape(B, G, dh)
    v_new = (h @ params["wv"][l] + params["bv"][l]).reshape(B, G, dh)
    if cfg.pos == "rope":
        q = rope(q, pos, dh)          # [B,H,dh], positions [B]
        k_new = rope(k_new, pos, dh)  # [B,G,dh]
    return q, k_new, v_new


def _select_heads(params, l, h, top_k, head_idx):
    """Resolve the per-request head selection: runtime-provided index, or
    the in-graph router's top-k."""
    if head_idx is None:
        logits = attn_router_logits(params, l, h)      # [B,G]
        _, head_idx = top_k_desc(logits, top_k)        # batch head index
        head_idx = head_idx.astype(jnp.int32)
    return head_idx


def _attend(cfg, params, l, h, q, k_l, v_l, lengths, *, sparse: bool,
            top_k: int, impl: str, head_idx=None):
    """Attention over a dense per-layer cache view k_l/v_l [B,G,N,dh].
    Returns attn_out [B,d] (already through the output projection)."""
    B = q.shape[0]
    G, qpg, dh = cfg.n_groups, cfg.q_per_group, cfg.d_head
    if sparse and top_k < G:
        head_idx = _select_heads(params, l, h, top_k, head_idx)
        if impl == "pallas":
            o_sel = sha_decode.sha_decode(q, k_l, v_l, head_idx, lengths, qpg)
        else:
            o_sel = kref.sha_decode_ref(q, k_l, v_l, head_idx, lengths, qpg)
        # scatter the selected heads back into the dense [B, H, dh] layout
        qidx = (head_idx[:, :, None] * qpg
                + jnp.arange(qpg, dtype=jnp.int32)[None, None, :]).reshape(B, -1)
        o = jnp.zeros((B, cfg.n_heads, dh), jnp.float32)
        o = o.at[jnp.arange(B)[:, None], qidx].set(o_sel)
    else:
        if impl == "pallas":
            o = sha_decode.dense_decode_attention(q, k_l, v_l, lengths, qpg)
        else:
            o = kref.dense_decode_attention_ref(q, k_l, v_l, lengths, qpg)
        o = o.reshape(B, cfg.n_heads, dh)
    return o.reshape(B, -1) @ params["wo"][l] + params["bo"][l]


def _decode_attention(cfg, params, l, x, h, kv_l, lengths, *, sparse: bool,
                      top_k: int, impl: str, head_idx=None):
    """One attention block in decode. x: residual [B,d], h: normed [B,d].

    kv_l: this layer's cache [2,B,G,N,dh] (weights indexed by absolute l).
    ``head_idx`` (i32 [B, top_k]) overrides the in-graph router with the
    runtime's per-request selection. Returns (attn_out [B,d], k_l, v_l).
    """
    del x
    pos = lengths - 1
    q, k_new, v_new = _decode_qkv(cfg, params, l, h, pos)

    def upd(cache_b, new_b, p):
        return jax.lax.dynamic_update_slice(cache_b, new_b[:, None, :], (0, p, 0))

    k_l = jax.vmap(upd)(kv_l[0], k_new, pos)   # [B,G,N,dh]
    v_l = jax.vmap(upd)(kv_l[1], v_new, pos)

    attn_out = _attend(cfg, params, l, h, q, k_l, v_l, lengths,
                       sparse=sparse, top_k=top_k, impl=impl,
                       head_idx=head_idx)
    return attn_out, k_l, v_l


def decode_core(cfg: ModelConfig, params, x, lengths, kv, *,
                layer_begin: int, layer_end: int,
                mode: str = "dense", density: float = 1.0,
                mlp_topk: tuple = (), attn_impl: str = "xla",
                mlp_impl: str = "xla", head_idx=None, mlp_idx=None):
    """Run decode layers [layer_begin, layer_end) on hidden x [B,d].

    kv holds only this slice's layers: [layer_end-layer_begin, 2, B,G,N,dh]
    (pipeline-parallel stages own disjoint KV shards). Returns (x, kv_new).

    ``head_idx`` [L,B,K] / ``mlp_idx`` [L,Km] (both i32, indexed by
    *absolute* layer) carry the runtime routers' per-step selection; when
    None the routers execute inside the graph as before.
    """
    if mode not in ("dense", "dejavu", "polar", "teal", "cats"):
        raise ValueError(mode)
    attn_k = max(1, min(cfg.n_groups, round(cfg.n_groups * density)))
    mlp_sparse_on = mode in ("dejavu", "polar") and cfg.mlp_sparsity and mlp_topk

    ks, vs = [], []
    for l in range(layer_begin, layer_end):
        lk = l - layer_begin  # kv-slice index
        h = layer_norm(x, params["ln1_g"][l], params["ln1_b"][l])
        sparse_attn = mode == "polar" and l > 0
        attn_out, k_l, v_l = _decode_attention(
            cfg, params, l, x, h, kv[lk], lengths,
            sparse=sparse_attn, top_k=attn_k, impl=attn_impl,
            head_idx=None if head_idx is None else head_idx[l],
        )
        x = x + attn_out
        ks.append(k_l)
        vs.append(v_l)
        h2 = layer_norm(x, params["ln2_g"][l], params["ln2_b"][l])
        if mlp_sparse_on and mlp_topk[l] < cfg.d_ff:
            mlp_out = mlp_sparse(
                cfg, params, l, h2, mlp_topk[l], mlp_impl,
                idx=None if mlp_idx is None else mlp_idx[l],
            )
        elif mode in ("teal", "cats") and density < 1.0:
            mlp_out = mlp_masked(cfg, params, l, h2, mode, density)
        else:
            mlp_out = mlp_dense(cfg, params, l, h2)
        x = x + mlp_out
    kv_new = jnp.stack([jnp.stack(ks), jnp.stack(vs)], axis=1)
    return x, kv_new


def final_logits(cfg, params, x):
    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["tok_emb"].T


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mode", "density", "mlp_topk", "attn_impl", "mlp_impl"),
)
def decode_step(cfg: ModelConfig, params, tokens, lengths, kv, *,
                mode: str = "dense", density: float = 1.0,
                mlp_topk: tuple = (), attn_impl: str = "xla",
                mlp_impl: str = "xla", head_idx=None, mlp_idx=None):
    """One decode step. tokens [B] (the *new* token, already appended to the
    sequence: lengths includes it). kv [L,2,B,G,N,dh]. Returns
    (logits [B,V], kv_new).

    mode="polar": layer 0 attention dense (Fig 2b), layers >0 at `density`;
    MLP top-k per layer from `mlp_topk` (calibrated, Algorithm 2) for ReLU
    models. mode="dejavu": MLP sparsity only. mode="dense": no sparsity.

    ``head_idx`` (i32 [L,B,K]) / ``mlp_idx`` (i32 [L,Km]) replace the
    in-graph routers with externally computed selections — the calling
    convention of the runtime routing subsystem's index-taking entries.
    """
    pos = lengths - 1
    x = _embed(cfg, params, tokens, pos)
    x, kv_new = decode_core(
        cfg, params, x, lengths, kv,
        layer_begin=0, layer_end=cfg.n_layers, mode=mode, density=density,
        mlp_topk=mlp_topk, attn_impl=attn_impl, mlp_impl=mlp_impl,
        head_idx=head_idx, mlp_idx=mlp_idx,
    )
    return final_logits(cfg, params, x), kv_new


# ---------------------------------------------------------------------------
# Fused paged decode (no gather/scatter shells)
#
# The twin path above (decode_step_paged) stages a dense [L,2,B,G,N,dh]
# intermediate on both sides of an unchanged core. The fused path kills
# both shells: each layer writes its single new-position KV row straight
# into its pool block through the table, then reads KV through the table —
# per-layer for the XLA oracle, per-tile inside the kernel for the pallas
# path (sha_decode_paged resolves tile addresses from the block table and
# writes selected head rows into the dense layout via an aliased output).
#
# The floating-point op sequence is identical to the twin path — only data
# movement changes — so live-slot logits match the twin bit for bit. The
# one divergence is don't-care by construction: padding slots whose tables
# are all-null write to (and may then read back) reserved block 0, where
# the twin's gather-before-write would have seen the pre-step rows. The
# aliasing contract (block manager) guarantees live slots never share a
# block inside any write window, so their views are unaffected.
# ---------------------------------------------------------------------------


def _gather_layer_kv(kv_pool, l, block_table):
    """One layer's dense cache view through the table:
    kv_pool [L,2,P,G,bs,dh] -> (k_l, v_l) each [B,G,NB*bs,dh]."""
    _, _, _, G, bs, dh = kv_pool.shape
    B, NB = block_table.shape
    flat = jnp.take(kv_pool[l], block_table.reshape(-1), axis=1)
    g = flat.reshape(2, B, NB, G, bs, dh)
    g = jnp.moveaxis(g, 2, 3).reshape(2, B, G, NB * bs, dh)
    return g[0], g[1]


def _write_kv_row(kv_pool, l, block_table, lengths, k_new, v_new):
    """Write the new position's K/V row for layer l directly into its pool
    block — no dense intermediate, no whole-view scatter."""
    bs = kv_pool.shape[4]
    pos = lengths - 1
    blk = jnp.take_along_axis(block_table, (pos // bs)[:, None], axis=1)[:, 0]
    off = pos % bs
    kv_pool = kv_pool.at[l, 0, blk, :, off, :].set(k_new)
    return kv_pool.at[l, 1, blk, :, off, :].set(v_new)


def _attend_fused(cfg, params, l, h, q, kv_pool, block_table, lengths, *,
                  sparse: bool, top_k: int, head_idx=None, pool_l=None):
    """Pallas fused attention: the kernel indexes the block table itself and
    writes selected head rows straight into the dense [B,H,dh] layout.
    ``pool_l`` is the pool's layer index when the pool holds only a layer
    slice (pipeline stage); weights always index by absolute ``l``."""
    B = q.shape[0]
    G, qpg = cfg.n_groups, cfg.q_per_group
    pl = l if pool_l is None else pool_l
    if sparse and top_k < G:
        head_idx = _select_heads(params, l, h, top_k, head_idx)
    else:
        head_idx = jnp.broadcast_to(
            jnp.arange(G, dtype=jnp.int32)[None, :], (B, G))
    o = sha_decode.sha_decode_paged(
        q, kv_pool[pl, 0], kv_pool[pl, 1], block_table, head_idx, lengths, qpg)
    return o.reshape(B, -1) @ params["wo"][l] + params["bo"][l]


def decode_core_paged(cfg: ModelConfig, params, x, lengths, kv_pool,
                      block_table, *, layer_begin: int = 0,
                      layer_end: int = None, mode: str = "dense",
                      density: float = 1.0, mlp_topk: tuple = (),
                      attn_impl: str = "xla", mlp_impl: str = "xla",
                      head_idx=None, mlp_idx=None):
    """Fused paged decode layers [layer_begin, layer_end) on hidden x [B,d].
    Returns (x, kv_pool').

    Same math as :func:`decode_core` over the gathered view, but KV moves
    block-at-a-time: the new row lands in its pool block before attention
    reads the layer's cache through the table. ``kv_pool`` holds only this
    slice's layers — [layer_end-layer_begin, 2, P, G, bs, dh] — so
    pipeline-parallel stages own disjoint pool slices while weights and
    ``head_idx``/``mlp_idx``/``mlp_topk`` index by absolute layer."""
    if mode not in ("dense", "dejavu", "polar", "teal", "cats"):
        raise ValueError(mode)
    if layer_end is None:
        layer_end = cfg.n_layers
    attn_k = max(1, min(cfg.n_groups, round(cfg.n_groups * density)))
    mlp_sparse_on = mode in ("dejavu", "polar") and cfg.mlp_sparsity and mlp_topk
    pos = lengths - 1

    for l in range(layer_begin, layer_end):
        lk = l - layer_begin  # pool-slice index
        h = layer_norm(x, params["ln1_g"][l], params["ln1_b"][l])
        q, k_new, v_new = _decode_qkv(cfg, params, l, h, pos)
        kv_pool = _write_kv_row(kv_pool, lk, block_table, lengths, k_new, v_new)
        sparse_attn = mode == "polar" and l > 0
        hi_l = None if head_idx is None else head_idx[l]
        if attn_impl == "pallas":
            attn_out = _attend_fused(
                cfg, params, l, h, q, kv_pool, block_table, lengths,
                sparse=sparse_attn, top_k=attn_k, head_idx=hi_l, pool_l=lk)
        else:
            k_l, v_l = _gather_layer_kv(kv_pool, lk, block_table)
            attn_out = _attend(
                cfg, params, l, h, q, k_l, v_l, lengths,
                sparse=sparse_attn, top_k=attn_k, impl=attn_impl,
                head_idx=hi_l)
        x = x + attn_out
        h2 = layer_norm(x, params["ln2_g"][l], params["ln2_b"][l])
        if mlp_sparse_on and mlp_topk[l] < cfg.d_ff:
            mlp_out = mlp_sparse(
                cfg, params, l, h2, mlp_topk[l],
                "pallas-fused" if mlp_impl == "pallas" else mlp_impl,
                idx=None if mlp_idx is None else mlp_idx[l],
            )
        elif mode in ("teal", "cats") and density < 1.0:
            mlp_out = mlp_masked(cfg, params, l, h2, mode, density)
        else:
            mlp_out = mlp_dense(cfg, params, l, h2)
        x = x + mlp_out
    return x, kv_pool


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mode", "density", "mlp_topk", "attn_impl", "mlp_impl"),
)
def decode_step_paged_fused(cfg: ModelConfig, params, tokens, lengths,
                            kv_pool, block_table, *, mode: str = "dense",
                            density: float = 1.0, mlp_topk: tuple = (),
                            attn_impl: str = "xla", mlp_impl: str = "xla",
                            head_idx=None, mlp_idx=None):
    """One fused decode step over the block pool (same contract and inputs
    as :func:`decode_step_paged`, bit-identical live-slot logits) without
    the dense [L,2,B,G,N,dh] intermediate on either side of the core."""
    pos = lengths - 1
    x = _embed(cfg, params, tokens, pos)
    x, kv_pool = decode_core_paged(
        cfg, params, x, lengths, kv_pool, block_table,
        mode=mode, density=density, mlp_topk=mlp_topk,
        attn_impl=attn_impl, mlp_impl=mlp_impl,
        head_idx=head_idx, mlp_idx=mlp_idx,
    )
    return final_logits(cfg, params, x), kv_pool


# ---------------------------------------------------------------------------
# Fused paged prefill chunks + on-device COW block copy
#
# The last shell traffic after fused decode was the prefill chunk: the twin
# path gathers the whole [L,2,B,G,S,dh] view, runs prefill_chunk, and
# scatters the view back — both shells every chunk. The fused path writes
# the chunk's new K/V rows straight into their pool blocks at per-slot
# offsets (a masked multi-row scatter, no dense intermediate) and reads KV
# through the table — per-layer for the XLA path, per-tile inside the
# kernel for the pallas path (prefill_attention_paged resolves tile
# addresses from the block table like _sha_paged_kernel).
#
# Bitwise contract with the twin: the twin's whole-view scatter writes
# back gathered (unchanged) rows everywhere outside the chunk window, an
# identity write, so a pool that only receives the chunk rows is equal
# everywhere — including reserved null block 0, which the fused write
# never touches: rows of inactive chunk positions (c >= lengths[b], so
# every row of a PAD slot) are routed out of range and dropped. The
# attention math is the twin's einsum over the same [B,G,S,dh] values, so
# logits match bit for bit; inactive slots still run the full (discarded)
# computation to keep the op sequence identical.
# ---------------------------------------------------------------------------


def _write_chunk_kv(kv_pool, l, block_table, offset, lengths, k_new, v_new):
    """Write one chunk's new K/V rows for layer l straight into their pool
    blocks at per-slot offsets. k_new/v_new: [B,C,G,dh].

    Inactive rows (c >= lengths[b]) get block index P — out of range, and
    ``mode="drop"`` discards them — so a padding slot can never write any
    pool block, not even the null block (the policy mock.rs enforces for
    decode)."""
    P, bs = kv_pool.shape[2], kv_pool.shape[4]
    NB = block_table.shape[1]
    C = k_new.shape[1]
    c = jnp.arange(C, dtype=jnp.int32)[None, :]
    pos = offset[:, None] + c                                # [B,C] absolute
    active = c < lengths[:, None]
    blk = jnp.take_along_axis(
        block_table, jnp.clip(pos // bs, 0, NB - 1), axis=1)
    blk = jnp.where(active, blk, P)                          # P -> dropped
    off = pos % bs
    kv_pool = kv_pool.at[l, 0, blk, :, off, :].set(k_new, mode="drop")
    return kv_pool.at[l, 1, blk, :, off, :].set(v_new, mode="drop")


@functools.partial(jax.jit, static_argnames=("cfg", "attn_impl"))
def prefill_chunk_paged_fused(cfg: ModelConfig, params, tokens, lengths,
                              offset, block_table, kv_pool, *,
                              attn_impl: str = "xla"):
    """One fused chunked-prefill step over the block pool (same contract
    and inputs as :func:`prefill_chunk_paged`, bit-identical logits and
    pool contents) without the dense [L,2,B,G,S,dh] view on either side.

    Each layer writes the chunk's K/V rows into their blocks first, then
    attends causally over the table's whole stream — prior chunks, prefix-
    cached blocks another request published, and the just-written
    intra-chunk rows all resolve through the same table lookup."""
    B, C = tokens.shape
    bs = kv_pool.shape[4]
    S = block_table.shape[1] * bs
    G, qpg, dh = cfg.n_groups, cfg.q_per_group, cfg.d_head
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    pos = offset[:, None] + jnp.arange(C)[None, :]          # [B,C] absolute
    x = _embed(cfg, params, tokens, jnp.clip(pos, 0, cfg.max_seq - 1))
    j = jnp.arange(S)[None, :]                              # [1,S]

    for l in range(cfg.n_layers):
        h = layer_norm(x, params["ln1_g"][l], params["ln1_b"][l])
        q = (h @ params["wq"][l] + params["bq"][l]).reshape(B, C, cfg.n_heads, dh)
        k_new = (h @ params["wk"][l] + params["bk"][l]).reshape(B, C, G, dh)
        v_new = (h @ params["wv"][l] + params["bv"][l]).reshape(B, C, G, dh)
        if cfg.pos == "rope":
            q = rope(q, pos, dh)
            k_new = rope(k_new, pos, dh)
        kv_pool = _write_chunk_kv(
            kv_pool, l, block_table, offset, lengths, k_new, v_new)
        if attn_impl == "pallas":
            o = sha_decode.prefill_attention_paged(
                q, kv_pool[l, 0], kv_pool[l, 1], block_table, offset, qpg)
            o = o.reshape(B, C, -1)
        else:
            k_l, v_l = _gather_layer_kv(kv_pool, l, block_table)
            qg = q.reshape(B, C, G, qpg, dh)
            s = jnp.einsum("bigqd,bgjd->bgqij", qg, k_l) * scale
            mask = j[:, None, :] <= pos[:, :, None]         # [B,C,S]
            s = jnp.where(mask[:, None, None, :, :], s, kref.NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bgqij,bgjd->bigqd", p, v_l).reshape(B, C, -1)
        x = x + o @ params["wo"][l] + params["bo"][l]
        h2 = layer_norm(x, params["ln2_g"][l], params["ln2_b"][l])
        x = x + mlp_dense(cfg, params, l, h2)
    last_idx = jnp.clip(lengths - 1, 0, C - 1)              # [B]
    x_last = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)[:, 0, :]
    return final_logits(cfg, params, x_last), kv_pool


@jax.jit
def copy_blocks(kv_pool, src, dst):
    """On-device COW block copy: pool[:, :, dst[i]] = pool[:, :, src[i]].

    The AOT ``copy_blocks`` entry has a fixed pair width; the engine pads
    a shorter pair list with (0, 0) — the null block copied onto itself,
    an identity write. Within one batch no dst is another pair's src (a
    COW dst is a freshly allocated private block), so gather-then-scatter
    is well-defined; duplicate (0, 0) dsts all write the same rows."""
    rows = jnp.take(kv_pool, src, axis=2)
    return kv_pool.at[:, :, dst].set(rows)


# ---------------------------------------------------------------------------
# Tensor-parallel shard entries over the block pool (Fig 12 substrate)
#
# Megatron-style TP simulated on one host: each shard executable computes its
# slice of head groups (attention) or FFN neurons (MLP) for *one* layer,
# selected dynamically by a scalar layer id (weights are stacked [L,...], so
# dynamic_index_in_dim keeps shapes static). Each shard owns a resident pool
# slice [L,2,P,Gs,bs,dh] — the group-axis split of the single-device pool —
# addressed by the same block tables, so paging and prefix sharing compose
# with TP unchanged.
#
# Bias convention: shard entries are BIASLESS — the per-layer reduce entry
# (tp_attn_reduce / tp_mlp_reduce) owns the output bias and the residual
# add. That makes a skipped shard's contribution an exact zero [B,d]
# buffer: a shard whose head groups are all router-unselected would have
# scattered o = 0 rows into its partial (0 @ wo_s == 0.0 exactly), so the
# driver can skip its attention dispatch entirely and feed the reduce a
# persistent zero buffer instead. The skipped shard still runs the cheap
# KV-write-only entry (mode="kvw") — future steps may select its groups,
# and the paper's KV cache is dense even where attention is sparse.
#
# Head/neuron indices are per-shard LOCAL with a sentinel: the runtime
# localizes the global head_idx [L,B,Kh] / mlp_idx [L,Km] to each shard
# (global id - shard*Gs if owned, else the sentinel Gs/Ds), and the entry
# drops sentinel rows in-graph (scatter mode="drop" / a where-mask), which
# reproduces the single-device scatter-into-zeros exactly.
# ---------------------------------------------------------------------------


def _layer_params(params, layer, names):
    return {n: jax.lax.dynamic_index_in_dim(params[n], layer, 0, keepdims=False)
            for n in names}


def tp_embed(cfg, params, tokens, lengths):
    """Replicated embedding (cheap): tokens [B] -> x [B,d]."""
    return _embed(cfg, params, tokens, lengths - 1)


def tp_final(cfg, params, x):
    """Replicated final norm + LM head: x [B,d] -> logits [B,V]."""
    return final_logits(cfg, params, x)


def _shard_layer_kv(kv_pool, layer, block_table):
    """Traced-layer dense view of one shard's pool slice:
    kv_pool [L,2,P,Gs,bs,dh], block_table [B,NB] -> (k,v) [B,Gs,NB*bs,dh]."""
    pool_l = jax.lax.dynamic_index_in_dim(kv_pool, layer, 0, keepdims=False)
    _, _, Gs, bs, dh = pool_l.shape
    B, NB = block_table.shape
    flat = jnp.take(pool_l, block_table.reshape(-1), axis=1)
    g = flat.reshape(2, B, NB, Gs, bs, dh)
    g = jnp.moveaxis(g, 2, 3).reshape(2, B, Gs, NB * bs, dh)
    return g[0], g[1]


def _write_shard_kv_row(kv_pool, layer, block_table, lengths, k_new, v_new):
    """Traced-layer variant of :func:`_write_kv_row` for a shard pool."""
    bs = kv_pool.shape[4]
    pos = lengths - 1
    blk = jnp.take_along_axis(block_table, (pos // bs)[:, None], axis=1)[:, 0]
    off = pos % bs
    kv_pool = kv_pool.at[layer, 0, blk, :, off, :].set(k_new)
    return kv_pool.at[layer, 1, blk, :, off, :].set(v_new)


def tp_attn_shard_paged(cfg, params, layer, x, lengths, block_table, kv_pool,
                        *, shard: int, n_shards: int, mode: str = "dense",
                        head_idx=None):
    """One attention block's shard over its resident pool slice.

    layer: scalar i32. kv_pool: [L,2,P,Gs,bs,dh] (this shard's group slice
    of the single-device pool, full layer depth). The new KV row is always
    written — even in mode="kvw", which then returns only the pool (the
    dispatch a router-skipped shard still runs). head_idx (mode="sha"):
    [B, Ks] LOCAL group ids, sentinel >= Gs for unselected slots.

    Returns (partial [B,d] biasless, kv_pool') — or kv_pool' alone for
    mode="kvw".
    """
    B = x.shape[0]
    H, G, dh = cfg.n_heads, cfg.n_groups, cfg.d_head
    Hs, Gs = H // n_shards, G // n_shards
    qpg = cfg.q_per_group
    hs, gs = shard * Hs * dh, shard * Gs * dh
    p = _layer_params(params, layer, ["ln1_g", "ln1_b", "wq", "bq", "wk", "bk",
                                      "wv", "bv", "wo"])
    pos = lengths - 1
    h = layer_norm(x, p["ln1_g"], p["ln1_b"])
    k_new = (h @ p["wk"][:, gs:gs + Gs * dh] + p["bk"][gs:gs + Gs * dh]).reshape(B, Gs, dh)
    v_new = (h @ p["wv"][:, gs:gs + Gs * dh] + p["bv"][gs:gs + Gs * dh]).reshape(B, Gs, dh)
    if cfg.pos == "rope":
        k_new = rope(k_new, pos, dh)
    kv_pool = _write_shard_kv_row(kv_pool, layer, block_table, lengths,
                                  k_new, v_new)
    if mode == "kvw":
        return kv_pool

    q = (h @ p["wq"][:, hs:hs + Hs * dh] + p["bq"][hs:hs + Hs * dh]).reshape(B, Hs, dh)
    if cfg.pos == "rope":
        q = rope(q, pos, dh)
    k_l, v_l = _shard_layer_kv(kv_pool, layer, block_table)
    if mode == "sha":
        # sentinel rows: computed on a clipped duplicate, discarded by the
        # out-of-range scatter — unselected heads stay exactly 0.0, the
        # same rows the single-device scatter-into-zeros leaves untouched
        sel = jnp.clip(head_idx, 0, Gs - 1)
        o_sel = kref.sha_decode_ref(q, k_l, v_l, sel, lengths, qpg)
        qidx = (head_idx[:, :, None] * qpg
                + jnp.arange(qpg, dtype=jnp.int32)[None, None, :]).reshape(B, -1)
        o = jnp.zeros((B, Hs, dh), jnp.float32)
        o = o.at[jnp.arange(B)[:, None], qidx].set(o_sel, mode="drop")
    else:
        o = kref.dense_decode_attention_ref(q, k_l, v_l, lengths, qpg)
        o = o.reshape(B, Hs, dh)
    partial = o.reshape(B, -1) @ p["wo"][hs:hs + Hs * dh, :]
    return partial, kv_pool


def tp_mlp_shard(cfg, params, layer, x, *, shard: int, n_shards: int,
                 mlp_idx=None):
    """One MLP block's shard: neurons [shard*Ds, (shard+1)*Ds). Biasless.

    mlp_idx (i32 [Kms], ReLU models): per-shard LOCAL neuron ids from the
    runtime's batch union, sentinel >= Ds for slots owned by other shards.
    Sentinel columns are masked to exact 0.0 before the down-projection,
    so the shard partials sum to the single-device selective MLP.
    """
    Dff = cfg.d_ff
    Ds = Dff // n_shards
    lo = shard * Ds
    names = ["ln2_g", "ln2_b", "w1", "b1", "w2"]
    if cfg.mlp == "swiglu":
        names.append("w3")
    p = _layer_params(params, layer, names)
    h = layer_norm(x, p["ln2_g"], p["ln2_b"])
    w1, w2 = p["w1"][lo:lo + Ds], p["w2"][lo:lo + Ds]
    b1 = p["b1"][lo:lo + Ds]
    if mlp_idx is not None and cfg.mlp == "relu":
        sel = jnp.clip(mlp_idx, 0, Ds - 1)
        a = jax.nn.relu(h @ jnp.take(w1, sel, axis=0).T + jnp.take(b1, sel))
        a = jnp.where((mlp_idx < Ds)[None, :], a, 0.0)
        partial = a @ jnp.take(w2, sel, axis=0)
    elif cfg.mlp == "relu":
        partial = jax.nn.relu(h @ w1.T + b1) @ w2
    else:
        w3 = p["w3"][lo:lo + Ds]
        partial = (jax.nn.silu(h @ w1.T) * (h @ w3.T)) @ w2
    return partial


def tp_attn_reduce(cfg, params, layer, x, partials):
    """All-reduce half of a TP attention layer: residual + Σ shard partials
    + the output bias the biasless shards omitted. Runs on-device — the
    driver feeds shard partials (or persistent zero buffers for skipped
    shards) as device buffers."""
    bo = jax.lax.dynamic_index_in_dim(params["bo"], layer, 0, keepdims=False)
    acc = partials[0]
    for part in partials[1:]:
        acc = acc + part
    return x + (acc + bo)


def tp_mlp_reduce(cfg, params, layer, x, partials):
    """All-reduce half of a TP MLP layer (see :func:`tp_attn_reduce`)."""
    b2 = jax.lax.dynamic_index_in_dim(params["b2"], layer, 0, keepdims=False)
    acc = partials[0]
    for part in partials[1:]:
        acc = acc + part
    return x + (acc + b2)


# ---------------------------------------------------------------------------
# Reference generation loop (python-side; used by tests & analysis only)
# ---------------------------------------------------------------------------


def generate_greedy(cfg, params, prompt_ids, max_new: int, n_bucket: int = None,
                    mode: str = "dense", density: float = 1.0,
                    mlp_topk: tuple = ()):
    """Greedy decode of a single sequence (B=1). Returns generated ids."""
    n_bucket = n_bucket or cfg.max_seq
    tokens = np.asarray(prompt_ids, np.int32)[None, :]
    lengths = np.array([tokens.shape[1]], np.int32)
    logits, kv = prefill(cfg, params, jnp.asarray(tokens), jnp.asarray(lengths), n_bucket)
    out = []
    for _ in range(max_new):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        lengths = lengths + 1
        if int(lengths[0]) > n_bucket:
            break
        logits, kv = decode_step(
            cfg, params, jnp.array([nxt], jnp.int32), jnp.asarray(lengths), kv,
            mode=mode, density=density, mlp_topk=mlp_topk,
        )
    return out
