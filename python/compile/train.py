"""Pretrain the model zoo on the synthetic corpus (build-time only).

Usage: python -m compile.train --model opt-tiny [--out ../artifacts]
Writes artifacts/<model>/model.npz (weights, no routers yet) and
artifacts/<model>/train_log.json (loss curve for EXPERIMENTS.md).
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model
from .configs import CONFIGS, PAD, get_config
from .optim import adam_init, adam_update


def batches(cfg, seed: int, n_steps: int, task_frac: float = 0.7):
    """Packed next-token training batches [B, T+1] from the corpus stream."""
    B, T = cfg.train_batch, cfg.train_seq
    stream = corpus.training_stream(
        seed, n_tokens=n_steps * B * (T + 1) + 1, task_frac=task_frac
    )
    per = B * (T + 1)
    for step in range(n_steps):
        chunk = stream[step * per : (step + 1) * per]
        yield chunk.reshape(B, T + 1)


@functools.partial(jax.jit, static_argnames=("cfg",))
def loss_fn(cfg, params, batch):
    """Next-token cross-entropy over the packed stream (no pads)."""
    tokens, targets = batch[:, :-1], batch[:, 1:]
    lengths = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
    logits, _, _ = model.forward_full(cfg, params, tokens, lengths)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = targets != PAD
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


@functools.partial(jax.jit, static_argnames=("cfg", "lr"))
def train_step(cfg, params, opt_state, batch, lr: float):
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
    params, opt_state = adam_update(params, grads, opt_state, lr)
    return params, opt_state, loss


def train(cfg, seed: int = 0, log_every: int = 25, init=None, steps=None,
          lr=None, task_frac: float = 0.7):
    if init is None:
        params = {k: jnp.asarray(v) for k, v in
                  model.init_params(cfg, seed, with_routers=False).items()}
    else:
        params = {k: jnp.asarray(v) for k, v in init.items()
                  if not k.startswith(("mr_", "ar_"))}
    steps = steps or cfg.train_steps
    lr = lr or cfg.lr
    opt_state = adam_init(params)
    log = []
    t0 = time.time()
    for step, batch in enumerate(batches(cfg, seed + 7, steps, task_frac)):
        params, opt_state, loss = train_step(
            cfg, params, opt_state, jnp.asarray(batch), lr
        )
        if step % log_every == 0 or step == steps - 1:
            log.append({"step": step, "loss": float(loss),
                        "elapsed_s": round(time.time() - t0, 1)})
            print(f"[{cfg.name}] step {step:4d} loss {float(loss):.4f}")
    return {k: np.asarray(v) for k, v in params.items()}, log


def heldout_ppl(cfg, params, n_tokens: int = 2048):
    ids = corpus.heldout_text_tokens(n_tokens + 1)
    T = cfg.train_seq
    n = (len(ids) - 1) // T
    total, count = 0.0, 0
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    for i in range(n):
        batch = ids[i * T : (i + 1) * T + 1][None, :]
        total += float(loss_fn(cfg, jp, jnp.asarray(batch))) * T
        count += T
    return float(np.exp(total / max(count, 1)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="all")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="warm-start from the existing model.npz")
    ap.add_argument("--extra-steps", type=int, default=0)
    ap.add_argument("--lr", type=float, default=0.0)
    ap.add_argument("--task-frac", type=float, default=0.7)
    args = ap.parse_args()

    names = list(CONFIGS) if args.model == "all" else [args.model]
    for name in names:
        cfg = get_config(name)
        out_dir = os.path.join(args.out, name)
        os.makedirs(out_dir, exist_ok=True)
        init = None
        if args.resume:
            init = dict(np.load(os.path.join(out_dir, "model.npz")))
        params, log = train(
            cfg, args.seed + (1 if args.resume else 0), init=init,
            steps=args.extra_steps or None, lr=args.lr or None,
            task_frac=args.task_frac,
        )
        ppl = heldout_ppl(cfg, params)
        print(f"[{name}] held-out text ppl: {ppl:.2f}")
        np.savez(os.path.join(out_dir, "model.npz"), **params)
        with open(os.path.join(out_dir, "train_log.json"), "w") as f:
            json.dump({"model": name, "heldout_ppl": ppl, "log": log}, f, indent=1)


if __name__ == "__main__":
    main()
