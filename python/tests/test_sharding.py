"""Sharded paged serving correctness: TP shard/reduce decomposition over
per-shard pool slices, KV-write-only skipped-shard dispatch, PP stage
composition over per-stage pool slices, and the sharded AOT contract.

Equality scope (mirrors the runtime's bench gate): the PP stage
composition reproduces the single-device paged path BIT FOR BIT — per
layer it is the same op sequence over the same values, only the pool is
layer-sliced. TP cannot be fully bitwise: splitting the output/down
projections over shards re-associates the K-dimension float sum, so the
hidden state (and with it logits and layers>0 KV rows) drifts at float
epsilon — those compare under tight allclose plus greedy-argmax equality
(the token stream the scheduler actually consumes), while the KV-write
contract itself IS pinned bitwise (kvw vs full dispatch on the same x);
the rust mock gate holds sharded streams bit-identical by construction.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import get_config, heads_for_density

RTOL, ATOL = 2e-3, 2e-3


@pytest.fixture(scope="module", params=["opt-tiny", "llama-gqa"])
def setup(request):
    cfg = get_config(request.param)
    params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed=3).items()}
    return cfg, params


def _pool_from_dense(kv_dense, bs, seed=0, extra_blocks=3):
    """Pack a dense [L,2,B,G,N,dh] cache into a block pool + tables with
    scrambled physical block ids (block 0 = reserved null)."""
    L, two, B, G, N, dh = kv_dense.shape
    NB = N // bs
    P = 1 + B * NB + extra_blocks
    rng = np.random.default_rng(seed)
    ids = rng.permutation(np.arange(1, P))[: B * NB]
    pool = np.zeros((L, two, P, G, bs, dh), np.float32)
    table = np.zeros((B, NB), np.int32)
    dense = np.asarray(kv_dense)
    for b in range(B):
        for j in range(NB):
            blk = int(ids[b * NB + j])
            table[b, j] = blk
            pool[:, :, blk] = dense[:, :, b, :, j * bs:(j + 1) * bs]
    return jnp.asarray(pool), jnp.asarray(table)


def split_pool_groups(pool, n_shards):
    """Per-shard resident pool slices: group-axis split of the single
    pool (same P, same block tables address every slice)."""
    Gs = pool.shape[3] // n_shards
    return [pool[:, :, :, s * Gs:(s + 1) * Gs] for s in range(n_shards)]


def localize_heads(head_row, shard, Gs, Ks):
    """Global per-request group ids [B,Kh] -> shard-local [B,Ks] with
    sentinel Gs for slots owned by other shards (the runtime's
    localization, mirrored here)."""
    B = head_row.shape[0]
    out = np.full((B, Ks), Gs, np.int32)
    lo = shard * Gs
    for b in range(B):
        mine = [g - lo for g in head_row[b] if lo <= g < lo + Gs]
        out[b, :len(mine)] = mine[:Ks]
    return jnp.asarray(out)


def localize_mlp(idx_row, shard, Ds, Kms):
    """Global union neuron ids [Km] -> shard-local [Kms], sentinel Ds."""
    lo = shard * Ds
    mine = [i - lo for i in idx_row if lo <= i < lo + Ds]
    out = np.full(Kms, Ds, np.int32)
    out[:len(mine)] = mine[:Kms]
    return jnp.asarray(out)


def run_tp_paged(cfg, params, n_shards, tokens, lengths, table, pools, *,
                 head_idx=None, mlp_idx=None, mlp_topk=(), Ks=None, Kms=None):
    """Drive the TP shard/reduce entries the way the rust driver does:
    route-then-dispatch — a shard whose head groups are all unselected for
    a layer runs only the KV-write entry and contributes a zero partial."""
    G, Ds = cfg.n_groups, cfg.d_ff // n_shards
    Gs = G // n_shards
    B = tokens.shape[0]
    dispatched, skipped = 0, 0
    x = model.tp_embed(cfg, params, tokens, lengths)
    for l in range(cfg.n_layers):
        li = jnp.int32(l)
        partials = []
        for s in range(n_shards):
            if head_idx is None or l == 0:  # layer 0 stays dense (§3.2)
                p, pools[s] = model.tp_attn_shard_paged(
                    cfg, params, li, x, lengths, table, pools[s],
                    shard=s, n_shards=n_shards, mode="dense")
                dispatched += 1
            else:
                local = localize_heads(np.asarray(head_idx[l]), s, Gs, Ks)
                if bool((np.asarray(local) < Gs).any()):
                    p, pools[s] = model.tp_attn_shard_paged(
                        cfg, params, li, x, lengths, table, pools[s],
                        shard=s, n_shards=n_shards, mode="sha",
                        head_idx=local)
                    dispatched += 1
                else:
                    pools[s] = model.tp_attn_shard_paged(
                        cfg, params, li, x, lengths, table, pools[s],
                        shard=s, n_shards=n_shards, mode="kvw")
                    p = jnp.zeros((B, cfg.d_model), jnp.float32)
                    skipped += 1
            partials.append(p)
        x = model.tp_attn_reduce(cfg, params, li, x, partials)
        partials = []
        for s in range(n_shards):
            if mlp_idx is not None and mlp_topk:
                local = localize_mlp(np.asarray(mlp_idx[l]), s, Ds, Kms)
                p = model.tp_mlp_shard(cfg, params, li, x, shard=s,
                                       n_shards=n_shards, mlp_idx=local)
            else:
                p = model.tp_mlp_shard(cfg, params, li, x, shard=s,
                                       n_shards=n_shards)
            partials.append(p)
        x = model.tp_mlp_reduce(cfg, params, li, x, partials)
    return model.tp_final(cfg, params, x), pools, dispatched, skipped


def _decode_setup(cfg, params, seed, B=2, S=8, N=32, bs=8):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, 250, (B, S)).astype(np.int32)
    lens0 = np.array([S, S - 2], np.int32)[:B]
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray(lens0), N)
    pool, table = _pool_from_dense(kv, bs, seed=seed)
    new = jnp.asarray(rng.integers(0, 250, B).astype(np.int32))
    lens = jnp.asarray(lens0 + 1)
    return new, lens, pool, table


def test_tp_paged_dense_matches_single_device(setup):
    """Dense TP over per-shard pool slices == single-device fused paged
    decode: logits allclose + same argmax, per-shard pools equal to the
    single pool's group slices to float epsilon (the shard-sum
    reassociation perturbs the hidden state feeding layers>0 KV rows)."""
    cfg, params = setup
    new, lens, pool, table = _decode_setup(cfg, params, 50)
    want, pool_ref = model.decode_step_paged_fused(
        cfg, params, new, lens, pool, table, mode="dense")
    for n_shards in (2,) if cfg.n_groups < 4 else (2, 4):
        pools = split_pool_groups(pool, n_shards)
        got, pools, dispatched, skipped = run_tp_paged(
            cfg, params, n_shards, new, lens, table, pools)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=RTOL, atol=ATOL)
        np.testing.assert_array_equal(
            np.argmax(np.asarray(got), -1), np.argmax(np.asarray(want), -1))
        ref_slices = split_pool_groups(pool_ref, n_shards)
        for s in range(n_shards):
            np.testing.assert_allclose(
                np.asarray(pools[s]), np.asarray(ref_slices[s]),
                rtol=1e-4, atol=1e-5)
        assert dispatched == cfg.n_layers * n_shards and skipped == 0


def test_tp_paged_routed_skips_unselected_shards(setup):
    """Routed TP: shards whose groups are all unselected run only the
    KV-write entry + a zero partial, and the result still matches the
    single-device polar run of the same global head_idx — including the
    skipped shards' pools (KV is written even where attention is not)."""
    cfg, params = setup
    new, lens, pool, table = _decode_setup(cfg, params, 51)
    B, L, G = new.shape[0], cfg.n_layers, cfg.n_groups
    n_shards = 2
    Gs = G // n_shards
    k = heads_for_density(cfg, 0.5)
    Ks = min(k, Gs)
    # every request picks groups from shard 1 only (for l > 0): shard 0
    # must be attention-skipped at every sparse layer
    rng = np.random.default_rng(7)
    hi = np.zeros((L, B, k), np.int32)
    for l in range(L):
        for b in range(B):
            hi[l, b] = rng.permutation(np.arange(Gs, G, dtype=np.int32))[:k]
    hi = jnp.asarray(hi)
    want, pool_ref = model.decode_step_paged_fused(
        cfg, params, new, lens, pool, table, mode="polar", density=0.5,
        head_idx=hi)
    pools = split_pool_groups(pool, n_shards)
    got, pools, dispatched, skipped = run_tp_paged(
        cfg, params, n_shards, new, lens, table, pools, head_idx=hi, Ks=Ks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(got), -1), np.argmax(np.asarray(want), -1))
    # shard 0 skipped on every layer > 0, both shards dense on layer 0
    assert skipped == L - 1
    assert dispatched == 2 * L - (L - 1)
    # the skipped shard still wrote its KV rows: pools match the
    # single-device pool's group slices (to the same epsilon as above)
    ref_slices = split_pool_groups(pool_ref, n_shards)
    for s in range(n_shards):
        np.testing.assert_allclose(
            np.asarray(pools[s]), np.asarray(ref_slices[s]),
            rtol=1e-4, atol=1e-5)


def test_tp_kvw_entry_writes_same_kv_as_full_dispatch(setup):
    """mode='kvw' must produce the exact pool a full dense dispatch of the
    same shard would have produced (attention reads KV, never writes it)."""
    cfg, params = setup
    new, lens, pool, table = _decode_setup(cfg, params, 52)
    n_shards = 2
    pools = split_pool_groups(pool, n_shards)
    x = model.tp_embed(cfg, params, new, lens)
    li = jnp.int32(1)
    _, pool_full = model.tp_attn_shard_paged(
        cfg, params, li, x, lens, table, pools[0], shard=0,
        n_shards=n_shards, mode="dense")
    pool_kvw = model.tp_attn_shard_paged(
        cfg, params, li, x, lens, table, pools[0], shard=0,
        n_shards=n_shards, mode="kvw")
    np.testing.assert_array_equal(np.asarray(pool_kvw), np.asarray(pool_full))


def test_tp_sha_sentinel_rows_are_exact_zero(setup):
    """An all-sentinel head_idx row must yield an exactly-zero partial —
    the invariant that lets the driver substitute a zero buffer for a
    skipped shard without changing the reduce."""
    cfg, params = setup
    new, lens, pool, table = _decode_setup(cfg, params, 53)
    n_shards = 2
    Gs = cfg.n_groups // n_shards
    pools = split_pool_groups(pool, n_shards)
    x = model.tp_embed(cfg, params, new, lens)
    B = new.shape[0]
    sent = jnp.full((B, max(1, Gs)), Gs, jnp.int32)
    partial, _ = model.tp_attn_shard_paged(
        cfg, params, jnp.int32(1), x, lens, table, pools[0], shard=0,
        n_shards=n_shards, mode="sha", head_idx=sent)
    np.testing.assert_array_equal(
        np.asarray(partial), np.zeros((B, cfg.d_model), np.float32))


def test_tp_mlp_idx_shards_compose_to_sparse_mlp():
    """Localized union indices: shard partials + reduce == the
    single-device selective MLP over the same global union; a shard owning
    no union neuron contributes an exactly-zero partial."""
    cfg = get_config("opt-tiny")
    params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed=4).items()}
    rng = np.random.default_rng(8)
    B, Dff, L = 3, cfg.d_ff, cfg.n_layers
    n_shards = 2
    Ds = Dff // n_shards
    x = jnp.asarray(rng.standard_normal((B, cfg.d_model)).astype(np.float32))
    l, Km = 1, Dff // 4
    idx = jnp.asarray(rng.permutation(Dff)[:Km].astype(np.int32))
    h = model.layer_norm(x, params["ln2_g"][l], params["ln2_b"][l])
    want = model.mlp_sparse(cfg, params, l, h, Km, idx=idx)
    li = jnp.int32(l)
    partials = [
        model.tp_mlp_shard(cfg, params, li, x, shard=s, n_shards=n_shards,
                           mlp_idx=localize_mlp(np.asarray(idx), s, Ds, Km))
        for s in range(n_shards)
    ]
    got = model.tp_mlp_reduce(cfg, params, li, x, partials)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x + want),
                               rtol=1e-5, atol=1e-5)
    # all-sentinel shard: exact zero partial
    zero = model.tp_mlp_shard(cfg, params, li, x, shard=0, n_shards=n_shards,
                              mlp_idx=jnp.full((Km,), Ds, jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(zero), np.zeros((B, cfg.d_model), np.float32))


def split_pool_layers(pool, l0):
    """Per-stage resident pool slices: layer split of the single pool."""
    return pool[:l0], pool[l0:]


def test_pp_paged_stages_compose_bitwise(setup):
    """PP over per-stage pool slices: stage0 (embed + layers [0,Lh)) then
    stage1 (layers [Lh,L) + head) over the SAME block tables reproduces
    the single-device fused paged decode bit for bit — logits and both
    stage pools — in dense and routed polar modes."""
    cfg, params = setup
    new, lens, pool, table = _decode_setup(cfg, params, 54)
    L, G, B = cfg.n_layers, cfg.n_groups, new.shape[0]
    Lh = L // 2
    k = heads_for_density(cfg, 0.5)
    hi = jnp.asarray(
        np.random.default_rng(9).integers(0, G, (L, B, k)).astype(np.int32))
    cases = [dict(mode="dense"),
             dict(mode="polar", density=0.5, head_idx=hi)]
    for kw in cases:
        # eager single-device reference: the exact op sequence the stages
        # replay per layer (jit fusion would perturb it at float epsilon)
        xr = model._embed(cfg, params, new, lens - 1)
        xr, pool_ref = model.decode_core_paged(
            cfg, params, xr, lens, pool, table, **kw)
        want = model.final_logits(cfg, params, xr)
        fused, _ = model.decode_step_paged_fused(
            cfg, params, new, lens, pool, table, **kw)
        np.testing.assert_allclose(np.asarray(want), np.asarray(fused),
                                   rtol=RTOL, atol=ATOL)
        kv0, kv1 = split_pool_layers(pool, Lh)
        x = model._embed(cfg, params, new, lens - 1)
        x, kv0 = model.decode_core_paged(
            cfg, params, x, lens, kv0, table, layer_begin=0, layer_end=Lh,
            **kw)
        x, kv1 = model.decode_core_paged(
            cfg, params, x, lens, kv1, table, layer_begin=Lh, layer_end=L,
            **kw)
        got = model.final_logits(cfg, params, x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(kv0), np.asarray(pool_ref)[:Lh])
        np.testing.assert_array_equal(np.asarray(kv1), np.asarray(pool_ref)[Lh:])


def test_tp_multi_step_stream_matches_single_device(setup):
    """A short greedy decode chain through the TP composition produces the
    same token stream as the single-device paged path (the scheduler-level
    invariant the rust mock gate holds bit-identically)."""
    cfg, params = setup
    new, lens, pool, table = _decode_setup(cfg, params, 55)
    n_shards = 2
    pool_sd = pool
    pools = split_pool_groups(pool, n_shards)
    lens_sd = lens
    new_sd = new
    new_tp, lens_tp = new, lens
    for _ in range(4):
        want, pool_sd = model.decode_step_paged_fused(
            cfg, params, new_sd, lens_sd, pool_sd, table, mode="dense")
        got, pools, _, _ = run_tp_paged(
            cfg, params, n_shards, new_tp, lens_tp, table, pools)
        tok_w = np.argmax(np.asarray(want), -1).astype(np.int32)
        tok_g = np.argmax(np.asarray(got), -1).astype(np.int32)
        np.testing.assert_array_equal(tok_g, tok_w)
        new_sd = jnp.asarray(tok_w)
        new_tp = jnp.asarray(tok_g)
        lens_sd = lens_sd + 1
        lens_tp = lens_tp + 1


def test_aot_tp_paged_entries_contract(tmp_path):
    """Manifest contract of the sharded entries: per-shard paged attention
    (dense | sha with local head_idx | kvw), biasless MLP shards with
    meta.top_k, and per-layer reduce entries; no contiguous-KV shard
    entries remain."""
    import json as _json
    from compile import aot
    from compile.configs import BATCH_BUCKETS, KV_BLOCK, SEQ_BUCKETS, \
        kv_pool_blocks

    cfg = get_config("opt-small")
    table = {"recall_targets": {"0.99": {
        str(b): [cfg.d_ff // 4] * cfg.n_layers for b in [1, 4, 16]}}}
    mdir = tmp_path / cfg.name
    mdir.mkdir(parents=True)
    (mdir / "topk_table.json").write_text(_json.dumps(table))

    for S in (2, 4):
        entries = {e.name: e for e in aot.tp_entries(cfg, str(tmp_path), S)}
        Gs = cfg.n_groups // S
        Ds = cfg.d_ff // S
        Ks = min(heads_for_density(cfg, cfg.critical_density), Gs)
        P = kv_pool_blocks(BATCH_BUCKETS, SEQ_BUCKETS)
        pshape = [cfg.n_layers, 2, P, Gs, KV_BLOCK, cfg.d_head]
        Kms = min(cfg.d_ff // 4, Ds)

        for s in range(S):
            de = entries[f"tp{S}_attn_s{s}_dense_b4_n256_paged_fused"]
            assert [d["name"] for d in de.data] == \
                ["layer", "x", "lengths", "block_table", "kv"]
            assert de.data[4]["shape"] == pshape
            assert [o["name"] for o in de.outputs] == ["partial", "kv"]

            sh = entries[f"tp{S}_attn_s{s}_sha_d0250_b4_n256_paged_fused"]
            assert sh.data[5]["name"] == "head_idx"
            assert sh.data[5]["shape"] == [4, Ks]
            assert sh.meta["head_k"] == Ks

            kvw = entries[f"tp{S}_attn_s{s}_kvw_b4_n256_paged_fused"]
            assert [o["name"] for o in kvw.outputs] == ["kv"]
            assert kvw.meta["mode"] == "kvw"

            mk = entries[f"tp{S}_mlp_s{s}_k{Kms}_b4"]
            assert mk.meta["top_k"] == Kms
            assert mk.data[2]["name"] == "mlp_idx"
            assert mk.data[2]["shape"] == [Kms]
            assert entries[f"tp{S}_mlp_s{s}_dense_b4"].meta["top_k"] == 0

        for op in ("attn", "mlp"):
            re = entries[f"tp{S}_{op}_reduce_b4"]
            assert [d["name"] for d in re.data] == \
                ["layer", "x"] + [f"p{s}" for s in range(S)]
            assert re.meta["op"] == op

        # no contiguous-KV shard entries remain
        for name in entries:
            assert "attn" not in name or name.endswith("_paged_fused") \
                or "reduce" in name, name


def test_aot_pp_paged_entries_contract(tmp_path):
    """PP stages are paged + index-taking: per-stage pool slices, shared
    block table, full-depth head_idx (+ mlp_idx on ReLU models)."""
    import json as _json
    from compile import aot
    from compile.configs import BATCH_BUCKETS, KV_BLOCK, SEQ_BUCKETS, \
        kv_pool_blocks

    cfg = get_config("opt-small")
    table = {"recall_targets": {"0.99": {
        str(b): [cfg.d_ff // 4] * cfg.n_layers for b in BATCH_BUCKETS}}}
    mdir = tmp_path / cfg.name
    mdir.mkdir(parents=True)
    (mdir / "topk_table.json").write_text(_json.dumps(table))

    entries = {e.name: e for e in aot.pp_entries(cfg, str(tmp_path))}
    L, Lh = cfg.n_layers, cfg.n_layers // 2
    P = kv_pool_blocks(BATCH_BUCKETS, SEQ_BUCKETS)
    Kh = heads_for_density(cfg, cfg.critical_density)

    s0 = entries[f"pp2_stage0_dense_b4_n256_paged_fused"]
    assert [d["name"] for d in s0.data] == \
        ["tokens", "lengths", "block_table", "kv"]
    assert s0.data[3]["shape"] == [Lh, 2, P, cfg.n_kv_heads, KV_BLOCK,
                                   cfg.d_head]
    s1 = entries[f"pp2_stage1_polar_d0250_b4_n256_paged_fused"]
    assert [d["name"] for d in s1.data] == \
        ["x", "lengths", "block_table", "kv", "head_idx", "mlp_idx"]
    assert s1.data[0]["shape"] == [4, cfg.d_model]
    assert s1.data[3]["shape"] == [L - Lh, 2, P, cfg.n_kv_heads, KV_BLOCK,
                                   cfg.d_head]
    assert s1.data[4]["shape"] == [L, 4, Kh]          # full depth
    assert s1.meta["routed"] and s1.meta["stage"] == 1
    for name in entries:
        assert name.endswith("_paged_fused"), name
