"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/top-k; these are the core numeric signal for the
AOT path (everything the rust runtime executes lowers through these ops).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sel_gemm, sha_decode

RTOL, ATOL = 2e-4, 2e-4


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# Selective Head Attention (Algorithm 1)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 4),
    g=st.sampled_from([2, 4, 8]),
    nblk=st.integers(1, 4),
    dh=st.sampled_from([8, 16, 24]),
    data=st.data(),
)
def test_sha_mha_matches_ref(b, g, nblk, dh, data):
    n = nblk * 32
    t = data.draw(st.integers(1, g))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    q = rand(rng, b, g, dh)
    k = rand(rng, b, g, n, dh)
    v = rand(rng, b, g, n, dh)
    hi = np.stack([
        rng.choice(g, t, replace=False).astype(np.int32) for _ in range(b)
    ])
    lens = rng.integers(1, n + 1, b).astype(np.int32)
    out = sha_decode.sha_decode(q, k, v, hi, lens)
    want = ref.sha_decode_ref(q, k, v, hi, lens)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 3),
    g=st.sampled_from([2, 4]),
    qpg=st.sampled_from([2, 4]),
    data=st.data(),
)
def test_sha_gqa_matches_ref(b, g, qpg, data):
    n, dh = 64, 16
    t = data.draw(st.integers(1, g))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    q = rand(rng, b, g * qpg, dh)
    k = rand(rng, b, g, n, dh)
    v = rand(rng, b, g, n, dh)
    hi = np.stack([
        rng.choice(g, t, replace=False).astype(np.int32) for _ in range(b)
    ])
    lens = rng.integers(1, n + 1, b).astype(np.int32)
    out = sha_decode.sha_decode(q, k, v, hi, lens, q_per_group=qpg)
    want = ref.sha_decode_ref(q, k, v, hi, lens, q_per_group=qpg)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


def test_sha_dense_equals_identity_index():
    rng = np.random.default_rng(0)
    q, k, v = rand(rng, 2, 4, 16), rand(rng, 2, 4, 64, 16), rand(rng, 2, 4, 64, 16)
    lens = np.array([30, 64], np.int32)
    a = sha_decode.dense_decode_attention(q, k, v, lens)
    b = ref.dense_decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)


def test_sha_masks_beyond_length():
    """Values past `lengths` must not influence the output."""
    rng = np.random.default_rng(1)
    q, k, v = rand(rng, 1, 2, 16), rand(rng, 1, 2, 64, 16), rand(rng, 1, 2, 64, 16)
    lens = np.array([17], np.int32)
    hi = np.array([[0, 1]], np.int32)
    base = np.asarray(sha_decode.sha_decode(q, k, v, hi, lens))
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 17:, :] = 1e6
    v2[:, :, 17:, :] = -1e6
    pert = np.asarray(sha_decode.sha_decode(q, k2, v2, hi, lens))
    np.testing.assert_allclose(base, pert, rtol=1e-5, atol=1e-5)


def test_sha_rejects_bad_shapes():
    rng = np.random.default_rng(2)
    q, k, v = rand(rng, 1, 4, 16), rand(rng, 1, 2, 64, 16), rand(rng, 1, 2, 64, 16)
    with pytest.raises(ValueError):
        sha_decode.sha_decode(q, k, v, np.zeros((1, 1), np.int32),
                              np.array([64], np.int32))  # H != G*qpg
    with pytest.raises(ValueError):
        sha_decode.sha_decode(
            rand(rng, 1, 2, 16), rand(rng, 1, 2, 60, 16), rand(rng, 1, 2, 60, 16),
            np.zeros((1, 1), np.int32), np.array([60], np.int32),
        )  # N not multiple of blk


# ---------------------------------------------------------------------------
# Sparse fused GEMM (Algorithm 3)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([1, 2, 16, 32]),
    kdim=st.sampled_from([32, 128]),
    dcap=st.sampled_from([128, 512]),
    sblk=st.integers(1, 4),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31),
)
def test_sel_gemm_nt_matches_ref(m, kdim, dcap, sblk, act, seed):
    s = sblk * 32
    if s > dcap:
        s = dcap
    rng = np.random.default_rng(seed)
    a = rand(rng, m, kdim)
    w = rand(rng, dcap, kdim)
    idx = rng.choice(dcap, s, replace=False).astype(np.int32)
    out = sel_gemm.sel_gemm_nt(a, w, idx, activation=act)
    want = ref.sel_gemm_nt_ref(a, w, idx, activation=act)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([1, 2, 16]),
    sblk=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_sel_gemm_nn_matches_ref(m, sblk, seed):
    s, dcap, kdim = sblk * 32, 256, 64
    rng = np.random.default_rng(seed)
    h = rand(rng, m, s)
    w = rand(rng, dcap, kdim)
    idx = rng.choice(dcap, s, replace=False).astype(np.int32)
    out = sel_gemm.sel_gemm_nn(h, w, idx)
    want = ref.sel_gemm_nn_ref(h, w, idx)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


def test_sparse_mlp_full_index_equals_dense():
    """With every neuron selected, the sparse MLP is the dense MLP."""
    rng = np.random.default_rng(3)
    m, d, dff = 4, 32, 64
    x = rand(rng, m, d)
    w1, w2 = rand(rng, dff, d), rand(rng, dff, d)
    b1, b2 = rand(rng, dff), rand(rng, d)
    idx = np.arange(dff, dtype=np.int32)
    sparse = np.asarray(sel_gemm.sparse_mlp(x, w1, b1, w2, b2, idx))
    dense = np.maximum(x @ w1.T + b1, 0.0) @ w2 + b2
    np.testing.assert_allclose(sparse, dense, rtol=RTOL, atol=ATOL)


def test_sparse_mlp_masks_unselected_neurons():
    """Unselected neurons contribute nothing (the paper's exact-sparsity
    property: selective != approximate for the selected set)."""
    rng = np.random.default_rng(4)
    m, d, dff, s = 2, 16, 64, 32
    x = rand(rng, m, d)
    w1, w2 = rand(rng, dff, d), rand(rng, dff, d)
    b1, b2 = rand(rng, dff), rand(rng, d)
    idx = rng.choice(dff, s, replace=False).astype(np.int32)
    out = np.asarray(ref.sparse_mlp_ref(x, w1, b1, w2, b2, idx))
    # corrupt the unselected rows: output must not change
    mask = np.ones(dff, bool)
    mask[idx] = False
    w1c, w2c = w1.copy(), w2.copy()
    w1c[mask] = 1e9
    w2c[mask] = -1e9
    out2 = np.asarray(ref.sparse_mlp_ref(x, w1c, b1, w2c, b2, idx))
    np.testing.assert_allclose(out, out2)
