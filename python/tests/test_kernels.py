"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/top-k; these are the core numeric signal for the
AOT path (everything the rust runtime executes lowers through these ops).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised where hypothesis is absent
    # The module must stay collectable without hypothesis: property tests
    # skip with a reason, everything else runs. The stand-ins keep the
    # module-level decorator expressions valid.
    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(**kw):
        return lambda fn: fn

    def given(**kw):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed; property test skipped")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

from compile.kernels import ref, sel_gemm, sha_decode

RTOL, ATOL = 2e-4, 2e-4


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# Selective Head Attention (Algorithm 1)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 4),
    g=st.sampled_from([2, 4, 8]),
    nblk=st.integers(1, 4),
    dh=st.sampled_from([8, 16, 24]),
    data=st.data(),
)
def test_sha_mha_matches_ref(b, g, nblk, dh, data):
    n = nblk * 32
    t = data.draw(st.integers(1, g))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    q = rand(rng, b, g, dh)
    k = rand(rng, b, g, n, dh)
    v = rand(rng, b, g, n, dh)
    hi = np.stack([
        rng.choice(g, t, replace=False).astype(np.int32) for _ in range(b)
    ])
    lens = rng.integers(1, n + 1, b).astype(np.int32)
    out = sha_decode.sha_decode(q, k, v, hi, lens)
    want = ref.sha_decode_ref(q, k, v, hi, lens)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 3),
    g=st.sampled_from([2, 4]),
    qpg=st.sampled_from([2, 4]),
    data=st.data(),
)
def test_sha_gqa_matches_ref(b, g, qpg, data):
    n, dh = 64, 16
    t = data.draw(st.integers(1, g))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    q = rand(rng, b, g * qpg, dh)
    k = rand(rng, b, g, n, dh)
    v = rand(rng, b, g, n, dh)
    hi = np.stack([
        rng.choice(g, t, replace=False).astype(np.int32) for _ in range(b)
    ])
    lens = rng.integers(1, n + 1, b).astype(np.int32)
    out = sha_decode.sha_decode(q, k, v, hi, lens, q_per_group=qpg)
    want = ref.sha_decode_ref(q, k, v, hi, lens, q_per_group=qpg)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


def test_sha_dense_equals_identity_index():
    rng = np.random.default_rng(0)
    q, k, v = rand(rng, 2, 4, 16), rand(rng, 2, 4, 64, 16), rand(rng, 2, 4, 64, 16)
    lens = np.array([30, 64], np.int32)
    a = sha_decode.dense_decode_attention(q, k, v, lens)
    b = ref.dense_decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)


def test_sha_masks_beyond_length():
    """Values past `lengths` must not influence the output."""
    rng = np.random.default_rng(1)
    q, k, v = rand(rng, 1, 2, 16), rand(rng, 1, 2, 64, 16), rand(rng, 1, 2, 64, 16)
    lens = np.array([17], np.int32)
    hi = np.array([[0, 1]], np.int32)
    base = np.asarray(sha_decode.sha_decode(q, k, v, hi, lens))
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 17:, :] = 1e6
    v2[:, :, 17:, :] = -1e6
    pert = np.asarray(sha_decode.sha_decode(q, k2, v2, hi, lens))
    np.testing.assert_allclose(base, pert, rtol=1e-5, atol=1e-5)


def test_sha_rejects_bad_shapes():
    rng = np.random.default_rng(2)
    q, k, v = rand(rng, 1, 4, 16), rand(rng, 1, 2, 64, 16), rand(rng, 1, 2, 64, 16)
    with pytest.raises(ValueError):
        sha_decode.sha_decode(q, k, v, np.zeros((1, 1), np.int32),
                              np.array([64], np.int32))  # H != G*qpg


@pytest.mark.parametrize("n", [60, 33, 5])
def test_sha_partial_final_tile(n):
    """N not a multiple of blk: the masked partial tile must include the
    trailing KV rows (regression: they were silently dropped)."""
    rng = np.random.default_rng(5)
    b, g, dh = 2, 2, 16
    q = rand(rng, b, g, dh)
    k = rand(rng, b, g, n, dh)
    v = rand(rng, b, g, n, dh)
    hi = np.stack([rng.permutation(g).astype(np.int32) for _ in range(b)])
    # lengths reaching into the final partial tile — the dropped region
    lens = np.array([n, max(1, n - 1)], np.int32)
    out = sha_decode.sha_decode(q, k, v, hi, lens)
    want = ref.sha_decode_ref(q, k, v, hi, lens)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)
    # and the tail rows actually matter: perturbing them changes the output
    k2 = k.copy()
    k2[:, :, -1, :] += 3.0
    pert = np.asarray(sha_decode.sha_decode(q, k2, v, hi, lens))
    assert not np.allclose(out, pert, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# Fused paged Selective Head Attention
# ---------------------------------------------------------------------------


def _paged_cache(rng, b, g, n, dh, bs=16, extra=2):
    """Scrambled block pool + tables and their gathered dense view."""
    nb = n // bs
    p = 1 + b * nb + extra
    table = rng.permutation(np.arange(1, p))[: b * nb].reshape(b, nb)
    table = table.astype(np.int32)
    kpool = rand(rng, p, g, bs, dh)
    vpool = rand(rng, p, g, bs, dh)
    kd = kpool[table.reshape(-1)].reshape(b, nb, g, bs, dh)
    kd = np.moveaxis(kd, 2, 1).reshape(b, g, n, dh)
    vd = vpool[table.reshape(-1)].reshape(b, nb, g, bs, dh)
    vd = np.moveaxis(vd, 2, 1).reshape(b, g, n, dh)
    return kpool, vpool, table, kd, vd


@pytest.mark.parametrize("qpg", [1, 2])
def test_sha_paged_matches_gathered_ref(qpg):
    """The fused kernel reading KV through the block table must match the
    reference on the gathered dense view, with the selected head rows in
    dense [B,H,dh] layout and unselected rows exactly zero."""
    rng = np.random.default_rng(6)
    b, g, n, dh, t = 3, 4, 64, 16, 2
    q = rand(rng, b, g * qpg, dh)
    kpool, vpool, table, kd, vd = _paged_cache(rng, b, g, n, dh)
    hi = np.stack([rng.choice(g, t, replace=False).astype(np.int32)
                   for _ in range(b)])
    lens = rng.integers(1, n + 1, b).astype(np.int32)
    out = np.asarray(sha_decode.sha_decode_paged(
        q, kpool, vpool, table, hi, lens, q_per_group=qpg))
    want = np.asarray(ref.sha_decode_ref(q, kd, vd, hi, lens, q_per_group=qpg))
    sel = np.zeros((b, g * qpg), bool)
    for i in range(b):
        rows = (hi[i][:, None] * qpg + np.arange(qpg)[None, :]).reshape(-1)
        np.testing.assert_allclose(out[i, rows], want[i], rtol=RTOL, atol=ATOL)
        sel[i, rows] = True
    assert (out[~sel] == 0.0).all()


def test_sha_paged_head_idx_ties():
    """Duplicate group ids in head_idx: the tied programs compute identical
    rows, so whichever write lands last the result is well-defined."""
    rng = np.random.default_rng(7)
    b, g, n, dh, qpg = 2, 4, 32, 8, 2
    q = rand(rng, b, g * qpg, dh)
    kpool, vpool, table, kd, vd = _paged_cache(rng, b, g, n, dh)
    hi = np.array([[1, 1], [3, 3]], np.int32)
    lens = np.array([n, n - 5], np.int32)
    out = np.asarray(sha_decode.sha_decode_paged(
        q, kpool, vpool, table, hi, lens, q_per_group=qpg))
    want = np.asarray(ref.sha_decode_ref(q, kd, vd, hi, lens, q_per_group=qpg))
    for i in range(b):
        rows = slice(hi[i, 0] * qpg, (hi[i, 0] + 1) * qpg)
        np.testing.assert_allclose(out[i, rows], want[i, :qpg],
                                   rtol=RTOL, atol=ATOL)


def test_sha_paged_null_blocks_masked():
    """Table entries past `lengths` point at the reserved null block (id 0);
    whatever it holds must not influence the output."""
    rng = np.random.default_rng(8)
    b, g, n, dh, bs = 1, 2, 64, 8, 16
    q = rand(rng, b, g, dh)
    kpool, vpool, table, _, _ = _paged_cache(rng, b, g, n, dh, bs=bs)
    table = table.copy()
    table[0, 2:] = 0                      # only blocks 0..1 are live
    lens = np.array([2 * bs], np.int32)
    base = np.asarray(sha_decode.sha_decode_paged(
        q, kpool, vpool, table, np.array([[0, 1]], np.int32), lens))
    kpool2, vpool2 = kpool.copy(), vpool.copy()
    kpool2[0] = 1e6
    vpool2[0] = -1e6
    pert = np.asarray(sha_decode.sha_decode_paged(
        q, kpool2, vpool2, table, np.array([[0, 1]], np.int32), lens))
    np.testing.assert_allclose(base, pert, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Fused paged prefill attention
# ---------------------------------------------------------------------------


def _prefill_ref(q, kd, vd, off, qpg):
    """Causal masked-softmax oracle on the gathered dense view.
    q: [B,C,H,dh]; kd/vd: [B,G,N,dh]; off: [B]."""
    b, c, h, dh = q.shape
    n = kd.shape[2]
    scale = 1.0 / np.sqrt(dh)
    out = np.zeros_like(q)
    for i in range(b):
        for hh in range(h):
            g = hh // qpg
            for cc in range(c):
                s = (kd[i, g] @ q[i, cc, hh]) * scale
                s = np.where(np.arange(n) <= off[i] + cc, s, -np.inf)
                p = np.exp(s - s.max())
                out[i, cc, hh] = (p / p.sum()) @ vd[i, g]
    return out


@pytest.mark.parametrize("qpg", [1, 2])
def test_prefill_paged_matches_masked_ref(qpg):
    """The fused prefill kernel reading KV through the block table must
    match the causal masked-softmax oracle on the gathered dense view —
    including per-slot offsets that start and end mid-block."""
    rng = np.random.default_rng(10)
    b, g, n, dh, c, bs = 2, 2, 64, 8, 8, 16
    q = rand(rng, b, c, g * qpg, dh)
    kpool, vpool, table, kd, vd = _paged_cache(rng, b, g, n, dh, bs=bs)
    off = np.array([5, 19], np.int32)     # both mid-block
    out = np.asarray(sha_decode.prefill_attention_paged(
        q, kpool, vpool, table, off, q_per_group=qpg))
    want = _prefill_ref(q, kd, vd, off, qpg)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


def test_prefill_paged_partial_block_tail():
    """Partial-tile regression (the `N % blk != 0` class of bug fixed in
    `_sha_kernel`): tiles here are whole pool blocks, so the hazard is a
    partially *occupied* final block. The last visible row must still
    influence the output, and the first row past the causal horizon must
    not."""
    rng = np.random.default_rng(11)
    b, g, n, dh, c, bs = 1, 2, 64, 8, 4, 16
    q = rand(rng, b, c, g, dh)
    kpool, vpool, table, _, _ = _paged_cache(rng, b, g, n, dh, bs=bs)
    off = np.array([17], np.int32)        # final query at pos 20, mid-block 1
    base = np.asarray(sha_decode.prefill_attention_paged(
        q, kpool, vpool, table, off))
    last_blk, last_row = int(table[0, 20 // bs]), 20 % bs
    kpool2 = kpool.copy()
    kpool2[last_blk, :, last_row] += 3.0  # last visible row: must matter
    pert = np.asarray(sha_decode.prefill_attention_paged(
        q, kpool2, vpool, table, off))
    assert not np.allclose(base[0, -1], pert[0, -1], rtol=RTOL, atol=ATOL)
    kpool3, vpool3 = kpool.copy(), vpool.copy()
    kpool3[last_blk, :, last_row + 1:] = 1e6   # past the horizon: masked
    vpool3[last_blk, :, last_row + 1:] = -1e6
    pert2 = np.asarray(sha_decode.prefill_attention_paged(
        q, kpool3, vpool3, table, off))
    np.testing.assert_allclose(base, pert2, rtol=1e-5, atol=1e-5)


def test_prefill_paged_null_blocks_masked():
    """Trailing table entries past the causal horizon point at the
    reserved null block (id 0); its contents must not influence any
    in-range query."""
    rng = np.random.default_rng(12)
    b, g, n, dh, c, bs = 1, 2, 64, 8, 8, 16
    q = rand(rng, b, c, g, dh)
    kpool, vpool, table, _, _ = _paged_cache(rng, b, g, n, dh, bs=bs)
    table = table.copy()
    table[0, 2:] = 0                      # only blocks 0..1 are live
    off = np.array([2 * bs - c], np.int32)  # last query ends block 1 exactly
    base = np.asarray(sha_decode.prefill_attention_paged(
        q, kpool, vpool, table, off))
    kpool2, vpool2 = kpool.copy(), vpool.copy()
    kpool2[0] = 1e6
    vpool2[0] = -1e6
    pert = np.asarray(sha_decode.prefill_attention_paged(
        q, kpool2, vpool2, table, off))
    np.testing.assert_allclose(base, pert, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Sparse fused GEMM (Algorithm 3)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([1, 2, 16, 32]),
    kdim=st.sampled_from([32, 128]),
    dcap=st.sampled_from([128, 512]),
    sblk=st.integers(1, 4),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31),
)
def test_sel_gemm_nt_matches_ref(m, kdim, dcap, sblk, act, seed):
    s = sblk * 32
    if s > dcap:
        s = dcap
    rng = np.random.default_rng(seed)
    a = rand(rng, m, kdim)
    w = rand(rng, dcap, kdim)
    idx = rng.choice(dcap, s, replace=False).astype(np.int32)
    out = sel_gemm.sel_gemm_nt(a, w, idx, activation=act)
    want = ref.sel_gemm_nt_ref(a, w, idx, activation=act)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([1, 2, 16]),
    sblk=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_sel_gemm_nn_matches_ref(m, sblk, seed):
    s, dcap, kdim = sblk * 32, 256, 64
    rng = np.random.default_rng(seed)
    h = rand(rng, m, s)
    w = rand(rng, dcap, kdim)
    idx = rng.choice(dcap, s, replace=False).astype(np.int32)
    out = sel_gemm.sel_gemm_nn(h, w, idx)
    want = ref.sel_gemm_nn_ref(h, w, idx)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


def test_sparse_mlp_full_index_equals_dense():
    """With every neuron selected, the sparse MLP is the dense MLP."""
    rng = np.random.default_rng(3)
    m, d, dff = 4, 32, 64
    x = rand(rng, m, d)
    w1, w2 = rand(rng, dff, d), rand(rng, dff, d)
    b1, b2 = rand(rng, dff), rand(rng, d)
    idx = np.arange(dff, dtype=np.int32)
    sparse = np.asarray(sel_gemm.sparse_mlp(x, w1, b1, w2, b2, idx))
    dense = np.maximum(x @ w1.T + b1, 0.0) @ w2 + b2
    np.testing.assert_allclose(sparse, dense, rtol=RTOL, atol=ATOL)


def test_sparse_mlp_fused_bitwise_equals_shell():
    """The fused-bias MLP (bias + activation inside the kernels, no
    elementwise shells) runs the same op sequence as the staged version,
    so the outputs are bit-identical."""
    rng = np.random.default_rng(9)
    m, d, dff, s = 4, 32, 128, 64
    x = rand(rng, m, d)
    w1, w2 = rand(rng, dff, d), rand(rng, dff, d)
    b1, b2 = rand(rng, dff), rand(rng, d)
    idx = rng.choice(dff, s, replace=False).astype(np.int32)
    fused = np.asarray(sel_gemm.sparse_mlp_fused(x, w1, b1, w2, b2, idx))
    shell = np.asarray(sel_gemm.sparse_mlp(x, w1, b1, w2, b2, idx))
    np.testing.assert_array_equal(fused, shell)
    want = np.asarray(ref.sparse_mlp_ref(x, w1, b1, w2, b2, idx))
    np.testing.assert_allclose(fused, want, rtol=RTOL, atol=ATOL)


def test_sparse_mlp_masks_unselected_neurons():
    """Unselected neurons contribute nothing (the paper's exact-sparsity
    property: selective != approximate for the selected set)."""
    rng = np.random.default_rng(4)
    m, d, dff, s = 2, 16, 64, 32
    x = rand(rng, m, d)
    w1, w2 = rand(rng, dff, d), rand(rng, dff, d)
    b1, b2 = rand(rng, dff), rand(rng, d)
    idx = rng.choice(dff, s, replace=False).astype(np.int32)
    out = np.asarray(ref.sparse_mlp_ref(x, w1, b1, w2, b2, idx))
    # corrupt the unselected rows: output must not change
    mask = np.ones(dff, bool)
    mask[idx] = False
    w1c, w2c = w1.copy(), w2.copy()
    w1c[mask] = 1e9
    w2c[mask] = -1e9
    out2 = np.asarray(ref.sparse_mlp_ref(x, w1c, b1, w2c, b2, idx))
    np.testing.assert_allclose(out, out2)
