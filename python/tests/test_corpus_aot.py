"""Corpus determinism + task-suite semantics + (if built) artifact
manifest integrity."""

import json
import os

import numpy as np
import pytest

from compile import corpus
from compile.configs import BOS, CONFIGS, get_config

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_stream_deterministic_and_in_vocab():
    a = corpus.training_stream(1, 5000)
    b = corpus.training_stream(1, 5000)
    np.testing.assert_array_equal(a, b)
    assert a[0] == BOS
    assert a.max() < 259 and a.min() >= 0


def test_task_answers_are_correct_by_construction():
    rng = np.random.default_rng(0)
    for fam in corpus.TASK_FAMILIES:
        for _ in range(25):
            p, a = corpus._sample(rng, fam)
            assert p.endswith("="), (fam, p)
            if fam == "copy":
                assert a == p[len("copy:"):-1]
            elif fam == "rev":
                assert a == p[len("rev:"):-1][::-1]
            elif fam == "add":
                x, y = p[len("add:"):-1].split("+")
                assert int(a) == int(x) + int(y)
            elif fam == "srt":
                assert a == "".join(sorted(p[len("srt:"):-1]))
            elif fam == "cmp":
                x, y = p[len("cmp:"):-1].split(",")
                assert a == ("<" if int(x) < int(y) else ">")
            elif fam == "succ":
                c = p[len("succ:"):-1]
                assert ord(a) == ord(c) + 1
            elif fam == "maj":
                s = p[len("maj:"):-1]
                assert s.count(a) > len(s) / 2
            elif fam == "kv":
                body, q = p[len("kv:"):-1].split("?")
                pairs = dict((x[0], x[1]) for x in body.split(" "))
                assert pairs[q] == a
            elif fam == "pat":
                s = p[len("pat:"):-2]  # strip "*="
                assert a * (len(s) // len(a)) == s


def test_eval_suite_fixed_and_balanced():
    a = corpus.eval_suite(seed=1234, per_family=5)
    b = corpus.eval_suite(seed=1234, per_family=5)
    assert a == b
    fams = [x["family"] for x in a]
    for f in corpus.TASK_FAMILIES:
        assert fams.count(f) == 5


def test_encode_decode_roundtrip():
    s = "kv:a1 b2?a=1\n"
    assert corpus.decode(corpus.encode(s)) == s


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "opt-tiny", "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@needs_artifacts
@pytest.mark.parametrize("name", list(CONFIGS))
def test_manifest_matches_weights(name):
    mdir = os.path.join(ART, name)
    if not os.path.exists(os.path.join(mdir, "manifest.json")):
        pytest.skip(f"{name} not built")
    man = json.load(open(os.path.join(mdir, "manifest.json")))
    weights = dict(np.load(os.path.join(mdir, "model.npz")))
    assert [p["name"] for p in man["params"]] == sorted(weights)
    for p in man["params"]:
        assert list(weights[p["name"]].shape) == p["shape"], p["name"]
    cfg = get_config(name)
    assert man["config"]["d_model"] == cfg.d_model
    assert man["config"]["n_layers"] == cfg.n_layers
    # every entry's HLO file exists and is non-trivial
    for e in man["entries"]:
        path = os.path.join(mdir, e["file"])
        assert os.path.exists(path), e["name"]
        assert os.path.getsize(path) > 500, e["name"]


@needs_artifacts
def test_decode_entry_coverage_opt_tiny():
    man = json.load(open(os.path.join(ART, "opt-tiny", "manifest.json")))
    names = {e["name"] for e in man["entries"]}
    for b in man["buckets"]["batch"]:
        for n in man["buckets"]["seq"]:
            assert f"prefill_b{b}_s{n}" in names, (b, n)
            assert f"prefill_b{b}_s{n}_paged_fused" in names, (b, n)
            for tag in ("dense", "dejavu", "polar_d0500"):
                assert f"decode_{tag}_b{b}_n{n}" in names, (tag, b, n)
                assert f"decode_{tag}_b{b}_n{n}_paged_fused" in names, (tag, b, n)
    assert "copy_blocks" in names
    # the deprecated twin entries are retired from the artifact
    assert not any(nm.endswith("_paged") for nm in names)
    assert man["buckets"]["prefill_chunk"] > 0
    assert man["buckets"]["kv_block"] > 0
    assert man["buckets"]["kv_pool_blocks"] > 1
    assert man["buckets"]["copy_pairs"] > 0
