"""Router training + Algorithm 2 calibration invariants (micro model so
the whole path runs in seconds)."""

import numpy as np
import pytest
from dataclasses import replace

from compile import calibrate, corpus, model, routers, train
from compile.configs import get_config


@pytest.fixture(scope="module")
def micro():
    cfg = replace(get_config("opt-tiny"), train_steps=6, train_batch=4, train_seq=48)
    params, _ = train.train(cfg)
    data = routers.collect(cfg, params, n_batches=2)
    return cfg, params, data


def test_collect_shapes(micro):
    cfg, _, data = micro
    n = 2 * cfg.train_batch * cfg.train_seq
    assert data["h_mlp"].shape == (cfg.n_layers, n, cfg.d_model)
    assert data["h_attn"].shape == (cfg.n_layers, n, cfg.d_model)
    assert data["head_norms"].shape == (cfg.n_layers, n, cfg.n_heads)
    assert data["mlp_active"].shape == (cfg.n_layers, n, cfg.d_ff)
    assert data["mlp_active"].dtype == bool
    # ReLU sparsity exists: not everything active, not everything dead
    frac = data["mlp_active"].mean()
    assert 0.01 < frac < 0.99


def test_group_labels_pick_top_half(micro):
    cfg, _, data = micro
    labels, norms = routers.group_labels(cfg, data["head_norms"])
    k = cfg.n_groups // 2
    assert labels.shape == (cfg.n_layers, data["head_norms"].shape[1], cfg.n_groups)
    per_token = labels.sum(axis=-1)
    assert (per_token >= k).all()  # ties can only add
    # labelled groups have norms >= the unlabelled ones
    l, i = 0, 0
    row_norm, row_lab = norms[l, i], labels[l, i]
    assert row_norm[row_lab > 0].min() >= row_norm[row_lab == 0].max() - 1e-6


def test_router_training_beats_chance(micro):
    cfg, params, data = micro
    merged, metrics = routers.train_routers(cfg, params, data)
    assert set(merged) >= {"ar_w", "ar_b", "mr_w1", "mr_b1", "mr_w2", "mr_b2"}
    # attention router should recall clearly above the 50% random baseline
    for m in metrics["attn"]:
        assert m["recall_at_half"] > 0.55, m
    for m in metrics["mlp"]:
        assert m["recall_at_mean_k"] > 0.55, m


def test_calibration_monotone_in_recall_and_batch(micro):
    cfg, params, data = micro
    merged, _ = routers.train_routers(cfg, params, data)
    full = {**params, **merged}
    sup = {k: v for k, v in data.items() if v is not None}
    out = calibrate.calibrate(cfg, full, sup)
    t = out["recall_targets"]
    for b in ("1", "4"):
        ks_lo = t["0.9"][b]
        ks_hi = t["0.99"][b]
        assert all(h >= l for h, l in zip(ks_hi, ks_lo)), (ks_lo, ks_hi)
    # union grows with batch -> calibrated k grows with batch (Fig 1b)
    for target in ("0.9", "0.99"):
        k1 = sum(t[target]["1"])
        k16 = sum(t[target]["16"])
        assert k16 >= k1, (k1, k16)
    # union_stats fraction grows with batch too
    assert np.mean(out["union_stats"]["16"]) >= np.mean(out["union_stats"]["1"])


def test_greedy_topk_meets_target():
    curve = np.linspace(0.0, 1.0, 512)  # recall grows linearly in k
    k = calibrate.greedy_topk(curve, 0.9)
    assert curve[k - 1] >= 0.9
    assert k <= 512
    # never exceeds Dff even for unreachable targets
    assert calibrate.greedy_topk(np.zeros(128), 0.99) == 128


def test_union_recall_curve_perfect_router():
    """A router whose logits equal the ground truth has recall 1 at k=|union|."""
    rng = np.random.default_rng(0)
    n, dff = 64, 128
    active = rng.random((n, dff)) < 0.2
    logits = active.astype(np.float64) + rng.random((n, dff)) * 1e-3
    batch_idx = rng.integers(0, n, size=(8, 4))
    curve, frac = calibrate.union_recall_curve(logits, active, batch_idx)
    assert 0.0 < frac < 1.0
    # at k = Dff recall is exactly 1
    assert abs(curve[-1] - 1.0) < 1e-9
    # monotone
    assert (np.diff(curve) >= -1e-12).all()


def test_export_fixture_writes_consistent_recalls(tmp_path):
    """The committed rust fixture contract: uncompressed npz + recall
    metrics that recompute from the stored weights/inputs/labels."""
    import json
    import zipfile

    routers.export_fixture(str(tmp_path))
    npz_path = tmp_path / "router_fixture.npz"
    # the vendored rust npz reader only handles stored (uncompressed) zips
    assert all(i.compress_type == 0 for i in zipfile.ZipFile(npz_path).infolist())
    d = np.load(npz_path)
    metrics = json.load(open(tmp_path / "router_fixture.json"))
    k = metrics["k"]
    logits = np.einsum("lnd,ldg->lng", d["h"], d["ar_w"]) + d["ar_b"][:, None, :]
    for m in metrics["attn"]:
        l = m["layer"]
        got = routers.recall_at_k(logits[l], d["labels"][l], k)
        assert abs(got - m["recall_at_half"]) < 1e-9
        # imperfect but well above the chance recall of k/G = 0.5
        assert 0.6 < m["recall_at_half"] < 1.0
