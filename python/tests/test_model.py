"""L2 correctness: prefill/decode parity, sparse-mode semantics, TP/PP
decompositions, and the AOT lowering contract."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from dataclasses import replace

from compile import model
from compile.configs import get_config

RTOL, ATOL = 1e-3, 1e-3


def tiny(name="opt-tiny", **kw):
    return replace(get_config(name), **kw) if kw else get_config(name)


@pytest.fixture(scope="module", params=["opt-tiny", "llama-gqa"])
def setup(request):
    cfg = get_config(request.param)
    params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed=3).items()}
    return cfg, params


def test_prefill_matches_full_forward(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    B, S = 2, 12
    toks = rng.integers(0, 250, (B, S)).astype(np.int32)
    lens = np.array([S, S - 3], np.int32)
    logits_full, _, _ = model.forward_full(cfg, params, jnp.asarray(toks), jnp.asarray(lens))
    last, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray(lens), 64)
    for b in range(B):
        np.testing.assert_allclose(
            last[b], logits_full[b, lens[b] - 1], rtol=RTOL, atol=ATOL
        )
    assert kv.shape == (cfg.n_layers, 2, B, cfg.n_kv_heads, 64, cfg.d_head)


def test_decode_chain_matches_full_forward(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    B, S, extra = 2, 8, 4
    toks = rng.integers(0, 250, (B, S + extra)).astype(np.int32)
    full_lens = np.array([S + extra, S + extra], np.int32)
    logits_full, _, _ = model.forward_full(
        cfg, params, jnp.asarray(toks), jnp.asarray(full_lens)
    )
    lens = np.array([S, S], np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks[:, :S]), jnp.asarray(lens), 64)
    for step in range(extra):
        new = toks[:, S + step].astype(np.int32)
        lens = lens + 1
        logits, kv = model.decode_step(
            cfg, params, jnp.asarray(new), jnp.asarray(lens), kv, mode="dense"
        )
        for b in range(B):
            np.testing.assert_allclose(
                logits[b], logits_full[b, lens[b] - 1], rtol=RTOL, atol=ATOL
            )


def test_chunked_prefill_matches_monolithic(setup):
    """Streaming a prompt through successive prefill_chunk calls must be
    numerically identical to one monolithic prefill: same final-position
    logits and the same KV prefix (positions beyond the prompt stay 0)."""
    cfg, params = setup
    rng = np.random.default_rng(20)
    B, P, C, S = 2, 20, 8, 64
    toks = rng.integers(0, 250, (B, P)).astype(np.int32)
    lens = np.array([P, P - 5], np.int32)
    want_logits, want_kv = model.prefill(
        cfg, params, jnp.asarray(toks), jnp.asarray(lens), S)

    kv = jnp.zeros((cfg.n_layers, 2, B, cfg.n_kv_heads, S, cfg.d_head),
                   jnp.float32)
    got_logits = np.zeros((B, cfg.vocab), np.float32)
    off = 0
    while off < P:
        chunk = np.full((B, C), 0, np.int32)
        clen = np.zeros(B, np.int32)
        for b in range(B):
            n = int(np.clip(lens[b] - off, 0, C))
            chunk[b, :n] = toks[b, off:off + n]
            clen[b] = n
        logits, kv = model.prefill_chunk(
            cfg, params, jnp.asarray(chunk), jnp.asarray(clen),
            jnp.asarray(np.minimum(off, lens).astype(np.int32)), kv)
        for b in range(B):
            if off < lens[b] <= off + C:  # this chunk ends slot b's prompt
                got_logits[b] = logits[b]
        off += C
    np.testing.assert_allclose(got_logits, want_logits, rtol=RTOL, atol=ATOL)
    # valid KV prefix matches per slot; monolithic prefill also writes K/V
    # for padding tokens past the prompt (masked at decode) where chunked
    # prefill leaves the cache untouched — compare only real positions,
    # and check the chunked tail is still zero (no stray writes)
    got_kv, ref_kv = np.asarray(kv), np.asarray(want_kv)
    for b in range(B):
        n = int(lens[b])
        np.testing.assert_allclose(got_kv[:, :, b, :, :n], ref_kv[:, :, b, :, :n],
                                   rtol=RTOL, atol=ATOL)
        assert np.all(got_kv[:, :, b, :, n:] == 0.0)


def test_prefill_chunk_masked_writes_preserve_other_slots(setup):
    """A chunk call with length 0 for a slot must leave that slot's cache
    bit-identical (masked writes, not blind dynamic slices), while the
    active slot's chunk lands at its offset."""
    cfg, params = setup
    rng = np.random.default_rng(21)
    B, C, S = 2, 8, 64
    kv0 = jnp.asarray(
        rng.standard_normal(
            (cfg.n_layers, 2, B, cfg.n_kv_heads, S, cfg.d_head)
        ).astype(np.float32))
    toks = rng.integers(0, 250, (B, C)).astype(np.int32)
    # slot 0 inactive (len 0); slot 1 appends 5 tokens at offset 16
    lens = np.array([0, 5], np.int32)
    offs = np.array([0, 16], np.int32)
    _, kv1 = model.prefill_chunk(
        cfg, params, jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(offs), kv0)
    kv0n, kv1n = np.asarray(kv0), np.asarray(kv1)
    # slot 0 untouched everywhere
    np.testing.assert_array_equal(kv1n[:, :, 0], kv0n[:, :, 0])
    # slot 1: only positions [16, 21) changed
    np.testing.assert_array_equal(kv1n[:, :, 1, :, :16], kv0n[:, :, 1, :, :16])
    np.testing.assert_array_equal(kv1n[:, :, 1, :, 21:], kv0n[:, :, 1, :, 21:])
    assert not np.allclose(kv1n[:, :, 1, :, 16:21], kv0n[:, :, 1, :, 16:21])


def test_aot_prefill_chunk_entry_matrix(tmp_path):
    """The manifest contract of chunked prefill: one prefill_b{B}_s{S}
    entry per (batch, seq) bucket taking [tokens, lengths, offset, kv]."""
    from compile import aot
    from compile.configs import BATCH_BUCKETS, PREFILL_LEN, SEQ_BUCKETS

    cfg = get_config("llama-tiny")
    entries = {e.name: e for e in aot.core_entries(cfg, str(tmp_path))}
    for B in BATCH_BUCKETS:
        for S in SEQ_BUCKETS:
            e = entries[f"prefill_b{B}_s{S}"]
            assert e.kind == "prefill"
            assert [d["name"] for d in e.data] == \
                ["tokens", "lengths", "offset", "kv"]
            assert e.data[0]["shape"] == [B, PREFILL_LEN]
            assert e.data[2]["shape"] == [B] and e.data[2]["dtype"] == "i32"
            assert e.data[3]["shape"] == \
                [cfg.n_layers, 2, B, cfg.n_kv_heads, S, cfg.d_head]
            assert e.outputs[1]["shape"] == e.data[3]["shape"]
            assert e.meta["chunk"] == PREFILL_LEN
    assert f"prefill_b{BATCH_BUCKETS[0]}" not in entries  # monolithic gone


def _pool_from_dense(kv_dense, bs, seed=0, extra_blocks=3):
    """Pack a dense [L,2,B,G,N,dh] cache into a block pool + per-slot
    tables with *scrambled* physical block ids (block 0 = reserved null),
    so the tests prove real table indirection, not identity layout."""
    L, two, B, G, N, dh = kv_dense.shape
    NB = N // bs
    P = 1 + B * NB + extra_blocks
    rng = np.random.default_rng(seed)
    ids = rng.permutation(np.arange(1, P))[: B * NB]
    pool = np.zeros((L, two, P, G, bs, dh), np.float32)
    table = np.zeros((B, NB), np.int32)
    dense = np.asarray(kv_dense)
    for b in range(B):
        for j in range(NB):
            blk = int(ids[b * NB + j])
            table[b, j] = blk
            pool[:, :, blk] = dense[:, :, b, :, j * bs:(j + 1) * bs]
    return jnp.asarray(pool), jnp.asarray(table)


def test_paged_decode_matches_contiguous_bitwise(setup):
    """Block-table decode must equal the contiguous path BIT FOR BIT:
    gather/scatter is pure data movement around the unchanged decode_step,
    so logits and the gathered post-step cache are np.assert_array_equal
    (not allclose) against the contiguous reference."""
    cfg, params = setup
    rng = np.random.default_rng(30)
    B, S, N, bs = 2, 8, 32, 8
    toks = rng.integers(0, 250, (B, S)).astype(np.int32)
    lens0 = np.array([S, S - 2], np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray(lens0), N)
    new = jnp.asarray(np.array([5, 7], np.int32))
    lens = jnp.asarray(lens0 + 1)
    pool, table = _pool_from_dense(kv, bs)
    pool0 = np.asarray(pool).copy()

    want, want_kv = model.decode_step(cfg, params, new, lens, kv, mode="dense")
    got, pool1 = model.decode_step_paged(cfg, params, new, lens, pool, table,
                                         mode="dense")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_kv = model.gather_block_kv(pool1, table)
    np.testing.assert_array_equal(np.asarray(got_kv), np.asarray(want_kv))
    # physical blocks outside every table (incl. the null block) untouched
    pool1n = np.asarray(pool1)
    unused = sorted(set(range(pool0.shape[2])) - set(np.asarray(table).ravel()))
    np.testing.assert_array_equal(pool1n[:, :, unused], pool0[:, :, unused])

    # the index-taking convention composes with paging: external head_idx
    # steers the paged entry exactly as it does the contiguous one
    L, G = cfg.n_layers, cfg.n_groups
    k = max(1, G // 2)
    hi = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[None, None, :],
                          (L, B, k))
    want_p, _ = model.decode_step(cfg, params, new, lens, kv, mode="polar",
                                  density=0.5, head_idx=hi)
    got_p, _ = model.decode_step_paged(cfg, params, new, lens, pool, table,
                                       mode="polar", density=0.5, head_idx=hi)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))


def test_paged_prefill_chunk_matches_contiguous_bitwise(setup):
    """Chunked prefill through block tables reproduces the contiguous
    chunked path bit for bit, chunk by chunk."""
    cfg, params = setup
    rng = np.random.default_rng(31)
    B, P_len, C, N, bs = 2, 20, 8, 32, 8
    toks = rng.integers(0, 250, (B, P_len)).astype(np.int32)
    lens = np.array([P_len, P_len - 5], np.int32)

    kv = jnp.zeros((cfg.n_layers, 2, B, cfg.n_kv_heads, N, cfg.d_head),
                   jnp.float32)
    pool, table = _pool_from_dense(kv, bs, seed=1)
    off = 0
    while off < P_len:
        chunk = np.zeros((B, C), np.int32)
        clen = np.zeros(B, np.int32)
        for b in range(B):
            n = int(np.clip(lens[b] - off, 0, C))
            chunk[b, :n] = toks[b, off:off + n]
            clen[b] = n
        offs = jnp.asarray(np.minimum(off, lens).astype(np.int32))
        want, kv = model.prefill_chunk(
            cfg, params, jnp.asarray(chunk), jnp.asarray(clen), offs, kv)
        got, pool = model.prefill_chunk_paged(
            cfg, params, jnp.asarray(chunk), jnp.asarray(clen), offs, table,
            pool)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        off += C
    np.testing.assert_array_equal(
        np.asarray(model.gather_block_kv(pool, table)), np.asarray(kv))


def test_paged_prefix_sharing_reuses_blocks(setup):
    """Cross-request prefix reuse: request B's table names request A's
    physical prefix blocks, so B prefills ONLY its suffix chunk and still
    produces logits bit-identical to prefilling its whole prompt — and
    the shared blocks survive B's call bit-exactly (the scatter's
    duplicate writes are identity on unwritten shared blocks)."""
    cfg, params = setup
    rng = np.random.default_rng(32)
    bs, C, N = 8, 8, 32
    prefix = rng.integers(0, 250, 16).astype(np.int32)      # 2 full blocks
    suf_a = rng.integers(0, 250, 4).astype(np.int32)
    suf_b = rng.integers(0, 250, 4).astype(np.int32)
    P = 8
    pool = jnp.zeros(model.kv_pool_shape(cfg, P, bs), jnp.float32)
    table_a = jnp.asarray(np.array([[1, 2, 3, 0]], np.int32))
    table_b = jnp.asarray(np.array([[1, 2, 4, 0]], np.int32))  # shares 1, 2

    def chunked(tokens_1d, offsets, table, pool):
        logits = None
        for off in offsets:
            n = min(C, len(tokens_1d) - off)
            chunk = np.zeros((1, C), np.int32)
            chunk[0, :n] = tokens_1d[off:off + n]
            logits, pool = model.prefill_chunk_paged(
                cfg, params, jnp.asarray(chunk),
                jnp.asarray(np.array([n], np.int32)),
                jnp.asarray(np.array([off], np.int32)), table, pool)
        return logits, pool

    # request A prefills the whole prompt (prefix writes blocks 1, 2)
    prompt_a = np.concatenate([prefix, suf_a])
    _, pool = chunked(prompt_a, [0, 8, 16], table_a, pool)
    shared_before = np.asarray(pool)[:, :, [1, 2]].copy()

    # request B: ONE suffix chunk at offset 16 — the prefix chunks are
    # never recomputed, yet the logits match a full prefill of B's prompt
    prompt_b = np.concatenate([prefix, suf_b])
    got, pool = chunked(prompt_b, [16], table_b, pool)

    kv_ref = jnp.zeros((cfg.n_layers, 2, 1, cfg.n_kv_heads, N, cfg.d_head),
                       jnp.float32)
    want = None
    for off in (0, 8, 16):
        n = min(C, len(prompt_b) - off)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :n] = prompt_b[off:off + n]
        want, kv_ref = model.prefill_chunk(
            cfg, params, jnp.asarray(chunk),
            jnp.asarray(np.array([n], np.int32)),
            jnp.asarray(np.array([off], np.int32)), kv_ref)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # shared prefix blocks untouched by B's call
    np.testing.assert_array_equal(np.asarray(pool)[:, :, [1, 2]], shared_before)
    # B's private suffix block matches the reference cache's window
    np.testing.assert_array_equal(
        np.asarray(model.gather_block_kv(pool, table_b))[:, :, 0, :, 16:20],
        np.asarray(kv_ref)[:, :, 0, :, 16:20])


def test_fused_paged_decode_matches_twin_bitwise(setup):
    """The fused path (per-layer table reads, direct pool-row write, no
    dense KV intermediate) must reproduce the twin gather -> dense core ->
    scatter path BIT FOR BIT: same logits, same gathered cache view, and
    physical blocks outside every table untouched. The llama-gqa fixture
    param covers q_per_group > 1."""
    cfg, params = setup
    rng = np.random.default_rng(33)
    B, S, N, bs = 2, 8, 32, 8
    toks = rng.integers(0, 250, (B, S)).astype(np.int32)
    lens0 = np.array([S, S - 2], np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray(lens0), N)
    new = jnp.asarray(np.array([5, 7], np.int32))
    lens = jnp.asarray(lens0 + 1)
    pool, table = _pool_from_dense(kv, bs, seed=2)
    pool0 = np.asarray(pool).copy()

    L, G = cfg.n_layers, cfg.n_groups
    k = max(1, G // 2)
    # deliberate TIES in head_idx: every selected group id duplicated
    hi_tie = jnp.asarray(
        np.zeros((L, B, k), np.int32) + np.arange(k, dtype=np.int32)[None, None, :] // 2)
    cases = [
        dict(mode="dense"),
        dict(mode="polar", density=0.5),
        dict(mode="polar", density=0.5, head_idx=hi_tie),
    ]
    for kw in cases:
        want, pool_t = model.decode_step_paged(
            cfg, params, new, lens, pool, table, **kw)
        got, pool_f = model.decode_step_paged_fused(
            cfg, params, new, lens, pool, table, **kw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(model.gather_block_kv(pool_f, table)),
            np.asarray(model.gather_block_kv(pool_t, table)))
        # fused writes nothing outside the tables' blocks
        unused = sorted(set(range(pool0.shape[2]))
                        - set(np.asarray(table).ravel()))
        np.testing.assert_array_equal(
            np.asarray(pool_f)[:, :, unused], pool0[:, :, unused])


def test_fused_paged_decode_cow_shared_boundary_block(setup):
    """Two requests share a read-only prefix block right at the boundary of
    their write windows (the post-COW layout). The fused step must leave
    the shared block bit-identical, keep logits equal to the twin path, and
    write each request's new row only into its private block."""
    cfg, params = setup
    rng = np.random.default_rng(34)
    B, S, N, bs = 2, 8, 32, 8
    toks = np.tile(rng.integers(0, 250, (1, S)).astype(np.int32), (B, 1))
    lens0 = np.array([S, S], np.int32)   # same prompt -> identical block 0
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray(lens0), N)
    pool, table = _pool_from_dense(kv, bs, seed=3)
    # rewrite the tables so both requests name THE SAME physical block for
    # their full first block (positions 0..bs-1) and keep private blocks
    # beyond it; the decode write at pos=S lands in block index 1 (private).
    table = np.asarray(table).copy()
    shared = int(table[0, 0])
    table[1, 0] = shared
    pool = np.asarray(pool).copy()
    pool[:, :, shared] = np.asarray(kv)[:, :, 0, :, :bs]  # canonical content
    pool, table = jnp.asarray(pool), jnp.asarray(table)
    pool0 = np.asarray(pool).copy()

    new = jnp.asarray(np.array([5, 7], np.int32))
    lens = jnp.asarray(lens0 + 1)        # pos = S = bs -> first row of blk 1
    want, pool_t = model.decode_step_paged(
        cfg, params, new, lens, pool, table, mode="dense")
    got, pool_f = model.decode_step_paged_fused(
        cfg, params, new, lens, pool, table, mode="dense")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the shared boundary block survives both paths bit-exactly
    np.testing.assert_array_equal(
        np.asarray(pool_f)[:, :, shared], pool0[:, :, shared])
    np.testing.assert_array_equal(
        np.asarray(pool_t)[:, :, shared], pool0[:, :, shared])
    # each request's new row landed in its private block-1
    for b in range(B):
        blk = int(np.asarray(table)[b, 1])
        row_f = np.asarray(pool_f)[:, :, blk, :, 0]
        row_t = np.asarray(pool_t)[:, :, blk, :, 0]
        np.testing.assert_array_equal(row_f, row_t)
        assert not np.array_equal(row_f, pool0[:, :, blk, :, 0])


def test_fused_paged_decode_chain_stays_bitwise(setup):
    """A multi-step decode chain alternating paths never diverges: running
    the fused entry for several steps produces the same pool and logits
    trajectory as the twin entry."""
    cfg, params = setup
    rng = np.random.default_rng(35)
    B, S, N, bs = 2, 6, 32, 8
    toks = rng.integers(0, 250, (B, S)).astype(np.int32)
    lens0 = np.array([S, S - 1], np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray(lens0), N)
    pool_t, table = _pool_from_dense(kv, bs, seed=4)
    pool_f = pool_t
    lens = lens0
    for step in range(4):
        new = jnp.asarray(rng.integers(0, 250, B).astype(np.int32))
        lens = lens + 1
        lt, pool_t = model.decode_step_paged(
            cfg, params, new, jnp.asarray(lens), pool_t, table, mode="dense")
        lf, pool_f = model.decode_step_paged_fused(
            cfg, params, new, jnp.asarray(lens), pool_f, table, mode="dense")
        np.testing.assert_array_equal(np.asarray(lf), np.asarray(lt))
    np.testing.assert_array_equal(
        np.asarray(model.gather_block_kv(pool_f, table)),
        np.asarray(model.gather_block_kv(pool_t, table)))


# The fused-vs-twin prefill contract is an *entry-level* one: both sides
# are jitted whole-graph programs (AOT entries), so the oracle is the
# jitted twin — op-by-op eager dispatch of the same math can associate
# reductions differently and is only allclose, not bitwise.
_twin_prefill = jax.jit(model.prefill_chunk_paged, static_argnames=("cfg",))


def test_fused_paged_prefill_matches_twin_bitwise(setup):
    """The fused prefill chunk (direct pool-block writes at per-slot
    offsets, per-layer table reads, no dense [L,2,B,G,S,dh] view) must
    reproduce the twin gather -> prefill_chunk -> scatter path BIT FOR
    BIT — logits and the ENTIRE pool — across per-slot offsets, a
    sub-chunk final chunk, and GQA (llama-gqa fixture param)."""
    cfg, params = setup
    rng = np.random.default_rng(40)
    B, P_len, C, N, bs = 2, 20, 8, 32, 8
    toks = rng.integers(0, 250, (B, P_len)).astype(np.int32)
    # slot 1's prompt ends mid-chunk AND mid-block (15 = 8 + 7): the final
    # chunk is sub-chunk (7 < C) and its last block is partially occupied
    lens = np.array([P_len, P_len - 5], np.int32)

    kv = jnp.zeros((cfg.n_layers, 2, B, cfg.n_kv_heads, N, cfg.d_head),
                   jnp.float32)
    pool_t, table = _pool_from_dense(kv, bs, seed=5)
    pool_f = pool_t
    off = 0
    while off < P_len:
        chunk = np.zeros((B, C), np.int32)
        clen = np.zeros(B, np.int32)
        for b in range(B):
            n = int(np.clip(lens[b] - off, 0, C))
            chunk[b, :n] = toks[b, off:off + n]
            clen[b] = n
        offs = jnp.asarray(np.minimum(off, lens).astype(np.int32))
        want, pool_t = _twin_prefill(
            cfg, params, jnp.asarray(chunk), jnp.asarray(clen), offs, table,
            pool_t)
        got, pool_f = model.prefill_chunk_paged_fused(
            cfg, params, jnp.asarray(chunk), jnp.asarray(clen), offs, table,
            pool_f)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(np.asarray(pool_f), np.asarray(pool_t))
        off += C


def test_fused_paged_prefill_prefix_skip_matches_twin(setup):
    """Prefix-cache skip through the fused path: request B's table names
    request A's published prefix blocks and B prefills ONLY its suffix
    chunk. Fused logits and pool match the twin bitwise, and the shared
    prefix blocks survive B's call untouched (the fused write can't even
    reach them — they're outside the chunk's write window)."""
    cfg, params = setup
    rng = np.random.default_rng(41)
    bs, C = 8, 8
    prefix = rng.integers(0, 250, 16).astype(np.int32)      # 2 full blocks
    suf_b = rng.integers(0, 250, 4).astype(np.int32)
    P = 8
    pool = jnp.zeros(model.kv_pool_shape(cfg, P, bs), jnp.float32)
    table_a = jnp.asarray(np.array([[1, 2, 3, 0]], np.int32))
    table_b = jnp.asarray(np.array([[1, 2, 4, 0]], np.int32))  # shares 1, 2

    def chunk_call(fn, tokens_1d, off, table, pool):
        n = min(C, len(tokens_1d) - off)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :n] = tokens_1d[off:off + n]
        return fn(cfg, params, jnp.asarray(chunk),
                  jnp.asarray(np.array([n], np.int32)),
                  jnp.asarray(np.array([off], np.int32)), table, pool)

    # request A publishes the prefix blocks through the FUSED path
    prompt_a = np.concatenate([prefix, rng.integers(0, 250, 4).astype(np.int32)])
    for off in (0, 8, 16):
        _, pool = chunk_call(model.prefill_chunk_paged_fused, prompt_a, off,
                             table_a, pool)
    shared_before = np.asarray(pool)[:, :, [1, 2]].copy()

    # request B: ONE suffix chunk at offset 16, fused vs twin
    prompt_b = np.concatenate([prefix, suf_b])
    want, pool_t = chunk_call(_twin_prefill, prompt_b, 16, table_b, pool)
    got, pool_f = chunk_call(model.prefill_chunk_paged_fused, prompt_b, 16,
                             table_b, pool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(pool_f), np.asarray(pool_t))
    np.testing.assert_array_equal(np.asarray(pool_f)[:, :, [1, 2]],
                                  shared_before)


def test_fused_paged_prefill_cow_boundary_block(setup):
    """COW at a chunk boundary: request B forks from A mid-block, the
    boundary block is duplicated with copy_blocks, and B's divergent
    suffix chunk writes into the COPY. Fused matches twin bitwise, A's
    original boundary block is untouched, and the copy keeps its
    pre-boundary rows while gaining B's divergent rows."""
    cfg, params = setup
    rng = np.random.default_rng(42)
    bs, C = 8, 8
    P = 8
    pool = jnp.zeros(model.kv_pool_shape(cfg, P, bs), jnp.float32)
    table_a = jnp.asarray(np.array([[1, 2, 3, 0]], np.int32))
    prompt_a = rng.integers(0, 250, 12).astype(np.int32)    # ends mid-block 2

    def chunk_call(fn, tokens_1d, off, table, pool):
        n = min(C, len(tokens_1d) - off)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :n] = tokens_1d[off:off + n]
        return fn(cfg, params, jnp.asarray(chunk),
                  jnp.asarray(np.array([n], np.int32)),
                  jnp.asarray(np.array([off], np.int32)), table, pool)

    for off in (0, 8):
        _, pool = chunk_call(model.prefill_chunk_paged_fused, prompt_a, off,
                             table_a, pool)

    # fork: B shares full block 1, COWs the half-full boundary block 2 -> 4
    pool = model.copy_blocks(pool, jnp.asarray(np.array([2], np.int32)),
                             jnp.asarray(np.array([4], np.int32)))
    table_b = jnp.asarray(np.array([[1, 4, 5, 0]], np.int32))
    block_a = np.asarray(pool)[:, :, 2].copy()
    copied = np.asarray(pool)[:, :, 4].copy()

    # B's divergent suffix: positions 12..15 land in the tail of the copy
    prompt_b = np.concatenate([prompt_a, rng.integers(0, 250, 4).astype(np.int32)])
    want, pool_t = chunk_call(_twin_prefill, prompt_b, 12, table_b, pool)
    got, pool_f = chunk_call(model.prefill_chunk_paged_fused, prompt_b, 12,
                             table_b, pool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(pool_f), np.asarray(pool_t))
    pf = np.asarray(pool_f)
    # A's boundary block survives B's divergent writes bit-exactly
    np.testing.assert_array_equal(pf[:, :, 2], block_a)
    # the copy keeps its shared pre-boundary rows and gained new tail rows
    np.testing.assert_array_equal(pf[:, :, 4, :, :4], copied[:, :, :, :4])
    assert not np.array_equal(pf[:, :, 4, :, 4:], copied[:, :, :, 4:])


def test_fused_paged_prefill_pad_slot_writes_nothing(setup):
    """Null-block write policy (the decode policy mock.rs enforces, now
    closed for prefill): a PAD slot — lengths 0, all-null table — must
    not write ANY pool block, not even reserved block 0; and an active
    slot's sub-chunk tail rows must be dropped, not scattered."""
    cfg, params = setup
    rng = np.random.default_rng(43)
    bs, C, P = 8, 8, 8
    pool0 = jnp.asarray(
        rng.standard_normal(model.kv_pool_shape(cfg, P, bs)).astype(np.float32))
    table = jnp.asarray(np.array([[1, 2, 3, 0], [0, 0, 0, 0]], np.int32))
    toks = jnp.asarray(rng.integers(0, 250, (2, C)).astype(np.int32))
    # slot 0: 5 valid tokens at offset 8; slot 1: PAD (lengths 0)
    lens = jnp.asarray(np.array([5, 0], np.int32))
    offs = jnp.asarray(np.array([8, 0], np.int32))
    _, pool1 = model.prefill_chunk_paged_fused(
        cfg, params, toks, lens, offs, table, pool0)
    p0, p1 = np.asarray(pool0), np.asarray(pool1)
    # only block 2 rows 0..4 (positions 8..12) may change
    np.testing.assert_array_equal(p1[:, :, 0], p0[:, :, 0])   # null block
    np.testing.assert_array_equal(p1[:, :, 1], p0[:, :, 1])
    np.testing.assert_array_equal(p1[:, :, 3:], p0[:, :, 3:])
    np.testing.assert_array_equal(p1[:, :, 2, :, 5:], p0[:, :, 2, :, 5:])
    assert not np.array_equal(p1[:, :, 2, :, :5], p0[:, :, 2, :, :5])


def test_copy_blocks_copies_pairs_and_identity(setup):
    """copy_blocks semantics the engine relies on: every (src, dst) pair
    lands dst <- src across all layers/K/V, (0, 0) pads are identity, and
    blocks outside the dst set are untouched."""
    cfg, params = setup
    del params
    rng = np.random.default_rng(44)
    bs, P = 8, 10
    pool0 = jnp.asarray(
        rng.standard_normal(model.kv_pool_shape(cfg, P, bs)).astype(np.float32))
    src = jnp.asarray(np.array([1, 3, 0, 0], np.int32))
    dst = jnp.asarray(np.array([7, 8, 0, 0], np.int32))
    pool1 = model.copy_blocks(pool0, src, dst)
    p0, p1 = np.asarray(pool0), np.asarray(pool1)
    np.testing.assert_array_equal(p1[:, :, 7], p0[:, :, 1])
    np.testing.assert_array_equal(p1[:, :, 8], p0[:, :, 3])
    untouched = [b for b in range(P) if b not in (7, 8)]
    np.testing.assert_array_equal(p1[:, :, untouched], p0[:, :, untouched])


def test_aot_paged_entries_contract(tmp_path):
    """Manifest contract of the paged matrix: every serving (batch, seq)
    bucket gains a fused prefill entry taking [tokens, lengths, offset,
    block_table, kv-pool] and fused decode entries taking [tokens,
    lengths, block_table, kv-pool, (head_idx...)], all addressing ONE
    pool shape, plus one copy_blocks entry (on-device COW). No deprecated
    twin entries are emitted."""
    from compile import aot
    from compile.configs import (
        BATCH_BUCKETS, COPY_BLOCKS_PAIRS, KV_BLOCK, SEQ_BUCKETS,
        kv_pool_blocks,
    )

    cfg = get_config("llama-tiny")
    entries = {e.name: e for e in aot.core_entries(cfg, str(tmp_path))}
    P = kv_pool_blocks(BATCH_BUCKETS, SEQ_BUCKETS)
    pshape = [cfg.n_layers, 2, P, cfg.n_kv_heads, KV_BLOCK, cfg.d_head]

    pe = entries["prefill_b4_s128_paged_fused"]
    assert pe.kind == "prefill_paged_fused"
    assert [d["name"] for d in pe.data] == \
        ["tokens", "lengths", "offset", "block_table", "kv"]
    assert pe.data[3]["shape"] == [4, 128 // KV_BLOCK]
    assert pe.data[3]["dtype"] == "i32"
    assert pe.data[4]["shape"] == pshape
    assert pe.outputs[1]["shape"] == pshape
    assert pe.meta["kv_block"] == KV_BLOCK
    assert pe.meta["kv_pool_blocks"] == P
    assert pe.meta["fused"] is True

    de = entries["decode_dense_b4_n128_paged_fused"]
    assert de.kind == "decode_paged_fused"
    assert [d["name"] for d in de.data] == \
        ["tokens", "lengths", "block_table", "kv"]
    assert de.data[3]["shape"] == pshape
    assert de.meta["fused"] is True

    # the index-taking convention rides along unchanged
    pp = entries["decode_polar_d0500_b4_n128_paged_fused"]
    assert [d["name"] for d in pp.data] == \
        ["tokens", "lengths", "block_table", "kv", "head_idx"]

    # on-device COW: one fixed-width block-pair copy entry per model
    cb = entries["copy_blocks"]
    assert cb.kind == "copy_blocks"
    assert [d["name"] for d in cb.data] == ["src", "dst", "kv"]
    assert cb.data[0]["shape"] == [COPY_BLOCKS_PAIRS]
    assert cb.data[0]["dtype"] == "i32" and cb.data[1]["dtype"] == "i32"
    assert cb.data[2]["shape"] == pshape
    assert cb.outputs == [{"name": "kv", "shape": pshape, "dtype": "f32"}]
    assert cb.meta["pairs"] == COPY_BLOCKS_PAIRS

    # the deprecated twin entries are retired: the fused path is the only
    # paged path the artifact carries
    for name in entries:
        assert not name.endswith("_paged"), name

    # contiguous entries stay (A/B baseline, eval, pp/tp drivers)
    for name in ("decode_dense_b4_n128", "prefill_b4_s128"):
        assert name in entries, name


def test_polar_full_density_equals_dense(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    B = 2
    toks = rng.integers(0, 250, (B, 6)).astype(np.int32)
    lens0 = np.array([6, 6], np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray(lens0), 64)
    new = jnp.asarray(np.array([5, 7], np.int32))
    lens = jnp.asarray(lens0 + 1)
    a, _ = model.decode_step(cfg, params, new, lens, kv, mode="dense")
    b, _ = model.decode_step(cfg, params, new, lens, kv, mode="polar", density=1.0)
    np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)


def test_polar_layer0_attention_stays_dense():
    """Zeroing layer-0 attention-router weights must not change polar
    output (layer 0 is always dense, §3.2)."""
    cfg = get_config("opt-tiny")
    params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed=5).items()}
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 250, (1, 6)).astype(np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray([6]), 64)
    new, lens = jnp.asarray([9], dtype=jnp.int32), jnp.asarray([7], dtype=jnp.int32)
    a, _ = model.decode_step(cfg, params, new, lens, kv, mode="polar", density=0.5)
    p2 = dict(params)
    arw = np.asarray(p2["ar_w"]).copy()
    arw[0] = 1e9  # would reorder layer-0 head selection if it were used
    p2["ar_w"] = jnp.asarray(arw)
    b, _ = model.decode_step(cfg, p2, new, lens, kv, mode="polar", density=0.5)
    np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)


def test_dejavu_ignores_attention_router():
    cfg = get_config("opt-tiny")
    params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed=6).items()}
    rng = np.random.default_rng(4)
    toks = rng.integers(0, 250, (1, 6)).astype(np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray([6]), 64)
    new, lens = jnp.asarray([9], dtype=jnp.int32), jnp.asarray([7], dtype=jnp.int32)
    topk = (64,) * cfg.n_layers
    a, _ = model.decode_step(cfg, params, new, lens, kv, mode="dejavu", mlp_topk=topk)
    p2 = dict(params)
    p2["ar_w"] = jnp.asarray(np.asarray(p2["ar_w"]) * 0 + 123.0)
    b, _ = model.decode_step(cfg, p2, new, lens, kv, mode="dejavu", mlp_topk=topk)
    np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)


def test_teal_cats_modes_run_and_differ_from_dense():
    cfg = get_config("llama-tiny")
    params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed=7).items()}
    rng = np.random.default_rng(5)
    toks = rng.integers(0, 250, (1, 6)).astype(np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray([6]), 64)
    new, lens = jnp.asarray([9], dtype=jnp.int32), jnp.asarray([7], dtype=jnp.int32)
    dense, _ = model.decode_step(cfg, params, new, lens, kv, mode="dense")
    for m in ("teal", "cats"):
        out, _ = model.decode_step(cfg, params, new, lens, kv, mode=m, density=0.25)
        assert np.isfinite(np.asarray(out)).all()
        assert not np.allclose(np.asarray(out), np.asarray(dense), atol=1e-5), m


def test_external_full_indices_match_internal_routing(setup):
    """The index-taking calling convention (runtime routing subsystem):
    with head_idx = every group and mlp_idx = every neuron the *selective*
    kernels must reduce exactly to dense. density=0.5 keeps the selective
    gate ON (sparse and top_k < G) while the external index width G feeds
    the full set through the SHA kernel + GQA scatter — so this fails if
    the selective path or the qidx reconstruction breaks, unlike a
    density=1.0 run where the dense branch would execute."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    B = 2
    toks = rng.integers(0, 250, (B, 6)).astype(np.int32)
    lens0 = np.array([6, 6], np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray(lens0), 64)
    new = jnp.asarray(np.array([5, 7], np.int32))
    lens = jnp.asarray(lens0 + 1)
    L, G, Dff = cfg.n_layers, cfg.n_groups, cfg.d_ff
    dense, _ = model.decode_step(cfg, params, new, lens, kv, mode="dense")
    head_idx = jnp.broadcast_to(
        jnp.arange(G, dtype=jnp.int32)[None, None, :], (L, B, G))
    got, _ = model.decode_step(cfg, params, new, lens, kv, mode="polar",
                               density=0.5, mlp_topk=(), head_idx=head_idx)
    np.testing.assert_allclose(got, dense, rtol=RTOL, atol=ATOL)
    if cfg.mlp_sparsity:
        # same for the selective GEMM: gated on (topk < Dff) but fed the
        # full neuron set externally
        topk = (Dff // 2,) * L
        mlp_idx = jnp.broadcast_to(
            jnp.arange(Dff, dtype=jnp.int32)[None, :], (L, Dff))
        got2, _ = model.decode_step(cfg, params, new, lens, kv, mode="polar",
                                    density=0.5, mlp_topk=topk,
                                    head_idx=head_idx, mlp_idx=mlp_idx)
        np.testing.assert_allclose(got2, dense, rtol=RTOL, atol=ATOL)
        # control: the in-graph run at the same settings truly sparsifies
        want, _ = model.decode_step(cfg, params, new, lens, kv, mode="polar",
                                    density=0.5, mlp_topk=topk)
        assert not np.allclose(np.asarray(want), np.asarray(dense), atol=1e-6)


def test_external_head_selection_changes_output():
    """Different externally supplied head sets must produce different
    logits (the indices really steer the computation), and layer 0's row
    must be ignored (always dense, §3.2)."""
    cfg = get_config("opt-tiny")
    params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed=9).items()}
    rng = np.random.default_rng(12)
    toks = rng.integers(0, 250, (1, 6)).astype(np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray([6]), 64)
    new, lens = jnp.asarray([9], dtype=jnp.int32), jnp.asarray([7], dtype=jnp.int32)
    L, G = cfg.n_layers, cfg.n_groups
    k = G // 2
    lo = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[None, None, :], (L, 1, k))
    hi = jnp.broadcast_to(
        jnp.arange(G - k, G, dtype=jnp.int32)[None, None, :], (L, 1, k))
    a, _ = model.decode_step(cfg, params, new, lens, kv, mode="polar",
                             density=0.5, head_idx=lo)
    b, _ = model.decode_step(cfg, params, new, lens, kv, mode="polar",
                             density=0.5, head_idx=hi)
    assert np.isfinite(np.asarray(a)).all() and np.isfinite(np.asarray(b)).all()
    assert not np.allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # layer 0 row is dead: scrambling it cannot change the output
    scrambled = lo.at[0].set(G - 1)
    c, _ = model.decode_step(cfg, params, new, lens, kv, mode="polar",
                             density=0.5, head_idx=scrambled)
    np.testing.assert_allclose(a, c, rtol=RTOL, atol=ATOL)


def test_aot_polar_entries_declare_index_inputs(tmp_path):
    """The manifest contract of the routing subsystem: every polar decode
    entry takes head_idx [L,B,Kh] (+ mlp_idx [L,Km] for ReLU models with
    a calibration table); dense/dejavu entries stay index-free."""
    import json as _json
    from compile import aot
    from compile.configs import BATCH_BUCKETS, heads_for_density

    cfg = get_config("opt-tiny")
    table = {"recall_targets": {"0.99": {
        str(b): [cfg.d_ff // 4] * cfg.n_layers for b in BATCH_BUCKETS}}}
    mdir = tmp_path / cfg.name
    mdir.mkdir(parents=True)
    (mdir / "topk_table.json").write_text(_json.dumps(table))
    entries = {e.name: e for e in aot.core_entries(cfg, str(tmp_path))}

    polar = entries[f"decode_polar_d0500_b4_n64"]
    names = [d["name"] for d in polar.data]
    assert names == ["tokens", "lengths", "kv", "head_idx", "mlp_idx"]
    kh = heads_for_density(cfg, 0.5)
    assert polar.data[3]["shape"] == [cfg.n_layers, 4, kh]
    assert polar.data[3]["dtype"] == "i32"
    assert polar.data[4]["shape"] == [cfg.n_layers, cfg.d_ff // 4]
    assert polar.meta["routed"] and polar.meta["head_k"] == kh

    for tag in ("dense", "dejavu"):
        e = entries[f"decode_{tag}_b4_n64"]
        assert [d["name"] for d in e.data] == ["tokens", "lengths", "kv"], tag
        assert not e.meta.get("routed"), tag

    # swiglu model: no MLP routing, head_idx only
    lcfg = get_config("llama-gqa")
    lentries = {e.name: e for e in aot.core_entries(lcfg, str(tmp_path))}
    lp = lentries["decode_polar_d0625_b4_n64"]
    assert [d["name"] for d in lp.data] == ["tokens", "lengths", "kv", "head_idx"]
    assert lp.data[3]["shape"] == [lcfg.n_layers, 4,
                                   heads_for_density(lcfg, 0.625)]


def test_pp_stages_compose_to_decode_step(setup):
    cfg, params = setup
    rng = np.random.default_rng(6)
    toks = rng.integers(0, 250, (2, 6)).astype(np.int32)
    lens0 = np.array([6, 6], np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray(lens0), 64)
    new = jnp.asarray(np.array([5, 7], np.int32))
    lens = jnp.asarray(lens0 + 1)
    want, kv_want = model.decode_step(cfg, params, new, lens, kv, mode="dense")
    lh = cfg.n_layers // 2
    x = model._embed(cfg, params, new, lens - 1)
    x, kv0 = model.decode_core(cfg, params, x, lens, kv[:lh], layer_begin=0, layer_end=lh)
    x, kv1 = model.decode_core(cfg, params, x, lens, kv[lh:], layer_begin=lh,
                               layer_end=cfg.n_layers)
    got = model.final_logits(cfg, params, x)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(
        np.concatenate([np.asarray(kv0), np.asarray(kv1)]), np.asarray(kv_want),
        rtol=RTOL, atol=ATOL,
    )


def test_tp_shards_compose_to_decode_step():
    """Paged TP shard/reduce decomposition == the legacy dense decode step
    (deeper sharded-vs-single-device coverage lives in test_sharding.py)."""
    cfg = get_config("opt-tiny")
    params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed=8).items()}
    rng = np.random.default_rng(7)
    toks = rng.integers(0, 250, (2, 6)).astype(np.int32)
    lens0 = np.array([6, 6], np.int32)
    _, kv = model.prefill(cfg, params, jnp.asarray(toks), jnp.asarray(lens0), 64)
    new = jnp.asarray(np.array([5, 7], np.int32))
    lens = jnp.asarray(lens0 + 1)
    want, _ = model.decode_step(cfg, params, new, lens, kv, mode="dense")

    n_shards = 2
    gs = cfg.n_kv_heads // n_shards
    bs = 16
    pool, table = _pool_from_dense(kv, bs, seed=7)
    pools = [pool[:, :, :, s * gs:(s + 1) * gs] for s in range(n_shards)]
    x = model.tp_embed(cfg, params, new, lens)
    for l in range(cfg.n_layers):
        li = jnp.int32(l)
        partials = []
        for s in range(n_shards):
            p, pools[s] = model.tp_attn_shard_paged(
                cfg, params, li, x, lens, table, pools[s],
                shard=s, n_shards=n_shards, mode="dense")
            partials.append(p)
        x = model.tp_attn_reduce(cfg, params, li, x, partials)
        partials = [
            model.tp_mlp_shard(cfg, params, li, x, shard=s, n_shards=n_shards)
            for s in range(n_shards)
        ]
        x = model.tp_mlp_reduce(cfg, params, li, x, partials)
    got = model.tp_final(cfg, params, x)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_aot_lowering_keeps_all_params():
    """The manifest calling convention: every weight appears as an entry
    parameter even when unused (keep_unused=True)."""
    from jax._src.lib import xla_client as xc

    cfg = get_config("opt-tiny")
    params = model.init_params(cfg, seed=0)
    avals = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in params.items()}
    fn = lambda toks, lens, params: (model._embed(cfg, params, toks, lens - 1),)
    lowered = jax.jit(fn, keep_unused=True).lower(
        jax.ShapeDtypeStruct((2,), jnp.int32),
        jax.ShapeDtypeStruct((2,), jnp.int32),
        avals,
    )
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
    )
    txt = comp.as_hlo_text()
    entry = txt[txt.index("ENTRY"):]
    body = entry[: entry.index("\n}")]
    n_params = body.count("parameter(")
    assert n_params == 2 + len(params), f"{n_params} vs {2 + len(params)}"
