//! Host-only shim of the `xla` (xla-rs 0.1.6) API surface used by the
//! polar-sparsity runtime.
//!
//! * [`Literal`] — host tensors (optionally tuples) with cheap `Clone`
//!   (`Arc`-backed storage), npy/npz readers, and untyped construction.
//! * [`PjRtClient`] / [`PjRtBuffer`] — "device" buffers. The shim has no
//!   device, so a buffer is a resident literal; the *interface* (explicit
//!   host->device upload, explicit `to_literal_sync` readback) mirrors
//!   PJRT so the engine's transfer accounting is structurally faithful.
//! * [`PjRtLoadedExecutable::execute*`] — returns a structured error: no
//!   XLA runtime is linked in this image. Everything up to execution
//!   (manifest load, HLO parse, compile-cache bookkeeping, buffer
//!   management) works, which is what the in-tree tests exercise.
//!
//! API parity note: `execute`/`execute_b` mirror xla-rs 0.1.6.
//! [`PjRtLoadedExecutable::execute_untupled_b`], `PjRtBuffer: Clone` and
//! O(1) `Literal: Clone` EXTEND that surface — PJRT itself supports
//! untupled results (`ExecuteOptions::untuple_result`), but the 0.1.6
//! wrapper does not expose it. Swapping this shim for the real crate
//! therefore needs a small wrapper patch for the resident-KV decode
//! path; until then `POLAR_KV_HOST=1` keeps the engine on the
//! 0.1.6-compatible literal path.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// errors
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(format!("io: {e}"))
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

// ---------------------------------------------------------------------------
// element types
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    F32,
    F64,
    S32,
    S64,
    U32,
}

impl ElementType {
    pub fn size_bytes(self) -> usize {
        match self {
            ElementType::Pred => 1,
            ElementType::F32 | ElementType::S32 | ElementType::U32 => 4,
            ElementType::F64 | ElementType::S64 => 8,
        }
    }
}

/// Rust scalar <-> XLA element type mapping (4-byte types only; that is
/// all the AOT artifacts use).
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le_bytes(b: [u8; 4]) -> Self;
    fn to_le_bytes(self) -> [u8; 4];
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
    fn to_le_bytes(self) -> [u8; 4] {
        self.to_le_bytes()
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
    fn to_le_bytes(self) -> [u8; 4] {
        self.to_le_bytes()
    }
}

// ---------------------------------------------------------------------------
// shapes + literals
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
    pub fn element_count(&self) -> usize {
        self.dims.iter().map(|&d| d as usize).product()
    }
}

/// Host literal. `Clone` is O(1) for arrays (shared `Arc` storage), which
/// the TP driver relies on to share one serialized tensor across shards.
#[derive(Debug, Clone)]
pub enum Literal {
    Array {
        ty: ElementType,
        dims: Vec<i64>,
        data: Arc<Vec<u8>>,
    },
    Tuple(Vec<Literal>),
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal::Array {
            ty: T::TY,
            dims: Vec::new(),
            data: Arc::new(v.to_le_bytes().to_vec()),
        }
    }

    pub fn vec1<T: NativeType>(vs: &[T]) -> Literal {
        let mut data = Vec::with_capacity(vs.len() * 4);
        for v in vs {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Literal::Array { ty: T::TY, dims: vec![vs.len() as i64], data: Arc::new(data) }
    }

    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if elems * ty.size_bytes() != data.len() {
            return err(format!(
                "literal: {} bytes for shape {dims:?} of {ty:?} (expected {})",
                data.len(),
                elems * ty.size_bytes()
            ));
        }
        Ok(Literal::Array {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: Arc::new(data.to_vec()),
        })
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { ty, dims: old, data } => {
                let n: i64 = old.iter().product();
                let m: i64 = dims.iter().product();
                if n != m {
                    return err(format!("reshape {old:?} -> {dims:?}: element count"));
                }
                Ok(Literal::Array { ty: *ty, dims: dims.to_vec(), data: data.clone() })
            }
            Literal::Tuple(_) => err("reshape on tuple"),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { ty, dims, .. } => {
                Ok(ArrayShape { ty: *ty, dims: dims.clone() })
            }
            Literal::Tuple(_) => err("array_shape on tuple"),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, data, .. } => {
                if *ty != T::TY {
                    return err(format!("to_vec: literal is {ty:?}"));
                }
                Ok(data
                    .chunks_exact(4)
                    .map(|c| T::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect())
            }
            Literal::Tuple(_) => err("to_vec on tuple"),
        }
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            Literal::Array { .. } => err("to_tuple on array literal"),
        }
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        let mut parts = self.to_tuple()?;
        if parts.len() != 1 {
            return err(format!("to_tuple1: {} elements", parts.len()));
        }
        Ok(parts.pop().unwrap())
    }

    /// Total payload size (tuples: sum of leaves) — the engine's transfer
    /// accounting uses this.
    pub fn size_bytes(&self) -> usize {
        match self {
            Literal::Array { data, .. } => data.len(),
            Literal::Tuple(parts) => parts.iter().map(|p| p.size_bytes()).sum(),
        }
    }
}

// ---------------------------------------------------------------------------
// npy / npz readers
// ---------------------------------------------------------------------------

pub trait FromRawBytes: Sized {
    fn from_raw_bytes(ty: ElementType, dims: &[usize], data: &[u8]) -> Result<Self>;

    fn read_npy<P: AsRef<Path>>(path: P, _ctx: &()) -> Result<Self> {
        let bytes = std::fs::read(path.as_ref())?;
        let (ty, dims, payload) = parse_npy(&bytes)?;
        Self::from_raw_bytes(ty, &dims, payload)
    }

    /// Read every array of an uncompressed (numpy default `np.savez`) zip
    /// archive; entry names have their `.npy` suffix stripped.
    fn read_npz<P: AsRef<Path>>(path: P, _ctx: &()) -> Result<Vec<(String, Self)>> {
        let bytes = std::fs::read(path.as_ref())?;
        let mut out = Vec::new();
        for (name, entry) in parse_zip_stored(&bytes)? {
            let (ty, dims, payload) = parse_npy(entry)?;
            let name = name.strip_suffix(".npy").unwrap_or(&name).to_string();
            out.push((name, Self::from_raw_bytes(ty, &dims, payload)?));
        }
        Ok(out)
    }
}

impl FromRawBytes for Literal {
    fn from_raw_bytes(ty: ElementType, dims: &[usize], data: &[u8]) -> Result<Self> {
        Literal::create_from_shape_and_untyped_data(ty, dims, data)
    }
}

/// Parse one .npy payload: (dtype, shape, data slice).
fn parse_npy(b: &[u8]) -> Result<(ElementType, Vec<usize>, &[u8])> {
    if b.len() < 10 || &b[..6] != b"\x93NUMPY" {
        return err("npy: bad magic");
    }
    let major = b[6];
    let (hdr_len, hdr_off) = if major == 1 {
        (u16::from_le_bytes([b[8], b[9]]) as usize, 10usize)
    } else {
        if b.len() < 12 {
            return err("npy: truncated v2 header");
        }
        (u32::from_le_bytes([b[8], b[9], b[10], b[11]]) as usize, 12usize)
    };
    let body_off = hdr_off + hdr_len;
    if b.len() < body_off {
        return err("npy: truncated header");
    }
    let header = &b[hdr_off..body_off];
    let header =
        std::str::from_utf8(header).map_err(|_| Error("npy: header utf-8".into()))?;
    let descr = dict_str(header, "descr").ok_or_else(|| Error("npy: no descr".into()))?;
    let ty = match descr.trim_start_matches(&['<', '|', '='][..]) {
        "f4" => ElementType::F32,
        "i4" => ElementType::S32,
        other => return err(format!("npy: unsupported dtype {other:?}")),
    };
    if header.contains("'fortran_order': True") {
        return err("npy: fortran order unsupported");
    }
    let shape_src = header
        .split("'shape':")
        .nth(1)
        .and_then(|s| s.split('(').nth(1))
        .and_then(|s| s.split(')').next())
        .ok_or_else(|| Error("npy: no shape".into()))?;
    let mut dims = Vec::new();
    for part in shape_src.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        dims.push(part.parse::<usize>().map_err(|_| Error(format!("npy: dim {part:?}")))?);
    }
    let elems: usize = dims.iter().product();
    let want = elems * ty.size_bytes();
    if b.len() < body_off + want {
        return err("npy: truncated data");
    }
    Ok((ty, dims, &b[body_off..body_off + want]))
}

fn dict_str<'a>(header: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("'{key}':");
    let rest = header.split(&pat).nth(1)?;
    let rest = rest.split('\'').nth(1)?;
    Some(rest)
}

/// Walk the local-file-header chain of a zip archive; stored (method 0)
/// entries only — numpy's default `savez` never compresses.
fn parse_zip_stored(b: &[u8]) -> Result<Vec<(String, &[u8])>> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 4 <= b.len() {
        let sig = u32::from_le_bytes([b[i], b[i + 1], b[i + 2], b[i + 3]]);
        if sig == 0x0201_4b50 || sig == 0x0605_4b50 {
            break; // central directory / end record: done with entries
        }
        if sig != 0x0403_4b50 {
            return err(format!("zip: bad signature {sig:#x} at {i}"));
        }
        if i + 30 > b.len() {
            return err("zip: truncated local header");
        }
        let flags = u16::from_le_bytes([b[i + 6], b[i + 7]]);
        let method = u16::from_le_bytes([b[i + 8], b[i + 9]]);
        let csize = u32::from_le_bytes([b[i + 18], b[i + 19], b[i + 20], b[i + 21]]) as usize;
        let name_len = u16::from_le_bytes([b[i + 26], b[i + 27]]) as usize;
        let extra_len = u16::from_le_bytes([b[i + 28], b[i + 29]]) as usize;
        if method != 0 {
            return err("zip: compressed entries unsupported (use np.savez, not savez_compressed)");
        }
        if flags & 0x08 != 0 {
            return err("zip: streamed entries (data descriptor) unsupported");
        }
        let name_off = i + 30;
        let data_off = name_off + name_len + extra_len;
        if data_off + csize > b.len() {
            return err("zip: truncated entry data");
        }
        let name = std::str::from_utf8(&b[name_off..name_off + name_len])
            .map_err(|_| Error("zip: entry name utf-8".into()))?
            .to_string();
        out.push((name, &b[data_off..data_off + csize]));
        i = data_off + csize;
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// HLO + PJRT
// ---------------------------------------------------------------------------

pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// HLO **text** is the interchange format; the shim validates only
    /// that the file reads (the real crate parses to a proto here).
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Ok(HloModuleProto { text: std::fs::read_to_string(path.as_ref())? })
    }
}

pub struct XlaComputation {
    text: String,
}

impl XlaComputation {
    pub fn from_proto(p: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: p.text.clone() }
    }
}

#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-shim".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    /// Host -> "device" upload. One payload copy, like a real transfer.
    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }

    pub fn compile(&self, c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        if c.text.is_empty() {
            return err("compile: empty HLO module");
        }
        Ok(PjRtLoadedExecutable { _hlo: c.text.clone() })
    }
}

/// Device-resident buffer (shim: a resident literal).
#[derive(Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    /// Device -> host readback.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }

    pub fn size_bytes(&self) -> usize {
        self.lit.size_bytes()
    }
}

pub struct PjRtLoadedExecutable {
    _hlo: String,
}

fn exec_unsupported<T>() -> Result<T> {
    err(
        "shim cannot execute HLO: no XLA runtime is linked in this image. \
         Build against the real `xla` crate (see rust/vendor/xla/Cargo.toml) \
         to run AOT artifacts; in-tree tests use the mock engine",
    )
}

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        exec_unsupported()
    }

    pub fn execute_b<T: Borrow<PjRtBuffer>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        exec_unsupported()
    }

    /// PJRT `untuple_result=true` analogue: one buffer per output tuple
    /// leaf, staying on device (the resident-KV decode path).
    pub fn execute_untupled_b<T: Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<PjRtBuffer>> {
        exec_unsupported()
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn npy_bytes(descr: &str, shape: &str, payload: &[u8]) -> Vec<u8> {
        let header = format!("{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape}, }}");
        let mut b = b"\x93NUMPY\x01\x00".to_vec();
        b.extend_from_slice(&(header.len() as u16).to_le_bytes());
        b.extend_from_slice(header.as_bytes());
        b.extend_from_slice(payload);
        b
    }

    fn zip_stored(entries: &[(&str, &[u8])]) -> Vec<u8> {
        let mut b = Vec::new();
        for (name, data) in entries {
            b.extend_from_slice(&0x0403_4b50u32.to_le_bytes());
            b.extend_from_slice(&[0u8; 2]); // version
            b.extend_from_slice(&[0u8; 2]); // flags
            b.extend_from_slice(&[0u8; 2]); // method: stored
            b.extend_from_slice(&[0u8; 4]); // time/date
            b.extend_from_slice(&[0u8; 4]); // crc (unchecked)
            b.extend_from_slice(&(data.len() as u32).to_le_bytes());
            b.extend_from_slice(&(data.len() as u32).to_le_bytes());
            b.extend_from_slice(&(name.len() as u16).to_le_bytes());
            b.extend_from_slice(&[0u8; 2]); // extra len
            b.extend_from_slice(name.as_bytes());
            b.extend_from_slice(data);
        }
        b.extend_from_slice(&0x0201_4b50u32.to_le_bytes()); // central dir
        b
    }

    #[test]
    fn literal_roundtrip_and_size() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(l.size_bytes(), 16);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        // clone shares storage
        let c = l.clone();
        if let (Literal::Array { data: a, .. }, Literal::Array { data: b, .. }) = (&l, &c) {
            assert!(Arc::ptr_eq(a, b));
        } else {
            panic!("expected arrays");
        }
    }

    #[test]
    fn npy_and_npz_parse() {
        let payload: Vec<u8> = [1i32, -2, 3, 4, 5, 6]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let npy = npy_bytes("<i4", "(2, 3)", &payload);
        let (ty, dims, body) = parse_npy(&npy).unwrap();
        assert_eq!(ty, ElementType::S32);
        assert_eq!(dims, vec![2, 3]);
        assert_eq!(body, &payload[..]);

        let f: Vec<u8> = [0.5f32, -0.5].iter().flat_map(|v| v.to_le_bytes()).collect();
        let npz = zip_stored(&[
            ("w.npy", &npy_bytes("<i4", "(6,)", &payload)),
            ("b.npy", &npy_bytes("<f4", "(2,)", &f)),
        ]);
        let dir = std::env::temp_dir().join("xla_shim_npz_test.npz");
        std::fs::write(&dir, npz).unwrap();
        let named = Literal::read_npz(&dir, &()).unwrap();
        assert_eq!(named.len(), 2);
        assert_eq!(named[0].0, "w");
        assert_eq!(named[0].1.to_vec::<i32>().unwrap(), vec![1, -2, 3, 4, 5, 6]);
        assert_eq!(named[1].0, "b");
        assert_eq!(named[1].1.to_vec::<f32>().unwrap(), vec![0.5, -0.5]);
    }

    #[test]
    fn scalar_shape_parses() {
        let npy = npy_bytes("<f4", "()", &1.5f32.to_le_bytes());
        let (ty, dims, body) = parse_npy(&npy).unwrap();
        assert_eq!(ty, ElementType::F32);
        assert!(dims.is_empty());
        assert_eq!(body.len(), 4);
    }

    #[test]
    fn execute_reports_shim_limit() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { text: "HloModule m".into() };
        let exe = client.compile(&comp).unwrap();
        let e = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(e.to_string().contains("shim cannot execute"));
    }
}
