//! Offline API-compatible shim for the subset of `anyhow` this workspace
//! uses. Errors are a flattened context chain of messages: `context` /
//! `with_context` prepend, `From<impl std::error::Error>` captures the
//! source chain, `{e}` prints the outermost message, `{e:#}` the full
//! chain, and `{e:?}` an anyhow-style "Caused by" report.

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-chain error. Deliberately does NOT implement
/// `std::error::Error`, exactly like the real `anyhow::Error`, so the
/// blanket `From<E: std::error::Error>` impl stays coherent.
pub struct Error {
    /// Outermost context first; the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display + Send + Sync + 'static>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn from_std(e: &dyn std::error::Error) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    fn push_context(mut self, c: String) -> Error {
        self.chain.insert(0, c);
        self
    }

    /// The context chain, outermost first (root cause last).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

mod ext {
    /// Either a std error or an `Error` already — what `.context()` can be
    /// applied to (the same coherence trick the real anyhow uses).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }
    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::from_std(&self)
        }
    }
    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        match self {
            Ok(t) => Ok(t),
            Err(e) => Err(e.into_error().push_context(context.to_string())),
        }
    }
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(t) => Ok(t),
            Err(e) => Err(e.into_error().push_context(f().to_string())),
        }
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")
            .map(|_| ())
            .context("reading config")
    }

    #[test]
    fn context_chain_formats() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").starts_with("reading config: "));
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context_and_bail() {
        fn f(x: Option<u32>) -> Result<u32> {
            let v = x.context("missing")?;
            if v == 0 {
                bail!("zero not allowed: {v}");
            }
            Ok(v)
        }
        assert_eq!(f(Some(3)).unwrap(), 3);
        assert_eq!(format!("{}", f(None).unwrap_err()), "missing");
        assert_eq!(format!("{}", f(Some(0)).unwrap_err()), "zero not allowed: 0");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path/xyz")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
