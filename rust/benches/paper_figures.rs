//! `cargo bench` entry: regenerates the full paper figure/table set into
//! results/ via the in-tree bench harness (criterion is not vendored in
//! this offline image — see DESIGN.md).
//!
//! Skips cleanly when artifacts are missing so `cargo bench` stays green
//! on a fresh checkout.

fn main() {
    // cargo bench passes --bench; ignore harness-style flags
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--bench") && !a.starts_with("--"))
        .collect();
    if !std::path::Path::new("artifacts/opt-tiny/manifest.json").exists() {
        eprintln!("[skip] artifacts not built; run `make artifacts` first");
        return;
    }
    let figure = args.first().map(|s| s.as_str()).unwrap_or("all");
    let argv = vec![
        figure.to_string(),
        "--iters".to_string(),
        "5".to_string(),
        "--warmup".to_string(),
        "1".to_string(),
        "--per-family".to_string(),
        "8".to_string(),
    ];
    if let Err(e) = polar_sparsity::bench::figures::run(&argv) {
        eprintln!("bench failed: {e:#}");
        std::process::exit(1);
    }
}
