//! PJRT executor: lazy per-entry compile cache + the weight set uploaded
//! once per model. All python is out of the picture here — executables are
//! compiled from AOT HLO text and run on the PJRT CPU client.
//!
//! Two execution paths:
//!   * [`Executor::run_bufs`] — buffer-in/buffer-out (untupled outputs).
//!     The decode hot path feeds one step's KV output buffer straight into
//!     the next step, so per-step host traffic is only tokens/lengths up
//!     and logits down. Host literals are uploaded lazily, which is how
//!     the KV cache re-enters the device after composition changes.
//!   * [`Executor::run_literals`] — the legacy literal-in/tuple-out path,
//!     kept as the A/B baseline (env `POLAR_KV_HOST=1` forces the engine
//!     onto it) and for prefill/micro entries.
//!
//! Every call records bytes and nanoseconds per phase into a shared
//! [`StepProfile`] so `bench decode-breakdown` can attribute step time.
//!
//! Thread-safety: the PJRT C++ client is thread-safe; the rust wrapper
//! types just hold raw pointers and are not marked Send/Sync. `Executor`
//! is used from the engine thread and (for the TP driver) from short-lived
//! worker threads via `unsafe impl Send + Sync` — see the safety note.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::FromRawBytes;

use crate::substrate::sync::lock_clean;

use super::manifest::{EntrySpec, Manifest};
use super::profile::StepProfile;
use super::tensor::Tensor;

/// One input to a buffer-path execution: either already device-resident
/// (flows across steps for free) or a host literal to upload this call.
pub enum DeviceInput {
    Host(xla::Literal),
    Buf(xla::PjRtBuffer),
}

pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    weights: Vec<xla::Literal>, // sorted by name, matches manifest.params
    /// The same weights uploaded ONCE as device buffers. The hot path runs
    /// `execute_b` over these, so per-step host->device traffic is only
    /// the entry's data inputs (tokens/lengths/kv) — without this, PJRT
    /// re-copies every weight literal on every call (§Perf).
    weight_bufs: Vec<xla::PjRtBuffer>,
    /// A/B switch for EXPERIMENTS.md §Perf (env POLAR_WEIGHTS_LITERAL=1
    /// forces the naive literal path).
    use_weight_bufs: bool,
    cache: Mutex<HashMap<String, Arc<CompiledEntry>>>,
    pub compile_stats: Mutex<CompileStats>,
    profile: Mutex<StepProfile>,
}

// SAFETY: PJRT's C API is thread-safe (all entry points lock internally or
// are immutable after construction); Literal buffers are only read after
// construction. The wrapper types lack Send/Sync solely because they hold
// raw pointers.
unsafe impl Send for Executor {}
unsafe impl Sync for Executor {}

pub struct CompiledEntry {
    pub spec: EntrySpec,
    exe: xla::PjRtLoadedExecutable,
}

#[derive(Debug, Default, Clone)]
pub struct CompileStats {
    pub compiled: usize,
    pub total_seconds: f64,
}

impl Executor {
    /// Load the model directory: manifest + weights (npz) and create the
    /// PJRT CPU client. HLO entries compile lazily on first use.
    pub fn load(model_dir: &Path) -> Result<Executor> {
        let manifest = Manifest::load(model_dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;

        let npz = model_dir.join("model.npz");
        let named: Vec<(String, xla::Literal)> = xla::Literal::read_npz(&npz, &())
            .with_context(|| format!("reading {}", npz.display()))?;
        let mut by_name: HashMap<String, xla::Literal> = named.into_iter().collect();
        let mut weights = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let lit = by_name
                .remove(&p.name)
                .with_context(|| format!("weight {} missing from npz", p.name))?;
            let shape: Vec<usize> = lit
                .array_shape()?
                .dims()
                .iter()
                .map(|&d| d as usize)
                .collect();
            if shape != p.shape {
                bail!(
                    "weight {} shape {:?} != manifest {:?}",
                    p.name, shape, p.shape
                );
            }
            weights.push(lit);
        }
        let weight_bufs = weights
            .iter()
            .map(|w| client.buffer_from_host_literal(None, w))
            .collect::<xla::Result<Vec<_>>>()
            .context("uploading weight buffers")?;
        let use_weight_bufs = std::env::var("POLAR_WEIGHTS_LITERAL").is_err();
        Ok(Executor {
            client,
            manifest,
            weights,
            weight_bufs,
            use_weight_bufs,
            cache: Mutex::new(HashMap::new()),
            compile_stats: Mutex::new(CompileStats::default()),
            profile: Mutex::new(StepProfile::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn config(&self) -> &super::manifest::ModelConfig {
        &self.manifest.config
    }

    /// Host copy of a loaded weight literal, by parameter name. The
    /// router bank reads `tok_emb`/`ar_*`/`mr_*` through this instead of
    /// re-opening the npz.
    pub fn weight(&self, name: &str) -> Option<&xla::Literal> {
        let i = self.manifest.params.iter().position(|p| p.name == name)?;
        self.weights.get(i)
    }

    /// Cumulative transfer/compute profile since the last reset.
    pub fn profile_snapshot(&self) -> StepProfile {
        *lock_clean(&self.profile)
    }

    pub fn reset_profile(&self) {
        *lock_clean(&self.profile) = StepProfile::default();
    }

    pub(crate) fn profile_mut(&self) -> std::sync::MutexGuard<'_, StepProfile> {
        lock_clean(&self.profile)
    }

    /// Compile (or fetch from cache) an entry by name.
    pub fn compiled(&self, name: &str) -> Result<Arc<CompiledEntry>> {
        if let Some(hit) = lock_clean(&self.cache).get(name) {
            return Ok(hit.clone());
        }
        let spec = self.manifest.entry(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("hlo path utf-8")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut st = lock_clean(&self.compile_stats);
            st.compiled += 1;
            st.total_seconds += dt;
        }
        let entry = Arc::new(CompiledEntry { spec, exe });
        lock_clean(&self.cache).insert(name.to_string(), entry.clone());
        Ok(entry)
    }

    pub fn is_cached(&self, name: &str) -> bool {
        lock_clean(&self.cache).contains_key(name)
    }

    /// Upload one host literal to the device (h2d accounted).
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let t0 = Instant::now();
        let buf = self
            .client
            .buffer_from_host_literal(None, lit)
            .context("uploading literal")?;
        let mut p = lock_clean(&self.profile);
        p.h2d_bytes += lit.size_bytes() as u64;
        p.h2d_ns += t0.elapsed().as_nanos() as u64;
        Ok(buf)
    }

    /// Fetch one output buffer back to the host (d2h accounted).
    pub fn fetch_literal(&self, buf: &xla::PjRtBuffer) -> Result<xla::Literal> {
        let t0 = Instant::now();
        let lit = buf.to_literal_sync().context("fetching buffer")?;
        let mut p = lock_clean(&self.profile);
        p.d2h_bytes += lit.size_bytes() as u64;
        p.d2h_ns += t0.elapsed().as_nanos() as u64;
        Ok(lit)
    }

    /// Buffer-in/buffer-out execution with untupled outputs: the decode
    /// hot path. Device-resident inputs cross no boundary; host inputs
    /// are uploaded here; outputs STAY on device — the caller fetches
    /// only what it needs (logits) via [`Executor::fetch_literal`].
    pub fn run_bufs(
        &self,
        name: &str,
        inputs: Vec<DeviceInput>,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let entry = self.compiled(name)?;
        if inputs.len() != entry.spec.data.len() {
            bail!(
                "{}: got {} data inputs, expected {}",
                entry.spec.name,
                inputs.len(),
                entry.spec.data.len()
            );
        }
        let data_bufs = inputs
            .into_iter()
            .map(|i| match i {
                DeviceInput::Buf(b) => Ok(b),
                DeviceInput::Host(l) => self.upload(&l),
            })
            .collect::<Result<Vec<_>>>()?;
        // POLAR_WEIGHTS_LITERAL=1 must stay honest on this path too: the
        // naive baseline re-uploads every weight each call (accounted as
        // h2d) instead of using the persistent device set.
        let naive_weight_bufs: Vec<xla::PjRtBuffer>;
        let weight_bufs: &[xla::PjRtBuffer] = if self.use_weight_bufs {
            &self.weight_bufs
        } else {
            naive_weight_bufs = self
                .weights
                .iter()
                .map(|w| self.upload(w))
                .collect::<Result<Vec<_>>>()?;
            &naive_weight_bufs
        };
        let mut all: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(data_bufs.len() + weight_bufs.len());
        all.extend(data_bufs.iter());
        all.extend(weight_bufs.iter());
        let t0 = Instant::now();
        let outs = entry
            .exe
            .execute_untupled_b::<&xla::PjRtBuffer>(&all)
            .with_context(|| format!("executing {} (buffer path)", entry.spec.name))?;
        lock_clean(&self.profile).compute_ns += t0.elapsed().as_nanos() as u64;
        if outs.len() != entry.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, expected {}",
                entry.spec.name,
                outs.len(),
                entry.spec.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Run an entry: data literals (entry order) + the model weight set.
    /// Returns the decomposed output tuple (one full d2h of the tuple —
    /// the A/B baseline cost the resident-buffer path removes).
    pub fn run_literals(
        &self,
        entry: &CompiledEntry,
        data: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        if data.len() != entry.spec.data.len() {
            bail!(
                "{}: got {} data inputs, expected {}",
                entry.spec.name,
                data.len(),
                entry.spec.data.len()
            );
        }
        let h2d: u64 = data.iter().map(|l| l.size_bytes() as u64).sum();
        let t_up = Instant::now();
        let result = if self.use_weight_bufs {
            // hot path: persistent weight buffers + per-call data buffers
            let data_bufs = data
                .iter()
                .map(|l| self.client.buffer_from_host_literal(None, l))
                .collect::<xla::Result<Vec<_>>>()
                .context("uploading data inputs")?;
            let up_ns = t_up.elapsed().as_nanos() as u64;
            let mut inputs: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(data.len() + self.weight_bufs.len());
            inputs.extend(data_bufs.iter());
            inputs.extend(self.weight_bufs.iter());
            let t0 = Instant::now();
            let r = entry
                .exe
                .execute_b::<&xla::PjRtBuffer>(&inputs)
                .with_context(|| format!("executing {}", entry.spec.name))?;
            let mut p = lock_clean(&self.profile);
            p.h2d_bytes += h2d;
            p.h2d_ns += up_ns;
            p.compute_ns += t0.elapsed().as_nanos() as u64;
            r
        } else {
            let mut inputs: Vec<&xla::Literal> =
                Vec::with_capacity(data.len() + self.weights.len());
            inputs.extend(data.iter());
            inputs.extend(self.weights.iter());
            let t0 = Instant::now();
            let r = entry
                .exe
                .execute::<&xla::Literal>(&inputs)
                .with_context(|| format!("executing {}", entry.spec.name))?;
            let mut p = lock_clean(&self.profile);
            p.h2d_bytes += h2d;
            // PJRT copies the literals inside execute on this path, so
            // upload time is not separable: it lands in compute_ns and
            // h2d_ns stays 0 despite nonzero h2d_bytes.
            p.compute_ns += t0.elapsed().as_nanos() as u64;
            r
        };
        let t_down = Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        {
            let mut p = lock_clean(&self.profile);
            p.d2h_bytes += tuple.size_bytes() as u64;
            p.d2h_ns += t_down.elapsed().as_nanos() as u64;
        }
        let parts = tuple.to_tuple().context("untuple result")?;
        if parts.len() != entry.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, expected {}",
                entry.spec.name,
                parts.len(),
                entry.spec.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Convenience: run by name with host tensors in, host tensors out.
    pub fn run(&self, name: &str, data: &[Tensor]) -> Result<Vec<Tensor>> {
        let entry = self.compiled(name)?;
        for (t, spec) in data.iter().zip(entry.spec.data.iter()) {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "{name}: input {} shape {:?} != expected {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        let lits: Vec<xla::Literal> = data
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let outs = self.run_literals(&entry, &lits)?;
        outs.iter().map(Tensor::from_literal).collect()
    }

    /// Run by name with pre-built literals (hot path: kv literal reuse).
    pub fn run_raw(&self, name: &str, data: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let entry = self.compiled(name)?;
        self.run_literals(&entry, data)
    }
}
