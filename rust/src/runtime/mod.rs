//! Runtime layer: PJRT client, artifact manifest, weight loading, lazy
//! executable compilation, the paged prefill/decode step drivers and the
//! shard-aware TP/PP drivers (`shard`: route-then-dispatch planning over
//! per-shard resident pool slices).
//! Adapted from the /opt/xla-example/load_hlo pattern (HLO **text** is the
//! interchange format — see DESIGN.md).

pub mod engine;
pub mod executor;
pub mod manifest;
pub mod profile;
pub mod router;
pub mod shard;
pub mod tensor;

pub use engine::{
    copy_pool_blocks, BlockTables, Engine, KvCache, KvStore, PagedKv, PagedStepOutput,
    StepOutput,
};
pub use executor::{DeviceInput, Executor};
pub use manifest::{EntrySpec, Manifest, ModelConfig, TensorSpec};
pub use profile::StepProfile;
pub use router::{RouterBank, RoutingPolicy, StepRouting};
pub use shard::{
    merge_pool_groups, merge_pool_layers, mlp_shard_k, plan_shard_dispatch,
    split_pool_groups, split_pool_layers, AttnDispatch, LayerPlan, MlpDispatch,
    ShardDispatch, ShardPlanSpec, TpStepOutput,
};
pub use tensor::{Dtype, Tensor};
