//! Runtime layer: PJRT client, artifact manifest, weight loading, lazy
//! executable compilation and the prefill/decode/PP/TP step drivers.
//! Adapted from the /opt/xla-example/load_hlo pattern (HLO **text** is the
//! interchange format — see DESIGN.md).

pub mod engine;
pub mod executor;
pub mod manifest;
pub mod profile;
pub mod router;
pub mod tensor;

pub use engine::{
    copy_pool_blocks, BlockTables, Engine, KvCache, KvStore, PagedKv, PagedStepOutput,
    StepOutput,
};
pub use executor::{DeviceInput, Executor};
pub use manifest::{EntrySpec, Manifest, ModelConfig, TensorSpec};
pub use profile::StepProfile;
pub use router::{RouterBank, RoutingPolicy, StepRouting};
pub use tensor::{Dtype, Tensor};
