//! Runtime head/neuron routing: the per-step *contextual* half of
//! contextual sparsity (paper §4.1/§4.2), executed by the serving runtime
//! instead of inside the compiled graph.
//!
//! A [`RouterBank`] holds the trained router weights straight out of the
//! artifact npz (they are ordinary model params, so the executor has
//! already loaded them): per-layer single-layer attention head/group
//! routers `ar_w`/`ar_b` and, for ReLU models, two-layer bottleneck MLP
//! routers `mr_*`. Every decode step [`RouterBank::route_step`]:
//!
//!   1. embeds the step's input tokens (the hidden state available
//!      *outside* the graph — see the approximation note below),
//!   2. runs each layer's routers on it,
//!   3. takes per-request top-k head groups (the SHA kernel consumes
//!      per-request indices, so head compute scales with `B * k` and the
//!      per-request density is batch-invariant),
//!   4. takes the **batch union** of per-request top-k MLP neurons (the
//!      selective GEMM gathers one row set for the whole batch, so MLP
//!      union density grows with B — Deja Vu's failure mode at batch),
//!
//! and returns the `head_idx` [L,B,Kh] / `mlp_idx` [L,Km] index tensors
//! the parameterized `polar` decode entries consume, plus per-layer union
//! densities and the router-overhead nanoseconds for telemetry.
//!
//! Approximation note: the routers are trained on each layer's *input
//! hidden state* (Appendix C), which only exists mid-graph. Routing from
//! the runtime applies them to the step's embedding instead — the same
//! signal for every layer. This is what makes the indices available
//! before the graph launches (and lets the scheduler record union
//! telemetry); the legacy in-graph entries remain the fidelity reference.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::executor::Executor;
use super::manifest::EntrySpec;
use super::tensor::Tensor;

// ---------------------------------------------------------------------------
// selection primitives
// ---------------------------------------------------------------------------

/// Indices of the `k` largest values of `row`, in descending value order.
/// Ties break toward the lower index (numpy's stable `argsort(-x)`);
/// `k >= row.len()` returns every index, `k == 0` none.
pub fn top_k_indices(row: &[f32], k: usize) -> Vec<i32> {
    let k = k.min(row.len());
    let mut order: Vec<usize> = (0..row.len()).collect();
    order.sort_by(|&a, &b| row[b].total_cmp(&row[a]).then(a.cmp(&b)));
    order.truncate(k);
    order.into_iter().map(|i| i as i32).collect()
}

/// Sorted (ascending) union of per-request selections. `rows` holds each
/// request's selected indices; out-of-range entries are ignored.
pub fn batch_union(rows: &[Vec<i32>], n: usize) -> Vec<i32> {
    let mut seen = vec![false; n];
    for row in rows {
        for &i in row {
            if (i as usize) < n {
                seen[i as usize] = true;
            }
        }
    }
    (0..n).filter(|&i| seen[i]).map(|i| i as i32).collect()
}

/// Query-head ids covered by a selected KV group (GQA mapping): group `g`
/// owns query heads `[g*q_per_group, (g+1)*q_per_group)`. With MHA
/// (`q_per_group == 1`) this is the identity.
pub fn group_query_heads(groups: &[i32], q_per_group: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(groups.len() * q_per_group);
    for &g in groups {
        for q in 0..q_per_group {
            out.push(g * q_per_group as i32 + q as i32);
        }
    }
    out
}

/// Mean top-k recall of router logits against binary labels, both flat
/// `[rows, n]` row-major — the metric routers.py reports per layer:
/// `E[|topk(pred) ∩ active| / |active|]`.
pub fn recall_at_k(logits: &[f32], labels: &[f32], n: usize, k: usize) -> f64 {
    assert_eq!(logits.len(), labels.len());
    assert!(n > 0 && logits.len() % n == 0);
    let rows = logits.len() / n;
    let mut total = 0.0;
    for r in 0..rows {
        let lr = &logits[r * n..(r + 1) * n];
        let yr = &labels[r * n..(r + 1) * n];
        let hit = top_k_indices(lr, k)
            .into_iter()
            .filter(|&i| yr[i as usize] > 0.0)
            .count();
        let active = yr.iter().filter(|&&y| y > 0.0).count().max(1);
        total += hit as f64 / active as f64;
    }
    if rows == 0 {
        0.0
    } else {
        total / rows as f64
    }
}

// ---------------------------------------------------------------------------
// policy + per-step decision
// ---------------------------------------------------------------------------

/// How much to select each step. Derived from the manifest entry for real
/// artifacts ([`RoutingPolicy::from_entry`]); constructed directly for the
/// mock engine and tests.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoutingPolicy {
    /// Head groups kept per request per layer (the entry's Kh).
    pub head_k: usize,
    /// Per-request MLP top-k per layer; empty disables MLP routing.
    pub mlp_req_k: Vec<usize>,
    /// Width of the `mlp_idx` tensor (the union capacity Km).
    pub mlp_cap: usize,
}

impl RoutingPolicy {
    /// Read the policy off an index-taking decode entry: `head_k` from the
    /// `head_idx` input shape [L,B,Kh], `mlp_cap` from `mlp_idx` [L,Km],
    /// per-request MLP k from the entry's calibrated `mlp_topk` meta.
    /// Returns None when the entry takes no index inputs (legacy in-graph
    /// routing).
    pub fn from_entry(spec: &EntrySpec) -> Option<RoutingPolicy> {
        let head = spec.data.iter().find(|d| d.name == "head_idx")?;
        let head_k = *head.shape.last().unwrap_or(&0);
        let n_layers = *head.shape.first().unwrap_or(&0);
        let (mlp_cap, mlp_req_k) = match spec.data.iter().find(|d| d.name == "mlp_idx") {
            Some(m) => {
                let cap = *m.shape.last().unwrap_or(&0);
                let req: Vec<usize> = match spec.meta.get("mlp_topk").as_arr() {
                    Some(a) if a.len() == n_layers => a
                        .iter()
                        .map(|v| v.as_usize().unwrap_or(cap).clamp(1, cap))
                        .collect(),
                    _ => vec![cap; n_layers],
                };
                (cap, req)
            }
            None => (0, Vec::new()),
        };
        Some(RoutingPolicy { head_k, mlp_req_k, mlp_cap })
    }
}

/// One step's routing decision: the index tensors the decode entry
/// consumes plus the telemetry the controller aggregates.
#[derive(Debug, Clone)]
pub struct StepRouting {
    /// i32 [n_layers, batch, head_k] — per-request selected head groups
    /// (layer 0's rows are present but ignored: layer 0 stays dense §3.2).
    pub head_idx: Tensor,
    /// i32 [n_layers, mlp_cap] — batch-union selected MLP neurons, fitted
    /// to the entry's capacity (see `route_step`). None for non-ReLU
    /// models or when the policy disables MLP routing.
    pub mlp_idx: Option<Tensor>,
    pub head_k: usize,
    pub n_groups: usize,
    /// Per-layer |union of selected groups across the batch| / n_groups.
    pub head_union: Vec<f64>,
    /// Per-layer |union of per-request top-k neurons| / d_ff, recorded
    /// *before* fitting to the capacity Km.
    pub mlp_union: Vec<f64>,
    /// Selection counts, [n_layers * n_groups] row-major — feeds the
    /// head-selection histogram in server stats.
    pub head_counts: Vec<u64>,
    /// Live-slot mask this decision was computed under (None = all live).
    /// Masked slots carry placeholder `0..k` head rows — the shard
    /// dispatch planner must not let those force a shard dispatch.
    pub active: Option<Vec<bool>>,
    pub router_ns: u64,
}

impl StepRouting {
    /// Per-request head work density (batch-invariant by construction:
    /// the SHA kernel runs exactly `head_k` of `n_groups` groups per
    /// request regardless of batch size).
    pub fn head_density(&self) -> f64 {
        self.head_k as f64 / self.n_groups.max(1) as f64
    }
}

// ---------------------------------------------------------------------------
// router bank
// ---------------------------------------------------------------------------

/// Two-layer bottleneck MLP router weights (ReLU models only).
#[derive(Debug, Clone)]
pub struct MlpRouterWeights {
    pub hidden: usize,
    w1: Vec<f32>, // [L, d, rh]
    b1: Vec<f32>, // [L, rh]
    w2: Vec<f32>, // [L, rh, d_ff]
    b2: Vec<f32>, // [L, d_ff]
}

/// Trained router weights + the embedding needed to produce their input,
/// all host-resident (routing is a few tiny GEMVs per step).
#[derive(Debug, Clone)]
pub struct RouterBank {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_groups: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub q_per_group: usize,
    tok_emb: Vec<f32>, // [V, d]
    pos_emb: Vec<f32>, // [S, d]; empty for rope models
    attn_w: Vec<f32>,  // [L, d, G]
    attn_b: Vec<f32>,  // [L, G]
    mlp: Option<MlpRouterWeights>,
}

impl RouterBank {
    /// Build from raw row-major weight vectors (used by the mock engine,
    /// the bench harness and tests). Lengths are validated against dims.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n_layers: usize,
        d_model: usize,
        n_groups: usize,
        d_ff: usize,
        q_per_group: usize,
        tok_emb: Vec<f32>,
        pos_emb: Vec<f32>,
        attn_w: Vec<f32>,
        attn_b: Vec<f32>,
        mlp: Option<MlpRouterWeights>,
    ) -> Result<RouterBank> {
        if d_model == 0 || tok_emb.len() % d_model != 0 {
            bail!("router bank: tok_emb len {} not a multiple of d_model {d_model}",
                  tok_emb.len());
        }
        if !pos_emb.is_empty() && pos_emb.len() % d_model != 0 {
            bail!("router bank: pos_emb len {} not a multiple of d_model {d_model}",
                  pos_emb.len());
        }
        if attn_w.len() != n_layers * d_model * n_groups
            || attn_b.len() != n_layers * n_groups
        {
            bail!(
                "router bank: attn router shapes {}/{} != [{n_layers},{d_model},{n_groups}]",
                attn_w.len(), attn_b.len()
            );
        }
        if let Some(m) = &mlp {
            let rh = m.hidden;
            if m.w1.len() != n_layers * d_model * rh
                || m.b1.len() != n_layers * rh
                || m.w2.len() != n_layers * rh * d_ff
                || m.b2.len() != n_layers * d_ff
            {
                bail!("router bank: mlp router shapes inconsistent with [L={n_layers},d={d_model},rh={rh},dff={d_ff}]");
            }
        }
        Ok(RouterBank {
            n_layers,
            d_model,
            n_groups,
            d_ff,
            vocab: tok_emb.len() / d_model,
            q_per_group,
            tok_emb,
            pos_emb,
            attn_w,
            attn_b,
            mlp,
        })
    }

    pub fn mlp_router(
        hidden: usize,
        w1: Vec<f32>,
        b1: Vec<f32>,
        w2: Vec<f32>,
        b2: Vec<f32>,
    ) -> MlpRouterWeights {
        MlpRouterWeights { hidden, w1, b1, w2, b2 }
    }

    /// Load the routers out of an executor's already-loaded weight set.
    /// `Ok(None)` when the artifact carries no attention-router weights
    /// (`ar_w`/`ar_b` absent from the npz) — the graceful-degradation
    /// path; `Err` on present-but-malformed weights.
    pub fn from_executor(exec: &Executor) -> Result<Option<RouterBank>> {
        let cfg = exec.config();
        let vecf = |name: &str| -> Result<Option<Vec<f32>>> {
            match exec.weight(name) {
                None => Ok(None),
                Some(l) => Ok(Some(
                    l.to_vec::<f32>()
                        .map_err(|e| anyhow::anyhow!("weight {name}: {e}"))?,
                )),
            }
        };
        let (Some(attn_w), Some(attn_b)) = (vecf("ar_w")?, vecf("ar_b")?) else {
            return Ok(None);
        };
        let tok_emb = vecf("tok_emb")?.context("tok_emb missing from weights")?;
        let pos_emb = if cfg.pos == "learned" {
            vecf("pos_emb")?.unwrap_or_default()
        } else {
            Vec::new()
        };
        let mlp = match (vecf("mr_w1")?, vecf("mr_b1")?, vecf("mr_w2")?, vecf("mr_b2")?) {
            (Some(w1), Some(b1), Some(w2), Some(b2)) => {
                let rh = b1.len() / cfg.n_layers.max(1);
                Some(MlpRouterWeights { hidden: rh, w1, b1, w2, b2 })
            }
            _ => None,
        };
        RouterBank::new(
            cfg.n_layers,
            cfg.d_model,
            cfg.n_groups(),
            cfg.d_ff,
            cfg.q_per_group(),
            tok_emb,
            pos_emb,
            attn_w,
            attn_b,
            mlp,
        )
        .map(Some)
    }

    pub fn has_mlp(&self) -> bool {
        self.mlp.is_some()
    }

    /// Embed the step's tokens: `tok_emb[t] (+ pos_emb[len-1])` — the
    /// hidden state the runtime can produce without running the graph.
    pub fn embed(&self, tokens: &[i32], lengths: &[i32]) -> Vec<f32> {
        let d = self.d_model;
        let mut h = vec![0f32; tokens.len() * d];
        let n_pos = self.pos_emb.len() / d.max(1);
        for (i, &t) in tokens.iter().enumerate() {
            let t = (t.max(0) as usize).min(self.vocab.saturating_sub(1));
            h[i * d..(i + 1) * d].copy_from_slice(&self.tok_emb[t * d..(t + 1) * d]);
            if n_pos > 0 {
                let pos = (lengths.get(i).copied().unwrap_or(1).max(1) as usize - 1)
                    .min(n_pos - 1);
                let row = &self.pos_emb[pos * d..(pos + 1) * d];
                for (x, p) in h[i * d..(i + 1) * d].iter_mut().zip(row) {
                    *x += p;
                }
            }
        }
        h
    }

    /// Layer `l` attention-router logits for hidden `h` [b, d] -> [b, G].
    pub fn attn_logits(&self, l: usize, h: &[f32], b: usize) -> Vec<f32> {
        let (d, g) = (self.d_model, self.n_groups);
        let w = &self.attn_w[l * d * g..(l + 1) * d * g];
        let bias = &self.attn_b[l * g..(l + 1) * g];
        let mut out = vec![0f32; b * g];
        for i in 0..b {
            let hi = &h[i * d..(i + 1) * d];
            let row = &mut out[i * g..(i + 1) * g];
            row.copy_from_slice(bias);
            for (j, &x) in hi.iter().enumerate() {
                if x != 0.0 {
                    let wr = &w[j * g..(j + 1) * g];
                    for (o, &wv) in row.iter_mut().zip(wr) {
                        *o += x * wv;
                    }
                }
            }
        }
        out
    }

    /// Layer `l` MLP-router logits [b, d_ff] (ReLU bottleneck FFN).
    pub fn mlp_logits(&self, l: usize, h: &[f32], b: usize) -> Option<Vec<f32>> {
        let m = self.mlp.as_ref()?;
        let (d, rh, dff) = (self.d_model, m.hidden, self.d_ff);
        let w1 = &m.w1[l * d * rh..(l + 1) * d * rh];
        let b1 = &m.b1[l * rh..(l + 1) * rh];
        let w2 = &m.w2[l * rh * dff..(l + 1) * rh * dff];
        let b2 = &m.b2[l * dff..(l + 1) * dff];
        let mut out = vec![0f32; b * dff];
        let mut z = vec![0f32; rh];
        for i in 0..b {
            let hi = &h[i * d..(i + 1) * d];
            z.copy_from_slice(b1);
            for (j, &x) in hi.iter().enumerate() {
                if x != 0.0 {
                    let wr = &w1[j * rh..(j + 1) * rh];
                    for (zv, &wv) in z.iter_mut().zip(wr) {
                        *zv += x * wv;
                    }
                }
            }
            let row = &mut out[i * dff..(i + 1) * dff];
            row.copy_from_slice(b2);
            for (j, &zv) in z.iter().enumerate() {
                let zv = zv.max(0.0); // relu
                if zv != 0.0 {
                    let wr = &w2[j * dff..(j + 1) * dff];
                    for (o, &wv) in row.iter_mut().zip(wr) {
                        *o += zv * wv;
                    }
                }
            }
        }
        Some(out)
    }

    /// One decode step's routing decision for the batch described by
    /// `tokens`/`lengths` (per-slot, like the decode entry's inputs).
    ///
    /// `active` masks the slots that carry live requests: the scheduler's
    /// batch is padded to the bucket, and the padding slots must neither
    /// count toward union telemetry nor compete for MLP capacity. Masked
    /// slots still get (valid) placeholder head indices `0..k`, because
    /// the static-shape entry attends every row regardless. `None` means
    /// every slot is live (direct eval/bench callers).
    ///
    /// The MLP union is fitted to the entry capacity Km: neurons are
    /// ranked (in-union first, then by batch-max router logit over live
    /// slots, then by index) and the top Km taken — a superset of the
    /// union when it fits, the best-scoring subset when it overflows.
    /// Padding never repeats a neuron, so the selective GEMM cannot
    /// double-count rows.
    pub fn route_step(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        active: Option<&[bool]>,
        policy: &RoutingPolicy,
    ) -> Result<StepRouting> {
        let t0 = Instant::now();
        let b = tokens.len();
        if b == 0 || lengths.len() != b {
            bail!("route_step: tokens/lengths batch mismatch ({b}/{})", lengths.len());
        }
        if let Some(a) = active {
            if a.len() != b {
                bail!("route_step: active mask len {} != batch {b}", a.len());
            }
        }
        let live = |i: usize| active.map_or(true, |a| a[i]);
        let (ll, g) = (self.n_layers, self.n_groups);
        let head_k = policy.head_k.clamp(1, g);
        let h = self.embed(tokens, lengths);

        let mut head_data = Vec::with_capacity(ll * b * head_k);
        let mut head_union = Vec::with_capacity(ll);
        let mut head_counts = vec![0u64; ll * g];
        for l in 0..ll {
            let logits = self.attn_logits(l, &h, b);
            let mut rows = Vec::new();
            for i in 0..b {
                if !live(i) {
                    head_data.extend((0..head_k).map(|x| x as i32));
                    continue;
                }
                let sel = top_k_indices(&logits[i * g..(i + 1) * g], head_k);
                for &gi in &sel {
                    head_counts[l * g + gi as usize] += 1;
                }
                head_data.extend(sel.iter().copied());
                rows.push(sel);
            }
            head_union.push(batch_union(&rows, g).len() as f64 / g as f64);
        }
        let head_idx = Tensor::i32(head_data, vec![ll, b, head_k])?;

        let route_mlp = self.mlp.is_some()
            && policy.mlp_cap > 0
            && policy.mlp_req_k.len() == ll;
        let (mlp_idx, mlp_union) = if route_mlp {
            let cap = policy.mlp_cap.min(self.d_ff);
            let dff = self.d_ff;
            let mut data = Vec::with_capacity(ll * cap);
            let mut unions = Vec::with_capacity(ll);
            for l in 0..ll {
                let logits = self.mlp_logits(l, &h, b).unwrap();
                let req_k = policy.mlp_req_k[l].clamp(1, dff);
                let mut in_union = vec![false; dff];
                let mut max_logit = vec![f32::NEG_INFINITY; dff];
                for i in 0..b {
                    if !live(i) {
                        continue;
                    }
                    let row = &logits[i * dff..(i + 1) * dff];
                    for &j in &top_k_indices(row, req_k) {
                        in_union[j as usize] = true;
                    }
                    for (m, &v) in max_logit.iter_mut().zip(row) {
                        *m = m.max(v);
                    }
                }
                let union_n = in_union.iter().filter(|x| **x).count();
                unions.push(union_n as f64 / dff as f64);
                // full sort of all d_ff candidates; at this zoo's widths
                // (d_ff <= 768) that is microseconds and shows up honestly
                // in router_ns — a select_nth fast path only pays off at
                // real-model widths
                let mut order: Vec<usize> = (0..dff).collect();
                order.sort_by(|&a, &c| {
                    in_union[c]
                        .cmp(&in_union[a])
                        .then(max_logit[c].total_cmp(&max_logit[a]))
                        .then(a.cmp(&c))
                });
                data.extend(order[..cap].iter().map(|&j| j as i32));
            }
            (Some(Tensor::i32(data, vec![ll, cap])?), unions)
        } else {
            (None, Vec::new())
        };

        Ok(StepRouting {
            head_idx,
            mlp_idx,
            head_k,
            n_groups: g,
            head_union,
            mlp_union,
            head_counts,
            active: active.map(|a| a.to_vec()),
            router_ns: t0.elapsed().as_nanos() as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_orders_by_value_then_index() {
        assert_eq!(top_k_indices(&[0.1, 0.9, 0.5], 2), vec![1, 2]);
        // exact ties break toward the lower index (stable argsort(-x))
        assert_eq!(top_k_indices(&[0.5, 0.5, 0.5, 0.9], 3), vec![3, 0, 1]);
        assert_eq!(top_k_indices(&[1.0, 2.0], 0), Vec::<i32>::new());
        // k >= n returns every index, still value-ordered
        assert_eq!(top_k_indices(&[1.0, 3.0, 2.0], 8), vec![1, 2, 0]);
    }

    #[test]
    fn union_is_sorted_and_deduped() {
        let rows = vec![vec![3, 1], vec![1, 0], vec![3, 3]];
        assert_eq!(batch_union(&rows, 4), vec![0, 1, 3]);
        assert_eq!(batch_union(&[], 4), Vec::<i32>::new());
        // out-of-range indices are ignored, not a panic
        assert_eq!(batch_union(&[vec![9, 0]], 2), vec![0]);
    }

    #[test]
    fn gqa_group_mapping_expands_to_query_heads() {
        // MHA: identity
        assert_eq!(group_query_heads(&[2, 0], 1), vec![2, 0]);
        // GQA with 4 query heads per KV group
        assert_eq!(group_query_heads(&[1], 4), vec![4, 5, 6, 7]);
        assert_eq!(group_query_heads(&[0, 2], 2), vec![0, 1, 4, 5]);
    }

    #[test]
    fn recall_at_k_matches_hand_count() {
        // row 0: top-2 = {1,3}, active = {1,2} -> 1/2
        // row 1: top-2 = {0,1}, active = {0,1} -> 2/2
        let logits = [0.0, 0.9, 0.1, 0.8, 0.9, 0.8, 0.0, 0.1];
        let labels = [0.0, 1.0, 1.0, 0.0, 1.0, 1.0, 0.0, 0.0];
        let r = recall_at_k(&logits, &labels, 4, 2);
        assert!((r - 0.75).abs() < 1e-12, "{r}");
    }

    fn tiny_bank() -> RouterBank {
        // d=2, L=2, G=3: attention logits = bias only for token 0 (whose
        // embedding is all-zero), token-dependent for the rest.
        let tok_emb = vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0]; // V=3
        let attn_w = vec![
            // layer 0: dim0 -> group0, dim1 -> group2
            5.0, 0.0, 0.0, 0.0, 0.0, 5.0,
            // layer 1: dim0 -> group1, dim1 -> group1
            0.0, 5.0, 0.0, 0.0, 5.0, 0.0,
        ];
        let attn_b = vec![0.0, 0.1, 0.2, 0.2, 0.1, 0.0];
        RouterBank::new(2, 2, 3, 4, 2, tok_emb, vec![], attn_w, attn_b, None).unwrap()
    }

    #[test]
    fn route_step_selects_per_request_and_unions_per_layer() {
        let bank = tiny_bank();
        let policy = RoutingPolicy { head_k: 1, ..Default::default() };
        let r = bank
            .route_step(&[1, 2], &[4, 4], None, &policy)
            .unwrap();
        assert_eq!(r.head_idx.shape(), &[2, 2, 1]);
        let idx = r.head_idx.as_i32().unwrap();
        // layer 0: token 1 -> group 0, token 2 -> group 2 (union 2/3)
        assert_eq!(&idx[..2], &[0, 2]);
        // layer 1: both tokens -> group 1 (union 1/3)
        assert_eq!(&idx[2..], &[1, 1]);
        assert!((r.head_union[0] - 2.0 / 3.0).abs() < 1e-12);
        assert!((r.head_union[1] - 1.0 / 3.0).abs() < 1e-12);
        assert!((r.head_density() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.head_counts, vec![1, 0, 1, 0, 2, 0]);
        assert!(r.mlp_idx.is_none());
    }

    #[test]
    fn masked_slots_get_placeholders_and_skip_telemetry() {
        let bank = tiny_bank();
        let policy = RoutingPolicy { head_k: 1, ..Default::default() };
        let r = bank
            .route_step(&[1, 2], &[4, 4], Some(&[true, false]), &policy)
            .unwrap();
        let idx = r.head_idx.as_i32().unwrap();
        // live token 1 -> group 0; masked slot -> placeholder 0..k
        assert_eq!(&idx[..2], &[0, 0]);
        // only the live slot counts toward union + histogram
        assert!((r.head_union[0] - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(&r.head_counts[..3], &[1, 0, 0]);
        // mask length must match the batch
        assert!(bank
            .route_step(&[1, 2], &[4, 4], Some(&[true]), &policy)
            .is_err());
    }

    #[test]
    fn masked_slots_do_not_inflate_mlp_union() {
        let bank = mlp_bank();
        let policy = RoutingPolicy { head_k: 1, mlp_req_k: vec![2, 2], mlp_cap: 4 };
        let both = bank.route_step(&[1, 2], &[4, 4], None, &policy).unwrap();
        assert_eq!(both.mlp_union, vec![1.0, 1.0]);
        let one = bank
            .route_step(&[1, 2], &[4, 4], Some(&[true, false]), &policy)
            .unwrap();
        // the masked slot's neurons must not join the union...
        assert_eq!(one.mlp_union, vec![0.5, 0.5]);
        // ...nor outrank live neurons in the capacity-fitted index set
        let row = &one.mlp_idx.as_ref().unwrap().as_i32().unwrap()[..4];
        assert!(row.contains(&0) && row.contains(&1), "{row:?}");
    }

    #[test]
    fn route_step_head_k_extremes() {
        let bank = tiny_bank();
        // k = n_groups: every group selected, union density exactly 1
        let all = RoutingPolicy { head_k: 3, ..Default::default() };
        let r = bank.route_step(&[1, 2], &[4, 4], None, &all).unwrap();
        assert_eq!(r.head_idx.shape(), &[2, 2, 3]);
        assert_eq!(r.head_union, vec![1.0, 1.0]);
        // k = 0 clamps to 1 (an empty head set cannot attend at all)
        let zero = RoutingPolicy { head_k: 0, ..Default::default() };
        let r = bank.route_step(&[1, 2], &[4, 4], None, &zero).unwrap();
        assert_eq!(r.head_k, 1);
    }

    fn mlp_bank() -> RouterBank {
        // d=2, rh=2 identity bottleneck, d_ff=4: token 1 scores neurons
        // {0,1}, token 2 scores neurons {2,3}.
        let tok_emb = vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0];
        let attn_w = vec![0.0; 2 * 2 * 1];
        let attn_b = vec![0.0; 2];
        let w1 = vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 1.0]; // [L=2,d=2,rh=2]
        let b1 = vec![0.0; 4];
        let w2 = vec![
            // layer 0: hidden0 -> neurons {0,1}, hidden1 -> neurons {2,3}
            4.0, 3.0, 0.0, 0.0, 0.0, 0.0, 4.0, 3.0,
            // layer 1: same
            4.0, 3.0, 0.0, 0.0, 0.0, 0.0, 4.0, 3.0,
        ];
        let b2 = vec![0.0; 8];
        RouterBank::new(
            2, 2, 1, 4, 1, tok_emb, vec![], attn_w, attn_b,
            Some(RouterBank::mlp_router(2, w1, b1, w2, b2)),
        )
        .unwrap()
    }

    #[test]
    fn mlp_union_grows_with_distinct_requests() {
        let bank = mlp_bank();
        let policy = RoutingPolicy { head_k: 1, mlp_req_k: vec![2, 2], mlp_cap: 4 };
        let one = bank.route_step(&[1], &[4], None, &policy).unwrap();
        assert_eq!(one.mlp_union, vec![0.5, 0.5]);
        let two = bank.route_step(&[1, 2], &[4, 4], None, &policy).unwrap();
        assert_eq!(two.mlp_union, vec![1.0, 1.0]);
        // identical requests do not inflate the union
        let same = bank.route_step(&[1, 1], &[4, 4], None, &policy).unwrap();
        assert_eq!(same.mlp_union, vec![0.5, 0.5]);
    }

    #[test]
    fn mlp_idx_fits_capacity_without_duplicates() {
        let bank = mlp_bank();
        // union is 4 neurons but the capacity is 3: keep the 3 best by
        // batch-max logit (4.0-weight neurons 0 and 2 first, then one 3.0)
        let policy = RoutingPolicy { head_k: 1, mlp_req_k: vec![2, 2], mlp_cap: 3 };
        let r = bank.route_step(&[1, 2], &[4, 4], None, &policy).unwrap();
        let t = r.mlp_idx.as_ref().unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        for l in 0..2 {
            let row = &t.as_i32().unwrap()[l * 3..(l + 1) * 3];
            let mut sorted = row.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 3, "duplicate neuron in {row:?}");
            assert!(row.contains(&0) && row.contains(&2), "{row:?}");
        }
        // true (pre-fit) union density is still reported
        assert_eq!(r.mlp_union, vec![1.0, 1.0]);
        // capacity above the union pads with distinct next-best neurons
        let wide = RoutingPolicy { head_k: 1, mlp_req_k: vec![1, 1], mlp_cap: 4 };
        let r = bank.route_step(&[1], &[4], None, &wide).unwrap();
        let row = r.mlp_idx.as_ref().unwrap().as_i32().unwrap()[..4].to_vec();
        let mut sorted = row.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3], "{row:?}");
    }

    #[test]
    fn policy_from_entry_reads_index_shapes() {
        use crate::substrate::json::Json;
        let spec = EntrySpec {
            name: "decode_polar_d0500_b2_n64".into(),
            kind: "decode".into(),
            file: "x".into(),
            data: vec![
                crate::runtime::TensorSpec {
                    name: "tokens".into(), shape: vec![2], dtype: crate::runtime::Dtype::I32 },
                crate::runtime::TensorSpec {
                    name: "head_idx".into(), shape: vec![4, 2, 3],
                    dtype: crate::runtime::Dtype::I32 },
                crate::runtime::TensorSpec {
                    name: "mlp_idx".into(), shape: vec![4, 48],
                    dtype: crate::runtime::Dtype::I32 },
            ],
            outputs: vec![],
            meta: Json::parse(r#"{"mlp_topk": [16, 24, 24, 16]}"#).unwrap(),
        };
        let p = RoutingPolicy::from_entry(&spec).unwrap();
        assert_eq!(p.head_k, 3);
        assert_eq!(p.mlp_cap, 48);
        assert_eq!(p.mlp_req_k, vec![16, 24, 24, 16]);
        // entries without index inputs are legacy (in-graph routing)
        let legacy = EntrySpec { data: vec![], ..spec };
        assert!(RoutingPolicy::from_entry(&legacy).is_none());
    }
}
