//! Artifact manifest (written by python/compile/aot.py).
//!
//! The manifest pins the whole rust<->HLO calling convention: for every
//! entry, inputs are the listed data tensors (in order) followed by the
//! full weight set sorted by name; outputs are a result tuple in the
//! listed order.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::substrate::json::Json;
use super::tensor::Dtype;

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.get("name").as_str().context("spec name")?.to_string(),
            shape: j
                .get("shape")
                .as_arr()
                .context("spec shape")?
                .iter()
                .map(|v| v.as_usize().context("shape dim"))
                .collect::<Result<_>>()?,
            dtype: Dtype::parse(j.get("dtype").as_str().unwrap_or("f32"))?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub kind: String,
    pub file: String,
    pub data: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl EntrySpec {
    pub fn batch(&self) -> usize {
        self.meta.get("batch").as_usize().unwrap_or(0)
    }
    pub fn seq_bucket(&self) -> usize {
        self.meta.get("seq_bucket").as_usize().unwrap_or(0)
    }
    pub fn mode(&self) -> &str {
        self.meta.get("mode").as_str().unwrap_or("")
    }
    pub fn density(&self) -> f64 {
        self.meta.get("density").as_f64().unwrap_or(1.0)
    }
}

/// Model geometry (mirror of python ModelConfig, from the manifest).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub analogue: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub d_head: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub mlp: String,
    pub pos: String,
    pub critical_density: f64,
}

impl ModelConfig {
    pub fn n_groups(&self) -> usize {
        self.n_kv_heads
    }
    pub fn q_per_group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }
    /// Elements in one KV cache tensor [L,2,B,G,N,dh].
    pub fn kv_elems(&self, batch: usize, n: usize) -> usize {
        self.n_layers * 2 * batch * self.n_kv_heads * n * self.d_head
    }
    pub fn kv_shape(&self, batch: usize, n: usize) -> Vec<usize> {
        vec![self.n_layers, 2, batch, self.n_kv_heads, n, self.d_head]
    }
    /// Shape of the paged KV pool [L,2,P,G,bs,dh] (P physical blocks of
    /// bs positions; block 0 is the reserved null block).
    pub fn kv_pool_shape(&self, pool_blocks: usize, block: usize) -> Vec<usize> {
        vec![self.n_layers, 2, pool_blocks, self.n_kv_heads, block, self.d_head]
    }
    /// Elements in one physical block's (layer, k/v) row [G,bs,dh].
    pub fn kv_block_row_elems(&self, block: usize) -> usize {
        self.n_kv_heads * block * self.d_head
    }
    /// Elements one physical block occupies across all layers and k/v.
    pub fn kv_block_elems(&self, block: usize) -> usize {
        self.n_layers * 2 * self.kv_block_row_elems(block)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: String,
    pub config: ModelConfig,
    pub params: Vec<TensorSpec>,
    pub batch_buckets: Vec<usize>,
    pub seq_buckets: Vec<usize>,
    /// Chunked-prefill token width: each `prefill_b{B}_s{S}` call appends
    /// up to this many prompt tokens per slot at a position offset.
    pub prefill_chunk: usize,
    /// Paged-KV geometry of the `*_paged_fused` entries: token positions per
    /// physical block, and total pool blocks (incl. the reserved null
    /// block 0). The pool tensor is [L,2,kv_pool_blocks,G,kv_block,dh].
    pub kv_block: usize,
    pub kv_pool_blocks: usize,
    /// Pair width of the `copy_blocks` entry (on-device COW).
    pub copy_pairs: usize,
    pub entries: BTreeMap<String, EntrySpec>,
}

impl Manifest {
    pub fn load(model_dir: &Path) -> Result<Manifest> {
        let path = model_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let c = j.get("config");
        let geta = |k: &str| -> Result<usize> {
            c.get(k).as_usize().with_context(|| format!("config.{k}"))
        };
        let config = ModelConfig {
            name: j.get("model").as_str().unwrap_or("").to_string(),
            analogue: j.get("analogue").as_str().unwrap_or("").to_string(),
            d_model: geta("d_model")?,
            n_layers: geta("n_layers")?,
            n_heads: geta("n_heads")?,
            n_kv_heads: geta("n_kv_heads")?,
            d_ff: geta("d_ff")?,
            d_head: geta("d_head")?,
            vocab: geta("vocab")?,
            max_seq: geta("max_seq")?,
            mlp: c.get("mlp").as_str().unwrap_or("relu").to_string(),
            pos: c.get("pos").as_str().unwrap_or("learned").to_string(),
            critical_density: c.get("critical_density").as_f64().unwrap_or(0.5),
        };

        let params = j
            .get("params")
            .as_arr()
            .context("params")?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;

        let buckets = j.get("buckets");
        let to_usize_vec = |v: &Json| -> Vec<usize> {
            v.as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default()
        };

        let mut entries = BTreeMap::new();
        for e in j.get("entries").as_arr().context("entries")?.iter() {
            let spec = EntrySpec {
                name: e.get("name").as_str().context("entry name")?.to_string(),
                kind: e.get("kind").as_str().unwrap_or("").to_string(),
                file: e.get("file").as_str().context("entry file")?.to_string(),
                data: e
                    .get("data")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: e
                    .get("outputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                meta: e.get("meta").clone(),
            };
            entries.insert(spec.name.clone(), spec);
        }

        let batch_buckets = to_usize_vec(buckets.get("batch"));
        let seq_buckets = to_usize_vec(buckets.get("seq"));
        // legacy manifests (pre-paging) carry no pool geometry: derive the
        // same defaults aot.py would emit, so the paged entry NAMES still
        // resolve predictably (loading them simply fails with "no entry"
        // until the artifact is rebuilt).
        let kv_block = buckets.get("kv_block").as_usize().unwrap_or(16);
        let kv_pool_blocks = buckets.get("kv_pool_blocks").as_usize().unwrap_or_else(|| {
            let b = batch_buckets.last().copied().unwrap_or(1);
            let s = seq_buckets.last().copied().unwrap_or(kv_block);
            1 + b * s / kv_block.max(1)
        });
        Ok(Manifest {
            dir: model_dir.to_path_buf(),
            model: j.get("model").as_str().unwrap_or("").to_string(),
            config,
            params,
            batch_buckets,
            seq_buckets,
            // "prefill" is the legacy name for the same width (the old
            // monolithic prompt bucket), kept as a parse fallback
            prefill_chunk: buckets
                .get("prefill_chunk")
                .as_usize()
                .or_else(|| buckets.get("prefill").as_usize())
                .unwrap_or(64),
            kv_block,
            kv_pool_blocks,
            copy_pairs: buckets.get("copy_pairs").as_usize().unwrap_or(8),
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("no entry {name:?} in manifest for {}", self.model))
    }

    pub fn hlo_path(&self, entry: &EntrySpec) -> PathBuf {
        self.dir.join(&entry.file)
    }

    pub fn decode_entry_name(&self, tag: &str, batch: usize, n: usize) -> String {
        format!("decode_{tag}_b{batch}_n{n}")
    }

    /// Chunked-prefill entry for a (batch, seq) bucket pair: appends one
    /// chunk (up to [`Manifest::prefill_chunk`] tokens per slot) into a
    /// `[.., n, ..]` cache at a per-slot position offset.
    pub fn prefill_entry_name(&self, batch: usize, n: usize) -> String {
        format!("prefill_b{batch}_s{n}")
    }

    /// Fused paged decode entry: the graph indexes the block table itself
    /// and writes only the new KV row into the resident pool — no dense
    /// intermediate, no gather/scatter shell.
    pub fn fused_decode_entry_name(&self, tag: &str, batch: usize, n: usize) -> String {
        format!("decode_{tag}_b{batch}_n{n}_paged_fused")
    }

    /// Whether the manifest carries an entry by this name.
    pub fn has_entry(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// Fused paged chunked-prefill entry: resolves prior-context KV tile
    /// addresses through the block table inside the kernel and writes the
    /// chunk's new K/V rows directly into their pool blocks at per-slot
    /// offsets.
    pub fn fused_prefill_entry_name(&self, batch: usize, n: usize) -> String {
        format!("prefill_b{batch}_s{n}_paged_fused")
    }

    /// On-device COW entry: copies up to `buckets.copy_pairs` (src, dst)
    /// block pairs inside the resident pool in one call. Pairs are padded
    /// with (0, 0) — the null block copied onto itself.
    pub fn copy_blocks_entry_name(&self) -> String {
        "copy_blocks".to_string()
    }

    /// TP shard attention entry over a per-shard pool slice. `tag` is
    /// "dense", "sha_dXXXX" (localized head_idx) or "kvw" (KV-write-only —
    /// the dispatch a routing-skipped shard still runs).
    pub fn tp_attn_entry_name(
        &self,
        n_shards: usize,
        shard: usize,
        tag: &str,
        batch: usize,
        n: usize,
    ) -> String {
        format!("tp{n_shards}_attn_s{shard}_{tag}_b{batch}_n{n}_paged_fused")
    }

    /// Biasless TP MLP shard entry. `tag` is "dense" or "k{Kms}" (localized
    /// union indices, sentinel = d_ff/n_shards).
    pub fn tp_mlp_entry_name(
        &self,
        n_shards: usize,
        shard: usize,
        tag: &str,
        batch: usize,
    ) -> String {
        format!("tp{n_shards}_mlp_s{shard}_{tag}_b{batch}")
    }

    /// Per-layer on-device all-reduce entry (`op` = "attn" | "mlp"):
    /// residual + Σ shard partials + the output bias the biasless shard
    /// entries dropped.
    pub fn tp_reduce_entry_name(&self, n_shards: usize, op: &str, batch: usize) -> String {
        format!("tp{n_shards}_{op}_reduce_b{batch}")
    }

    pub fn tp_embed_entry_name(&self, n_shards: usize, batch: usize) -> String {
        format!("tp{n_shards}_embed_b{batch}")
    }

    pub fn tp_final_entry_name(&self, n_shards: usize, batch: usize) -> String {
        format!("tp{n_shards}_final_b{batch}")
    }

    /// Pipeline stage entry over a per-stage pool slice (`stage` 0 embeds
    /// tokens and runs layers [0, L/2); stage 1 finishes and projects).
    pub fn pp_stage_entry_name(
        &self,
        stage: usize,
        tag: &str,
        batch: usize,
        n: usize,
    ) -> String {
        format!("pp2_stage{stage}_{tag}_b{batch}_n{n}_paged_fused")
    }

    /// Smallest batch bucket >= need (error if need exceeds the largest).
    pub fn batch_bucket(&self, need: usize) -> Result<usize> {
        self.batch_buckets
            .iter()
            .copied()
            .find(|&b| b >= need)
            .with_context(|| format!("no batch bucket >= {need}"))
    }

    /// Smallest seq bucket >= need.
    pub fn seq_bucket(&self, need: usize) -> Result<usize> {
        self.seq_buckets
            .iter()
            .copied()
            .find(|&n| n >= need)
            .with_context(|| format!("no seq bucket >= {need}"))
    }

    /// Mode tag for a decode entry ("dense", "dejavu", "polar_d0500", ...).
    pub fn mode_tag(mode: &str, density: f64) -> String {
        if mode == "dense" || mode == "dejavu" {
            mode.to_string()
        } else {
            format!("{mode}_d{:04}", (density * 1000.0).round() as usize)
        }
    }

    pub fn entry_names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_tags() {
        assert_eq!(Manifest::mode_tag("dense", 1.0), "dense");
        assert_eq!(Manifest::mode_tag("dejavu", 0.5), "dejavu");
        assert_eq!(Manifest::mode_tag("polar", 0.5), "polar_d0500");
        assert_eq!(Manifest::mode_tag("polar", 0.625), "polar_d0625");
        assert_eq!(Manifest::mode_tag("teal", 0.25), "teal_d0250");
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("ps_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "model": "m", "analogue": "x",
          "config": {"d_model": 8, "n_layers": 2, "n_heads": 2, "n_kv_heads": 2,
                     "d_ff": 16, "d_head": 4, "vocab": 10, "max_seq": 32,
                     "mlp": "relu", "pos": "learned", "critical_density": 0.5},
          "params": [{"name": "w", "shape": [2, 8], "dtype": "float32"}],
          "buckets": {"batch": [1, 2, 4], "seq": [16, 32], "prefill_chunk": 16},
          "entries": [{"name": "decode_dense_b1_n16", "kind": "decode",
            "file": "hlo/decode_dense_b1_n16.hlo.txt",
            "data": [{"name": "tokens", "shape": [1], "dtype": "i32"}],
            "outputs": [{"name": "logits", "shape": [1, 10], "dtype": "f32"}],
            "meta": {"batch": 1, "seq_bucket": 16, "mode": "dense", "density": 1.0}}]
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.kv_shape(1, 16), vec![2, 2, 1, 2, 16, 4]);
        assert_eq!(m.prefill_chunk, 16);
        assert_eq!(m.prefill_entry_name(2, 32), "prefill_b2_s32");
        assert_eq!(m.fused_prefill_entry_name(2, 32), "prefill_b2_s32_paged_fused");
        assert_eq!(m.copy_blocks_entry_name(), "copy_blocks");
        assert_eq!(m.copy_pairs, 8);
        assert_eq!(
            m.fused_decode_entry_name("polar_d0500", 2, 32),
            "decode_polar_d0500_b2_n32_paged_fused"
        );
        assert_eq!(
            m.tp_attn_entry_name(2, 1, "sha_d0250", 4, 256),
            "tp2_attn_s1_sha_d0250_b4_n256_paged_fused"
        );
        assert_eq!(m.tp_attn_entry_name(4, 0, "kvw", 1, 256),
                   "tp4_attn_s0_kvw_b1_n256_paged_fused");
        assert_eq!(m.tp_mlp_entry_name(2, 1, "k96", 16), "tp2_mlp_s1_k96_b16");
        assert_eq!(m.tp_reduce_entry_name(2, "attn", 4), "tp2_attn_reduce_b4");
        assert_eq!(m.tp_embed_entry_name(2, 4), "tp2_embed_b4");
        assert_eq!(m.tp_final_entry_name(4, 1), "tp4_final_b1");
        assert_eq!(
            m.pp_stage_entry_name(1, "polar_d0250", 4, 256),
            "pp2_stage1_polar_d0250_b4_n256_paged_fused"
        );
        assert!(m.has_entry("decode_dense_b1_n16"));
        assert!(!m.has_entry("decode_dense_b1_n16_paged_fused"));
        // legacy manifest (no kv_* buckets): defaults derived from the
        // bucket ladder — block 16, pool 1 + 4 * 32 / 16
        assert_eq!(m.kv_block, 16);
        assert_eq!(m.kv_pool_blocks, 9);
        assert_eq!(m.config.kv_pool_shape(9, 16), vec![2, 2, 9, 2, 16, 4]);
        assert_eq!(m.config.kv_block_elems(16), 2 * 2 * 2 * 16 * 4);
        assert_eq!(m.batch_bucket(3).unwrap(), 4);
        assert!(m.batch_bucket(5).is_err());
        assert_eq!(m.seq_bucket(17).unwrap(), 32);
        let e = m.entry("decode_dense_b1_n16").unwrap();
        assert_eq!(e.batch(), 1);
        assert_eq!(e.mode(), "dense");
        assert_eq!(e.data[0].dtype, Dtype::I32);
    }
}
