//! Host tensors + conversions to/from `xla::Literal`.
//!
//! The coordinator does all of its KV-cache surgery (slot splicing, bucket
//! promotion, batch regrouping) on these host buffers; literals are built
//! right before `execute`.

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" | "float32" => Ok(Dtype::F32),
            "i32" | "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn element_type(self) -> xla::ElementType {
        match self {
            Dtype::F32 => xla::ElementType::F32,
            Dtype::I32 => xla::ElementType::S32,
        }
    }
}

/// Dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Result<Tensor> {
        if data.len() != numel(&shape) {
            bail!("f32 tensor: {} elements vs shape {:?}", data.len(), shape);
        }
        Ok(Tensor::F32 { data, shape })
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Result<Tensor> {
        if data.len() != numel(&shape) {
            bail!("i32 tensor: {} elements vs shape {:?}", data.len(), shape);
        }
        Ok(Tensor::I32 { data, shape })
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        Tensor::F32 { data: vec![0.0; numel(&shape)], shape }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Tensor::F32 { .. } => Dtype::F32,
            Tensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn len(&self) -> usize {
        numel(self.shape())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let (bytes, ty, shape): (&[u8], _, _) = match self {
            Tensor::F32 { data, shape } => (
                unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                },
                xla::ElementType::F32,
                shape,
            ),
            Tensor::I32 { data, shape } => (
                unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                },
                xla::ElementType::S32,
                shape,
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, shape, bytes)
            .context("literal from tensor")
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Tensor::f32(lit.to_vec::<f32>()?, dims),
            xla::ElementType::S32 => Tensor::i32(lit.to_vec::<i32>()?, dims),
            other => bail!("unsupported literal dtype {other:?}"),
        }
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let shape = self.shape();
        let mut s = vec![1; shape.len()];
        for i in (0..shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * shape[i + 1];
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::f32(vec![0.0; 6], vec![2, 3]).is_ok());
        assert!(Tensor::f32(vec![0.0; 5], vec![2, 3]).is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32((0..24).map(|i| i as f32).collect(), vec![2, 3, 4]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::i32(vec![5, -2, 7], vec![3]).unwrap();
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros_f32(vec![2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }
}
