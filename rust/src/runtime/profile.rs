//! Per-step decode cost breakdown: where a serving step's wall time and
//! host<->device traffic go. Filled by the [`Executor`](super::Executor)
//! (transfers + compute), by the scheduler (host-side KV surgery), and by
//! the mock engine (analytic byte accounting), then surfaced through
//! `bench decode-breakdown` / `BENCH_decode.json` and the server's stats
//! command. All counters are cumulative since the last reset.

use crate::substrate::json::Json;

#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct StepProfile {
    /// Host -> device payload bytes (data inputs; weights are uploaded
    /// once at load and never counted here).
    pub h2d_bytes: u64,
    /// Device -> host payload bytes (fetched outputs).
    pub d2h_bytes: u64,
    pub h2d_ns: u64,
    pub compute_ns: u64,
    pub d2h_ns: u64,
    /// Host-side KV surgery (slot copies, bucket promotion, regroup).
    pub host_surgery_ns: u64,
    /// Host-side router execution (per-step head/MLP top-k + union) —
    /// the overhead the runtime pays to produce `head_idx`/`mlp_idx`.
    pub router_ns: u64,
    /// Wall time spent inside chunked-prefill calls (the prefill share of
    /// a serving step; `compute_ns` et al. cover all entry executions, so
    /// decode-side cost is the remainder).
    pub prefill_ns: u64,
    /// Chunked-prefill calls the counters cover.
    pub prefill_chunks: u64,
    /// Decode steps the counters cover (for per-step averages).
    pub decode_steps: u64,
    /// Decode-side bytes moved assembling dense KV views from the block
    /// pool (the gather shell of a shell-path paged call). The fused
    /// entries index the pool in place: the default path reports 0 here,
    /// and `bench decode-breakdown` gates on that.
    pub gather_bytes: u64,
    /// Decode-side bytes moved writing dense KV views back through the
    /// block table (the scatter shell). Fused entries write only the new
    /// row in place and report 0 here.
    pub scatter_bytes: u64,
    /// Prefill-side gather-shell bytes (dense view assembly before a
    /// chunked-prefill call). Zero on the fused prefill path.
    pub prefill_gather_bytes: u64,
    /// Prefill-side scatter-shell bytes (dense view write-back after a
    /// chunked-prefill call). Zero on the fused prefill path.
    pub prefill_scatter_bytes: u64,
    /// Bytes copied between pool blocks by on-device COW (`copy_blocks`
    /// calls). This is device-local traffic, not host<->device — counted
    /// separately so COW cost stays visible once the shells are gone.
    pub cow_bytes: u64,
    /// Bytes the per-layer reduce entries consume combining shard partials
    /// (n_shards x B x d x 4 per reduce call). Device-local like
    /// `cow_bytes`: the partials stay device buffers, nothing crosses the
    /// host boundary on the reduce.
    pub allreduce_bytes: u64,
    /// (layer, shard) pairs that ran a full compute dispatch (dense/SHA
    /// attention, MLP shard) on sharded steps.
    pub shards_dispatched: u64,
    /// (layer, shard) pairs routing let us skip: the shard ran only the
    /// KV-write entry (attention) or nothing at all (MLP with no union
    /// neuron in the shard's range) and contributed a zero partial.
    pub shards_skipped: u64,
}

impl StepProfile {
    pub fn merge(&mut self, o: &StepProfile) {
        self.h2d_bytes += o.h2d_bytes;
        self.d2h_bytes += o.d2h_bytes;
        self.h2d_ns += o.h2d_ns;
        self.compute_ns += o.compute_ns;
        self.d2h_ns += o.d2h_ns;
        self.host_surgery_ns += o.host_surgery_ns;
        self.router_ns += o.router_ns;
        self.prefill_ns += o.prefill_ns;
        self.prefill_chunks += o.prefill_chunks;
        self.decode_steps += o.decode_steps;
        self.gather_bytes += o.gather_bytes;
        self.scatter_bytes += o.scatter_bytes;
        self.prefill_gather_bytes += o.prefill_gather_bytes;
        self.prefill_scatter_bytes += o.prefill_scatter_bytes;
        self.cow_bytes += o.cow_bytes;
        self.allreduce_bytes += o.allreduce_bytes;
        self.shards_dispatched += o.shards_dispatched;
        self.shards_skipped += o.shards_skipped;
    }

    /// Total bytes crossing the host<->device boundary.
    pub fn host_copy_bytes(&self) -> u64 {
        self.h2d_bytes + self.d2h_bytes
    }

    fn per_step(&self, v: u64) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            v as f64 / self.decode_steps as f64
        }
    }

    /// Per-step averages (bytes, milliseconds) for reports. The counters
    /// are cumulative since the last reset, so on a mixed serving run the
    /// averages amortize prefill/composition traffic over decode steps;
    /// `bench decode-breakdown` isolates pure decode cost by resetting
    /// the profile after prefill.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("decode_steps", (self.decode_steps as usize).into()),
            ("h2d_bytes_per_step", self.per_step(self.h2d_bytes).into()),
            ("d2h_bytes_per_step", self.per_step(self.d2h_bytes).into()),
            (
                "host_copy_bytes_per_step",
                self.per_step(self.host_copy_bytes()).into(),
            ),
            ("gather_bytes", (self.gather_bytes as usize).into()),
            ("scatter_bytes", (self.scatter_bytes as usize).into()),
            ("gather_bytes_per_step", self.per_step(self.gather_bytes).into()),
            (
                "scatter_bytes_per_step",
                self.per_step(self.scatter_bytes).into(),
            ),
            (
                "prefill_gather_bytes",
                (self.prefill_gather_bytes as usize).into(),
            ),
            (
                "prefill_scatter_bytes",
                (self.prefill_scatter_bytes as usize).into(),
            ),
            ("cow_bytes", (self.cow_bytes as usize).into()),
            ("allreduce_bytes", (self.allreduce_bytes as usize).into()),
            ("shards_dispatched", (self.shards_dispatched as usize).into()),
            ("shards_skipped", (self.shards_skipped as usize).into()),
            ("h2d_ms", (self.h2d_ns as f64 * 1e-6).into()),
            ("compute_ms", (self.compute_ns as f64 * 1e-6).into()),
            ("d2h_ms", (self.d2h_ns as f64 * 1e-6).into()),
            ("host_surgery_ms", (self.host_surgery_ns as f64 * 1e-6).into()),
            ("router_ms", (self.router_ns as f64 * 1e-6).into()),
            ("prefill_ms", (self.prefill_ns as f64 * 1e-6).into()),
            ("prefill_chunks", (self.prefill_chunks as usize).into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_and_per_step() {
        let mut a = StepProfile { h2d_bytes: 10, d2h_bytes: 30, decode_steps: 2, ..Default::default() };
        let b = StepProfile {
            h2d_bytes: 10,
            compute_ns: 500,
            router_ns: 3_000_000,
            prefill_ns: 4_000_000,
            prefill_chunks: 3,
            decode_steps: 2,
            gather_bytes: 100,
            scatter_bytes: 60,
            prefill_gather_bytes: 40,
            prefill_scatter_bytes: 20,
            cow_bytes: 2048,
            allreduce_bytes: 512,
            shards_dispatched: 6,
            shards_skipped: 2,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.host_copy_bytes(), 50);
        assert_eq!(a.decode_steps, 4);
        assert_eq!(a.router_ns, 3_000_000);
        assert_eq!(a.prefill_chunks, 3);
        assert_eq!(a.gather_bytes, 100);
        assert_eq!(a.scatter_bytes, 60);
        assert_eq!(a.prefill_gather_bytes, 40);
        assert_eq!(a.prefill_scatter_bytes, 20);
        assert_eq!(a.cow_bytes, 2048);
        assert_eq!(a.allreduce_bytes, 512);
        assert_eq!(a.shards_dispatched, 6);
        assert_eq!(a.shards_skipped, 2);
        let j = a.to_json();
        assert_eq!(j.get("allreduce_bytes").as_usize(), Some(512));
        assert_eq!(j.get("shards_dispatched").as_usize(), Some(6));
        assert_eq!(j.get("shards_skipped").as_usize(), Some(2));
        assert_eq!(j.get("prefill_gather_bytes").as_usize(), Some(40));
        assert_eq!(j.get("prefill_scatter_bytes").as_usize(), Some(20));
        assert_eq!(j.get("cow_bytes").as_usize(), Some(2048));
        assert_eq!(j.get("h2d_bytes_per_step").as_f64(), Some(5.0));
        assert_eq!(j.get("host_copy_bytes_per_step").as_f64(), Some(12.5));
        assert_eq!(j.get("gather_bytes").as_usize(), Some(100));
        assert_eq!(j.get("gather_bytes_per_step").as_f64(), Some(25.0));
        assert_eq!(j.get("scatter_bytes_per_step").as_f64(), Some(15.0));
        assert_eq!(j.get("router_ms").as_f64(), Some(3.0));
        assert_eq!(j.get("prefill_ms").as_f64(), Some(4.0));
        assert_eq!(j.get("prefill_chunks").as_usize(), Some(3));
    }

    #[test]
    fn zero_steps_has_no_nan() {
        let p = StepProfile::default();
        assert_eq!(p.to_json().get("h2d_bytes_per_step").as_f64(), Some(0.0));
    }
}
