//! Shard-aware zero-shell serving: route-then-dispatch planning plus the
//! paged TP/PP step drivers (Figs 11, 12).
//!
//! The [`SparsityController`](crate::coordinator) plans a step's routing
//! FIRST; [`plan_shard_dispatch`] then turns the decision into per-shard
//! work: a TP shard whose head groups are all unselected for a layer runs
//! only the cheap KV-write entry (`kvw`) and contributes a zero partial to
//! the reduce — KV must be written every step even where attention is
//! skipped, or the cache corrupts for future steps. Layer 0 always stays
//! dense (paper §3.2). MLP shards owning no batch-union neuron are skipped
//! outright (the selective GEMM of an empty row set is exactly zero).
//!
//! Data movement discipline (the "zero-shell" part): each shard owns a
//! resident pool slice `[L,2,P,Gs,bs,dh]` addressed by the SAME block
//! tables; the activation and every shard partial stay device buffers and
//! the per-layer `tp{S}_{attn,mlp}_reduce` entries sum them on-device —
//! accounted as `allreduce_bytes` (device-local, like `cow_bytes`), with
//! no per-layer f32 host loop and no gather/scatter shells anywhere.

use anyhow::{bail, Context, Result};

use super::engine::{BlockTables, Engine, KvStore, PagedKv};
use super::executor::DeviceInput;
use super::manifest::Manifest;
use super::router::{RoutingPolicy, StepRouting};
use super::tensor::Tensor;

// ---------------------------------------------------------------------------
// dispatch plan
// ---------------------------------------------------------------------------

/// What one TP shard runs for one layer's attention.
#[derive(Debug, Clone, PartialEq)]
pub enum AttnDispatch {
    /// Full dense attention over all the shard's local groups.
    Dense,
    /// SHA entry with localized per-request group ids, row-major `[B, Ks]`
    /// (sentinel `Gs` marks unselected slots — exact zero rows in-graph).
    Sha(Vec<i32>),
    /// No live slot selected any of this shard's groups: run only the
    /// KV-write entry and contribute a zero partial to the reduce.
    KvWrite,
}

/// What one TP shard runs for one layer's MLP.
#[derive(Debug, Clone, PartialEq)]
pub enum MlpDispatch {
    Dense,
    /// Localized union neuron ids `[Kms]`, sentinel-`Ds` padded.
    Sparse(Vec<i32>),
    /// No union neuron lands in this shard's range: zero partial, no call.
    Skip,
}

#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    pub attn: Vec<AttnDispatch>,
    pub mlp: Vec<MlpDispatch>,
}

/// One step's per-(layer, shard) dispatch decisions.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardDispatch {
    pub n_shards: usize,
    pub layers: Vec<LayerPlan>,
}

impl ShardDispatch {
    /// (layer, shard) pairs running a full compute dispatch.
    pub fn dispatched(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| {
                l.attn.iter().filter(|d| !matches!(d, AttnDispatch::KvWrite)).count()
                    + l.mlp.iter().filter(|d| !matches!(d, MlpDispatch::Skip)).count()
            })
            .sum::<usize>() as u64
    }

    /// (layer, shard) pairs routing let us skip (kvw-only attention or a
    /// skipped MLP shard).
    pub fn skipped(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| {
                l.attn.iter().filter(|d| matches!(d, AttnDispatch::KvWrite)).count()
                    + l.mlp.iter().filter(|d| matches!(d, MlpDispatch::Skip)).count()
            })
            .sum::<usize>() as u64
    }
}

/// Geometry + mode inputs of [`plan_shard_dispatch`].
#[derive(Debug, Clone)]
pub struct ShardPlanSpec {
    pub n_shards: usize,
    pub n_layers: usize,
    pub n_groups: usize,
    pub d_ff: usize,
    pub batch: usize,
    /// SHA-dispatch attention from the routing decision (false = dense
    /// attention entries on every shard regardless of routing).
    pub route_attn: bool,
    /// Per-shard `mlp_idx` width of the artifact's k-entries
    /// ([`mlp_shard_k`]); 0 = dense MLP shards.
    pub mlp_ks: usize,
}

/// Turn a step's routing decision into per-(layer, shard) dispatches.
///
/// Routing `None` (or `route_attn: false`) plans dense attention
/// everywhere. With routing, layer 0 stays dense (§3.2); for l > 0 a
/// shard gets a [`AttnDispatch::Sha`] row set localized to its group
/// range iff some LIVE slot (per `routing.active` — masked slots carry
/// placeholder indices that must not force a dispatch) selected one of
/// its groups, else [`AttnDispatch::KvWrite`]. Sparse MLP partitions each
/// layer's union row by shard range.
pub fn plan_shard_dispatch(
    spec: &ShardPlanSpec,
    routing: Option<&StepRouting>,
) -> Result<ShardDispatch> {
    let s = spec.n_shards;
    if s == 0 || spec.n_groups % s != 0 {
        bail!(
            "plan_shard_dispatch: {} groups not divisible into {s} shards",
            spec.n_groups
        );
    }
    let gs = spec.n_groups / s;
    let route_attn = spec.route_attn && routing.is_some();
    let (head, kh, ks) = if route_attn {
        let r = routing.unwrap();
        let sh = r.head_idx.shape().to_vec();
        if sh.len() != 3 || sh[0] != spec.n_layers || sh[1] != spec.batch {
            bail!(
                "plan_shard_dispatch: head_idx {:?} != [{}, {}, k]",
                sh, spec.n_layers, spec.batch
            );
        }
        (Some(r.head_idx.as_i32()?), sh[2], sh[2].min(gs).max(1))
    } else {
        (None, 0, 0)
    };
    let (mlp, ds) = if spec.mlp_ks > 0 {
        if spec.d_ff % s != 0 {
            bail!("plan_shard_dispatch: d_ff {} not divisible into {s} shards", spec.d_ff);
        }
        let r = routing.context("plan_shard_dispatch: sparse MLP entries need routing")?;
        let t = r
            .mlp_idx
            .as_ref()
            .context("plan_shard_dispatch: routing decision carries no mlp_idx")?;
        let sh = t.shape();
        if sh.len() != 2 || sh[0] != spec.n_layers {
            bail!("plan_shard_dispatch: mlp_idx {:?} != [{}, k]", sh, spec.n_layers);
        }
        (Some((t.as_i32()?, sh[1])), spec.d_ff / s)
    } else {
        (None, 0)
    };
    let live = |i: usize| {
        routing.map_or(true, |r| {
            r.active.as_ref().map_or(true, |a| a.get(i).copied().unwrap_or(false))
        })
    };

    let mut layers = Vec::with_capacity(spec.n_layers);
    for l in 0..spec.n_layers {
        let mut attn = Vec::with_capacity(s);
        for shard in 0..s {
            let data = match &head {
                // layer 0 stays dense per §3.2 even when routing is on
                Some(d) if l > 0 => d,
                _ => {
                    attn.push(AttnDispatch::Dense);
                    continue;
                }
            };
            let lo = (shard * gs) as i32;
            let hi = lo + gs as i32;
            let mut rows = vec![gs as i32; spec.batch * ks];
            let mut any = false;
            for b in 0..spec.batch {
                if !live(b) {
                    continue;
                }
                let row = &data[(l * spec.batch + b) * kh..(l * spec.batch + b + 1) * kh];
                let mut w = 0;
                for &g in row {
                    if g >= lo && g < hi && w < ks {
                        rows[b * ks + w] = g - lo;
                        w += 1;
                    }
                }
                any |= w > 0;
            }
            attn.push(if any { AttnDispatch::Sha(rows) } else { AttnDispatch::KvWrite });
        }
        let mut mlp_row = Vec::with_capacity(s);
        for shard in 0..s {
            match &mlp {
                None => mlp_row.push(MlpDispatch::Dense),
                Some((data, km)) => {
                    let lo = (shard * ds) as i32;
                    let hi = lo + ds as i32;
                    let mut out = vec![ds as i32; spec.mlp_ks];
                    let mut w = 0;
                    for &i in &data[l * km..(l + 1) * km] {
                        if i >= lo && i < hi && w < spec.mlp_ks {
                            out[w] = i - lo;
                            w += 1;
                        }
                    }
                    mlp_row.push(if w > 0 {
                        MlpDispatch::Sparse(out)
                    } else {
                        MlpDispatch::Skip
                    });
                }
            }
        }
        layers.push(LayerPlan { attn, mlp: mlp_row });
    }
    Ok(ShardDispatch { n_shards: s, layers })
}

/// Per-shard sparse-MLP index width baked into the artifact for
/// (n_shards, batch): the `top_k` meta of the shard-0 k-entry. `None`
/// when the artifact ships only dense MLP shards (non-ReLU models, no
/// calibration table, or an unsharded artifact).
pub fn mlp_shard_k(m: &Manifest, n_shards: usize, batch: usize) -> Option<usize> {
    m.entries.values().find_map(|e| {
        if e.kind != "tp_mlp"
            || e.meta.get("n_shards").as_usize()? != n_shards
            || e.meta.get("batch").as_usize()? != batch
            || e.meta.get("shard").as_usize()? != 0
        {
            return None;
        }
        match e.meta.get("top_k").as_usize()? {
            0 => None,
            k => Some(k),
        }
    })
}

// ---------------------------------------------------------------------------
// pool slicing (host side of shard composition changes)
// ---------------------------------------------------------------------------

fn pool_dims(t: &Tensor) -> Result<[usize; 6]> {
    let s = t.shape();
    if s.len() != 6 || s[1] != 2 {
        bail!("expected pool [L,2,P,G,bs,dh], got {s:?}");
    }
    Ok([s[0], s[1], s[2], s[3], s[4], s[5]])
}

/// Split a host pool `[L,2,P,G,bs,dh]` into per-shard group slices
/// `[L,2,P,Gs,bs,dh]`. Every slice keeps the full pool depth P, so the
/// same block tables address all of them.
pub fn split_pool_groups(pool: &Tensor, n_shards: usize) -> Result<Vec<Tensor>> {
    let [l, two, p, g, bs, dh] = pool_dims(pool)?;
    if n_shards == 0 || g % n_shards != 0 {
        bail!("split_pool_groups: {g} groups not divisible into {n_shards} shards");
    }
    let gs = g / n_shards;
    let row = bs * dh;
    let data = pool.as_f32()?;
    let mut out = Vec::with_capacity(n_shards);
    for s in 0..n_shards {
        let mut shard = Vec::with_capacity(l * two * p * gs * row);
        for o in 0..l * two * p {
            let base = o * g * row + s * gs * row;
            shard.extend_from_slice(&data[base..base + gs * row]);
        }
        out.push(Tensor::f32(shard, vec![l, two, p, gs, bs, dh])?);
    }
    Ok(out)
}

/// Inverse of [`split_pool_groups`]: reassemble the single-device pool
/// from per-shard group slices.
pub fn merge_pool_groups(shards: &[Tensor]) -> Result<Tensor> {
    let n_shards = shards.len();
    if n_shards == 0 {
        bail!("merge_pool_groups: no shards");
    }
    let [l, two, p, gs, bs, dh] = pool_dims(&shards[0])?;
    let row = bs * dh;
    let g = gs * n_shards;
    let mut data = vec![0f32; l * two * p * g * row];
    for (s, t) in shards.iter().enumerate() {
        if t.shape() != shards[0].shape() {
            bail!("merge_pool_groups: shard {s} shape {:?} != {:?}", t.shape(),
                  shards[0].shape());
        }
        let src = t.as_f32()?;
        for o in 0..l * two * p {
            let dst = o * g * row + s * gs * row;
            data[dst..dst + gs * row]
                .copy_from_slice(&src[o * gs * row..(o + 1) * gs * row]);
        }
    }
    Tensor::f32(data, vec![l, two, p, g, bs, dh])
}

/// Split a host pool `[L,2,P,G,bs,dh]` into per-stage layer slices
/// `[0, l0)` and `[l0, L)` (layers are the outermost axis, so both slices
/// are contiguous ranges of the flat buffer).
pub fn split_pool_layers(pool: &Tensor, l0: usize) -> Result<(Tensor, Tensor)> {
    let [l, two, p, g, bs, dh] = pool_dims(pool)?;
    if l0 == 0 || l0 >= l {
        bail!("split_pool_layers: split {l0} outside (0, {l})");
    }
    let per_layer = two * p * g * bs * dh;
    let data = pool.as_f32()?;
    Ok((
        Tensor::f32(data[..l0 * per_layer].to_vec(), vec![l0, two, p, g, bs, dh])?,
        Tensor::f32(data[l0 * per_layer..].to_vec(), vec![l - l0, two, p, g, bs, dh])?,
    ))
}

/// Inverse of [`split_pool_layers`].
pub fn merge_pool_layers(s0: &Tensor, s1: &Tensor) -> Result<Tensor> {
    let [l0, two, p, g, bs, dh] = pool_dims(s0)?;
    let [l1, two1, p1, g1, bs1, dh1] = pool_dims(s1)?;
    if (two, p, g, bs, dh) != (two1, p1, g1, bs1, dh1) {
        bail!("merge_pool_layers: stage shapes {:?} / {:?} disagree", s0.shape(),
              s1.shape());
    }
    let mut data = Vec::with_capacity((l0 + l1) * two * p * g * bs * dh);
    data.extend_from_slice(s0.as_f32()?);
    data.extend_from_slice(s1.as_f32()?);
    Tensor::f32(data, vec![l0 + l1, two, p, g, bs, dh])
}

// ---------------------------------------------------------------------------
// engine drivers
// ---------------------------------------------------------------------------

pub struct TpStepOutput {
    pub logits: Tensor, // [B, V]
    /// Per-shard pool slices, KV rows written on EVERY shard (kvw included).
    pub pools: Vec<PagedKv>,
    /// The dispatch plan the step ran (counters already merged into the
    /// profile; returned so callers can assert on the shape of the work).
    pub plan: ShardDispatch,
}

impl Engine {
    /// Routing policy for a self-routed TP step (direct bench/eval
    /// callers): prefer the single-device fused polar entry matching the
    /// SHA tag's density — it carries the calibrated per-layer mlp_topk —
    /// and fall back to tag-derived values.
    fn tp_routing_policy(
        &self,
        attn_tag: &str,
        mlp_ks: usize,
        n_shards: usize,
        b: usize,
        n: usize,
    ) -> Result<RoutingPolicy> {
        let m = self.exec.manifest();
        let cfg = self.exec.config();
        if let Some(d) = attn_tag.strip_prefix("sha_") {
            let fused = m.fused_decode_entry_name(&format!("polar_{d}"), b, n);
            if let Ok(spec) = m.entry(&fused) {
                if let Some(p) = RoutingPolicy::from_entry(spec) {
                    return Ok(p);
                }
            }
        }
        let g = cfg.n_groups();
        let head_k = match attn_tag.strip_prefix("sha_d") {
            Some(t) => {
                let density = t.parse::<f64>().map(|x| x / 1000.0).unwrap_or(1.0);
                ((g as f64 * density).round() as usize).clamp(1, g)
            }
            None => g,
        };
        let (mlp_cap, mlp_req_k) = if mlp_ks > 0 {
            ((mlp_ks * n_shards).min(cfg.d_ff), vec![mlp_ks.min(cfg.d_ff); cfg.n_layers])
        } else {
            (0, Vec::new())
        };
        Ok(RoutingPolicy { head_k, mlp_req_k, mlp_cap })
    }

    /// One decode step across `n_shards` TP shards over per-shard resident
    /// pool slices — route-then-dispatch (see module doc). `attn_tag` is
    /// "dense" or "sha_dXXXX" (layer 0 always runs dense, §3.2);
    /// `mlp_tag` is "dense" or "k{Kms}". With routed tags and `routing:
    /// None` the engine runs the artifact routers itself, like
    /// [`Engine::decode_paged`].
    ///
    /// The activation and every shard partial stay device buffers; the
    /// per-layer reduce entries sum them on-device (`allreduce_bytes`).
    /// A routing-skipped shard runs the KV-write entry and contributes a
    /// cloned zero buffer uploaded once per step.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_tp_paged(
        &self,
        n_shards: usize,
        attn_tag: &str,
        mlp_tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        tables: &BlockTables,
        pools: Vec<PagedKv>,
        routing: Option<&StepRouting>,
    ) -> Result<TpStepOutput> {
        let cfg = self.exec.config().clone();
        let b = tables.batch;
        if tokens.len() != b || lengths.len() != b {
            bail!("decode_tp_paged: tokens/lengths len != batch {b}");
        }
        if pools.len() != n_shards || n_shards == 0 {
            bail!("decode_tp_paged: {} pools vs {n_shards} shards", pools.len());
        }
        let (pool_blocks, block) = (pools[0].pool_blocks, pools[0].block);
        if pools.iter().any(|p| p.pool_blocks != pool_blocks || p.block != block) {
            bail!("decode_tp_paged: shard pool geometries disagree");
        }
        if tables.flat.iter().any(|&x| x < 0 || x as usize >= pool_blocks) {
            bail!("decode_tp_paged: block id out of pool ({pool_blocks})");
        }
        let n = tables.n(block);
        let mlp_ks = if mlp_tag == "dense" {
            0
        } else {
            mlp_tag
                .strip_prefix('k')
                .and_then(|s| s.parse::<usize>().ok())
                .filter(|&k| k > 0)
                .with_context(|| format!("decode_tp_paged: bad mlp tag {mlp_tag:?}"))?
        };
        let route_attn = attn_tag != "dense";
        let computed = if routing.is_none() && (route_attn || mlp_ks > 0) {
            let policy = self.tp_routing_policy(attn_tag, mlp_ks, n_shards, b, n)?;
            let bank = self.router_bank().as_ref().with_context(|| {
                format!(
                    "TP tags {attn_tag}/{mlp_tag} take router indices but the \
                     artifact has no router weights"
                )
            })?;
            let r = bank.route_step(tokens, lengths, None, &policy)?;
            self.exec.profile_mut().router_ns += r.router_ns;
            Some(r)
        } else {
            None
        };
        let routing = computed.as_ref().or(routing);
        let plan = plan_shard_dispatch(
            &ShardPlanSpec {
                n_shards,
                n_layers: cfg.n_layers,
                n_groups: cfg.n_groups(),
                d_ff: cfg.d_ff,
                batch: b,
                route_attn,
                mlp_ks,
            },
            routing,
        )?;

        let m = self.exec.manifest();
        let toks_lit = Tensor::i32(tokens.to_vec(), vec![b])?.to_literal()?;
        let lens_lit = Tensor::i32(lengths.to_vec(), vec![b])?.to_literal()?;
        let tbl_lit = tables.to_literal()?;
        // one zero [B,d] buffer uploaded per step, cloned per skipped shard
        // (buffer clones are O(1) handles — nothing re-crosses the host)
        let zero_buf = if plan.skipped() > 0 {
            Some(self.exec.upload(&Tensor::zeros_f32(vec![b, cfg.d_model]).to_literal()?)?)
        } else {
            None
        };
        let zero = || DeviceInput::Buf(zero_buf.clone().expect("zero partial"));

        let embed = self.exec.run_bufs(
            &m.tp_embed_entry_name(n_shards, b),
            vec![DeviceInput::Host(toks_lit), DeviceInput::Host(lens_lit.clone())],
        )?;
        let mut x = embed.into_iter().next().context("tp embed x")?;
        let mut stores: Vec<Option<KvStore>> =
            pools.into_iter().map(|p| Some(p.store)).collect();

        for (l, lp) in plan.layers.iter().enumerate() {
            let l_lit = Tensor::i32(vec![l as i32], vec![])?.to_literal()?;
            // attention shards: data order [layer, x, lengths, block_table,
            // kv, (head_idx)] — pinned by the AOT contract test
            let mut partials: Vec<DeviceInput> = Vec::with_capacity(n_shards);
            for (s, d) in lp.attn.iter().enumerate() {
                let kv_in = match stores[s].take().expect("kv store") {
                    KvStore::Lit(lit) => DeviceInput::Host(lit),
                    KvStore::Buf(buf) => DeviceInput::Buf(buf),
                };
                let mut ins = vec![
                    DeviceInput::Host(l_lit.clone()),
                    DeviceInput::Buf(x.clone()),
                    DeviceInput::Host(lens_lit.clone()),
                    DeviceInput::Host(tbl_lit.clone()),
                    kv_in,
                ];
                let name = match d {
                    AttnDispatch::Dense => m.tp_attn_entry_name(n_shards, s, "dense", b, n),
                    AttnDispatch::Sha(rows) => {
                        let ks = rows.len() / b.max(1);
                        ins.push(DeviceInput::Host(
                            Tensor::i32(rows.clone(), vec![b, ks])?.to_literal()?,
                        ));
                        m.tp_attn_entry_name(n_shards, s, attn_tag, b, n)
                    }
                    AttnDispatch::KvWrite => m.tp_attn_entry_name(n_shards, s, "kvw", b, n),
                };
                let mut it = self.exec.run_bufs(&name, ins)?.into_iter();
                if matches!(d, AttnDispatch::KvWrite) {
                    stores[s] = Some(KvStore::Buf(it.next().context("kvw kv")?));
                    partials.push(zero());
                } else {
                    partials.push(DeviceInput::Buf(it.next().context("attn partial")?));
                    stores[s] = Some(KvStore::Buf(it.next().context("attn kv")?));
                }
            }
            let mut ins = vec![DeviceInput::Host(l_lit.clone()), DeviceInput::Buf(x)];
            ins.extend(partials);
            x = self
                .exec
                .run_bufs(&m.tp_reduce_entry_name(n_shards, "attn", b), ins)?
                .into_iter()
                .next()
                .context("attn reduce x")?;

            // MLP shards: data order [layer, x, (mlp_idx)]
            let mut partials: Vec<DeviceInput> = Vec::with_capacity(n_shards);
            for (s, d) in lp.mlp.iter().enumerate() {
                if matches!(d, MlpDispatch::Skip) {
                    partials.push(zero());
                    continue;
                }
                let mut ins =
                    vec![DeviceInput::Host(l_lit.clone()), DeviceInput::Buf(x.clone())];
                let name = match d {
                    MlpDispatch::Sparse(idx) => {
                        ins.push(DeviceInput::Host(
                            Tensor::i32(idx.clone(), vec![idx.len()])?.to_literal()?,
                        ));
                        m.tp_mlp_entry_name(n_shards, s, mlp_tag, b)
                    }
                    _ => m.tp_mlp_entry_name(n_shards, s, "dense", b),
                };
                partials.push(DeviceInput::Buf(
                    self.exec
                        .run_bufs(&name, ins)?
                        .into_iter()
                        .next()
                        .context("mlp partial")?,
                ));
            }
            let mut ins = vec![DeviceInput::Host(l_lit), DeviceInput::Buf(x)];
            ins.extend(partials);
            x = self
                .exec
                .run_bufs(&m.tp_reduce_entry_name(n_shards, "mlp", b), ins)?
                .into_iter()
                .next()
                .context("mlp reduce x")?;
        }

        let logits_buf = self
            .exec
            .run_bufs(&m.tp_final_entry_name(n_shards, b), vec![DeviceInput::Buf(x)])?
            .into_iter()
            .next()
            .context("tp logits")?;
        let logits = Tensor::from_literal(&self.exec.fetch_literal(&logits_buf)?)?;

        let resident = self.kv_resident();
        let pools = stores
            .into_iter()
            .map(|st| -> Result<PagedKv> {
                let store = match st.expect("kv store") {
                    // A/B host path: materialize like the single-device
                    // baseline (accounted d2h)
                    KvStore::Buf(buf) if !resident => {
                        KvStore::Lit(self.exec.fetch_literal(&buf)?)
                    }
                    s => s,
                };
                Ok(PagedKv { store, pool_blocks, block })
            })
            .collect::<Result<Vec<_>>>()?;

        let mut p = self.exec.profile_mut();
        p.decode_steps += 1;
        // 2 reduces per layer, each consuming S partials of B x d floats
        p.allreduce_bytes +=
            (2 * cfg.n_layers * n_shards * b * cfg.d_model * 4) as u64;
        p.shards_dispatched += plan.dispatched();
        p.shards_skipped += plan.skipped();
        drop(p);
        Ok(TpStepOutput { logits, pools, plan })
    }

    /// One decode step through the two paged pipeline stages. `kv0`/`kv1`
    /// hold the per-stage resident pool slices (layer split, same block
    /// tables); the stage-0 activation crosses to stage 1 as a device
    /// buffer. Polar tags are index-taking: the full-depth routing tensors
    /// ride to both stages and each reads its own layers' rows; with
    /// `routing: None` the engine self-routes like [`Engine::decode_paged`].
    #[allow(clippy::too_many_arguments)]
    pub fn decode_pp2_paged(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        tables: &BlockTables,
        kv0: PagedKv,
        kv1: PagedKv,
        routing: Option<&StepRouting>,
    ) -> Result<(Tensor, PagedKv, PagedKv)> {
        let b = tables.batch;
        if tokens.len() != b || lengths.len() != b {
            bail!("decode_pp2_paged: tokens/lengths len != batch {b}");
        }
        let geom0 = (kv0.pool_blocks, kv0.block);
        let geom1 = (kv1.pool_blocks, kv1.block);
        if geom0 != geom1 {
            bail!("decode_pp2_paged: stage pool geometries disagree");
        }
        if tables.flat.iter().any(|&x| x < 0 || x as usize >= kv0.pool_blocks) {
            bail!("decode_pp2_paged: block id out of pool ({})", kv0.pool_blocks);
        }
        let n = tables.n(kv0.block);
        let m = self.exec.manifest();
        let s0 = m.pp_stage_entry_name(0, tag, b, n);
        let s1 = m.pp_stage_entry_name(1, tag, b, n);
        let spec0 = m.entry(&s0)?;
        let takes_head = spec0.data.iter().any(|d| d.name == "head_idx");
        let takes_mlp = spec0.data.iter().any(|d| d.name == "mlp_idx");
        let computed = match (routing.is_some(), RoutingPolicy::from_entry(spec0)) {
            (false, Some(policy)) => {
                let bank = self.router_bank().as_ref().with_context(|| {
                    format!("{s0} takes router indices but the artifact has no router weights")
                })?;
                let r = bank.route_step(tokens, lengths, None, &policy)?;
                self.exec.profile_mut().router_ns += r.router_ns;
                Some(r)
            }
            _ => None,
        };
        let routing = computed.as_ref().or(routing);
        let mut idx_lits: Vec<xla::Literal> = Vec::new();
        if takes_head {
            let r = routing.with_context(|| format!("{s0} takes head_idx but no routing"))?;
            idx_lits.push(r.head_idx.to_literal()?);
        }
        if takes_mlp {
            let r = routing.with_context(|| format!("{s0} takes mlp_idx but no routing"))?;
            let t = r
                .mlp_idx
                .as_ref()
                .with_context(|| format!("{s0}: routing decision carries no mlp_idx"))?;
            idx_lits.push(t.to_literal()?);
        }

        let toks = Tensor::i32(tokens.to_vec(), vec![b])?.to_literal()?;
        let lens = Tensor::i32(lengths.to_vec(), vec![b])?.to_literal()?;
        let tbl = tables.to_literal()?;

        // stage 0: [tokens, lengths, block_table, kv, (idx...)] -> [x, kv]
        let kv0_in = match kv0.store {
            KvStore::Lit(l) => DeviceInput::Host(l),
            KvStore::Buf(buf) => DeviceInput::Buf(buf),
        };
        let mut ins0 = vec![
            DeviceInput::Host(toks),
            DeviceInput::Host(lens.clone()),
            DeviceInput::Host(tbl.clone()),
            kv0_in,
        ];
        ins0.extend(idx_lits.iter().cloned().map(DeviceInput::Host));
        let mut it0 = self.exec.run_bufs(&s0, ins0)?.into_iter();
        let x = it0.next().context("stage0 x")?;
        let kv0_store = KvStore::Buf(it0.next().context("stage0 kv")?);

        // stage 1: [x, lengths, block_table, kv, (idx...)] -> [logits, kv]
        let kv1_in = match kv1.store {
            KvStore::Lit(l) => DeviceInput::Host(l),
            KvStore::Buf(buf) => DeviceInput::Buf(buf),
        };
        let mut ins1 = vec![
            DeviceInput::Buf(x),
            DeviceInput::Host(lens),
            DeviceInput::Host(tbl),
            kv1_in,
        ];
        ins1.extend(idx_lits.into_iter().map(DeviceInput::Host));
        let mut it1 = self.exec.run_bufs(&s1, ins1)?.into_iter();
        let logits_buf = it1.next().context("stage1 logits")?;
        let kv1_store = KvStore::Buf(it1.next().context("stage1 kv")?);
        let logits = Tensor::from_literal(&self.exec.fetch_literal(&logits_buf)?)?;

        let resident = self.kv_resident();
        let mat = |store: KvStore| -> Result<KvStore> {
            Ok(match store {
                KvStore::Buf(buf) if !resident => {
                    KvStore::Lit(self.exec.fetch_literal(&buf)?)
                }
                s => s,
            })
        };
        let kv0 = PagedKv { store: mat(kv0_store)?, pool_blocks: geom0.0, block: geom0.1 };
        let kv1 = PagedKv { store: mat(kv1_store)?, pool_blocks: geom1.0, block: geom1.1 };
        self.exec.profile_mut().decode_steps += 1;
        Ok((logits, kv0, kv1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn routing(
        n_layers: usize,
        batch: usize,
        head: Vec<i32>,
        head_k: usize,
        n_groups: usize,
        mlp: Option<(Vec<i32>, usize)>,
        active: Option<Vec<bool>>,
    ) -> StepRouting {
        StepRouting {
            head_idx: Tensor::i32(head, vec![n_layers, batch, head_k]).unwrap(),
            mlp_idx: mlp
                .map(|(v, k)| Tensor::i32(v, vec![n_layers, k]).unwrap()),
            head_k,
            n_groups,
            head_union: vec![],
            mlp_union: vec![],
            head_counts: vec![],
            active,
            router_ns: 0,
        }
    }

    #[test]
    fn dense_plan_dispatches_everything() {
        let spec = ShardPlanSpec {
            n_shards: 2, n_layers: 3, n_groups: 4, d_ff: 8, batch: 2,
            route_attn: false, mlp_ks: 0,
        };
        let p = plan_shard_dispatch(&spec, None).unwrap();
        assert_eq!(p.layers.len(), 3);
        for l in &p.layers {
            assert_eq!(l.attn, vec![AttnDispatch::Dense; 2]);
            assert_eq!(l.mlp, vec![MlpDispatch::Dense; 2]);
        }
        assert_eq!(p.dispatched(), 3 * 2 * 2);
        assert_eq!(p.skipped(), 0);
    }

    #[test]
    fn routed_plan_localizes_and_skips() {
        // G=4, 2 shards (Gs=2), L=2, B=2, k=1: layer 1 both requests pick
        // groups {2, 3} -> shard 0 skipped, shard 1 gets local ids {0, 1}
        let r = routing(
            2, 2,
            vec![0, 3, /* layer 1: */ 2, 3],
            1, 4, None, None,
        );
        let spec = ShardPlanSpec {
            n_shards: 2, n_layers: 2, n_groups: 4, d_ff: 8, batch: 2,
            route_attn: true, mlp_ks: 0,
        };
        let p = plan_shard_dispatch(&spec, Some(&r)).unwrap();
        // layer 0 dense on every shard regardless of the indices
        assert_eq!(p.layers[0].attn, vec![AttnDispatch::Dense; 2]);
        assert_eq!(p.layers[1].attn[0], AttnDispatch::KvWrite);
        // Ks = min(1, 2) = 1; global {2, 3} -> local {0, 1} on shard 1
        assert_eq!(p.layers[1].attn[1], AttnDispatch::Sha(vec![0, 1]));
        assert_eq!(p.dispatched(), 2 + 1 + 2 + 2); // attn l0 + attn l1 + mlp x2
        assert_eq!(p.skipped(), 1);
    }

    #[test]
    fn masked_slots_do_not_force_a_dispatch() {
        // slot 1 is a padding slot whose placeholder row points at shard 0;
        // only live slot 0 (groups in shard 1's range) may drive dispatch
        let r = routing(
            2, 2,
            vec![0, 0, /* layer 1: */ 3, 0],
            1, 4, None,
            Some(vec![true, false]),
        );
        let spec = ShardPlanSpec {
            n_shards: 2, n_layers: 2, n_groups: 4, d_ff: 8, batch: 2,
            route_attn: true, mlp_ks: 0,
        };
        let p = plan_shard_dispatch(&spec, Some(&r)).unwrap();
        assert_eq!(p.layers[1].attn[0], AttnDispatch::KvWrite);
        // sentinel Gs=2 on the masked slot's row
        assert_eq!(p.layers[1].attn[1], AttnDispatch::Sha(vec![1, 2]));
    }

    #[test]
    fn mlp_union_partitions_by_shard_range() {
        // d_ff=8, 2 shards (Ds=4), union row layer 0 = {1, 6}, layer 1 all
        // in shard 0 -> shard 1 skipped there
        let r = routing(
            2, 1,
            vec![0, 0],
            1, 2,
            Some((vec![1, 6, /* layer 1: */ 0, 2], 2)),
            None,
        );
        let spec = ShardPlanSpec {
            n_shards: 2, n_layers: 2, n_groups: 2, d_ff: 8, batch: 1,
            route_attn: false, mlp_ks: 2,
        };
        let p = plan_shard_dispatch(&spec, Some(&r)).unwrap();
        // sentinel Ds=4 pads the localized rows to width mlp_ks
        assert_eq!(p.layers[0].mlp[0], MlpDispatch::Sparse(vec![1, 4]));
        assert_eq!(p.layers[0].mlp[1], MlpDispatch::Sparse(vec![2, 4]));
        assert_eq!(p.layers[1].mlp[0], MlpDispatch::Sparse(vec![0, 2]));
        assert_eq!(p.layers[1].mlp[1], MlpDispatch::Skip);
        assert_eq!(p.skipped(), 1);
        // attention stayed dense (route_attn: false)
        assert_eq!(p.layers[1].attn, vec![AttnDispatch::Dense; 2]);
    }

    #[test]
    fn plan_rejects_bad_geometry() {
        let spec = ShardPlanSpec {
            n_shards: 3, n_layers: 2, n_groups: 4, d_ff: 9, batch: 1,
            route_attn: false, mlp_ks: 0,
        };
        assert!(plan_shard_dispatch(&spec, None).is_err());
        // sparse MLP without a routing decision is an error, not silence
        let spec = ShardPlanSpec {
            n_shards: 2, n_layers: 2, n_groups: 4, d_ff: 8, batch: 1,
            route_attn: false, mlp_ks: 2,
        };
        assert!(plan_shard_dispatch(&spec, None).is_err());
    }

    fn seq_pool(shape: [usize; 6]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::f32((0..n).map(|i| i as f32).collect(), shape.to_vec()).unwrap()
    }

    #[test]
    fn pool_group_split_merge_roundtrip() {
        let pool = seq_pool([2, 2, 3, 4, 2, 2]);
        let shards = split_pool_groups(&pool, 2).unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].shape(), &[2, 2, 3, 2, 2, 2]);
        // shard 1 owns groups {2, 3}: first element is the (0,0,0,2,0,0)
        // entry of the full pool
        assert_eq!(shards[1].as_f32().unwrap()[0], 2.0 * 2.0 * 2.0);
        let merged = merge_pool_groups(&shards).unwrap();
        assert_eq!(merged.as_f32().unwrap(), pool.as_f32().unwrap());
        assert!(split_pool_groups(&pool, 3).is_err());
    }

    #[test]
    fn pool_layer_split_merge_roundtrip() {
        let pool = seq_pool([4, 2, 3, 2, 2, 2]);
        let (a, b) = split_pool_layers(&pool, 1).unwrap();
        assert_eq!(a.shape(), &[1, 2, 3, 2, 2, 2]);
        assert_eq!(b.shape(), &[3, 2, 3, 2, 2, 2]);
        let merged = merge_pool_layers(&a, &b).unwrap();
        assert_eq!(merged.as_f32().unwrap(), pool.as_f32().unwrap());
        assert!(split_pool_layers(&pool, 0).is_err());
        assert!(split_pool_layers(&pool, 4).is_err());
    }

    #[test]
    fn mlp_shard_k_reads_meta_not_names() {
        use crate::substrate::json::Json;
        use crate::runtime::manifest::EntrySpec;
        let entry = |name: &str, meta: &str| EntrySpec {
            name: name.into(),
            kind: "tp_mlp".into(),
            file: "x".into(),
            data: vec![],
            outputs: vec![],
            meta: Json::parse(meta).unwrap(),
        };
        let dir = std::env::temp_dir().join("ps_shard_mlpk_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"model":"m","analogue":"x",
                "config":{"d_model":8,"n_layers":2,"n_heads":2,"n_kv_heads":2,
                          "d_ff":16,"d_head":4,"vocab":10,"max_seq":32,
                          "mlp":"relu","pos":"learned","critical_density":0.5},
                "params":[],"buckets":{"batch":[1],"seq":[16]},"entries":[]}"#,
        )
        .unwrap();
        let mut m = Manifest::load(&dir).unwrap();
        // multi-k artifact: k96 at B=4 and k188 at B=16 must not bleed into
        // each other (the old string-prefix scan returned whichever name
        // sorted first)
        for (name, meta) in [
            ("tp2_mlp_s0_dense_b4",
             r#"{"batch":4,"shard":0,"n_shards":2,"top_k":0}"#),
            ("tp2_mlp_s0_k96_b4",
             r#"{"batch":4,"shard":0,"n_shards":2,"top_k":96}"#),
            ("tp2_mlp_s1_k96_b4",
             r#"{"batch":4,"shard":1,"n_shards":2,"top_k":96}"#),
            ("tp2_mlp_s0_k188_b16",
             r#"{"batch":16,"shard":0,"n_shards":2,"top_k":188}"#),
            ("tp4_mlp_s0_k48_b4",
             r#"{"batch":4,"shard":0,"n_shards":4,"top_k":48}"#),
        ] {
            m.entries.insert(name.to_string(), entry(name, meta));
        }
        assert_eq!(mlp_shard_k(&m, 2, 4), Some(96));
        assert_eq!(mlp_shard_k(&m, 2, 16), Some(188));
        assert_eq!(mlp_shard_k(&m, 4, 4), Some(48));
        assert_eq!(mlp_shard_k(&m, 4, 16), None);
        assert_eq!(mlp_shard_k(&m, 8, 4), None);
    }
}
