//! Model engine: prefill / decode step API over compiled entries. The
//! shard-aware paged TP/PP drivers (Figs 11, 12) live in
//! [`super::shard`].
//!
//! The decode hot path keeps the KV cache **resident on the device**: each
//! step's KV output buffer is fed straight into the next step
//! ([`Executor::run_bufs`]), so the only per-step host traffic is
//! tokens/lengths up and logits down. Host literals exist only around
//! composition changes (admission, re-bucketing), when the coordinator
//! needs the cache bytes for slot surgery. Env `POLAR_KV_HOST=1` forces
//! the legacy literal-per-step path, kept as the A/B baseline for
//! `bench decode-breakdown`.

use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::substrate::sync::lock_clean;

use super::executor::{DeviceInput, Executor};
use super::router::{RouterBank, RoutingPolicy, StepRouting};
use super::tensor::Tensor;

/// Where a batch group's KV cache currently lives.
pub enum KvStore {
    /// Host literal — produced by coordinator surgery (fresh groups,
    /// re-buckets) and by the legacy host-KV A/B path; the engine uploads
    /// it on the next prefill-chunk or decode call.
    Lit(xla::Literal),
    /// Device-resident buffer — flows output -> input across decode steps
    /// without crossing the host boundary.
    Buf(xla::PjRtBuffer),
}

/// Batched KV cache at a fixed (batch, seq) bucket.
pub struct KvCache {
    pub store: KvStore,
    pub batch: usize,
    pub n: usize,
}

impl KvCache {
    /// Materialize the cache on the host for slot surgery. For a resident
    /// cache this is the one d2h copy a composition change costs.
    pub fn to_tensor(&self) -> Result<Tensor> {
        match &self.store {
            KvStore::Lit(l) => Tensor::from_literal(l),
            KvStore::Buf(b) => {
                Tensor::from_literal(&b.to_literal_sync().context("fetch resident kv")?)
            }
        }
    }

    pub fn from_tensor(t: &Tensor, batch: usize, n: usize) -> Result<KvCache> {
        Ok(KvCache { store: KvStore::Lit(t.to_literal()?), batch, n })
    }

    /// True when the cache lives on the device (no host copy per step).
    pub fn is_resident(&self) -> bool {
        matches!(self.store, KvStore::Buf(_))
    }

    fn into_input(self) -> DeviceInput {
        match self.store {
            KvStore::Lit(l) => DeviceInput::Host(l),
            KvStore::Buf(b) => DeviceInput::Buf(b),
        }
    }

    fn into_literal(self, exec: &Executor) -> Result<xla::Literal> {
        match self.store {
            KvStore::Lit(l) => Ok(l),
            KvStore::Buf(b) => exec.fetch_literal(&b),
        }
    }
}

pub struct StepOutput {
    pub logits: Tensor, // [B, V]
    pub kv: KvCache,
}

/// The paged KV pool: ONE `[L, 2, P, G, bs, dh]` tensor resident on the
/// engine for the process lifetime, shared by every request. Per-call
/// block tables address it, so batch/seq bucket changes and request
/// admission/finish move **no cache bytes** — the property the
/// contiguous per-bucket caches could not give us.
pub struct PagedKv {
    pub store: KvStore,
    /// Physical blocks in the pool (incl. the reserved null block 0).
    pub pool_blocks: usize,
    /// Token positions per block.
    pub block: usize,
}

impl PagedKv {
    /// Materialize the pool on the host (block copies, diagnostics).
    pub fn to_tensor(&self) -> Result<Tensor> {
        match &self.store {
            KvStore::Lit(l) => Tensor::from_literal(l),
            KvStore::Buf(b) => {
                Tensor::from_literal(&b.to_literal_sync().context("fetch resident kv pool")?)
            }
        }
    }

    pub fn from_tensor(t: &Tensor, pool_blocks: usize, block: usize) -> Result<PagedKv> {
        Ok(PagedKv { store: KvStore::Lit(t.to_literal()?), pool_blocks, block })
    }

    pub fn is_resident(&self) -> bool {
        matches!(self.store, KvStore::Buf(_))
    }

    fn into_store(self) -> KvStore {
        self.store
    }
}

/// One step's per-slot block tables, row-major `[batch, width]` (width =
/// logical seq bucket / block size). Rows of inactive slots are all null
/// block, so their blind per-step writes land in don't-care memory.
#[derive(Debug, Clone)]
pub struct BlockTables {
    pub flat: Vec<i32>,
    pub batch: usize,
    pub width: usize,
}

impl BlockTables {
    pub fn new(flat: Vec<i32>, batch: usize, width: usize) -> Result<BlockTables> {
        if flat.len() != batch * width {
            bail!("block tables: {} entries vs {batch} x {width}", flat.len());
        }
        Ok(BlockTables { flat, batch, width })
    }

    /// Logical positions the tables cover (the entry's seq bucket).
    pub fn n(&self, block: usize) -> usize {
        self.width * block
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        Tensor::i32(self.flat.clone(), vec![self.batch, self.width])?.to_literal()
    }
}

pub struct PagedStepOutput {
    pub logits: Tensor, // [B, V]
    pub kv: PagedKv,
}

/// Copy whole physical blocks (`src` -> `dst` pairs) inside a pool
/// tensor `[L,2,P,G,bs,dh]` — the host half of copy-on-write. Every
/// (layer, k/v) plane copies one `G*bs*dh` row per pair.
pub fn copy_pool_blocks(t: &mut Tensor, pairs: &[(u32, u32)]) -> Result<()> {
    let s = t.shape().to_vec();
    if s.len() != 6 || s[1] != 2 {
        bail!("expected pool [L,2,P,G,bs,dh], got {s:?}");
    }
    let (l, two, p, row) = (s[0], s[1], s[2], s[3] * s[4] * s[5]);
    let data = t.as_f32_mut()?;
    for &(src, dst) in pairs {
        let (src, dst) = (src as usize, dst as usize);
        if src >= p || dst >= p {
            bail!("copy_pool_blocks: {src} -> {dst} out of pool ({p} blocks)");
        }
        if src == dst {
            continue;
        }
        for li in 0..l {
            for c in 0..two {
                let base = ((li * two + c) * p) * row;
                data.copy_within(base + src * row..base + src * row + row, base + dst * row);
            }
        }
    }
    Ok(())
}

#[derive(Clone)]
pub struct Engine {
    pub exec: Arc<Executor>,
    /// A/B switch: true = legacy host-literal KV path (env POLAR_KV_HOST).
    kv_host_path: bool,
    /// Router weights from the artifact (None when it ships no routers),
    /// built **lazily** on first routed use — dense/dejavu serving never
    /// pays the host-side weight copies (tok_emb alone duplicates the
    /// embedding table). Shared with the sparsity controller, which
    /// normally computes each step's routing; the engine runs the
    /// routers itself only for direct `decode` callers (eval, benches)
    /// hitting an index-taking entry.
    routers: Arc<OnceLock<Option<RouterBank>>>,
    /// Fault-recovery stash: the paged entry points take the pool by
    /// value, so a pre-execution validation failure would otherwise lose
    /// the only KV handle. They park the pool here before bailing; the
    /// scheduler drains it via [`Engine::recover_kv`] and retries (or
    /// bisects). An error with an empty stash is unrecoverable.
    kv_stash: Arc<Mutex<Option<PagedKv>>>,
}

impl Engine {
    pub fn new(exec: Arc<Executor>) -> Engine {
        let kv_host_path = std::env::var("POLAR_KV_HOST").is_ok();
        Engine {
            exec,
            kv_host_path,
            routers: Arc::new(OnceLock::new()),
            kv_stash: Arc::new(Mutex::new(None)),
        }
    }

    /// Drain the pool parked by a recoverable paged-entry failure
    /// (see `kv_stash`). `None` means the error lost the pool — fatal.
    pub fn recover_kv(&self) -> Option<PagedKv> {
        lock_clean(&self.kv_stash).take()
    }

    /// Park the pool and pass the error through: every paged-entry
    /// failure before the pool is consumed by execution routes here so
    /// the caller can recover-and-retry.
    fn stash_and_err(&self, kv: PagedKv, e: anyhow::Error) -> anyhow::Error {
        *lock_clean(&self.kv_stash) = Some(kv);
        e
    }

    /// The artifact's router bank, built on first call (None when the
    /// artifact ships no — or malformed — router weights).
    pub fn router_bank(&self) -> &Option<RouterBank> {
        self.routers.get_or_init(|| match RouterBank::from_executor(&self.exec) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("warning: router weights unusable, routing disabled: {e:#}");
                None
            }
        })
    }

    /// The shared lazily-initialized bank cell (the sparsity controller
    /// holds a clone so engine and controller build the bank only once).
    pub fn router_cell(&self) -> Arc<OnceLock<Option<RouterBank>>> {
        self.routers.clone()
    }

    /// Force the legacy host-KV path (the `bench decode-breakdown`
    /// baseline) regardless of the environment.
    pub fn with_kv_host_path(mut self, host: bool) -> Engine {
        self.kv_host_path = host;
        self
    }

    pub fn kv_resident(&self) -> bool {
        !self.kv_host_path
    }

    pub fn vocab(&self) -> usize {
        self.exec.config().vocab
    }

    /// Pre-compile every (batch, seq) bucket of a decode mode plus the
    /// prefill entries, so serving never pays a JIT stall mid-request
    /// (the CUDA-graph capture analogue). Returns the number compiled.
    pub fn precompile(&self, tag: &str) -> Result<usize> {
        let m = self.exec.manifest();
        let mut n = 0;
        let names: Vec<String> = m
            .batch_buckets
            .iter()
            .flat_map(|&b| {
                m.seq_buckets.iter().flat_map(move |&s| {
                    [m.decode_entry_name(tag, b, s), m.prefill_entry_name(b, s)]
                })
            })
            .collect();
        for name in names {
            if m.entries.contains_key(&name) && !self.exec.is_cached(&name) {
                self.exec.compiled(&name)?;
                n += 1;
            }
        }
        Ok(n)
    }

    /// Token width of one chunked-prefill call.
    pub fn prefill_chunk_len(&self) -> usize {
        self.exec.manifest().prefill_chunk
    }

    /// One chunked-prefill call through `prefill_b{B}_s{N}`: append each
    /// slot's next prompt chunk into the group cache at a per-slot
    /// position offset. `tokens`: [B*C] row-major (C = chunk width,
    /// padded), `lengths`: valid tokens per slot in THIS chunk (0 =
    /// inactive slot, cache row untouched), `offset`: absolute start
    /// position per slot. The cache keeps the decode path's residency
    /// discipline: on the hot path it stays a device buffer across chunk
    /// calls and into the decode step that follows; only the logits come
    /// home.
    pub fn prefill_chunk(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        offset: &[i32],
        kv: KvCache,
    ) -> Result<StepOutput> {
        let b = kv.batch;
        let n = kv.n;
        let c = self.prefill_chunk_len();
        if tokens.len() != b * c || lengths.len() != b || offset.len() != b {
            bail!(
                "prefill_chunk: tokens {} / lengths {} / offset {} vs batch {b} chunk {c}",
                tokens.len(),
                lengths.len(),
                offset.len()
            );
        }
        for i in 0..b {
            let end = offset[i] as usize + lengths[i] as usize;
            if end > n {
                bail!("prefill_chunk: slot {i} writes to {end} > kv bucket {n}");
            }
        }
        let name = self.exec.manifest().prefill_entry_name(b, n);
        let spec = self.exec.manifest().entry(&name)?;
        let t0 = std::time::Instant::now();
        let toks = Tensor::i32(tokens.to_vec(), vec![b, c])?.to_literal()?;
        let lens = Tensor::i32(lengths.to_vec(), vec![b])?.to_literal()?;
        let offs = Tensor::i32(offset.to_vec(), vec![b])?.to_literal()?;

        // assemble the data inputs in the entry's declared order
        enum In {
            Lit(xla::Literal),
            Kv,
        }
        let mut ins: Vec<In> = Vec::with_capacity(spec.data.len());
        let mut kv_inputs = 0usize;
        for d in &spec.data {
            match d.name.as_str() {
                "tokens" => ins.push(In::Lit(toks.clone())),
                "lengths" => ins.push(In::Lit(lens.clone())),
                "offset" => ins.push(In::Lit(offs.clone())),
                "kv" => {
                    kv_inputs += 1;
                    ins.push(In::Kv);
                }
                other => bail!("{name}: unsupported prefill data input {other:?}"),
            }
        }
        if kv_inputs != 1 {
            bail!("{name}: expected exactly one kv input, found {kv_inputs}");
        }

        let out = if self.kv_host_path {
            let mut kv_lit = Some(kv.into_literal(&self.exec)?);
            let data: Vec<xla::Literal> = ins
                .into_iter()
                .map(|i| match i {
                    In::Lit(l) => l,
                    In::Kv => kv_lit.take().expect("single kv input"),
                })
                .collect();
            let outs = self.exec.run_raw(&name, &data)?;
            let logits = Tensor::from_literal(&outs[0])?;
            let kv = KvCache {
                store: KvStore::Lit(outs.into_iter().nth(1).unwrap()),
                batch: b,
                n,
            };
            StepOutput { logits, kv }
        } else {
            let mut kv_in = Some(kv.into_input());
            let inputs: Vec<DeviceInput> = ins
                .into_iter()
                .map(|i| match i {
                    In::Lit(l) => DeviceInput::Host(l),
                    In::Kv => kv_in.take().expect("single kv input"),
                })
                .collect();
            let outs = self.exec.run_bufs(&name, inputs)?;
            let mut it = outs.into_iter();
            let logits_buf = it.next().context("prefill logits")?;
            let kv_buf = it.next().context("prefill kv")?;
            let logits = Tensor::from_literal(&self.exec.fetch_literal(&logits_buf)?)?;
            StepOutput {
                logits,
                kv: KvCache { store: KvStore::Buf(kv_buf), batch: b, n },
            }
        };
        let mut p = self.exec.profile_mut();
        p.prefill_ns += t0.elapsed().as_nanos() as u64;
        p.prefill_chunks += 1;
        Ok(out)
    }

    /// Monolithic-compat prompt pass: stream `tokens` [B, S] (padded, any
    /// S) through successive chunk calls into a fresh zeroed cache at
    /// `n_bucket`. Returns each slot's final-position logits + the filled
    /// cache. Used by the eval/bench paths that want a whole prompt
    /// prefilled in one call; the serving scheduler drives
    /// [`Engine::prefill_chunk`] incrementally instead.
    pub fn prefill(
        &self,
        tokens: &Tensor,
        lengths: &Tensor,
        n_bucket: usize,
    ) -> Result<StepOutput> {
        let (b, s) = (tokens.shape()[0], tokens.shape()[1]);
        let c = self.prefill_chunk_len();
        let toks = tokens.as_i32()?.to_vec();
        let lens = lengths.as_i32()?.to_vec();
        let max_len = lens.iter().copied().max().unwrap_or(0).max(1) as usize;
        if max_len > s || max_len > n_bucket {
            bail!("prefill: length {max_len} exceeds tokens {s} or bucket {n_bucket}");
        }
        let cfg = self.exec.config();
        let mut kv = KvCache::from_tensor(
            &Tensor::zeros_f32(cfg.kv_shape(b, n_bucket)),
            b,
            n_bucket,
        )?;
        let vocab = cfg.vocab;
        let mut final_logits = vec![0f32; b * vocab];
        let mut off = 0usize;
        while off < max_len {
            let mut chunk = vec![crate::tokenizer::PAD; b * c];
            let mut clen = vec![0i32; b];
            let mut coff = vec![0i32; b];
            for i in 0..b {
                let l = lens[i] as usize;
                let take = l.saturating_sub(off).min(c);
                for k in 0..take {
                    chunk[i * c + k] = toks[i * s + off + k];
                }
                clen[i] = take as i32;
                coff[i] = off.min(l) as i32;
            }
            let out = self.prefill_chunk(&chunk, &clen, &coff, kv)?;
            let rows = out.logits.as_f32()?;
            for i in 0..b {
                let l = lens[i] as usize;
                if l > off && l <= off + c {
                    final_logits[i * vocab..(i + 1) * vocab]
                        .copy_from_slice(&rows[i * vocab..(i + 1) * vocab]);
                }
            }
            kv = out.kv;
            off += c;
        }
        Ok(StepOutput {
            logits: Tensor::f32(final_logits, vec![b, vocab])?,
            kv,
        })
    }

    /// One decode step through the entry `decode_{tag}_b{B}_n{N}`.
    /// tokens/lengths: per-slot [B]; lengths already include the new token.
    ///
    /// Index-taking entries (the `polar` grid: data inputs `head_idx`
    /// [L,B,Kh] and, for ReLU models, `mlp_idx` [L,Km]) consume the
    /// `routing` decision the sparsity controller computed for this step.
    /// When a direct caller (eval, benches) passes `None` for such an
    /// entry, the engine runs the artifact's routers itself so the legacy
    /// call sites keep working; entries without index inputs ignore
    /// `routing` entirely.
    pub fn decode(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv: KvCache,
        routing: Option<&StepRouting>,
    ) -> Result<StepOutput> {
        let b = kv.batch;
        let n = kv.n;
        if tokens.len() != b || lengths.len() != b {
            bail!("decode: tokens/lengths len != batch {b}");
        }
        if let Some(&max) = lengths.iter().max() {
            if max as usize > n {
                bail!("decode: length {max} exceeds kv bucket {n}");
            }
        }
        let name = self.exec.manifest().decode_entry_name(tag, b, n);
        let spec = self.exec.manifest().entry(&name)?;
        let computed;
        let routing = match (routing, RoutingPolicy::from_entry(spec)) {
            (None, Some(policy)) => {
                let bank = self.router_bank().as_ref().with_context(|| {
                    format!(
                        "{name} takes router indices but the artifact has no \
                         router weights (run compile.routers, or serve with \
                         --mode dense)"
                    )
                })?;
                computed = bank.route_step(tokens, lengths, None, &policy)?;
                self.exec.profile_mut().router_ns += computed.router_ns;
                Some(&computed)
            }
            (r, _) => r,
        };
        let toks = Tensor::i32(tokens.to_vec(), vec![b])?.to_literal()?;
        let lens = Tensor::i32(lengths.to_vec(), vec![b])?.to_literal()?;

        // assemble the data inputs in the entry's declared order
        enum In {
            Lit(xla::Literal),
            Kv,
        }
        let mut ins: Vec<In> = Vec::with_capacity(spec.data.len());
        let mut kv_inputs = 0usize;
        for d in &spec.data {
            match d.name.as_str() {
                "tokens" => ins.push(In::Lit(toks.clone())),
                "lengths" => ins.push(In::Lit(lens.clone())),
                "kv" => {
                    kv_inputs += 1;
                    ins.push(In::Kv);
                }
                "head_idx" | "mlp_idx" => {
                    let r = routing.with_context(|| {
                        format!("{name}: entry takes {} but no routing was computed", d.name)
                    })?;
                    let t = if d.name == "head_idx" {
                        Some(&r.head_idx)
                    } else {
                        r.mlp_idx.as_ref()
                    };
                    let t = t.with_context(|| {
                        format!("{name}: routing decision carries no {}", d.name)
                    })?;
                    if t.shape() != d.shape.as_slice() {
                        bail!(
                            "{name}: {} shape {:?} != entry's {:?}",
                            d.name,
                            t.shape(),
                            d.shape
                        );
                    }
                    ins.push(In::Lit(t.to_literal()?));
                }
                other => bail!("{name}: unsupported decode data input {other:?}"),
            }
        }
        if kv_inputs != 1 {
            bail!("{name}: expected exactly one kv input, found {kv_inputs}");
        }

        let out = if self.kv_host_path {
            // A/B baseline: full output tuple (logits + KV) fetched to the
            // host every step, KV re-uploaded next step.
            let mut kv_lit = Some(kv.into_literal(&self.exec)?);
            let data: Vec<xla::Literal> = ins
                .into_iter()
                .map(|i| match i {
                    In::Lit(l) => l,
                    In::Kv => kv_lit.take().expect("single kv input"),
                })
                .collect();
            let outs = self.exec.run_raw(&name, &data)?;
            let logits = Tensor::from_literal(&outs[0])?;
            let kv = KvCache {
                store: KvStore::Lit(outs.into_iter().nth(1).unwrap()),
                batch: b,
                n,
            };
            StepOutput { logits, kv }
        } else {
            // hot path: KV stays device-resident; only logits come home
            let mut kv_in = Some(kv.into_input());
            let inputs: Vec<DeviceInput> = ins
                .into_iter()
                .map(|i| match i {
                    In::Lit(l) => DeviceInput::Host(l),
                    In::Kv => kv_in.take().expect("single kv input"),
                })
                .collect();
            let outs = self.exec.run_bufs(&name, inputs)?;
            let mut it = outs.into_iter();
            let logits_buf = it.next().context("decode logits")?;
            let kv_buf = it.next().context("decode kv")?;
            let logits = Tensor::from_literal(&self.exec.fetch_literal(&logits_buf)?)?;
            StepOutput {
                logits,
                kv: KvCache { store: KvStore::Buf(kv_buf), batch: b, n },
            }
        };
        self.exec.profile_mut().decode_steps += 1;
        Ok(out)
    }

    // -- paged KV (block pool + block tables) -----------------------------

    /// Paged-KV geometry from the manifest: (block size, pool blocks).
    pub fn kv_layout(&self) -> (usize, usize) {
        let m = self.exec.manifest();
        (m.kv_block, m.kv_pool_blocks)
    }

    /// A fresh zeroed pool at the manifest geometry. Allocated once per
    /// serving process; bucket changes never touch it again.
    pub fn new_kv_pool(&self) -> Result<PagedKv> {
        let (block, pool_blocks) = self.kv_layout();
        let t = Tensor::zeros_f32(self.exec.config().kv_pool_shape(pool_blocks, block));
        PagedKv::from_tensor(&t, pool_blocks, block)
    }

    /// Assemble one KV-carrying entry's data inputs in declared order
    /// (named literals + the single `kv` store + routing index tensors),
    /// run it on the configured path, and return (logits, kv-out). Shared
    /// by the fused paged decode/prefill entry points; the contract is
    /// identical to the contiguous paths': host path fetches the full
    /// output tuple, resident path leaves the KV on-device and fetches
    /// only logits.
    fn run_kv_entry(
        &self,
        name: &str,
        named: &[(&str, xla::Literal)],
        kv_store: KvStore,
        routing: Option<&StepRouting>,
    ) -> Result<(Tensor, KvStore)> {
        let spec = self.exec.manifest().entry(name)?;
        enum In {
            Lit(xla::Literal),
            Kv,
        }
        let mut ins: Vec<In> = Vec::with_capacity(spec.data.len());
        let mut kv_inputs = 0usize;
        for d in &spec.data {
            match d.name.as_str() {
                "kv" => {
                    kv_inputs += 1;
                    ins.push(In::Kv);
                }
                "head_idx" | "mlp_idx" => {
                    let r = routing.with_context(|| {
                        format!("{name}: entry takes {} but no routing was computed", d.name)
                    })?;
                    let t = if d.name == "head_idx" {
                        Some(&r.head_idx)
                    } else {
                        r.mlp_idx.as_ref()
                    };
                    let t = t.with_context(|| {
                        format!("{name}: routing decision carries no {}", d.name)
                    })?;
                    if t.shape() != d.shape.as_slice() {
                        bail!(
                            "{name}: {} shape {:?} != entry's {:?}",
                            d.name,
                            t.shape(),
                            d.shape
                        );
                    }
                    ins.push(In::Lit(t.to_literal()?));
                }
                other => {
                    let lit = named
                        .iter()
                        .find(|(n, _)| *n == other)
                        .map(|(_, l)| l.clone())
                        .with_context(|| format!("{name}: unsupported data input {other:?}"))?;
                    ins.push(In::Lit(lit));
                }
            }
        }
        if kv_inputs != 1 {
            bail!("{name}: expected exactly one kv input, found {kv_inputs}");
        }
        if self.kv_host_path {
            let mut kv_lit = Some(match kv_store {
                KvStore::Lit(l) => l,
                KvStore::Buf(b) => self.exec.fetch_literal(&b)?,
            });
            let data: Vec<xla::Literal> = ins
                .into_iter()
                .map(|i| match i {
                    In::Lit(l) => l,
                    In::Kv => kv_lit.take().expect("single kv input"),
                })
                .collect();
            let outs = self.exec.run_raw(name, &data)?;
            let logits = Tensor::from_literal(&outs[0])?;
            let kv = KvStore::Lit(outs.into_iter().nth(1).context("kv output")?);
            Ok((logits, kv))
        } else {
            let mut kv_in = Some(match kv_store {
                KvStore::Lit(l) => DeviceInput::Host(l),
                KvStore::Buf(b) => DeviceInput::Buf(b),
            });
            let inputs: Vec<DeviceInput> = ins
                .into_iter()
                .map(|i| match i {
                    In::Lit(l) => DeviceInput::Host(l),
                    In::Kv => kv_in.take().expect("single kv input"),
                })
                .collect();
            let outs = self.exec.run_bufs(name, inputs)?;
            let mut it = outs.into_iter();
            let logits_buf = it.next().context("logits output")?;
            let kv_buf = it.next().context("kv output")?;
            let logits = Tensor::from_literal(&self.exec.fetch_literal(&logits_buf)?)?;
            Ok((logits, KvStore::Buf(kv_buf)))
        }
    }

    /// Block-pool chunked prefill through `prefill_b{B}_s{N}_paged_fused`:
    /// the same per-slot chunk semantics as [`Engine::prefill_chunk`],
    /// with each slot's cache addressed through its block-table row (the
    /// graph resolves prior-context KV through the table and writes the
    /// chunk's new rows straight into their pool blocks — no dense view,
    /// no gather/scatter shell). The logical bucket N is implied by the
    /// tables' width x block size.
    pub fn prefill_chunk_paged(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        offset: &[i32],
        tables: &BlockTables,
        kv: PagedKv,
    ) -> Result<PagedStepOutput> {
        let b = tables.batch;
        let c = self.prefill_chunk_len();
        let n = tables.n(kv.block);
        // everything up to execution happens while we still own the
        // pool: failures park it for `recover_kv` instead of losing it
        let prep = (|| -> Result<[xla::Literal; 4]> {
            if tokens.len() != b * c || lengths.len() != b || offset.len() != b {
                bail!(
                    "prefill_chunk_paged: tokens {} / lengths {} / offset {} vs batch {b} chunk {c}",
                    tokens.len(),
                    lengths.len(),
                    offset.len()
                );
            }
            for i in 0..b {
                let end = offset[i] as usize + lengths[i] as usize;
                if end > n {
                    bail!("prefill_chunk_paged: slot {i} writes to {end} > bucket {n}");
                }
            }
            if tables.flat.iter().any(|&x| x < 0 || x as usize >= kv.pool_blocks) {
                bail!("prefill_chunk_paged: block id out of pool ({})", kv.pool_blocks);
            }
            Ok([
                Tensor::i32(tokens.to_vec(), vec![b, c])?.to_literal()?,
                Tensor::i32(lengths.to_vec(), vec![b])?.to_literal()?,
                Tensor::i32(offset.to_vec(), vec![b])?.to_literal()?,
                tables.to_literal()?,
            ])
        })();
        let [toks, lens, offs, tbl] = match prep {
            Ok(lits) => lits,
            Err(e) => return Err(self.stash_and_err(kv, e)),
        };
        let name = self.exec.manifest().fused_prefill_entry_name(b, n);
        let t0 = std::time::Instant::now();
        let (pool_blocks, block) = (kv.pool_blocks, kv.block);
        let (logits, store) = self.run_kv_entry(
            &name,
            &[("tokens", toks), ("lengths", lens), ("offset", offs), ("block_table", tbl)],
            kv.into_store(),
            None,
        )?;
        let mut p = self.exec.profile_mut();
        p.prefill_ns += t0.elapsed().as_nanos() as u64;
        p.prefill_chunks += 1;
        Ok(PagedStepOutput { logits, kv: PagedKv { store, pool_blocks, block } })
    }

    /// Block-pool decode through `decode_{tag}_b{B}_n{N}_paged_fused` —
    /// the serving hot path. Same index-taking routing convention as
    /// [`Engine::decode`] (the engine runs the artifact routers itself
    /// for direct callers hitting an index-taking entry).
    pub fn decode_paged(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        tables: &BlockTables,
        kv: PagedKv,
        routing: Option<&StepRouting>,
    ) -> Result<PagedStepOutput> {
        let b = tables.batch;
        let n = tables.n(kv.block);
        // everything up to execution happens while we still own the
        // pool: failures park it for `recover_kv` instead of losing it.
        // The fused entry indexes the block table in-graph — no dense KV
        // intermediate, no gather/scatter shell.
        let name = self.exec.manifest().fused_decode_entry_name(tag, b, n);
        let computed;
        let prep = (|| -> Result<(Option<StepRouting>, [xla::Literal; 3])> {
            if tokens.len() != b || lengths.len() != b {
                bail!("decode_paged: tokens/lengths len != batch {b}");
            }
            if let Some(&max) = lengths.iter().max() {
                if max as usize > n {
                    bail!("decode_paged: length {max} exceeds logical bucket {n}");
                }
            }
            if tables.flat.iter().any(|&x| x < 0 || x as usize >= kv.pool_blocks) {
                bail!("decode_paged: block id out of pool ({})", kv.pool_blocks);
            }
            let spec = self.exec.manifest().entry(&name)?;
            let computed = match (routing.is_some(), RoutingPolicy::from_entry(spec)) {
                (false, Some(policy)) => {
                    let bank = self.router_bank().as_ref().with_context(|| {
                        format!(
                            "{name} takes router indices but the artifact has no \
                             router weights (run compile.routers, or serve with \
                             --mode dense)"
                        )
                    })?;
                    let r = bank.route_step(tokens, lengths, None, &policy)?;
                    self.exec.profile_mut().router_ns += r.router_ns;
                    Some(r)
                }
                _ => None,
            };
            let lits = [
                Tensor::i32(tokens.to_vec(), vec![b])?.to_literal()?,
                Tensor::i32(lengths.to_vec(), vec![b])?.to_literal()?,
                tables.to_literal()?,
            ];
            Ok((computed, lits))
        })();
        let (toks, lens, tbl) = match prep {
            Ok((c, [toks, lens, tbl])) => {
                computed = c;
                (toks, lens, tbl)
            }
            Err(e) => return Err(self.stash_and_err(kv, e)),
        };
        let routing = computed.as_ref().or(routing);
        let (pool_blocks, block) = (kv.pool_blocks, kv.block);
        let (logits, store) = self.run_kv_entry(
            &name,
            &[("tokens", toks), ("lengths", lens), ("block_table", tbl)],
            kv.into_store(),
            routing,
        )?;
        self.exec.profile_mut().decode_steps += 1;
        Ok(PagedStepOutput { logits, kv: PagedKv { store, pool_blocks, block } })
    }

    /// Copy physical blocks inside the pool (copy-on-write service).
    ///
    /// On a resident pool this runs the AOT `copy_blocks` entry: the pool
    /// buffer stays on the device, pairs are chunked into fixed-width
    /// calls padded with (0, 0) (null block copied onto itself — an
    /// identity write), and the only host traffic is the tiny index
    /// uploads. Only the bytes logically copied are accounted, as
    /// `cow_bytes` (device-local, not host<->device). A host-literal pool
    /// (the POLAR_KV_HOST A/B baseline, or a legacy artifact without the
    /// entry) falls back to the host-side [`copy_pool_blocks`].
    pub fn copy_kv_blocks(&self, kv: PagedKv, pairs: &[(u32, u32)]) -> Result<PagedKv> {
        if pairs.is_empty() {
            return Ok(kv);
        }
        let (pool_blocks, block) = (kv.pool_blocks, kv.block);
        if let Err(e) = (|| -> Result<()> {
            for &(src, dst) in pairs {
                if src as usize >= pool_blocks || dst as usize >= pool_blocks {
                    bail!("copy_kv_blocks: {src} -> {dst} out of pool ({pool_blocks} blocks)");
                }
            }
            Ok(())
        })() {
            return Err(self.stash_and_err(kv, e));
        }
        let live = pairs.iter().filter(|&&(s, d)| s != d).count() as u64;
        let cow = live * self.exec.config().kv_block_elems(block) as u64 * 4;
        let m = self.exec.manifest();
        let name = m.copy_blocks_entry_name();
        if self.kv_host_path || !m.has_entry(&name) {
            let mut t = match kv.store {
                KvStore::Lit(l) => Tensor::from_literal(&l)?,
                // account the full-pool fetch like any other d2h
                KvStore::Buf(b) => Tensor::from_literal(&self.exec.fetch_literal(&b)?)?,
            };
            copy_pool_blocks(&mut t, pairs)?;
            self.exec.profile_mut().cow_bytes += cow;
            return PagedKv::from_tensor(&t, pool_blocks, block);
        }
        let width = m.copy_pairs.max(1);
        let mut store = kv.into_store();
        for chunk in pairs.chunks(width) {
            let mut src = vec![0i32; width]; // (0, 0) pad: null -> null
            let mut dst = vec![0i32; width];
            for (i, &(s, d)) in chunk.iter().enumerate() {
                src[i] = s as i32;
                dst[i] = d as i32;
            }
            let src_l = Tensor::i32(src, vec![width])?.to_literal()?;
            let dst_l = Tensor::i32(dst, vec![width])?.to_literal()?;
            let kv_in = match store {
                KvStore::Lit(l) => DeviceInput::Host(l),
                KvStore::Buf(b) => DeviceInput::Buf(b),
            };
            let outs = self.exec.run_bufs(
                &name,
                vec![DeviceInput::Host(src_l), DeviceInput::Host(dst_l), kv_in],
            )?;
            store = KvStore::Buf(outs.into_iter().next().context("copy_blocks kv output")?);
        }
        self.exec.profile_mut().cow_bytes += cow;
        Ok(PagedKv { store, pool_blocks, block })
    }

}

