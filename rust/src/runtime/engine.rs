//! Model engine: prefill / decode step API over compiled entries, plus the
//! pipeline-parallel and tensor-parallel drivers (Figs 11, 12).
//!
//! The decode hot path keeps the KV cache as an `xla::Literal` that flows
//! output -> input across steps without host-side reshaping. (The 0.1.6
//! crate cannot donate buffers or decompose tuples on device, so each step
//! still pays one host copy of the tuple output — see DESIGN.md §Perf.)

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::executor::Executor;
use super::tensor::Tensor;

/// Batched KV cache at a fixed (batch, seq) bucket.
pub struct KvCache {
    pub lit: xla::Literal,
    pub batch: usize,
    pub n: usize,
}

impl KvCache {
    pub fn to_tensor(&self) -> Result<Tensor> {
        Tensor::from_literal(&self.lit)
    }

    pub fn from_tensor(t: &Tensor, batch: usize, n: usize) -> Result<KvCache> {
        Ok(KvCache { lit: t.to_literal()?, batch, n })
    }
}

pub struct StepOutput {
    pub logits: Tensor, // [B, V]
    pub kv: KvCache,
}

#[derive(Clone)]
pub struct Engine {
    pub exec: Arc<Executor>,
}

impl Engine {
    pub fn new(exec: Arc<Executor>) -> Engine {
        Engine { exec }
    }

    pub fn vocab(&self) -> usize {
        self.exec.config().vocab
    }

    /// Pre-compile every (batch, seq) bucket of a decode mode plus the
    /// prefill entries, so serving never pays a JIT stall mid-request
    /// (the CUDA-graph capture analogue). Returns the number compiled.
    pub fn precompile(&self, tag: &str) -> Result<usize> {
        let m = self.exec.manifest();
        let mut n = 0;
        let names: Vec<String> = m
            .batch_buckets
            .iter()
            .flat_map(|&b| {
                let mut v: Vec<String> = m
                    .seq_buckets
                    .iter()
                    .map(|&s| m.decode_entry_name(tag, b, s))
                    .collect();
                v.push(m.prefill_entry_name(b));
                v
            })
            .collect();
        for name in names {
            if m.entries.contains_key(&name) && !self.exec.is_cached(&name) {
                self.exec.compiled(&name)?;
                n += 1;
            }
        }
        Ok(n)
    }

    /// Dense prompt pass at the prefill bucket. tokens: [B, S_prefill]
    /// (padded), lengths: [B]. Returns last-position logits + KV (n =
    /// prefill bucket).
    pub fn prefill(&self, tokens: &Tensor, lengths: &Tensor) -> Result<StepOutput> {
        let b = tokens.shape()[0];
        let name = self.exec.manifest().prefill_entry_name(b);
        let outs = self
            .exec
            .run_raw(&name, &[tokens.to_literal()?, lengths.to_literal()?])?;
        let logits = Tensor::from_literal(&outs[0])?;
        let n = self.exec.manifest().prefill_len;
        let kv = KvCache { lit: outs.into_iter().nth(1).unwrap(), batch: b, n };
        Ok(StepOutput { logits, kv })
    }

    /// One decode step through the entry `decode_{tag}_b{B}_n{N}`.
    /// tokens/lengths: per-slot [B]; lengths already include the new token.
    pub fn decode(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv: KvCache,
    ) -> Result<StepOutput> {
        let b = kv.batch;
        if tokens.len() != b || lengths.len() != b {
            bail!("decode: tokens/lengths len != batch {b}");
        }
        if let Some(&max) = lengths.iter().max() {
            if max as usize > kv.n {
                bail!("decode: length {max} exceeds kv bucket {}", kv.n);
            }
        }
        let name = self.exec.manifest().decode_entry_name(tag, b, kv.n);
        let toks = Tensor::i32(tokens.to_vec(), vec![b])?.to_literal()?;
        let lens = Tensor::i32(lengths.to_vec(), vec![b])?.to_literal()?;
        let outs = self.exec.run_raw(&name, &[toks, lens, kv.lit])?;
        let logits = Tensor::from_literal(&outs[0])?;
        let kv = KvCache { lit: outs.into_iter().nth(1).unwrap(), batch: b, n: kv.n };
        Ok(StepOutput { logits, kv })
    }

    // -- pipeline parallel (2 stages, Fig 11) -----------------------------

    /// One decode step through the two pipeline stages. kv0/kv1 hold the
    /// stage-local layer slices (split by `coordinator::kv::split_layers`).
    pub fn decode_pp2(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv0: KvCache,
        kv1: KvCache,
        n: usize,
    ) -> Result<(Tensor, KvCache, KvCache)> {
        let b = tokens.len();
        let toks = Tensor::i32(tokens.to_vec(), vec![b])?.to_literal()?;
        let lens = Tensor::i32(lengths.to_vec(), vec![b])?.to_literal()?;
        let s0 = format!("pp2_stage0_{tag}_b{b}_n{n}");
        let outs0 = self.exec.run_raw(&s0, &[toks, lens, kv0.lit])?;
        let mut it0 = outs0.into_iter();
        let x = it0.next().context("stage0 x")?;
        let kv0 = KvCache { lit: it0.next().context("stage0 kv")?, batch: b, n };

        let lens = Tensor::i32(lengths.to_vec(), vec![b])?.to_literal()?;
        let s1 = format!("pp2_stage1_{tag}_b{b}_n{n}");
        let outs1 = self.exec.run_raw(&s1, &[x, lens, kv1.lit])?;
        let mut it1 = outs1.into_iter();
        let logits = Tensor::from_literal(&it1.next().context("stage1 logits")?)?;
        let kv1 = KvCache { lit: it1.next().context("stage1 kv")?, batch: b, n };
        Ok((logits, kv0, kv1))
    }

    // -- tensor parallel (Megatron-style, Fig 12) --------------------------

    /// One decode step across `n_shards` TP shards with host all-reduce
    /// after attention and MLP of every layer. `kv[shard][layer]` holds
    /// [2,B,Gs,N,dh] literals. `attn_tag` is "dense" or "sha_dXXXX"
    /// (layer 0 always uses "dense", §3.2); `mlp_tag` is "dense" or "kNN".
    #[allow(clippy::too_many_arguments)]
    pub fn decode_tp(
        &self,
        n_shards: usize,
        attn_tag: &str,
        mlp_tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv: Vec<Vec<xla::Literal>>,
        n: usize,
        parallel: bool,
    ) -> Result<(Tensor, Vec<Vec<xla::Literal>>)> {
        let b = tokens.len();
        let cfg = self.exec.config();
        let toks = Tensor::i32(tokens.to_vec(), vec![b])?.to_literal()?;
        let lens_t = Tensor::i32(lengths.to_vec(), vec![b])?;
        let embed = self
            .exec
            .run_raw(&format!("tp{n_shards}_embed_b{b}"), &[toks, lens_t.to_literal()?])?;
        let mut x = Tensor::from_literal(&embed[0])?;

        let mut kv_new: Vec<Vec<xla::Literal>> =
            (0..n_shards).map(|_| Vec::new()).collect();
        let mut kv = kv;
        for l in 0..cfg.n_layers {
            let tag = if l == 0 { "dense" } else { attn_tag };
            // attention shards (+ local kv update)
            let shard_outs = self.run_shards(
                n_shards,
                parallel,
                |s| format!("tp{n_shards}_attn_s{s}_{tag}_b{b}_n{n}"),
                |s| {
                    Ok(vec![
                        Tensor::i32(vec![l as i32], vec![])?.to_literal()?,
                        x.to_literal()?,
                        std::mem::replace(&mut kv[s][l], empty_literal()),
                        lens_t.to_literal()?,
                    ])
                },
            )?;
            let xd = x.as_f32_mut()?;
            for (s, outs) in shard_outs.into_iter().enumerate() {
                let mut it = outs.into_iter();
                let partial = Tensor::from_literal(&it.next().context("attn partial")?)?;
                for (xi, pi) in xd.iter_mut().zip(partial.as_f32()?) {
                    *xi += pi; // host all-reduce: sum partials into residual
                }
                kv_new[s].push(it.next().context("attn kv")?);
            }
            // MLP shards
            let shard_outs = self.run_shards(
                n_shards,
                parallel,
                |s| format!("tp{n_shards}_mlp_s{s}_{mlp_tag}_b{b}"),
                |_| {
                    Ok(vec![
                        Tensor::i32(vec![l as i32], vec![])?.to_literal()?,
                        x.to_literal()?,
                    ])
                },
            )?;
            let xd = x.as_f32_mut()?;
            for outs in shard_outs {
                let partial = Tensor::from_literal(&outs[0])?;
                for (xi, pi) in xd.iter_mut().zip(partial.as_f32()?) {
                    *xi += pi;
                }
            }
        }
        let fin = self
            .exec
            .run_raw(&format!("tp{n_shards}_final_b{b}"), &[x.to_literal()?])?;
        Ok((Tensor::from_literal(&fin[0])?, kv_new))
    }

    /// Run one executable per shard, optionally on worker threads (the
    /// host-side analogue of simultaneous multi-GPU dispatch).
    fn run_shards(
        &self,
        n_shards: usize,
        parallel: bool,
        name: impl Fn(usize) -> String + Sync,
        inputs: impl FnMut(usize) -> Result<Vec<xla::Literal>>,
    ) -> Result<Vec<Vec<xla::Literal>>> {
        let mut inputs = inputs;
        let mut prepared = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            prepared.push((name(s), inputs(s)?));
        }
        if parallel {
            // SAFETY: PJRT execution is thread-safe; Literal is only moved,
            // not aliased, across the scope boundary (see Executor note).
            struct SendLits(Vec<xla::Literal>);
            unsafe impl Send for SendLits {}
            let exec = &self.exec;
            std::thread::scope(|scope| {
                let handles: Vec<_> = prepared
                    .into_iter()
                    .map(|(nm, ins)| {
                        let ins = SendLits(ins);
                        scope.spawn(move || {
                            // rebind to defeat disjoint-field capture (which
                            // would capture the inner Vec<Literal> directly)
                            let ins = ins;
                            exec.run_raw(&nm, &ins.0).map(SendLits)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("shard thread panicked").map(|r| r.0))
                    .collect()
            })
        } else {
            prepared
                .into_iter()
                .map(|(nm, ins)| self.exec.run_raw(&nm, &ins))
                .collect()
        }
    }
}

fn empty_literal() -> xla::Literal {
    xla::Literal::scalar(0f32)
}
