//! Overload scenario suite: deterministic multi-tenant workloads for the
//! SLO-aware overload controller (`coordinator::overload`). Each
//! generator maps a seed to the exact same request sequence — prompts
//! are synthetic token ids (no tokenizer), so the traces replay
//! byte-for-byte against the mock engine in tests and `bench overload`.
//!
//! Four shapes, matching the conditions the admission/preemption policy
//! has to survive:
//!
//! - [`bursty`]: Poisson bursts separated by quiet gaps — arrival-rate
//!   spikes that overcommit the KV block pool.
//! - [`heavy_tail`]: mostly short prompts with a heavy tail of long
//!   ones — a single long request can hold blocks hostage.
//! - [`two_tenant`]: an interactive tenant (high priority, tight
//!   deadlines) sharing the pool with a batch tenant (low priority,
//!   no deadlines) — the preemption rank order is what keeps the
//!   interactive SLO.
//! - [`chat_sessions`]: multi-turn sessions re-sending a shared
//!   session prefix — resume-after-preemption and admission both lean
//!   on the prefix cache.
//! - [`fault_mix`]: disjoint per-request token bands so a fault
//!   injector can poison individual requests by token value alone —
//!   the replay trace behind `bench fault-recovery`.

use std::time::Duration;

use crate::coordinator::{Request, SamplingParams};
use crate::substrate::rng::Rng;

use super::TimedRequest;

/// Knobs shared by every scenario generator.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub n_requests: usize,
    pub seed: u64,
    pub max_new_tokens: usize,
    /// Deadline applied to deadline-carrying requests (ms); 0 = none.
    pub deadline_ms: f64,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig { n_requests: 48, seed: 0, max_new_tokens: 8, deadline_ms: 0.0 }
    }
}

/// Synthetic prompt: ids in [20, 220) so the mock's +1 chain never
/// trips the byte-range newline stop within a scenario's budget.
fn prompt_ids(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| (20 + rng.below(200)) as i32).collect()
}

fn build(
    id: u64,
    at_s: f64,
    ids: Vec<i32>,
    priority: i32,
    deadline_ms: f64,
    max_new: usize,
) -> TimedRequest {
    let mut b = Request::builder(ids)
        .id(id)
        .params(SamplingParams { max_new_tokens: max_new, ..Default::default() })
        .priority(priority);
    if deadline_ms > 0.0 {
        b = b.deadline(Duration::from_secs_f64(deadline_ms / 1e3));
    }
    TimedRequest { at_s, request: b.build() }
}

/// Poisson bursts: groups of near-simultaneous arrivals (intra-burst
/// rate 400/s) separated by 80 ms quiet gaps. Every burst overcommits
/// a small block pool on its own.
pub fn bursty(cfg: &ScenarioConfig) -> Vec<TimedRequest> {
    let mut rng = Rng::new(cfg.seed);
    let burst = (cfg.n_requests / 4).max(4);
    let mut t = 0.0f64;
    (0..cfg.n_requests)
        .map(|i| {
            if i > 0 {
                t += if i % burst == 0 { 0.08 } else { rng.exponential(400.0) };
            }
            let len = rng.range(40, 57);
            let ids = prompt_ids(&mut rng, len);
            build(i as u64, t, ids, 0, cfg.deadline_ms, cfg.max_new_tokens)
        })
        .collect()
}

/// Heavy-tailed prompt lengths: ~7/8 short (8..=16 ids), ~1/8 long
/// (48..=56 ids), steady Poisson arrivals at 150/s. The long requests
/// pin several blocks each and become the natural preemption victims.
pub fn heavy_tail(cfg: &ScenarioConfig) -> Vec<TimedRequest> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    (0..cfg.n_requests)
        .map(|i| {
            if i > 0 {
                t += rng.exponential(150.0);
            }
            let len = if rng.below(8) == 0 { rng.range(48, 57) } else { rng.range(8, 17) };
            let ids = prompt_ids(&mut rng, len);
            build(i as u64, t, ids, 0, cfg.deadline_ms, cfg.max_new_tokens)
        })
        .collect()
}

/// Two tenants sharing the pool: even ids are the interactive tenant
/// (priority 5, deadline `cfg.deadline_ms`, short prompts), odd ids the
/// batch tenant (priority 0, no deadline, long prompts and a 3x token
/// budget — batch jobs hold their blocks long enough that the
/// interactive tenant's rank has to preempt them). Arrivals interleave
/// at 120/s.
pub fn two_tenant(cfg: &ScenarioConfig) -> Vec<TimedRequest> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    (0..cfg.n_requests)
        .map(|i| {
            if i > 0 {
                t += rng.exponential(120.0);
            }
            let interactive = i % 2 == 0;
            let len = if interactive { rng.range(10, 25) } else { rng.range(40, 57) };
            let ids = prompt_ids(&mut rng, len);
            let (prio, dl, max_new) = if interactive {
                (5, cfg.deadline_ms, cfg.max_new_tokens)
            } else {
                (0, 0.0, cfg.max_new_tokens * 3)
            };
            build(i as u64, t, ids, prio, dl, max_new)
        })
        .collect()
}

/// Multi-turn chat sessions: `n_requests / 4` sessions, each re-sending
/// a fixed 32-id session prefix (two full 16-token blocks) plus a
/// per-turn suffix, turns spaced 30 ms apart. Later turns of a session
/// re-hit the prefix cache — both at first admission and on
/// resume-after-preemption.
pub fn chat_sessions(cfg: &ScenarioConfig) -> Vec<TimedRequest> {
    let mut rng = Rng::new(cfg.seed);
    let sessions = (cfg.n_requests / 4).max(1);
    let prefixes: Vec<Vec<i32>> =
        (0..sessions).map(|_| prompt_ids(&mut rng, 32)).collect();
    let mut out = Vec::with_capacity(cfg.n_requests);
    for i in 0..cfg.n_requests {
        let session = i % sessions;
        let turn = i / sessions;
        let mut ids = prefixes[session].clone();
        ids.extend(prompt_ids(&mut rng, 4 + rng.below(8)));
        let t = turn as f64 * 0.03 + session as f64 * 0.002;
        out.push(build(i as u64, t, ids, 0, cfg.deadline_ms, cfg.max_new_tokens));
    }
    out.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
    out
}

/// Fault-injection trace: request `i`'s prompt ids all equal
/// `20 + (i % 20) * 10`, giving each request its own band of ten token
/// values (disjoint for up to 20 requests — the mock's +1 decode chain
/// stays inside the band for `max_new <= 9`). A [`FaultScript`]'s
/// `poison_token_range`/`nan_token_range` can then target exactly one
/// request, which is what lets `bench fault-recovery` gate that every
/// *other* request replays bit-identical under faults. No deadlines:
/// retry backoff must never turn a healthy request into an SLO miss,
/// or the bit-identical comparison against the fault-free run breaks.
///
/// [`FaultScript`]: crate::coordinator::FaultScript
pub fn fault_mix(cfg: &ScenarioConfig) -> Vec<TimedRequest> {
    (0..cfg.n_requests)
        .map(|i| {
            let band = 20 + ((i % 20) as i32) * 10;
            let len = 8 + (i % 3) * 4; // 8 / 12 / 16 ids, all the band value
            let ids = vec![band; len];
            build(i as u64, i as f64 * 0.004, ids, 0, 0.0, cfg.max_new_tokens)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_deterministic(gen: fn(&ScenarioConfig) -> Vec<TimedRequest>) {
        let cfg = ScenarioConfig { n_requests: 24, seed: 7, ..Default::default() };
        let (a, b) = (gen(&cfg), gen(&cfg));
        assert_eq!(a.len(), 24);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.prompt_ids, y.request.prompt_ids);
            assert_eq!(x.request.priority, y.request.priority);
            assert_eq!(x.request.deadline, y.request.deadline);
            assert!((x.at_s - y.at_s).abs() < 1e-12);
        }
        for pair in a.windows(2) {
            assert!(pair[1].at_s >= pair[0].at_s, "arrivals must be monotone");
        }
    }

    #[test]
    fn all_scenarios_are_deterministic_with_monotone_arrivals() {
        assert_deterministic(bursty);
        assert_deterministic(heavy_tail);
        assert_deterministic(two_tenant);
        assert_deterministic(chat_sessions);
        assert_deterministic(fault_mix);
    }

    #[test]
    fn fault_mix_bands_are_disjoint_and_deadline_free() {
        let w = fault_mix(&ScenarioConfig { n_requests: 16, ..Default::default() });
        let mut bands = Vec::new();
        for r in &w {
            let first = r.request.prompt_ids[0];
            assert!(r.request.prompt_ids.iter().all(|&t| t == first));
            assert!(r.request.deadline.is_none(), "deadlines would break replay");
            bands.push(first);
        }
        bands.sort();
        bands.dedup();
        assert_eq!(bands.len(), 16, "one private token band per request");
        // +1 decode chains stay inside a request's own band of ten
        for pair in bands.windows(2) {
            assert!(pair[1] - pair[0] >= 10);
        }
    }

    #[test]
    fn bursty_arrivals_cluster_into_bursts() {
        let w = bursty(&ScenarioConfig { n_requests: 24, ..Default::default() });
        // 4 bursts of 6: exactly 3 inter-burst gaps of >= 80 ms
        let gaps = w
            .windows(2)
            .filter(|p| p[1].at_s - p[0].at_s >= 0.08)
            .count();
        assert_eq!(gaps, 3, "arrivals: {:?}", w.iter().map(|r| r.at_s).collect::<Vec<_>>());
    }

    #[test]
    fn heavy_tail_mixes_short_and_long_prompts() {
        let w = heavy_tail(&ScenarioConfig { n_requests: 64, ..Default::default() });
        let long = w.iter().filter(|r| r.request.prompt_ids.len() >= 48).count();
        let short = w.iter().filter(|r| r.request.prompt_ids.len() <= 16).count();
        assert!(long >= 2, "expected a long tail, got {long}");
        assert!(short > w.len() / 2, "body should be short prompts, got {short}");
    }

    #[test]
    fn two_tenant_splits_priority_and_deadlines() {
        let cfg = ScenarioConfig { n_requests: 20, deadline_ms: 250.0, ..Default::default() };
        let w = two_tenant(&cfg);
        for r in &w {
            let interactive = r.request.id % 2 == 0;
            assert_eq!(r.request.priority, if interactive { 5 } else { 0 });
            assert_eq!(r.request.deadline.is_some(), interactive);
        }
    }

    #[test]
    fn chat_sessions_share_block_aligned_prefixes() {
        let w = chat_sessions(&ScenarioConfig { n_requests: 16, ..Default::default() });
        // 4 sessions x 4 turns: every turn of a session starts with the
        // same 32-id prefix, and distinct sessions have distinct prefixes
        let mut by_session: std::collections::BTreeMap<u64, Vec<&[i32]>> = Default::default();
        for r in &w {
            by_session
                .entry(r.request.id % 4)
                .or_default()
                .push(&r.request.prompt_ids[..32]);
        }
        assert_eq!(by_session.len(), 4);
        let mut firsts = Vec::new();
        for (_, prefixes) in &by_session {
            assert_eq!(prefixes.len(), 4);
            assert!(prefixes.iter().all(|p| p == &prefixes[0]));
            firsts.push(prefixes[0]);
        }
        firsts.sort();
        firsts.dedup();
        assert_eq!(firsts.len(), 4, "sessions must not share prefixes");
    }
}
