//! Serving-trace record/replay: persist a generated workload (arrival
//! times + prompts) as JSONL so throughput experiments are replayable
//! byte-for-byte across modes (dense vs DejaVu vs Polar use the *same*
//! trace in the benches).

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::{Request, SamplingParams};
use crate::substrate::json::Json;

use super::TimedRequest;

pub fn save(path: &Path, reqs: &[TimedRequest]) -> Result<()> {
    let mut out = String::new();
    for r in reqs {
        let mut j = Json::obj(vec![
            ("id", (r.request.id as usize).into()),
            ("at_s", r.at_s.into()),
            (
                "prompt_ids",
                Json::arr(r.request.prompt_ids.iter().map(|&t| (t as i64).into())),
            ),
            ("max_new", r.request.params.max_new_tokens.into()),
            ("temperature", (r.request.params.temperature as f64).into()),
        ]);
        // SLO fields (overload scenarios): keep lines minimal for the
        // common no-priority, no-deadline case
        if r.request.priority != 0 {
            j.set("priority", (r.request.priority as i64).into());
        }
        if let Some(d) = r.request.deadline {
            j.set("deadline_ms", (d.as_secs_f64() * 1e3).into());
        }
        out.push_str(&j.to_string());
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

pub fn load(path: &Path) -> Result<Vec<TimedRequest>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).with_context(|| format!("trace line {}", i + 1))?;
        let prompt_ids = j
            .get("prompt_ids")
            .as_arr()
            .context("prompt_ids")?
            .iter()
            .map(|v| v.as_i64().map(|x| x as i32).context("token id"))
            .collect::<Result<Vec<i32>>>()?;
        let mut b = Request::builder(prompt_ids)
            .id(j.get("id").as_usize().unwrap_or(i) as u64)
            .params(SamplingParams {
                max_new_tokens: j.get("max_new").as_usize().unwrap_or(16),
                temperature: j.get("temperature").as_f64().unwrap_or(0.0) as f32,
                ..Default::default()
            })
            .priority(j.get("priority").as_i64().unwrap_or(0) as i32);
        if let Some(ms) = j.get("deadline_ms").as_f64() {
            b = b.deadline(std::time::Duration::from_secs_f64((ms / 1e3).max(0.0)));
        }
        out.push(TimedRequest { at_s: j.get("at_s").as_f64().unwrap_or(0.0), request: b.build() });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, WorkloadConfig};

    #[test]
    fn roundtrip() {
        let reqs = generate(&WorkloadConfig {
            n_requests: 7,
            arrival_rate: 10.0,
            ..Default::default()
        });
        let dir = std::env::temp_dir().join("ps_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trace.jsonl");
        save(&p, &reqs).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), reqs.len());
        for (a, b) in reqs.iter().zip(&back) {
            assert_eq!(a.request.id, b.request.id);
            assert_eq!(a.request.prompt_ids, b.request.prompt_ids);
            assert!((a.at_s - b.at_s).abs() < 1e-9);
            assert_eq!(
                a.request.params.max_new_tokens,
                b.request.params.max_new_tokens
            );
        }
    }

    /// SLO fields survive the wire: priority and deadline_ms round-trip
    /// so overload traces replay with the exact same rank order.
    #[test]
    fn roundtrip_preserves_priority_and_deadline() {
        let reqs = crate::workload::scenarios::two_tenant(
            &crate::workload::scenarios::ScenarioConfig {
                n_requests: 10,
                deadline_ms: 250.0,
                ..Default::default()
            },
        );
        let dir = std::env::temp_dir().join("ps_trace_slo_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trace.jsonl");
        save(&p, &reqs).unwrap();
        let back = load(&p).unwrap();
        for (a, b) in reqs.iter().zip(&back) {
            assert_eq!(a.request.priority, b.request.priority);
            assert_eq!(a.request.deadline, b.request.deadline);
        }
        // the two tenants actually differ, so the assertions bite
        assert!(back.iter().any(|r| r.request.priority == 5));
        assert!(back.iter().any(|r| r.request.deadline.is_none()));
    }
}
