//! Zero-shot task suite (the lm-eval-harness analogue).
//!
//! The fixed eval set is produced by python/compile/corpus.py
//! (artifacts/eval_tasks.jsonl) so rust and python score identical
//! instances. Scoring protocol: greedy-decode after the prompt's '='
//! delimiter; exact match of the expected answer (continuation up to the
//! stop token).

use std::path::Path;

use anyhow::{Context, Result};

use crate::substrate::json::Json;

pub const FAMILIES: [&str; 9] = [
    "copy", "rev", "succ", "add", "maj", "cmp", "srt", "kv", "pat",
];

#[derive(Debug, Clone)]
pub struct TaskItem {
    pub family: String,
    pub prompt: String,
    pub answer: String,
}

/// Load the fixed eval suite written at artifact-build time.
pub fn load_suite(path: &Path) -> Result<Vec<TaskItem>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut items = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).with_context(|| format!("line {}", i + 1))?;
        items.push(TaskItem {
            family: j.get("family").as_str().context("family")?.to_string(),
            prompt: j.get("prompt").as_str().context("prompt")?.to_string(),
            answer: j.get("answer").as_str().context("answer")?.to_string(),
        });
    }
    Ok(items)
}

/// Exact-match scoring of a generated continuation against the answer.
/// The generation may include the stop token ('\n') after the answer.
pub fn is_correct(item: &TaskItem, generated: &str) -> bool {
    let g = generated.split('\n').next().unwrap_or("");
    g == item.answer
}

/// Per-family + aggregate accuracy.
#[derive(Debug, Default, Clone)]
pub struct SuiteScore {
    pub per_family: Vec<(String, f64, usize)>, // (family, accuracy, n)
    pub average: f64,
}

pub fn score(results: &[(TaskItem, String)]) -> SuiteScore {
    let mut agg: std::collections::BTreeMap<String, (usize, usize)> = Default::default();
    for (item, gen) in results {
        let e = agg.entry(item.family.clone()).or_default();
        e.1 += 1;
        if is_correct(item, gen) {
            e.0 += 1;
        }
    }
    let per_family: Vec<(String, f64, usize)> = agg
        .into_iter()
        .map(|(f, (c, n))| (f, c as f64 / n.max(1) as f64, n))
        .collect();
    let average = if per_family.is_empty() {
        0.0
    } else {
        per_family.iter().map(|(_, a, _)| a).sum::<f64>() / per_family.len() as f64
    };
    SuiteScore { per_family, average }
}

/// A small built-in prompt set for workload generation (serving benches
/// don't need the fixed suite, just realistic prompt shapes).
pub fn builtin_prompts() -> Vec<String> {
    vec![
        "copy:abcde=".into(),
        "rev:abc=".into(),
        "succ:f=".into(),
        "add:17+25=".into(),
        "maj:aabab=".into(),
        "cmp:4,7=".into(),
        "srt:cab=".into(),
        "kv:a1 b2 c3?b=".into(),
        "pat:ababab*=".into(),
        "the scheduler groups requests into batches. copy:ab=".into(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(fam: &str, prompt: &str, ans: &str) -> TaskItem {
        TaskItem {
            family: fam.into(),
            prompt: prompt.into(),
            answer: ans.into(),
        }
    }

    #[test]
    fn exact_match_scoring() {
        let it = item("copy", "copy:ab=", "ab");
        assert!(is_correct(&it, "ab"));
        assert!(is_correct(&it, "ab\nextra"));
        assert!(!is_correct(&it, "abx"));
        assert!(!is_correct(&it, "a"));
    }

    #[test]
    fn aggregate_score() {
        let results = vec![
            (item("copy", "p", "x"), "x".to_string()),
            (item("copy", "p", "y"), "z".to_string()),
            (item("rev", "p", "q"), "q".to_string()),
        ];
        let s = score(&results);
        assert_eq!(s.per_family.len(), 2);
        let copy = s.per_family.iter().find(|(f, _, _)| f == "copy").unwrap();
        assert!((copy.1 - 0.5).abs() < 1e-9);
        assert!((s.average - 0.75).abs() < 1e-9);
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join("ps_tasks_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("tasks.jsonl");
        std::fs::write(
            &p,
            "{\"family\":\"copy\",\"prompt\":\"copy:ab=\",\"answer\":\"ab\"}\n\
             {\"family\":\"add\",\"prompt\":\"add:1+1=\",\"answer\":\"2\"}\n",
        )
        .unwrap();
        let items = load_suite(&p).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[1].answer, "2");
    }
}
