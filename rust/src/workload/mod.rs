//! Synthetic serving workloads: request generators (Poisson arrivals,
//! prompt-length distributions), the overload scenario suite
//! ([`scenarios`]: bursty Poisson, heavy-tail prompts, two-tenant
//! priority mixes, chat sessions re-hitting the prefix cache), the
//! zero-shot task suite reader (artifacts/eval_tasks.jsonl, written by
//! python/compile/corpus.py), and trace record/replay.

pub mod scenarios;
pub mod tasks;
pub mod trace;

use crate::coordinator::{Request, SamplingParams};
use crate::substrate::rng::Rng;
use crate::tokenizer::Tokenizer;

/// Workload generator configuration.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_requests: usize,
    /// Poisson arrival rate (requests/sec); 0 => all arrive at t=0.
    pub arrival_rate: f64,
    pub prompt_len_min: usize,
    pub prompt_len_max: usize,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            n_requests: 32,
            arrival_rate: 0.0,
            prompt_len_min: 8,
            prompt_len_max: 48,
            max_new_tokens: 24,
            temperature: 0.0,
            seed: 0,
        }
    }
}

/// A request plus its arrival offset from workload start.
#[derive(Debug, Clone)]
pub struct TimedRequest {
    pub at_s: f64,
    pub request: Request,
}

/// Generate a batch of requests from task-suite-shaped prompts.
pub fn generate(cfg: &WorkloadConfig) -> Vec<TimedRequest> {
    let mut rng = Rng::new(cfg.seed);
    let tok = Tokenizer::new();
    let suite = tasks::builtin_prompts();
    let mut t = 0.0f64;
    (0..cfg.n_requests)
        .map(|i| {
            if cfg.arrival_rate > 0.0 {
                t += rng.exponential(cfg.arrival_rate);
            }
            // prompt: a task-style line, padded with corpus-like filler to
            // hit the target length distribution
            let base = &suite[rng.below(suite.len())];
            let target = rng.range(cfg.prompt_len_min, cfg.prompt_len_max + 1);
            let mut text = base.clone();
            while text.len() < target.saturating_sub(1) {
                text.insert(0, ' ');
                text.insert(0, b"theandofwork"[rng.below(12)] as char);
            }
            let mut prompt_ids = tok.encode_prompt(&text);
            prompt_ids.truncate(target.max(2));
            TimedRequest {
                at_s: t,
                request: Request::builder(prompt_ids)
                    .id(i as u64)
                    .params(SamplingParams {
                        temperature: cfg.temperature,
                        max_new_tokens: cfg.max_new_tokens,
                        seed: cfg.seed,
                        ..Default::default()
                    })
                    .build(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count_with_monotone_arrivals() {
        let cfg = WorkloadConfig {
            n_requests: 20,
            arrival_rate: 100.0,
            ..Default::default()
        };
        let w = generate(&cfg);
        assert_eq!(w.len(), 20);
        for pair in w.windows(2) {
            assert!(pair[1].at_s >= pair[0].at_s);
        }
        for r in &w {
            let len = r.request.prompt_ids.len();
            assert!(len >= 2 && len <= cfg.prompt_len_max, "len {len}");
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let cfg = WorkloadConfig { n_requests: 5, seed: 9, ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.prompt_ids, y.request.prompt_ids);
        }
    }
}
