//! Benchmark harness regenerating every table and figure of the paper's
//! evaluation (see DESIGN.md experiment index). Each `figNN` module prints
//! the rows the paper reports and writes results/<fig>.csv.

pub mod accuracy;
pub mod decode_breakdown;
pub mod fault_recovery;
pub mod figures;
pub mod harness;
pub mod kv_paging;
pub mod overload;
pub mod prefill_interference;
pub mod serving;
pub mod shard_scaling;
pub mod sparsity_scaling;
pub mod throughput;

pub use harness::{
    fmt_ms, fmt_x, pretty_json, time_it, write_bench_json, BenchOpts, Report,
};
