//! `bench decode-breakdown` — A/B breakdown of one decode step's cost:
//! h2d / compute / d2h / host-surgery time and, crucially, the bytes
//! crossing the host<->device boundary per step, for the legacy host-KV
//! path vs. the resident-device-KV path — plus the paged fused-vs-twin
//! contrast: the deprecated twin entries stage a dense KV view both ways
//! around the decode core (`gather_bytes`/`scatter_bytes`), the fused
//! entries index the block pool in place and must report ~0. The run
//! FAILS if the fused path moves shell bytes. Emits `BENCH_decode.json`
//! so every PR's CI run records the perf trajectory.
//!
//! `--smoke` runs against the deterministic mock engine (no AOT
//! artifacts): byte counters are analytic and reproducible; timing fields
//! are whatever the host measured.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::mock::MockEngine;
use crate::coordinator::{Mode, SparsityController, StepEngine};
use crate::runtime::{BlockTables, Engine, Executor, KvCache, StepProfile, Tensor};
use crate::substrate::argparse::Args;
use crate::substrate::json::Json;
use crate::tokenizer::PAD;

struct PathRun {
    profile: StepProfile,
    n: usize,
    wall_s: f64,
}

/// Prefill a steady batch (one chunk call into a zeroed cache at the
/// smallest seq bucket), then run `steps` decode steps, feeding each
/// step's KV output into the next — exactly the scheduler's hot loop,
/// minus composition changes. The profile covers only the decode loop.
fn run_path<E: StepEngine>(e: &E, tag: &str, b: usize, steps: usize) -> Result<PathRun> {
    let c = e.prefill_chunk_len();
    let n = e.seq_buckets()[0];
    let prompt_len = 4.min(c).min(n - 1);
    let mut toks = vec![PAD; b * c];
    let mut lens = vec![0i32; b];
    let offs = vec![0i32; b];
    for i in 0..b {
        for j in 0..prompt_len {
            toks[i * c + j] = 40 + i as i32;
        }
        lens[i] = prompt_len as i32;
    }
    let cfg = e.config().clone();
    let fresh = KvCache::from_tensor(&Tensor::zeros_f32(cfg.kv_shape(b, n)), b, n)?;
    let out = e.prefill_chunk(&toks, &lens, &offs, fresh)?;
    let mut kv = out.kv;
    let n = kv.n;
    e.reset_profile();
    let tokens: Vec<i32> = (0..b).map(|i| 60 + i as i32).collect();
    let lengths = vec![(prompt_len + 1) as i32; b];
    let t0 = Instant::now();
    for _ in 0..steps {
        let o = e.decode(tag, &tokens, &lengths, kv, None)?;
        kv = o.kv;
    }
    Ok(PathRun { profile: e.profile_snapshot(), n, wall_s: t0.elapsed().as_secs_f64() })
}

/// The paged counterpart of [`run_path`]: the same steady batch and
/// decode loop, but served from the block pool through per-slot block
/// tables (slot `i` owns blocks `1 + i*width ..`). Twin entries account
/// the dense view they stage both ways (`gather_bytes`/`scatter_bytes`);
/// fused entries index the pool in place and account 0. The profile
/// covers only the decode loop.
fn run_paged_path<E: StepEngine>(e: &E, tag: &str, b: usize, steps: usize) -> Result<PathRun> {
    let c = e.prefill_chunk_len();
    let n = e.seq_buckets()[0];
    let (bs, pool_blocks) = e.kv_layout();
    let width = (n + bs - 1) / bs;
    if 1 + b * width > pool_blocks {
        bail!("pool too small: {pool_blocks} blocks for {b} slots x {width}");
    }
    let prompt_len = 4.min(c).min(n - 1);
    let mut toks = vec![PAD; b * c];
    let mut lens = vec![0i32; b];
    let offs = vec![0i32; b];
    let mut flat = vec![0i32; b * width];
    for i in 0..b {
        for j in 0..prompt_len {
            toks[i * c + j] = 40 + i as i32;
        }
        lens[i] = prompt_len as i32;
        for w in 0..width {
            flat[i * width + w] = (1 + i * width + w) as i32;
        }
    }
    let tables = BlockTables::new(flat, b, width)?;
    let out = e.prefill_chunk_paged(&toks, &lens, &offs, &tables, e.new_kv_pool()?)?;
    let mut kv = out.kv;
    e.reset_profile();
    let tokens: Vec<i32> = (0..b).map(|i| 60 + i as i32).collect();
    let lengths = vec![(prompt_len + 1) as i32; b];
    let t0 = Instant::now();
    for _ in 0..steps {
        let o = e.decode_paged(tag, &tokens, &lengths, &tables, kv, None)?;
        kv = o.kv;
    }
    Ok(PathRun {
        profile: e.profile_snapshot(),
        n: tables.n(bs),
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

fn path_json(r: &PathRun) -> Json {
    let mut j = r.profile.to_json();
    j.set("wall_ms", (r.wall_s * 1e3).into());
    j
}

fn per_step_host_copy(r: &PathRun) -> f64 {
    r.profile.host_copy_bytes() as f64 / r.profile.decode_steps.max(1) as f64
}

/// Gather + scatter shell bytes per decode step (the dense-view traffic
/// the twin entries stage around the core; fused must be ~0).
fn per_step_shell(r: &PathRun) -> f64 {
    (r.profile.gather_bytes + r.profile.scatter_bytes) as f64
        / r.profile.decode_steps.max(1) as f64
}

pub fn run(rest: &[String]) -> Result<()> {
    let args = Args::new(
        "bench decode-breakdown",
        "A/B per-step decode cost breakdown (host-KV vs resident-KV)",
    )
    .flag("model", "opt-tiny", "model name under the artifacts dir")
    .flag("artifacts", "artifacts", "artifacts root directory")
    .flag("mode", "dense", "dense | dejavu | polar | polar@<density>")
    .flag("batch", "8", "decode batch size")
    .flag("steps", "64", "timed decode steps per path")
    .flag("out", "BENCH_decode.json", "output JSON path")
    .switch("smoke", "run on the deterministic mock engine (no artifacts)");
    let p = match args.parse(rest) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let b = p.get_usize("batch").map_err(anyhow::Error::msg)?;
    let steps = p.get_usize("steps").map_err(anyhow::Error::msg)?;

    let (engine_label, base, fast, twin, fused) = if p.get_bool("smoke") {
        let base_e = MockEngine::new().with_host_kv_path(true);
        let fast_e = MockEngine::new();
        let twin_e = MockEngine::new().with_twin_kv_path(true);
        let fused_e = MockEngine::new();
        (
            "mock".to_string(),
            run_path(&base_e, "dense", b, steps)?,
            run_path(&fast_e, "dense", b, steps)?,
            run_paged_path(&twin_e, "dense", b, steps)?,
            run_paged_path(&fused_e, "dense", b, steps)?,
        )
    } else {
        let dir = std::path::PathBuf::from(p.get("artifacts")).join(p.get("model"));
        let exec = std::sync::Arc::new(
            Executor::load(&dir)
                .with_context(|| format!("loading {} — run `make artifacts` first", dir.display()))?,
        );
        let mode = Mode::parse(p.get("mode"), exec.config().critical_density)?;
        let tag = SparsityController::new(mode).decode_tag();
        let base_e = Engine::new(exec.clone()).with_kv_host_path(true);
        let fast_e = Engine::new(exec.clone()).with_kv_host_path(false);
        let twin_e = Engine::new(exec.clone()).with_twin_kv_path(true);
        let fused_e = Engine::new(exec).with_twin_kv_path(false);
        (
            p.get("model").to_string(),
            run_path(&base_e, &tag, b, steps)?,
            run_path(&fast_e, &tag, b, steps)?,
            run_paged_path(&twin_e, &tag, b, steps)?,
            run_paged_path(&fused_e, &tag, b, steps)?,
        )
    };

    let (hc_base, hc_fast) = (per_step_host_copy(&base), per_step_host_copy(&fast));
    let reduction = if hc_fast > 0.0 { hc_base / hc_fast } else { f64::INFINITY };
    let reduction = (reduction * 1e4).round() / 1e4;
    let (sh_twin, sh_fused) = (per_step_shell(&twin), per_step_shell(&fused));
    let report = Json::obj(vec![
        ("bench", "decode-breakdown".into()),
        ("engine", engine_label.into()),
        ("batch", b.into()),
        ("seq_bucket", base.n.into()),
        ("steps", steps.into()),
        (
            "paths",
            Json::obj(vec![
                ("baseline_host_kv", path_json(&base)),
                ("resident_device_kv", path_json(&fast)),
                ("paged_twin", path_json(&twin)),
                ("paged_fused", path_json(&fused)),
            ]),
        ),
        ("host_copy_bytes_reduction", reduction.into()),
        ("shell_bytes_per_step_twin", sh_twin.into()),
        ("shell_bytes_per_step_fused", sh_fused.into()),
    ]);

    println!("decode-breakdown ({engine_label}, b={b}, n={}, {steps} steps)", base.n);
    println!(
        "  host-copy bytes/step: {:.0} (host-KV baseline) -> {:.0} (resident) = {reduction}x reduction",
        hc_base, hc_fast
    );
    println!(
        "  paged shell bytes/step: {:.0} (twin gather+scatter) -> {:.0} (fused)",
        sh_twin, sh_fused
    );
    println!(
        "  step wall: {:.3} ms -> {:.3} ms",
        base.wall_s * 1e3 / steps.max(1) as f64,
        fast.wall_s * 1e3 / steps.max(1) as f64
    );
    super::harness::write_bench_json(p.get("out"), &report)?;
    // the acceptance gate this bench exists for: fused entries index the
    // pool in place — any shell traffic means the twin path leaked back
    if sh_fused != 0.0 {
        bail!("fused paged decode moved {sh_fused} shell bytes/step — expected 0");
    }
    if sh_twin <= 0.0 {
        bail!("twin paged decode reported no shell bytes — A/B baseline broken");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate: at b=8 the resident path must move under half
    /// the bytes per step of the host-KV baseline.
    #[test]
    fn smoke_breakdown_reports_2x_reduction() {
        let base = MockEngine::new().with_host_kv_path(true);
        let fast = MockEngine::new();
        let rb = run_path(&base, "dense", 8, 64).unwrap();
        let rf = run_path(&fast, "dense", 8, 64).unwrap();
        // analytic expectations for the mock config (L=2,G=2,dh=2,n=16):
        // kv 8192 B, logits 9600 B, tokens+lengths 64 B per step. The
        // chunked prefill hands decode a cache that is ALREADY resident,
        // so the resident path no longer pays even the one-off post-
        // prefill upload the old monolithic path amortized (9792 B/step
        // -> 9664 B/step at 64 steps).
        assert_eq!(rb.profile.decode_steps, 64);
        assert_eq!(per_step_host_copy(&rb), 26048.0);
        assert_eq!(per_step_host_copy(&rf), 9664.0);
        let reduction = per_step_host_copy(&rb) / per_step_host_copy(&rf);
        assert!(reduction >= 2.0, "got {reduction}x");
    }

    /// The fused acceptance gate: at b=8/n=16 the twin paged path stages
    /// the dense [L,2,B,G,N,dh] view both ways (8192 B each, per step);
    /// the fused path moves zero shell bytes. Host<->device traffic is
    /// identical — the shells are device-side movement, so the A/B
    /// isolates exactly what fusion kills.
    #[test]
    fn smoke_paged_fused_kills_shell_bytes() {
        let twin = MockEngine::new().with_twin_kv_path(true);
        let fused = MockEngine::new();
        let rt = run_paged_path(&twin, "dense", 8, 64).unwrap();
        let rf = run_paged_path(&fused, "dense", 8, 64).unwrap();
        assert_eq!(rt.profile.decode_steps, 64);
        assert_eq!(rf.profile.decode_steps, 64);
        // dense view = 2*2*8*2*16*2 f32 = 2048 elems = 8192 B each way
        assert_eq!(rt.profile.gather_bytes, 64 * 8192);
        assert_eq!(rt.profile.scatter_bytes, 64 * 8192);
        assert_eq!(per_step_shell(&rt), 16384.0);
        assert_eq!(rf.profile.gather_bytes, 0);
        assert_eq!(rf.profile.scatter_bytes, 0);
        assert_eq!(per_step_host_copy(&rt), per_step_host_copy(&rf));
    }
}
