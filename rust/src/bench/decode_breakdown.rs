//! `bench decode-breakdown` — A/B breakdown of one decode step's cost:
//! h2d / compute / d2h / host-surgery time and, crucially, the bytes
//! crossing the host<->device boundary per step, for the legacy host-KV
//! path vs. the resident-device-KV path. Emits `BENCH_decode.json` so
//! every PR's CI run records the perf trajectory.
//!
//! `--smoke` runs against the deterministic mock engine (no AOT
//! artifacts): byte counters are analytic and reproducible; timing fields
//! are whatever the host measured.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::mock::MockEngine;
use crate::coordinator::{Mode, SparsityController, StepEngine};
use crate::runtime::{Engine, Executor, KvCache, StepProfile, Tensor};
use crate::substrate::argparse::Args;
use crate::substrate::json::Json;
use crate::tokenizer::PAD;

struct PathRun {
    profile: StepProfile,
    n: usize,
    wall_s: f64,
}

/// Prefill a steady batch (one chunk call into a zeroed cache at the
/// smallest seq bucket), then run `steps` decode steps, feeding each
/// step's KV output into the next — exactly the scheduler's hot loop,
/// minus composition changes. The profile covers only the decode loop.
fn run_path<E: StepEngine>(e: &E, tag: &str, b: usize, steps: usize) -> Result<PathRun> {
    let c = e.prefill_chunk_len();
    let n = e.seq_buckets()[0];
    let prompt_len = 4.min(c).min(n - 1);
    let mut toks = vec![PAD; b * c];
    let mut lens = vec![0i32; b];
    let offs = vec![0i32; b];
    for i in 0..b {
        for j in 0..prompt_len {
            toks[i * c + j] = 40 + i as i32;
        }
        lens[i] = prompt_len as i32;
    }
    let cfg = e.config().clone();
    let fresh = KvCache::from_tensor(&Tensor::zeros_f32(cfg.kv_shape(b, n)), b, n)?;
    let out = e.prefill_chunk(&toks, &lens, &offs, fresh)?;
    let mut kv = out.kv;
    let n = kv.n;
    e.reset_profile();
    let tokens: Vec<i32> = (0..b).map(|i| 60 + i as i32).collect();
    let lengths = vec![(prompt_len + 1) as i32; b];
    let t0 = Instant::now();
    for _ in 0..steps {
        let o = e.decode(tag, &tokens, &lengths, kv, None)?;
        kv = o.kv;
    }
    Ok(PathRun { profile: e.profile_snapshot(), n, wall_s: t0.elapsed().as_secs_f64() })
}

fn path_json(r: &PathRun) -> Json {
    let mut j = r.profile.to_json();
    j.set("wall_ms", (r.wall_s * 1e3).into());
    j
}

fn per_step_host_copy(r: &PathRun) -> f64 {
    r.profile.host_copy_bytes() as f64 / r.profile.decode_steps.max(1) as f64
}

pub fn run(rest: &[String]) -> Result<()> {
    let args = Args::new(
        "bench decode-breakdown",
        "A/B per-step decode cost breakdown (host-KV vs resident-KV)",
    )
    .flag("model", "opt-tiny", "model name under the artifacts dir")
    .flag("artifacts", "artifacts", "artifacts root directory")
    .flag("mode", "dense", "dense | dejavu | polar | polar@<density>")
    .flag("batch", "8", "decode batch size")
    .flag("steps", "64", "timed decode steps per path")
    .flag("out", "BENCH_decode.json", "output JSON path")
    .switch("smoke", "run on the deterministic mock engine (no artifacts)");
    let p = match args.parse(rest) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let b = p.get_usize("batch").map_err(anyhow::Error::msg)?;
    let steps = p.get_usize("steps").map_err(anyhow::Error::msg)?;

    let (engine_label, base, fast) = if p.get_bool("smoke") {
        let base_e = MockEngine::new().with_host_kv_path(true);
        let fast_e = MockEngine::new();
        (
            "mock".to_string(),
            run_path(&base_e, "dense", b, steps)?,
            run_path(&fast_e, "dense", b, steps)?,
        )
    } else {
        let dir = std::path::PathBuf::from(p.get("artifacts")).join(p.get("model"));
        let exec = std::sync::Arc::new(
            Executor::load(&dir)
                .with_context(|| format!("loading {} — run `make artifacts` first", dir.display()))?,
        );
        let mode = Mode::parse(p.get("mode"), exec.config().critical_density)?;
        let tag = SparsityController::new(mode).decode_tag();
        let base_e = Engine::new(exec.clone()).with_kv_host_path(true);
        let fast_e = Engine::new(exec).with_kv_host_path(false);
        (
            p.get("model").to_string(),
            run_path(&base_e, &tag, b, steps)?,
            run_path(&fast_e, &tag, b, steps)?,
        )
    };

    let (hc_base, hc_fast) = (per_step_host_copy(&base), per_step_host_copy(&fast));
    let reduction = if hc_fast > 0.0 { hc_base / hc_fast } else { f64::INFINITY };
    let reduction = (reduction * 1e4).round() / 1e4;
    let report = Json::obj(vec![
        ("bench", "decode-breakdown".into()),
        ("engine", engine_label.into()),
        ("batch", b.into()),
        ("seq_bucket", base.n.into()),
        ("steps", steps.into()),
        (
            "paths",
            Json::obj(vec![
                ("baseline_host_kv", path_json(&base)),
                ("resident_device_kv", path_json(&fast)),
            ]),
        ),
        ("host_copy_bytes_reduction", reduction.into()),
    ]);

    println!("decode-breakdown ({engine_label}, b={b}, n={}, {steps} steps)", base.n);
    println!(
        "  host-copy bytes/step: {:.0} (host-KV baseline) -> {:.0} (resident) = {reduction}x reduction",
        hc_base, hc_fast
    );
    println!(
        "  step wall: {:.3} ms -> {:.3} ms",
        base.wall_s * 1e3 / steps.max(1) as f64,
        fast.wall_s * 1e3 / steps.max(1) as f64
    );
    super::harness::write_bench_json(p.get("out"), &report)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate: at b=8 the resident path must move under half
    /// the bytes per step of the host-KV baseline.
    #[test]
    fn smoke_breakdown_reports_2x_reduction() {
        let base = MockEngine::new().with_host_kv_path(true);
        let fast = MockEngine::new();
        let rb = run_path(&base, "dense", 8, 64).unwrap();
        let rf = run_path(&fast, "dense", 8, 64).unwrap();
        // analytic expectations for the mock config (L=2,G=2,dh=2,n=16):
        // kv 8192 B, logits 9600 B, tokens+lengths 64 B per step. The
        // chunked prefill hands decode a cache that is ALREADY resident,
        // so the resident path no longer pays even the one-off post-
        // prefill upload the old monolithic path amortized (9792 B/step
        // -> 9664 B/step at 64 steps).
        assert_eq!(rb.profile.decode_steps, 64);
        assert_eq!(per_step_host_copy(&rb), 26048.0);
        assert_eq!(per_step_host_copy(&rf), 9664.0);
        let reduction = per_step_host_copy(&rb) / per_step_host_copy(&rf);
        assert!(reduction >= 2.0, "got {reduction}x");
    }
}
