//! `bench decode-breakdown` — A/B breakdown of one decode step's cost:
//! h2d / compute / d2h / host-surgery time and, crucially, the bytes
//! crossing the host<->device boundary per step, for the legacy host-KV
//! path vs. the resident-device-KV path — plus the fused paged pipeline
//! end to end: chunked prefill, one COW `copy_blocks`, and the decode
//! loop all index the block pool in place, so every shell counter
//! (`gather_bytes`/`scatter_bytes` on the decode side,
//! `prefill_gather_bytes`/`prefill_scatter_bytes` on the prefill side)
//! must report 0 and COW shows up only as device-local `cow_bytes`. The
//! run FAILS if any default-path step moves shell bytes. Emits
//! `BENCH_decode.json` so every PR's CI run records the perf trajectory.
//!
//! `--smoke` runs against the deterministic mock engine (no AOT
//! artifacts): byte counters are analytic and reproducible; timing fields
//! are whatever the host measured.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::mock::MockEngine;
use crate::coordinator::{Mode, SparsityController, StepEngine};
use crate::runtime::{BlockTables, Engine, Executor, KvCache, StepProfile, Tensor};
use crate::substrate::argparse::Args;
use crate::substrate::json::Json;
use crate::tokenizer::PAD;

struct PathRun {
    profile: StepProfile,
    n: usize,
    wall_s: f64,
}

/// Prefill a steady batch (one chunk call into a zeroed cache at the
/// smallest seq bucket), then run `steps` decode steps, feeding each
/// step's KV output into the next — exactly the scheduler's hot loop,
/// minus composition changes. The profile covers only the decode loop.
fn run_path<E: StepEngine>(e: &E, tag: &str, b: usize, steps: usize) -> Result<PathRun> {
    let c = e.prefill_chunk_len();
    let n = e.seq_buckets()[0];
    let prompt_len = 4.min(c).min(n - 1);
    let mut toks = vec![PAD; b * c];
    let mut lens = vec![0i32; b];
    let offs = vec![0i32; b];
    for i in 0..b {
        for j in 0..prompt_len {
            toks[i * c + j] = 40 + i as i32;
        }
        lens[i] = prompt_len as i32;
    }
    let cfg = e.config().clone();
    let fresh = KvCache::from_tensor(&Tensor::zeros_f32(cfg.kv_shape(b, n)), b, n)?;
    let out = e.prefill_chunk(&toks, &lens, &offs, fresh)?;
    let mut kv = out.kv;
    let n = kv.n;
    e.reset_profile();
    let tokens: Vec<i32> = (0..b).map(|i| 60 + i as i32).collect();
    let lengths = vec![(prompt_len + 1) as i32; b];
    let t0 = Instant::now();
    for _ in 0..steps {
        let o = e.decode(tag, &tokens, &lengths, kv, None)?;
        kv = o.kv;
    }
    Ok(PathRun { profile: e.profile_snapshot(), n, wall_s: t0.elapsed().as_secs_f64() })
}

/// The paged counterpart of [`run_path`], covering the WHOLE fused
/// pipeline: chunked prefill into the pool (slot `i` owns blocks
/// `1 + i*width ..`), one COW `copy_blocks` (slot 0's first block forked
/// into the first spare block, the shared-prefix divergence pattern),
/// then the decode loop. The profile covers all three phases — so the
/// zero-shell gate proves no default-path step stages a dense KV view.
fn run_paged_path<E: StepEngine>(e: &E, tag: &str, b: usize, steps: usize) -> Result<PathRun> {
    let c = e.prefill_chunk_len();
    let n = e.seq_buckets()[0];
    let (bs, pool_blocks) = e.kv_layout();
    let width = (n + bs - 1) / bs;
    // one spare block past the slots' own, for the COW fork
    if 1 + b * width + 1 > pool_blocks {
        bail!("pool too small: {pool_blocks} blocks for {b} slots x {width} + COW spare");
    }
    let prompt_len = 4.min(c).min(n - 1);
    let mut toks = vec![PAD; b * c];
    let mut lens = vec![0i32; b];
    let offs = vec![0i32; b];
    let mut flat = vec![0i32; b * width];
    for i in 0..b {
        for j in 0..prompt_len {
            toks[i * c + j] = 40 + i as i32;
        }
        lens[i] = prompt_len as i32;
        for w in 0..width {
            flat[i * width + w] = (1 + i * width + w) as i32;
        }
    }
    let tables = BlockTables::new(flat, b, width)?;
    e.reset_profile();
    let out = e.prefill_chunk_paged(&toks, &lens, &offs, &tables, e.new_kv_pool()?)?;
    // COW fork: copy slot 0's first block into the spare — on-device,
    // accounted as cow_bytes, never as shell or full-pool traffic
    let spare = (1 + b * width) as u32;
    let mut kv = e.copy_blocks(out.kv, &[(1, spare)])?;
    let tokens: Vec<i32> = (0..b).map(|i| 60 + i as i32).collect();
    let lengths = vec![(prompt_len + 1) as i32; b];
    let t0 = Instant::now();
    for _ in 0..steps {
        let o = e.decode_paged(tag, &tokens, &lengths, &tables, kv, None)?;
        kv = o.kv;
    }
    Ok(PathRun {
        profile: e.profile_snapshot(),
        n: tables.n(bs),
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

fn path_json(r: &PathRun) -> Json {
    let mut j = r.profile.to_json();
    j.set("wall_ms", (r.wall_s * 1e3).into());
    j
}

fn per_step_host_copy(r: &PathRun) -> f64 {
    r.profile.host_copy_bytes() as f64 / r.profile.decode_steps.max(1) as f64
}

/// Total dense-view shell bytes across the run — decode gather/scatter
/// plus the prefill-side counters. The fused pipeline must report 0.
fn total_shell(r: &PathRun) -> u64 {
    r.profile.gather_bytes
        + r.profile.scatter_bytes
        + r.profile.prefill_gather_bytes
        + r.profile.prefill_scatter_bytes
}

pub fn run(rest: &[String]) -> Result<()> {
    let args = Args::new(
        "bench decode-breakdown",
        "A/B per-step decode cost breakdown (host-KV vs resident-KV)",
    )
    .flag("model", "opt-tiny", "model name under the artifacts dir")
    .flag("artifacts", "artifacts", "artifacts root directory")
    .flag("mode", "dense", "dense | dejavu | polar | polar@<density>")
    .flag("batch", "8", "decode batch size")
    .flag("steps", "64", "timed decode steps per path")
    .flag("out", "BENCH_decode.json", "output JSON path")
    .switch("smoke", "run on the deterministic mock engine (no artifacts)");
    let p = match args.parse(rest) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let b = p.get_usize("batch").map_err(anyhow::Error::msg)?;
    let steps = p.get_usize("steps").map_err(anyhow::Error::msg)?;

    let (engine_label, base, fast, paged) = if p.get_bool("smoke") {
        let base_e = MockEngine::new().with_host_kv_path(true);
        let fast_e = MockEngine::new();
        let paged_e = MockEngine::new();
        (
            "mock".to_string(),
            run_path(&base_e, "dense", b, steps)?,
            run_path(&fast_e, "dense", b, steps)?,
            run_paged_path(&paged_e, "dense", b, steps)?,
        )
    } else {
        let dir = std::path::PathBuf::from(p.get("artifacts")).join(p.get("model"));
        let exec = std::sync::Arc::new(
            Executor::load(&dir)
                .with_context(|| format!("loading {} — run `make artifacts` first", dir.display()))?,
        );
        let mode = Mode::parse(p.get("mode"), exec.config().critical_density)?;
        let tag = SparsityController::new(mode).decode_tag();
        let base_e = Engine::new(exec.clone()).with_kv_host_path(true);
        let fast_e = Engine::new(exec.clone()).with_kv_host_path(false);
        let paged_e = Engine::new(exec);
        (
            p.get("model").to_string(),
            run_path(&base_e, &tag, b, steps)?,
            run_path(&fast_e, &tag, b, steps)?,
            run_paged_path(&paged_e, &tag, b, steps)?,
        )
    };

    let (hc_base, hc_fast) = (per_step_host_copy(&base), per_step_host_copy(&fast));
    let reduction = if hc_fast > 0.0 { hc_base / hc_fast } else { f64::INFINITY };
    let reduction = (reduction * 1e4).round() / 1e4;
    let shell = total_shell(&paged);
    let report = Json::obj(vec![
        ("bench", "decode-breakdown".into()),
        ("engine", engine_label.into()),
        ("batch", b.into()),
        ("seq_bucket", base.n.into()),
        ("steps", steps.into()),
        (
            "paths",
            Json::obj(vec![
                ("baseline_host_kv", path_json(&base)),
                ("resident_device_kv", path_json(&fast)),
                ("paged_fused", path_json(&paged)),
            ]),
        ),
        ("host_copy_bytes_reduction", reduction.into()),
        ("shell_bytes_paged", (shell as usize).into()),
        ("cow_bytes_paged", (paged.profile.cow_bytes as usize).into()),
    ]);

    println!("decode-breakdown ({engine_label}, b={b}, n={}, {steps} steps)", base.n);
    println!(
        "  host-copy bytes/step: {:.0} (host-KV baseline) -> {:.0} (resident) = {reduction}x reduction",
        hc_base, hc_fast
    );
    println!(
        "  paged pipeline (prefill + COW + decode): shell bytes {shell}, cow bytes {}",
        paged.profile.cow_bytes
    );
    println!(
        "  step wall: {:.3} ms -> {:.3} ms",
        base.wall_s * 1e3 / steps.max(1) as f64,
        fast.wall_s * 1e3 / steps.max(1) as f64
    );
    super::harness::write_bench_json(p.get("out"), &report)?;
    // the acceptance gate this bench exists for: the fused pipeline
    // indexes the pool in place end to end — ANY shell traffic on any
    // default-path step (prefill, COW, or decode) fails the run
    if paged.profile.gather_bytes != 0 || paged.profile.scatter_bytes != 0 {
        bail!(
            "paged decode moved shell bytes (gather {} / scatter {}) — expected 0",
            paged.profile.gather_bytes,
            paged.profile.scatter_bytes
        );
    }
    if paged.profile.prefill_gather_bytes != 0 || paged.profile.prefill_scatter_bytes != 0 {
        bail!(
            "paged prefill moved shell bytes (gather {} / scatter {}) — expected 0",
            paged.profile.prefill_gather_bytes,
            paged.profile.prefill_scatter_bytes
        );
    }
    if paged.profile.cow_bytes == 0 {
        bail!("COW fork accounted no cow_bytes — copy_blocks path broken");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate: at b=8 the resident path must move under half
    /// the bytes per step of the host-KV baseline.
    #[test]
    fn smoke_breakdown_reports_2x_reduction() {
        let base = MockEngine::new().with_host_kv_path(true);
        let fast = MockEngine::new();
        let rb = run_path(&base, "dense", 8, 64).unwrap();
        let rf = run_path(&fast, "dense", 8, 64).unwrap();
        // analytic expectations for the mock config (L=2,G=2,dh=2,n=16):
        // kv 8192 B, logits 9600 B, tokens+lengths 64 B per step. The
        // chunked prefill hands decode a cache that is ALREADY resident,
        // so the resident path no longer pays even the one-off post-
        // prefill upload the old monolithic path amortized (9792 B/step
        // -> 9664 B/step at 64 steps).
        assert_eq!(rb.profile.decode_steps, 64);
        assert_eq!(per_step_host_copy(&rb), 26048.0);
        assert_eq!(per_step_host_copy(&rf), 9664.0);
        let reduction = per_step_host_copy(&rb) / per_step_host_copy(&rf);
        assert!(reduction >= 2.0, "got {reduction}x");
    }

    /// The zero-shell acceptance gate: the whole paged pipeline —
    /// chunked prefill, the COW fork, and 64 decode steps — moves zero
    /// dense-view shell bytes, uploads the pool exactly once, and
    /// accounts the COW as one block of device-local `cow_bytes`.
    #[test]
    fn smoke_paged_pipeline_moves_zero_shell_bytes() {
        let e = MockEngine::new();
        let r = run_paged_path(&e, "dense", 8, 64).unwrap();
        assert_eq!(r.profile.decode_steps, 64);
        assert_eq!(r.profile.prefill_chunks, 1);
        assert_eq!(total_shell(&r), 0, "fused pipeline staged a dense view");
        // one (1 -> spare) pair: a block is L*2*G*bs*dh = 256 f32 = 1024 B
        assert_eq!(r.profile.cow_bytes, 1024);
        assert_eq!(e.pool_uploads(), 1, "pool crossed host->device again");
        // analytic traffic for the mock at b=8, n=16, 33-block pool:
        //   h2d: prefill payload 608 + pool upload 33792 + COW indices 64
        //        + 64 decode steps x 96 B tokens/lengths/tables
        //   d2h: logits 9600 B per prefill chunk and per decode step
        assert_eq!(r.profile.h2d_bytes, 608 + 33792 + 64 + 64 * 96);
        assert_eq!(r.profile.d2h_bytes, 9600 + 64 * 9600);
    }
}
