//! Serving-latency replay over the scheduler's event stream.
//!
//! Replays a timed workload (Poisson arrivals) through a scheduler and
//! measures TTFT and inter-token latency as an external observer: each
//! sample is taken when the corresponding [`GenerationEvent`] is
//! surfaced, exactly as a streaming client would see it — not
//! reconstructed from completion records. The scheduler's internal
//! `EngineMetrics` measure the same quantities at emission time; this
//! harness cross-checks them from outside the scheduler.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{Completion, GenerationEvent, Scheduler, StepEngine};
use crate::substrate::stats::Samples;
use crate::workload::TimedRequest;

/// Observed latency profile of one replay.
pub struct ServingRun {
    pub completions: Vec<Completion>,
    /// Total events surfaced (lifecycle + tokens + terminals).
    pub events: usize,
    /// Queue-entry -> first `Token` event, per request.
    pub ttft: Samples,
    /// Gap between consecutive `Token` events, per request.
    pub itl: Samples,
    /// Queue-entry -> terminal event, per request.
    pub e2e: Samples,
}

/// Replay `trace` through `sched`, respecting arrival offsets, until every
/// request reaches a terminal event.
pub fn replay<E: StepEngine>(
    sched: &mut Scheduler<E>,
    trace: Vec<TimedRequest>,
) -> Result<ServingRun> {
    let n = trace.len();
    let mut pending: VecDeque<TimedRequest> = trace.into();
    let mut run = ServingRun {
        completions: Vec::with_capacity(n),
        events: 0,
        ttft: Samples::new(),
        itl: Samples::new(),
        e2e: Samples::new(),
    };
    let t0 = Instant::now();
    let mut enqueued_at: HashMap<u64, Instant> = HashMap::new();
    let mut last_token_at: HashMap<u64, Instant> = HashMap::new();
    while run.completions.len() < n {
        while pending
            .front()
            .map_or(false, |f| t0.elapsed().as_secs_f64() >= f.at_s)
        {
            let mut tr = pending.pop_front().unwrap();
            let now = Instant::now();
            tr.request.enqueued_at = now;
            enqueued_at.insert(tr.request.id, now);
            sched.enqueue(tr.request);
        }
        if sched.is_idle() {
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        for ev in sched.step()? {
            run.events += 1;
            match ev {
                GenerationEvent::Token { request, index, .. } => {
                    let now = Instant::now();
                    if index == 0 {
                        if let Some(&t) = enqueued_at.get(&request) {
                            run.ttft.push(now.duration_since(t).as_secs_f64());
                        }
                    } else if let Some(&prev) = last_token_at.get(&request) {
                        run.itl.push(now.duration_since(prev).as_secs_f64());
                    }
                    last_token_at.insert(request, now);
                }
                GenerationEvent::Finished(c) | GenerationEvent::Cancelled(c) => {
                    if let Some(&t) = enqueued_at.get(&c.id) {
                        run.e2e.push(t.elapsed().as_secs_f64());
                    }
                    last_token_at.remove(&c.id);
                    run.completions.push(c);
                }
                _ => {}
            }
        }
    }
    Ok(run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::mock::MockEngine;
    use crate::coordinator::{Mode, SchedulerConfig, SparsityController};
    use crate::workload::{generate, WorkloadConfig};

    #[test]
    fn replay_observes_every_request_and_token() {
        let mut sched = Scheduler::new(
            MockEngine::new(),
            SparsityController::new(Mode::Dense),
            SchedulerConfig { max_batch: 4, compact: true, ..Default::default() },
        );
        let trace = generate(&WorkloadConfig {
            n_requests: 6,
            arrival_rate: 0.0, // all arrive at t=0
            max_new_tokens: 5,
            prompt_len_min: 4,
            prompt_len_max: 10,
            ..Default::default()
        });
        let run = replay(&mut sched, trace).unwrap();
        assert_eq!(run.completions.len(), 6);
        assert_eq!(run.ttft.len(), 6);
        assert_eq!(run.e2e.len(), 6);
        let tokens: usize = run.completions.iter().map(|c| c.output_ids.len()).sum();
        // every token beyond each request's first contributes one ITL gap
        assert_eq!(run.itl.len(), tokens - 6);
        // observer-side and scheduler-side token accounting agree
        assert_eq!(sched.metrics.ttft.len(), 6);
        assert_eq!(sched.metrics.itl.len(), tokens - 6);
    }
}
