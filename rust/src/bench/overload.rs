//! `bench overload [--smoke]` — SLO-aware overload control A/B, emitted
//! as `BENCH_overload.json`: the same four workload scenarios
//! (`workload::scenarios`: bursty, heavy-tail, two-tenant, chat
//! sessions) replayed under two policies over an undersized KV block
//! pool:
//!
//! * **preempt_resume** (the default [`OverloadConfig`]): admission
//!   gates on predicted block demand and defers what does not fit;
//!   under pressure the lowest-rank running victim is preempted
//!   (recompute-on-resume via the prefix cache, host-swap for long
//!   victims) and re-queued.
//! * **reject_only** (the baseline): same demand gate, but load that
//!   does not fit is shed with `FinishReason::Rejected` instead of
//!   queued — the classic admission-control-only server.
//!
//! The headline figure is **goodput** (deadline-met tokens per second):
//! rejected work earns zero, so on the bursty trace preempt_resume must
//! strictly beat reject_only — that inequality is the in-tree gate
//! (`preemption_and_admission_beat_reject_only_on_bursty_goodput`).
//!
//! `--smoke` runs the deterministic mock engine (17-block pool, 2 ms
//! step delay so arrivals actually overlap); counts are trace-exact,
//! wall-clock figures are machine-dependent (zeroed in the committed
//! artifact).

use anyhow::{bail, Context, Result};

use crate::bench::serving::replay;
use crate::coordinator::mock::MockEngine;
use crate::coordinator::{
    FinishReason, Mode, OverloadConfig, Scheduler, SchedulerConfig, SparsityController,
    StepEngine,
};
use crate::runtime::{Engine, Executor};
use crate::substrate::argparse::Args;
use crate::substrate::json::Json;
use crate::workload::scenarios::{self, ScenarioConfig};
use crate::workload::TimedRequest;

use super::harness::write_bench_json;

use std::time::Duration;

/// Outcome of one (scenario, policy) replay.
pub struct PolicyOut {
    /// Requests that reached a natural finish (length / stop / cache
    /// limit / stop sequence).
    pub completed: usize,
    pub rejected: usize,
    pub deadline_missed: usize,
    pub tokens_out: usize,
    pub deadline_met_tokens: u64,
    pub goodput_tok_per_s: f64,
    pub preemptions: u64,
    pub resumes: u64,
    pub swap_out_bytes: u64,
    pub swap_in_bytes: u64,
    pub admission_rejections: u64,
    pub prefix_tokens_skipped: u64,
    pub ttft_ms_p50: f64,
    pub ttft_ms_p99: f64,
    pub wall_s: f64,
}

/// Replay one scenario trace under one overload policy.
pub fn run_policy<E: StepEngine>(
    engine: E,
    overload: OverloadConfig,
    trace: Vec<TimedRequest>,
) -> Result<PolicyOut> {
    let n = trace.len();
    let mut s = Scheduler::new(
        engine,
        SparsityController::new(Mode::Dense),
        SchedulerConfig { max_batch: 8, overload, ..Default::default() },
    );
    let t0 = std::time::Instant::now();
    let run = replay(&mut s, trace)?;
    let wall_s = t0.elapsed().as_secs_f64();
    if run.completions.len() != n {
        bail!("replay produced {} completions, expected {n}", run.completions.len());
    }
    let count = |f: fn(FinishReason) -> bool| {
        run.completions.iter().filter(|c| f(c.finish)).count()
    };
    Ok(PolicyOut {
        completed: count(|f| {
            matches!(
                f,
                FinishReason::Length
                    | FinishReason::Stop
                    | FinishReason::StopSequence
                    | FinishReason::CacheLimit
            )
        }),
        rejected: count(|f| f == FinishReason::Rejected),
        deadline_missed: count(|f| f == FinishReason::Deadline),
        tokens_out: run.completions.iter().map(|c| c.output_ids.len()).sum(),
        deadline_met_tokens: s.metrics.deadline_met_tokens,
        goodput_tok_per_s: s.metrics.deadline_met_tokens as f64 / wall_s.max(1e-9),
        preemptions: s.metrics.preemptions,
        resumes: s.metrics.resumes,
        swap_out_bytes: s.metrics.swap_out_bytes,
        swap_in_bytes: s.metrics.swap_in_bytes,
        admission_rejections: s.metrics.admission_rejections,
        prefix_tokens_skipped: s.metrics.prefix_tokens_skipped,
        ttft_ms_p50: run.ttft.p50() * 1e3,
        ttft_ms_p99: run.ttft.p99() * 1e3,
        wall_s,
    })
}

fn policy_json(o: &PolicyOut) -> Json {
    Json::obj(vec![
        ("completed", o.completed.into()),
        ("rejected", o.rejected.into()),
        ("deadline_missed", o.deadline_missed.into()),
        ("tokens_out", o.tokens_out.into()),
        ("deadline_met_tokens", (o.deadline_met_tokens as usize).into()),
        ("goodput_tok_per_s", o.goodput_tok_per_s.into()),
        ("preemptions", (o.preemptions as usize).into()),
        ("resumes", (o.resumes as usize).into()),
        ("swap_out_bytes", (o.swap_out_bytes as usize).into()),
        ("swap_in_bytes", (o.swap_in_bytes as usize).into()),
        ("admission_rejections", (o.admission_rejections as usize).into()),
        ("prefix_tokens_skipped", (o.prefix_tokens_skipped as usize).into()),
        ("ttft_ms_p50", o.ttft_ms_p50.into()),
        ("ttft_ms_p99", o.ttft_ms_p99.into()),
        ("wall_ms", (o.wall_s * 1e3).into()),
    ])
}

/// Smoke engine: a 17-block pool (16 usable) so every scenario
/// overcommits it, seq buckets to 128 so batch-tenant requests are not
/// capped at 64, and a 2 ms step delay so Poisson arrivals overlap
/// in-flight work instead of draining one at a time.
fn smoke_engine() -> MockEngine {
    MockEngine::new()
        .with_seq_buckets(vec![16, 32, 64, 128])
        .with_pool_blocks(17)
        .with_step_delay(Duration::from_millis(2))
}

/// The four smoke scenarios: (name, trace) pairs, one fixed seed each.
pub fn smoke_scenarios() -> Vec<(&'static str, Vec<TimedRequest>)> {
    vec![
        ("bursty", scenarios::bursty(&bursty_cfg())),
        (
            "heavy_tail",
            scenarios::heavy_tail(&ScenarioConfig { n_requests: 48, seed: 2, ..Default::default() }),
        ),
        (
            "two_tenant",
            scenarios::two_tenant(&ScenarioConfig {
                n_requests: 32,
                seed: 3,
                deadline_ms: 10_000.0,
                ..Default::default()
            }),
        ),
        (
            "chat_sessions",
            scenarios::chat_sessions(&ScenarioConfig { n_requests: 32, seed: 4, ..Default::default() }),
        ),
    ]
}

/// Bursty trace for the goodput gate: 4 bursts of 12, loose 10 s
/// deadlines so every natural finish counts toward goodput.
pub fn bursty_cfg() -> ScenarioConfig {
    ScenarioConfig { n_requests: 48, seed: 1, deadline_ms: 10_000.0, ..Default::default() }
}

pub fn run(rest: &[String]) -> Result<()> {
    let args = Args::new(
        "bench overload",
        "SLO-aware overload control: preempt+admission vs reject-only goodput",
    )
    .flag("model", "opt-tiny", "model name under the artifacts dir")
    .flag("artifacts", "artifacts", "artifacts root directory")
    .flag("out", "BENCH_overload.json", "output JSON path")
    .switch("smoke", "run on the deterministic mock engine (no artifacts)");
    let p = match args.parse(rest) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let smoke = p.get_bool("smoke");

    let mut scenario_rows: Vec<(&str, Json)> = Vec::new();
    let mut gate_ratio = 0.0f64;
    let (engine_label, block, pool_blocks) = if smoke {
        let (block, pool_blocks) = smoke_engine().kv_layout();
        ("mock".to_string(), block, pool_blocks)
    } else {
        let dir = std::path::PathBuf::from(p.get("artifacts")).join(p.get("model"));
        let exec = std::sync::Arc::new(Executor::load(&dir).with_context(|| {
            format!("loading {} — run `make artifacts` first", dir.display())
        })?);
        let engine = Engine::new(exec);
        let (block, pool_blocks) = engine.kv_layout();
        (p.get("model").to_string(), block, pool_blocks)
    };

    for (name, trace) in smoke_scenarios() {
        let (a, b) = if smoke {
            (
                run_policy(smoke_engine(), OverloadConfig::default(), trace.clone())?,
                run_policy(smoke_engine(), OverloadConfig::reject_only(), trace)?,
            )
        } else {
            // real engine: same traces against the engine's own pool —
            // pressure depends on the artifact's pool size, so the
            // counts are informational rather than gated
            let dir = std::path::PathBuf::from(p.get("artifacts")).join(p.get("model"));
            let exec = std::sync::Arc::new(Executor::load(&dir)?);
            let e1 = Engine::new(exec.clone());
            let e2 = Engine::new(exec);
            (
                run_policy(e1, OverloadConfig::default(), trace.clone())?,
                run_policy(e2, OverloadConfig::reject_only(), trace)?,
            )
        };
        let ratio = if b.goodput_tok_per_s > 0.0 {
            ((a.goodput_tok_per_s / b.goodput_tok_per_s) * 1e3).round() / 1e3
        } else {
            f64::INFINITY
        };
        if name == "bursty" {
            gate_ratio = ratio;
        }
        println!(
            "{name:<13} preempt_resume: {} done / {} tok ({:.0} tok/s, {} preempt, {} resume) \
             | reject_only: {} done / {} rejected ({:.0} tok/s) | goodput x{ratio}",
            a.completed,
            a.deadline_met_tokens,
            a.goodput_tok_per_s,
            a.preemptions,
            a.resumes,
            b.completed,
            b.rejected,
            b.goodput_tok_per_s,
        );
        scenario_rows.push((
            name,
            Json::obj(vec![
                ("requests", smoke_request_count(name).into()),
                ("preempt_resume", policy_json(&a)),
                ("reject_only", policy_json(&b)),
                ("goodput_ratio", ratio.into()),
            ]),
        ));
    }

    let report = Json::obj(vec![
        ("bench", "overload".into()),
        ("engine", engine_label.into()),
        ("block_size", block.into()),
        ("pool_blocks", pool_blocks.into()),
        (
            "policies",
            Json::obj(vec![
                ("preempt_resume", "admission gate + defer + rank-ordered preemption".into()),
                ("reject_only", "admission gate sheds non-fitting load".into()),
            ]),
        ),
        ("scenarios", Json::obj(scenario_rows)),
        (
            "gate",
            Json::obj(vec![
                ("bursty_goodput_preempt_over_reject", gate_ratio.into()),
                ("pass", (gate_ratio > 1.0).into()),
            ]),
        ),
    ]);
    if gate_ratio <= 1.0 {
        eprintln!("WARNING: preempt_resume did not beat reject_only on bursty goodput");
    }
    write_bench_json(p.get("out"), &report)?;
    Ok(())
}

fn smoke_request_count(name: &str) -> usize {
    match name {
        "bursty" | "heavy_tail" => 48,
        _ => 32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate: on the bursty trace (4 bursts of 12 over a
    /// 16-usable-block pool), preemption+admission strictly beats
    /// reject-only on goodput — the reject-only baseline sheds most of
    /// each burst, and shed work earns zero deadline-met tokens.
    #[test]
    fn preemption_and_admission_beat_reject_only_on_bursty_goodput() {
        let trace = scenarios::bursty(&bursty_cfg());
        let a = run_policy(smoke_engine(), OverloadConfig::default(), trace.clone()).unwrap();
        let b = run_policy(smoke_engine(), OverloadConfig::reject_only(), trace).unwrap();
        // defer-instead-of-reject completes every request
        assert_eq!(a.completed, 48, "preempt_resume must complete the full burst");
        assert_eq!(a.rejected, 0);
        assert!(b.rejected >= 8, "reject_only should shed most of each burst, shed {}", b.rejected);
        assert_eq!(b.admission_rejections as usize, b.rejected);
        // the gate: strictly more deadline-met tokens AND higher goodput
        assert!(
            a.deadline_met_tokens >= b.deadline_met_tokens * 3 / 2,
            "expected a wide deadline-met-token margin: {} vs {}",
            a.deadline_met_tokens,
            b.deadline_met_tokens
        );
        assert!(
            a.goodput_tok_per_s > b.goodput_tok_per_s,
            "goodput gate failed: preempt_resume {:.1} tok/s <= reject_only {:.1} tok/s",
            a.goodput_tok_per_s,
            b.goodput_tok_per_s
        );
    }

    /// Two-tenant mix: the interactive tenant's rank (priority 5, tight
    /// slack) preempts batch-tenant victims, and every preempted victim
    /// resumes and finishes — nothing is lost, nothing misses its SLO.
    #[test]
    fn two_tenant_smoke_exercises_preemption_and_resume() {
        let trace = scenarios::two_tenant(&ScenarioConfig {
            n_requests: 32,
            seed: 3,
            deadline_ms: 10_000.0,
            ..Default::default()
        });
        let out = run_policy(smoke_engine(), OverloadConfig::default(), trace).unwrap();
        assert_eq!(out.completed, 32, "all requests finish under preempt_resume");
        assert_eq!(out.rejected, 0);
        assert_eq!(out.deadline_missed, 0);
        assert!(out.preemptions >= 1, "batch tenant never preempted");
        assert_eq!(out.preemptions, out.resumes, "every victim resumed");
    }

    /// Chat sessions re-hit the prefix cache: later turns (and resumed
    /// victims) skip already-published prefix blocks.
    #[test]
    fn chat_sessions_smoke_reuses_prefixes() {
        let trace = scenarios::chat_sessions(&ScenarioConfig {
            n_requests: 32,
            seed: 4,
            ..Default::default()
        });
        let out = run_policy(smoke_engine(), OverloadConfig::default(), trace).unwrap();
        assert_eq!(out.completed, 32);
        assert!(
            out.prefix_tokens_skipped >= 32,
            "session prefixes should re-hit the cache, skipped {}",
            out.prefix_tokens_skipped
        );
    }
}
