//! Micro/E2E bench harness (criterion is not vendored; this provides the
//! warmup + timed-iterations + stats loop the figures need), the
//! CSV/markdown report writer that regenerates the paper's tables, and
//! the shared BENCH_*.json emission path every perf bench uses.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::substrate::json::Json;
use crate::substrate::stats::Samples;

/// Indented JSON for the committed `BENCH_*.json` artifacts (key order
/// matches the compact serializer: alphabetical). Shared by every perf
/// bench — formerly copy-pasted across `decode_breakdown` /
/// `sparsity_scaling` / `prefill_interference`.
pub fn pretty_json(v: &Json, indent: usize) -> String {
    let pad = "  ".repeat(indent);
    let pad_in = "  ".repeat(indent + 1);
    match v {
        Json::Obj(o) if !o.is_empty() => {
            let fields: Vec<String> = o
                .iter()
                .map(|(k, x)| {
                    format!("{pad_in}{}: {}", Json::str(k.clone()), pretty_json(x, indent + 1))
                })
                .collect();
            format!("{{\n{}\n{pad}}}", fields.join(",\n"))
        }
        Json::Arr(a) if !a.is_empty() => {
            let items: Vec<String> =
                a.iter().map(|x| format!("{pad_in}{}", pretty_json(x, indent + 1))).collect();
            format!("[\n{}\n{pad}]", items.join(",\n"))
        }
        other => other.to_string(),
    }
}

/// Write one bench's JSON report (pretty, newline-terminated) and echo
/// the destination.
pub fn write_bench_json(path: &str, report: &Json) -> Result<()> {
    std::fs::write(path, format!("{}\n", pretty_json(report, 0)))
        .with_context(|| format!("writing {path}"))?;
    println!("[wrote {path}]");
    Ok(())
}

#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup: 3, iters: 10 }
    }
}

/// Time `f` for opts.iters iterations after warmup; returns samples (sec).
pub fn time_it<F: FnMut() -> Result<()>>(opts: BenchOpts, mut f: F) -> Result<Samples> {
    for _ in 0..opts.warmup {
        f()?;
    }
    let mut s = Samples::new();
    for _ in 0..opts.iters {
        let t0 = Instant::now();
        f()?;
        s.push_duration(t0.elapsed());
    }
    Ok(s)
}

/// Tabular result collector -> CSV + aligned-markdown, echoed to stdout.
pub struct Report {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str, columns: &[&str]) -> Report {
        Report {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.columns.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut s = format!("### {}\n\n", self.title);
        s.push_str(&fmt_row(&self.columns));
        s.push('|');
        for w in &widths {
            s.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        s.push('\n');
        for r in &self.rows {
            s.push_str(&fmt_row(r));
        }
        s
    }

    /// Write CSV to results/<name>.csv and echo markdown to stdout.
    pub fn emit(&self, results_dir: &Path, name: &str) -> Result<()> {
        std::fs::create_dir_all(results_dir)
            .with_context(|| format!("mkdir {}", results_dir.display()))?;
        let path = results_dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())
            .with_context(|| format!("writing {}", path.display()))?;
        println!("{}", self.to_markdown());
        println!("[wrote {}]", path.display());
        Ok(())
    }
}

pub fn fmt_ms(sec: f64) -> String {
    format!("{:.3}", sec * 1e3)
}

pub fn fmt_x(ratio: f64) -> String {
    format!("{ratio:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_counts_iters() {
        let mut n = 0;
        let s = time_it(BenchOpts { warmup: 2, iters: 5 }, || {
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 7);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn pretty_json_roundtrips() {
        let j = Json::obj(vec![
            ("a", 1usize.into()),
            ("b", Json::obj(vec![("c", 2.5.into())])),
        ]);
        let s = pretty_json(&j, 0);
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn report_csv_and_markdown() {
        let mut r = Report::new("t", &["a", "b"]);
        r.row(vec!["1".into(), "2".into()]);
        assert_eq!(r.to_csv(), "a,b\n1,2\n");
        let md = r.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }
}
