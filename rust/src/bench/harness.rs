//! Micro/E2E bench harness (criterion is not vendored; this provides the
//! warmup + timed-iterations + stats loop the figures need) and the
//! CSV/markdown report writer that regenerates the paper's tables.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::substrate::stats::Samples;

#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup: 3, iters: 10 }
    }
}

/// Time `f` for opts.iters iterations after warmup; returns samples (sec).
pub fn time_it<F: FnMut() -> Result<()>>(opts: BenchOpts, mut f: F) -> Result<Samples> {
    for _ in 0..opts.warmup {
        f()?;
    }
    let mut s = Samples::new();
    for _ in 0..opts.iters {
        let t0 = Instant::now();
        f()?;
        s.push_duration(t0.elapsed());
    }
    Ok(s)
}

/// Tabular result collector -> CSV + aligned-markdown, echoed to stdout.
pub struct Report {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str, columns: &[&str]) -> Report {
        Report {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.columns.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut s = format!("### {}\n\n", self.title);
        s.push_str(&fmt_row(&self.columns));
        s.push('|');
        for w in &widths {
            s.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        s.push('\n');
        for r in &self.rows {
            s.push_str(&fmt_row(r));
        }
        s
    }

    /// Write CSV to results/<name>.csv and echo markdown to stdout.
    pub fn emit(&self, results_dir: &Path, name: &str) -> Result<()> {
        std::fs::create_dir_all(results_dir)
            .with_context(|| format!("mkdir {}", results_dir.display()))?;
        let path = results_dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())
            .with_context(|| format!("writing {}", path.display()))?;
        println!("{}", self.to_markdown());
        println!("[wrote {}]", path.display());
        Ok(())
    }
}

pub fn fmt_ms(sec: f64) -> String {
    format!("{:.3}", sec * 1e3)
}

pub fn fmt_x(ratio: f64) -> String {
    format!("{ratio:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_counts_iters() {
        let mut n = 0;
        let s = time_it(BenchOpts { warmup: 2, iters: 5 }, || {
            n += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(n, 7);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn report_csv_and_markdown() {
        let mut r = Report::new("t", &["a", "b"]);
        r.row(vec!["1".into(), "2".into()]);
        assert_eq!(r.to_csv(), "a,b\n1,2\n");
        let md = r.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }
}
