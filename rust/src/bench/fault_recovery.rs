//! `bench fault-recovery [--smoke]` — fault-tolerant step execution,
//! emitted as `BENCH_faults.json`: the `workload::scenarios::fault_mix`
//! trace replayed twice on the deterministic mock engine, once
//! fault-free and once under a scripted [`FaultScript`] that exercises
//! every recovery path at once:
//!
//! * a **stalled** first decode call (trips the step watchdog, then
//!   retries),
//! * a **transient** decode call and a transient prefill chunk (both
//!   retried under exponential backoff, invisible in the output),
//! * a **transient pool allocation** failure at startup,
//! * a **poisoned request** (every decode batch containing its private
//!   token band fails persistently → polar step degrades to dense →
//!   bisection blame search isolates the one bad slot), and
//! * a **NaN request** (its logits rows come back non-finite → the
//!   sampler guard quarantines just that slot).
//!
//! The gate is the paper-level robustness claim: the two bad requests
//! finish with a structured `engine_fault`, and **every other request's
//! token stream is bit-identical to the fault-free replay** — the
//! scheduler never dies, and blame isolation never perturbs a healthy
//! stream. `--smoke` is the mode CI runs; without it the same mock gate
//! runs plus a fault-free reference replay on the real engine
//! (injection hooks are mock-only — the real engine's natural failures
//! take the same recovery paths via its KV stash).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::bench::serving::{replay, ServingRun};
use crate::coordinator::mock::MockEngine;
use crate::coordinator::{
    FaultInjector, FaultScript, FinishReason, Mode, RetryPolicy, Scheduler,
    SchedulerConfig, SparsityController,
};
use crate::runtime::{Engine, Executor};
use crate::substrate::argparse::Args;
use crate::substrate::json::Json;
use crate::workload::scenarios::{self, ScenarioConfig};
use crate::workload::TimedRequest;

use super::harness::write_bench_json;

/// Requests whose private token band the script targets (`fault_mix`
/// gives request `i` the band `[20 + 10i, 20 + 10i + 9]`).
const POISONED_ID: u64 = 2; // band [40, 49]: persistent decode fault
const NAN_ID: u64 = 5; // band [70, 79]: non-finite logits rows

/// The replayed trace: 12 requests, disjoint token bands, no deadlines
/// (backoff delays must never flip a healthy finish reason).
pub fn fault_trace() -> Vec<TimedRequest> {
    scenarios::fault_mix(&ScenarioConfig {
        n_requests: 12,
        max_new_tokens: 8,
        ..Default::default()
    })
}

/// The injected schedule. Scripted stall/transient ordinals sit at
/// decode calls 0 and 1 so they are always consumed *before* the first
/// persistent fault can start a blame search — bisection probes must
/// only ever see the poison fault, or an innocent slot could be blamed.
pub fn smoke_script() -> FaultScript {
    FaultScript {
        transient_decode_calls: vec![1],
        transient_prefill_calls: vec![0],
        poison_token_range: Some((40, 49)),
        nan_token_range: Some((70, 79)),
        stall_decode_calls: vec![0],
        stall: Duration::from_millis(10),
        pool_alloc_failures: 1,
    }
}

/// Fast-recovery policy for the smoke gate: sub-millisecond backoff (the
/// gate is about counts and byte-identity, not wall time) and a 5 ms
/// watchdog threshold so the scripted 10 ms stall is counted.
fn smoke_retry() -> RetryPolicy {
    RetryPolicy { backoff_ms: 0.5, watchdog_ms: 5.0, ..Default::default() }
}

/// One mock replay of the fault trace, optionally under a fault script.
pub struct MockOut {
    pub run: ServingRun,
    pub injected: u64,
    pub faults: Json,
    pub transient_retries: u64,
    pub blame_bisections: u64,
    pub blamed_requests: u64,
    pub quarantined: u64,
    pub degraded_steps: u64,
    pub watchdog_stalls: u64,
    pub wall_s: f64,
}

fn replay_mock(script: Option<FaultScript>) -> Result<MockOut> {
    let engine =
        MockEngine::new().with_seq_buckets(vec![16, 32, 64, 128]).with_step_delay(
            Duration::from_millis(2),
        );
    let (engine, injector) = match script {
        Some(sc) => {
            let inj = Arc::new(FaultInjector::new(sc));
            (engine.with_faults(inj.clone()), Some(inj))
        }
        None => (engine, None),
    };
    let mut sched = Scheduler::new(
        engine,
        // polar mode so a persistent fault exercises the dense
        // degradation path before blame isolation
        SparsityController::new(Mode::Polar { density: 0.5 }),
        SchedulerConfig { max_batch: 8, retry: smoke_retry(), ..Default::default() },
    );
    let t0 = std::time::Instant::now();
    let run = replay(&mut sched, fault_trace())?;
    let wall_s = t0.elapsed().as_secs_f64();
    let m = &sched.metrics;
    Ok(MockOut {
        injected: injector.map_or(0, |i| i.injected()),
        faults: m.faults_json(),
        transient_retries: m.transient_retries,
        blame_bisections: m.blame_bisections,
        blamed_requests: m.blamed_requests,
        quarantined: m.quarantined,
        degraded_steps: m.degraded_steps,
        watchdog_stalls: m.watchdog_stalls,
        wall_s,
        run,
    })
}

fn outputs(run: &ServingRun) -> BTreeMap<u64, (Vec<i32>, FinishReason)> {
    run.completions
        .iter()
        .map(|c| (c.id, (c.output_ids.clone(), c.finish)))
        .collect()
}

/// The in-tree acceptance gate (also asserted by this module's tests).
pub struct Gate {
    /// Every request outside the two targeted bands finished with the
    /// exact same token ids and finish reason as the fault-free replay.
    pub survivors_bit_identical: bool,
    /// Both targeted requests terminated with `engine_fault` (not a
    /// hang, not a server death, not a silent wrong answer).
    pub faulted_terminal: bool,
    pub pass: bool,
}

pub fn check_gate(baseline: &MockOut, faulted: &MockOut) -> Gate {
    let base = outputs(&baseline.run);
    let bad = outputs(&faulted.run);
    let mut survivors_bit_identical = base.len() == bad.len();
    for (id, expect) in &base {
        if *id == POISONED_ID || *id == NAN_ID {
            continue;
        }
        if bad.get(id) != Some(expect) {
            survivors_bit_identical = false;
        }
    }
    let faulted_terminal = [POISONED_ID, NAN_ID].iter().all(|id| {
        bad.get(id).is_some_and(|(_, f)| *f == FinishReason::EngineFault)
    });
    let pass = survivors_bit_identical
        && faulted_terminal
        && faulted.transient_retries > 0
        && faulted.blame_bisections >= 1
        && faulted.blamed_requests == 1
        && faulted.quarantined >= 1
        && faulted.degraded_steps >= 1
        && faulted.watchdog_stalls >= 1;
    Gate { survivors_bit_identical, faulted_terminal, pass }
}

fn run_json(o: &MockOut) -> Json {
    Json::obj(vec![
        ("completions", o.run.completions.len().into()),
        (
            "tokens_out",
            o.run
                .completions
                .iter()
                .map(|c| c.output_ids.len())
                .sum::<usize>()
                .into(),
        ),
        ("injected_faults", (o.injected as usize).into()),
        ("faults", o.faults.clone()),
        ("wall_ms", (o.wall_s * 1e3).into()),
    ])
}

pub fn run(rest: &[String]) -> Result<()> {
    let args = Args::new(
        "bench fault-recovery",
        "injected-fault replay: survivors bit-identical, bad requests engine_fault",
    )
    .flag("model", "opt-tiny", "model name under the artifacts dir")
    .flag("artifacts", "artifacts", "artifacts root directory")
    .flag("out", "BENCH_faults.json", "output JSON path")
    .switch("smoke", "mock-only (no artifacts); this is what CI gates on");
    let p = match args.parse(rest) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };

    let baseline = replay_mock(None)?;
    let faulted = replay_mock(Some(smoke_script()))?;
    let gate = check_gate(&baseline, &faulted);
    println!(
        "fault-free : {} requests, {} tokens",
        baseline.run.completions.len(),
        baseline
            .run
            .completions
            .iter()
            .map(|c| c.output_ids.len())
            .sum::<usize>()
    );
    println!(
        "faulted    : {} injected — {} retries, {} bisection(s), {} blamed, \
         {} quarantined, {} degraded step(s), {} watchdog stall(s)",
        faulted.injected,
        faulted.transient_retries,
        faulted.blame_bisections,
        faulted.blamed_requests,
        faulted.quarantined,
        faulted.degraded_steps,
        faulted.watchdog_stalls,
    );
    println!(
        "gate       : survivors bit-identical {} | bad requests engine_fault {} | pass {}",
        gate.survivors_bit_identical, gate.faulted_terminal, gate.pass
    );
    if !gate.pass {
        eprintln!("WARNING: fault-recovery gate failed");
    }

    // non-smoke: a fault-free reference replay on the real engine
    // (injection is mock-only; this row is informational)
    let reference = if p.get_bool("smoke") {
        Json::Null
    } else {
        let dir = std::path::PathBuf::from(p.get("artifacts")).join(p.get("model"));
        let exec = Arc::new(Executor::load(&dir).with_context(|| {
            format!("loading {} — run `make artifacts` first", dir.display())
        })?);
        let mut sched = Scheduler::new(
            Engine::new(exec),
            SparsityController::new(Mode::Dense),
            SchedulerConfig { max_batch: 8, ..Default::default() },
        );
        let t0 = std::time::Instant::now();
        let run = replay(&mut sched, fault_trace())?;
        Json::obj(vec![
            ("engine", p.get("model").into()),
            ("completions", run.completions.len().into()),
            ("ttft_ms_p50", (run.ttft.p50() * 1e3).into()),
            ("wall_ms", (t0.elapsed().as_secs_f64() * 1e3).into()),
        ])
    };

    let sc = smoke_script();
    let report = Json::obj(vec![
        ("bench", "fault-recovery".into()),
        ("engine", "mock".into()),
        ("requests", fault_trace().len().into()),
        (
            "script",
            Json::obj(vec![
                ("stall_decode_calls", sc.stall_decode_calls.len().into()),
                ("stall_ms", (sc.stall.as_secs_f64() * 1e3).into()),
                ("transient_decode_calls", sc.transient_decode_calls.len().into()),
                ("transient_prefill_calls", sc.transient_prefill_calls.len().into()),
                ("pool_alloc_failures", (sc.pool_alloc_failures as usize).into()),
                ("poisoned_request", (POISONED_ID as usize).into()),
                ("nan_request", (NAN_ID as usize).into()),
            ]),
        ),
        ("baseline", run_json(&baseline)),
        ("faulted", run_json(&faulted)),
        ("reference", reference),
        (
            "gate",
            Json::obj(vec![
                ("survivors_bit_identical", gate.survivors_bit_identical.into()),
                ("faulted_finish_engine_fault", gate.faulted_terminal.into()),
                ("pass", gate.pass.into()),
            ]),
        ),
    ]);
    write_bench_json(p.get("out"), &report)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate: under the full fault script the server-side
    /// scheduler never dies, the poisoned and NaN requests finish with a
    /// structured `engine_fault`, and every survivor's token stream is
    /// byte-for-byte the fault-free replay.
    #[test]
    fn injected_fault_replay_passes_the_recovery_gate() {
        let baseline = replay_mock(None).unwrap();
        let faulted = replay_mock(Some(smoke_script())).unwrap();
        assert_eq!(baseline.run.completions.len(), 12);
        assert_eq!(faulted.run.completions.len(), 12, "no request may hang or vanish");
        assert!(faulted.injected >= 4, "script barely fired: {}", faulted.injected);
        let gate = check_gate(&baseline, &faulted);
        assert!(gate.survivors_bit_identical, "a healthy stream was perturbed");
        assert!(gate.faulted_terminal, "bad requests must finish engine_fault");
        assert!(gate.pass, "faults: {}", faulted.faults);
        // the targeted requests got exactly their prefill token before
        // the fault landed (decode is where both injections live)
        let bad = outputs(&faulted.run);
        assert_eq!(bad[&POISONED_ID].0, vec![41]);
        assert_eq!(bad[&NAN_ID].0, vec![71]);
    }

    /// Fault-free replays of the same trace are deterministic — the
    /// bit-identical comparison is meaningful.
    #[test]
    fn fault_free_replay_is_deterministic() {
        let a = replay_mock(None).unwrap();
        let b = replay_mock(None).unwrap();
        assert_eq!(outputs(&a.run), outputs(&b.run));
        assert_eq!(a.injected, 0);
        assert_eq!(a.transient_retries, 0);
        assert_eq!(a.blame_bisections, 0);
    }
}
