//! `bench shard-scaling` — shard-aware zero-shell serving (Figs 11, 12),
//! gated in-tree: sweep TP ∈ {1, 2, 4} and PP ∈ {1, 2} across the batch
//! buckets and prove that selective-head routing *cuts shard dispatches*
//! without perturbing the served streams.
//!
//! What the gates hold:
//! * **dispatch cut** — on every routed TP point, dispatched (layer,
//!   shard) pairs per step are strictly below the dense-sharded run on
//!   the same geometry (unselected attention shards degrade to the cheap
//!   KV-write entry; MLP shards owning no union neuron are skipped).
//! * **attention skip floor + flat ratio** — with the mock bank's top-1
//!   head routing, every routed layer dispatches exactly one attention
//!   shard, so each step banks at least `S - 1` skips and the dispatch
//!   ratio stays flat across batch buckets (head sparsity is
//!   batch-invariant §4.2). The capacity-fitted MLP union row spans every
//!   shard at the mock's full `mlp_cap`, so the cut here is purely
//!   head-driven — the MLP union's climb toward dense is
//!   `bench sparsity-scaling`'s gate.
//! * **bit-identical streams** — every sharded configuration reproduces
//!   the single-device run's token streams exactly.
//! * **zero shell, zero extra host bytes** — no gather/scatter bytes
//!   anywhere, and sharding moves no additional host traffic vs the
//!   single-device run (partials combine on-device, accounted as
//!   `allreduce_bytes`; the old per-layer f32 host loop is gone).
//!
//! `--smoke` runs the deterministic mock (TP=4 uses the G=4 bank variant
//! so four shards each own one head group); the full mode sweeps the real
//! sharded entries (`tp{S}_*`, `pp2_stage*`) from compiled artifacts.

use anyhow::{bail, Context, Result};

use crate::coordinator::mock::{mock_router_bank_g, MockEngine};
use crate::coordinator::{
    Mode, Request, Scheduler, SchedulerConfig, SparsityController,
};
use crate::runtime::{mlp_shard_k, Engine, Executor, RoutingPolicy, StepProfile};
use crate::substrate::argparse::Args;
use crate::substrate::json::Json;

use super::harness::{write_bench_json, BenchOpts};
use super::throughput::{decode_throughput_pp2, decode_throughput_tp};

/// One sharded configuration at one batch bucket.
pub struct ShardPoint {
    pub config: String,
    pub n_shards: usize,
    pub pp_stages: usize,
    pub batch: usize,
    pub decode_steps: u64,
    pub dispatched: u64,
    pub skipped: u64,
    /// Dense-mode run on the same sharded geometry (the cut baseline).
    pub dense_dispatched: u64,
    pub dense_steps: u64,
    pub allreduce_bytes: u64,
    pub shell_bytes: u64,
    pub streams_match: bool,
    pub host_bytes_match: bool,
}

impl ShardPoint {
    pub fn dispatched_per_step(&self) -> f64 {
        self.dispatched as f64 / self.decode_steps.max(1) as f64
    }
    pub fn dense_per_step(&self) -> f64 {
        self.dense_dispatched as f64 / self.dense_steps.max(1) as f64
    }
}

fn shell_bytes(p: &StepProfile) -> u64 {
    p.gather_bytes + p.scatter_bytes + p.prefill_gather_bytes + p.prefill_scatter_bytes
}

/// Serve `batch` lockstep requests through a scheduler on a mock with the
/// given shard mode; returns the sorted token streams and the profile.
fn run_point(
    groups: usize,
    tp: Option<usize>,
    pp2: bool,
    batch: usize,
    max_new: usize,
    routed: bool,
) -> Result<(Vec<Vec<i32>>, StepProfile)> {
    let mut eng = MockEngine::new().with_groups(groups);
    if let Some(s) = tp {
        eng = eng.with_tp(s);
    }
    if pp2 {
        eng = eng.with_pp2();
    }
    let ctl = if routed {
        SparsityController::with_routers(
            Mode::Polar { density: 1.0 / groups as f64 },
            Some(mock_router_bank_g(groups)),
            RoutingPolicy { head_k: 1, mlp_req_k: vec![2, 2], mlp_cap: 16 },
        )
    } else {
        SparsityController::new(Mode::Dense)
    };
    let mut sched = Scheduler::new(
        eng,
        ctl,
        SchedulerConfig { max_batch: batch, compact: true, ..Default::default() },
    );
    for i in 0..batch {
        let t = 100 + i as i32;
        sched.enqueue(
            Request::builder(vec![t, t]).id(i as u64).max_new_tokens(max_new).build(),
        );
    }
    let mut done = sched.run_to_completion()?;
    if done.len() != batch {
        bail!("shard point b={batch}: {} of {batch} completed", done.len());
    }
    done.sort_by_key(|c| c.id);
    let streams = done.into_iter().map(|c| c.output_ids).collect();
    Ok((streams, sched.profile()))
}

/// The smoke sweep used by CI and the in-tree acceptance test: for each
/// batch bucket, a single-device baseline per bank geometry, then TP=2,
/// TP=4 (G=4) and PP=2 runs compared against it.
pub fn smoke_sweep(batches: &[usize], max_new: usize) -> Result<Vec<ShardPoint>> {
    let mut points = Vec::new();
    for &b in batches {
        let (base2, base2_prof) = run_point(2, None, false, b, max_new, true)?;
        let (base4, base4_prof) = run_point(4, None, false, b, max_new, true)?;
        points.push(ShardPoint {
            config: "single".into(),
            n_shards: 1,
            pp_stages: 1,
            batch: b,
            decode_steps: base2_prof.decode_steps,
            dispatched: base2_prof.shards_dispatched,
            skipped: base2_prof.shards_skipped,
            dense_dispatched: 0,
            dense_steps: 0,
            allreduce_bytes: base2_prof.allreduce_bytes,
            shell_bytes: shell_bytes(&base2_prof),
            streams_match: true,
            host_bytes_match: true,
        });
        for (config, groups, tp, pp2) in [
            ("tp2", 2usize, Some(2usize), false),
            ("tp4", 4, Some(4), false),
            ("pp2", 2, None, true),
        ] {
            let (base, base_prof) =
                if groups == 4 { (&base4, &base4_prof) } else { (&base2, &base2_prof) };
            let (streams, prof) = run_point(groups, tp, pp2, b, max_new, true)?;
            let (_, dense_prof) = run_point(groups, tp, pp2, b, max_new, false)?;
            points.push(ShardPoint {
                config: config.into(),
                n_shards: tp.unwrap_or(1),
                pp_stages: if pp2 { 2 } else { 1 },
                batch: b,
                decode_steps: prof.decode_steps,
                dispatched: prof.shards_dispatched,
                skipped: prof.shards_skipped,
                dense_dispatched: dense_prof.shards_dispatched,
                dense_steps: dense_prof.decode_steps,
                allreduce_bytes: prof.allreduce_bytes,
                shell_bytes: shell_bytes(&prof),
                streams_match: streams == *base,
                host_bytes_match: prof.h2d_bytes == base_prof.h2d_bytes
                    && prof.d2h_bytes == base_prof.d2h_bytes,
            });
        }
    }
    Ok(points)
}

// ---------------------------------------------------------------------------
// gates
// ---------------------------------------------------------------------------

/// Routed TP points dispatch strictly fewer (layer, shard) pairs per step
/// than the dense-sharded run on the same geometry.
pub fn dispatch_cut(points: &[ShardPoint]) -> bool {
    points.iter().filter(|p| p.n_shards > 1).all(|p| {
        p.dispatched * p.dense_steps.max(1) < p.dense_dispatched * p.decode_steps.max(1)
    })
}

/// Top-1 head routing leaves at least `S - 1` kvw-only attention shards
/// per routed layer per step, at EVERY batch bucket (batch-invariant).
pub fn attn_skip_floor(points: &[ShardPoint]) -> bool {
    points
        .iter()
        .filter(|p| p.n_shards > 1)
        .all(|p| p.skipped >= (p.n_shards as u64 - 1) * p.decode_steps)
}

pub fn streams_identical(points: &[ShardPoint]) -> bool {
    points.iter().all(|p| p.streams_match)
}

pub fn zero_shell(points: &[ShardPoint]) -> bool {
    points.iter().all(|p| p.shell_bytes == 0)
}

/// Sharding adds no host traffic: sharded runs move byte-for-byte the
/// same h2d/d2h as the single-device run of the same workload.
pub fn host_bytes_flat(points: &[ShardPoint]) -> bool {
    points.iter().all(|p| p.host_bytes_match)
}

/// PP stages always both dispatch and nothing reduces across them.
pub fn pp_stages_sound(points: &[ShardPoint]) -> bool {
    points.iter().filter(|p| p.pp_stages == 2).all(|p| {
        p.dispatched == 2 * p.decode_steps && p.skipped == 0 && p.allreduce_bytes == 0
    })
}

/// The dispatch ratio tracks head density, flat across batch buckets:
/// per-config relative spread of dispatched-per-step ≤ 5% (head routing
/// is per-request top-k, so the shard cut is batch-invariant §4.2).
pub fn dispatch_flat(points: &[ShardPoint]) -> bool {
    let mut configs: Vec<&str> = points.iter().map(|p| p.config.as_str()).collect();
    configs.sort_unstable();
    configs.dedup();
    configs.into_iter().all(|c| {
        let vals: Vec<f64> = points
            .iter()
            .filter(|p| p.config == c && p.n_shards > 1)
            .map(|p| p.dispatched_per_step())
            .collect();
        let max = vals.iter().cloned().fold(f64::MIN, f64::max);
        let min = vals.iter().cloned().fold(f64::MAX, f64::min);
        vals.is_empty() || (max - min) / max <= 0.05
    })
}

fn point_json(p: &ShardPoint) -> Json {
    Json::obj(vec![
        ("config", p.config.clone().into()),
        ("n_shards", p.n_shards.into()),
        ("pp_stages", p.pp_stages.into()),
        ("batch", p.batch.into()),
        ("decode_steps", (p.decode_steps as usize).into()),
        ("shards_dispatched", (p.dispatched as usize).into()),
        ("shards_skipped", (p.skipped as usize).into()),
        ("dispatched_per_step", p.dispatched_per_step().into()),
        ("dense_dispatched_per_step", p.dense_per_step().into()),
        ("allreduce_bytes", (p.allreduce_bytes as usize).into()),
        ("shell_bytes", (p.shell_bytes as usize).into()),
        ("streams_match_single_device", p.streams_match.into()),
        ("host_bytes_match_single_device", p.host_bytes_match.into()),
    ])
}

fn gates_json(points: &[ShardPoint]) -> (Json, bool) {
    let cut = dispatch_cut(points);
    let floor = attn_skip_floor(points);
    let flat = dispatch_flat(points);
    let streams = streams_identical(points);
    let shell = zero_shell(points);
    let host = host_bytes_flat(points);
    let pp = pp_stages_sound(points);
    let pass = cut && floor && flat && streams && shell && host && pp;
    (
        Json::obj(vec![
            ("dispatch_cut", cut.into()),
            ("attn_skip_floor", floor.into()),
            ("dispatch_flat", flat.into()),
            ("streams_identical", streams.into()),
            ("zero_shell", shell.into()),
            ("host_bytes_flat", host.into()),
            ("pp_stages_sound", pp.into()),
            ("pass", pass.into()),
        ]),
        pass,
    )
}

/// Real-artifact sweep: time the fused TP/PP drivers over the sharded
/// entries and read the dispatch counters off the engine profile. Only
/// configurations whose entries exist in the manifest are run.
fn real_sweep(engine: &Engine, opts: BenchOpts) -> Result<Vec<ShardPoint>> {
    let m = engine.exec.manifest();
    let crit = engine.exec.config().critical_density;
    let sha = format!("sha_d{:04}", (crit * 1000.0).round() as usize);
    let polar = format!("polar_d{:04}", (crit * 1000.0).round() as usize);
    let n = *m.seq_buckets.last().context("empty seq buckets")?;
    let mut points = Vec::new();
    for s in [2usize, 4] {
        for &b in &m.batch_buckets {
            if !m.entries.contains_key(&m.tp_attn_entry_name(s, 0, &sha, b, n)) {
                continue;
            }
            let mlp = match mlp_shard_k(m, s, b) {
                Some(k) => format!("k{k}"),
                None => "dense".to_string(),
            };
            engine.exec.reset_profile();
            decode_throughput_tp(engine, s, "dense", "dense", b, n, opts)?;
            let dense = engine.exec.profile_snapshot();
            engine.exec.reset_profile();
            decode_throughput_tp(engine, s, &sha, &mlp, b, n, opts)?;
            let prof = engine.exec.profile_snapshot();
            points.push(ShardPoint {
                config: format!("tp{s}"),
                n_shards: s,
                pp_stages: 1,
                batch: b,
                decode_steps: prof.decode_steps,
                dispatched: prof.shards_dispatched,
                skipped: prof.shards_skipped,
                dense_dispatched: dense.shards_dispatched,
                dense_steps: dense.decode_steps,
                allreduce_bytes: prof.allreduce_bytes,
                shell_bytes: shell_bytes(&prof),
                // the bitwise-equality gates run on the mock (and in the
                // AOT suite's python bitwise tests); timing sweeps here
                streams_match: true,
                host_bytes_match: true,
            });
        }
    }
    for &b in &m.batch_buckets {
        if !m.entries.contains_key(&m.pp_stage_entry_name(0, &polar, b, n)) {
            continue;
        }
        engine.exec.reset_profile();
        decode_throughput_pp2(engine, &polar, b, n, opts)?;
        let prof = engine.exec.profile_snapshot();
        points.push(ShardPoint {
            config: "pp2".into(),
            n_shards: 1,
            pp_stages: 2,
            batch: b,
            decode_steps: prof.decode_steps,
            dispatched: 2 * prof.decode_steps,
            skipped: 0,
            dense_dispatched: 2 * prof.decode_steps,
            dense_steps: prof.decode_steps,
            allreduce_bytes: prof.allreduce_bytes,
            shell_bytes: shell_bytes(&prof),
            streams_match: true,
            host_bytes_match: true,
        });
    }
    Ok(points)
}

pub fn run(rest: &[String]) -> Result<()> {
    let args = Args::new(
        "bench shard-scaling",
        "shard-aware serving: routing cuts shard dispatches, streams stay bit-identical",
    )
    .flag("model", "opt-tiny", "model name under the artifacts dir")
    .flag("artifacts", "artifacts", "artifacts root directory")
    .flag("max-new", "8", "tokens generated per request at each smoke point")
    .flag("out", "BENCH_shards.json", "output JSON path")
    .switch("smoke", "run on the deterministic mock engine (no artifacts)");
    let p = match args.parse(rest) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let max_new = p.get_usize("max-new").map_err(anyhow::Error::msg)?;

    let (engine_label, points) = if p.get_bool("smoke") {
        ("mock".to_string(), smoke_sweep(&[1, 2, 4, 8], max_new)?)
    } else {
        let dir = std::path::PathBuf::from(p.get("artifacts")).join(p.get("model"));
        let exec = std::sync::Arc::new(Executor::load(&dir).with_context(|| {
            format!("loading {} — run `make artifacts` first", dir.display())
        })?);
        let engine = Engine::new(exec);
        let points = real_sweep(&engine, BenchOpts::default())?;
        if points.is_empty() {
            bail!("no sharded entries (tp*/pp2_stage*) in this artifact's manifest");
        }
        (p.get("model").to_string(), points)
    };

    let (gates, pass) = gates_json(&points);
    let report = Json::obj(vec![
        ("bench", "shard-scaling".into()),
        ("engine", engine_label.clone().into()),
        ("max_new", max_new.into()),
        ("configs", Json::arr(points.iter().map(point_json))),
        ("gates", gates),
    ]);

    println!("shard-scaling ({engine_label}, {} points)", points.len());
    for pt in &points {
        println!(
            "  {:<7} b={:<3} dispatched/step {:.2} (dense {:.2})  skipped {:<4} allreduce {} B  shell {} B",
            pt.config,
            pt.batch,
            pt.dispatched_per_step(),
            pt.dense_per_step(),
            pt.skipped,
            pt.allreduce_bytes,
            pt.shell_bytes,
        );
    }
    write_bench_json(p.get("out"), &report)?;
    if !pass {
        bail!("shard-scaling gates failed: {}", gates_json(&points).0);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate, end to end on the mock: routing strictly cuts
    /// shard dispatches at every batch bucket while every sharded stream
    /// stays bit-identical to single-device serving, with zero shell
    /// bytes and zero sharding-induced host traffic.
    #[test]
    fn smoke_gates_hold_across_the_sweep() {
        let points = smoke_sweep(&[1, 2, 4, 8], 8).unwrap();
        // 4 batch buckets x (single + tp2 + tp4 + pp2)
        assert_eq!(points.len(), 16);
        assert!(dispatch_cut(&points), "routed TP did not cut dispatches");
        assert!(attn_skip_floor(&points), "attention skip floor violated");
        assert!(streams_identical(&points), "a sharded stream diverged");
        assert!(zero_shell(&points), "shell bytes on a sharded step");
        assert!(host_bytes_flat(&points), "sharding moved extra host bytes");
        assert!(pp_stages_sound(&points), "pp2 accounting broken");
        assert!(dispatch_flat(&points), "dispatch ratio varies with batch");
        let (_, pass) = gates_json(&points);
        assert!(pass);
        // unsharded baseline reports no shard traffic at all
        for p in points.iter().filter(|p| p.config == "single") {
            assert_eq!((p.dispatched, p.skipped, p.allreduce_bytes), (0, 0, 0));
        }
        // exact per-step arithmetic, every batch bucket: each step covers
        // L*S attn + L*S mlp pairs; top-1 head routing dispatches exactly
        // one attention shard on layer 1 (S-1 kvw skips), and the
        // capacity-fitted MLP row spans every shard — so the cut is
        // purely head-driven and EXACTLY batch-invariant
        for p in points.iter().filter(|p| p.n_shards > 1) {
            let s = p.n_shards as u64;
            assert_eq!(
                p.dispatched + p.skipped,
                4 * s * p.decode_steps,
                "{} b={}: dispatch partition does not cover the step",
                p.config,
                p.batch
            );
            assert_eq!(p.skipped, (s - 1) * p.decode_steps, "{} b={}", p.config, p.batch);
            assert_eq!(p.dense_per_step(), (4 * s) as f64);
            assert!(p.allreduce_bytes > 0, "TP partials never reduced");
        }
    }

    /// The gate helpers reject the failure shapes they exist to catch.
    #[test]
    fn gates_detect_violations() {
        let mk = |dispatched: u64, skipped: u64, shell: u64, streams: bool| ShardPoint {
            config: "tp2".into(),
            n_shards: 2,
            pp_stages: 1,
            batch: 1,
            decode_steps: 10,
            dispatched,
            skipped,
            dense_dispatched: 80,
            dense_steps: 10,
            allreduce_bytes: 1,
            shell_bytes: shell,
            streams_match: streams,
            host_bytes_match: true,
        };
        let good = [mk(60, 20, 0, true)];
        assert!(dispatch_cut(&good) && attn_skip_floor(&good));
        assert!(streams_identical(&good) && zero_shell(&good));
        // no cut: routed dispatches as much as dense
        assert!(!dispatch_cut(&[mk(80, 0, 0, true)]));
        // floor: fewer than (S-1) skips per step
        assert!(!attn_skip_floor(&[mk(75, 5, 0, true)]));
        assert!(!streams_identical(&[mk(60, 20, 0, false)]));
        assert!(!zero_shell(&[mk(60, 20, 64, true)]));
    }
}
