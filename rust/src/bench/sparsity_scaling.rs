//! `bench sparsity-scaling` — the paper's central crossover, measured
//! in-tree: sweep the batch buckets at a fixed sparsity mode and record
//! per-layer **batch-union densities** from the runtime routers.
//!
//! Selective head attention consumes *per-request* top-k indices, so its
//! union (and its per-request work density) stays flat as the batch
//! grows; the selective MLP GEMM gathers the *union* of every request's
//! top-k neurons, so its union density climbs toward dense — Deja Vu's
//! failure mode at batch (§4.1 vs §4.2, Fig 1b). The emitted
//! `BENCH_sparsity.json` records both curves plus router overhead per
//! step.
//!
//! `--smoke` runs the mock engine with [`mock_router_bank`]: head routing
//! is input-independent and MLP routing token-dependent, so the union
//! densities are exact, deterministic functions of the batch size (the
//! committed artifact's numbers reproduce bit-for-bit; only the
//! router-overhead timings are machine-dependent).

use anyhow::{Context, Result};

use crate::coordinator::mock::{mock_router_bank, MockEngine};
use crate::coordinator::{
    Mode, Request, Scheduler, SchedulerConfig, SparsityController, StepEngine,
};
use crate::runtime::{Engine, Executor, RoutingPolicy};
use crate::substrate::argparse::Args;
use crate::substrate::json::Json;

use super::harness::write_bench_json;

/// One batch point of the sweep.
pub struct BatchPoint {
    pub batch: usize,
    pub routed_steps: u64,
    pub head_union: Vec<f64>,
    pub mlp_union: Vec<f64>,
    pub head_density: f64,
    pub router_ns_per_step: f64,
}

impl BatchPoint {
    pub fn head_union_mean(&self) -> f64 {
        mean(&self.head_union)
    }
    pub fn mlp_union_mean(&self) -> f64 {
        mean(&self.mlp_union)
    }
}

fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

/// Serve `batch` lockstep requests (distinct first tokens, identical
/// lengths) through a scheduler and return the controller's accumulated
/// routing telemetry. The scheduler path is the measurement: indices
/// travel controller -> engine -> entry exactly as in production.
fn sweep_point<E: StepEngine>(
    make_engine: impl FnOnce() -> E,
    ctl: SparsityController,
    batch: usize,
    max_new: usize,
) -> Result<BatchPoint> {
    let mut sched = Scheduler::new(
        make_engine(),
        ctl,
        SchedulerConfig { max_batch: batch, compact: true, ..Default::default() },
    );
    for i in 0..batch {
        // distinct tokens per request: the MLP union sees `batch` distinct
        // activation sets while the head routers see the same ranking
        let t = 100 + i as i32;
        sched.enqueue(
            Request::builder(vec![t, t])
                .id(i as u64)
                .max_new_tokens(max_new)
                .build(),
        );
    }
    let done = sched.run_to_completion()?;
    if done.len() != batch {
        anyhow::bail!("sweep point b={batch}: {} of {batch} completed", done.len());
    }
    let stats = &sched.sparsity().stats;
    Ok(BatchPoint {
        batch,
        routed_steps: stats.routed_steps,
        head_union: stats.head_union_mean(),
        mlp_union: stats.mlp_union_mean(),
        head_density: stats.head_density,
        router_ns_per_step: stats.router_ns as f64 / stats.routed_steps.max(1) as f64,
    })
}

/// The smoke sweep used by CI and the in-tree acceptance test.
pub fn smoke_sweep(batches: &[usize], max_new: usize) -> Result<Vec<BatchPoint>> {
    let policy = RoutingPolicy { head_k: 1, mlp_req_k: vec![2, 2], mlp_cap: 16 };
    batches
        .iter()
        .map(|&b| {
            let ctl = SparsityController::with_routers(
                Mode::Polar { density: 0.5 },
                Some(mock_router_bank()),
                policy.clone(),
            );
            sweep_point(MockEngine::new, ctl, b, max_new)
        })
        .collect()
}

fn point_json(p: &BatchPoint) -> Json {
    Json::obj(vec![
        ("batch", p.batch.into()),
        ("routed_steps", (p.routed_steps as usize).into()),
        ("head_union_density", p.head_union_mean().into()),
        ("mlp_union_density", p.mlp_union_mean().into()),
        (
            "head_union_per_layer",
            Json::arr(p.head_union.iter().map(|&x| x.into())),
        ),
        (
            "mlp_union_per_layer",
            Json::arr(p.mlp_union.iter().map(|&x| x.into())),
        ),
        ("head_density_per_request", p.head_density.into()),
        ("router_ns_per_step", p.router_ns_per_step.into()),
    ])
}

/// Relative spread of the head-union curve: (max - min) / max.
pub fn head_spread(points: &[BatchPoint]) -> f64 {
    let vals: Vec<f64> = points.iter().map(|p| p.head_union_mean()).collect();
    let max = vals.iter().cloned().fold(f64::MIN, f64::max);
    let min = vals.iter().cloned().fold(f64::MAX, f64::min);
    if max <= 0.0 {
        0.0
    } else {
        (max - min) / max
    }
}

pub fn mlp_monotone(points: &[BatchPoint]) -> bool {
    points
        .windows(2)
        .all(|w| w[1].mlp_union_mean() >= w[0].mlp_union_mean() - 1e-12)
}

pub fn run(rest: &[String]) -> Result<()> {
    let args = Args::new(
        "bench sparsity-scaling",
        "batch-union density scaling: head (flat) vs MLP (toward dense)",
    )
    .flag("model", "opt-tiny", "model name under the artifacts dir")
    .flag("artifacts", "artifacts", "artifacts root directory")
    .flag("mode", "polar", "polar | polar@<density>")
    .flag("max-new", "16", "tokens generated per request at each point")
    .flag("out", "BENCH_sparsity.json", "output JSON path")
    .switch("smoke", "run on the deterministic mock engine (no artifacts)");
    let p = match args.parse(rest) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let max_new = p.get_usize("max-new").map_err(anyhow::Error::msg)?;

    let (engine_label, mode_tag, points) = if p.get_bool("smoke") {
        let batches = [1usize, 2, 4, 8];
        (
            "mock".to_string(),
            "polar_d0500".to_string(),
            smoke_sweep(&batches, max_new)?,
        )
    } else {
        let dir = std::path::PathBuf::from(p.get("artifacts")).join(p.get("model"));
        let exec = std::sync::Arc::new(Executor::load(&dir).with_context(|| {
            format!("loading {} — run `make artifacts` first", dir.display())
        })?);
        let mode = Mode::parse(p.get("mode"), exec.config().critical_density)?;
        let batches = exec.manifest().batch_buckets.clone();
        // one engine for the whole sweep (the router bank is built once);
        // Engine is cheaply cloneable (Arc-backed) per point
        let engine = Engine::new(exec);
        SparsityController::for_engine(mode, &engine).validate(engine.exec.manifest())?;
        let points = batches
            .iter()
            .map(|&b| {
                let e = engine.clone();
                let ctl = SparsityController::for_engine(mode, &e);
                sweep_point(move || e, ctl, b, max_new)
            })
            .collect::<Result<Vec<_>>>()?;
        (p.get("model").to_string(), mode.tag(), points)
    };

    let spread = head_spread(&points);
    let monotone = mlp_monotone(&points);
    let report = Json::obj(vec![
        ("bench", "sparsity-scaling".into()),
        ("engine", engine_label.clone().into()),
        ("mode", mode_tag.into()),
        ("max_new", max_new.into()),
        ("batches", Json::arr(points.iter().map(point_json))),
        ("head_union_spread", spread.into()),
        ("mlp_union_monotone", monotone.into()),
    ]);

    println!("sparsity-scaling ({engine_label}, {} batch points)", points.len());
    for pt in &points {
        println!(
            "  b={:<3} head union {:.3} (per-request {:.3})  mlp union {:.3}  router {:.1} us/step",
            pt.batch,
            pt.head_union_mean(),
            pt.head_density,
            pt.mlp_union_mean(),
            pt.router_ns_per_step / 1e3,
        );
    }
    println!(
        "  head-union spread {:.1}% across batches; mlp union monotone: {monotone}",
        spread * 100.0
    );
    write_bench_json(p.get("out"), &report)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate: head union density batch-invariant (flat
    /// within 5% from b=1 to the max bucket) while MLP union density
    /// rises monotonically to dense.
    #[test]
    fn smoke_head_flat_mlp_monotone_to_dense() {
        let points = smoke_sweep(&[1, 2, 4, 8], 16).unwrap();
        assert_eq!(points.len(), 4);
        // exact analytic values for the mock bank
        for p in &points {
            assert_eq!(p.head_union_mean(), 0.5, "b={}", p.batch);
            assert_eq!(p.head_density, 0.5, "b={}", p.batch);
            assert_eq!(p.routed_steps, 15, "b={}", p.batch);
        }
        let mlp: Vec<f64> = points.iter().map(|p| p.mlp_union_mean()).collect();
        assert_eq!(mlp, vec![0.125, 0.25, 0.5, 1.0]);
        assert!(head_spread(&points) <= 0.05, "{}", head_spread(&points));
        assert!(mlp_monotone(&points));
        assert_eq!(points.last().unwrap().mlp_union_mean(), 1.0);
    }

    #[test]
    fn spread_and_monotone_detect_violations() {
        let mk = |h: f64, m: f64| BatchPoint {
            batch: 1,
            routed_steps: 1,
            head_union: vec![h],
            mlp_union: vec![m],
            head_density: h,
            router_ns_per_step: 0.0,
        };
        let flat = [mk(0.5, 0.1), mk(0.5, 0.4)];
        assert_eq!(head_spread(&flat), 0.0);
        assert!(mlp_monotone(&flat));
        let bad = [mk(0.5, 0.4), mk(0.9, 0.1)];
        assert!(head_spread(&bad) > 0.05);
        assert!(!mlp_monotone(&bad));
    }
}
