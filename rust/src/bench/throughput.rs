//! Decode-throughput and latency measurement helpers shared by the
//! figure benches (Figs 1a, 5, 6, 11, 12, 13, 14).
//!
//! Protocol mirrors the paper: steady-state batched decoding at a fixed
//! (batch, kv-bucket) with sequences deep into the bucket (the paper uses
//! seq len 1920 with 2048-token caches; we use 7/8 of the bucket).

use anyhow::{bail, Result};

use crate::runtime::{
    split_pool_groups, split_pool_layers, BlockTables, Engine, KvCache, PagedKv, Tensor,
};
use crate::substrate::rng::Rng;
use crate::substrate::stats::Samples;

use super::harness::BenchOpts;

/// Sequence length used inside a bucket (paper: 1920 in 2048).
pub fn steady_len(n_bucket: usize) -> usize {
    (n_bucket * 7 / 8).max(1)
}

pub struct DecodeBench {
    pub tok_per_s: f64,
    pub step: Samples,
}

fn synthetic_inputs(engine: &Engine, b: usize, n: usize, seed: u64)
    -> Result<(Vec<i32>, Vec<i32>, Tensor)> {
    let cfg = engine.exec.config();
    let mut rng = Rng::new(seed);
    let tokens: Vec<i32> = (0..b).map(|_| rng.below(256) as i32).collect();
    let lengths = vec![steady_len(n) as i32; b];
    // small random KV values: realistic softmax spread without NaN risk
    let kv_elems = cfg.kv_elems(b, n);
    let data: Vec<f32> = (0..kv_elems)
        .map(|_| (rng.f64() as f32 - 0.5) * 0.2)
        .collect();
    let kvt = Tensor::f32(data, cfg.kv_shape(b, n))?;
    Ok((tokens, lengths, kvt))
}

/// Steady-state decode throughput for one (tag, batch, bucket).
pub fn decode_throughput(
    engine: &Engine,
    tag: &str,
    b: usize,
    n: usize,
    opts: BenchOpts,
) -> Result<DecodeBench> {
    let (tokens, lengths, kvt) = synthetic_inputs(engine, b, n, 42)?;
    let mut kv = Some(KvCache::from_tensor(&kvt, b, n)?);
    let mut run = |s: &mut Option<Samples>| -> Result<()> {
        let t0 = std::time::Instant::now();
        let out = engine.decode(tag, &tokens, &lengths, kv.take().unwrap(), None)?;
        if let Some(samples) = s {
            samples.push_duration(t0.elapsed());
        }
        kv = Some(out.kv);
        Ok(())
    };
    for _ in 0..opts.warmup {
        run(&mut None)?;
    }
    let mut step = Samples::new();
    for _ in 0..opts.iters {
        let mut s = Some(std::mem::take(&mut step));
        run(&mut s)?;
        step = s.unwrap();
    }
    let tok_per_s = b as f64 / step.mean();
    Ok(DecodeBench { tok_per_s, step })
}

/// Synthetic steady-state paged inputs shared by the PP/TP benches: a
/// randomly-filled pool (every slot deep into the bucket), identity-ish
/// block tables (slot `i` owns blocks `1 + i*width ..`), tokens and
/// lengths. The single source of the sharded benches' KV layout — the
/// per-path split happens through [`crate::runtime::shard`]'s pool
/// helpers, not ad-hoc slicing here.
fn synthetic_paged_inputs(
    engine: &Engine,
    b: usize,
    n: usize,
    seed: u64,
) -> Result<(Vec<i32>, Vec<i32>, BlockTables, Tensor)> {
    let cfg = engine.exec.config();
    let m = engine.exec.manifest();
    let (block, pool_blocks) = (m.kv_block, m.kv_pool_blocks);
    let width = n.div_ceil(block);
    if 1 + b * width > pool_blocks {
        bail!(
            "pool too small: {pool_blocks} blocks for {b} slots x {width} (n={n})"
        );
    }
    let mut rng = Rng::new(seed);
    let tokens: Vec<i32> = (0..b).map(|_| rng.below(256) as i32).collect();
    let lengths = vec![steady_len(n) as i32; b];
    let mut flat = vec![0i32; b * width];
    for i in 0..b {
        for w in 0..width {
            flat[i * width + w] = (1 + i * width + w) as i32;
        }
    }
    let tables = BlockTables::new(flat, b, width)?;
    let elems: usize = cfg.kv_pool_shape(pool_blocks, block).iter().product();
    let data: Vec<f32> = (0..elems)
        .map(|_| (rng.f64() as f32 - 0.5) * 0.2)
        .collect();
    let pool = Tensor::f32(data, cfg.kv_pool_shape(pool_blocks, block))?;
    Ok((tokens, lengths, tables, pool))
}

/// Same through the 2 paged pipeline stages (Fig 11): the pool is layer-
/// split across the stages and each step feeds both stages' KV buffers
/// straight into the next.
pub fn decode_throughput_pp2(
    engine: &Engine,
    tag: &str,
    b: usize,
    n: usize,
    opts: BenchOpts,
) -> Result<DecodeBench> {
    let cfg = engine.exec.config();
    let (tokens, lengths, tables, pool) = synthetic_paged_inputs(engine, b, n, 43)?;
    let (pool_blocks, block) = (engine.exec.manifest().kv_pool_blocks, engine.exec.manifest().kv_block);
    let l0 = cfg.n_layers / 2;
    let (k0, k1) = split_pool_layers(&pool, l0)?;
    let mut kv0 = Some(PagedKv::from_tensor(&k0, pool_blocks, block)?);
    let mut kv1 = Some(PagedKv::from_tensor(&k1, pool_blocks, block)?);
    let mut step = Samples::new();
    for i in 0..opts.warmup + opts.iters {
        let t0 = std::time::Instant::now();
        let (_logits, a, b2) = engine.decode_pp2_paged(
            tag,
            &tokens,
            &lengths,
            &tables,
            kv0.take().unwrap(),
            kv1.take().unwrap(),
            None,
        )?;
        if i >= opts.warmup {
            step.push_duration(t0.elapsed());
        }
        kv0 = Some(a);
        kv1 = Some(b2);
    }
    Ok(DecodeBench { tok_per_s: b as f64 / step.mean(), step })
}

/// Megatron-style paged TP decode (Fig 12): per-shard pool slices, the
/// activation and partials stay device buffers, and routing skips whole
/// shard dispatches (`attn_tag` "dense"|"sha_dXXXX", `mlp_tag`
/// "dense"|"kNN").
pub fn decode_throughput_tp(
    engine: &Engine,
    n_shards: usize,
    attn_tag: &str,
    mlp_tag: &str,
    b: usize,
    n: usize,
    opts: BenchOpts,
) -> Result<DecodeBench> {
    let (tokens, lengths, tables, pool) = synthetic_paged_inputs(engine, b, n, 44)?;
    let (pool_blocks, block) = (engine.exec.manifest().kv_pool_blocks, engine.exec.manifest().kv_block);
    let mut pools = split_pool_groups(&pool, n_shards)?
        .iter()
        .map(|t| PagedKv::from_tensor(t, pool_blocks, block))
        .collect::<Result<Vec<_>>>()?;
    let mut step = Samples::new();
    for i in 0..opts.warmup + opts.iters {
        let t0 = std::time::Instant::now();
        let out = engine.decode_tp_paged(
            n_shards, attn_tag, mlp_tag, &tokens, &lengths, &tables, pools, None,
        )?;
        if i >= opts.warmup {
            step.push_duration(t0.elapsed());
        }
        pools = out.pools;
    }
    Ok(DecodeBench { tok_per_s: b as f64 / step.mean(), step })
}

/// Time one micro entry (module-level benches, Figs 1a/3/10).
pub fn micro_latency(
    engine: &Engine,
    name: &str,
    data: &[Tensor],
    opts: BenchOpts,
) -> Result<Samples> {
    let lits: Vec<xla::Literal> = data
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<_>>()?;
    let entry = engine.exec.compiled(name)?;
    super::harness::time_it(opts, || {
        engine.exec.run_literals(&entry, &lits)?;
        Ok(())
    })
}
