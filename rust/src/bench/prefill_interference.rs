//! `bench prefill-interference [--smoke]` — what long-prompt arrival does
//! to running decoders: p99 inter-token latency of active requests while
//! prompts of increasing length {64, 256, 1024} are admitted, plus TTFT
//! per prompt length, for the **monolithic** schedule (whole prompt in
//! one step, the pre-chunking behaviour, `prefill_chunk_tokens = MAX`)
//! vs the **chunked** schedule (default budget = one chunk bucket).
//! Emits `BENCH_prefill.json` so every PR's CI run records the
//! interference trajectory.
//!
//! `--smoke` runs against the deterministic mock engine (no AOT
//! artifacts) with an artificial per-chunk delay: a monolithic admission
//! of a 1024-token prompt pays all 64 chunk delays inside one step —
//! every decoder stalls for the whole prompt — while the chunked
//! schedule pays one per step. The mock also fingerprints every cache
//! position it writes, so the 1024-token prompt is *verified*
//! un-truncated (its first generated token continues the true last
//! prompt token).

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::mock::MockEngine;
use crate::coordinator::{
    GenerationEvent, Mode, Request, SamplingParams, Scheduler, SchedulerConfig,
    SparsityController, StepEngine,
};
use crate::runtime::Engine;
use crate::substrate::argparse::Args;
use crate::substrate::json::Json;
use crate::substrate::stats::Samples;

use super::harness::write_bench_json;

const DECODERS: u64 = 2;
const LONG_ID_BASE: u64 = 100;

pub struct ScenarioOut {
    /// Decoder inter-token gaps sampled while a long prompt was being
    /// admitted (the interference window).
    pub itl: Samples,
    /// (prompt_len, ttft_s, untruncated) per long prompt.
    pub longs: Vec<(usize, f64, bool)>,
    pub prefill_chunks: u64,
    pub steps: u64,
    pub interleaved_steps: u64,
}

/// Drive one schedule: warm `DECODERS` decoders, then admit one long
/// prompt per length in `prompt_lens` (each enqueued once the previous
/// finished prefilling), sampling decoder inter-token gaps while any
/// long prompt is in admission. `budget` is the per-step prefill token
/// budget (`usize::MAX` = the monolithic baseline).
pub fn run_scenario<E: StepEngine>(
    engine: E,
    budget: usize,
    prompt_lens: &[usize],
    decoder_tokens: usize,
) -> Result<ScenarioOut> {
    let mut s = Scheduler::new(
        engine,
        SparsityController::new(Mode::Dense),
        SchedulerConfig {
            max_batch: 8,
            prefill_chunk_tokens: budget,
            ..Default::default()
        },
    );
    // decoders: disable the stop token so the +1 chain never terminates
    // early; only max_new bounds them
    for id in 1..=DECODERS {
        s.enqueue(
            Request::builder(vec![5, 5])
                .id(id)
                .params(SamplingParams {
                    max_new_tokens: decoder_tokens,
                    stop_token: -1,
                    ..Default::default()
                })
                .build(),
        );
    }
    let mut itl = Samples::default();
    let mut last_tok: HashMap<u64, Instant> = HashMap::new();
    let mut completions: HashMap<u64, (usize, f64, bool)> = HashMap::new();
    let mut prompt_last: HashMap<u64, i32> = HashMap::new();
    let mut guard = 0usize;
    // warm-up: decoders admitted and emitting before any long prompt
    for _ in 0..3 {
        for ev in s.step()? {
            if let GenerationEvent::Token { request, .. } = ev {
                last_tok.insert(request, Instant::now());
            }
        }
    }
    let longs_in: Vec<(u64, Vec<i32>)> = prompt_lens
        .iter()
        .enumerate()
        .map(|(k, &plen)| {
            let prompt: Vec<i32> = (0..plen).map(|i| 20 + (i as i32 % 200)).collect();
            (LONG_ID_BASE + k as u64, prompt)
        })
        .collect();
    for (id, prompt) in &longs_in {
        prompt_last.insert(*id, *prompt.last().unwrap());
    }
    let mut drive = |s: &mut Scheduler<E>,
                     itl: &mut Samples,
                     in_window: bool,
                     until_prefilled: Option<u64>|
     -> Result<()> {
        loop {
            guard += 1;
            if guard > 200_000 {
                bail!("scenario did not converge");
            }
            let mut prefilled = until_prefilled.is_none();
            for ev in s.step()? {
                match ev {
                    GenerationEvent::Token { request, .. } if request <= DECODERS => {
                        let now = Instant::now();
                        if in_window {
                            if let Some(prev) = last_tok.get(&request) {
                                itl.push(now.duration_since(*prev).as_secs_f64());
                            }
                        }
                        last_tok.insert(request, now);
                    }
                    GenerationEvent::Prefilled { request }
                        if Some(request) == until_prefilled =>
                    {
                        prefilled = true;
                    }
                    GenerationEvent::Finished(c) if c.id >= LONG_ID_BASE => {
                        let untrunc = prompt_last
                            .get(&c.id)
                            .map(|&last| c.output_ids.first() == Some(&(last + 1)))
                            .unwrap_or(false);
                        completions.insert(c.id, (c.prompt_len, c.ttft_s, untrunc));
                    }
                    _ => {}
                }
            }
            if prefilled || s.is_idle() {
                return Ok(());
            }
        }
    };
    for (id, prompt) in longs_in {
        s.enqueue(Request::builder(prompt).id(id).max_new_tokens(2).build());
        drive(&mut s, &mut itl, true, Some(id))?;
    }
    // drain outside the interference window
    while !s.is_idle() {
        drive(&mut s, &mut itl, false, None)?;
    }
    let mut longs: Vec<(usize, f64, bool)> = Vec::new();
    for k in 0..prompt_lens.len() {
        let id = LONG_ID_BASE + k as u64;
        let c = completions
            .get(&id)
            .with_context(|| format!("long prompt {id} never completed"))?;
        longs.push(*c);
    }
    Ok(ScenarioOut {
        itl,
        longs,
        prefill_chunks: s.metrics.prefill_chunks,
        steps: s.metrics.sched_steps,
        interleaved_steps: s.metrics.interleaved_steps,
    })
}

fn mock_long(chunk_delay: Duration, step_delay: Duration) -> MockEngine {
    MockEngine::new()
        .with_seq_buckets(vec![16, 32, 64, 128, 256, 512, 1024, 1152])
        .with_chunk_delay(chunk_delay)
        .with_step_delay(step_delay)
}

fn scenario_json(r: &ScenarioOut) -> Json {
    let mut ttft = Json::obj(vec![]);
    for &(plen, t, _) in &r.longs {
        ttft.set(&plen.to_string(), (t * 1e3).into());
    }
    Json::obj(vec![
        ("itl_p50_ms", (r.itl.p50() * 1e3).into()),
        ("itl_p99_ms", (r.itl.p99() * 1e3).into()),
        ("itl_samples", r.itl.len().into()),
        ("ttft_ms_by_prompt_len", ttft),
        ("prefill_chunks", (r.prefill_chunks as usize).into()),
        ("steps", (r.steps as usize).into()),
        ("interleaved_steps", (r.interleaved_steps as usize).into()),
    ])
}

pub fn run(rest: &[String]) -> Result<()> {
    let args = Args::new(
        "bench prefill-interference",
        "decoder p99 ITL under long-prompt arrival: monolithic vs chunked prefill",
    )
    .flag("model", "opt-tiny", "model name under the artifacts dir")
    .flag("artifacts", "artifacts", "artifacts root directory")
    .flag("decoder-tokens", "120", "tokens each background decoder generates")
    .flag("out", "BENCH_prefill.json", "output JSON path")
    .switch("smoke", "run on the deterministic mock engine (no artifacts)");
    let p = match args.parse(rest) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let decoder_tokens = p.get_usize("decoder-tokens").map_err(anyhow::Error::msg)?;

    let (engine_label, chunk_len, lens, mono, chunked) = if p.get_bool("smoke") {
        let lens = vec![64usize, 256, 1024];
        let mk = || mock_long(Duration::from_millis(2), Duration::from_millis(1));
        (
            "mock".to_string(),
            16usize,
            lens.clone(),
            run_scenario(mk(), usize::MAX, &lens, decoder_tokens)?,
            run_scenario(mk(), 0, &lens, decoder_tokens)?,
        )
    } else {
        let dir = std::path::PathBuf::from(p.get("artifacts")).join(p.get("model"));
        let exec = std::sync::Arc::new(
            crate::runtime::Executor::load(&dir).with_context(|| {
                format!("loading {} — run `make artifacts` first", dir.display())
            })?,
        );
        let max_n = *exec.manifest().seq_buckets.last().unwrap();
        let chunk_len = exec.manifest().prefill_chunk;
        // only prompt lengths the artifact's buckets admit (a prompt
        // exactly filling the largest bucket is still admissible)
        let lens: Vec<usize> =
            [64usize, 256, 1024].into_iter().filter(|&l| l <= max_n).collect();
        (
            p.get("model").to_string(),
            chunk_len,
            lens.clone(),
            run_scenario(
                Engine::new(exec.clone()),
                usize::MAX,
                &lens,
                decoder_tokens,
            )?,
            run_scenario(Engine::new(exec), 0, &lens, decoder_tokens)?,
        )
    };

    let untruncated = chunked.longs.iter().all(|&(_, _, u)| u);
    let improvement = if chunked.itl.p99() > 0.0 {
        ((mono.itl.p99() / chunked.itl.p99()) * 1e4).round() / 1e4
    } else {
        f64::INFINITY
    };
    let report = Json::obj(vec![
        ("bench", "prefill-interference".into()),
        ("engine", engine_label.clone().into()),
        ("chunk_tokens", chunk_len.into()),
        (
            "prompt_lens",
            Json::arr(lens.iter().map(|&l| l.into())),
        ),
        (
            "modes",
            Json::obj(vec![
                ("monolithic", scenario_json(&mono)),
                ("chunked", scenario_json(&chunked)),
            ]),
        ),
        ("itl_p99_improvement", improvement.into()),
        ("untruncated", untruncated.into()),
    ]);

    println!("prefill-interference ({engine_label}, prompts {lens:?})");
    println!(
        "  decoder ITL p99 during admission: {:.2} ms (monolithic) -> {:.2} ms (chunked) = {improvement}x better",
        mono.itl.p99() * 1e3,
        chunked.itl.p99() * 1e3
    );
    for (&(plen, mt, _), &(_, ct, _)) in mono.longs.iter().zip(chunked.longs.iter()) {
        println!(
            "  ttft prompt {plen:>5}: {:.2} ms (monolithic) vs {:.2} ms (chunked)",
            mt * 1e3,
            ct * 1e3
        );
    }
    println!("  longest prompt un-truncated: {untruncated}");
    write_bench_json(p.get("out"), &report)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate: with chunking enabled, the p99 inter-token
    /// latency of running decoders while long prompts arrive must beat
    /// the monolithic baseline, and the longest prompt must stream
    /// through un-truncated. Scaled-down scenario so the margin (one
    /// 2 ms chunk per step vs 16 chunks in one step) stays decisive on
    /// any CI machine.
    #[test]
    fn chunked_beats_monolithic_p99_itl() {
        let lens = [64usize, 256];
        let mk = || {
            MockEngine::new()
                .with_seq_buckets(vec![16, 32, 64, 128, 256, 512])
                .with_chunk_delay(Duration::from_millis(2))
                .with_step_delay(Duration::from_millis(1))
        };
        let mono = run_scenario(mk(), usize::MAX, &lens, 40).unwrap();
        let chunked = run_scenario(mk(), 0, &lens, 40).unwrap();
        // every long prompt completed with its true first token in both
        // schedules (the mock would emit a different token on truncation)
        assert!(mono.longs.iter().all(|&(_, _, u)| u), "{:?}", mono.longs);
        assert!(chunked.longs.iter().all(|&(_, _, u)| u), "{:?}", chunked.longs);
        // monolithic: the 256-prompt admission stalls decoders for
        // 16 chunks x 2 ms inside one step; chunked: one chunk per step
        assert!(
            chunked.itl.p99() < mono.itl.p99(),
            "chunked p99 {:.3}ms !< monolithic p99 {:.3}ms",
            chunked.itl.p99() * 1e3,
            mono.itl.p99() * 1e3
        );
        // both schedules move the same chunk volume ((64+256)/16 calls);
        // only the chunked one spreads it across interleaved steps
        assert_eq!(mono.prefill_chunks, 20 + 1); // +1: the decoders' own prompt
        assert_eq!(chunked.prefill_chunks, 20 + 1);
        assert!(chunked.interleaved_steps > mono.interleaved_steps);
    }
}
