//! `bench kv-paging [--smoke]` — what the paged KV cache buys over the
//! retired contiguous per-bucket caches, measured on a shared-prefix
//! serving trace and emitted as `BENCH_kv.json`:
//!
//! * **Prefill tokens saved.** Three requests share a long prompt prefix
//!   (request 3's prompt is byte-identical to request 1's — the
//!   system-prompt / retry pattern). With the hash-keyed prefix cache
//!   the prefix's chunks are computed ONCE; the A/B run disables the
//!   cache (`SchedulerConfig::prefix_cache = false`) and pays full
//!   prefill per request.
//! * **Rebuild bytes.** Admitting the two followers mid-decode grows the
//!   batch bucket 1 -> 4. The paged pool moves zero cache bytes for
//!   that; the analytic `contiguous_equivalent` figure is what the
//!   pre-paging scheduler's re-bucket would have copied (materialize the
//!   old group + rebuild at the new bucket). The only bytes the paged
//!   path copies are one copy-on-write block (the identical-prompt
//!   follower's capped last-token recompute).
//!
//! `--smoke` runs the deterministic mock engine (no AOT artifacts):
//! every count is an exact function of the trace; only wall-clock
//! timings are machine-dependent (zeroed in the committed artifact).

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::mock::MockEngine;
use crate::coordinator::{
    GenerationEvent, Mode, Request, Scheduler, SchedulerConfig, SparsityController,
    StepEngine,
};
use crate::runtime::{Engine, Executor, ModelConfig};
use crate::substrate::argparse::Args;
use crate::substrate::json::Json;

use super::harness::write_bench_json;

/// One run of the shared-prefix trace.
pub struct TraceOut {
    pub prefill_tokens: u64,
    pub prefill_chunks: u64,
    pub prefix_queries: usize,
    pub prefix_hits: usize,
    pub prefix_tokens_reused: usize,
    /// Prompt tokens whose prefill was skipped (post-cap accounting).
    pub tokens_saved: u64,
    pub cow_copies: usize,
    pub evictions: usize,
    pub block_allocs: usize,
    pub blocks_in_use_end: usize,
    pub blocks_cached_end: usize,
    /// Per-request `cached_prompt_tokens`, by request id (1, 2, 3).
    pub cached_per_request: Vec<usize>,
    pub wall_s: f64,
}

/// Drive the canonical trace: request 1 (prefix + suffix A) prefills in
/// full and keeps decoding; once it is prefilled, request 2 (same
/// prefix, suffix B) and request 3 (prompt identical to request 1's)
/// arrive and run to completion.
pub fn run_trace<E: StepEngine>(
    engine: E,
    prefix_cache: bool,
    prefix_tokens: usize,
    suffix_tokens: usize,
) -> Result<TraceOut> {
    let mut s = Scheduler::new(
        engine,
        SparsityController::new(Mode::Dense),
        SchedulerConfig { max_batch: 8, prefix_cache, ..Default::default() },
    );
    // low token values keep the mock's +1 chains inside byte range
    let prefix: Vec<i32> = (0..prefix_tokens).map(|i| 20 + (i as i32 % 40)).collect();
    let mut prompt_a = prefix.clone();
    prompt_a.extend((0..suffix_tokens as i32).map(|k| 60 + k % 40));
    let mut prompt_b = prefix.clone();
    prompt_b.extend((0..suffix_tokens as i32).map(|k| 130 + k % 40));

    let t0 = Instant::now();
    s.enqueue(Request::builder(prompt_a.clone()).id(1).max_new_tokens(24).build());
    let mut guard = 0;
    'prefill: loop {
        for ev in s.step()? {
            if matches!(ev, GenerationEvent::Prefilled { request: 1 }) {
                break 'prefill;
            }
        }
        guard += 1;
        if guard > 10_000 {
            bail!("request 1 never finished prefilling");
        }
    }
    s.enqueue(Request::builder(prompt_b).id(2).max_new_tokens(8).build());
    s.enqueue(Request::builder(prompt_a).id(3).max_new_tokens(8).build());
    let mut done = s.run_to_completion()?;
    let wall_s = t0.elapsed().as_secs_f64();
    if done.len() != 3 {
        bail!("trace produced {} completions, expected 3", done.len());
    }
    done.sort_by_key(|c| c.id);

    let kv = s.kv_stats();
    let g = |k: &str| kv.get(k).as_usize().unwrap_or(0);
    Ok(TraceOut {
        prefill_tokens: s.metrics.prefill_tokens,
        prefill_chunks: s.metrics.prefill_chunks,
        prefix_queries: g("prefix_queries"),
        prefix_hits: g("prefix_hits"),
        prefix_tokens_reused: g("prefix_tokens_reused"),
        tokens_saved: s.metrics.prefix_tokens_skipped,
        cow_copies: g("cow_copies"),
        evictions: g("evictions"),
        block_allocs: g("block_allocs"),
        blocks_in_use_end: g("blocks_in_use"),
        blocks_cached_end: g("blocks_cached"),
        cached_per_request: done.iter().map(|c| c.cached_prompt_tokens).collect(),
        wall_s,
    })
}

/// Analytic contiguous-era rebuild cost for this trace's one batch
/// re-bucket (1 -> 4 at `seq_bucket`): materialize the old group + copy
/// into the new one. The paged path's figure for the same event is 0.
pub fn contiguous_rebuild_bytes(cfg: &ModelConfig, seq_bucket: usize) -> u64 {
    ((cfg.kv_elems(1, seq_bucket) + cfg.kv_elems(4, seq_bucket)) * 4) as u64
}

fn trace_json(t: &TraceOut) -> Json {
    Json::obj(vec![
        ("prefill_tokens", (t.prefill_tokens as usize).into()),
        ("prefill_chunks", (t.prefill_chunks as usize).into()),
        ("prefix_queries", t.prefix_queries.into()),
        ("prefix_hits", t.prefix_hits.into()),
        ("prefix_tokens_reused", t.prefix_tokens_reused.into()),
        ("prefill_tokens_saved", (t.tokens_saved as usize).into()),
        ("cow_copies", t.cow_copies.into()),
        ("evictions", t.evictions.into()),
        ("block_allocs", t.block_allocs.into()),
        ("blocks_in_use_end", t.blocks_in_use_end.into()),
        ("blocks_cached_end", t.blocks_cached_end.into()),
        (
            "cached_prompt_tokens_per_request",
            Json::arr(t.cached_per_request.iter().map(|&x| x.into())),
        ),
        ("wall_ms", (t.wall_s * 1e3).into()),
    ])
}

fn smoke_engine() -> MockEngine {
    MockEngine::new().with_seq_buckets(vec![16, 32, 64, 128, 256, 512])
}

pub fn run(rest: &[String]) -> Result<()> {
    let args = Args::new(
        "bench kv-paging",
        "paged KV: prefill tokens saved by prefix caching + rebuild bytes vs contiguous",
    )
    .flag("model", "opt-tiny", "model name under the artifacts dir")
    .flag("artifacts", "artifacts", "artifacts root directory")
    .flag("prefix-tokens", "256", "shared prompt prefix length (block-aligned)")
    .flag("suffix-tokens", "16", "per-request distinct suffix length")
    .flag("out", "BENCH_kv.json", "output JSON path")
    .switch("smoke", "run on the deterministic mock engine (no artifacts)");
    let p = match args.parse(rest) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let suffix = p.get_usize("suffix-tokens").map_err(anyhow::Error::msg)?;
    let mut prefix = p.get_usize("prefix-tokens").map_err(anyhow::Error::msg)?;

    let (engine_label, block, pool_blocks, seq_bucket, cfg, shared, baseline) = if p
        .get_bool("smoke")
    {
        let eng = smoke_engine();
        let (block, pool_blocks) = eng.kv_layout();
        prefix -= prefix % block;
        let cfg = eng.config().clone();
        let need = prefix + suffix + 1;
        let seq_bucket = *eng
            .seq_buckets()
            .iter()
            .find(|&&n| n >= need)
            .context("no mock seq bucket fits the trace")?;
        let shared = run_trace(smoke_engine(), true, prefix, suffix)?;
        let baseline = run_trace(smoke_engine(), false, prefix, suffix)?;
        ("mock".to_string(), block, pool_blocks, seq_bucket, cfg, shared, baseline)
    } else {
        let dir = std::path::PathBuf::from(p.get("artifacts")).join(p.get("model"));
        let exec = std::sync::Arc::new(Executor::load(&dir).with_context(|| {
            format!("loading {} — run `make artifacts` first", dir.display())
        })?);
        let engine = Engine::new(exec);
        let (block, pool_blocks) = engine.kv_layout();
        // the whole prompt (+1 for the first token) must fit the ladder
        let max_n = *engine.seq_buckets().last().unwrap();
        prefix = prefix.min(max_n.saturating_sub(suffix + 1));
        prefix -= prefix % block;
        let cfg = engine.config().clone();
        let need = prefix + suffix + 1;
        let seq_bucket = *engine
            .seq_buckets()
            .iter()
            .find(|&&n| n >= need)
            .context("no seq bucket fits the trace")?;
        let shared = run_trace(engine.clone(), true, prefix, suffix)?;
        let baseline = run_trace(engine, false, prefix, suffix)?;
        (p.get("model").to_string(), block, pool_blocks, seq_bucket, cfg, shared, baseline)
    };

    let saved = baseline.prefill_tokens.saturating_sub(shared.prefill_tokens);
    let reduction = if shared.prefill_tokens > 0 {
        ((baseline.prefill_tokens as f64 / shared.prefill_tokens as f64) * 1e4).round() / 1e4
    } else {
        f64::INFINITY
    };
    let cow_block_bytes = (shared.cow_copies * cfg.kv_block_elems(block) * 4) as u64;
    let report = Json::obj(vec![
        ("bench", "kv-paging".into()),
        ("engine", engine_label.clone().into()),
        ("block_size", block.into()),
        ("pool_blocks", pool_blocks.into()),
        (
            "workload",
            Json::obj(vec![
                ("requests", 3usize.into()),
                ("prefix_tokens", prefix.into()),
                ("suffix_tokens", suffix.into()),
                ("identical_twin", true.into()),
            ]),
        ),
        (
            "paths",
            Json::obj(vec![
                ("prefix_cache", trace_json(&shared)),
                ("no_sharing", trace_json(&baseline)),
            ]),
        ),
        ("prefill_tokens_saved", (saved as usize).into()),
        ("prefill_reduction", reduction.into()),
        (
            "rebuild_bytes",
            Json::obj(vec![
                // the batch bucket grew 1 -> 4 when the followers arrived:
                // zero cache bytes moved, vs one full materialize+rebuild
                // on the contiguous path (analytic)
                ("paged", 0usize.into()),
                ("paged_cow_block_bytes", (cow_block_bytes as usize).into()),
                (
                    "contiguous_equivalent_analytic",
                    (contiguous_rebuild_bytes(&cfg, seq_bucket) as usize).into(),
                ),
            ]),
        ),
    ]);

    println!("kv-paging ({engine_label}, prefix {prefix} + suffix {suffix}, 3 requests)");
    println!(
        "  prefill tokens: {} (no sharing) -> {} (prefix cache) = {reduction}x fewer",
        baseline.prefill_tokens, shared.prefill_tokens
    );
    println!(
        "  prefix hits {} / queries {}; cow copies {}; blocks in use at end {}",
        shared.prefix_hits, shared.prefix_queries, shared.cow_copies, shared.blocks_in_use_end
    );
    println!(
        "  re-bucket bytes: paged 0 (+{cow_block_bytes} cow) vs contiguous {} (analytic)",
        contiguous_rebuild_bytes(&cfg, seq_bucket)
    );
    write_bench_json(p.get("out"), &report)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance gate: with a 256-token shared prefix, the prefix's
    /// prefill chunks run once — prefill tokens drop from 816 (3 x 272)
    /// to 289 (272 + suffix 16 + capped recompute 1), prefix_hits are
    /// nonzero, the identical-prompt follower COWs exactly one block,
    /// and every block reclaims.
    #[test]
    fn smoke_prefix_sharing_reduces_prefill_tokens() {
        let shared = run_trace(smoke_engine(), true, 256, 16).unwrap();
        let baseline = run_trace(smoke_engine(), false, 256, 16).unwrap();
        assert_eq!(baseline.prefill_tokens, 816);
        assert_eq!(shared.prefill_tokens, 289);
        assert_eq!(baseline.prefill_chunks, 51);
        assert_eq!(shared.prefill_chunks, 19);
        // request 2 reused the 256-token prefix; request 3 everything but
        // the recomputed final token
        assert_eq!(shared.cached_per_request, vec![0, 256, 271]);
        assert_eq!(shared.prefix_hits, 16 + 17);
        assert_eq!(shared.tokens_saved, 256 + 271);
        assert_eq!(shared.cow_copies, 1);
        assert_eq!(baseline.prefix_hits, 0);
        assert_eq!(baseline.cow_copies, 0);
        // pool fully reclaimed in both runs; the shared run retains
        // published blocks in the prefix cache, the baseline publishes
        // nothing
        assert_eq!(shared.blocks_in_use_end, 0);
        assert_eq!(baseline.blocks_in_use_end, 0);
        assert!(shared.blocks_cached_end > 0);
        assert_eq!(baseline.blocks_cached_end, 0);
        assert_eq!(shared.evictions, 0);
    }

    #[test]
    fn contiguous_baseline_formula_scales_with_bucket() {
        let cfg = smoke_engine().config().clone();
        let small = contiguous_rebuild_bytes(&cfg, 64);
        let big = contiguous_rebuild_bytes(&cfg, 512);
        assert_eq!(big, small * 8);
        // 1 + 4 slots' worth of [L,2,G,n,dh] f32 rows
        assert_eq!(small, (cfg.kv_elems(1, 64) + cfg.kv_elems(4, 64)) as u64 * 4);
    }
}
