//! One module per paper figure/table (DESIGN.md experiment index).
//! `polar-sparsity bench <id>` regenerates the rows into results/<id>.csv.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::Mode;
use crate::runtime::{Engine, Executor, Manifest, Tensor};
use crate::substrate::argparse::Args;
use crate::substrate::rng::Rng;

use super::accuracy::{self};
use super::harness::{fmt_ms, fmt_x, BenchOpts, Report};
use super::throughput::{
    decode_throughput, decode_throughput_pp2, decode_throughput_tp, micro_latency,
    steady_len,
};

pub struct Ctx {
    pub artifacts: PathBuf,
    pub results: PathBuf,
    pub opts: BenchOpts,
    pub per_family: usize,
    engines: std::cell::RefCell<std::collections::HashMap<String, Engine>>,
}

impl Ctx {
    pub fn engine(&self, model: &str) -> Result<Engine> {
        if let Some(e) = self.engines.borrow().get(model) {
            return Ok(e.clone());
        }
        let exec = Arc::new(Executor::load(&self.artifacts.join(model))?);
        let e = Engine::new(exec);
        self.engines
            .borrow_mut()
            .insert(model.to_string(), e.clone());
        Ok(e)
    }
}

pub fn run(rest: &[String]) -> Result<()> {
    let args = Args::new("bench", "regenerate paper figures/tables")
        .flag("artifacts", "artifacts", "artifacts root")
        .flag("results", "results", "output directory for CSVs")
        .flag("iters", "8", "timed iterations per point")
        .flag("warmup", "2", "warmup iterations per point")
        .flag("per-family", "12", "eval items per task family (accuracy)")
        .positional("figure", "fig1a|fig3a|fig3b|fig4|fig5|fig6|fig10|fig11|fig12|fig13|fig14|table1|table2|all");
    let p = match args.parse(rest) {
        Ok(p) => p,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let ctx = Ctx {
        artifacts: PathBuf::from(p.get("artifacts")),
        results: PathBuf::from(p.get("results")),
        opts: BenchOpts {
            warmup: p.get_usize("warmup").map_err(anyhow::Error::msg)?,
            iters: p.get_usize("iters").map_err(anyhow::Error::msg)?,
        },
        per_family: p.get_usize("per-family").map_err(anyhow::Error::msg)?,
        engines: Default::default(),
    };
    let which = p.positional(0).unwrap_or("all").to_string();
    let all: &[(&str, fn(&Ctx) -> Result<()>)] = &[
        ("fig1a", fig1a),
        ("fig3a", fig3a),
        ("fig3b", fig3b),
        ("fig4", fig4),
        ("fig5", fig5),
        ("fig6", fig6),
        ("fig10", fig10),
        ("fig11", fig11),
        ("fig12", fig12),
        ("fig13", fig13),
        ("fig14", fig14),
        ("table1", table1),
        ("table2", table2),
    ];
    if which == "all" {
        for (name, f) in all {
            println!("\n===== {name} =====");
            f(&ctx).with_context(|| format!("bench {name}"))?;
        }
        return Ok(());
    }
    for (name, f) in all {
        if *name == which {
            return f(&ctx);
        }
    }
    bail!("unknown figure {which:?}");
}

fn rand_f32(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| (rng.f64() as f32 - 0.5) * scale).collect()
}

// ---------------------------------------------------------------------------
// Fig 1a — decode latency breakdown vs batch size (opt-small, N=256)
// ---------------------------------------------------------------------------
fn fig1a(ctx: &Ctx) -> Result<()> {
    let e = ctx.engine("opt-small")?;
    let c = e.exec.config().clone();
    let n = 256;
    let mut rng = Rng::new(7);
    let mut rep = Report::new(
        "Fig 1a — decode latency breakdown (opt-small, N=256)",
        &["batch", "qkv_ms", "attn_ms", "out_proj_ms", "mlp_ms", "other_ms", "total_ms", "attn_share"],
    );
    for &b in &[1usize, 4, 16] {
        let x = Tensor::f32(rand_f32(&mut rng, b * c.d_model, 0.2), vec![b, c.d_model])?;
        let q = Tensor::f32(
            rand_f32(&mut rng, b * c.n_heads * c.d_head, 0.2),
            vec![b, c.n_heads, c.d_head],
        )?;
        let kv1 = Tensor::f32(
            rand_f32(&mut rng, b * c.n_kv_heads * n * c.d_head, 0.2),
            vec![b, c.n_kv_heads, n, c.d_head],
        )?;
        let o = Tensor::f32(
            rand_f32(&mut rng, b * c.n_heads * c.d_head, 0.2),
            vec![b, c.n_heads * c.d_head],
        )?;
        let lens = Tensor::i32(vec![steady_len(n) as i32; b], vec![b])?;

        let l = c.n_layers as f64; // micro entries measure ONE layer
        let qkv = micro_latency(&e, &format!("micro_qkv_b{b}"), &[x.clone()], ctx.opts)?.mean() * l;
        let attn = micro_latency(
            &e,
            &format!("micro_attn_dense_b{b}_n{n}"),
            &[q, kv1.clone(), kv1, lens],
            ctx.opts,
        )?
        .mean() * l;
        let outp =
            micro_latency(&e, &format!("micro_out_proj_b{b}"), &[o], ctx.opts)?.mean() * l;
        let mlp =
            micro_latency(&e, &format!("micro_mlp_dense_b{b}"), &[x], ctx.opts)?.mean() * l;
        let total = decode_throughput(&e, "dense", b, n, ctx.opts)?.step.mean();
        let other = (total - qkv - attn - outp - mlp).max(0.0);
        rep.row(vec![
            b.to_string(),
            fmt_ms(qkv),
            fmt_ms(attn),
            fmt_ms(outp),
            fmt_ms(mlp),
            fmt_ms(other),
            fmt_ms(total),
            format!("{:.2}", attn / total),
        ]);
    }
    rep.emit(&ctx.results, "fig1a")
}

// ---------------------------------------------------------------------------
// Fig 3a — Selective GEMM kernel speedup vs sparsity (opt-small, B=16)
// ---------------------------------------------------------------------------
fn fig3a(ctx: &Ctx) -> Result<()> {
    let e = ctx.engine("opt-small")?;
    let c = e.exec.config().clone();
    let b = 16;
    let dff = c.d_ff;
    let mut rng = Rng::new(11);
    let x = Tensor::f32(rand_f32(&mut rng, b * c.d_model, 0.2), vec![b, c.d_model])?;
    let dense_ms = micro_latency(&e, &format!("micro_mlp_dense_b{b}"), &[std::clone::Clone::clone(&x)], ctx.opts)?
        .mean();
    let mut rep = Report::new(
        "Fig 3a — Selective GEMM speedup vs sparsity (opt-small, B=16)",
        &["top_k", "density", "xla_ms", "pallas_ms", "xla_speedup_vs_dense", "pallas_speedup_vs_pallas_dense"],
    );
    let ks: Vec<usize> = vec![dff / 8, dff / 4, dff / 2, 3 * dff / 4, dff];
    // pallas dense baseline = pallas kernel at k = Dff (same machinery)
    let full_idx = Tensor::i32((0..dff as i32).collect(), vec![dff])?;
    let pallas_dense = micro_latency(
        &e,
        &format!("micro_mlp_sparse_pallas_k{dff}_b{b}"),
        &[x.clone(), full_idx],
        ctx.opts,
    )?
    .mean();
    for k in ks {
        let mut pool: Vec<i32> = (0..dff as i32).collect();
        rng.shuffle(&mut pool);
        let idx = Tensor::i32(pool[..k].to_vec(), vec![k])?;
        let xla = micro_latency(
            &e,
            &format!("micro_mlp_sparse_xla_k{k}_b{b}"),
            &[x.clone(), idx.clone()],
            ctx.opts,
        )?
        .mean();
        let pallas = micro_latency(
            &e,
            &format!("micro_mlp_sparse_pallas_k{k}_b{b}"),
            &[x.clone(), idx],
            ctx.opts,
        )?
        .mean();
        rep.row(vec![
            k.to_string(),
            format!("{:.3}", k as f64 / dff as f64),
            fmt_ms(xla),
            fmt_ms(pallas),
            fmt_x(dense_ms / xla),
            fmt_x(pallas_dense / pallas),
        ]);
    }
    rep.emit(&ctx.results, "fig3a")
}

// ---------------------------------------------------------------------------
// Fig 3b — Selective Head Attention kernel speedup (opt-small, B=16, N=256)
// ---------------------------------------------------------------------------
fn fig3b(ctx: &Ctx) -> Result<()> {
    let e = ctx.engine("opt-small")?;
    let c = e.exec.config().clone();
    let (b, n, g) = (16usize, 256usize, c.n_groups());
    let mut rng = Rng::new(13);
    let q = Tensor::f32(
        rand_f32(&mut rng, b * c.n_heads * c.d_head, 0.2),
        vec![b, c.n_heads, c.d_head],
    )?;
    let k_ = Tensor::f32(
        rand_f32(&mut rng, b * g * n * c.d_head, 0.2),
        vec![b, g, n, c.d_head],
    )?;
    let v = Tensor::f32(
        rand_f32(&mut rng, b * g * n * c.d_head, 0.2),
        vec![b, g, n, c.d_head],
    )?;
    let lens = Tensor::i32(vec![steady_len(n) as i32; b], vec![b])?;
    let dense_ms = micro_latency(
        &e,
        &format!("micro_attn_dense_b{b}_n{n}"),
        &[q.clone(), k_.clone(), v.clone(), lens.clone()],
        ctx.opts,
    )?
    .mean();
    let mut rep = Report::new(
        "Fig 3b — Selective Head Attention speedup (opt-small, B=16, N=256)",
        &["top_k", "density", "sha_xla_ms", "sha_pallas_ms", "xla_speedup_vs_dense", "pallas_speedup_vs_pallas_dense"],
    );
    let mut head_index_for = |kk: usize| -> Result<Tensor> {
        let mut rows = Vec::with_capacity(b * kk);
        for _ in 0..b {
            let mut pool: Vec<i32> = (0..g as i32).collect();
            rng.shuffle(&mut pool);
            rows.extend_from_slice(&pool[..kk]);
        }
        Tensor::i32(rows, vec![b, kk])
    };
    let hi_full = head_index_for(g)?;
    let pallas_dense = micro_latency(
        &e,
        &format!("micro_attn_sha_pallas_k{g}_b{b}_n{n}"),
        &[q.clone(), k_.clone(), v.clone(), lens.clone(), hi_full],
        ctx.opts,
    )?
    .mean();
    for kk in [g / 4, g / 2, 3 * g / 4, g] {
        let kk = kk.max(1);
        let hi = head_index_for(kk)?;
        let xla = micro_latency(
            &e,
            &format!("micro_attn_sha_xla_k{kk}_b{b}_n{n}"),
            &[q.clone(), k_.clone(), v.clone(), lens.clone(), hi.clone()],
            ctx.opts,
        )?
        .mean();
        let pallas = micro_latency(
            &e,
            &format!("micro_attn_sha_pallas_k{kk}_b{b}_n{n}"),
            &[q.clone(), k_.clone(), v.clone(), lens.clone(), hi],
            ctx.opts,
        )?
        .mean();
        rep.row(vec![
            kk.to_string(),
            format!("{:.3}", kk as f64 / g as f64),
            fmt_ms(xla),
            fmt_ms(pallas),
            fmt_x(dense_ms / xla),
            fmt_x(pallas_dense / pallas),
        ]);
    }
    rep.emit(&ctx.results, "fig3b")
}

// ---------------------------------------------------------------------------
// Fig 4 — accuracy vs attention density (3 panels)
// ---------------------------------------------------------------------------
fn fig4(ctx: &Ctx) -> Result<()> {
    let suite = ctx.artifacts.join("eval_tasks.jsonl");
    let mut rep = Report::new(
        "Fig 4 — task accuracy vs attention density",
        &["model", "density", "avg_accuracy"],
    );
    for model in ["opt-small", "llama-tiny", "llama-gqa"] {
        let e = ctx.engine(model)?;
        let dense = accuracy::eval_suite(&e, Mode::Dense, &suite, ctx.per_family, 12)?;
        rep.row(vec![model.into(), "1.000(dense)".into(), format!("{:.3}", dense.average)]);
        for d in accuracy::available_densities(e.exec.manifest()) {
            let s = accuracy::eval_suite(&e, Mode::Polar { density: d }, &suite, ctx.per_family, 12)?;
            rep.row(vec![model.into(), format!("{d:.3}"), format!("{:.3}", s.average)]);
        }
    }
    rep.emit(&ctx.results, "fig4")
}

// ---------------------------------------------------------------------------
// Table 1 — zero-shot eval at critical thresholds (all models)
// ---------------------------------------------------------------------------
fn table1(ctx: &Ctx) -> Result<()> {
    let suite = ctx.artifacts.join("eval_tasks.jsonl");
    let mut cols: Vec<&str> = vec!["model", "config"];
    cols.extend(crate::workload::tasks::FAMILIES);
    cols.push("average");
    let mut rep = Report::new("Table 1 — zero-shot eval at critical thresholds", &cols);
    for model in ["opt-tiny", "opt-small", "llama-tiny", "llama-gqa"] {
        let e = ctx.engine(model)?;
        let crit = e.exec.config().critical_density;
        for (label, mode) in [
            ("dense".to_string(), Mode::Dense),
            (format!("PolarSparse-{crit}"), Mode::Polar { density: crit }),
        ] {
            let s = accuracy::eval_suite(&e, mode, &suite, ctx.per_family, 12)?;
            let mut row = vec![model.to_string(), label];
            for fam in crate::workload::tasks::FAMILIES {
                let acc = s
                    .per_family
                    .iter()
                    .find(|(f, _, _)| f == fam)
                    .map(|(_, a, _)| *a)
                    .unwrap_or(f64::NAN);
                row.push(format!("{acc:.2}"));
            }
            row.push(format!("{:.3}", s.average));
            rep.row(row);
        }
    }
    rep.emit(&ctx.results, "table1")
}

// ---------------------------------------------------------------------------
// Table 2 — sparsity methods on the LLaMA-2-7b analogue
// ---------------------------------------------------------------------------
fn table2(ctx: &Ctx) -> Result<()> {
    let suite = ctx.artifacts.join("eval_tasks.jsonl");
    let mut cols: Vec<&str> = vec!["method"];
    cols.extend(crate::workload::tasks::FAMILIES);
    cols.push("average");
    let mut rep = Report::new("Table 2 — sparsity methods, llama-tiny", &cols);
    let e = ctx.engine("llama-tiny")?;
    let add = |rep: &mut Report, label: &str, s: crate::workload::tasks::SuiteScore| {
        let mut row = vec![label.to_string()];
        for fam in crate::workload::tasks::FAMILIES {
            let acc = s
                .per_family
                .iter()
                .find(|(f, _, _)| f == fam)
                .map(|(_, a, _)| *a)
                .unwrap_or(f64::NAN);
            row.push(format!("{acc:.2}"));
        }
        row.push(format!("{:.3}", s.average));
        rep.row(row);
    };
    add(&mut rep, "Dense baseline",
        accuracy::eval_suite(&e, Mode::Dense, &suite, ctx.per_family, 12)?);
    add(&mut rep, "PolarSparse-50%",
        accuracy::eval_suite(&e, Mode::Polar { density: 0.5 }, &suite, ctx.per_family, 12)?);
    add(&mut rep, "TEAL-50% (magnitude)",
        accuracy::eval_suite_tag(&e, "teal_d0500", &suite, ctx.per_family, 12)?);
    add(&mut rep, "CATS-50% (gate threshold)",
        accuracy::eval_suite_tag(&e, "cats_d0500", &suite, ctx.per_family, 12)?);
    // ReLUfication baseline: separately-trained llama-relu model
    let er = ctx.engine("llama-relu")?;
    add(&mut rep, "ReLUfication (dense)",
        accuracy::eval_suite_tag(&er, "dense", &suite, ctx.per_family, 12)?);
    add(&mut rep, "ReLUfication + DejaVu MLP",
        accuracy::eval_suite_tag(&er, "dejavu", &suite, ctx.per_family, 12)?);
    rep.emit(&ctx.results, "table2")
}

// ---------------------------------------------------------------------------
// Figs 5/6 — decode throughput vs batch size
// ---------------------------------------------------------------------------
fn throughput_fig(
    ctx: &Ctx,
    name: &str,
    title: &str,
    models: &[(&str, &[&str])], // (model, mode tags)
) -> Result<()> {
    let mut rep = Report::new(title, &["model", "batch", "mode", "tok_per_s", "step_ms", "speedup_vs_dense"]);
    let n = 256;
    for (model, tags) in models {
        let e = ctx.engine(model)?;
        for &b in &[1usize, 2, 4, 8, 16] {
            let mut dense_tps = f64::NAN;
            for tag in tags.iter() {
                let r = decode_throughput(&e, tag, b, n, ctx.opts)?;
                if *tag == "dense" {
                    dense_tps = r.tok_per_s;
                }
                rep.row(vec![
                    model.to_string(),
                    b.to_string(),
                    tag.to_string(),
                    format!("{:.1}", r.tok_per_s),
                    fmt_ms(r.step.mean()),
                    fmt_x(r.tok_per_s / dense_tps),
                ]);
            }
        }
    }
    rep.emit(&ctx.results, name)
}

fn fig5(ctx: &Ctx) -> Result<()> {
    throughput_fig(
        ctx,
        "fig5",
        "Fig 5 — OPT decode throughput vs batch (N=256)",
        &[
            ("opt-tiny", &["dense", "dejavu", "polar_d0500"]),
            ("opt-small", &["dense", "dejavu", "polar_d0250"]),
        ],
    )
}

fn fig6(ctx: &Ctx) -> Result<()> {
    throughput_fig(
        ctx,
        "fig6",
        "Fig 6 — LLaMA decode throughput vs batch (N=256)",
        &[
            ("llama-tiny", &["dense", "polar_d0500"]),
            ("llama-gqa", &["dense", "polar_d0625"]),
        ],
    )
}

// ---------------------------------------------------------------------------
// Fig 10 — router ablation (opt-small, B=16)
// ---------------------------------------------------------------------------
fn fig10(ctx: &Ctx) -> Result<()> {
    let e = ctx.engine("opt-small")?;
    let c = e.exec.config().clone();
    let (b, n) = (16usize, 256usize);
    let mut rng = Rng::new(17);
    let x = Tensor::f32(rand_f32(&mut rng, b * c.d_model, 0.2), vec![b, c.d_model])?;
    let q = Tensor::f32(
        rand_f32(&mut rng, b * c.n_heads * c.d_head, 0.2),
        vec![b, c.n_heads, c.d_head],
    )?;
    let kv1 = Tensor::f32(
        rand_f32(&mut rng, b * c.n_kv_heads * n * c.d_head, 0.2),
        vec![b, c.n_kv_heads, n, c.d_head],
    )?;
    let lens = Tensor::i32(vec![steady_len(n) as i32; b], vec![b])?;

    let r_mlp = micro_latency(&e, &format!("micro_router_mlp_b{b}"), &[x.clone()], ctx.opts)?.mean();
    let r_attn = micro_latency(&e, &format!("micro_router_attn_b{b}"), &[x.clone()], ctx.opts)?.mean();
    let mlp_dense = micro_latency(&e, &format!("micro_mlp_dense_b{b}"), &[x.clone()], ctx.opts)?.mean();
    let attn_dense = micro_latency(
        &e,
        &format!("micro_attn_dense_b{b}_n{n}"),
        &[q.clone(), kv1.clone(), kv1.clone(), lens.clone()],
        ctx.opts,
    )?
    .mean();

    let mut rep = Report::new(
        "Fig 10 — router ablation (opt-small, B=16): block+router latency vs sparsity",
        &["density", "mlp_sparse_ms", "mlp_router_ms", "mlp_total_vs_dense", "attn_sha_ms", "attn_router_ms", "attn_total_vs_dense"],
    );
    let dff = c.d_ff;
    for (frac, k_mlp, k_attn) in [
        (0.25, dff / 4, c.n_groups() / 4),
        (0.5, dff / 2, c.n_groups() / 2),
        (0.75, 3 * dff / 4, 3 * c.n_groups() / 4),
    ] {
        let mut pool: Vec<i32> = (0..dff as i32).collect();
        rng.shuffle(&mut pool);
        let idx = Tensor::i32(pool[..k_mlp].to_vec(), vec![k_mlp])?;
        let mlp_sparse = micro_latency(
            &e,
            &format!("micro_mlp_sparse_xla_k{k_mlp}_b{b}"),
            &[x.clone(), idx],
            ctx.opts,
        )?
        .mean();
        let kk = k_attn.max(1);
        let mut rows = Vec::with_capacity(b * kk);
        for _ in 0..b {
            let mut hp: Vec<i32> = (0..c.n_groups() as i32).collect();
            rng.shuffle(&mut hp);
            rows.extend_from_slice(&hp[..kk]);
        }
        let hi = Tensor::i32(rows, vec![b, kk])?;
        let sha = micro_latency(
            &e,
            &format!("micro_attn_sha_xla_k{kk}_b{b}_n{n}"),
            &[q.clone(), kv1.clone(), kv1.clone(), lens.clone(), hi],
            ctx.opts,
        )?
        .mean();
        rep.row(vec![
            format!("{frac}"),
            fmt_ms(mlp_sparse),
            fmt_ms(r_mlp),
            fmt_x((mlp_sparse + r_mlp) / mlp_dense),
            fmt_ms(sha),
            fmt_ms(r_attn),
            fmt_x((sha + r_attn) / attn_dense),
        ]);
    }
    rep.emit(&ctx.results, "fig10")
}

// ---------------------------------------------------------------------------
// Fig 11 — pipeline-parallel decode throughput
// ---------------------------------------------------------------------------
fn fig11(ctx: &Ctx) -> Result<()> {
    let mut rep = Report::new(
        "Fig 11 — 2-stage pipeline-parallel decode throughput (N=256)",
        &["model", "batch", "mode", "tok_per_s", "step_ms", "speedup_vs_dense"],
    );
    for (model, polar_tag) in [("opt-small", "polar_d0250"), ("llama-tiny", "polar_d0500")] {
        let e = ctx.engine(model)?;
        for &b in &[1usize, 2, 4, 8, 16] {
            let mut dense_tps = f64::NAN;
            for tag in ["dense", polar_tag] {
                let r = decode_throughput_pp2(&e, tag, b, 256, ctx.opts)?;
                if tag == "dense" {
                    dense_tps = r.tok_per_s;
                }
                rep.row(vec![
                    model.to_string(),
                    b.to_string(),
                    tag.to_string(),
                    format!("{:.1}", r.tok_per_s),
                    fmt_ms(r.step.mean()),
                    fmt_x(r.tok_per_s / dense_tps),
                ]);
            }
        }
    }
    rep.emit(&ctx.results, "fig11")
}

// ---------------------------------------------------------------------------
// Fig 12 — tensor-parallel decode throughput (opt-small)
// ---------------------------------------------------------------------------
fn mlp_tag_for(m: &Manifest, n_shards: usize, b: usize) -> String {
    // discover the sparse MLP shard k baked at AOT time (depends on the
    // calibrated table) from entry meta; fall back to dense when absent
    match crate::runtime::mlp_shard_k(m, n_shards, b) {
        Some(k) => format!("k{k}"),
        None => "dense".to_string(),
    }
}

fn fig12(ctx: &Ctx) -> Result<()> {
    let e = ctx.engine("opt-small")?;
    let crit = e.exec.config().critical_density;
    let sha_tag = format!("sha_d{:04}", (crit * 1000.0).round() as usize);
    let mut rep = Report::new(
        "Fig 12 — Megatron-style TP decode throughput (opt-small, N=256)",
        &["tp", "batch", "mode", "tok_per_s", "step_ms", "speedup_vs_dense"],
    );
    for n_shards in [2usize, 4] {
        for &b in &[1usize, 4, 16] {
            let mlp_sparse_tag = mlp_tag_for(e.exec.manifest(), n_shards, b);
            let mut dense_tps = f64::NAN;
            for (label, attn, mlp) in [
                ("dense", "dense", "dense".to_string()),
                ("polar", sha_tag.as_str(), mlp_sparse_tag),
            ] {
                let r = decode_throughput_tp(&e, n_shards, attn, &mlp, b, 256, ctx.opts)?;
                if label == "dense" {
                    dense_tps = r.tok_per_s;
                }
                rep.row(vec![
                    n_shards.to_string(),
                    b.to_string(),
                    label.to_string(),
                    format!("{:.1}", r.tok_per_s),
                    fmt_ms(r.step.mean()),
                    fmt_x(r.tok_per_s / dense_tps),
                ]);
            }
        }
    }
    rep.emit(&ctx.results, "fig12")
}

// ---------------------------------------------------------------------------
// Figs 13/14 — inter-token latency vs sequence bucket at B=16
// ---------------------------------------------------------------------------
fn latency_fig(
    ctx: &Ctx,
    name: &str,
    title: &str,
    models: &[(&str, &[&str])],
) -> Result<()> {
    let mut rep = Report::new(title, &["model", "seq_bucket", "mode", "itl_ms", "speedup_vs_dense"]);
    let b = 16;
    for (model, tags) in models {
        let e = ctx.engine(model)?;
        for &n in &[64usize, 128, 256] {
            let mut dense_ms = f64::NAN;
            for tag in tags.iter() {
                let r = decode_throughput(&e, tag, b, n, ctx.opts)?;
                let ms = r.step.mean();
                if *tag == "dense" {
                    dense_ms = ms;
                }
                rep.row(vec![
                    model.to_string(),
                    n.to_string(),
                    tag.to_string(),
                    fmt_ms(ms),
                    fmt_x(dense_ms / ms),
                ]);
            }
        }
    }
    rep.emit(&ctx.results, name)
}

fn fig13(ctx: &Ctx) -> Result<()> {
    latency_fig(
        ctx,
        "fig13",
        "Fig 13 — OPT inter-token latency vs seq bucket (B=16)",
        &[
            ("opt-tiny", &["dense", "dejavu", "polar_d0500"]),
            ("opt-small", &["dense", "dejavu", "polar_d0250"]),
        ],
    )
}

fn fig14(ctx: &Ctx) -> Result<()> {
    latency_fig(
        ctx,
        "fig14",
        "Fig 14 — LLaMA inter-token latency vs seq bucket (B=16)",
        &[
            ("llama-tiny", &["dense", "polar_d0500"]),
            ("llama-gqa", &["dense", "polar_d0625"]),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_tag_parsing() {
        // k comes from entry meta, not the entry-name string: a multi-k
        // artifact (k96@b4, k188@b16) must resolve per batch bucket
        let dir = std::env::temp_dir().join("ps_fig_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"model":"m","analogue":"x",
                "config":{"d_model":8,"n_layers":2,"n_heads":2,"n_kv_heads":2,
                          "d_ff":16,"d_head":4,"vocab":10,"max_seq":32,
                          "mlp":"relu","pos":"learned","critical_density":0.5},
                "params":[],"buckets":{"batch":[1],"seq":[16],"prefill":16},
                "entries":[
                  {"name":"tp2_mlp_s0_k96_b4","kind":"tp_mlp","file":"x",
                   "data":[],"outputs":[],
                   "meta":{"batch":4,"shard":0,"n_shards":2,"top_k":96}},
                  {"name":"tp2_mlp_s0_k188_b16","kind":"tp_mlp","file":"x",
                   "data":[],"outputs":[],
                   "meta":{"batch":16,"shard":0,"n_shards":2,"top_k":188}},
                  {"name":"tp2_mlp_s0_dense_b1","kind":"tp_mlp","file":"x",
                   "data":[],"outputs":[],
                   "meta":{"batch":1,"shard":0,"n_shards":2,"top_k":0}}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(mlp_tag_for(&m, 2, 4), "k96");
        assert_eq!(mlp_tag_for(&m, 2, 16), "k188");
        // dense-only bucket and unsharded counts fall back to dense
        assert_eq!(mlp_tag_for(&m, 2, 1), "dense");
        assert_eq!(mlp_tag_for(&m, 4, 4), "dense");
    }
}
