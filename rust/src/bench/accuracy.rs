//! Zero-shot task-suite evaluation through the compiled engine — the
//! lm-eval-harness analogue behind Fig 4, Table 1 and Table 2.
//!
//! Protocol: B=1 greedy decoding at the N=128 bucket (accuracy is
//! batch-size-independent for head sparsity — §4.2; the MLP union effect is
//! covered by the throughput benches). Every (model, mode, density) uses
//! the same fixed eval set written at artifact-build time.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::Mode;
use crate::runtime::{Engine, Manifest, Tensor};
use crate::tokenizer::Tokenizer;
use crate::workload::tasks::{load_suite, score, SuiteScore, TaskItem};

pub const EVAL_N: usize = 128;

/// Mode tag for accuracy entries (supports teal/cats baselines too).
pub fn accuracy_tag(mode: Mode) -> String {
    mode.tag()
}

/// Greedy-generate a continuation for one prompt at B=1.
pub fn generate_one(
    engine: &Engine,
    tag: &str,
    prompt_ids: &[i32],
    max_new: usize,
) -> Result<Vec<i32>> {
    if prompt_ids.is_empty() {
        bail!("empty prompt");
    }
    // chunked prefill streams the whole prompt straight into the eval
    // bucket (no monolithic 64-token cap, no pad-to-bucket copy); an
    // over-long prompt is an error, never a silent truncation
    let plen = prompt_ids.len();
    if plen >= EVAL_N {
        bail!("prompt of {plen} tokens does not fit the eval bucket {EVAL_N}");
    }
    let out = engine.prefill(
        &Tensor::i32(prompt_ids.to_vec(), vec![1, plen])?,
        &Tensor::i32(vec![plen as i32], vec![1])?,
        EVAL_N,
    )?;
    let mut kv = out.kv;
    let mut logits = out.logits;
    let mut ids = Vec::with_capacity(max_new);
    let mut len = plen;
    for _ in 0..max_new {
        let row = logits.as_f32()?;
        let next = crate::substrate::rng::argmax(row) as i32;
        ids.push(next);
        if next == b'\n' as i32 {
            break;
        }
        len += 1;
        if len + 1 > EVAL_N {
            break;
        }
        let name = m.decode_entry_name(tag, 1, EVAL_N);
        if m.entries.get(&name).is_none() {
            bail!("manifest missing accuracy entry {name}");
        }
        // index-taking polar entries: the engine runs the artifact's
        // routers itself when no routing is supplied
        let step = engine.decode(tag, &[next], &[(len) as i32], kv, None)?;
        logits = step.logits;
        kv = step.kv;
    }
    Ok(ids)
}

/// Evaluate the fixed suite at a sparsity mode. `per_family` limits items
/// per family (the full set is 50/family).
pub fn eval_suite(
    engine: &Engine,
    mode: Mode,
    suite_path: &Path,
    per_family: usize,
    max_new: usize,
) -> Result<SuiteScore> {
    let tag = accuracy_tag(mode);
    eval_suite_tag(engine, &tag, suite_path, per_family, max_new)
}

/// Same, for a raw entry tag ("teal_d0500", ...).
pub fn eval_suite_tag(
    engine: &Engine,
    tag: &str,
    suite_path: &Path,
    per_family: usize,
    max_new: usize,
) -> Result<SuiteScore> {
    let all = load_suite(suite_path).context("loading eval suite")?;
    let tok = Tokenizer::new();
    let mut counts: std::collections::BTreeMap<String, usize> = Default::default();
    let mut results: Vec<(TaskItem, String)> = Vec::new();
    for item in all {
        let c = counts.entry(item.family.clone()).or_default();
        if *c >= per_family {
            continue;
        }
        *c += 1;
        let prompt_ids = tok.encode_prompt(&item.prompt);
        let gen = generate_one(engine, tag, &prompt_ids, max_new)?;
        results.push((item, tok.decode(&gen)));
    }
    Ok(score(&results))
}

/// Lookup: which polar densities have accuracy entries for this model?
pub fn available_densities(m: &Manifest) -> Vec<f64> {
    let mut out: Vec<f64> = m
        .entries
        .values()
        .filter(|e| {
            e.kind == "decode"
                && e.batch() == 1
                && e.seq_bucket() == EVAL_N
                && e.mode() == "polar"
        })
        .map(|e| e.density())
        .collect();
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    out.dedup();
    out
}
