//! L3 coordinator: the paper's serving-system contribution.
//!
//! Modules: continuous batching scheduler over static-shape executables,
//! KV-slot surgery, sparsity controller (dense / DejaVu / Polar), sampler,
//! metrics.

pub mod kv;
pub mod metrics;
pub mod request;
pub mod sampler;
pub mod scheduler;
pub mod sparsity;

pub use request::{Completion, FinishReason, Request, SamplingParams};
pub use scheduler::{Scheduler, SchedulerConfig, StepEngine};
pub use sparsity::{Mode, SparsityController};

#[cfg(test)]
mod scheduler_tests {
    use std::time::Instant;

    use anyhow::Result;

    use crate::prop_assert;
    use crate::runtime::{KvCache, ModelConfig, StepOutput, Tensor};
    use crate::substrate::prop::check;
    use crate::tokenizer::PAD;

    use super::scheduler::{Scheduler, SchedulerConfig, StepEngine};
    use super::sparsity::{Mode, SparsityController};
    use super::*;

    /// Mock engine: deterministic "LM" that, for a prompt whose first id is
    /// `c`, emits `c+1` for `c+1 - prompt-first-id` steps then the stop
    /// token. Verifies scheduling, not numerics. KV carries a per-slot
    /// fingerprint in position 0 so tests can detect slot aliasing.
    struct MockEngine {
        cfg: ModelConfig,
        batch_buckets: Vec<usize>,
        seq_buckets: Vec<usize>,
    }

    impl MockEngine {
        fn new() -> Self {
            MockEngine {
                cfg: ModelConfig {
                    name: "mock".into(),
                    analogue: "mock".into(),
                    d_model: 8,
                    n_layers: 2,
                    n_heads: 2,
                    n_kv_heads: 2,
                    d_ff: 16,
                    d_head: 2,
                    vocab: 300,
                    max_seq: 64,
                    mlp: "relu".into(),
                    pos: "learned".into(),
                    critical_density: 0.5,
                },
                batch_buckets: vec![1, 2, 4, 8],
                seq_buckets: vec![16, 32, 64],
            }
        }

        fn logits_for(&self, token: i32) -> Vec<f32> {
            // next token = token + 1 (wrapping inside byte range)
            let mut row = vec![0.0f32; self.cfg.vocab];
            let next = if token >= 255 { b'\n' as i32 } else { token + 1 };
            row[next as usize] = 10.0;
            row
        }
    }

    impl StepEngine for MockEngine {
        fn config(&self) -> &ModelConfig {
            &self.cfg
        }
        fn batch_buckets(&self) -> &[usize] {
            &self.batch_buckets
        }
        fn seq_buckets(&self) -> &[usize] {
            &self.seq_buckets
        }
        fn prefill_len(&self) -> usize {
            16
        }
        fn prefill(&self, tokens: &Tensor, lengths: &Tensor) -> Result<StepOutput> {
            let b = tokens.shape()[0];
            let s = tokens.shape()[1];
            let toks = tokens.as_i32()?;
            let lens = lengths.as_i32()?;
            let mut logits = Vec::with_capacity(b * self.cfg.vocab);
            for i in 0..b {
                let last = toks[i * s + (lens[i] as usize - 1).min(s - 1)];
                logits.extend(self.logits_for(last));
            }
            let mut kvt = Tensor::zeros_f32(self.cfg.kv_shape(b, 16));
            // fingerprint: first element per slot = last prompt token
            for i in 0..b {
                let block = self.cfg.n_kv_heads * 16 * self.cfg.d_head;
                kvt.as_f32_mut()?[i * block] = toks[i * s] as f32;
            }
            Ok(StepOutput {
                logits: Tensor::f32(logits, vec![b, self.cfg.vocab])?,
                kv: KvCache::from_tensor(&kvt, b, 16)?,
            })
        }
        fn decode(
            &self,
            _tag: &str,
            tokens: &[i32],
            _lengths: &[i32],
            kv: KvCache,
        ) -> Result<StepOutput> {
            let b = tokens.len();
            let mut logits = Vec::with_capacity(b * self.cfg.vocab);
            for &t in tokens {
                logits.extend(self.logits_for(if t == PAD { 0 } else { t }));
            }
            Ok(StepOutput {
                logits: Tensor::f32(logits, vec![b, self.cfg.vocab])?,
                kv,
            })
        }
    }

    fn req(id: u64, first: i32, max_new: usize) -> Request {
        Request {
            id,
            prompt_ids: vec![first, first],
            params: SamplingParams {
                max_new_tokens: max_new,
                ..Default::default()
            },
            enqueued_at: Instant::now(),
        }
    }

    fn sched() -> Scheduler<MockEngine> {
        Scheduler::new(
            MockEngine::new(),
            SparsityController::new(Mode::Polar { density: 0.5 }),
            SchedulerConfig { max_batch: 8, compact: true },
        )
    }

    #[test]
    fn single_request_generates_increments() {
        let mut s = sched();
        s.enqueue(req(1, 10, 5));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        // prompt [10,10]: prefill emits 11, then 12, 13, 14, 15
        assert_eq!(done[0].output_ids, vec![11, 12, 13, 14, 15]);
        assert_eq!(done[0].finish, FinishReason::Length);
    }

    #[test]
    fn stop_token_halts() {
        let mut s = sched();
        s.enqueue(req(1, (b'\n' as i32) - 1, 50)); // first sampled == '\n'
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::Stop);
        assert_eq!(done[0].output_ids, vec![b'\n' as i32]);
    }

    #[test]
    fn batch_of_mixed_lengths_completes_all() {
        let mut s = sched();
        for i in 0..6 {
            s.enqueue(req(i, 20 + i as i32, 3 + i as usize));
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 6);
        for c in &done {
            let first = 20 + c.id as i32;
            assert_eq!(c.output_ids[0], first + 1, "req {}", c.id);
            assert_eq!(c.output_ids.len(), 3 + c.id as usize);
        }
        assert_eq!(s.metrics.completed_requests, 6);
        // batch bucket grew past 4
        assert!(s.metrics.kv_rebuilds >= 1);
    }

    #[test]
    fn late_arrivals_join_running_batch() {
        let mut s = sched();
        s.enqueue(req(1, 30, 10));
        // run a few steps, then add another request mid-flight
        for _ in 0..3 {
            s.step().unwrap();
        }
        s.enqueue(req(2, 40, 4));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        let c2 = done.iter().find(|c| c.id == 2).unwrap();
        assert_eq!(c2.output_ids, vec![41, 42, 43, 44]);
    }

    #[test]
    fn seq_bucket_promotes_for_long_generation() {
        let mut s = sched();
        // prompt 2 + 40 generated > 32 bucket -> at least one promotion
        // (start at 100 so the +1 chain never hits the '\n' stop token)
        s.enqueue(req(1, 100, 40));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].output_ids.len(), 40);
        assert!(s.metrics.bucket_promotions >= 1);
    }

    #[test]
    fn cache_limit_finishes_gracefully() {
        let mut s = sched();
        s.enqueue(req(1, 100, 1000)); // would exceed max seq bucket 64
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::CacheLimit);
        assert!(done[0].output_ids.len() < 1000);
    }

    #[test]
    fn drains_and_compacts_to_empty() {
        let mut s = sched();
        s.enqueue(req(1, 10, 2));
        s.run_to_completion().unwrap();
        assert!(s.is_idle());
        assert_eq!(s.capacity(), 0); // group dropped when drained
    }

    #[test]
    fn prop_every_request_completes_exactly_once() {
        check("scheduler-completeness", 15, |g| {
            let mut s = sched();
            let n = g.usize_in(1, 12);
            let mut expected = std::collections::BTreeMap::new();
            for id in 0..n as u64 {
                let first = g.usize_in(30, 200) as i32;
                let max_new = g.usize_in(1, 12);
                expected.insert(id, (first, max_new));
                s.enqueue(req(id, first, max_new));
            }
            let mut done = Vec::new();
            let mut guard = 0;
            while !s.is_idle() {
                done.extend(s.step().map_err(|e| e.to_string())?);
                guard += 1;
                prop_assert!(guard < 10_000, "scheduler did not converge");
            }
            prop_assert!(done.len() == n, "{} of {} completed", done.len(), n);
            let mut seen = std::collections::BTreeSet::new();
            for c in &done {
                prop_assert!(seen.insert(c.id), "request {} completed twice", c.id);
                let (first, max_new) = expected[&c.id];
                prop_assert!(
                    !c.output_ids.is_empty() && c.output_ids[0] == first + 1,
                    "req {} first token {} != {}",
                    c.id, c.output_ids[0], first + 1
                );
                prop_assert!(
                    c.output_ids.len() <= max_new,
                    "req {} overshot max_new", c.id
                );
            }
            Ok(())
        });
    }
}
