//! L3 coordinator: the paper's serving-system contribution.
//!
//! Modules: continuous batching scheduler with chunked prefill over
//! static-shape executables (event-driven: `Scheduler::step()` emits
//! per-token [`GenerationEvent`]s), the token-budget prefill planner,
//! the paged KV block manager ([`kv::BlockPool`]: ref-counted physical
//! blocks, per-request block tables, copy-on-write, hash-keyed prefix
//! caching) plus contiguous host-tensor surgery for the A/B and PP/TP
//! paths, the SLO-aware overload controller ([`overload`]: block-demand
//! admission, preemption with recompute-or-swap resume, deadline-slack
//! urgency), sparsity controller (dense / DejaVu / Polar), sampler,
//! metrics, the fault-tolerance layer ([`faults`]: deterministic fault
//! injection, error classification, retry/backoff policy behind the
//! scheduler's blame-isolation machinery), and a deterministic mock
//! engine for tests and offline protocol work.

pub mod faults;
pub mod kv;
pub mod metrics;
pub mod mock;
pub mod overload;
pub mod planner;
pub mod request;
pub mod sampler;
pub mod scheduler;
pub mod sparsity;

pub use faults::{FaultInjector, FaultScript, RetryPolicy, StepFault};
pub use overload::{OverloadConfig, PressurePolicy};
pub use request::{
    Completion, FinishReason, GenerationEvent, Request, RequestBuilder, SamplingParams,
};
pub use scheduler::{Scheduler, SchedulerConfig, StepEngine};
pub use sparsity::{Mode, RoutingStats, SparsityController, StepPlan};

#[cfg(test)]
mod scheduler_tests {
    use std::time::Duration;

    use crate::prop_assert;
    use crate::substrate::prop::check;

    use super::mock::MockEngine;
    use super::scheduler::{Scheduler, SchedulerConfig};
    use super::sparsity::{Mode, SparsityController};
    use super::*;

    fn req(id: u64, first: i32, max_new: usize) -> Request {
        Request::builder(vec![first, first])
            .id(id)
            .max_new_tokens(max_new)
            .build()
    }

    fn sched() -> Scheduler<MockEngine> {
        sched_with(SchedulerConfig { max_batch: 8, compact: true, ..Default::default() })
    }

    fn sched_with(cfg: SchedulerConfig) -> Scheduler<MockEngine> {
        Scheduler::new(
            MockEngine::new(),
            SparsityController::new(Mode::Polar { density: 0.5 }),
            cfg,
        )
    }

    #[test]
    fn single_request_generates_increments() {
        let mut s = sched();
        s.enqueue(req(1, 10, 5));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        // prompt [10,10]: prefill emits 11, then 12, 13, 14, 15
        assert_eq!(done[0].output_ids, vec![11, 12, 13, 14, 15]);
        assert_eq!(done[0].finish, FinishReason::Length);
    }

    #[test]
    fn stop_token_halts() {
        let mut s = sched();
        s.enqueue(req(1, (b'\n' as i32) - 1, 50)); // first sampled == '\n'
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::Stop);
        assert_eq!(done[0].output_ids, vec![b'\n' as i32]);
    }

    #[test]
    fn batch_of_mixed_lengths_completes_all() {
        let mut s = sched();
        for i in 0..6 {
            s.enqueue(req(i, 20 + i as i32, 3 + i as usize));
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 6);
        for c in &done {
            let first = 20 + c.id as i32;
            assert_eq!(c.output_ids[0], first + 1, "req {}", c.id);
            assert_eq!(c.output_ids.len(), 3 + c.id as usize);
        }
        assert_eq!(s.metrics.completed_requests, 6);
        // every prompt streamed through the chunked-prefill path into
        // the paged pool; all blocks returned when the batch drained
        assert!(s.metrics.prefill_chunks >= 1);
        assert_eq!(s.metrics.prefill_tokens, 12);
        assert_eq!(s.kv_blocks_in_use(), 0);
    }

    #[test]
    fn late_arrivals_join_running_batch() {
        let mut s = sched();
        s.enqueue(req(1, 30, 10));
        // run a few steps, then add another request mid-flight
        for _ in 0..3 {
            s.step().unwrap();
        }
        s.enqueue(req(2, 40, 4));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        let c2 = done.iter().find(|c| c.id == 2).unwrap();
        assert_eq!(c2.output_ids, vec![41, 42, 43, 44]);
    }

    #[test]
    fn seq_bucket_promotes_for_long_generation() {
        let mut s = sched();
        // prompt 2 + 40 generated > 32 bucket -> at least one promotion
        // (start at 100 so the +1 chain never hits the '\n' stop token)
        s.enqueue(req(1, 100, 40));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].output_ids.len(), 40);
        assert!(s.metrics.bucket_promotions >= 1);
    }

    #[test]
    fn cache_limit_finishes_gracefully() {
        let mut s = sched();
        s.enqueue(req(1, 100, 1000)); // would exceed max seq bucket 64
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::CacheLimit);
        assert!(done[0].output_ids.len() < 1000);
    }

    #[test]
    fn drains_and_compacts_to_empty() {
        let mut s = sched();
        s.enqueue(req(1, 10, 2));
        s.run_to_completion().unwrap();
        assert!(s.is_idle());
        assert_eq!(s.capacity(), 0); // group dropped when drained
    }

    #[test]
    fn event_stream_is_ordered_per_request() {
        let mut s = sched();
        s.enqueue(req(1, 10, 4));
        let mut events = Vec::new();
        while !s.is_idle() {
            events.extend(s.step().unwrap());
        }
        // exact lifecycle: Queued, Prefilled, Token x4, Finished
        assert_eq!(events.len(), 7, "events: {events:?}");
        assert!(matches!(events[0], GenerationEvent::Queued { request: 1 }));
        assert!(matches!(events[1], GenerationEvent::Prefilled { request: 1 }));
        for (k, ev) in events[2..6].iter().enumerate() {
            match ev {
                GenerationEvent::Token { request, id, index, text_offset } => {
                    assert_eq!(*request, 1);
                    assert_eq!(*id, 11 + k as i32);
                    assert_eq!(*index, k);
                    // byte tokens: offset advances one byte per token
                    assert_eq!(*text_offset, k);
                }
                other => panic!("expected Token, got {other:?}"),
            }
        }
        match &events[6] {
            GenerationEvent::Finished(c) => {
                assert_eq!(c.output_ids, vec![11, 12, 13, 14]);
                assert!(c.ttft_s <= c.e2e_s);
            }
            other => panic!("expected Finished, got {other:?}"),
        }
    }

    #[test]
    fn cancel_mid_generation_frees_slot_and_emits_partial() {
        let mut s = sched();
        s.enqueue(req(1, 100, 50));
        for _ in 0..4 {
            s.step().unwrap();
        }
        assert_eq!(s.active_len(), 1);
        assert!(s.cancel(1));
        // slot freed immediately, before the next step runs
        assert_eq!(s.active_len(), 0);
        let events = s.step().unwrap();
        let c = events
            .into_iter()
            .find_map(|e| match e {
                GenerationEvent::Cancelled(c) => Some(c),
                _ => None,
            })
            .expect("cancelled event");
        assert_eq!(c.finish, FinishReason::Cancelled);
        assert!(!c.output_ids.is_empty() && c.output_ids.len() < 50);
        assert_eq!(s.metrics.cancelled_requests, 1);
        // no further events for the cancelled request
        while !s.is_idle() {
            for ev in s.step().unwrap() {
                panic!("unexpected event after cancel: {ev:?}");
            }
        }
        assert!(!s.cancel(1), "cancel of finished id must report false");
    }

    #[test]
    fn cancel_pending_request_never_prefills() {
        let mut s = sched();
        s.enqueue(req(1, 10, 5));
        assert!(s.cancel(1));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::Cancelled);
        assert!(done[0].output_ids.is_empty());
        assert_eq!(s.metrics.completed_requests, 0);
        assert_eq!(s.metrics.cancelled_requests, 1);
    }

    #[test]
    fn deadline_expires_pending_and_active() {
        let mut s = sched();
        // already-expired pending request never starts
        s.enqueue(
            Request::builder(vec![10, 10])
                .id(1)
                .max_new_tokens(5)
                .deadline(Duration::ZERO)
                .build(),
        );
        // generous deadline finishes normally
        s.enqueue(
            Request::builder(vec![20, 20])
                .id(2)
                .max_new_tokens(3)
                .deadline(Duration::from_secs(60))
                .build(),
        );
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        let c1 = done.iter().find(|c| c.id == 1).unwrap();
        assert_eq!(c1.finish, FinishReason::Deadline);
        assert!(c1.output_ids.is_empty());
        let c2 = done.iter().find(|c| c.id == 2).unwrap();
        assert_eq!(c2.finish, FinishReason::Length);
        assert_eq!(s.metrics.deadline_expired, 1);
    }

    #[test]
    fn stop_sequence_halts_generation() {
        let mut s = sched();
        // increments 11, 12, 13, 14, ... — stop when output ends [13, 14]
        s.enqueue(
            Request::builder(vec![10, 10])
                .id(1)
                .max_new_tokens(50)
                .stop_sequence(vec![13, 14])
                .build(),
        );
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::StopSequence);
        assert_eq!(done[0].output_ids, vec![11, 12, 13, 14]);
    }

    #[test]
    fn priority_orders_admission() {
        // capacity 1: requests run one at a time, so admission order is
        // completion order
        let mut s =
            sched_with(SchedulerConfig { max_batch: 1, compact: true, ..Default::default() });
        s.enqueue(req(1, 10, 3)); // priority 0
        s.enqueue(
            Request::builder(vec![20, 20])
                .id(2)
                .max_new_tokens(3)
                .priority(5)
                .build(),
        );
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, 2, "high priority must finish first");
        assert_eq!(done[1].id, 1);
    }

    #[test]
    fn ttft_and_itl_recorded_at_emission() {
        let mut s = sched();
        s.enqueue(req(1, 100, 8));
        s.run_to_completion().unwrap();
        assert_eq!(s.metrics.ttft.len(), 1);
        // 8 tokens -> 7 inter-token gaps
        assert_eq!(s.metrics.itl.len(), 7);
    }

    /// The workload that motivated the retired `shrink_patience`
    /// hysteresis: 4 long-runners pin the batch at bucket 4 while a
    /// stream of 1-token requests oscillates occupancy across the 4/8
    /// boundary every cycle. Under paged KV a re-bucket moves table
    /// entries, not cache bytes — the pool tensor crosses the host
    /// boundary exactly ONCE (its initial upload) no matter how often
    /// the bucket thrashes, so eager shrinking is free and hysteresis is
    /// gone.
    #[test]
    fn batch_rebuckets_move_no_kv_bytes() {
        let mut s = sched();
        for i in 0..4 {
            s.enqueue(req(i, 100 + i as i32, 30));
        }
        s.step().unwrap();
        assert_eq!(s.capacity(), 4);
        let mut grew = false;
        let mut shrank = false;
        for k in 0..12u64 {
            s.enqueue(req(100 + k, 50, 1));
            let before = s.capacity();
            s.step().unwrap();
            grew |= s.capacity() > before;
            shrank |= s.capacity() < before;
        }
        assert!(grew, "churn never grew the bucket");
        assert!(shrank, "eager shrink never fired");
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 16);
        // the pool uploaded once; every re-bucket after that moved zero
        // cache bytes (per-step h2d is tokens/lengths/tables only)
        let pool_bytes =
            (s.engine().config().kv_pool_shape(33, 16).iter().product::<usize>() * 4) as u64;
        let p = s.profile();
        assert!(
            p.h2d_bytes < 2 * pool_bytes,
            "pool crossed the boundary more than once: {} vs pool {}",
            p.h2d_bytes,
            pool_bytes
        );
        assert_eq!(s.kv_blocks_in_use(), 0);
    }

    #[test]
    fn router_indices_flow_scheduler_to_engine() {
        // polar + mock router bank: every decode step must carry
        // controller-computed head/MLP indices into the engine, and the
        // controller must record union densities + router overhead
        use crate::runtime::RoutingPolicy;
        let ctl = SparsityController::with_routers(
            Mode::Polar { density: 0.5 },
            Some(mock::mock_router_bank()),
            RoutingPolicy { head_k: 1, mlp_req_k: vec![2, 2], mlp_cap: 16 },
        );
        let mut s = Scheduler::new(
            MockEngine::new(),
            ctl,
            SchedulerConfig { max_batch: 4, compact: true, ..Default::default() },
        );
        for i in 0..4 {
            s.enqueue(req(i, 100 + i as i32, 6));
        }
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 4);
        // mock "+1 chain" semantics survive the routed entries
        for c in &done {
            assert_eq!(c.output_ids[0], 101 + c.id as i32);
        }
        let routed = s.engine().routed_steps();
        assert!(routed > 0, "no decode step carried router indices");
        let stats = &s.sparsity().stats;
        assert_eq!(stats.routed_steps, routed);
        assert_eq!(stats.fallback_steps, 0);
        // head union is input-independent for the mock bank: exactly k/G
        for u in stats.head_union_mean() {
            assert!((u - 0.5).abs() < 1e-9, "head union {u}");
        }
        // 4 distinct tokens -> 4 neuron pairs of 16 = 0.5 union density
        for u in stats.mlp_union_mean() {
            assert!((u - 0.5).abs() < 1e-9, "mlp union {u}");
        }
        // selection histogram covers every routed layer
        assert_eq!(stats.head_counts.iter().sum::<u64>(), routed * 4 * 2);
        // router overhead lands in the merged step profile
        assert_eq!(s.profile().router_ns, stats.router_ns);
    }

    #[test]
    fn routing_excludes_finished_slots_from_union() {
        // one request finishes early; the steps that follow decode at the
        // same bucket with a PAD slot, which must not join the MLP union
        use crate::runtime::RoutingPolicy;
        let ctl = SparsityController::with_routers(
            Mode::Polar { density: 0.5 },
            Some(mock::mock_router_bank()),
            RoutingPolicy { head_k: 1, mlp_req_k: vec![2, 2], mlp_cap: 16 },
        );
        let mut s = Scheduler::new(
            MockEngine::new(),
            ctl,
            SchedulerConfig { max_batch: 2, compact: true, ..Default::default() },
        );
        s.enqueue(req(0, 100, 2));
        s.enqueue(req(1, 101, 6));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        let stats = &s.sparsity().stats;
        // step 1 routes both slots (union 4/16), steps 2..5 only the
        // survivor (2/16): mean = (0.25 + 4 * 0.125) / 5 = 0.15
        assert_eq!(stats.routed_steps, 5);
        for u in stats.mlp_union_mean() {
            assert!((u - 0.15).abs() < 1e-9, "mlp union {u} (PAD slot routed?)");
        }
        // head union stays at k/G, computed over live slots only
        for u in stats.head_union_mean() {
            assert!((u - 0.5).abs() < 1e-9, "head union {u}");
        }
        // histogram: 2 live slots on step 1, 1 on steps 2..5, x2 layers
        assert_eq!(stats.head_counts.iter().sum::<u64>(), (2 + 4) * 2);
    }

    #[test]
    fn fallback_controller_serves_dense_on_mock() {
        use crate::runtime::RoutingPolicy;
        let ctl = SparsityController::with_routers(
            Mode::Polar { density: 0.5 },
            None,
            RoutingPolicy { head_k: 1, ..Default::default() },
        );
        let mut s = Scheduler::new(MockEngine::new(), ctl, SchedulerConfig::default());
        s.enqueue(req(1, 50, 4));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].output_ids, vec![51, 52, 53, 54]);
        assert_eq!(s.engine().routed_steps(), 0);
        assert!(s.sparsity().is_fallback());
        assert_eq!(s.sparsity().stats.fallback_steps, 3);
    }

    #[test]
    fn allocator_metrics_account_paged_serving() {
        let mut s = sched();
        for i in 0..3 {
            s.enqueue(req(i, 100 + i as i32, 8));
        }
        s.step().unwrap();
        // 3 two-token prompts -> one block each, live in the pool
        assert_eq!(s.kv_blocks_in_use(), 3);
        let stats = s.kv_stats();
        assert_eq!(stats.get("blocks_in_use").as_usize(), Some(3));
        assert_eq!(stats.get("block_size").as_usize(), Some(16));
        assert_eq!(stats.get("pool_blocks").as_usize(), Some(33));
        assert!(stats.get("utilization").as_f64().unwrap() > 0.0);
        // growing the batch bucket mid-flight copies NOTHING
        for i in 3..6 {
            s.enqueue(req(i, 100 + i as i32, 4));
        }
        s.run_to_completion().unwrap();
        let j = s.metrics.to_json();
        // the always-zero rebuild-era counters are gone from the stats
        // payload entirely (PROTOCOL.md documents the removal)
        assert_eq!(j.get("kv_rebuilds").as_usize(), None);
        assert_eq!(j.get("regroups").as_usize(), None);
        assert_eq!(j.get("slot_copies").as_usize(), None);
        assert_eq!(j.get("kv_pool_reuses").as_usize(), None);
        assert_eq!(j.get("kv_pool_allocs").as_usize(), None);
        // pool creation time is the only host "surgery" this run paid
        let p = s.profile();
        assert!(p.host_surgery_ns > 0, "pool creation time not recorded");
        // mock resident path: per-step d2h is logits-only, h2d is
        // tokens/lengths/tables (+ the single pool upload)
        assert!(p.d2h_bytes > 0 && p.h2d_bytes > 0);
        // prefill sub-timings surfaced through the merged profile
        assert!(p.prefill_chunks >= 2);
        // everything reclaimed; six one-block tables were allocated
        assert_eq!(s.kv_blocks_in_use(), 0);
        assert!(s.kv_stats().get("block_allocs").as_usize().unwrap() >= 6);
    }

    /// A prompt far past the old monolithic bucket (64) streams through
    /// successive chunks un-truncated: the mock fingerprints every cache
    /// position it writes, so the whole 1024-token prompt must be present
    /// in order, and the first generated token must continue the *true*
    /// last prompt token (truncation would continue an earlier one).
    #[test]
    fn long_prompt_streams_untruncated_through_chunks() {
        let eng = MockEngine::new()
            .with_seq_buckets(vec![16, 32, 64, 128, 256, 512, 1024, 1152]);
        let mut s = Scheduler::new(
            eng,
            SparsityController::new(Mode::Dense),
            SchedulerConfig { max_batch: 8, ..Default::default() },
        );
        let prompt: Vec<i32> = (0..1024).map(|i| (i % 200) + 20).collect();
        let last = *prompt.last().unwrap();
        s.enqueue(Request::builder(prompt.clone()).id(1).max_new_tokens(4).build());
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].prompt_len, 1024);
        assert_eq!(
            done[0].output_ids,
            vec![last + 1, last + 2, last + 3, last + 4]
        );
        // 1024 tokens / 16-token chunks, one chunk per step by default
        assert_eq!(s.metrics.prefill_chunks, 64);
        assert_eq!(s.metrics.prefill_tokens, 1024);
        assert!(s.n_bucket() >= 1025 || s.capacity() == 0);
    }

    /// While a long prompt is being admitted, an already-running decoder
    /// keeps emitting exactly one token per step — chunked prefill and
    /// the decode batch share each step (no head-of-line blocking).
    #[test]
    fn prefill_chunks_interleave_with_decode() {
        let mut s = sched();
        s.enqueue(req(1, 100, 40));
        s.step().unwrap(); // decoder admitted + first tokens
        // long prompt: 40 tokens = 2 full chunks + one 8-token chunk
        let prompt: Vec<i32> = (0..40).map(|i| 30 + (i % 100)) .collect();
        let plast = *prompt.last().unwrap();
        s.enqueue(Request::builder(prompt).id(2).max_new_tokens(3).build());
        let mut decoder_tokens_during_prefill = 0;
        let mut prefilled_at_step = None;
        for step in 0..3 {
            let events = s.step().unwrap();
            for ev in &events {
                match ev {
                    GenerationEvent::Token { request: 1, .. } => {
                        decoder_tokens_during_prefill += 1;
                    }
                    GenerationEvent::Prefilled { request: 2 } => {
                        prefilled_at_step = Some(step);
                    }
                    _ => {}
                }
            }
        }
        // one decoder token per step, even while request 2 prefilled
        assert_eq!(decoder_tokens_during_prefill, 3);
        assert_eq!(prefilled_at_step, Some(2), "3 chunks -> prefilled on 3rd step");
        assert!(s.metrics.interleaved_steps >= 3);
        let done = s.run_to_completion().unwrap();
        let c2 = done.iter().find(|c| c.id == 2).unwrap();
        assert_eq!(c2.output_ids, vec![plast + 1, plast + 2, plast + 3]);
    }

    /// The mock honors block tables end-to-end: after interleaved
    /// admission, the POOL carries both requests' prompts in exactly the
    /// physical blocks their tables name — chunk writes never clobber a
    /// co-resident request, and reading the pool back through each
    /// table reconstructs each prompt in order.
    #[test]
    fn chunk_writes_preserve_coresident_blocks() {
        let mut s = sched();
        s.enqueue(req(1, 100, 20));
        s.step().unwrap();
        let prompt: Vec<i32> = (40..40 + 36).collect(); // 3 chunks
        s.enqueue(Request::builder(prompt.clone()).id(2).max_new_tokens(2).build());
        for _ in 0..3 {
            s.step().unwrap();
        }
        let pool = s.kv_snapshot().unwrap().expect("kv pool");
        // request 2 = the long prompt: its table reconstructs positions
        // 0..36 in order out of the pool
        let t2 = s.block_table_of(2).expect("live table");
        assert!(t2.len() >= 3, "36 tokens need 3 blocks, got {t2:?}");
        let fp2 = s.engine().table_fingerprints(&pool, &t2).unwrap();
        for (p, &t) in prompt.iter().enumerate() {
            assert_eq!(fp2[p], t as f32, "position {p} clobbered or misplaced");
        }
        // request 1's prompt [100, 100] intact in ITS blocks
        let t1 = s.block_table_of(1).expect("live table");
        let fp1 = s.engine().table_fingerprints(&pool, &t1).unwrap();
        assert_eq!(&fp1[..2], &[100.0, 100.0]);
        // distinct prompts, distinct physical memory
        assert!(t1.iter().all(|b| !t2.contains(b)), "foreign aliasing: {t1:?} vs {t2:?}");
        s.run_to_completion().unwrap();
    }

    /// Acceptance: a multi-request paged workload produces token output
    /// identical to the mock's +1-chain ground truth (the same stream
    /// the contiguous scheduler produced before paging), with per-block
    /// fingerprint verification — every prompt position sits in exactly
    /// the physical block its table names, and no two non-sharing
    /// requests alias a block.
    #[test]
    fn paged_workload_matches_contiguous_semantics_with_fingerprints() {
        let mut s = sched();
        let prompts: Vec<Vec<i32>> = (0..5)
            .map(|i| {
                let len = 3 + 9 * i; // 3..39 tokens: 1..3 blocks
                (0..len).map(|k| 30 + ((i * 37 + k) % 150) as i32).collect()
            })
            .collect();
        for (i, p) in prompts.iter().enumerate() {
            s.enqueue(
                Request::builder(p.clone())
                    .id(i as u64)
                    .max_new_tokens(20)
                    .build(),
            );
        }
        // drive until every prompt finished prefilling (longest = 3
        // chunks); nobody completes yet, so every table is still live
        let mut prefilled = 0;
        let mut guard = 0;
        while prefilled < 5 {
            for ev in s.step().unwrap() {
                if matches!(ev, GenerationEvent::Prefilled { .. }) {
                    prefilled += 1;
                }
            }
            guard += 1;
            assert!(guard < 50, "prompts never finished prefilling");
        }
        let pool = s.kv_snapshot().unwrap().expect("kv pool");
        let tables: Vec<Vec<i32>> = (0..5)
            .map(|i| s.block_table_of(i as u64).expect("live table"))
            .collect();
        for (i, p) in prompts.iter().enumerate() {
            let fp = s.engine().table_fingerprints(&pool, &tables[i]).unwrap();
            for (pos, &t) in p.iter().enumerate() {
                assert_eq!(
                    fp[pos], t as f32,
                    "req {i} pos {pos}: wrong block content"
                );
            }
        }
        // distinct prompts (no shared full-block prefix here): no block
        // may back two requests
        for i in 0..5 {
            for j in (i + 1)..5 {
                assert!(
                    tables[i].iter().all(|b| !tables[j].contains(b)),
                    "requests {i}/{j} alias blocks: {:?} vs {:?}",
                    tables[i],
                    tables[j]
                );
            }
        }
        let mut done = s.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        for (i, c) in done.iter().enumerate() {
            let last = *prompts[i].last().unwrap();
            let want: Vec<i32> = (1..=20).map(|k| last + k).collect();
            assert_eq!(c.output_ids, want, "req {i} diverged from the +1 chain");
        }
        assert_eq!(s.kv_blocks_in_use(), 0, "blocks leaked after drain");
    }

    /// Zero-shell acceptance on a mixed paged workload: chunked prefill,
    /// a shared-prefix COW fork, and fused decode interleave, and from
    /// process start to drain the profile shows ZERO gather/scatter shell
    /// bytes on either the decode or the prefill side, exactly one
    /// full-pool upload (the first paged call), and COW accounted as
    /// device-local `cow_bytes` — while every per-block fingerprint and
    /// token stream reproduces the +1-chain ground truth.
    #[test]
    fn zero_shell_paged_pipeline_with_cow_and_fingerprints() {
        let mut s = Scheduler::new(
            MockEngine::new(),
            SparsityController::new(Mode::Polar { density: 0.5 }),
            SchedulerConfig { max_batch: 8, compact: true, ..Default::default() },
        );
        let prefix: Vec<i32> = (0..32).map(|i| 20 + i).collect();
        let mut prompt_a = prefix.clone();
        prompt_a.extend(60..76); // 48 tokens = 3 full blocks
        let mut prompt_b = prefix.clone();
        prompt_b.extend(130..146);

        // request 1 prefills all 3 chunks, then keeps decoding while the
        // later admissions prefill (chunk/decode steps interleave);
        // 48 prompt + 16 new tokens exactly fills the 64 bucket
        s.enqueue(Request::builder(prompt_a.clone()).id(1).max_new_tokens(16).build());
        let mut guard = 0;
        loop {
            let evs = s.step().unwrap();
            if evs.iter().any(|e| matches!(e, GenerationEvent::Prefilled { request: 1 })) {
                break;
            }
            guard += 1;
            assert!(guard < 50, "request 1 never prefilled");
        }
        // request 2: shared 32-token prefix -> only its suffix prefills.
        // request 3: prompt identical to request 1's fully-cached one ->
        // the last token recomputes into a COW COPY of the shared final
        // block (request 1 still owns the original).
        s.enqueue(Request::builder(prompt_b.clone()).id(2).max_new_tokens(4).build());
        s.enqueue(Request::builder(prompt_a.clone()).id(3).max_new_tokens(4).build());
        let mut prefilled = 0;
        let mut guard = 0;
        while prefilled < 2 {
            for ev in s.step().unwrap() {
                if matches!(ev, GenerationEvent::Prefilled { .. }) {
                    prefilled += 1;
                }
            }
            guard += 1;
            assert!(guard < 50, "requests 2/3 never finished prefilling");
        }

        // per-block fingerprints: every prompt position sits in exactly
        // the physical block its table names
        let pool = s.kv_snapshot().unwrap().expect("kv pool");
        let t1 = s.block_table_of(1).expect("live table");
        let t2 = s.block_table_of(2).expect("live table");
        let t3 = s.block_table_of(3).expect("live table");
        let fp1 = s.engine().table_fingerprints(&pool, &t1).unwrap();
        for (pos, &t) in prompt_a.iter().enumerate() {
            assert_eq!(fp1[pos], t as f32, "req 1 pos {pos}: wrong block content");
        }
        let fp2 = s.engine().table_fingerprints(&pool, &t2).unwrap();
        for (pos, &t) in prompt_b.iter().enumerate() {
            assert_eq!(fp2[pos], t as f32, "req 2 pos {pos}: wrong block content");
        }
        let fp3 = s.engine().table_fingerprints(&pool, &t3).unwrap();
        for (pos, &t) in prompt_a.iter().enumerate() {
            assert_eq!(fp3[pos], t as f32, "req 3 pos {pos}: wrong block content");
        }
        // sharing shape: prefix blocks aliased, divergent/COWed tails not
        assert_eq!(&t1[..2], &t2[..2], "prefix blocks not shared with req 2");
        assert_eq!(&t1[..2], &t3[..2], "prefix blocks not shared with req 3");
        assert_ne!(t1[2], t2[2], "req 2's divergent suffix block aliased");
        assert_ne!(t1[2], t3[2], "cap write did not COW the shared block");

        let mut done = s.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 3);
        let want1: Vec<i32> = (76..=91).collect();
        assert_eq!(done[0].output_ids, want1, "req 1 diverged from the +1 chain");
        assert_eq!(done[1].output_ids, vec![146, 147, 148, 149]);
        assert_eq!(done[2].output_ids, vec![76, 77, 78, 79]);

        // the zero-shell gate: NOTHING since process start moved dense-view
        // shell bytes, and the pool crossed host->device exactly once
        let p = s.engine().profile_snapshot();
        assert!(p.decode_steps > 0 && p.prefill_chunks >= 5);
        assert_eq!(p.gather_bytes, 0, "decode gathered shell bytes");
        assert_eq!(p.scatter_bytes, 0, "decode scattered shell bytes");
        assert_eq!(p.prefill_gather_bytes, 0, "prefill gathered shell bytes");
        assert_eq!(p.prefill_scatter_bytes, 0, "prefill scattered shell bytes");
        assert_eq!(s.engine().pool_uploads(), 1, "pool uploaded more than once");
        // COW ran on-device: one block per cow_copy, nothing host-bound
        let kv = s.kv_stats();
        let cows = kv.get("cow_copies").as_usize().unwrap();
        assert!(cows >= 1, "cap write never COWed: {kv}");
        let block_bytes = s.engine().config().kv_block_elems(16) * 4;
        assert_eq!(p.cow_bytes as usize, cows * block_bytes);
        assert_eq!(s.kv_blocks_in_use(), 0, "blocks leaked after drain");
    }

    /// Sharded-serving acceptance: the same paged + routed workload —
    /// chunked prefill, shared-prefix COW fork, decode drain — served
    /// TP=2 produces token streams BIT-IDENTICAL to the single-device
    /// run, while each shard's pool slice independently reconstructs
    /// every prompt (KV-write-always: a routed-away shard still runs its
    /// KV write), routing strictly cuts dispatched (layer, shard) pairs,
    /// and the zero-shell gate holds on the sharded steps.
    #[test]
    fn tp2_sharded_serving_is_bit_identical_and_skips_shards() {
        use crate::runtime::{split_pool_groups, RoutingPolicy, StepProfile};

        fn run(tp: Option<usize>) -> (Vec<Completion>, StepProfile) {
            let ctl = SparsityController::with_routers(
                Mode::Polar { density: 0.5 },
                Some(mock::mock_router_bank()),
                RoutingPolicy { head_k: 1, mlp_req_k: vec![2, 2], mlp_cap: 16 },
            );
            let eng = match tp {
                Some(n) => MockEngine::new().with_tp(n),
                None => MockEngine::new(),
            };
            let mut s = Scheduler::new(
                eng,
                ctl,
                SchedulerConfig { max_batch: 8, compact: true, ..Default::default() },
            );
            let prefix: Vec<i32> = (0..32).map(|i| 20 + i).collect();
            let mut prompt_a = prefix.clone();
            prompt_a.extend(60..76); // 48 tokens = 3 full blocks
            let mut prompt_b = prefix;
            prompt_b.extend(130..146);
            s.enqueue(Request::builder(prompt_a.clone()).id(1).max_new_tokens(8).build());
            let mut guard = 0;
            loop {
                let evs = s.step().unwrap();
                if evs.iter().any(|e| matches!(e, GenerationEvent::Prefilled { request: 1 })) {
                    break;
                }
                guard += 1;
                assert!(guard < 50, "request 1 never prefilled");
            }
            // request 2 shares the prefix; request 3's identical prompt
            // forces the cap-recompute COW fork
            s.enqueue(Request::builder(prompt_b.clone()).id(2).max_new_tokens(4).build());
            s.enqueue(Request::builder(prompt_a.clone()).id(3).max_new_tokens(4).build());
            let mut prefilled = 0;
            let mut guard = 0;
            while prefilled < 2 {
                for ev in s.step().unwrap() {
                    if matches!(ev, GenerationEvent::Prefilled { .. }) {
                        prefilled += 1;
                    }
                }
                guard += 1;
                assert!(guard < 50, "requests 2/3 never finished prefilling");
            }
            if let Some(n) = tp {
                // per-shard KV-write proof: EVERY shard's group slice of
                // the pool independently reconstructs every live prompt —
                // the shard routing skipped still wrote its KV rows
                let pool = s.kv_snapshot().unwrap().expect("kv pool");
                let shards = split_pool_groups(&pool, n).unwrap();
                for (id, prompt) in [(1u64, &prompt_a), (2, &prompt_b), (3, &prompt_a)] {
                    let table = s.block_table_of(id).expect("live table");
                    for (si, slice) in shards.iter().enumerate() {
                        let fp = s.engine().table_fingerprints(slice, &table).unwrap();
                        for (pos, &t) in prompt.iter().enumerate() {
                            assert_eq!(
                                fp[pos], t as f32,
                                "req {id} pos {pos} missing from shard {si}'s KV"
                            );
                        }
                    }
                }
                // the COW fork happened under sharding too
                let t1 = s.block_table_of(1).unwrap();
                let t3 = s.block_table_of(3).unwrap();
                assert_eq!(&t1[..2], &t3[..2], "prefix blocks not shared");
                assert_ne!(t1[2], t3[2], "cap write did not COW the shared block");
            }
            let mut done = s.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            let p = s.profile();
            // stats.shards mirrors the merged profile counters
            let st = s.shard_stats();
            assert_eq!(st.get("shards_dispatched").as_usize(), Some(p.shards_dispatched as usize));
            assert_eq!(st.get("shards_skipped").as_usize(), Some(p.shards_skipped as usize));
            assert_eq!(st.get("allreduce_bytes").as_usize(), Some(p.allreduce_bytes as usize));
            (done, p)
        }

        let (dense_done, base) = run(None);
        let (tp_done, tp) = run(Some(2));
        // token streams bit-identical to the single-device run
        assert_eq!(tp_done.len(), 3);
        for (a, b) in dense_done.iter().zip(&tp_done) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output_ids, b.output_ids, "req {} diverged under TP=2", a.id);
        }
        // unsharded runs report no shard traffic at all
        assert_eq!(base.shards_dispatched, 0);
        assert_eq!(base.shards_skipped, 0);
        assert_eq!(base.allreduce_bytes, 0);
        // routing CUT shard dispatches: every routed step covers
        // L*S attention + L*S MLP = 8 (layer, shard) pairs, and with
        // G=2, S=2, k=1 layer 1's attention routes to exactly one
        // shard — at least one kvw-only pair per step, strictly fewer
        // dispatches than dense sharded serving (8 * steps)
        let total = 8 * tp.decode_steps;
        assert_eq!(tp.shards_dispatched + tp.shards_skipped, total);
        assert!(tp.shards_skipped >= tp.decode_steps, "layer-1 attn never skipped");
        assert!(tp.shards_dispatched < total, "routing cut no shard dispatches");
        // partials combine on-device; no shell bytes on sharded steps
        assert!(tp.allreduce_bytes > 0);
        assert_eq!(tp.gather_bytes, 0);
        assert_eq!(tp.scatter_bytes, 0);
    }

    /// Acceptance: two requests sharing a 256-token prefix perform the
    /// prefix's prefill chunk compute ONCE. The second request's table
    /// re-uses the first's physical blocks (prefix_hits > 0), only its
    /// suffix chunks run, and an identical-prompt follow-up triggers the
    /// cap-recompute copy-on-write while the original still holds the
    /// shared block.
    #[test]
    fn shared_prefix_prefills_once_and_cows_on_divergence() {
        let eng = MockEngine::new().with_seq_buckets(vec![16, 32, 64, 128, 256, 512]);
        let mut s = Scheduler::new(
            eng,
            SparsityController::new(Mode::Dense),
            SchedulerConfig { max_batch: 8, ..Default::default() },
        );
        let prefix: Vec<i32> = (0..256).map(|i| 20 + (i % 200)).collect();
        // suffix values stay low so the +1 chain of 40 generated tokens
        // never reaches the mock's byte-range stop
        let mut prompt_a = prefix.clone();
        prompt_a.extend((0..16).map(|k| 60 + k)); // 272 = 17 full blocks
        let mut prompt_b = prefix.clone();
        prompt_b.extend((0..16).map(|k| 130 + k));

        // request 1 prefills the whole 272-token prompt and keeps decoding
        s.enqueue(Request::builder(prompt_a.clone()).id(1).max_new_tokens(40).build());
        let mut guard = 0;
        loop {
            let evs = s.step().unwrap();
            if evs.iter().any(|e| matches!(e, GenerationEvent::Prefilled { request: 1 })) {
                break;
            }
            guard += 1;
            assert!(guard < 100, "request 1 never prefilled");
        }
        assert_eq!(s.metrics.prefill_tokens, 272);

        // request 2: shared prefix -> only its 16-token suffix prefills
        s.enqueue(Request::builder(prompt_b.clone()).id(2).max_new_tokens(2).build());
        // request 3: prompt identical to request 1's, which is fully
        // cached — the last token is recomputed (prefill of exactly 1)
        // into a COPY of the shared final block (request 1 still owns it)
        s.enqueue(Request::builder(prompt_a.clone()).id(3).max_new_tokens(2).build());
        let mut done = s.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 3);

        // prefix chunks ran once: 272 (req 1) + 16 (req 2) + 1 (req 3)
        assert_eq!(s.metrics.prefill_tokens, 289);
        let c2 = &done[1];
        assert_eq!(c2.cached_prompt_tokens, 256);
        assert_eq!(c2.output_ids[0], 130 + 15 + 1, "req 2 first token off its true suffix");
        let c3 = &done[2];
        assert_eq!(c3.cached_prompt_tokens, 271);
        assert_eq!(c3.output_ids[0], 60 + 15 + 1, "req 3 first token off the cached prompt");
        // and request 1 itself was never perturbed by the sharing
        assert_eq!(done[0].output_ids.len(), 40);
        assert_eq!(done[0].output_ids[0], 60 + 15 + 1);

        let kv = s.kv_stats();
        assert!(kv.get("prefix_hits").as_usize().unwrap() >= 16 + 17, "{kv}");
        assert_eq!(
            s.metrics.prefix_tokens_skipped, 256 + 271,
            "prefill tokens saved misaccounted"
        );
        assert!(kv.get("cow_copies").as_usize().unwrap() >= 1, "cap write never COWed: {kv}");
        assert_eq!(s.kv_blocks_in_use(), 0, "blocks leaked");
    }

    /// Cancelling mid-decode releases the request's blocks (and
    /// shared-prefix ref counts) immediately: the pool returns to its
    /// baseline free count before the next step runs.
    #[test]
    fn cancel_mid_decode_returns_pool_to_baseline() {
        let mut s = sched();
        let baseline = s.kv_free_blocks();
        s.enqueue(req(1, 100, 50));
        for _ in 0..4 {
            s.step().unwrap();
        }
        assert!(s.kv_blocks_in_use() >= 1);
        assert!(s.cancel(1));
        // freed at cancel, not at the next reap
        assert_eq!(s.kv_blocks_in_use(), 0);
        assert_eq!(s.kv_free_blocks(), baseline);
        s.run_to_completion().unwrap();
        assert_eq!(s.metrics.cancelled_requests, 1);
    }

    /// The planner with the default budget must generate exactly the
    /// same tokens as the monolithic schedule (budget = MAX, the
    /// pre-refactor behaviour) for short prompts.
    #[test]
    fn chunked_schedule_matches_monolithic_tokens() {
        let run = |budget: usize| {
            let mut s = sched_with(SchedulerConfig {
                max_batch: 8,
                prefill_chunk_tokens: budget,
                ..Default::default()
            });
            for i in 0..5 {
                let prompt: Vec<i32> = (0..(2 + 7 * i as i32)).map(|k| 60 + k).collect();
                s.enqueue(
                    Request::builder(prompt)
                        .id(i)
                        .max_new_tokens(3 + i as usize)
                        .build(),
                );
            }
            let mut done = s.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            done.iter().map(|c| c.output_ids.clone()).collect::<Vec<_>>()
        };
        let chunked = run(0); // default: one chunk bucket per step
        let monolithic = run(usize::MAX);
        assert_eq!(chunked, monolithic);
        // and both match the mock's +1-chain ground truth
        for (i, out) in chunked.iter().enumerate() {
            let last = 60 + (2 + 7 * i as i32) - 1;
            let want: Vec<i32> = (1..=(3 + i as i32)).map(|k| last + k).collect();
            assert_eq!(out, &want, "request {i}");
        }
    }

    /// A sub-chunk budget splits chunks: 32-token prompt at 8 tokens per
    /// step takes 4 steps of 8-token windows (offsets need no alignment).
    #[test]
    fn sub_chunk_budget_throttles_prefill() {
        let mut s = sched_with(SchedulerConfig {
            max_batch: 8,
            prefill_chunk_tokens: 8,
            ..Default::default()
        });
        let prompt: Vec<i32> = (100..132).collect();
        s.enqueue(Request::builder(prompt).id(1).max_new_tokens(2).build());
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].output_ids, vec![132, 133]);
        assert_eq!(s.metrics.prefill_chunks, 4);
        assert_eq!(s.metrics.prefill_tokens, 32);
    }

    /// Over-long prompts are rejected with `prompt_too_long` instead of
    /// the old silent truncation; a prompt that exactly fills the
    /// largest bucket is accepted and yields its first token before
    /// finishing CacheLimit.
    #[test]
    fn prompt_too_long_rejected_exact_fill_accepted() {
        let mut s = sched();
        assert_eq!(s.max_prompt_len(), 64);
        s.enqueue(Request::builder(vec![50; 65]).id(1).max_new_tokens(4).build());
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::PromptTooLong);
        assert!(done[0].output_ids.is_empty());
        assert_eq!(s.metrics.rejected_prompts, 1);
        assert_eq!(s.metrics.prefill_chunks, 0, "rejected prompt must not prefill");

        // exactly filling the largest bucket is legal
        let mut s = sched();
        s.enqueue(Request::builder(vec![70; 64]).id(2).max_new_tokens(8).build());
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].finish, FinishReason::CacheLimit);
        assert_eq!(done[0].output_ids, vec![71]);
        assert_eq!(done[0].prompt_len, 64);
        assert_eq!(s.metrics.rejected_prompts, 0);
    }

    /// An empty prompt can never complete a chunk; it must finish with
    /// zero tokens instead of parking a Prefilling slot forever.
    #[test]
    fn empty_prompt_finishes_without_tokens() {
        let mut s = sched();
        s.enqueue(Request::builder(vec![]).id(1).max_new_tokens(5).build());
        s.enqueue(req(2, 10, 2)); // a real request behind it still runs
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        let c1 = done.iter().find(|c| c.id == 1).unwrap();
        assert_eq!(c1.finish, FinishReason::Length);
        assert!(c1.output_ids.is_empty());
        let c2 = done.iter().find(|c| c.id == 2).unwrap();
        assert_eq!(c2.output_ids, vec![11, 12]);
        assert!(s.is_idle());
    }

    /// Cancelling a request mid-prefill frees its slot before the prompt
    /// ever finishes streaming.
    #[test]
    fn cancel_during_prefill_frees_slot() {
        let mut s = sched();
        let prompt: Vec<i32> = (0..48).map(|k| 60 + k).collect(); // 3 chunks
        s.enqueue(Request::builder(prompt).id(1).max_new_tokens(5).build());
        s.step().unwrap(); // 1 of 3 chunks done
        assert_eq!(s.active_len(), 1);
        assert!(s.cancel(1));
        assert_eq!(s.active_len(), 0);
        let events = s.step().unwrap();
        let c = events
            .into_iter()
            .find_map(|e| match e {
                GenerationEvent::Cancelled(c) => Some(c),
                _ => None,
            })
            .expect("cancelled event");
        assert!(c.output_ids.is_empty(), "no token was ever emitted");
        assert!(s.metrics.prefill_chunks < 3);
        while !s.is_idle() {
            s.step().unwrap();
        }
    }

    #[test]
    fn prop_every_request_completes_exactly_once() {
        check("scheduler-completeness", 15, |g| {
            let mut s = sched();
            let n = g.usize_in(1, 12);
            let mut expected = std::collections::BTreeMap::new();
            for id in 0..n as u64 {
                let first = g.usize_in(30, 200) as i32;
                let max_new = g.usize_in(1, 12);
                expected.insert(id, (first, max_new));
                s.enqueue(req(id, first, max_new));
            }
            let mut done = Vec::new();
            let mut guard = 0;
            while !s.is_idle() {
                let events = s.step().map_err(|e| e.to_string())?;
                done.extend(events.into_iter().filter_map(GenerationEvent::completion));
                guard += 1;
                prop_assert!(guard < 10_000, "scheduler did not converge");
            }
            prop_assert!(done.len() == n, "{} of {} completed", done.len(), n);
            let mut seen = std::collections::BTreeSet::new();
            for c in &done {
                prop_assert!(seen.insert(c.id), "request {} completed twice", c.id);
                let (first, max_new) = expected[&c.id];
                prop_assert!(
                    !c.output_ids.is_empty() && c.output_ids[0] == first + 1,
                    "req {} first token {} != {}",
                    c.id, c.output_ids[0], first + 1
                );
                prop_assert!(
                    c.output_ids.len() <= max_new,
                    "req {} overshot max_new", c.id
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_event_stream_consistent_with_completions() {
        check("scheduler-event-consistency", 10, |g| {
            let mut s = sched();
            let n = g.usize_in(1, 8);
            for id in 0..n as u64 {
                let first = g.usize_in(30, 200) as i32;
                let max_new = g.usize_in(1, 10);
                s.enqueue(req(id, first, max_new));
            }
            let mut token_counts = std::collections::BTreeMap::new();
            let mut completions = Vec::new();
            let mut guard = 0;
            while !s.is_idle() {
                for ev in s.step().map_err(|e| e.to_string())? {
                    match ev {
                        GenerationEvent::Token { request, index, .. } => {
                            let c = token_counts.entry(request).or_insert(0usize);
                            prop_assert!(
                                index == *c,
                                "req {request} token index {index} != {c}"
                            );
                            *c += 1;
                        }
                        GenerationEvent::Finished(c) => completions.push(c),
                        _ => {}
                    }
                }
                guard += 1;
                prop_assert!(guard < 10_000, "did not converge");
            }
            prop_assert!(completions.len() == n, "missing completions");
            for c in &completions {
                let toks = token_counts.get(&c.id).copied().unwrap_or(0);
                prop_assert!(
                    toks == c.output_ids.len(),
                    "req {}: {} token events but {} output ids",
                    c.id, toks, c.output_ids.len()
                );
            }
            Ok(())
        });
    }

    // ---- overload control: admission, preemption, resume ----

    /// Scheduler over a deliberately small block pool so admission and
    /// preemption actually trigger (default mock pools are sized to
    /// never run out).
    fn sched_pool(pool_blocks: usize, cfg: SchedulerConfig) -> Scheduler<MockEngine> {
        Scheduler::new(
            MockEngine::new().with_pool_blocks(pool_blocks),
            SparsityController::new(Mode::Polar { density: 0.5 }),
            cfg,
        )
    }

    /// 33-token prompt (3 blocks), 24 new tokens -> predicted demand of
    /// 4 blocks out of a 7-usable-block pool.
    fn victim_req(id: u64) -> Request {
        Request::builder((100..133).collect())
            .id(id)
            .max_new_tokens(24)
            .build()
    }

    /// Acceptance: a running request preempted under pool pressure
    /// resumes with a bit-identical token stream (indices continue,
    /// no re-emission), its recomputed KV fingerprints match the
    /// uninterrupted run, and the pool returns to its baseline free
    /// count after the drain.
    #[test]
    fn preempted_request_resumes_bit_identical_and_pool_returns_to_baseline() {
        // 8 blocks = 7 usable. Victim holds 3 + 1 reserved; the hot
        // request needs 4 > 3 unreserved -> preemption.
        let mut s = sched_pool(8, SchedulerConfig { max_batch: 8, ..Default::default() });
        let baseline = s.kv_free_blocks();
        let mut events: Vec<GenerationEvent> = Vec::new();
        s.enqueue(victim_req(1));
        // 3 prefill steps (the last one also decodes) + 3 decodes:
        // generated=[133..=137], virtual length 37
        for _ in 0..6 {
            events.extend(s.step().unwrap());
        }
        // hot request: priority 5, 49-token prompt (4 blocks), 8 new
        s.enqueue(
            Request::builder((30..79).collect())
                .id(2)
                .max_new_tokens(8)
                .priority(5)
                .build(),
        );
        let step7 = s.step().unwrap();
        assert!(
            step7.iter().any(|e| matches!(e, GenerationEvent::Preempted { request: 1 })),
            "expected a preemption event, got {step7:?}"
        );
        events.extend(step7);
        assert_eq!(s.preempted_len(), 1);
        assert_eq!(s.active_len(), 1, "hot request admitted into the freed blocks");
        let mut checked = false;
        let mut guard = 0;
        while !s.is_idle() {
            events.extend(s.step().unwrap());
            if !checked && s.metrics.resumes == 1 {
                // Resume recomputed positions 32..37 through the prefix
                // cache (blocks 0..32 were published); the table must
                // reconstruct the virtual prompt = prompt + generated[..4]
                // exactly as the uninterrupted run would have.
                let pool = s.kv_snapshot().unwrap().expect("kv pool");
                let table = s.block_table_of(1).expect("victim table live again");
                let fp = s.engine().table_fingerprints(&pool, &table).unwrap();
                let mut want: Vec<f32> = (100..133).map(|t| t as f32).collect();
                want.extend([133.0, 134.0, 135.0, 136.0]);
                for (p, w) in want.iter().enumerate() {
                    assert_eq!(fp[p], *w, "resumed KV wrong at position {p}");
                }
                checked = true;
            }
            guard += 1;
            assert!(guard < 1000, "overload run did not converge");
        }
        assert!(checked, "victim never resumed");
        // bit-identical stream: 24 tokens, contiguous indices, the +1
        // chain uninterrupted across the preemption boundary
        let victim_tokens: Vec<(usize, i32)> = events
            .iter()
            .filter_map(|e| match e {
                GenerationEvent::Token { request: 1, index, id, .. } => Some((*index, *id)),
                _ => None,
            })
            .collect();
        let want: Vec<(usize, i32)> = (0..24).map(|k| (k, 133 + k as i32)).collect();
        assert_eq!(victim_tokens, want);
        let done: Vec<&Completion> = events
            .iter()
            .filter_map(|e| match e {
                GenerationEvent::Finished(c) => Some(c),
                _ => None,
            })
            .collect();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, 2, "hot request finishes while the victim waits");
        assert_eq!(done[0].output_ids, (79..=86).collect::<Vec<i32>>());
        assert_eq!(done[1].id, 1);
        assert_eq!(done[1].output_ids, (133..=156).collect::<Vec<i32>>());
        assert_eq!(done[1].finish, FinishReason::Length);
        assert_eq!(s.metrics.preemptions, 1);
        assert_eq!(s.metrics.resumes, 1);
        assert_eq!(s.metrics.admission_rejections, 0);
        assert_eq!(s.metrics.swap_out_bytes, 0, "2 full blocks < swap_min_blocks: recompute path");
        assert_eq!(s.metrics.deadline_met_tokens, 32);
        // every block accounted for after the drain
        assert_eq!(s.kv_blocks_in_use(), 0);
        assert_eq!(s.kv_free_blocks(), baseline);
    }

    /// Default policy with nothing to outrank: an arrival whose
    /// predicted demand exceeds unreserved blocks waits in the queue
    /// (no preemption between equal ranks, no rejection) and admits
    /// once the first request drains.
    #[test]
    fn admission_defers_under_block_pressure() {
        let mut s = sched_pool(8, SchedulerConfig { max_batch: 8, ..Default::default() });
        s.enqueue(victim_req(1));
        s.enqueue(
            Request::builder((160..193).collect())
                .id(2)
                .max_new_tokens(24)
                .build(),
        );
        s.step().unwrap();
        assert_eq!(s.active_len(), 1, "second request deferred, not admitted");
        assert_eq!(s.preempted_len(), 0);
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, 1);
        assert_eq!(done[0].output_ids, (133..=156).collect::<Vec<i32>>());
        assert_eq!(done[1].id, 2);
        assert_eq!(done[1].output_ids, (193..=216).collect::<Vec<i32>>());
        assert_eq!(s.metrics.preemptions, 0);
        assert_eq!(s.metrics.admission_rejections, 0);
    }

    /// Reject-only baseline: same pressure as the defer test, but the
    /// policy sheds the request that does not fit instead of queueing
    /// it. It finishes immediately with `FinishReason::Rejected` and an
    /// empty output.
    #[test]
    fn reject_only_policy_sheds_load_at_admission() {
        let mut s = sched_pool(
            8,
            SchedulerConfig {
                max_batch: 8,
                overload: OverloadConfig::reject_only(),
                ..Default::default()
            },
        );
        s.enqueue(victim_req(1));
        s.enqueue(
            Request::builder((160..193).collect())
                .id(2)
                .max_new_tokens(24)
                .build(),
        );
        let done = s.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, 2, "rejected immediately, before request 1 finishes");
        assert_eq!(done[0].finish, FinishReason::Rejected);
        assert!(done[0].output_ids.is_empty());
        assert_eq!(done[1].id, 1);
        assert_eq!(done[1].output_ids, (133..=156).collect::<Vec<i32>>());
        assert_eq!(s.metrics.admission_rejections, 1);
        assert_eq!(s.metrics.preemptions, 0);
        // rejected work earns no goodput
        assert_eq!(s.metrics.deadline_met_tokens, 24);
    }

    /// With the prefix cache off there is nothing to recompute from, so
    /// preemption host-swaps the victim's full blocks out and the
    /// resume path restores them byte-for-byte: fingerprints and the
    /// token stream both match the uninterrupted run.
    #[test]
    fn swap_preemption_restores_kv_without_prefix_cache() {
        let mut s = sched_pool(
            8,
            SchedulerConfig {
                max_batch: 8,
                prefix_cache: false,
                overload: OverloadConfig { swap_min_blocks: 1, ..Default::default() },
                ..Default::default()
            },
        );
        let mut events: Vec<GenerationEvent> = Vec::new();
        s.enqueue(victim_req(1));
        for _ in 0..6 {
            events.extend(s.step().unwrap());
        }
        s.enqueue(
            Request::builder((30..79).collect())
                .id(2)
                .max_new_tokens(8)
                .priority(5)
                .build(),
        );
        events.extend(s.step().unwrap());
        assert_eq!(s.metrics.preemptions, 1);
        // virtual length 36 -> 2 full blocks swapped to host
        assert!(s.metrics.swap_out_bytes > 0);
        let mut checked = false;
        let mut guard = 0;
        while !s.is_idle() {
            events.extend(s.step().unwrap());
            if !checked && s.metrics.resumes == 1 {
                assert_eq!(s.metrics.swap_in_bytes, s.metrics.swap_out_bytes);
                let pool = s.kv_snapshot().unwrap().expect("kv pool");
                let table = s.block_table_of(1).expect("victim table live again");
                let fp = s.engine().table_fingerprints(&pool, &table).unwrap();
                let mut want: Vec<f32> = (100..133).map(|t| t as f32).collect();
                want.extend([133.0, 134.0, 135.0, 136.0]);
                for (p, w) in want.iter().enumerate() {
                    assert_eq!(fp[p], *w, "swap-restored KV wrong at position {p}");
                }
                checked = true;
            }
            guard += 1;
            assert!(guard < 1000, "swap run did not converge");
        }
        assert!(checked, "victim never resumed");
        let victim_tokens: Vec<i32> = events
            .iter()
            .filter_map(|e| match e {
                GenerationEvent::Token { request: 1, id, .. } => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(victim_tokens, (133..=156).collect::<Vec<i32>>());
    }

    /// A deadline keeps ticking while a request sits preempted: the
    /// expiry sweep finishes it out of the preempted queue with its
    /// partial output.
    #[test]
    fn deadline_expires_preempted_request() {
        let mut s = sched_pool(8, SchedulerConfig { max_batch: 8, ..Default::default() });
        let baseline = s.kv_free_blocks();
        s.enqueue(
            Request::builder((100..133).collect())
                .id(1)
                .max_new_tokens(24)
                .deadline(Duration::from_millis(500))
                .build(),
        );
        for _ in 0..6 {
            s.step().unwrap();
        }
        s.enqueue(
            Request::builder((30..79).collect())
                .id(2)
                .max_new_tokens(8)
                .priority(5)
                .build(),
        );
        s.step().unwrap();
        assert_eq!(s.preempted_len(), 1);
        std::thread::sleep(Duration::from_millis(600));
        let done = s.run_to_completion().unwrap();
        let victim = done.iter().find(|c| c.id == 1).expect("victim completion");
        assert_eq!(victim.finish, FinishReason::Deadline);
        // partial output survives preemption: 5 tokens before the cut
        assert_eq!(victim.output_ids, vec![133, 134, 135, 136, 137]);
        assert_eq!(s.metrics.deadline_expired, 1);
        assert_eq!(s.metrics.resumes, 0);
        assert_eq!(s.preempted_len(), 0);
        assert_eq!(s.kv_blocks_in_use(), 0);
        assert_eq!(s.kv_free_blocks(), baseline);
    }

    /// Satellite: a step whose budget is consumed entirely by prefill
    /// runs with an empty decode batch — no Token events, no decode
    /// accounting, and the request survives to decode next step.
    #[test]
    fn all_prefill_step_runs_with_empty_decode_batch() {
        let mut s = sched();
        let prompt: Vec<i32> = (40..40 + 48).collect(); // 3 chunks
        s.enqueue(Request::builder(prompt).id(1).max_new_tokens(2).build());
        let events = s.step().unwrap();
        assert!(
            !events.iter().any(|e| matches!(e, GenerationEvent::Token { .. })),
            "pure-prefill step must emit no tokens"
        );
        assert_eq!(s.metrics.prefill_steps, 1);
        assert_eq!(s.metrics.interleaved_steps, 0);
        assert_eq!(s.active_len(), 1);
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].output_ids, vec![88, 89]);
    }

    /// Satellite: a decode-only step with zero queued prompts plans no
    /// prefill work — exactly one token, no Prefilled event, chunk and
    /// prefill-step counters frozen.
    #[test]
    fn decode_only_step_with_zero_queued_prompts() {
        let mut s = sched();
        s.enqueue(req(1, 50, 5));
        s.step().unwrap(); // prefill + first token
        assert_eq!(s.queued_prompt_tokens(), 0);
        let (chunks, psteps) = (s.metrics.prefill_chunks, s.metrics.prefill_steps);
        let events = s.step().unwrap();
        let tokens = events
            .iter()
            .filter(|e| matches!(e, GenerationEvent::Token { .. }))
            .count();
        assert_eq!(tokens, 1);
        assert!(!events.iter().any(|e| matches!(e, GenerationEvent::Prefilled { .. })));
        assert_eq!(s.metrics.prefill_chunks, chunks);
        assert_eq!(s.metrics.prefill_steps, psteps);
        s.run_to_completion().unwrap();
    }

    // ---- fault tolerance: retry, blame isolation, degradation ----

    use std::sync::Arc;

    use super::faults::{FaultInjector, FaultScript, RetryPolicy};

    /// Scheduler over a fault-injecting mock; backoff shortened so retry
    /// tests stay fast.
    fn faulty_sched(script: FaultScript) -> (Scheduler<MockEngine>, Arc<FaultInjector>) {
        faulty_sched_with(script, RetryPolicy { backoff_ms: 0.1, ..Default::default() })
    }

    fn faulty_sched_with(
        script: FaultScript,
        retry: RetryPolicy,
    ) -> (Scheduler<MockEngine>, Arc<FaultInjector>) {
        let inj = Arc::new(FaultInjector::new(script));
        let s = Scheduler::new(
            MockEngine::new().with_faults(inj.clone()),
            SparsityController::new(Mode::Polar { density: 0.5 }),
            SchedulerConfig { max_batch: 8, retry, ..Default::default() },
        );
        (s, inj)
    }

    fn run_events(s: &mut Scheduler<MockEngine>) -> Vec<GenerationEvent> {
        let mut evs = Vec::new();
        let mut guard = 0;
        while !s.is_idle() {
            evs.extend(s.step().unwrap());
            guard += 1;
            assert!(guard < 10_000, "faulted scheduler did not converge");
        }
        evs
    }

    /// Per-request (index, token) stream — the exactly-once currency.
    fn token_streams(
        evs: &[GenerationEvent],
    ) -> std::collections::BTreeMap<u64, Vec<(usize, i32)>> {
        let mut m = std::collections::BTreeMap::new();
        for ev in evs {
            if let GenerationEvent::Token { request, id, index, .. } = ev {
                m.entry(*request).or_insert_with(Vec::new).push((*index, *id));
            }
        }
        m
    }

    fn completion_by_id(evs: &[GenerationEvent], id: u64) -> &Completion {
        evs.iter()
            .find_map(|e| match e {
                GenerationEvent::Finished(c) if c.id == id => Some(c),
                _ => None,
            })
            .unwrap_or_else(|| panic!("no completion for request {id}"))
    }

    /// Transient engine faults (decode and prefill) retry under backoff
    /// and every request's token stream is exactly-once: identical, with
    /// contiguous indices, to a never-faulting run of the same workload.
    #[test]
    fn transient_faults_retry_with_exactly_once_emission() {
        let script = FaultScript {
            transient_decode_calls: vec![1, 3],
            transient_prefill_calls: vec![0],
            ..Default::default()
        };
        let (mut s, inj) = faulty_sched(script);
        for i in 0..4 {
            s.enqueue(req(i, 30 + 10 * i as i32, 6));
        }
        let evs = run_events(&mut s);
        assert!(inj.injected() >= 3, "script never fired");
        assert!(s.metrics.transient_retries >= 3);
        assert!(s.metrics.backoff_ms > 0.0);
        assert_eq!(s.metrics.blame_bisections, 0, "transients must never bisect");
        assert_eq!(s.metrics.completed_requests, 4);

        let mut b = sched();
        for i in 0..4 {
            b.enqueue(req(i, 30 + 10 * i as i32, 6));
        }
        let bevs = run_events(&mut b);
        assert_eq!(
            token_streams(&evs),
            token_streams(&bevs),
            "retry duplicated or lost a token"
        );
        assert_eq!(s.kv_blocks_in_use(), 0);
    }

    /// A persistently-poisoned request is isolated by the bisection
    /// blame search and finished with `engine_fault`; every other
    /// request's stream is bit-identical to a fault-free run, and the
    /// faulting polar step degraded to dense (with `Degraded` events)
    /// before blame was assigned.
    #[test]
    fn poisoned_request_blamed_others_bit_identical() {
        // request 2's token band [50, 59]: its decode inputs always
        // fault; bands are disjoint so nobody else ever matches
        let script =
            FaultScript { poison_token_range: Some((50, 59)), ..Default::default() };
        let (mut s, _inj) = faulty_sched(script);
        for i in 0..4 {
            s.enqueue(req(i, 30 + 10 * i as i32, 6));
        }
        let evs = run_events(&mut s);
        assert!(s.metrics.blame_bisections >= 1, "no bisection ran");
        assert_eq!(s.metrics.blamed_requests, 1, "exactly one culprit");
        assert!(s.metrics.degraded_steps >= 1, "polar step never degraded");
        assert!(s.sparsity().stats.fallback_steps >= 1);
        assert!(
            evs.iter().any(|e| matches!(e, GenerationEvent::Degraded { .. })),
            "no Degraded event emitted"
        );
        let bad = completion_by_id(&evs, 2);
        assert_eq!(bad.finish, FinishReason::EngineFault);
        // the first token came from (clean) prefill logits; decode never
        // produced another
        assert_eq!(bad.output_ids, vec![51]);

        let mut b = sched();
        for i in 0..4 {
            b.enqueue(req(i, 30 + 10 * i as i32, 6));
        }
        let bevs = run_events(&mut b);
        let faulted = token_streams(&evs);
        let clean = token_streams(&bevs);
        for id in [0u64, 1, 3] {
            assert_eq!(
                faulted.get(&id),
                clean.get(&id),
                "survivor {id} diverged from the fault-free run"
            );
            assert_eq!(completion_by_id(&evs, id).finish, FinishReason::Length);
        }
        // blamed request is not a completion and earns no goodput
        assert_eq!(s.metrics.completed_requests, 3);
        assert_eq!(s.metrics.deadline_met_tokens, 18);
        assert_eq!(s.kv_blocks_in_use(), 0, "blame leaked blocks");
    }

    /// Non-finite logits quarantine only the offending slot: no token is
    /// sampled from the garbage row, the slot finishes `engine_fault`,
    /// and co-resident requests stream on untouched.
    #[test]
    fn nan_logits_quarantine_only_offending_slot() {
        let script =
            FaultScript { nan_token_range: Some((70, 79)), ..Default::default() };
        let (mut s, inj) = faulty_sched(script);
        s.enqueue(req(1, 30, 5));
        s.enqueue(req(2, 70, 5));
        let evs = run_events(&mut s);
        assert!(inj.injected() >= 1, "corruption never fired");
        assert_eq!(s.metrics.quarantined, 1);
        assert_eq!(s.metrics.blame_bisections, 0, "NaN is a logits fault, not a step fault");
        let bad = completion_by_id(&evs, 2);
        assert_eq!(bad.finish, FinishReason::EngineFault);
        assert_eq!(bad.output_ids, vec![71], "prefill token only; no decode token");
        let ok = completion_by_id(&evs, 1);
        assert_eq!(ok.finish, FinishReason::Length);
        assert_eq!(ok.output_ids, vec![31, 32, 33, 34, 35]);
        assert_eq!(s.metrics.completed_requests, 1);
        assert_eq!(s.kv_blocks_in_use(), 0);
    }

    /// Transient pool-allocation failures retry inside admission instead
    /// of failing the step.
    #[test]
    fn pool_alloc_failure_retries_and_admits() {
        let script = FaultScript { pool_alloc_failures: 2, ..Default::default() };
        let (mut s, _inj) = faulty_sched(script);
        s.enqueue(req(1, 30, 3));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].output_ids, vec![31, 32, 33]);
        assert_eq!(done[0].finish, FinishReason::Length);
        assert!(s.metrics.transient_retries >= 2);
    }

    /// An injected stall overruns the watchdog threshold (counted) and
    /// then recovers through the normal transient-retry path.
    #[test]
    fn stalled_step_trips_watchdog_and_recovers() {
        let script = FaultScript {
            stall_decode_calls: vec![0],
            stall: Duration::from_millis(20),
            ..Default::default()
        };
        let (mut s, _inj) = faulty_sched_with(
            script,
            RetryPolicy { watchdog_ms: 5.0, backoff_ms: 0.1, ..Default::default() },
        );
        s.enqueue(req(1, 30, 4));
        let done = s.run_to_completion().unwrap();
        assert_eq!(done[0].output_ids, vec![31, 32, 33, 34]);
        assert!(s.metrics.watchdog_stalls >= 1, "stall never tripped the watchdog");
        assert!(s.metrics.transient_retries >= 1);
    }

    /// stats.faults carries the counters end-to-end.
    #[test]
    fn faults_json_surfaces_injected_run() {
        let script = FaultScript {
            transient_decode_calls: vec![0],
            ..Default::default()
        };
        let (mut s, _inj) = faulty_sched(script);
        s.enqueue(req(1, 30, 3));
        s.run_to_completion().unwrap();
        let j = s.metrics.faults_json();
        assert!(j.get("transient_retries").as_usize().unwrap() >= 1);
        assert!(j.get("backoff_ms").as_f64().unwrap() > 0.0);
        assert_eq!(j.get("blame_bisections").as_usize(), Some(0));
        assert_eq!(j.get("quarantined").as_usize(), Some(0));
    }
}
