//! Token-budget step planner for chunked prefill.
//!
//! Each scheduling step spends a configurable token budget
//! ([`SchedulerConfig::prefill_chunk_tokens`](super::SchedulerConfig),
//! default one chunk bucket) on the prompts of admitted-but-unprefilled
//! slots — urgent-deadline first, then priority, then tightest slack,
//! then oldest — while the decode batch for already-running slots
//! executes in the same step — chunked prefill is what removes the
//! prefill head-of-line blocking the monolithic path suffered.
//!
//! The planner is pure: it sees a snapshot of the prefilling slots and
//! produces the step's engine calls. One call carries **at most one chunk
//! per slot** (the entry takes a single `offset`/`length` pair per slot),
//! so a budget larger than one chunk yields several calls per step — the
//! same slot may advance multiple chunks, and several slots may share one
//! call. A chunk may be cut short by the remaining budget as well as by
//! the prompt end: offsets are not required to be chunk-aligned (the
//! entries' masked per-position writes accept any window).

/// One prefilling slot, as the planner sees it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefillJob {
    pub slot: usize,
    /// Next prompt position to process (tokens `[0, next_pos)` are done —
    /// streamed by earlier chunks OR served from the paged KV prefix
    /// cache, which admits slots with `next_pos` already deep into the
    /// prompt; the planner only ever plans the remainder).
    pub next_pos: usize,
    pub prompt_len: usize,
    /// Admission order (monotonic): lower = older; the final tie-break.
    pub seq: u64,
    /// Request priority: higher values are planned first among
    /// equally-urgent jobs, so a high-priority prompt never queues its
    /// prefill behind a bulk one.
    pub priority: i32,
    /// Seconds until the request's deadline at planning time (None = no
    /// deadline, ordered last among equal priority).
    pub slack: Option<f64>,
    /// Deadline slack no longer covers the remaining work
    /// ([`overload::deadline_slack_urgent`](super::overload) as judged by
    /// the scheduler) — urgent jobs outrank everything else.
    pub urgent: bool,
}

impl PrefillJob {
    pub fn remaining(&self) -> usize {
        self.prompt_len.saturating_sub(self.next_pos)
    }
}

/// One slot's share of one engine call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkAssignment {
    pub slot: usize,
    pub offset: usize,
    pub len: usize,
}

/// Plan one step: the list of engine calls (each a set of per-slot chunk
/// assignments) that spends up to `budget` prompt tokens on `jobs`.
/// Pick order is urgent-deadline first, then priority (descending), then
/// tightest slack, then admission order (`seq`). `budget` and `chunk`
/// are clamped to at least 1, so a step with pending prefill work always
/// makes progress.
pub fn plan_step(
    jobs: &[PrefillJob],
    budget: usize,
    chunk: usize,
) -> Vec<Vec<ChunkAssignment>> {
    let chunk = chunk.max(1);
    let mut budget = budget.max(1);
    let mut jobs: Vec<PrefillJob> = jobs.iter().copied().filter(|j| j.remaining() > 0).collect();
    jobs.sort_by(|a, b| {
        b.urgent
            .cmp(&a.urgent)
            .then_with(|| b.priority.cmp(&a.priority))
            .then_with(|| cmp_slack_tightest_first(a.slack, b.slack))
            .then_with(|| a.seq.cmp(&b.seq))
    });
    let mut calls = Vec::new();
    loop {
        let mut call = Vec::new();
        for j in jobs.iter_mut() {
            if budget == 0 {
                break;
            }
            let len = chunk.min(j.remaining()).min(budget);
            if len == 0 {
                continue;
            }
            call.push(ChunkAssignment { slot: j.slot, offset: j.next_pos, len });
            j.next_pos += len;
            budget -= len;
        }
        if call.is_empty() {
            return calls;
        }
        calls.push(call);
    }
}

fn cmp_slack_tightest_first(a: Option<f64>, b: Option<f64>) -> std::cmp::Ordering {
    use std::cmp::Ordering;
    match (a, b) {
        (None, None) => Ordering::Equal,
        (None, Some(_)) => Ordering::Greater,
        (Some(_), None) => Ordering::Less,
        (Some(x), Some(y)) => x.partial_cmp(&y).unwrap_or(Ordering::Equal),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(slot: usize, next: usize, prompt: usize, seq: u64) -> PrefillJob {
        PrefillJob {
            slot,
            next_pos: next,
            prompt_len: prompt,
            seq,
            priority: 0,
            slack: None,
            urgent: false,
        }
    }

    #[test]
    fn default_budget_serves_one_chunk_of_the_oldest() {
        // seq decides order, not slot index
        let jobs = [job(3, 0, 100, 7), job(1, 32, 200, 2)];
        let calls = plan_step(&jobs, 16, 16);
        assert_eq!(calls.len(), 1);
        assert_eq!(
            calls[0],
            vec![ChunkAssignment { slot: 1, offset: 32, len: 16 }]
        );
    }

    #[test]
    fn budget_spans_slots_within_one_call() {
        // 36 tokens of budget: oldest gets a full chunk (16), the next
        // gets its final partial chunk (4), the third gets the remainder
        let jobs = [job(0, 0, 64, 0), job(1, 12, 16, 1), job(2, 0, 64, 2)];
        let calls = plan_step(&jobs, 36, 16);
        assert_eq!(calls.len(), 1);
        assert_eq!(
            calls[0],
            vec![
                ChunkAssignment { slot: 0, offset: 0, len: 16 },
                ChunkAssignment { slot: 1, offset: 12, len: 4 },
                ChunkAssignment { slot: 2, offset: 0, len: 16 },
            ]
        );
        // a budget tail past every job's one-chunk share rolls into a
        // follow-up call that advances the oldest slot again
        let calls = plan_step(&jobs, 44, 16);
        assert_eq!(calls.len(), 2);
        assert_eq!(
            calls[1],
            vec![ChunkAssignment { slot: 0, offset: 16, len: 8 }]
        );
    }

    #[test]
    fn large_budget_streams_a_whole_prompt_in_one_step() {
        // monolithic A/B: budget = usize::MAX drains the prompt in
        // successive calls within a single step
        let jobs = [job(0, 0, 70, 0)];
        let calls = plan_step(&jobs, usize::MAX, 32);
        assert_eq!(calls.len(), 3);
        let total: usize = calls.iter().flatten().map(|a| a.len).sum();
        assert_eq!(total, 70);
        assert_eq!(calls[2][0], ChunkAssignment { slot: 0, offset: 64, len: 6 });
    }

    #[test]
    fn zero_budget_still_makes_progress() {
        let jobs = [job(0, 5, 40, 0)];
        let calls = plan_step(&jobs, 0, 16);
        assert_eq!(calls, vec![vec![ChunkAssignment { slot: 0, offset: 5, len: 1 }]]);
    }

    #[test]
    fn finished_jobs_are_ignored() {
        let jobs = [job(0, 16, 16, 0), job(1, 0, 8, 1)];
        let calls = plan_step(&jobs, 64, 16);
        assert_eq!(calls, vec![vec![ChunkAssignment { slot: 1, offset: 0, len: 8 }]]);
    }

    #[test]
    fn no_jobs_no_calls() {
        assert!(plan_step(&[], 16, 16).is_empty());
    }

    #[test]
    fn prefix_cached_jobs_plan_only_the_remainder() {
        // a slot admitted with 256 of 272 tokens already in the prefix
        // cache plans one 16-token chunk at offset 256; a fully-cached
        // prompt capped to its last token plans exactly that token
        let jobs = [job(0, 256, 272, 0), job(1, 271, 272, 1)];
        let calls = plan_step(&jobs, 64, 16);
        assert_eq!(calls.len(), 1);
        assert_eq!(
            calls[0],
            vec![
                ChunkAssignment { slot: 0, offset: 256, len: 16 },
                ChunkAssignment { slot: 1, offset: 271, len: 1 },
            ]
        );
    }

    #[test]
    fn priority_outranks_admission_order() {
        // the bulk prompt arrived first (seq 0) but the interactive one
        // (priority 5, seq 1) takes the step's only chunk
        let bulk = job(0, 0, 64, 0);
        let hot = PrefillJob { priority: 5, ..job(1, 0, 64, 1) };
        let calls = plan_step(&[bulk, hot], 16, 16);
        assert_eq!(calls.len(), 1);
        assert_eq!(calls[0], vec![ChunkAssignment { slot: 1, offset: 0, len: 16 }]);
        // equal priority falls back to FCFS by seq
        let calls = plan_step(&[job(0, 0, 64, 0), job(1, 0, 64, 1)], 16, 16);
        assert_eq!(calls[0][0].slot, 0);
    }

    #[test]
    fn tighter_slack_wins_among_equal_priority() {
        let loose = PrefillJob { slack: Some(4.0), ..job(0, 0, 64, 0) };
        let tight = PrefillJob { slack: Some(0.5), ..job(1, 0, 64, 1) };
        let none = job(2, 0, 64, 2);
        let calls = plan_step(&[loose, tight, none], 16, 16);
        assert_eq!(calls[0][0].slot, 1);
        // no-deadline jobs order last among equal priority
        let calls = plan_step(&[none, loose], 32, 16);
        assert_eq!(calls[0][0].slot, 0 /* loose, slot 0 */);
    }

    #[test]
    fn urgent_deadline_outranks_priority() {
        let hot = PrefillJob { priority: 9, ..job(0, 0, 64, 0) };
        let late = PrefillJob { urgent: true, slack: Some(0.05), ..job(1, 0, 64, 1) };
        let calls = plan_step(&[hot, late], 16, 16);
        assert_eq!(calls[0][0].slot, 1);
    }

    #[test]
    fn budget_smaller_than_one_chunk_plans_a_partial_chunk() {
        // a 5-token budget against a 16-token chunk bucket cuts the chunk
        // short rather than stalling or overshooting
        let jobs = [job(0, 0, 64, 0), job(1, 0, 64, 1)];
        let calls = plan_step(&jobs, 5, 16);
        assert_eq!(calls, vec![vec![ChunkAssignment { slot: 0, offset: 0, len: 5 }]]);
    }
}
