//! Iteration-level scheduler: continuous batching over static-shape
//! executables (the CUDA-graph-style constraint, DESIGN.md).
//!
//! Responsibilities per step:
//!   1. expire deadlines, reap finished slots -> terminal events
//!   2. admit pending requests by priority: pick the batch bucket,
//!      batch-prefill the newcomers, splice their KV into the group cache
//!   3. promote the seq bucket when any sequence outgrows it
//!   4. ask the sparsity controller for this step's plan (entry tag +
//!      router-produced `head_idx`/`mlp_idx` tensors) and run one decode
//!      step through it
//!   5. sample next tokens per active slot -> `Token` events
//!
//! `step()` returns the [`GenerationEvent`]s produced this iteration: for
//! every request the stream is `Queued` -> `Prefilled` -> `Token`+ ->
//! `Finished`/`Cancelled`. TTFT and inter-token latency are recorded at
//! the moment each token is emitted, not reconstructed at completion.
//!
//! The group KV cache stays resident on the engine between steps;
//! host-side surgery happens only on composition changes (admission /
//! re-bucketing) and is slot-incremental through a pooled buffer
//! ([`kv::KvPool`]). Batch-bucket *growth* is immediate (a bigger batch
//! cannot run in the current bucket), but *shrinking* waits
//! `shrink_patience` consecutive eligible steps so an admit/finish
//! oscillation around a bucket boundary cannot trigger a full-cache
//! rebuild every step.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::{KvCache, ModelConfig, StepOutput, StepProfile, StepRouting, Tensor};
use crate::tokenizer::{token_byte_len, PAD};

use super::kv;
use super::metrics::EngineMetrics;
use super::request::{Completion, FinishReason, GenerationEvent, Request};
use super::sampler::Sampler;
use super::sparsity::SparsityController;

/// What the scheduler needs from an engine (the real PJRT engine or a mock).
pub trait StepEngine {
    fn config(&self) -> &ModelConfig;
    fn batch_buckets(&self) -> &[usize];
    fn seq_buckets(&self) -> &[usize];
    fn prefill_len(&self) -> usize;
    fn prefill(&self, tokens: &Tensor, lengths: &Tensor) -> Result<StepOutput>;
    /// One decode step. `routing` carries the sparsity controller's
    /// per-step head/MLP index tensors for index-taking entries; engines
    /// whose entries route in-graph (and the dense/dejavu paths) receive
    /// `None` and must ignore it.
    fn decode(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv: KvCache,
        routing: Option<&StepRouting>,
    ) -> Result<StepOutput>;
    /// Cumulative transfer/compute breakdown since the last reset (engines
    /// without instrumentation report zeros).
    fn profile_snapshot(&self) -> StepProfile {
        StepProfile::default()
    }
    fn reset_profile(&self) {}
}

impl StepEngine for crate::runtime::Engine {
    fn config(&self) -> &ModelConfig {
        self.exec.config()
    }
    fn batch_buckets(&self) -> &[usize] {
        &self.exec.manifest().batch_buckets
    }
    fn seq_buckets(&self) -> &[usize] {
        &self.exec.manifest().seq_buckets
    }
    fn prefill_len(&self) -> usize {
        self.exec.manifest().prefill_len
    }
    fn prefill(&self, tokens: &Tensor, lengths: &Tensor) -> Result<StepOutput> {
        crate::runtime::Engine::prefill(self, tokens, lengths)
    }
    fn decode(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv: KvCache,
        routing: Option<&StepRouting>,
    ) -> Result<StepOutput> {
        crate::runtime::Engine::decode(self, tag, tokens, lengths, kv, routing)
    }
    fn profile_snapshot(&self) -> StepProfile {
        self.exec.profile_snapshot()
    }
    fn reset_profile(&self) {
        self.exec.reset_profile()
    }
}

struct Slot {
    req: Request,
    sampler: Sampler,
    /// prompt_len + generated tokens (== attention length of the next step)
    len: usize,
    generated: Vec<i32>,
    /// decoded-text byte length of `generated` (Token event text_offset)
    text_len: usize,
    first_token_at: Option<Instant>,
    /// last token emission (inter-token latency is measured between these)
    last_token_at: Instant,
    finished: Option<FinishReason>,
}

impl Slot {
    fn last_token(&self) -> i32 {
        *self.generated.last().unwrap_or(&PAD)
    }
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Upper bound on the batch bucket (must be one of the buckets).
    pub max_batch: usize,
    /// Shrink the group when occupancy falls below a smaller bucket.
    pub compact: bool,
    /// Consecutive steps a smaller batch bucket must suffice before the
    /// group actually shrinks. 1 = shrink eagerly (the pre-hysteresis
    /// behaviour); higher values absorb admit/finish oscillation around a
    /// bucket boundary, each avoided re-bucket being a full-cache copy.
    pub shrink_patience: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_batch: 16, compact: true, shrink_patience: 8 }
    }
}

pub struct Scheduler<E: StepEngine> {
    engine: E,
    ctl: SparsityController,
    cfg: SchedulerConfig,
    pending: VecDeque<Request>,
    slots: Vec<Option<Slot>>,
    group_kv: Option<KvCache>,
    n_bucket: usize,
    /// Pooled host buffers for composition-change surgery.
    pool: kv::KvPool,
    /// Consecutive steps a shrink has been possible (bucket hysteresis).
    shrink_streak: usize,
    /// Events produced since the last `step()` return (enqueue/cancel also
    /// buffer here so lifecycle events are never lost between steps).
    events: Vec<GenerationEvent>,
    pub metrics: EngineMetrics,
}

impl<E: StepEngine> Scheduler<E> {
    pub fn new(engine: E, ctl: SparsityController, cfg: SchedulerConfig) -> Self {
        let n0 = engine.seq_buckets().first().copied().unwrap_or(64);
        Scheduler {
            engine,
            ctl,
            cfg,
            pending: VecDeque::new(),
            slots: Vec::new(),
            group_kv: None,
            n_bucket: n0,
            pool: kv::KvPool::new(),
            shrink_streak: 0,
            events: Vec::new(),
            metrics: EngineMetrics::default(),
        }
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// The per-step sparsity controller (routing telemetry lives here).
    pub fn sparsity(&self) -> &SparsityController {
        &self.ctl
    }

    /// Combined step breakdown: engine transfers/compute + the
    /// scheduler's host-surgery time.
    pub fn profile(&self) -> StepProfile {
        let mut p = self.engine.profile_snapshot();
        p.merge(&self.metrics.surgery);
        p
    }

    pub fn enqueue(&mut self, req: Request) {
        self.events.push(GenerationEvent::Queued { request: req.id });
        self.pending.push_back(req);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn active_len(&self) -> usize {
        self.slots.iter().flatten().filter(|s| s.finished.is_none()).count()
    }

    pub fn is_idle(&self) -> bool {
        // finished-but-unreaped slots and buffered events still count as
        // work: they must be surfaced by a further step()
        self.pending.is_empty()
            && self.slots.iter().all(|s| s.is_none())
            && self.events.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn n_bucket(&self) -> usize {
        self.n_bucket
    }

    /// Cancel a pending or in-flight request. The slot (and its KV) is
    /// freed immediately; the terminal `Cancelled` event (with any partial
    /// output) is delivered by the next `step()`. Returns false when the
    /// id is unknown (never enqueued, or already finished — including
    /// finished-but-unreaped slots, whose natural `Finished` event is
    /// already owed and must not be rewritten as a cancellation).
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(pos) = self.pending.iter().position(|r| r.id == id) {
            let r = self.pending.remove(pos).unwrap();
            self.finish_unstarted(r, FinishReason::Cancelled);
            return true;
        }
        let found = self.slots.iter().position(|s| {
            s.as_ref().map_or(false, |s| s.req.id == id && s.finished.is_none())
        });
        if let Some(i) = found {
            let s = self.slots[i].take().unwrap();
            self.metrics.cancelled_requests += 1;
            let c = Self::completion_of(&mut self.metrics, s, FinishReason::Cancelled);
            self.events.push(GenerationEvent::Cancelled(c));
            return true;
        }
        false
    }

    fn batch_bucket_for(&self, need: usize) -> usize {
        let capped = need.min(self.cfg.max_batch).max(1);
        self.engine
            .batch_buckets()
            .iter()
            .copied()
            .find(|&b| b >= capped)
            .unwrap_or_else(|| *self.engine.batch_buckets().last().unwrap())
    }

    fn seq_bucket_for(&self, need: usize) -> Result<usize> {
        self.engine
            .seq_buckets()
            .iter()
            .copied()
            .find(|&n| n >= need)
            .with_context(|| format!("sequence length {need} exceeds the largest bucket"))
    }

    /// One scheduling iteration. Returns the generation events it produced
    /// (including any buffered by `enqueue`/`cancel` since the last step).
    pub fn step(&mut self) -> Result<Vec<GenerationEvent>> {
        let t_start = Instant::now();
        self.expire_deadlines();
        self.reap_finished();
        self.admit()?;

        if self.active_len() > 0 {
            self.maybe_promote_seq_bucket()?;
            self.decode_once()?;
            self.reap_finished();
        }
        if self.pending.is_empty() {
            self.maybe_compact()?;
        }
        self.metrics.total_wall_s += t_start.elapsed().as_secs_f64();
        Ok(std::mem::take(&mut self.events))
    }

    /// Drive everything currently enqueued to a terminal event; thin
    /// compatibility wrapper over the event loop.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step()?.into_iter().filter_map(GenerationEvent::completion));
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    /// Build the completion for a reaped slot, recording e2e metrics.
    /// (TTFT was already recorded when the first token was emitted.)
    fn completion_of(metrics: &mut EngineMetrics, s: Slot, finish: FinishReason) -> Completion {
        let now = Instant::now();
        let e2e = now.duration_since(s.req.enqueued_at).as_secs_f64();
        let ttft = s
            .first_token_at
            .map(|t| t.duration_since(s.req.enqueued_at).as_secs_f64())
            .unwrap_or(e2e);
        metrics.e2e.push(e2e);
        let decode_steps = s.generated.len();
        Completion {
            id: s.req.id,
            output_ids: s.generated,
            finish,
            prompt_len: s.req.prompt_ids.len(),
            ttft_s: ttft,
            e2e_s: e2e,
            decode_steps,
        }
    }

    /// Terminal event for a request that never reached a slot.
    fn finish_unstarted(&mut self, r: Request, finish: FinishReason) {
        let e2e = Instant::now().duration_since(r.enqueued_at).as_secs_f64();
        self.metrics.e2e.push(e2e);
        let c = Completion {
            id: r.id,
            output_ids: Vec::new(),
            finish,
            prompt_len: r.prompt_ids.len(),
            ttft_s: e2e,
            e2e_s: e2e,
            decode_steps: 0,
        };
        match finish {
            FinishReason::Cancelled => {
                self.metrics.cancelled_requests += 1;
                self.events.push(GenerationEvent::Cancelled(c));
            }
            _ => {
                if finish == FinishReason::Deadline {
                    self.metrics.deadline_expired += 1;
                }
                self.events.push(GenerationEvent::Finished(c));
            }
        }
    }

    /// Mark expired requests (active and pending) with `Deadline`.
    fn expire_deadlines(&mut self) {
        let now = Instant::now();
        for slot in self.slots.iter_mut() {
            if let Some(s) = slot {
                if s.finished.is_none() {
                    if let Some(d) = s.req.deadline {
                        if now.duration_since(s.req.enqueued_at) >= d {
                            s.finished = Some(FinishReason::Deadline);
                        }
                    }
                }
            }
        }
        // fast path: deadlines are rare, skip the queue rebuild entirely
        if self.pending.iter().all(|r| r.deadline.is_none()) {
            return;
        }
        let mut keep = VecDeque::with_capacity(self.pending.len());
        while let Some(r) = self.pending.pop_front() {
            match r.deadline {
                Some(d) if now.duration_since(r.enqueued_at) >= d => {
                    self.finish_unstarted(r, FinishReason::Deadline);
                }
                _ => keep.push_back(r),
            }
        }
        self.pending = keep;
    }

    fn reap_finished(&mut self) {
        for i in 0..self.slots.len() {
            let fin = self.slots[i].as_ref().and_then(|s| s.finished);
            if let Some(reason) = fin {
                let s = self.slots[i].take().unwrap();
                if reason == FinishReason::Deadline {
                    self.metrics.deadline_expired += 1;
                } else {
                    self.metrics.completed_requests += 1;
                }
                let c = Self::completion_of(&mut self.metrics, s, reason);
                self.events.push(GenerationEvent::Finished(c));
            }
        }
    }

    fn free_slots(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    fn admit(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        // highest priority first; stable sort keeps FIFO among equals
        // (skipped in the common all-equal case)
        let mixed_priorities = self
            .pending
            .iter()
            .zip(self.pending.iter().skip(1))
            .any(|(a, b)| a.priority != b.priority);
        if mixed_priorities {
            self.pending
                .make_contiguous()
                .sort_by_key(|r| std::cmp::Reverse(r.priority));
        }
        let want = self.active_len() + self.pending.len();
        let target = self.batch_bucket_for(want);
        // growth is mandatory (the bigger batch cannot run otherwise);
        // shrinking is maybe_compact's job, behind hysteresis
        if target > self.capacity() {
            self.regroup(target)?;
        } else if target == self.capacity() {
            // demand needed the current bucket this step: a shrink now
            // would be undone immediately, so the streak restarts
            self.shrink_streak = 0;
        }
        let free = self.free_slots();
        let n_new = free.len().min(self.pending.len());
        if n_new == 0 {
            return Ok(());
        }
        let newcomers: Vec<Request> = (0..n_new)
            .map(|_| self.pending.pop_front().unwrap())
            .collect();
        self.prefill_into(&newcomers, &free[..n_new])?;
        Ok(())
    }

    /// Batch-prefill newcomers and splice their KV into the group cache.
    fn prefill_into(&mut self, reqs: &[Request], slots: &[usize]) -> Result<()> {
        let s_len = self.engine.prefill_len();
        let pb = self.batch_bucket_for(reqs.len());
        let mut toks = vec![PAD; pb * s_len];
        let mut lens = vec![1i32; pb];
        for (i, r) in reqs.iter().enumerate() {
            let p = &r.prompt_ids[..r.prompt_ids.len().min(s_len)];
            toks[i * s_len..i * s_len + p.len()].copy_from_slice(p);
            lens[i] = p.len() as i32;
        }
        let t0 = Instant::now();
        let out = self.engine.prefill(
            &Tensor::i32(toks, vec![pb, s_len])?,
            &Tensor::i32(lens.clone(), vec![pb])?,
        )?;
        self.metrics.prefill_latency.push_duration(t0.elapsed());

        // the prefill logits give every newcomer its first token now
        let logits = out.logits.as_f32()?;
        let vocab = self.engine.config().vocab;

        // group cache must exist and cover max(len)+1 positions
        let max_need = reqs
            .iter()
            .map(|r| r.prompt_ids.len().min(s_len) + 1)
            .max()
            .unwrap();
        if self.group_kv.is_none() {
            // fresh group: pick the bucket now; the zeroed cache is
            // acquired directly as the splice target below (no interim
            // literal roundtrip of an all-zeros tensor)
            self.n_bucket = self.seq_bucket_for(max_need.max(self.n_bucket))?;
        } else if max_need > self.n_bucket {
            let n = self.seq_bucket_for(max_need)?;
            self.promote_seq_bucket(n)?;
        }

        // slot-incremental splice: each newcomer's prefill KV is copied
        // straight into its group slot, no per-slot intermediate
        let t_surgery = Instant::now();
        let mut gt = match self.group_kv.take() {
            Some(gkv) => {
                self.note_materialize(&gkv);
                gkv.to_tensor()?
            }
            None => {
                let cfg = self.engine.config().clone();
                self.pool.acquire(cfg.kv_shape(self.capacity(), self.n_bucket))
            }
        };
        let prefill_kv = out.kv.to_tensor()?;
        for (i, r) in reqs.iter().enumerate() {
            let slot_idx = slots[i];
            kv::copy_slot(&mut gt, slot_idx, &prefill_kv, i)?;
            self.metrics.slot_copies += 1;
            let prompt_len = r.prompt_ids.len().min(s_len);
            let row = &logits[i * vocab..(i + 1) * vocab];
            let mut sampler = Sampler::new(r.params, r.id);
            let first = sampler.sample(row);
            let now = Instant::now();
            // TTFT measured at first-token emission, not back-computed
            self.metrics
                .ttft
                .push(now.duration_since(r.enqueued_at).as_secs_f64());
            self.events.push(GenerationEvent::Prefilled { request: r.id });
            self.events.push(GenerationEvent::Token {
                request: r.id,
                id: first,
                index: 0,
                text_offset: 0,
            });
            let mut slot = Slot {
                req: r.clone(),
                sampler,
                len: prompt_len + 1,
                generated: vec![first],
                text_len: token_byte_len(first),
                first_token_at: Some(now),
                last_token_at: now,
                finished: None,
            };
            if first == r.params.stop_token {
                slot.finished = Some(FinishReason::Stop);
            } else if hits_stop_sequence(&slot.generated, &r.stop_sequences) {
                slot.finished = Some(FinishReason::StopSequence);
            } else if r.params.max_new_tokens <= 1 {
                slot.finished = Some(FinishReason::Length);
            }
            self.slots[slot_idx] = Some(slot);
        }
        self.metrics.kv_rebuilds += 1;
        self.group_kv = Some(KvCache::from_tensor(&gt, self.capacity(), self.n_bucket)?);
        self.pool.release(gt);
        self.note_surgery(t_surgery);
        Ok(())
    }

    /// Rebuild the group at a new batch bucket, keeping live slots.
    /// Slot-incremental: only surviving slots are copied, into a pooled
    /// destination buffer.
    fn regroup(&mut self, new_capacity: usize) -> Result<()> {
        let t_surgery = Instant::now();
        let mut new_slots: Vec<Option<Slot>> = (0..new_capacity).map(|_| None).collect();
        if let Some(gkv) = self.group_kv.take() {
            let cfg = self.engine.config().clone();
            let mut dst = self.pool.acquire(cfg.kv_shape(new_capacity, self.n_bucket));
            self.note_materialize(&gkv);
            let gt = gkv.to_tensor()?;
            let mut j = 0;
            for i in 0..self.slots.len() {
                if let Some(s) = self.slots[i].take() {
                    assert!(j < new_capacity, "regroup would drop live slots");
                    kv::copy_slot(&mut dst, j, &gt, i)?;
                    self.metrics.slot_copies += 1;
                    new_slots[j] = Some(s);
                    j += 1;
                }
            }
            self.pool.release(gt);
            self.group_kv = Some(KvCache::from_tensor(&dst, new_capacity, self.n_bucket)?);
            self.pool.release(dst);
            // only an actual full-group copy counts: initial bucket
            // creation (no prior group) moves no KV bytes
            self.metrics.kv_rebuilds += 1;
            self.metrics.regroups += 1;
        }
        // no prior group: stays None — prefill_into acquires the zeroed
        // cache directly as its splice target (no literal roundtrip of an
        // all-zeros tensor)
        self.slots = new_slots;
        self.shrink_streak = 0;
        self.note_surgery(t_surgery);
        Ok(())
    }

    fn maybe_compact(&mut self) -> Result<()> {
        if !self.cfg.compact || self.capacity() == 0 {
            return Ok(());
        }
        // count *occupied* slots (finished-but-unreaped ones still hold a
        // completion that a later step must surface — never drop them)
        let occupied = self.slots.iter().filter(|s| s.is_some()).count();
        if occupied == 0 {
            // drop the group entirely when drained
            self.slots.clear();
            self.group_kv = None;
            self.shrink_streak = 0;
            return Ok(());
        }
        let smaller = self.batch_bucket_for(occupied);
        if smaller < self.capacity() {
            // hysteresis: only shrink after the smaller bucket has been
            // sufficient for `shrink_patience` consecutive steps
            self.shrink_streak += 1;
            if self.shrink_streak >= self.cfg.shrink_patience.max(1) {
                self.regroup(smaller)?;
            }
        } else {
            self.shrink_streak = 0;
        }
        Ok(())
    }

    fn required_n(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .filter(|s| s.finished.is_none())
            .map(|s| s.len)
            .max()
            .unwrap_or(1)
    }

    fn maybe_promote_seq_bucket(&mut self) -> Result<()> {
        let need = self.required_n();
        if need > self.n_bucket {
            let n = self.seq_bucket_for(need)?;
            self.promote_seq_bucket(n)?;
        }
        Ok(())
    }

    /// Grow the position bucket in place: one pooled destination, rows
    /// copied once (no allocate-then-copy churn).
    fn promote_seq_bucket(&mut self, n_new: usize) -> Result<()> {
        let t_surgery = Instant::now();
        let gkv = self.group_kv.take().context("promote without group")?;
        self.note_materialize(&gkv);
        let gt = gkv.to_tensor()?;
        let cfg = self.engine.config().clone();
        // pad_n_into overwrites every destination element, so the pooled
        // buffer is taken without the redundant zero pass
        let mut dst = self.pool.acquire_overwritten(cfg.kv_shape(self.capacity(), n_new));
        kv::pad_n_into(&gt, &mut dst)?;
        self.pool.release(gt);
        self.group_kv = Some(KvCache::from_tensor(&dst, self.capacity(), n_new)?);
        self.pool.release(dst);
        self.n_bucket = n_new;
        self.metrics.bucket_promotions += 1;
        self.note_surgery(t_surgery);
        Ok(())
    }

    /// Account the d2h cost of pulling a resident cache home for surgery.
    fn note_materialize(&mut self, gkv: &KvCache) {
        if gkv.is_resident() {
            let cfg = self.engine.config();
            self.metrics.surgery.d2h_bytes += (cfg.kv_elems(gkv.batch, gkv.n) * 4) as u64;
        }
    }

    fn note_surgery(&mut self, t0: Instant) {
        let ns = t0.elapsed().as_nanos() as u64;
        self.metrics.surgery.host_surgery_ns += ns;
        self.metrics.host_surgery_s += ns as f64 * 1e-9;
        self.metrics.kv_pool_reuses = self.pool.reuses;
        self.metrics.kv_pool_allocs = self.pool.allocs;
    }

    fn decode_once(&mut self) -> Result<()> {
        let b = self.capacity();
        let mut tokens = vec![PAD; b];
        let mut lengths = vec![1i32; b];
        let mut active = vec![false; b];
        for (i, slot) in self.slots.iter().enumerate() {
            if let Some(s) = slot {
                if s.finished.is_none() {
                    tokens[i] = s.last_token();
                    lengths[i] = s.len as i32;
                    active[i] = true;
                }
            }
        }
        let gkv = self.group_kv.take().context("decode without group kv")?;
        // per-step routing: the controller picks the entry and computes
        // the head/MLP index tensors for this batch's hidden state (the
        // mask keeps padding slots out of selection and telemetry)
        let plan = self.ctl.plan(&tokens, &lengths, Some(&active))?;
        if let Some(r) = &plan.routing {
            self.metrics.surgery.router_ns += r.router_ns;
        }
        let t0 = Instant::now();
        let out =
            self.engine
                .decode(&plan.tag, &tokens, &lengths, gkv, plan.routing.as_ref())?;
        let dt = t0.elapsed();
        self.group_kv = Some(out.kv);

        let logits = out.logits.as_f32()?;
        let vocab = self.engine.config().vocab;
        let max_total = *self.engine.seq_buckets().last().unwrap();
        let mut active = 0;
        for (i, slot) in self.slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            if s.finished.is_some() {
                continue;
            }
            active += 1;
            let row = &logits[i * vocab..(i + 1) * vocab];
            let next = s.sampler.sample(row);
            let now = Instant::now();
            // inter-token latency measured between real emissions
            self.metrics
                .itl
                .push(now.duration_since(s.last_token_at).as_secs_f64());
            s.last_token_at = now;
            self.events.push(GenerationEvent::Token {
                request: s.req.id,
                id: next,
                index: s.generated.len(),
                text_offset: s.text_len,
            });
            s.generated.push(next);
            s.text_len += token_byte_len(next);
            s.len += 1;
            if next == s.req.params.stop_token {
                s.finished = Some(FinishReason::Stop);
            } else if hits_stop_sequence(&s.generated, &s.req.stop_sequences) {
                s.finished = Some(FinishReason::StopSequence);
            } else if s.generated.len() >= s.req.params.max_new_tokens {
                s.finished = Some(FinishReason::Length);
            } else if s.len >= max_total {
                s.finished = Some(FinishReason::CacheLimit);
            }
        }
        self.metrics.record_step(dt, active);
        Ok(())
    }
}

/// Does `generated` end with any of the stop sequences?
fn hits_stop_sequence(generated: &[i32], stops: &[Vec<i32>]) -> bool {
    stops.iter().any(|s| !s.is_empty() && generated.ends_with(s))
}
