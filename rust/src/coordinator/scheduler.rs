//! Iteration-level scheduler: continuous batching with chunked prefill
//! over static-shape executables (the CUDA-graph-style constraint,
//! DESIGN.md).
//!
//! Responsibilities per step:
//!   1. expire deadlines, reap finished slots -> terminal events
//!   2. admit pending requests by priority: reject over-long prompts,
//!      pick the batch bucket, assign newcomers to slots in the
//!      `Prefilling` state (no prompt compute yet)
//!   3. spend the step's prefill token budget ([`planner`]) on the oldest
//!      admitted-but-unprefilled prompts: each chunk call appends into
//!      the resident group cache at a per-slot position offset, and the
//!      final chunk's logits yield the request's first token
//!   4. promote the seq bucket when any sequence outgrows it
//!   5. ask the sparsity controller for this step's plan (entry tag +
//!      router-produced `head_idx`/`mlp_idx` tensors) and run one decode
//!      step for the running slots — *in the same step as the prefill
//!      chunks*, so a long prompt's admission never stalls running
//!      decoders for more than one chunk (no prefill head-of-line
//!      blocking)
//!   6. sample next tokens per active slot -> `Token` events
//!
//! `step()` returns the [`GenerationEvent`]s produced this iteration: for
//! every request the stream is `Queued` -> `Prefilled` -> `Token`+ ->
//! `Finished`/`Cancelled`. TTFT and inter-token latency are recorded at
//! the moment each token is emitted, not reconstructed at completion.
//!
//! The group KV cache stays resident on the engine between steps —
//! prefill chunks write into it on-device (masked per-position writes, so
//! co-resident slots are never clobbered), which removes the host-side
//! KV splice the monolithic prefill path paid on every admission.
//! Host-side surgery happens only on composition changes (re-bucketing)
//! and is slot-incremental through a pooled buffer ([`kv::KvPool`]).
//! Batch-bucket *growth* is immediate (a bigger batch cannot run in the
//! current bucket), but *shrinking* waits `shrink_patience` consecutive
//! eligible steps so an admit/finish oscillation around a bucket boundary
//! cannot trigger a full-cache rebuild every step.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::{KvCache, ModelConfig, StepOutput, StepProfile, StepRouting, Tensor};
use crate::substrate::json::Json;
use crate::tokenizer::{token_byte_len, PAD};

use super::kv;
use super::metrics::EngineMetrics;
use super::planner::{self, PrefillJob};
use super::request::{Completion, FinishReason, GenerationEvent, Request};
use super::sampler::Sampler;
use super::sparsity::SparsityController;

/// What the scheduler needs from an engine (the real PJRT engine or a mock).
pub trait StepEngine {
    fn config(&self) -> &ModelConfig;
    fn batch_buckets(&self) -> &[usize];
    fn seq_buckets(&self) -> &[usize];
    /// Token width of one chunked-prefill call.
    fn prefill_chunk_len(&self) -> usize;
    /// Append one prompt chunk per slot into the group cache at per-slot
    /// position offsets. `tokens`: [B*C] row-major (C = chunk width),
    /// `lengths`: valid tokens per slot in this chunk (0 = inactive slot,
    /// cache row untouched), `offset`: absolute start positions. Returns
    /// each slot's logits at its chunk's last position (the first-token
    /// logits when the chunk completes a prompt) plus the updated cache.
    fn prefill_chunk(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        offset: &[i32],
        kv: KvCache,
    ) -> Result<StepOutput>;
    /// One decode step. `routing` carries the sparsity controller's
    /// per-step head/MLP index tensors for index-taking entries; engines
    /// whose entries route in-graph (and the dense/dejavu paths) receive
    /// `None` and must ignore it.
    fn decode(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv: KvCache,
        routing: Option<&StepRouting>,
    ) -> Result<StepOutput>;
    /// Cumulative transfer/compute breakdown since the last reset (engines
    /// without instrumentation report zeros).
    fn profile_snapshot(&self) -> StepProfile {
        StepProfile::default()
    }
    fn reset_profile(&self) {}
}

impl StepEngine for crate::runtime::Engine {
    fn config(&self) -> &ModelConfig {
        self.exec.config()
    }
    fn batch_buckets(&self) -> &[usize] {
        &self.exec.manifest().batch_buckets
    }
    fn seq_buckets(&self) -> &[usize] {
        &self.exec.manifest().seq_buckets
    }
    fn prefill_chunk_len(&self) -> usize {
        crate::runtime::Engine::prefill_chunk_len(self)
    }
    fn prefill_chunk(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        offset: &[i32],
        kv: KvCache,
    ) -> Result<StepOutput> {
        crate::runtime::Engine::prefill_chunk(self, tokens, lengths, offset, kv)
    }
    fn decode(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv: KvCache,
        routing: Option<&StepRouting>,
    ) -> Result<StepOutput> {
        crate::runtime::Engine::decode(self, tag, tokens, lengths, kv, routing)
    }
    fn profile_snapshot(&self) -> StepProfile {
        self.exec.profile_snapshot()
    }
    fn reset_profile(&self) {
        self.exec.reset_profile()
    }
}

/// Where a slot is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotPhase {
    /// Admitted; prompt positions `[0, next_pos)` are in the group cache,
    /// the rest stream in chunk by chunk under the step token budget.
    Prefilling { next_pos: usize },
    /// Prompt fully prefilled and first token emitted; decoding.
    Running,
}

struct Slot {
    req: Request,
    sampler: Sampler,
    phase: SlotPhase,
    /// Admission order (monotonic): the planner serves older slots first.
    seq: u64,
    /// prompt_len + generated tokens (== attention length of the next
    /// step); meaningful once `Running`.
    len: usize,
    generated: Vec<i32>,
    /// decoded-text byte length of `generated` (Token event text_offset)
    text_len: usize,
    first_chunk_at: Option<Instant>,
    last_chunk_at: Option<Instant>,
    first_token_at: Option<Instant>,
    /// last token emission (inter-token latency is measured between these)
    last_token_at: Instant,
    finished: Option<FinishReason>,
}

impl Slot {
    fn last_token(&self) -> i32 {
        *self.generated.last().unwrap_or(&PAD)
    }
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Upper bound on the batch bucket (must be one of the buckets).
    pub max_batch: usize,
    /// Shrink the group when occupancy falls below a smaller bucket.
    pub compact: bool,
    /// Consecutive steps a smaller batch bucket must suffice before the
    /// group actually shrinks. 1 = shrink eagerly (the pre-hysteresis
    /// behaviour); higher values absorb admit/finish oscillation around a
    /// bucket boundary, each avoided re-bucket being a full-cache copy.
    pub shrink_patience: usize,
    /// Prompt tokens one step may spend on prefill chunks (0 = one chunk
    /// bucket, the default). Larger budgets admit prompts faster at the
    /// cost of longer stalls for running decoders; `usize::MAX`
    /// reproduces the old monolithic behaviour (whole prompt in one step)
    /// and is the A/B baseline of `bench prefill-interference`.
    pub prefill_chunk_tokens: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 16,
            compact: true,
            shrink_patience: 8,
            prefill_chunk_tokens: 0,
        }
    }
}

pub struct Scheduler<E: StepEngine> {
    engine: E,
    ctl: SparsityController,
    cfg: SchedulerConfig,
    pending: VecDeque<Request>,
    slots: Vec<Option<Slot>>,
    group_kv: Option<KvCache>,
    n_bucket: usize,
    /// Pooled host buffers for composition-change surgery.
    pool: kv::KvPool,
    /// Consecutive steps a shrink has been possible (bucket hysteresis).
    shrink_streak: usize,
    /// Monotonic admission counter (planner seniority).
    admit_seq: u64,
    /// Events produced since the last `step()` return (enqueue/cancel also
    /// buffer here so lifecycle events are never lost between steps).
    events: Vec<GenerationEvent>,
    pub metrics: EngineMetrics,
}

impl<E: StepEngine> Scheduler<E> {
    pub fn new(engine: E, ctl: SparsityController, cfg: SchedulerConfig) -> Self {
        let n0 = engine.seq_buckets().first().copied().unwrap_or(64);
        Scheduler {
            engine,
            ctl,
            cfg,
            pending: VecDeque::new(),
            slots: Vec::new(),
            group_kv: None,
            n_bucket: n0,
            pool: kv::KvPool::new(),
            shrink_streak: 0,
            admit_seq: 0,
            events: Vec::new(),
            metrics: EngineMetrics::default(),
        }
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// The per-step sparsity controller (routing telemetry lives here).
    pub fn sparsity(&self) -> &SparsityController {
        &self.ctl
    }

    /// Combined step breakdown: engine transfers/compute + the
    /// scheduler's host-surgery time.
    pub fn profile(&self) -> StepProfile {
        let mut p = self.engine.profile_snapshot();
        p.merge(&self.metrics.surgery);
        p
    }

    /// Longest admissible prompt: the largest seq bucket. A prompt of
    /// exactly this length is accepted (its first token comes out of the
    /// prefill logits, then it finishes `CacheLimit`); anything longer is
    /// rejected with `prompt_too_long` instead of being truncated.
    pub fn max_prompt_len(&self) -> usize {
        self.engine.seq_buckets().last().copied().unwrap_or(0)
    }

    pub fn enqueue(&mut self, req: Request) {
        self.events.push(GenerationEvent::Queued { request: req.id });
        self.pending.push_back(req);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Live requests holding a slot (prefilling or decoding).
    pub fn active_len(&self) -> usize {
        self.slots.iter().flatten().filter(|s| s.finished.is_none()).count()
    }

    /// Slots currently in the decode batch (running, unfinished).
    fn decoding_len(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .filter(|s| s.finished.is_none() && s.phase == SlotPhase::Running)
            .count()
    }

    /// Prompt tokens not yet prefilled: queued requests plus the
    /// unprocessed remainder of prefilling slots (stats gauge).
    pub fn queued_prompt_tokens(&self) -> usize {
        let pending: usize = self.pending.iter().map(|r| r.prompt_ids.len()).sum();
        let inflight: usize = self
            .slots
            .iter()
            .flatten()
            .filter(|s| s.finished.is_none())
            .map(|s| match s.phase {
                SlotPhase::Prefilling { next_pos } => {
                    s.req.prompt_ids.len().saturating_sub(next_pos)
                }
                SlotPhase::Running => 0,
            })
            .sum();
        pending + inflight
    }

    /// The server's `stats.prefill` object: chunk counts, interleave
    /// ratio, queue-wait / chunk latency series and the TTFT breakdown.
    pub fn prefill_stats(&self) -> Json {
        self.metrics.prefill_json(self.queued_prompt_tokens())
    }

    pub fn is_idle(&self) -> bool {
        // finished-but-unreaped slots and buffered events still count as
        // work: they must be surfaced by a further step()
        self.pending.is_empty()
            && self.slots.iter().all(|s| s.is_none())
            && self.events.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn n_bucket(&self) -> usize {
        self.n_bucket
    }

    /// Host snapshot of the group KV cache (tests/diagnostics only — on
    /// the hot path the cache stays resident on the engine).
    pub fn kv_snapshot(&self) -> Result<Option<Tensor>> {
        self.group_kv.as_ref().map(|g| g.to_tensor()).transpose()
    }

    /// Cancel a pending or in-flight request. The slot (and its KV) is
    /// freed immediately; the terminal `Cancelled` event (with any partial
    /// output) is delivered by the next `step()`. Returns false when the
    /// id is unknown (never enqueued, or already finished — including
    /// finished-but-unreaped slots, whose natural `Finished` event is
    /// already owed and must not be rewritten as a cancellation).
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(pos) = self.pending.iter().position(|r| r.id == id) {
            let r = self.pending.remove(pos).unwrap();
            self.finish_unstarted(r, FinishReason::Cancelled);
            return true;
        }
        let found = self.slots.iter().position(|s| {
            s.as_ref().map_or(false, |s| s.req.id == id && s.finished.is_none())
        });
        if let Some(i) = found {
            let s = self.slots[i].take().unwrap();
            self.metrics.cancelled_requests += 1;
            let c = Self::completion_of(&mut self.metrics, s, FinishReason::Cancelled);
            self.events.push(GenerationEvent::Cancelled(c));
            return true;
        }
        false
    }

    fn batch_bucket_for(&self, need: usize) -> usize {
        let capped = need.min(self.cfg.max_batch).max(1);
        self.engine
            .batch_buckets()
            .iter()
            .copied()
            .find(|&b| b >= capped)
            .unwrap_or_else(|| *self.engine.batch_buckets().last().unwrap())
    }

    fn seq_bucket_for(&self, need: usize) -> Result<usize> {
        self.engine
            .seq_buckets()
            .iter()
            .copied()
            .find(|&n| n >= need)
            .with_context(|| format!("sequence length {need} exceeds the largest bucket"))
    }

    /// One scheduling iteration. Returns the generation events it produced
    /// (including any buffered by `enqueue`/`cancel` since the last step).
    pub fn step(&mut self) -> Result<Vec<GenerationEvent>> {
        let t_start = Instant::now();
        self.metrics.sched_steps += 1;
        self.expire_deadlines();
        self.reap_finished();
        self.admit()?;

        // prefill chunks and the decode batch share the step: a long
        // prompt streams in budget-sized pieces while running slots keep
        // emitting tokens between its chunks
        let did_prefill = self.run_prefill_chunks()?;
        let mut did_decode = false;
        if self.decoding_len() > 0 {
            self.maybe_promote_seq_bucket()?;
            self.decode_once()?;
            self.reap_finished();
            did_decode = true;
        }
        if did_prefill {
            self.metrics.prefill_steps += 1;
            if did_decode {
                self.metrics.interleaved_steps += 1;
            }
        }
        if self.pending.is_empty() {
            self.maybe_compact()?;
        }
        self.metrics.total_wall_s += t_start.elapsed().as_secs_f64();
        Ok(std::mem::take(&mut self.events))
    }

    /// Drive everything currently enqueued to a terminal event; thin
    /// compatibility wrapper over the event loop.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step()?.into_iter().filter_map(GenerationEvent::completion));
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    /// Build the completion for a reaped slot, recording e2e metrics.
    /// (TTFT was already recorded when the first token was emitted.)
    fn completion_of(metrics: &mut EngineMetrics, s: Slot, finish: FinishReason) -> Completion {
        let now = Instant::now();
        let e2e = now.duration_since(s.req.enqueued_at).as_secs_f64();
        let ttft = s
            .first_token_at
            .map(|t| t.duration_since(s.req.enqueued_at).as_secs_f64())
            .unwrap_or(e2e);
        metrics.e2e.push(e2e);
        let decode_steps = s.generated.len();
        Completion {
            id: s.req.id,
            output_ids: s.generated,
            finish,
            prompt_len: s.req.prompt_ids.len(),
            ttft_s: ttft,
            e2e_s: e2e,
            decode_steps,
        }
    }

    /// Terminal event for a request that never reached a slot.
    fn finish_unstarted(&mut self, r: Request, finish: FinishReason) {
        let e2e = Instant::now().duration_since(r.enqueued_at).as_secs_f64();
        self.metrics.e2e.push(e2e);
        let c = Completion {
            id: r.id,
            output_ids: Vec::new(),
            finish,
            prompt_len: r.prompt_ids.len(),
            ttft_s: e2e,
            e2e_s: e2e,
            decode_steps: 0,
        };
        match finish {
            FinishReason::Cancelled => {
                self.metrics.cancelled_requests += 1;
                self.events.push(GenerationEvent::Cancelled(c));
            }
            _ => {
                if finish == FinishReason::Deadline {
                    self.metrics.deadline_expired += 1;
                }
                if finish == FinishReason::PromptTooLong {
                    self.metrics.rejected_prompts += 1;
                }
                self.events.push(GenerationEvent::Finished(c));
            }
        }
    }

    /// Mark expired requests (active and pending) with `Deadline`.
    fn expire_deadlines(&mut self) {
        let now = Instant::now();
        for slot in self.slots.iter_mut() {
            if let Some(s) = slot {
                if s.finished.is_none() {
                    if let Some(d) = s.req.deadline {
                        if now.duration_since(s.req.enqueued_at) >= d {
                            s.finished = Some(FinishReason::Deadline);
                        }
                    }
                }
            }
        }
        // fast path: deadlines are rare, skip the queue rebuild entirely
        if self.pending.iter().all(|r| r.deadline.is_none()) {
            return;
        }
        let mut keep = VecDeque::with_capacity(self.pending.len());
        while let Some(r) = self.pending.pop_front() {
            match r.deadline {
                Some(d) if now.duration_since(r.enqueued_at) >= d => {
                    self.finish_unstarted(r, FinishReason::Deadline);
                }
                _ => keep.push_back(r),
            }
        }
        self.pending = keep;
    }

    fn reap_finished(&mut self) {
        for i in 0..self.slots.len() {
            let fin = self.slots[i].as_ref().and_then(|s| s.finished);
            if let Some(reason) = fin {
                let s = self.slots[i].take().unwrap();
                if reason == FinishReason::Deadline {
                    self.metrics.deadline_expired += 1;
                } else {
                    self.metrics.completed_requests += 1;
                }
                let c = Self::completion_of(&mut self.metrics, s, reason);
                self.events.push(GenerationEvent::Finished(c));
            }
        }
    }

    fn free_slots(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    fn occupied_len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Admission: reject over-long prompts, grow the batch bucket for
    /// demand, and hand free slots to the highest-priority pending
    /// requests as `Prefilling` slots. No prompt compute happens here —
    /// the step's chunk budget does that work incrementally.
    fn admit(&mut self) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        // structured rejection instead of the old silent truncation: a
        // prompt that cannot fit the largest seq bucket never occupies a
        // slot (the server surfaces the same condition as a protocol
        // error before enqueue; this is the backstop for direct callers)
        let limit = self.max_prompt_len();
        if self
            .pending
            .iter()
            .any(|r| r.prompt_ids.len() > limit || r.prompt_ids.is_empty())
        {
            let mut keep = VecDeque::with_capacity(self.pending.len());
            while let Some(r) = self.pending.pop_front() {
                if r.prompt_ids.len() > limit {
                    self.finish_unstarted(r, FinishReason::PromptTooLong);
                } else if r.prompt_ids.is_empty() {
                    // nothing to condition a first token on: finish with
                    // zero tokens instead of parking a slot that no chunk
                    // could ever complete (the server rejects promptless
                    // requests earlier; this is the direct-caller backstop)
                    self.finish_unstarted(r, FinishReason::Length);
                } else {
                    keep.push_back(r);
                }
            }
            self.pending = keep;
            if self.pending.is_empty() {
                return Ok(());
            }
        }
        // highest priority first; stable sort keeps FIFO among equals
        // (skipped in the common all-equal case)
        let mixed_priorities = self
            .pending
            .iter()
            .zip(self.pending.iter().skip(1))
            .any(|(a, b)| a.priority != b.priority);
        if mixed_priorities {
            self.pending
                .make_contiguous()
                .sort_by_key(|r| std::cmp::Reverse(r.priority));
        }
        let want = self.occupied_len() + self.pending.len();
        let target = self.batch_bucket_for(want);
        // growth is mandatory (the bigger batch cannot run otherwise);
        // shrinking is maybe_compact's job, behind hysteresis
        if target > self.capacity() {
            self.regroup(target)?;
        } else if target == self.capacity() {
            // demand needed the current bucket this step: a shrink now
            // would be undone immediately, so the streak restarts
            self.shrink_streak = 0;
        }
        let free = self.free_slots();
        let n_new = free.len().min(self.pending.len());
        if n_new == 0 {
            return Ok(());
        }
        let newcomers: Vec<Request> = (0..n_new)
            .map(|_| self.pending.pop_front().unwrap())
            .collect();

        // the group cache must exist and cover the longest admitted
        // prompt (+1 for the first generated token; an exactly-filling
        // prompt caps at the bucket and finishes CacheLimit after its
        // first token)
        let max_total = self.max_prompt_len();
        let need = newcomers
            .iter()
            .map(|r| (r.prompt_ids.len() + 1).min(max_total))
            .max()
            .unwrap();
        if self.group_kv.is_none() {
            self.n_bucket = self.seq_bucket_for(need.max(self.n_bucket))?;
            let t_surgery = Instant::now();
            let cfg = self.engine.config().clone();
            let zeroed = self.pool.acquire(cfg.kv_shape(self.capacity(), self.n_bucket));
            self.group_kv =
                Some(KvCache::from_tensor(&zeroed, self.capacity(), self.n_bucket)?);
            self.pool.release(zeroed);
            self.note_surgery(t_surgery);
        } else if need > self.n_bucket {
            let n = self.seq_bucket_for(need)?;
            self.promote_seq_bucket(n)?;
        }

        let now = Instant::now();
        for (r, &slot_idx) in newcomers.into_iter().zip(free.iter()) {
            self.admit_seq += 1;
            let sampler = Sampler::new(r.params, r.id);
            self.slots[slot_idx] = Some(Slot {
                sampler,
                phase: SlotPhase::Prefilling { next_pos: 0 },
                seq: self.admit_seq,
                len: 0,
                generated: Vec::new(),
                text_len: 0,
                first_chunk_at: None,
                last_chunk_at: None,
                first_token_at: None,
                last_token_at: now,
                finished: None,
                req: r,
            });
        }
        Ok(())
    }

    /// Spend this step's token budget on prefill chunks (planner order:
    /// oldest admitted first). Slots whose final chunk lands here sample
    /// their first token from the chunk logits and switch to `Running`.
    /// Returns whether any chunk ran.
    fn run_prefill_chunks(&mut self) -> Result<bool> {
        let chunk = self.engine.prefill_chunk_len().max(1);
        let budget = if self.cfg.prefill_chunk_tokens == 0 {
            chunk
        } else {
            self.cfg.prefill_chunk_tokens
        };
        let jobs: Vec<PrefillJob> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, slot)| {
                let s = slot.as_ref()?;
                if s.finished.is_some() {
                    return None;
                }
                match s.phase {
                    SlotPhase::Prefilling { next_pos } => Some(PrefillJob {
                        slot: i,
                        next_pos,
                        prompt_len: s.req.prompt_ids.len(),
                        seq: s.seq,
                    }),
                    SlotPhase::Running => None,
                }
            })
            .collect();
        if jobs.is_empty() {
            return Ok(false);
        }
        let calls = planner::plan_step(&jobs, budget, chunk);
        if calls.is_empty() {
            return Ok(false);
        }
        let b = self.capacity();
        let vocab = self.engine.config().vocab;
        let max_total = self.max_prompt_len();
        for call in calls {
            let mut toks = vec![PAD; b * chunk];
            let mut lens = vec![0i32; b];
            let mut offs = vec![0i32; b];
            for a in &call {
                let s = self.slots[a.slot].as_ref().unwrap();
                toks[a.slot * chunk..a.slot * chunk + a.len]
                    .copy_from_slice(&s.req.prompt_ids[a.offset..a.offset + a.len]);
                lens[a.slot] = a.len as i32;
                offs[a.slot] = a.offset as i32;
            }
            let gkv = self.group_kv.take().context("prefill without group kv")?;
            let t0 = Instant::now();
            let out = self.engine.prefill_chunk(&toks, &lens, &offs, gkv)?;
            self.group_kv = Some(out.kv);
            self.metrics.prefill_chunk_latency.push_duration(t0.elapsed());
            self.metrics.prefill_chunks += 1;
            self.metrics.prefill_tokens += call.iter().map(|a| a.len as u64).sum::<u64>();
            let logits = out.logits.as_f32()?;
            for a in &call {
                let s = self.slots[a.slot].as_mut().unwrap();
                let now = Instant::now();
                if s.first_chunk_at.is_none() {
                    s.first_chunk_at = Some(t0);
                    self.metrics
                        .prefill_queue_wait
                        .push(t0.duration_since(s.req.enqueued_at).as_secs_f64());
                }
                s.last_chunk_at = Some(now);
                let done = a.offset + a.len;
                if done < s.req.prompt_ids.len() {
                    s.phase = SlotPhase::Prefilling { next_pos: done };
                    continue;
                }
                // prompt complete: this chunk's logits row carries the
                // first-token distribution
                let row = &logits[a.slot * vocab..(a.slot + 1) * vocab];
                let first = s.sampler.sample(row);
                // TTFT measured at first-token emission, not back-computed
                self.metrics
                    .ttft
                    .push(now.duration_since(s.req.enqueued_at).as_secs_f64());
                if let (Some(fc), Some(lc)) = (s.first_chunk_at, s.last_chunk_at) {
                    self.metrics
                        .prefill_chunk_span
                        .push(lc.duration_since(fc).as_secs_f64());
                    self.metrics
                        .prefill_emit_gap
                        .push(now.duration_since(lc).as_secs_f64());
                }
                self.events.push(GenerationEvent::Prefilled { request: s.req.id });
                self.events.push(GenerationEvent::Token {
                    request: s.req.id,
                    id: first,
                    index: 0,
                    text_offset: 0,
                });
                s.phase = SlotPhase::Running;
                s.len = s.req.prompt_ids.len() + 1;
                s.generated.push(first);
                s.text_len = token_byte_len(first);
                s.first_token_at = Some(now);
                s.last_token_at = now;
                if first == s.req.params.stop_token {
                    s.finished = Some(FinishReason::Stop);
                } else if hits_stop_sequence(&s.generated, &s.req.stop_sequences) {
                    s.finished = Some(FinishReason::StopSequence);
                } else if s.req.params.max_new_tokens <= 1 {
                    s.finished = Some(FinishReason::Length);
                } else if s.len > max_total {
                    // prompt filled the largest bucket exactly: the first
                    // token is all the cache can hold
                    s.finished = Some(FinishReason::CacheLimit);
                }
            }
        }
        Ok(true)
    }

    /// Rebuild the group at a new batch bucket, keeping live slots.
    /// Slot-incremental: only surviving slots are copied, into a pooled
    /// destination buffer.
    fn regroup(&mut self, new_capacity: usize) -> Result<()> {
        let t_surgery = Instant::now();
        let mut new_slots: Vec<Option<Slot>> = (0..new_capacity).map(|_| None).collect();
        if let Some(gkv) = self.group_kv.take() {
            let cfg = self.engine.config().clone();
            let mut dst = self.pool.acquire(cfg.kv_shape(new_capacity, self.n_bucket));
            self.note_materialize(&gkv);
            let gt = gkv.to_tensor()?;
            let mut j = 0;
            for i in 0..self.slots.len() {
                if let Some(s) = self.slots[i].take() {
                    assert!(j < new_capacity, "regroup would drop live slots");
                    kv::copy_slot(&mut dst, j, &gt, i)?;
                    self.metrics.slot_copies += 1;
                    new_slots[j] = Some(s);
                    j += 1;
                }
            }
            self.pool.release(gt);
            self.group_kv = Some(KvCache::from_tensor(&dst, new_capacity, self.n_bucket)?);
            self.pool.release(dst);
            // only an actual full-group copy counts: initial bucket
            // creation (no prior group) moves no KV bytes
            self.metrics.kv_rebuilds += 1;
            self.metrics.regroups += 1;
        }
        // no prior group: stays None — admit() acquires the zeroed cache
        // directly (prefill chunks then write into it on-device)
        self.slots = new_slots;
        self.shrink_streak = 0;
        self.note_surgery(t_surgery);
        Ok(())
    }

    fn maybe_compact(&mut self) -> Result<()> {
        if !self.cfg.compact || self.capacity() == 0 {
            return Ok(());
        }
        // count *occupied* slots (finished-but-unreaped ones still hold a
        // completion that a later step must surface — never drop them)
        let occupied = self.occupied_len();
        if occupied == 0 {
            // drop the group entirely when drained
            self.slots.clear();
            self.group_kv = None;
            self.shrink_streak = 0;
            return Ok(());
        }
        let smaller = self.batch_bucket_for(occupied);
        if smaller < self.capacity() {
            // hysteresis: only shrink after the smaller bucket has been
            // sufficient for `shrink_patience` consecutive steps
            self.shrink_streak += 1;
            if self.shrink_streak >= self.cfg.shrink_patience.max(1) {
                self.regroup(smaller)?;
            }
        } else {
            self.shrink_streak = 0;
        }
        Ok(())
    }

    fn required_n(&self) -> usize {
        let max_total = self.max_prompt_len().max(1);
        self.slots
            .iter()
            .flatten()
            .filter(|s| s.finished.is_none())
            .map(|s| match s.phase {
                SlotPhase::Running => s.len,
                // a prefilling slot will need its whole prompt (+1 for
                // the first token, capped at the largest bucket)
                SlotPhase::Prefilling { .. } => {
                    (s.req.prompt_ids.len() + 1).min(max_total)
                }
            })
            .max()
            .unwrap_or(1)
    }

    fn maybe_promote_seq_bucket(&mut self) -> Result<()> {
        let need = self.required_n();
        if need > self.n_bucket {
            let n = self.seq_bucket_for(need)?;
            self.promote_seq_bucket(n)?;
        }
        Ok(())
    }

    /// Grow the position bucket in place: one pooled destination, rows
    /// copied once (no allocate-then-copy churn).
    fn promote_seq_bucket(&mut self, n_new: usize) -> Result<()> {
        let t_surgery = Instant::now();
        let gkv = self.group_kv.take().context("promote without group")?;
        self.note_materialize(&gkv);
        let gt = gkv.to_tensor()?;
        let cfg = self.engine.config().clone();
        // pad_n_into overwrites every destination element, so the pooled
        // buffer is taken without the redundant zero pass
        let mut dst = self.pool.acquire_overwritten(cfg.kv_shape(self.capacity(), n_new));
        kv::pad_n_into(&gt, &mut dst)?;
        self.pool.release(gt);
        self.group_kv = Some(KvCache::from_tensor(&dst, self.capacity(), n_new)?);
        self.pool.release(dst);
        self.n_bucket = n_new;
        self.metrics.bucket_promotions += 1;
        self.note_surgery(t_surgery);
        Ok(())
    }

    /// Account the d2h cost of pulling a resident cache home for surgery.
    fn note_materialize(&mut self, gkv: &KvCache) {
        if gkv.is_resident() {
            let cfg = self.engine.config();
            self.metrics.surgery.d2h_bytes += (cfg.kv_elems(gkv.batch, gkv.n) * 4) as u64;
        }
    }

    fn note_surgery(&mut self, t0: Instant) {
        let ns = t0.elapsed().as_nanos() as u64;
        self.metrics.surgery.host_surgery_ns += ns;
        self.metrics.host_surgery_s += ns as f64 * 1e-9;
        self.metrics.kv_pool_reuses = self.pool.reuses;
        self.metrics.kv_pool_allocs = self.pool.allocs;
    }

    fn decode_once(&mut self) -> Result<()> {
        let b = self.capacity();
        let mut tokens = vec![PAD; b];
        let mut lengths = vec![1i32; b];
        let mut active = vec![false; b];
        for (i, slot) in self.slots.iter().enumerate() {
            if let Some(s) = slot {
                if s.finished.is_some() {
                    continue;
                }
                match s.phase {
                    SlotPhase::Running => {
                        tokens[i] = s.last_token();
                        lengths[i] = s.len as i32;
                        active[i] = true;
                    }
                    SlotPhase::Prefilling { next_pos } => {
                        // a decode entry writes this step's K/V at
                        // lengths-1 for every slot; aim the write at the
                        // slot's next chunk position, which the next
                        // chunk's masked write overwrites — the real
                        // prefix [0, next_pos) stays untouched
                        lengths[i] = (next_pos + 1) as i32;
                    }
                }
            }
        }
        let gkv = self.group_kv.take().context("decode without group kv")?;
        // per-step routing: the controller picks the entry and computes
        // the head/MLP index tensors for this batch's hidden state (the
        // mask keeps padding and prefilling slots out of selection and
        // telemetry)
        let plan = self.ctl.plan(&tokens, &lengths, Some(&active))?;
        if let Some(r) = &plan.routing {
            self.metrics.surgery.router_ns += r.router_ns;
        }
        let t0 = Instant::now();
        let out =
            self.engine
                .decode(&plan.tag, &tokens, &lengths, gkv, plan.routing.as_ref())?;
        let dt = t0.elapsed();
        self.group_kv = Some(out.kv);

        let logits = out.logits.as_f32()?;
        let vocab = self.engine.config().vocab;
        let max_total = self.max_prompt_len();
        let mut active = 0;
        for (i, slot) in self.slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            if s.finished.is_some() || s.phase != SlotPhase::Running {
                continue;
            }
            active += 1;
            let row = &logits[i * vocab..(i + 1) * vocab];
            let next = s.sampler.sample(row);
            let now = Instant::now();
            // inter-token latency measured between real emissions
            self.metrics
                .itl
                .push(now.duration_since(s.last_token_at).as_secs_f64());
            s.last_token_at = now;
            self.events.push(GenerationEvent::Token {
                request: s.req.id,
                id: next,
                index: s.generated.len(),
                text_offset: s.text_len,
            });
            s.generated.push(next);
            s.text_len += token_byte_len(next);
            s.len += 1;
            if next == s.req.params.stop_token {
                s.finished = Some(FinishReason::Stop);
            } else if hits_stop_sequence(&s.generated, &s.req.stop_sequences) {
                s.finished = Some(FinishReason::StopSequence);
            } else if s.generated.len() >= s.req.params.max_new_tokens {
                s.finished = Some(FinishReason::Length);
            } else if s.len >= max_total {
                s.finished = Some(FinishReason::CacheLimit);
            }
        }
        self.metrics.record_step(dt, active);
        Ok(())
    }
}

/// Does `generated` end with any of the stop sequences?
fn hits_stop_sequence(generated: &[i32], stops: &[Vec<i32>]) -> bool {
    stops.iter().any(|s| !s.is_empty() && generated.ends_with(s))
}
