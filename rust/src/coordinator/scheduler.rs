//! Iteration-level scheduler: continuous batching with chunked prefill
//! over static-shape executables (the CUDA-graph-style constraint,
//! DESIGN.md), backed by a **paged KV cache**.
//!
//! KV memory is one engine-resident block pool (`[L,2,P,G,bs,dh]`,
//! allocated once per process) plus a per-request block table managed by
//! [`kv::BlockPool`]. Every composition change the contiguous era paid a
//! full-cache rebuild for — admission, finish, batch-bucket growth and
//! shrink, seq-bucket promotion — now moves **table entries, not cache
//! bytes**: the re-bucket rebuilds, the slot-surgery copies, and the
//! `shrink_patience` hysteresis that existed to suppress rebuild
//! oscillation are all gone. Requests whose prompts share a prefix
//! (system prompts, multi-turn chat) share physical blocks through the
//! pool's hash-keyed prefix cache and skip the already-cached prefill
//! chunks entirely; divergent writes into a shared block are preceded by
//! an engine-side copy-on-write ([`StepEngine::copy_blocks`]).
//!
//! Responsibilities per step:
//!   1. expire deadlines, reap finished slots -> terminal events
//!      (freeing their KV blocks back to the pool immediately)
//!   2. admit pending requests by priority: reject over-long prompts,
//!      grow the slot vector for demand (free — no cache rebuild),
//!      allocate each newcomer's block table (prefix-cache hits skip
//!      whole blocks of prefill), COW the boundary block if the write
//!      window touches shared memory
//!   3. spend the step's prefill token budget ([`planner`]) on the oldest
//!      admitted-but-unprefilled prompts, starting AFTER any cached
//!      prefix: each chunk call writes through the block tables into the
//!      resident pool, and the final chunk's logits yield the request's
//!      first token; freshly-completed full blocks publish into the
//!      prefix cache
//!   4. pick this step's *logical* seq bucket (widest running sequence
//!      rounds up the bucket ladder — a table-width change, not a copy)
//!   5. ask the sparsity controller for this step's plan (entry tag +
//!      router-produced `head_idx`/`mlp_idx` tensors) and run one paged
//!      decode step for the running slots — *in the same step as the
//!      prefill chunks*, so a long prompt's admission never stalls
//!      running decoders for more than one chunk
//!   6. sample next tokens per active slot -> `Token` events; blocks
//!      filled by generation publish too (multi-turn reuse)
//!
//! `step()` returns the [`GenerationEvent`]s produced this iteration: for
//! every request the stream is `Queued` -> `Prefilled` -> `Token`+ ->
//! `Finished`/`Cancelled`. TTFT and inter-token latency are recorded at
//! the moment each token is emitted, not reconstructed at completion.
//!
//! **Overload control** ([`overload`](super::overload)): admission is
//! gated on *predicted KV block demand* (prompt + budgeted new tokens vs
//! the pool's unreserved headroom, tracked by a per-request reservation
//! ledger), not slot availability. Under pressure a strictly
//! higher-ranked arrival preempts the lowest-priority/latest-deadline
//! running victim: the victim's blocks return to the pool, a `Preempted`
//! event is emitted, and it re-queues for resume — recompute-on-resume
//! through the prefix cache, with long victims' complete blocks swapped
//! to host memory and restored instead. A resumed request's token stream
//! is bit-identical to an uninterrupted run (the sampler object and all
//! generated tokens survive preemption; only KV is rebuilt).
//!
//! **Fault tolerance** ([`faults`](super::faults)): engine step calls
//! run under a bounded-retry policy. A failed call first recovers the
//! KV pool ([`StepEngine::recover_kv`] — a fault that loses the pool is
//! fatal), then: transient faults retry with exponential backoff; a
//! persistent fault on a routed (polar/dejavu) step *degrades* it to
//! the dense fallback entries once; a fault that survives degradation
//! triggers a **bisection blame search** that probes batch halves
//! (masked to PAD tokens +
//! null-block table rows) to pin the poisoned request, finishes it with
//! `FinishReason::EngineFault`, and re-runs the step for the survivors
//! — whose token streams stay bit-identical to a fault-free run
//! because probes never touch sampler state and only the final
//! successful call's logits are consumed. Non-finite logits rows
//! quarantine just their slot at the sampling sites. Counters land in
//! `stats.faults` (PROTOCOL.md).

#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::{
    BlockTables, KvCache, ModelConfig, PagedKv, PagedStepOutput, StepOutput, StepProfile,
    StepRouting, Tensor,
};
use crate::substrate::json::Json;
use crate::tokenizer::{token_byte_len, PAD};

use super::faults::{RetryPolicy, StepFault};
use super::kv::{self, BlockTable, MakePrivate};
use super::metrics::EngineMetrics;
use super::overload::{self, HostSwap, OverloadConfig, PressurePolicy, Rank};
use super::planner::{self, PrefillJob};
use super::request::{Completion, FinishReason, GenerationEvent, Request};
use super::sampler::{logits_finite, Sampler};
use super::sparsity::{SparsityController, StepPlan};

/// What the scheduler needs from an engine (the real PJRT engine or a
/// mock). The serving hot path is the paged family; the contiguous
/// `prefill_chunk`/`decode` pair remains the A/B baseline (`bench
/// decode-breakdown`) and the direct-caller path (eval, figures).
pub trait StepEngine {
    fn config(&self) -> &ModelConfig;
    fn batch_buckets(&self) -> &[usize];
    fn seq_buckets(&self) -> &[usize];
    /// Token width of one chunked-prefill call.
    fn prefill_chunk_len(&self) -> usize;
    /// Paged-KV geometry: (token positions per block, pool blocks incl.
    /// the reserved null block 0).
    fn kv_layout(&self) -> (usize, usize);
    /// A fresh zeroed pool at the engine's geometry. The scheduler calls
    /// this once and keeps the pool resident for the process lifetime.
    fn new_kv_pool(&self) -> Result<PagedKv>;
    /// Append one prompt chunk per slot into the pool through the given
    /// block tables at per-slot position offsets. `tokens`: [B*C]
    /// row-major (C = chunk width), `lengths`: valid tokens per slot in
    /// this chunk (0 = inactive slot, no writes), `offset`: absolute
    /// start positions. Returns each slot's logits at its chunk's last
    /// position plus the updated pool.
    fn prefill_chunk_paged(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        offset: &[i32],
        tables: &BlockTables,
        kv: PagedKv,
    ) -> Result<PagedStepOutput>;
    /// One paged decode step. `routing` carries the sparsity
    /// controller's per-step head/MLP index tensors for index-taking
    /// entries; engines whose entries route in-graph receive `None` and
    /// must ignore it.
    fn decode_paged(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        tables: &BlockTables,
        kv: PagedKv,
        routing: Option<&StepRouting>,
    ) -> Result<PagedStepOutput>;
    /// Copy whole physical blocks (src -> dst) inside the pool — the
    /// copy-on-write service behind divergent writes into shared blocks.
    fn copy_blocks(&self, kv: PagedKv, pairs: &[(u32, u32)]) -> Result<PagedKv>;
    /// Contiguous chunked prefill (A/B baseline + direct callers).
    fn prefill_chunk(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        offset: &[i32],
        kv: KvCache,
    ) -> Result<StepOutput>;
    /// Contiguous decode step (A/B baseline + direct callers).
    fn decode(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv: KvCache,
        routing: Option<&StepRouting>,
    ) -> Result<StepOutput>;
    /// Cumulative transfer/compute breakdown since the last reset (engines
    /// without instrumentation report zeros).
    fn profile_snapshot(&self) -> StepProfile {
        StepProfile::default()
    }
    fn reset_profile(&self) {}
    /// Reclaim the KV pool after a failed paged call. The paged entry
    /// points consume the pool by value; an engine that can survive the
    /// fault parks the pool before returning the error and hands it
    /// back here so the scheduler can retry. `None` means the pool is
    /// gone with the failure — the fault is unrecoverable and the
    /// scheduler must propagate it.
    fn recover_kv(&self) -> Option<PagedKv> {
        None
    }
}

impl StepEngine for crate::runtime::Engine {
    fn config(&self) -> &ModelConfig {
        self.exec.config()
    }
    fn batch_buckets(&self) -> &[usize] {
        &self.exec.manifest().batch_buckets
    }
    fn seq_buckets(&self) -> &[usize] {
        &self.exec.manifest().seq_buckets
    }
    fn prefill_chunk_len(&self) -> usize {
        crate::runtime::Engine::prefill_chunk_len(self)
    }
    fn kv_layout(&self) -> (usize, usize) {
        crate::runtime::Engine::kv_layout(self)
    }
    fn new_kv_pool(&self) -> Result<PagedKv> {
        crate::runtime::Engine::new_kv_pool(self)
    }
    fn prefill_chunk_paged(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        offset: &[i32],
        tables: &BlockTables,
        kv: PagedKv,
    ) -> Result<PagedStepOutput> {
        crate::runtime::Engine::prefill_chunk_paged(self, tokens, lengths, offset, tables, kv)
    }
    fn decode_paged(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        tables: &BlockTables,
        kv: PagedKv,
        routing: Option<&StepRouting>,
    ) -> Result<PagedStepOutput> {
        crate::runtime::Engine::decode_paged(self, tag, tokens, lengths, tables, kv, routing)
    }
    fn copy_blocks(&self, kv: PagedKv, pairs: &[(u32, u32)]) -> Result<PagedKv> {
        crate::runtime::Engine::copy_kv_blocks(self, kv, pairs)
    }
    fn prefill_chunk(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        offset: &[i32],
        kv: KvCache,
    ) -> Result<StepOutput> {
        crate::runtime::Engine::prefill_chunk(self, tokens, lengths, offset, kv)
    }
    fn decode(
        &self,
        tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv: KvCache,
        routing: Option<&StepRouting>,
    ) -> Result<StepOutput> {
        crate::runtime::Engine::decode(self, tag, tokens, lengths, kv, routing)
    }
    fn profile_snapshot(&self) -> StepProfile {
        self.exec.profile_snapshot()
    }
    fn reset_profile(&self) {
        self.exec.reset_profile()
    }
    fn recover_kv(&self) -> Option<PagedKv> {
        crate::runtime::Engine::recover_kv(self)
    }
}

/// Where a slot is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotPhase {
    /// Admitted; prompt positions `[0, next_pos)` are in the cache
    /// (cached prefix + streamed chunks), the rest stream in chunk by
    /// chunk under the step token budget.
    Prefilling { next_pos: usize },
    /// Prompt fully prefilled and first token emitted; decoding.
    Running,
    /// Preempted under block pressure: KV blocks freed, waiting in the
    /// preempted queue for re-admission (never present in `slots`).
    Preempted,
    /// Re-admitted after preemption: rebuilding KV over the *virtual
    /// prompt* (prompt + all generated tokens but the last) via prefix
    /// cache hits, swap restore, and recompute chunks; positions
    /// `[0, next_pos)` are back. No tokens are sampled in this phase.
    Resuming { next_pos: usize },
}

struct Slot {
    req: Request,
    sampler: Sampler,
    phase: SlotPhase,
    /// This request's logical-to-physical block mapping.
    table: BlockTable,
    /// Prompt tokens served straight from the prefix cache (never
    /// prefilled here).
    cached_prompt: usize,
    /// Admission order (monotonic): the planner serves older slots first.
    seq: u64,
    /// prompt_len + generated tokens (== attention length of the next
    /// step); meaningful once `Running`.
    len: usize,
    generated: Vec<i32>,
    /// decoded-text byte length of `generated` (Token event text_offset)
    text_len: usize,
    first_chunk_at: Option<Instant>,
    last_chunk_at: Option<Instant>,
    first_token_at: Option<Instant>,
    /// last token emission (inter-token latency is measured between these)
    last_token_at: Instant,
    finished: Option<FinishReason>,
}

impl Slot {
    fn last_token(&self) -> i32 {
        *self.generated.last().unwrap_or(&PAD)
    }

    /// Token stream whose KV is (or is about to be) written: prompt +
    /// everything generated. Used to hash generated blocks into the
    /// prefix cache as they fill.
    fn stream(&self) -> Vec<i32> {
        let mut s = self.req.prompt_ids.clone();
        s.extend_from_slice(&self.generated);
        s
    }

    /// Length of the *virtual prompt* a resume rebuilds: every token
    /// whose KV existed at preemption — the prompt plus all generated
    /// tokens except the last, whose KV the next decode step writes
    /// (exactly as it would have in an uninterrupted run).
    fn virtual_len(&self) -> usize {
        self.req.prompt_ids.len() + self.generated.len().saturating_sub(1)
    }
}

/// Admission/preemption rank of a request at `now`.
fn rank_of(r: &Request, now: Instant) -> Rank {
    Rank { priority: r.priority, slack: slack_of(r, now) }
}

/// Seconds until the deadline (negative = past it; None = no deadline).
fn slack_of(r: &Request, now: Instant) -> Option<f64> {
    r.deadline
        .map(|d| d.as_secs_f64() - now.duration_since(r.enqueued_at).as_secs_f64())
}

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Upper bound on the batch bucket (must be one of the buckets).
    pub max_batch: usize,
    /// Shrink the slot vector when occupancy falls below a smaller
    /// bucket. Batch re-buckets are free under paged KV (tables travel
    /// with their slots; zero cache bytes move), so shrinking is eager —
    /// the contiguous era's `shrink_patience` hysteresis is retired.
    pub compact: bool,
    /// Prompt tokens one step may spend on prefill chunks (0 = one chunk
    /// bucket, the default). Larger budgets admit prompts faster at the
    /// cost of longer stalls for running decoders; `usize::MAX`
    /// reproduces the old monolithic behaviour (whole prompt in one step)
    /// and is the A/B baseline of `bench prefill-interference`.
    pub prefill_chunk_tokens: usize,
    /// Hash-keyed cross-request prefix caching. Off = every request
    /// prefills its whole prompt (the no-sharing baseline `bench
    /// kv-paging` measures against).
    pub prefix_cache: bool,
    /// Overload control: block-demand admission, pressure policy,
    /// preemption, host swap (see [`overload`]).
    pub overload: OverloadConfig,
    /// Fault tolerance: transient-retry budget, backoff curve, and the
    /// step watchdog threshold (see [`faults`](super::faults)).
    pub retry: RetryPolicy,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 16,
            compact: true,
            prefill_chunk_tokens: 0,
            prefix_cache: true,
            overload: OverloadConfig::default(),
            retry: RetryPolicy::default(),
        }
    }
}

pub struct Scheduler<E: StepEngine> {
    engine: E,
    ctl: SparsityController,
    cfg: SchedulerConfig,
    pending: VecDeque<Request>,
    slots: Vec<Option<Slot>>,
    /// The engine-resident block pool (one tensor, process lifetime).
    pool_kv: Option<PagedKv>,
    /// Block allocator: ref counts, free list, prefix cache, COW.
    blocks: kv::BlockPool,
    /// Logical seq bucket the last step ran at (telemetry only — bucket
    /// changes are table-width changes now, not copies).
    logical_n: usize,
    /// Monotonic admission counter (planner seniority).
    admit_seq: u64,
    /// Preempted requests waiting to resume (blocks freed; slot state —
    /// sampler, generated tokens — intact). Re-admitted before pending.
    preempted: VecDeque<Slot>,
    /// Host copies of long preemption victims' full KV blocks, restored
    /// at resume instead of recomputed (keyed by request id).
    swaps: HashMap<u64, HostSwap>,
    /// Events produced since the last `step()` return (enqueue/cancel also
    /// buffer here so lifecycle events are never lost between steps).
    events: Vec<GenerationEvent>,
    pub metrics: EngineMetrics,
}

impl<E: StepEngine> Scheduler<E> {
    pub fn new(engine: E, ctl: SparsityController, cfg: SchedulerConfig) -> Self {
        let (block, pool_blocks) = engine.kv_layout();
        // logical buckets translate to table widths (n / block), so every
        // seq bucket must be block-aligned — a manifest/mock invariant
        assert!(
            engine.seq_buckets().iter().all(|&n| n % block == 0),
            "seq buckets {:?} not divisible by kv block {block}",
            engine.seq_buckets()
        );
        let blocks = kv::BlockPool::new(pool_blocks, block)
            .unwrap_or_else(|e| panic!("kv pool geometry: {e:#}"));
        Scheduler {
            engine,
            ctl,
            cfg,
            pending: VecDeque::new(),
            slots: Vec::new(),
            pool_kv: None,
            blocks,
            logical_n: 0,
            admit_seq: 0,
            preempted: VecDeque::new(),
            swaps: HashMap::new(),
            events: Vec::new(),
            metrics: EngineMetrics::default(),
        }
    }

    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// The per-step sparsity controller (routing telemetry lives here).
    pub fn sparsity(&self) -> &SparsityController {
        &self.ctl
    }

    /// Combined step breakdown: engine transfers/compute + the
    /// scheduler's host-surgery time.
    pub fn profile(&self) -> StepProfile {
        let mut p = self.engine.profile_snapshot();
        p.merge(&self.metrics.surgery);
        p
    }

    /// Longest admissible prompt: the largest seq bucket. A prompt of
    /// exactly this length is accepted (its first token comes out of the
    /// prefill logits, then it finishes `CacheLimit`); anything longer is
    /// rejected with `prompt_too_long` instead of being truncated.
    pub fn max_prompt_len(&self) -> usize {
        self.engine.seq_buckets().last().copied().unwrap_or(0)
    }

    pub fn enqueue(&mut self, req: Request) {
        self.events.push(GenerationEvent::Queued { request: req.id });
        self.pending.push_back(req);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Live requests holding a slot (prefilling or decoding).
    pub fn active_len(&self) -> usize {
        self.slots.iter().flatten().filter(|s| s.finished.is_none()).count()
    }

    /// Slots currently in the decode batch (running, unfinished).
    fn decoding_len(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .filter(|s| s.finished.is_none() && s.phase == SlotPhase::Running)
            .count()
    }

    /// Prompt tokens not yet prefilled: queued requests plus the
    /// unprocessed remainder of prefilling slots (stats gauge).
    pub fn queued_prompt_tokens(&self) -> usize {
        let pending: usize = self.pending.iter().map(|r| r.prompt_ids.len()).sum();
        let inflight: usize = self
            .slots
            .iter()
            .flatten()
            .filter(|s| s.finished.is_none())
            .map(|s| match s.phase {
                SlotPhase::Prefilling { next_pos } => {
                    s.req.prompt_ids.len().saturating_sub(next_pos)
                }
                SlotPhase::Resuming { next_pos } => {
                    s.virtual_len().saturating_sub(next_pos)
                }
                SlotPhase::Running | SlotPhase::Preempted => 0,
            })
            .sum();
        let preempted: usize =
            self.preempted.iter().map(|s| s.virtual_len()).sum();
        pending + inflight + preempted
    }

    /// The server's `stats.prefill` object: chunk counts, interleave
    /// ratio, queue-wait / chunk latency series and the TTFT breakdown.
    pub fn prefill_stats(&self) -> Json {
        self.metrics.prefill_json(self.queued_prompt_tokens())
    }

    /// The server's `stats.kv` object: block-allocator gauges and
    /// prefix-cache / COW counters (the replacement for the retired
    /// rebuild metrics — see PROTOCOL.md).
    pub fn kv_stats(&self) -> Json {
        let s = &self.blocks.stats;
        Json::obj(vec![
            ("block_size", self.blocks.block_size().into()),
            ("pool_blocks", self.blocks.total_blocks().into()),
            ("blocks_in_use", self.blocks.blocks_in_use().into()),
            ("blocks_cached", self.blocks.cached_blocks().into()),
            // disjoint gauges: in_use + cached + free == pool - 1 (null)
            ("blocks_free", self.blocks.free_list_len().into()),
            // free + cached (cached blocks are evictable on demand)
            ("blocks_available", self.blocks.available().into()),
            ("blocks_peak", s.peak_in_use.into()),
            ("utilization", self.blocks.utilization().into()),
            ("prefix_queries", (s.prefix_queries as usize).into()),
            ("prefix_hits", (s.prefix_hits as usize).into()),
            ("prefix_tokens_reused", (s.prefix_tokens_reused as usize).into()),
            (
                "prefill_tokens_saved",
                (self.metrics.prefix_tokens_skipped as usize).into(),
            ),
            ("cow_copies", (s.cow_copies as usize).into()),
            ("evictions", (s.evictions as usize).into()),
            ("block_allocs", (s.block_allocs as usize).into()),
        ])
    }

    /// Allocator gauge used by tests and the disconnect path: blocks
    /// grantable right now (free + evictable cached).
    pub fn kv_free_blocks(&self) -> usize {
        self.blocks.available()
    }

    pub fn kv_blocks_in_use(&self) -> usize {
        self.blocks.blocks_in_use()
    }

    pub fn is_idle(&self) -> bool {
        // finished-but-unreaped slots and buffered events still count as
        // work: they must be surfaced by a further step()
        self.pending.is_empty()
            && self.preempted.is_empty()
            && self.slots.iter().all(|s| s.is_none())
            && self.events.is_empty()
    }

    /// Preempted requests waiting to resume (stats gauge).
    pub fn preempted_len(&self) -> usize {
        self.preempted.len()
    }

    /// The server's `stats.overload` object: preemption/resume/swap
    /// counters, admission rejections, deadline misses, goodput, and the
    /// live reservation/queue gauges (PROTOCOL.md).
    pub fn overload_stats(&self) -> Json {
        let mut j = self.metrics.overload_json();
        j.set("policy", self.cfg.overload.policy_name().into());
        j.set("preempted_queued", self.preempted.len().into());
        j.set("reserved_blocks", self.blocks.reserved_total().into());
        j
    }

    /// The server's `stats.shards` object: per-(layer, shard) dispatch
    /// counters from the shard-aware serving path (routing cuts
    /// `shards_dispatched` and grows `shards_skipped`; skipped attention
    /// shards still ran their KV write) plus the device-local all-reduce
    /// traffic. All zero on unsharded engines.
    pub fn shard_stats(&self) -> Json {
        let p = self.profile();
        Json::obj(vec![
            ("shards_dispatched", (p.shards_dispatched as usize).into()),
            ("shards_skipped", (p.shards_skipped as usize).into()),
            ("allreduce_bytes", (p.allreduce_bytes as usize).into()),
        ])
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// The logical seq bucket the last step decoded at (0 before any).
    pub fn n_bucket(&self) -> usize {
        self.logical_n
    }

    /// Host snapshot of the KV pool (tests/diagnostics only — on the hot
    /// path the pool stays resident on the engine).
    pub fn kv_snapshot(&self) -> Result<Option<Tensor>> {
        self.pool_kv.as_ref().map(|g| g.to_tensor()).transpose()
    }

    /// The physical blocks backing a live request's cache, in logical
    /// order (tests pair this with [`Scheduler::kv_snapshot`] and the
    /// mock's `table_fingerprints`).
    pub fn block_table_of(&self, id: u64) -> Option<Vec<i32>> {
        self.slots.iter().flatten().find(|s| s.req.id == id).map(|s| {
            s.table.blocks.iter().map(|&b| b as i32).collect()
        })
    }

    /// Cancel a pending or in-flight request. The slot — and its KV
    /// blocks — are freed immediately (shared-prefix ref counts
    /// decremented); the terminal `Cancelled` event (with any partial
    /// output) is delivered by the next `step()`. Returns false when the
    /// id is unknown (never enqueued, or already finished — including
    /// finished-but-unreaped slots, whose natural `Finished` event is
    /// already owed and must not be rewritten as a cancellation).
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(r) = self
            .pending
            .iter()
            .position(|r| r.id == id)
            .and_then(|pos| self.pending.remove(pos))
        {
            self.finish_unstarted(r, FinishReason::Cancelled);
            return true;
        }
        // preempted requests hold no slot or blocks, only queue state
        if let Some(s) = self
            .preempted
            .iter()
            .position(|s| s.req.id == id)
            .and_then(|pos| self.preempted.remove(pos))
        {
            self.swaps.remove(&id);
            self.metrics.cancelled_requests += 1;
            let c = Self::completion_of(&mut self.metrics, s, FinishReason::Cancelled);
            self.events.push(GenerationEvent::Cancelled(c));
            return true;
        }
        let found = self.slots.iter().position(|s| {
            s.as_ref().map_or(false, |s| s.req.id == id && s.finished.is_none())
        });
        if let Some(mut s) = found.and_then(|i| self.slots[i].take()) {
            self.blocks.free_table(std::mem::take(&mut s.table));
            self.blocks.release_reservation(id);
            self.swaps.remove(&id);
            self.metrics.cancelled_requests += 1;
            let c = Self::completion_of(&mut self.metrics, s, FinishReason::Cancelled);
            self.events.push(GenerationEvent::Cancelled(c));
            return true;
        }
        false
    }

    fn batch_bucket_for(&self, need: usize) -> usize {
        let capped = need.min(self.cfg.max_batch).max(1);
        self.engine
            .batch_buckets()
            .iter()
            .copied()
            .find(|&b| b >= capped)
            .or_else(|| self.engine.batch_buckets().last().copied())
            .unwrap_or(1)
    }

    fn seq_bucket_for(&self, need: usize) -> Result<usize> {
        self.engine
            .seq_buckets()
            .iter()
            .copied()
            .find(|&n| n >= need)
            .with_context(|| format!("sequence length {need} exceeds the largest bucket"))
    }

    /// One scheduling iteration. Returns the generation events it produced
    /// (including any buffered by `enqueue`/`cancel` since the last step).
    pub fn step(&mut self) -> Result<Vec<GenerationEvent>> {
        let t_start = Instant::now();
        self.metrics.sched_steps += 1;
        self.expire_deadlines();
        self.reap_finished();
        self.admit()?;

        // prefill chunks and the decode batch share the step: a long
        // prompt streams in budget-sized pieces while running slots keep
        // emitting tokens between its chunks
        let did_prefill = self.run_prefill_chunks()?;
        let mut did_decode = false;
        if self.decoding_len() > 0 {
            self.decode_once()?;
            self.reap_finished();
            did_decode = true;
        }
        if did_prefill {
            self.metrics.prefill_steps += 1;
            if did_decode {
                self.metrics.interleaved_steps += 1;
            }
        }
        if self.pending.is_empty() {
            self.maybe_compact();
        }
        self.metrics.total_wall_s += t_start.elapsed().as_secs_f64();
        Ok(std::mem::take(&mut self.events))
    }

    /// Drive everything currently enqueued to a terminal event; thin
    /// compatibility wrapper over the event loop.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step()?.into_iter().filter_map(GenerationEvent::completion));
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    /// Build the completion for a reaped slot, recording e2e metrics.
    /// (TTFT was already recorded when the first token was emitted.)
    fn completion_of(metrics: &mut EngineMetrics, s: Slot, finish: FinishReason) -> Completion {
        let now = Instant::now();
        let e2e = now.duration_since(s.req.enqueued_at).as_secs_f64();
        let ttft = s
            .first_token_at
            .map(|t| t.duration_since(s.req.enqueued_at).as_secs_f64())
            .unwrap_or(e2e);
        metrics.e2e.push(e2e);
        let decode_steps = s.generated.len();
        Completion {
            id: s.req.id,
            output_ids: s.generated,
            finish,
            prompt_len: s.req.prompt_ids.len(),
            cached_prompt_tokens: s.cached_prompt,
            ttft_s: ttft,
            e2e_s: e2e,
            decode_steps,
        }
    }

    /// Terminal event for a request that never reached a slot.
    fn finish_unstarted(&mut self, r: Request, finish: FinishReason) {
        let e2e = Instant::now().duration_since(r.enqueued_at).as_secs_f64();
        self.metrics.e2e.push(e2e);
        let c = Completion {
            id: r.id,
            output_ids: Vec::new(),
            finish,
            prompt_len: r.prompt_ids.len(),
            cached_prompt_tokens: 0,
            ttft_s: e2e,
            e2e_s: e2e,
            decode_steps: 0,
        };
        match finish {
            FinishReason::Cancelled => {
                self.metrics.cancelled_requests += 1;
                self.events.push(GenerationEvent::Cancelled(c));
            }
            _ => {
                if finish == FinishReason::Deadline {
                    self.metrics.deadline_expired += 1;
                }
                if finish == FinishReason::PromptTooLong {
                    self.metrics.rejected_prompts += 1;
                }
                if finish == FinishReason::Rejected {
                    self.metrics.admission_rejections += 1;
                }
                self.events.push(GenerationEvent::Finished(c));
            }
        }
    }

    /// Mark expired requests (active and pending) with `Deadline`.
    fn expire_deadlines(&mut self) {
        let now = Instant::now();
        for slot in self.slots.iter_mut() {
            if let Some(s) = slot {
                if s.finished.is_none() {
                    if let Some(d) = s.req.deadline {
                        if now.duration_since(s.req.enqueued_at) >= d {
                            s.finished = Some(FinishReason::Deadline);
                        }
                    }
                }
            }
        }
        // a preempted request's deadline keeps ticking while it waits
        if self.preempted.iter().any(|s| s.req.deadline.is_some()) {
            let mut keep = VecDeque::with_capacity(self.preempted.len());
            while let Some(s) = self.preempted.pop_front() {
                let expired = s
                    .req
                    .deadline
                    .map_or(false, |d| now.duration_since(s.req.enqueued_at) >= d);
                if expired {
                    self.swaps.remove(&s.req.id);
                    self.metrics.deadline_expired += 1;
                    let c =
                        Self::completion_of(&mut self.metrics, s, FinishReason::Deadline);
                    self.events.push(GenerationEvent::Finished(c));
                } else {
                    keep.push_back(s);
                }
            }
            self.preempted = keep;
        }
        // fast path: deadlines are rare, skip the queue rebuild entirely
        if self.pending.iter().all(|r| r.deadline.is_none()) {
            return;
        }
        let mut keep = VecDeque::with_capacity(self.pending.len());
        while let Some(r) = self.pending.pop_front() {
            match r.deadline {
                Some(d) if now.duration_since(r.enqueued_at) >= d => {
                    self.finish_unstarted(r, FinishReason::Deadline);
                }
                _ => keep.push_back(r),
            }
        }
        self.pending = keep;
    }

    fn reap_finished(&mut self) {
        for i in 0..self.slots.len() {
            let fin = self.slots[i].as_ref().and_then(|s| s.finished);
            if let Some(reason) = fin {
                let Some(mut s) = self.slots[i].take() else { continue };
                // KV blocks return to the pool at the terminal event;
                // published blocks stay in the prefix cache for future
                // requests sharing the prefix
                self.blocks.free_table(std::mem::take(&mut s.table));
                self.blocks.release_reservation(s.req.id);
                self.swaps.remove(&s.req.id);
                if reason == FinishReason::Deadline {
                    self.metrics.deadline_expired += 1;
                } else if reason == FinishReason::EngineFault {
                    // a blamed or quarantined request is not a
                    // completion and earns no goodput; its counters
                    // live in stats.faults
                } else {
                    self.metrics.completed_requests += 1;
                    // goodput: tokens delivered within the SLO (natural
                    // finishes only; deadline misses contribute nothing)
                    self.metrics.deadline_met_tokens += s.generated.len() as u64;
                }
                let c = Self::completion_of(&mut self.metrics, s, reason);
                self.events.push(GenerationEvent::Finished(c));
            }
        }
    }

    fn free_slots(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    fn occupied_len(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Admission: reject over-long prompts, grow the slot vector for
    /// demand (free — tables make batch re-buckets copyless), and hand
    /// free slots to the highest-priority pending requests as
    /// `Prefilling` slots with freshly-allocated block tables. Prompt
    /// prefixes already in the pool's hash cache skip their prefill
    /// chunks entirely; the one block a skip-capped recompute writes
    /// into is copy-on-written if shared.
    fn admit(&mut self) -> Result<()> {
        if self.pending.is_empty() && self.preempted.is_empty() {
            return Ok(());
        }
        // structured rejection instead of the old silent truncation: a
        // prompt that cannot fit the largest seq bucket never occupies a
        // slot (the server surfaces the same condition as a protocol
        // error before enqueue; this is the backstop for direct callers)
        let limit = self.max_prompt_len();
        if self
            .pending
            .iter()
            .any(|r| r.prompt_ids.len() > limit || r.prompt_ids.is_empty())
        {
            let mut keep = VecDeque::with_capacity(self.pending.len());
            while let Some(r) = self.pending.pop_front() {
                if r.prompt_ids.len() > limit {
                    self.finish_unstarted(r, FinishReason::PromptTooLong);
                } else if r.prompt_ids.is_empty() {
                    // nothing to condition a first token on: finish with
                    // zero tokens instead of parking a slot that no chunk
                    // could ever complete (the server rejects promptless
                    // requests earlier; this is the direct-caller backstop)
                    self.finish_unstarted(r, FinishReason::Length);
                } else {
                    keep.push_back(r);
                }
            }
            self.pending = keep;
        }
        // highest priority first; stable sort keeps FIFO among equals
        // (skipped in the common all-equal case)
        let mixed_priorities = self
            .pending
            .iter()
            .zip(self.pending.iter().skip(1))
            .any(|(a, b)| a.priority != b.priority);
        if mixed_priorities {
            self.pending
                .make_contiguous()
                .sort_by_key(|r| std::cmp::Reverse(r.priority));
        }
        let want = self.occupied_len() + self.preempted.len() + self.pending.len();
        if want == 0 {
            return Ok(());
        }
        let target = self.batch_bucket_for(want);
        // growth is a Vec resize now — no cache rebuild, no hysteresis
        if target > self.capacity() {
            self.slots.resize_with(target, || None);
        }
        let free = self.free_slots();
        if free.is_empty() {
            return Ok(());
        }
        // the pool exists from the first admission for the whole process
        // lifetime (its prefix cache outlives every request)
        if self.pool_kv.is_none() {
            let t0 = Instant::now();
            self.pool_kv = Some(self.new_pool_with_retry()?);
            self.note_surgery(t0);
        }

        // resume preempted requests first — they hold queue seniority
        // (and possibly a host swap); highest rank resumes first, and a
        // resume never preempts
        let mut fi = 0;
        if !self.preempted.is_empty() {
            self.preempted.make_contiguous().sort_by(|a, b| {
                b.req.priority.cmp(&a.req.priority).then(a.seq.cmp(&b.seq))
            });
            while fi < free.len() && !self.preempted.is_empty() {
                if !self.try_resume(free[fi])? {
                    break;
                }
                fi += 1;
            }
        }

        let ov = self.cfg.overload;
        let usable = self.blocks.total_blocks().saturating_sub(1);
        let now = Instant::now();
        let mut cow_pairs: Vec<(u32, u32)> = Vec::new();
        while fi < free.len() {
            let slot_idx = free[fi];
            let Some(r) = self.pending.pop_front() else { break };
            let plen = r.prompt_ids.len();
            // demand-gated admission: will the pool cover this request's
            // whole lifetime (prompt + decode budget), net of the blocks
            // already promised to admitted requests? Clamped to the pool
            // size so a request larger than the machine still admits
            // alone and ends `CacheLimit` exactly as before.
            let demand = overload::predicted_blocks(
                plen,
                r.params.max_new_tokens,
                self.blocks.block_size(),
                limit.max(1),
            )
            .min(usable);
            if ov.admission && demand > self.blocks.available_unreserved() {
                // under pressure a strictly higher-ranked arrival evicts
                // the lowest-ranked running victims until it fits
                if ov.preemption {
                    let rank = rank_of(&r, now);
                    while demand > self.blocks.available_unreserved() {
                        if !self.preempt_one(&rank, None) {
                            break;
                        }
                    }
                }
                if demand > self.blocks.available_unreserved() {
                    match ov.on_pressure {
                        PressurePolicy::Reject => {
                            // turn the request away now (load shedding);
                            // the same slot goes to the next candidate
                            self.finish_unstarted(r, FinishReason::Rejected);
                            continue;
                        }
                        PressurePolicy::Defer => {
                            self.pending.push_front(r);
                            break;
                        }
                    }
                }
            }
            // allocate the prompt's block table; prefix-cache hits hand
            // back already-filled physical blocks
            let Some((mut table, cached_raw)) = self.blocks.alloc_prompt(&r.prompt_ids)?
            else {
                // pool exhausted: defer this (and every later) admission —
                // blocks free as running requests finish
                self.pending.push_front(r);
                break;
            };
            // a fully-cached prompt still needs its LAST position's
            // logits to sample the first token: recompute exactly one
            // token. That write may land in a shared cached block — the
            // one genuine copy-on-write in the serving path (the rewrite
            // is bit-identical, but the block must still be private in
            // case generation then extends into it).
            let cached = cached_raw.min(plen.saturating_sub(1));
            if cached < cached_raw || (cached > 0 && cached % self.blocks.block_size() != 0)
            {
                let idx = cached / self.blocks.block_size();
                match self.blocks.make_private(&mut table, idx)? {
                    MakePrivate::Cow { src, dst } => cow_pairs.push((src, dst)),
                    MakePrivate::Private => {}
                    MakePrivate::Exhausted => {
                        self.blocks.free_table(table);
                        self.pending.push_front(r);
                        break;
                    }
                }
            }
            self.metrics.prefix_tokens_skipped += cached as u64;
            self.admit_seq += 1;
            if ov.admission {
                // reserve the unallocated remainder of the predicted
                // demand; shrinks as decode blocks materialize
                self.blocks
                    .set_reservation(r.id, demand.saturating_sub(table.blocks.len()));
            }
            let sampler = Sampler::new(r.params, r.id);
            self.slots[slot_idx] = Some(Slot {
                sampler,
                phase: SlotPhase::Prefilling { next_pos: cached },
                table,
                cached_prompt: cached,
                seq: self.admit_seq,
                len: 0,
                generated: Vec::new(),
                text_len: 0,
                first_chunk_at: None,
                last_chunk_at: None,
                first_token_at: None,
                last_token_at: now,
                finished: None,
                req: r,
            });
            fi += 1;
        }
        if !cow_pairs.is_empty() {
            let t0 = Instant::now();
            let pool = self.pool_kv.take().context("cow without pool")?;
            self.pool_kv = Some(self.engine.copy_blocks(pool, &cow_pairs)?);
            self.note_surgery(t0);
        }
        Ok(())
    }

    /// This step's logical seq bucket: smallest bucket covering every
    /// live sequence (prefilling slots count their whole prompt + first
    /// token). Bucket changes are table-width changes — zero-copy — so
    /// the bucket simply tracks demand each step; growth is counted as a
    /// promotion for continuity with the old telemetry.
    fn logical_bucket(&mut self) -> Result<usize> {
        let n = self.seq_bucket_for(self.required_n())?;
        if self.logical_n != 0 && n > self.logical_n {
            self.metrics.bucket_promotions += 1;
        }
        self.logical_n = n;
        Ok(n)
    }

    fn required_n(&self) -> usize {
        let max_total = self.max_prompt_len().max(1);
        self.slots
            .iter()
            .flatten()
            .filter(|s| s.finished.is_none())
            .map(|s| match s.phase {
                SlotPhase::Running => s.len,
                // a prefilling slot will need its whole prompt (+1 for
                // the first token, capped at the largest bucket)
                SlotPhase::Prefilling { .. } => {
                    (s.req.prompt_ids.len() + 1).min(max_total)
                }
                // a resume rebuilds to its pre-preemption length
                SlotPhase::Resuming { .. } => s.len.min(max_total),
                SlotPhase::Preempted => 1,
            })
            .max()
            .unwrap_or(1)
    }

    /// (slack, urgent) of a slot at `now`: urgent when the deadline
    /// slack no longer covers the remaining decode work at the measured
    /// inter-token cadence.
    fn urgency(&self, s: &Slot, now: Instant) -> (Option<f64>, bool) {
        let slack = slack_of(&s.req, now);
        let urgent = match slack {
            Some(sl) => {
                let itl = self.metrics.itl.mean();
                let remaining = match s.phase {
                    SlotPhase::Running => {
                        s.req.params.max_new_tokens.saturating_sub(s.generated.len())
                    }
                    _ => s.req.params.max_new_tokens,
                };
                itl > 0.0 && overload::deadline_slack_urgent(sl, itl, remaining)
            }
            None => false,
        };
        (slack, urgent)
    }

    /// Per-slot block-table rows at `width` entries (null-padded; empty
    /// slots all-null).
    fn tables_at(&self, width: usize) -> Result<BlockTables> {
        let b = self.capacity();
        let mut flat = Vec::with_capacity(b * width);
        for slot in &self.slots {
            match slot {
                Some(s) => flat.extend(s.table.row(width)),
                None => flat.extend(std::iter::repeat(0).take(width)),
            }
        }
        BlockTables::new(flat, b, width)
    }

    /// Like [`tables_at`](Self::tables_at), but slots with
    /// `include[i] == false` get an all-null row: the blame search masks
    /// suspects out of a probe by aiming their blind per-step K/V write
    /// at the reserved null block, so a probe can never corrupt a
    /// surviving request's cache.
    fn tables_masked(&self, width: usize, include: &[bool]) -> Result<BlockTables> {
        let b = self.capacity();
        let mut flat = Vec::with_capacity(b * width);
        for (i, slot) in self.slots.iter().enumerate() {
            match slot {
                Some(s) if include.get(i).copied().unwrap_or(false) => {
                    flat.extend(s.table.row(width))
                }
                _ => flat.extend(std::iter::repeat(0).take(width)),
            }
        }
        BlockTables::new(flat, b, width)
    }

    /// Spend this step's token budget on prefill chunks (planner order:
    /// oldest admitted first), skipping each slot's cached prefix. Slots
    /// whose final chunk lands here sample their first token from the
    /// chunk logits and switch to `Running`. Returns whether any chunk
    /// ran.
    fn run_prefill_chunks(&mut self) -> Result<bool> {
        let chunk = self.engine.prefill_chunk_len().max(1);
        let budget = if self.cfg.prefill_chunk_tokens == 0 {
            chunk
        } else {
            self.cfg.prefill_chunk_tokens
        };
        let now = Instant::now();
        // deadline enforcement in the budget split: when a running
        // decoder's slack no longer covers its remaining tokens at the
        // measured cadence, cap this step's prefill spend at one chunk
        // so the decode batch keeps its rhythm
        let urgent_decode = self.slots.iter().flatten().any(|s| {
            s.finished.is_none()
                && s.phase == SlotPhase::Running
                && self.urgency(s, now).1
        });
        let budget = if urgent_decode { budget.min(chunk) } else { budget };
        let jobs: Vec<PrefillJob> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, slot)| {
                let s = slot.as_ref()?;
                if s.finished.is_some() {
                    return None;
                }
                let (next_pos, prompt_len) = match s.phase {
                    SlotPhase::Prefilling { next_pos } => {
                        (next_pos, s.req.prompt_ids.len())
                    }
                    // a resume streams the *virtual prompt* (prompt +
                    // generated tokens whose KV was dropped) back in
                    SlotPhase::Resuming { next_pos } => (next_pos, s.virtual_len()),
                    SlotPhase::Running | SlotPhase::Preempted => return None,
                };
                let (slack, urgent) = self.urgency(s, now);
                Some(PrefillJob {
                    slot: i,
                    next_pos,
                    prompt_len,
                    seq: s.seq,
                    priority: s.req.priority,
                    slack,
                    urgent,
                })
            })
            .collect();
        if jobs.is_empty() {
            return Ok(false);
        }
        let calls = planner::plan_step(&jobs, budget, chunk);
        if calls.is_empty() {
            return Ok(false);
        }
        let b = self.capacity();
        let vocab = self.engine.config().vocab;
        let max_total = self.max_prompt_len();
        let bs = self.blocks.block_size();
        let prefix_cache_on = self.cfg.prefix_cache;
        let n = self.logical_bucket()?;
        let tables = self.tables_at(n / bs)?;
        for call in calls {
            let mut toks = vec![PAD; b * chunk];
            let mut lens = vec![0i32; b];
            let mut offs = vec![0i32; b];
            for a in &call {
                let Some(s) = self.slots[a.slot].as_ref() else { continue };
                if matches!(s.phase, SlotPhase::Resuming { .. }) {
                    let stream = s.stream();
                    toks[a.slot * chunk..a.slot * chunk + a.len]
                        .copy_from_slice(&stream[a.offset..a.offset + a.len]);
                } else {
                    toks[a.slot * chunk..a.slot * chunk + a.len]
                        .copy_from_slice(&s.req.prompt_ids[a.offset..a.offset + a.len]);
                }
                lens[a.slot] = a.len as i32;
                offs[a.slot] = a.offset as i32;
            }
            let t0 = Instant::now();
            let out = match self.paged_prefill_with_retry(&toks, &lens, &offs, &tables) {
                Ok(out) => out,
                Err(e) if self.pool_kv.is_none() => {
                    // the failing call also lost the pool: nothing left
                    // to retry against — propagate (server last resort)
                    return Err(e);
                }
                Err(_) => {
                    // persistent prefill failure with the pool intact:
                    // blame every slot in this call (chunk granularity —
                    // prefill has no per-slot probe) instead of taking
                    // the server down; other calls keep streaming
                    for a in &call {
                        if let Some(s) = self.slots[a.slot].as_mut() {
                            if s.finished.is_none() {
                                s.finished = Some(FinishReason::EngineFault);
                                self.metrics.blamed_requests += 1;
                            }
                        }
                    }
                    continue;
                }
            };
            self.metrics.prefill_chunk_latency.push_duration(t0.elapsed());
            self.metrics.prefill_chunks += 1;
            self.metrics.prefill_tokens += call.iter().map(|a| a.len as u64).sum::<u64>();
            let logits = out.logits.as_f32()?;
            for a in &call {
                let Some(s) = self.slots[a.slot].as_mut() else { continue };
                let now = Instant::now();
                if s.first_chunk_at.is_none() {
                    s.first_chunk_at = Some(t0);
                    self.metrics
                        .prefill_queue_wait
                        .push(t0.duration_since(s.req.enqueued_at).as_secs_f64());
                }
                s.last_chunk_at = Some(now);
                let done = a.offset + a.len;
                let resuming = matches!(s.phase, SlotPhase::Resuming { .. });
                // the chunk may have completed whole blocks: publish them
                // into the prefix cache so the NEXT request sharing this
                // prompt skips their compute
                if prefix_cache_on {
                    let stream;
                    let tokens: &[i32] = if resuming {
                        stream = s.stream();
                        &stream[..done]
                    } else {
                        &s.req.prompt_ids[..done]
                    };
                    self.blocks.publish_full_blocks(&mut s.table, tokens);
                }
                let total = if resuming {
                    s.virtual_len()
                } else {
                    s.req.prompt_ids.len()
                };
                if done < total {
                    s.phase = if resuming {
                        SlotPhase::Resuming { next_pos: done }
                    } else {
                        SlotPhase::Prefilling { next_pos: done }
                    };
                    continue;
                }
                if resuming {
                    // virtual prompt rebuilt: rejoin the decode batch
                    // exactly where preemption cut in. Nothing is sampled
                    // here — the next token comes from the next decode
                    // step, conditioned on the same KV an uninterrupted
                    // run would carry, so the stream stays bit-identical.
                    s.phase = SlotPhase::Running;
                    s.last_token_at = now;
                    self.metrics.resumes += 1;
                    continue;
                }
                // prompt complete: this chunk's logits row carries the
                // first-token distribution
                let row = &logits[a.slot * vocab..(a.slot + 1) * vocab];
                if !logits_finite(row) {
                    // quarantine just this slot — a corrupted row never
                    // reaches the sampler or emits a token
                    s.finished = Some(FinishReason::EngineFault);
                    self.metrics.quarantined += 1;
                    continue;
                }
                let first = s.sampler.sample(row);
                // TTFT measured at first-token emission, not back-computed
                self.metrics
                    .ttft
                    .push(now.duration_since(s.req.enqueued_at).as_secs_f64());
                if let (Some(fc), Some(lc)) = (s.first_chunk_at, s.last_chunk_at) {
                    self.metrics
                        .prefill_chunk_span
                        .push(lc.duration_since(fc).as_secs_f64());
                    self.metrics
                        .prefill_emit_gap
                        .push(now.duration_since(lc).as_secs_f64());
                }
                self.events.push(GenerationEvent::Prefilled { request: s.req.id });
                self.events.push(GenerationEvent::Token {
                    request: s.req.id,
                    id: first,
                    index: 0,
                    text_offset: 0,
                });
                s.phase = SlotPhase::Running;
                s.len = s.req.prompt_ids.len() + 1;
                s.generated.push(first);
                s.text_len = token_byte_len(first);
                s.first_token_at = Some(now);
                s.last_token_at = now;
                if first == s.req.params.stop_token {
                    s.finished = Some(FinishReason::Stop);
                } else if hits_stop_sequence(&s.generated, &s.req.stop_sequences) {
                    s.finished = Some(FinishReason::StopSequence);
                } else if s.req.params.max_new_tokens <= 1 {
                    s.finished = Some(FinishReason::Length);
                } else if s.len > max_total {
                    // prompt filled the largest bucket exactly: the first
                    // token is all the cache can hold
                    s.finished = Some(FinishReason::CacheLimit);
                }
            }
        }
        Ok(true)
    }

    /// Shrink the slot vector (and drop it entirely when drained). Both
    /// are free under paged KV: live slots carry their tables with them,
    /// and the pool — with its prefix cache — persists across drains.
    fn maybe_compact(&mut self) {
        if !self.cfg.compact || self.capacity() == 0 {
            return;
        }
        // count *occupied* slots (finished-but-unreaped ones still hold a
        // completion that a later step must surface — never drop them)
        let occupied = self.occupied_len();
        if occupied == 0 {
            self.slots.clear();
            return;
        }
        let smaller = self.batch_bucket_for(occupied);
        if smaller < self.capacity() {
            // stable-compact live slots to the front; zero KV bytes move
            let mut live: Vec<Option<Slot>> =
                self.slots.drain(..).filter(|s| s.is_some()).collect();
            live.resize_with(smaller, || None);
            self.slots = live;
        }
    }

    /// Grow tables so every active slot's next write position is backed
    /// by a block. When the pool cannot serve the append, a strictly
    /// lower-ranked running victim is preempted to free blocks; with no
    /// such victim the growing request finishes `CacheLimit` as before.
    fn ensure_block_capacity(&mut self) {
        let bs = self.blocks.block_size();
        for i in 0..self.slots.len() {
            loop {
                let grown = {
                    let Some(s) = self.slots[i].as_mut() else { break };
                    if s.finished.is_some() || s.phase != SlotPhase::Running {
                        break;
                    }
                    if s.table.capacity(bs) >= s.len {
                        break;
                    }
                    self.blocks.append_block(&mut s.table)
                };
                if grown {
                    continue;
                }
                let Some((rank, id)) = self.slots[i]
                    .as_ref()
                    .map(|s| (rank_of(&s.req, Instant::now()), s.req.id))
                else {
                    break;
                };
                if self.cfg.overload.preemption && self.preempt_one(&rank, Some(id)) {
                    continue;
                }
                // out of physical memory: end this request rather than
                // stall the whole batch
                if let Some(s) = self.slots[i].as_mut() {
                    s.finished = Some(FinishReason::CacheLimit);
                }
                break;
            }
        }
        if self.cfg.overload.admission {
            self.refresh_reservations();
        }
    }

    /// Re-derive every live slot's reservation as predicted demand minus
    /// blocks already held (shrinking toward zero as KV materializes).
    fn refresh_reservations(&mut self) {
        let bs = self.blocks.block_size();
        let limit = self.max_prompt_len().max(1);
        let usable = self.blocks.total_blocks().saturating_sub(1);
        for i in 0..self.slots.len() {
            let Some(s) = self.slots[i].as_ref() else { continue };
            if s.finished.is_some() {
                continue;
            }
            let demand = overload::predicted_blocks(
                s.req.prompt_ids.len(),
                s.req.params.max_new_tokens,
                bs,
                limit,
            )
            .min(usable);
            let held = s.table.blocks.len();
            let id = s.req.id;
            self.blocks.set_reservation(id, demand.saturating_sub(held));
        }
    }

    /// Preempt the lowest-ranked running victim, provided `cand`
    /// strictly outranks it ([`Rank::outranks`] — equality never
    /// preempts, which rules out ping-pong). Returns whether a victim
    /// was evicted.
    fn preempt_one(&mut self, cand: &Rank, exclude: Option<u64>) -> bool {
        let now = Instant::now();
        let mut victims: Vec<((Rank, u64), usize)> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, slot)| {
                let s = slot.as_ref()?;
                if s.finished.is_some() || s.phase != SlotPhase::Running {
                    return None;
                }
                if exclude == Some(s.req.id) {
                    return None;
                }
                Some(((rank_of(&s.req, now), s.seq), i))
            })
            .collect();
        victims.sort_by(|a, b| overload::victim_cmp(&a.0, &b.0));
        let Some(&((vrank, _), idx)) = victims.first() else {
            return false;
        };
        if !cand.outranks(&vrank) {
            return false;
        }
        self.preempt_slot(idx);
        true
    }

    /// Evict the slot at `idx`: free its KV blocks back to the pool
    /// (long victims' complete blocks are copied to host first so the
    /// resume can skip the recompute), emit `Preempted`, and park the
    /// slot — sampler, generated tokens and all — in the resume queue.
    fn preempt_slot(&mut self, idx: usize) {
        let Some(mut s) = self.slots[idx].take() else { return };
        let min = self.cfg.overload.swap_min_blocks;
        let full = s.virtual_len() / self.blocks.block_size();
        if min > 0 && full >= min {
            match self.swap_out(&s, full) {
                Ok(swap) => {
                    self.metrics.swap_out_bytes += swap.bytes() as u64;
                    self.swaps.insert(s.req.id, swap);
                }
                // swap is an optimization: losing it only costs recompute
                Err(_) => {}
            }
        }
        self.blocks.free_table(std::mem::take(&mut s.table));
        self.blocks.release_reservation(s.req.id);
        s.phase = SlotPhase::Preempted;
        self.metrics.preemptions += 1;
        self.events.push(GenerationEvent::Preempted { request: s.req.id });
        self.preempted.push_back(s);
    }

    /// Host copy of a victim's first `full` (complete) blocks.
    fn swap_out(&mut self, s: &Slot, full: usize) -> Result<HostSwap> {
        let pool = self.pool_kv.as_ref().context("swap-out without kv pool")?;
        let t0 = Instant::now();
        let t = pool.to_tensor()?;
        let data = t.as_f32()?;
        let cfg = self.engine.config();
        let row = cfg.n_kv_heads * self.blocks.block_size() * cfg.d_head;
        let pool_blocks = self.blocks.total_blocks();
        let blocks = s.table.blocks[..full]
            .iter()
            .map(|&b| overload::read_block(data, cfg.n_layers, pool_blocks, row, b as usize))
            .collect();
        self.note_surgery(t0);
        Ok(HostSwap { blocks })
    }

    /// Write a swap's saved blocks back into `table`'s freshly-allocated
    /// private blocks, starting at block index `start` (earlier blocks
    /// came back through the prefix cache). Returns the number of token
    /// positions the restore covers.
    fn swap_in(&mut self, swap: &HostSwap, table: &BlockTable, start: usize) -> Result<usize> {
        let full = swap.blocks.len().min(table.blocks.len());
        if start >= full {
            return Ok(0);
        }
        let t0 = Instant::now();
        let pool = self.pool_kv.take().context("swap-in without kv pool")?;
        let mut t = pool.to_tensor()?;
        let bs = self.blocks.block_size();
        let pool_blocks = self.blocks.total_blocks();
        let (layers, row) = {
            let cfg = self.engine.config();
            (cfg.n_layers, cfg.n_kv_heads * bs * cfg.d_head)
        };
        {
            let data = t.as_f32_mut()?;
            for bi in start..full {
                overload::write_block(
                    data,
                    layers,
                    pool_blocks,
                    row,
                    table.blocks[bi] as usize,
                    &swap.blocks[bi],
                );
                self.metrics.swap_in_bytes += (swap.blocks[bi].len() * 4) as u64;
            }
        }
        self.pool_kv = Some(PagedKv::from_tensor(&t, pool_blocks, bs)?);
        self.note_surgery(t0);
        Ok(full * bs)
    }

    /// Try to resume the highest-ranked preempted request into
    /// `slot_idx`. Returns false — leaving the queue untouched — when
    /// the pool cannot host it yet; a resume never preempts. The
    /// rebuilt KV comes from three sources in preference order: prefix
    /// cache hits, the host swap, recompute chunks.
    fn try_resume(&mut self, slot_idx: usize) -> Result<bool> {
        let ov = self.cfg.overload;
        let bs = self.blocks.block_size();
        let limit = self.max_prompt_len().max(1);
        let usable = self.blocks.total_blocks().saturating_sub(1);
        let (demand, virt) = {
            let Some(s) = self.preempted.front() else { return Ok(false) };
            let demand = overload::predicted_blocks(
                s.req.prompt_ids.len(),
                s.req.params.max_new_tokens,
                bs,
                limit,
            )
            .min(usable);
            let mut virt = s.stream();
            virt.truncate(s.virtual_len());
            (demand, virt)
        };
        if ov.admission && demand > self.blocks.available_unreserved() {
            return Ok(false);
        }
        // cached is a whole-block count and a resume samples nothing, so
        // there is no last-token cap and no boundary COW: every
        // recompute/restore write lands in the freshly-allocated tail
        let Some((mut table, cached)) = self.blocks.alloc_prompt(&virt)? else {
            return Ok(false);
        };
        let Some(mut s) = self.preempted.pop_front() else {
            self.blocks.free_table(table);
            return Ok(false);
        };
        let id = s.req.id;
        let mut next_pos = cached;
        if let Some(swap) = self.swaps.remove(&id) {
            // the swap is an optimization: a failed restore must not
            // propagate here — the slot is already off the queue and
            // the table allocated, so an early `?` would leak both the
            // blocks and the request. Fall back to recompute chunks.
            match self.swap_in(&swap, &table, cached / bs) {
                Ok(restored) if restored > next_pos => {
                    next_pos = restored;
                    if self.cfg.prefix_cache {
                        self.blocks.publish_full_blocks(&mut table, &virt[..next_pos]);
                    }
                }
                Ok(_) | Err(_) => {}
            }
        }
        self.metrics.prefix_tokens_skipped += cached as u64;
        s.table = table;
        if next_pos >= virt.len() {
            // everything came back without a single recompute chunk
            s.phase = SlotPhase::Running;
            s.last_token_at = Instant::now();
            self.metrics.resumes += 1;
        } else {
            s.phase = SlotPhase::Resuming { next_pos };
        }
        if ov.admission {
            let held = s.table.blocks.len();
            self.blocks.set_reservation(id, demand.saturating_sub(held));
        }
        self.slots[slot_idx] = Some(s);
        Ok(true)
    }

    fn note_surgery(&mut self, t0: Instant) {
        let ns = t0.elapsed().as_nanos() as u64;
        self.metrics.surgery.host_surgery_ns += ns;
        self.metrics.host_surgery_s += ns as f64 * 1e-9;
    }

    /// Sleep out one step of the exponential backoff curve and account
    /// for it in `stats.faults`.
    fn backoff_sleep(&mut self, attempt: u32) {
        let d = self.cfg.retry.backoff(attempt);
        std::thread::sleep(d);
        self.metrics.transient_retries += 1;
        self.metrics.backoff_ms += d.as_secs_f64() * 1e3;
    }

    /// Step watchdog: an engine call that overran the configured stall
    /// threshold is counted (the result itself is never discarded — a
    /// slow success is still a success).
    fn note_watchdog(&mut self, t0: Instant) {
        if t0.elapsed().as_secs_f64() * 1e3 > self.cfg.retry.watchdog_ms {
            self.metrics.watchdog_stalls += 1;
        }
    }

    /// Allocate the process-lifetime KV pool, retrying transient
    /// allocation failures under the backoff policy. Unlike step faults
    /// there is no pool to recover here — exhausting the budget is
    /// fatal to admission (and surfaces as a step error).
    fn new_pool_with_retry(&mut self) -> Result<PagedKv> {
        let mut attempt = 0u32;
        loop {
            match self.engine.new_kv_pool() {
                Ok(kv) => return Ok(kv),
                Err(e) => {
                    let transient = StepFault::classify(&e).unwrap_or(true);
                    if !transient || attempt >= self.cfg.retry.max_retries {
                        return Err(e.context("allocating the kv pool"));
                    }
                    self.backoff_sleep(attempt);
                    attempt += 1;
                }
            }
        }
    }

    /// One prefill-chunk call under the retry policy: every failure
    /// first reclaims the pool via [`StepEngine::recover_kv`] (a lost
    /// pool is fatal), transient faults back off and retry. On give-up
    /// the pool is back in `self.pool_kv` iff recovery succeeded — the
    /// caller distinguishes the two by checking it.
    fn paged_prefill_with_retry(
        &mut self,
        toks: &[i32],
        lens: &[i32],
        offs: &[i32],
        tables: &BlockTables,
    ) -> Result<PagedStepOutput> {
        let mut attempt = 0u32;
        loop {
            let pool = self.pool_kv.take().context("prefill without kv pool")?;
            let t0 = Instant::now();
            let r = self.engine.prefill_chunk_paged(toks, lens, offs, tables, pool);
            self.note_watchdog(t0);
            match r {
                Ok(out) => return Ok(out),
                Err(e) => {
                    match self.engine.recover_kv() {
                        Some(kv) => self.pool_kv = Some(kv),
                        None => {
                            return Err(e.context(
                                "prefill chunk failed and lost the kv pool (unrecoverable)",
                            ))
                        }
                    }
                    let transient = StepFault::classify(&e).unwrap_or(true);
                    if !transient || attempt >= self.cfg.retry.max_retries {
                        return Err(e.context("prefill chunk failed after retries"));
                    }
                    self.backoff_sleep(attempt);
                    attempt += 1;
                }
            }
        }
    }

    /// One blame probe: re-run the failing decode step with only
    /// `subset` of the active slots unmasked (everyone else active gets
    /// a PAD token, length 1, and a null-block table row). Probe logits
    /// are discarded and sampler state is never touched, so probes are
    /// invisible in the surviving requests' token streams. Returns
    /// whether the fault reproduced.
    fn probe_fails(
        &mut self,
        plan: &StepPlan,
        toks: &[i32],
        lens: &[i32],
        width: usize,
        subset: &[usize],
        active: &[bool],
    ) -> Result<bool> {
        let mut ptoks = toks.to_vec();
        let mut plens = lens.to_vec();
        let mut include = vec![true; active.len()];
        for i in 0..active.len() {
            if active[i] && !subset.contains(&i) {
                ptoks[i] = PAD;
                plens[i] = 1;
                include[i] = false;
            }
        }
        let tables = self.tables_masked(width, &include)?;
        let pool = self.pool_kv.take().context("blame probe without kv pool")?;
        match self.engine.decode_paged(
            &plan.tag,
            &ptoks,
            &plens,
            &tables,
            pool,
            plan.routing.as_ref(),
        ) {
            Ok(out) => {
                self.pool_kv = Some(out.kv);
                Ok(false)
            }
            Err(e) => match self.engine.recover_kv() {
                Some(kv) => {
                    self.pool_kv = Some(kv);
                    Ok(true)
                }
                None => Err(e.context("blame probe lost the kv pool (unrecoverable)")),
            },
        }
    }

    /// The decode step failed persistently: bisection blame search.
    /// Halve the active set, probing each half until a single slot
    /// reproduces the fault; finish it with `FinishReason::EngineFault`
    /// and re-run the step for the survivors. A second failure of the
    /// survivor run means another culprit — bisect again over the
    /// remainder. Returns the survivors' successful step output, whose
    /// logits are the only ones ever sampled — so every non-blamed
    /// request's token stream is bit-identical to a fault-free run.
    fn bisect_blame(
        &mut self,
        plan: &StepPlan,
        tokens: &[i32],
        lengths: &[i32],
        width: usize,
        active: &[bool],
    ) -> Result<PagedStepOutput> {
        self.metrics.blame_bisections += 1;
        let mut toks = tokens.to_vec();
        let mut lens = lengths.to_vec();
        let mut live: Vec<usize> = (0..active.len()).filter(|&i| active[i]).collect();
        loop {
            // pin one culprit: the invariant is that the fault
            // reproduces on `suspects`; a clean first-half probe moves
            // the blame to the second half
            let mut suspects = live.clone();
            while suspects.len() > 1 {
                let half = suspects[..suspects.len() / 2].to_vec();
                if self.probe_fails(plan, &toks, &lens, width, &half, active)? {
                    suspects = half;
                } else {
                    suspects.retain(|i| !half.contains(i));
                }
            }
            let Some(&bad) = suspects.first() else {
                bail!("blame search over an empty active set");
            };
            if let Some(s) = self.slots[bad].as_mut() {
                s.finished = Some(FinishReason::EngineFault);
            }
            self.metrics.blamed_requests += 1;
            toks[bad] = PAD;
            lens[bad] = 1;
            live.retain(|&i| i != bad);
            let mut include = vec![true; active.len()];
            for (i, inc) in include.iter_mut().enumerate() {
                if active[i] && !live.contains(&i) {
                    *inc = false;
                }
            }
            let tables = self.tables_masked(width, &include)?;
            let pool = self.pool_kv.take().context("decode without kv pool")?;
            match self.engine.decode_paged(
                &plan.tag,
                &toks,
                &lens,
                &tables,
                pool,
                plan.routing.as_ref(),
            ) {
                Ok(out) => return Ok(out),
                Err(e) => {
                    match self.engine.recover_kv() {
                        Some(kv) => self.pool_kv = Some(kv),
                        None => {
                            return Err(
                                e.context("blame re-run lost the kv pool (unrecoverable)")
                            )
                        }
                    }
                    if live.is_empty() {
                        return Err(e.context(
                            "engine still failing with every active slot masked",
                        ));
                    }
                }
            }
        }
    }

    fn decode_once(&mut self) -> Result<()> {
        self.ensure_block_capacity();
        self.reap_finished();
        if self.decoding_len() == 0 {
            return Ok(());
        }
        let b = self.capacity();
        let mut tokens = vec![PAD; b];
        let mut lengths = vec![1i32; b];
        let mut active = vec![false; b];
        for (i, slot) in self.slots.iter().enumerate() {
            if let Some(s) = slot {
                if s.finished.is_some() {
                    continue;
                }
                match s.phase {
                    SlotPhase::Running => {
                        tokens[i] = s.last_token();
                        lengths[i] = s.len as i32;
                        active[i] = true;
                    }
                    SlotPhase::Prefilling { next_pos }
                    | SlotPhase::Resuming { next_pos } => {
                        // a decode entry writes this step's K/V at
                        // lengths-1 for every slot; aim the write at the
                        // slot's next chunk position — inside its own
                        // private blocks, the next chunk's write
                        // overwrites it — the real prefix [0, next_pos)
                        // stays untouched
                        lengths[i] = (next_pos + 1) as i32;
                    }
                    SlotPhase::Preempted => {}
                }
            }
        }
        let bs = self.blocks.block_size();
        let n = self.logical_bucket()?;
        let width = n / bs;
        let tables = self.tables_at(width)?;
        // per-step routing: the controller picks the entry and computes
        // the head/MLP index tensors for this batch's hidden state (the
        // mask keeps padding and prefilling slots out of selection and
        // telemetry). Planned ONCE — retries of the same step reuse it
        // (or its dense degradation) so controller telemetry counts
        // steps, not attempts.
        let mut plan = self.ctl.plan(&tokens, &lengths, Some(&active))?;
        if let Some(r) = &plan.routing {
            self.metrics.surgery.router_ns += r.router_ns;
        }
        let t_step = Instant::now();
        let mut attempt = 0u32;
        let mut degraded = false;
        let out = loop {
            let pool = self.pool_kv.take().context("decode without kv pool")?;
            let t_call = Instant::now();
            let r = self.engine.decode_paged(
                &plan.tag,
                &tokens,
                &lengths,
                &tables,
                pool,
                plan.routing.as_ref(),
            );
            self.note_watchdog(t_call);
            match r {
                Ok(out) => break out,
                Err(e) => {
                    match self.engine.recover_kv() {
                        Some(kv) => self.pool_kv = Some(kv),
                        None => {
                            return Err(e.context(
                                "decode step failed and lost the kv pool (unrecoverable)",
                            ))
                        }
                    }
                    let transient = StepFault::classify(&e).unwrap_or(true);
                    if transient && attempt < self.cfg.retry.max_retries {
                        self.backoff_sleep(attempt);
                        attempt += 1;
                        continue;
                    }
                    // the fault is persistent (or outlived the retry
                    // budget): before blaming a request, degrade a
                    // routed step to the dense fallback entries once —
                    // if the sparse path itself is at fault, dense
                    // clears it and the controller resumes routing on
                    // the next step
                    if !degraded && plan.tag != "dense" {
                        degraded = true;
                        plan = self.ctl.degrade();
                        self.metrics.degraded_steps += 1;
                        for (i, slot) in self.slots.iter().enumerate() {
                            if let Some(s) = slot {
                                if active[i] && s.finished.is_none() {
                                    self.events.push(GenerationEvent::Degraded {
                                        request: s.req.id,
                                    });
                                }
                            }
                        }
                        continue;
                    }
                    // retries exhausted (or the fault is persistent):
                    // isolate the poisoned request and finish the step
                    // for everyone else
                    break self.bisect_blame(&plan, &tokens, &lengths, width, &active)?;
                }
            }
        };
        let dt = t_step.elapsed();
        self.pool_kv = Some(out.kv);

        let logits = out.logits.as_f32()?;
        let vocab = self.engine.config().vocab;
        let max_total = self.max_prompt_len();
        let prefix_cache_on = self.cfg.prefix_cache;
        let mut emitted = 0;
        for (i, slot) in self.slots.iter_mut().enumerate() {
            let Some(s) = slot else { continue };
            if s.finished.is_some() || s.phase != SlotPhase::Running {
                continue;
            }
            // this step wrote position s.len - 1 — if that filled a
            // block, its content (prompt + generated ids) is final:
            // publish it so multi-turn follow-ups embedding this turn's
            // output hit the prefix cache
            if prefix_cache_on && s.len % bs == 0 {
                let stream = s.stream();
                self.blocks.publish_full_blocks(&mut s.table, &stream[..s.len]);
            }
            let row = &logits[i * vocab..(i + 1) * vocab];
            if !logits_finite(row) {
                // graceful degradation, slot granularity: a non-finite
                // row (NaN/Inf) quarantines only this request — no
                // token is sampled from garbage and nothing is emitted
                s.finished = Some(FinishReason::EngineFault);
                self.metrics.quarantined += 1;
                continue;
            }
            let next = s.sampler.sample(row);
            emitted += 1;
            let now = Instant::now();
            // inter-token latency measured between real emissions
            self.metrics
                .itl
                .push(now.duration_since(s.last_token_at).as_secs_f64());
            s.last_token_at = now;
            self.events.push(GenerationEvent::Token {
                request: s.req.id,
                id: next,
                index: s.generated.len(),
                text_offset: s.text_len,
            });
            s.generated.push(next);
            s.text_len += token_byte_len(next);
            s.len += 1;
            if next == s.req.params.stop_token {
                s.finished = Some(FinishReason::Stop);
            } else if hits_stop_sequence(&s.generated, &s.req.stop_sequences) {
                s.finished = Some(FinishReason::StopSequence);
            } else if s.generated.len() >= s.req.params.max_new_tokens {
                s.finished = Some(FinishReason::Length);
            } else if s.len >= max_total {
                s.finished = Some(FinishReason::CacheLimit);
            }
        }
        self.metrics.record_step(dt, emitted);
        Ok(())
    }
}

/// Does `generated` end with any of the stop sequences?
fn hits_stop_sequence(generated: &[i32], stops: &[Vec<i32>]) -> bool {
    stops.iter().any(|s| !s.is_empty() && generated.ends_with(s))
}
