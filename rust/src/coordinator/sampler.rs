//! Token sampling over the decode step's logits (host side, per slot).

use crate::substrate::rng::{argmax, Rng};

use super::request::SamplingParams;

/// True when every logit in the row is finite. The scheduler guards
/// every sampling site with this: a non-finite row (engine fault, bad
/// entry state) quarantines only the offending slot with
/// `FinishReason::EngineFault` instead of sampling garbage — or
/// panicking inside a comparator — and taking the batch down.
pub fn logits_finite(row: &[f32]) -> bool {
    row.iter().all(|v| v.is_finite())
}

/// Per-request sampler state (owns the request's RNG stream).
#[derive(Debug, Clone)]
pub struct Sampler {
    params: SamplingParams,
    rng: Rng,
}

impl Sampler {
    pub fn new(params: SamplingParams, request_id: u64) -> Sampler {
        Sampler {
            params,
            rng: Rng::new(params.seed ^ request_id.wrapping_mul(0x9E3779B97F4A7C15)),
        }
    }

    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    /// Sample the next token id from a [V] logits row.
    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        if self.params.temperature <= 0.0 {
            return argmax(logits) as i32;
        }
        if self.params.top_k > 0 && self.params.top_k < logits.len() {
            // mask everything below the k-th largest logit
            let mut sorted: Vec<f32> = logits.to_vec();
            // total_cmp, not partial_cmp().unwrap(): a NaN that slips
            // past the guard must not panic the engine thread
            sorted.sort_by(|a, b| b.total_cmp(a));
            let kth = sorted[self.params.top_k - 1];
            let masked: Vec<f32> = logits
                .iter()
                .map(|&l| if l >= kth { l } else { f32::NEG_INFINITY })
                .collect();
            return self.rng.sample_logits(&masked, self.params.temperature) as i32;
        }
        self.rng.sample_logits(logits, self.params.temperature) as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::substrate::prop::check;

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::new(SamplingParams::default(), 1);
        assert_eq!(s.sample(&[0.0, 3.0, 1.0]), 1);
    }

    #[test]
    fn finite_guard_flags_bad_rows() {
        assert!(logits_finite(&[0.0, 3.0, -1.0]));
        assert!(!logits_finite(&[0.0, f32::NAN, 1.0]));
        assert!(!logits_finite(&[f32::INFINITY, 0.0]));
        assert!(!logits_finite(&[f32::NEG_INFINITY]));
    }

    #[test]
    fn topk_sort_survives_nan() {
        // a NaN row must not panic the sampler even if the guard is
        // bypassed; any in-vocab token is acceptable
        let p = SamplingParams { temperature: 1.0, top_k: 2, ..Default::default() };
        let mut s = Sampler::new(p, 3);
        let t = s.sample(&[0.1, f32::NAN, 0.3, 0.2]);
        assert!((0..4).contains(&t));
    }

    #[test]
    fn prop_topk_support() {
        check("sampler-topk-support", 50, |g| {
            let v = g.usize_in(4, 40);
            let k = g.usize_in(1, v);
            let logits = g.vec_f32(v, -5.0, 5.0);
            let params = SamplingParams {
                temperature: 1.0,
                top_k: k,
                seed: g.seed,
                ..Default::default()
            };
            let mut s = Sampler::new(params, 7);
            // the k-th largest logit value
            let mut sorted = logits.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let kth = sorted[k - 1];
            for _ in 0..20 {
                let t = s.sample(&logits) as usize;
                prop_assert!(
                    logits[t] >= kth,
                    "sampled token {t} (logit {}) outside top-{k} (kth {kth})",
                    logits[t]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_per_request_stream() {
        let p = SamplingParams { temperature: 0.8, seed: 9, ..Default::default() };
        let logits = vec![0.1f32, 0.2, 0.3, 0.4];
        let mut a = Sampler::new(p, 42);
        let mut b = Sampler::new(p, 42);
        for _ in 0..10 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
    }
}
