//! Deterministic mock [`StepEngine`](super::StepEngine) for scheduler and
//! protocol tests (and offline protocol development — the v2 streaming
//! server runs against it without any AOT artifacts).
//!
//! The "LM": for a prompt whose last id is `c`, it emits `c+1`, `c+2`, …
//! until the id leaves byte range, then the `'\n'` stop token. It
//! verifies scheduling and protocol behaviour, not numerics. Chunked
//! prefill **honors per-slot offsets**: each chunk call writes a
//! fingerprint (the token id) at `[l=0, k, slot, g=0, position, d=0]`
//! through [`super::kv::append_chunk`], so tests can read the cache back and
//! prove that a long prompt streamed through many chunks landed
//! un-truncated, in order, without clobbering co-resident slots. Decode
//! mirrors the real entries' cache update too: every step writes a `-1`
//! sentinel at each slot's `lengths-1` position — for a prefilling slot
//! that lands on the next chunk position (which the chunk's masked
//! write must overwrite), so the fingerprint tests fail if the
//! chunk-after-decode overwrite ordering ever regresses.
//!
//! The mock also mirrors the engine's two KV paths for `bench
//! decode-breakdown --smoke`: in the default *resident* mode a host KV is
//! "uploaded" once and then flows step-to-step as a buffer; in
//! `with_host_kv_path` mode every step pays the full round trip. The
//! paged pipeline is fused end to end: prefill and decode index the pool
//! in place (zero gather/scatter shell bytes, on either side), and COW
//! runs as an on-device block-pair copy accounted in `cow_bytes` — the
//! pool uploads once per process ([`MockEngine::pool_uploads`]) and never
//! crosses the host boundary again. Byte accounting is analytic (computed
//! from the shapes the real paths would move), so the breakdown is
//! deterministic.
//!
//! **Paged KV**: the mock implements the full block-pool path the
//! scheduler serves from (`prefill_chunk_paged` / `decode_paged` /
//! `copy_blocks`), fingerprinting every written position at
//! `[l=0, k=0, block, g=0, pos % bs, d=0]` — so paged tests can read the
//! pool back through a request's block table ([`MockEngine::table_fingerprints`])
//! and prove that paged scheduling produced exactly the contiguous
//! path's token stream while writing exactly the physical blocks the
//! allocator granted (never the null block, never a foreign request's).
//!
//! Routing: the mock *honors* router indices end-to-end. A step that
//! arrives with a [`StepRouting`] has its `head_idx`/`mlp_idx` tensors
//! shape- and range-checked against the mock geometry, counts toward
//! `routed_steps()`, and nudges the logits by the selected head set — so
//! scheduler-level tests can assert the controller's indices actually
//! reach the engine and change the computation. [`mock_router_bank`]
//! provides the deterministic bank `bench sparsity-scaling --smoke`
//! routes with: head selection is input-independent (batch-union density
//! stays flat as B grows) while MLP selection is token-dependent (union
//! density climbs toward dense) — the paper's central crossover.
//!
//! **Sharding**: [`MockEngine::with_tp`] / [`MockEngine::with_pp2`] model
//! the shard-aware serving modes: TP fans every KV write across all head
//! groups (each shard's `split_pool_groups` slice carries the
//! fingerprints — KV-write-always) and runs each routed step through
//! [`plan_shard_dispatch`], accounting `shards_dispatched` /
//! `shards_skipped` / `allreduce_bytes` exactly as the sharded driver
//! would; logits are untouched, so sharded streams stay bit-identical to
//! single-device runs of the same workload.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::runtime::{
    copy_pool_blocks, plan_shard_dispatch, BlockTables, KvCache, KvStore,
    ModelConfig, PagedKv, PagedStepOutput, RouterBank, ShardPlanSpec, StepOutput,
    StepProfile, StepRouting, Tensor,
};
use crate::substrate::sync::lock_clean;
use crate::tokenizer::PAD;

use super::faults::FaultInjector;
use super::scheduler::StepEngine;

/// Deterministic router bank matching the mock geometry (L=2, d=8, G=2,
/// d_ff=16, vocab=300).
///
/// * token embedding: one-hot on `token % 8` — routing depends only on
///   the token id, never on wall time or rng.
/// * attention router: zero weights, per-layer bias — every request gets
///   the same top-k head groups, so the batch union never grows (the
///   head-specialization regime the paper measures §4.2).
/// * MLP router: identity bottleneck into per-token neuron pairs — token
///   `t` scores neurons `{2*(t%8), 2*(t%8)+1}`, so the batch union grows
///   with the number of distinct tokens in flight (Deja Vu's failure
///   mode at batch, §4.1).
pub fn mock_router_bank() -> RouterBank {
    mock_router_bank_g(2)
}

/// [`mock_router_bank`] generalized over the group count, for sharding
/// tests that need more head groups than TP shards (e.g. G=4 with 4
/// shards: top-1 selection dispatches exactly 1 of 4 attention shards per
/// routed layer). Layer `li`'s top-1 group is `(g - 1 - li) % g` — still
/// input-independent, so the dispatch pattern is flat across batch.
pub fn mock_router_bank_g(g: usize) -> RouterBank {
    let (l, d, dff, rh, vocab) = (2usize, 8usize, 16usize, 8usize, 300usize);
    let mut tok_emb = vec![0f32; vocab * d];
    for t in 0..vocab {
        tok_emb[t * d + t % d] = 1.0;
    }
    let pos_emb = vec![0f32; 64 * d];
    let attn_w = vec![0f32; l * d * g];
    let mut attn_b = vec![0f32; l * g];
    for li in 0..l {
        for gi in 0..g {
            attn_b[li * g + gi] = ((gi + li) % g) as f32;
        }
    }
    let mut w1 = vec![0f32; l * d * rh];
    for li in 0..l {
        for j in 0..d {
            w1[li * d * rh + j * rh + j] = 1.0; // identity bottleneck
        }
    }
    let b1 = vec![0f32; l * rh];
    let mut w2 = vec![0f32; l * rh * dff];
    for li in 0..l {
        for j in 0..rh {
            w2[li * rh * dff + j * dff + 2 * j] = 1.0;
            w2[li * rh * dff + j * dff + 2 * j + 1] = 1.0;
        }
    }
    let b2 = vec![0f32; l * dff];
    RouterBank::new(
        l,
        d,
        g,
        dff,
        1,
        tok_emb,
        pos_emb,
        attn_w,
        attn_b,
        Some(RouterBank::mlp_router(rh, w1, b1, w2, b2)),
    )
    .expect("mock router bank")
}

pub struct MockEngine {
    cfg: ModelConfig,
    batch_buckets: Vec<usize>,
    seq_buckets: Vec<usize>,
    /// Chunked-prefill token width (mirrors `Manifest::prefill_chunk`).
    chunk_len: usize,
    /// Artificial per-decode-step delay, so tests can race cancellation
    /// against generation deterministically.
    step_delay: Duration,
    /// Artificial delay per prefill-chunk call: under the monolithic
    /// budget a long prompt pays all its chunk delays inside one step
    /// (stalling every decoder), under the chunked budget one per step —
    /// the contrast `bench prefill-interference` measures.
    chunk_delay: Duration,
    /// A/B: model the legacy host-KV path (full cache both ways per step).
    host_kv_path: bool,
    /// Override the paged pool's block count (None = the no-sharing
    /// worst case of the bucket ladder). Overload tests shrink this so
    /// block pressure bites long before slot pressure.
    pool_blocks: Option<usize>,
    /// Model tensor-parallel serving across this many shards: paged
    /// writes land in EVERY head group (each shard's group slice carries
    /// the fingerprints — the KV-write-always discipline), and every
    /// decode step runs [`plan_shard_dispatch`] on the incoming routing
    /// to account `shards_dispatched` / `shards_skipped` /
    /// `allreduce_bytes` exactly as the sharded driver would. Logits are
    /// untouched, so sharded streams stay bit-identical to single-device.
    tp_shards: Option<usize>,
    /// Model 2-stage pipeline serving: the pool's layer halves live on
    /// different stages (tests slice with `split_pool_layers`), and each
    /// decode step accounts two stage dispatches. PP stages are never
    /// skippable — routing thins work *within* a stage, not across.
    pp2: bool,
    client: xla::PjRtClient,
    profile: Mutex<StepProfile>,
    /// Decode steps that arrived with (validated) router indices.
    routed_steps: AtomicU64,
    /// Paged calls that uploaded the pool (resident path: exactly one
    /// per process — the first; see [`MockEngine::pool_uploads`]).
    pool_uploads: AtomicU64,
    /// Scripted fault injection (`with_faults`): the paged entry points
    /// consult it before touching the pool, and NaN corruption runs over
    /// the finished logits — see [`super::faults`].
    faults: Option<Arc<FaultInjector>>,
}

impl Default for MockEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl MockEngine {
    pub fn new() -> Self {
        MockEngine {
            cfg: ModelConfig {
                name: "mock".into(),
                analogue: "mock".into(),
                d_model: 8,
                n_layers: 2,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 16,
                d_head: 2,
                vocab: 300,
                max_seq: 64,
                mlp: "relu".into(),
                pos: "learned".into(),
                critical_density: 0.5,
            },
            batch_buckets: vec![1, 2, 4, 8],
            seq_buckets: vec![16, 32, 64],
            chunk_len: 16,
            step_delay: Duration::ZERO,
            chunk_delay: Duration::ZERO,
            host_kv_path: false,
            pool_blocks: None,
            tp_shards: None,
            pp2: false,
            client: xla::PjRtClient::cpu().expect("shim client"),
            profile: Mutex::new(StepProfile::default()),
            routed_steps: AtomicU64::new(0),
            pool_uploads: AtomicU64::new(0),
            faults: None,
        }
    }

    /// Replay a scripted fault schedule from inside the paged entry
    /// points (deterministic injection for the fault-tolerance tests
    /// and `bench fault-recovery`).
    pub fn with_faults(mut self, inj: Arc<FaultInjector>) -> Self {
        self.faults = Some(inj);
        self
    }

    /// How many decode steps consumed router indices.
    pub fn routed_steps(&self) -> u64 {
        self.routed_steps.load(Ordering::Relaxed)
    }

    /// Shape/range-check one step's index tensors against the mock
    /// geometry; returns each request's selected-group sum (the logits
    /// nudge, so tests can observe which indices arrived).
    fn check_routing(&self, r: &StepRouting, b: usize) -> Result<Vec<i64>> {
        let (l, g, dff) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.d_ff);
        let shape = r.head_idx.shape();
        if shape.len() != 3 || shape[0] != l || shape[1] != b {
            bail!("mock: head_idx shape {shape:?} != [{l}, {b}, k]");
        }
        let idx = r.head_idx.as_i32()?;
        let k = shape[2];
        let mut sums = vec![0i64; b];
        for (pos, &gi) in idx.iter().enumerate() {
            if gi < 0 || gi as usize >= g {
                bail!("mock: head_idx value {gi} out of range [0, {g})");
            }
            sums[(pos / k) % b] += gi as i64;
        }
        if let Some(m) = &r.mlp_idx {
            let shape = m.shape();
            if shape.len() != 2 || shape[0] != l {
                bail!("mock: mlp_idx shape {shape:?} != [{l}, k]");
            }
            for &ni in m.as_i32()? {
                if ni < 0 || ni as usize >= dff {
                    bail!("mock: mlp_idx value {ni} out of range [0, {dff})");
                }
            }
        }
        Ok(sums)
    }

    /// Sleep this long inside every decode step.
    pub fn with_step_delay(mut self, d: Duration) -> Self {
        self.step_delay = d;
        self
    }

    /// Sleep this long inside every prefill-chunk call.
    pub fn with_chunk_delay(mut self, d: Duration) -> Self {
        self.chunk_delay = d;
        self
    }

    /// Replace the seq-bucket ladder (ascending; the largest bucket
    /// becomes `max_seq`, i.e. the longest admissible prompt). Lets the
    /// interference bench admit a 1024-token prompt through the mock.
    pub fn with_seq_buckets(mut self, buckets: Vec<usize>) -> Self {
        assert!(!buckets.is_empty() && buckets.windows(2).all(|w| w[0] < w[1]));
        self.cfg.max_seq = *buckets.last().unwrap();
        self.seq_buckets = buckets;
        self
    }

    /// Model the legacy host-KV decode path (the A/B baseline).
    pub fn with_host_kv_path(mut self, host: bool) -> Self {
        self.host_kv_path = host;
        self
    }

    /// How many times a paged entry call uploaded the pool (a resident
    /// serving run uploads it exactly once, at the first paged call, and
    /// never again — bucket changes, COW, admissions included).
    pub fn pool_uploads(&self) -> u64 {
        self.pool_uploads.load(Ordering::Relaxed)
    }

    /// Shrink (or grow) the paged pool to exactly `n` physical blocks
    /// (incl. the null block). Overload tests use a pool much smaller
    /// than the bucket ladder's worst case so admission/preemption
    /// trigger on block pressure while batch slots are still free.
    pub fn with_pool_blocks(mut self, n: usize) -> Self {
        assert!(n >= 2, "pool needs the null block + at least one usable");
        self.pool_blocks = Some(n);
        self
    }

    /// Widen the mock to `g` KV head groups (pair with
    /// [`mock_router_bank_g`]), so sharding tests can split groups across
    /// more TP shards than the default G=2 allows.
    pub fn with_groups(mut self, g: usize) -> Self {
        assert!(g >= 1);
        self.cfg.n_heads = g;
        self.cfg.n_kv_heads = g;
        self
    }

    /// Serve as `n_shards` tensor-parallel shards (see the field doc):
    /// all-group KV writes + per-step shard-dispatch accounting.
    pub fn with_tp(mut self, n_shards: usize) -> Self {
        assert!(
            n_shards >= 1 && self.cfg.n_kv_heads % n_shards == 0,
            "G must divide into shards"
        );
        self.tp_shards = Some(n_shards);
        self
    }

    /// Serve as a 2-stage pipeline (see the field doc): per-step stage
    /// dispatch accounting; the layer split point is `n_layers / 2`.
    pub fn with_pp2(mut self) -> Self {
        assert!(self.cfg.n_layers >= 2);
        self.pp2 = true;
        self
    }

    /// Paged geometry the mock serves: block = the chunk width, pool
    /// sized for the no-sharing worst case of the current bucket ladder
    /// (+ the null block) — the same formula aot.py bakes into real
    /// manifests.
    fn paged_layout(&self) -> (usize, usize) {
        let bs = self.chunk_len;
        let max_b = *self.batch_buckets.last().unwrap();
        let max_n = *self.seq_buckets.last().unwrap();
        (bs, self.pool_blocks.unwrap_or(1 + max_b * max_n / bs))
    }

    /// Read one request's per-position fingerprints out of a POOL
    /// snapshot through its block-table row (0 entries = null block,
    /// whose content is don't-care). The paged counterpart of
    /// [`MockEngine::slot_fingerprints`]: tests walk a prompt's logical
    /// positions and prove each one landed in the right physical block.
    pub fn table_fingerprints(&self, pool: &Tensor, row: &[i32]) -> Result<Vec<f32>> {
        let s = pool.shape();
        if s.len() != 6 {
            bail!("expected pool [L,2,P,G,bs,dh], got {s:?}");
        }
        let (p, g, bs, dh) = (s[2], s[3], s[4], s[5]);
        let data = pool.as_f32()?;
        let block_row = g * bs * dh;
        let mut out = Vec::with_capacity(row.len() * bs);
        for &blk in row {
            if blk < 0 || blk as usize >= p {
                bail!("table row names block {blk} outside pool ({p})");
            }
            for off in 0..bs {
                // fingerprints live at [l=0, k=0, block, g=0, off, d=0]
                out.push(data[blk as usize * block_row + off * dh]);
            }
        }
        Ok(out)
    }

    /// Read the prompt fingerprints of one slot out of a cache snapshot:
    /// the token value written at each position by the chunked-prefill
    /// path (0.0 = never written). Tests use this to prove long prompts
    /// land un-truncated and in order.
    pub fn slot_fingerprints(&self, kv: &Tensor, slot: usize) -> Result<Vec<f32>> {
        let s = kv.shape();
        if s.len() != 6 {
            bail!("expected [L,2,B,G,N,dh], got {s:?}");
        }
        let (b, g, n, dh) = (s[2], s[3], s[4], s[5]);
        if slot >= b {
            bail!("slot {slot} out of range (B={b})");
        }
        let data = kv.as_f32()?;
        // fingerprints live at [l=0, k=0, slot, g=0, pos, d=0]
        let base = (slot * g) * n * dh;
        Ok((0..n).map(|p| data[base + p * dh]).collect())
    }

    fn logits_for(&self, token: i32) -> Vec<f32> {
        // next token = token + 1 (wrapping to '\n' outside byte range)
        let mut row = vec![0.0f32; self.cfg.vocab];
        let next = if token >= 255 { b'\n' as i32 } else { token + 1 };
        row[next as usize] = 10.0;
        row
    }
}

impl StepEngine for MockEngine {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn batch_buckets(&self) -> &[usize] {
        &self.batch_buckets
    }
    fn seq_buckets(&self) -> &[usize] {
        &self.seq_buckets
    }
    fn prefill_chunk_len(&self) -> usize {
        self.chunk_len
    }
    fn profile_snapshot(&self) -> StepProfile {
        *lock_clean(&self.profile)
    }
    fn reset_profile(&self) {
        *lock_clean(&self.profile) = StepProfile::default();
    }
    fn prefill_chunk(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        offset: &[i32],
        kv: KvCache,
    ) -> Result<StepOutput> {
        let t0 = Instant::now();
        let b = kv.batch;
        let n = kv.n;
        let c = self.chunk_len;
        if tokens.len() != b * c || lengths.len() != b || offset.len() != b {
            bail!(
                "mock prefill_chunk: tokens {} / lengths {} / offset {} vs batch {b} chunk {c}",
                tokens.len(),
                lengths.len(),
                offset.len()
            );
        }
        if lengths.iter().any(|&l| l > 0) && !self.chunk_delay.is_zero() {
            std::thread::sleep(self.chunk_delay);
        }
        // honor the offsets: fingerprint each written position with its
        // token id through the same surgery primitive the host path uses,
        // leaving inactive slots and untouched positions bit-identical
        let mut t = kv.to_tensor()?;
        let mut logits = Vec::with_capacity(b * self.cfg.vocab);
        for i in 0..b {
            let len = lengths[i] as usize;
            if len == 0 {
                logits.extend(vec![0.0f32; self.cfg.vocab]);
                continue;
            }
            let off = offset[i] as usize;
            if len > c || off + len > n {
                bail!("mock prefill_chunk: slot {i} window {off}+{len} vs chunk {c} bucket {n}");
            }
            let mut chunk_kv = Tensor::zeros_f32(self.cfg.kv_shape(1, len));
            {
                let d = chunk_kv.as_f32_mut()?;
                let dh = self.cfg.d_head;
                for p in 0..len {
                    // flat index of [l=0, k=0, b=0, g=0, pos=p, d=0]
                    d[p * dh] = tokens[i * c + p] as f32;
                }
            }
            super::kv::append_chunk(&mut t, i, &chunk_kv, off, len)?;
            logits.extend(self.logits_for(tokens[i * c + len - 1]));
        }
        // transfer accounting, mirroring the real engine's two paths
        let kv_bytes = (self.cfg.kv_elems(b, n) * 4) as u64;
        let payload = (tokens.len() * 4 + lengths.len() * 4 + offset.len() * 4) as u64;
        let logits_bytes = (b * self.cfg.vocab * 4) as u64;
        let was_resident = kv.is_resident();
        let kv_out = if self.host_kv_path {
            let mut p = lock_clean(&self.profile);
            p.h2d_bytes += payload + kv_bytes;
            p.d2h_bytes += logits_bytes + kv_bytes;
            KvCache::from_tensor(&t, b, n)?
        } else {
            // resident path: the chunk write happens on-device; the cache
            // is uploaded only when it arrived as a host literal (fresh
            // group or post-surgery) and then stays put
            let lit = t.to_literal()?;
            let buf = self.client.buffer_from_host_literal(None, &lit)?;
            let mut p = lock_clean(&self.profile);
            p.h2d_bytes += payload + if was_resident { 0 } else { kv_bytes };
            p.d2h_bytes += logits_bytes;
            KvCache { store: KvStore::Buf(buf), batch: b, n }
        };
        {
            let mut p = lock_clean(&self.profile);
            p.prefill_ns += t0.elapsed().as_nanos() as u64;
            p.prefill_chunks += 1;
        }
        Ok(StepOutput {
            logits: Tensor::f32(logits, vec![b, self.cfg.vocab])?,
            kv: kv_out,
        })
    }
    fn decode(
        &self,
        _tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv: KvCache,
        routing: Option<&StepRouting>,
    ) -> Result<StepOutput> {
        let t0 = Instant::now();
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let b = tokens.len();
        // honor router indices: validate, count, and let the selection
        // perturb the logits (without moving the +1-chain argmax) so
        // end-to-end tests can see exactly which indices arrived
        let head_sums = match routing {
            Some(r) => {
                let sums = self.check_routing(r, b)?;
                self.routed_steps.fetch_add(1, Ordering::Relaxed);
                Some(sums)
            }
            None => None,
        };
        let mut logits = Vec::with_capacity(b * self.cfg.vocab);
        for (i, &t) in tokens.iter().enumerate() {
            let mut row = self.logits_for(if t == PAD { 0 } else { t });
            if let Some(sums) = &head_sums {
                row[sums[i] as usize % self.cfg.vocab] += 0.5;
            }
            logits.extend(row);
        }
        // mirror the real decode entries' cache update: every slot gets
        // this step's K/V written at position lengths-1. For running
        // slots that is the new token's position; for a *prefilling*
        // slot the scheduler aims it at the next chunk position, whose
        // masked write must overwrite it — the sentinel makes the
        // fingerprint tests fail if that overwrite ordering ever breaks.
        let (batch, n) = (kv.batch, kv.n);
        if let Some(&max) = lengths.iter().max() {
            if max as usize > n {
                bail!("mock decode: length {max} exceeds kv bucket {n}");
            }
        }
        let was_resident = kv.is_resident();
        let mut t = kv.to_tensor()?;
        {
            let d = t.as_f32_mut()?;
            let g = self.cfg.n_kv_heads;
            let dh = self.cfg.d_head;
            for (i, &len) in lengths.iter().enumerate() {
                let pos = (len.max(1) as usize) - 1;
                // flat index of [l=0, k=0, slot=i, g=0, pos, d=0]
                d[((i * g) * n + pos) * dh] = -1.0;
            }
        }
        // transfer accounting, mirroring the real engine's two paths
        // (analytic: counters reflect what the real paths would move,
        // not the host-side copies this mock makes)
        let kv_bytes = (self.cfg.kv_elems(batch, n) * 4) as u64;
        let io_bytes = (tokens.len() * 4 + lengths.len() * 4) as u64;
        let logits_bytes = (b * self.cfg.vocab * 4) as u64;
        let kv_out = if self.host_kv_path {
            // legacy path: cache crosses the boundary both ways each step
            let mut p = lock_clean(&self.profile);
            p.h2d_bytes += io_bytes + kv_bytes;
            p.d2h_bytes += logits_bytes + kv_bytes;
            p.decode_steps += 1;
            KvCache::from_tensor(&t, batch, n)?
        } else {
            // resident path: the cache is uploaded once (when it arrives
            // as a host literal after surgery) and then stays put
            let uploaded = if was_resident { 0 } else { kv_bytes };
            let lit = t.to_literal()?;
            let store = KvStore::Buf(self.client.buffer_from_host_literal(None, &lit)?);
            let mut p = lock_clean(&self.profile);
            p.h2d_bytes += io_bytes + uploaded;
            p.d2h_bytes += logits_bytes;
            p.decode_steps += 1;
            KvCache { store, batch, n }
        };
        lock_clean(&self.profile).compute_ns += t0.elapsed().as_nanos() as u64;
        Ok(StepOutput {
            logits: Tensor::f32(logits, vec![b, self.cfg.vocab])?,
            kv: kv_out,
        })
    }

    // -- paged KV (block pool + block tables) ------------------------------

    fn kv_layout(&self) -> (usize, usize) {
        self.paged_layout()
    }

    fn recover_kv(&self) -> Option<PagedKv> {
        self.faults.as_ref().and_then(|f| f.take_stash())
    }

    fn new_kv_pool(&self) -> Result<PagedKv> {
        if let Some(f) = &self.faults {
            f.check_pool_alloc()?;
        }
        let (bs, p) = self.paged_layout();
        PagedKv::from_tensor(
            &Tensor::zeros_f32(self.cfg.kv_pool_shape(p, bs)),
            p,
            bs,
        )
    }

    /// Paged chunked prefill: identical chunk semantics to the
    /// contiguous path, with each written position routed through the
    /// slot's block-table row. Fingerprints land at
    /// `[l=0, k=0, block, g=0, pos % bs, d=0]`, so tests can prove a
    /// prompt streamed into exactly the physical blocks its table names
    /// — and never into block 0 or a foreign block.
    fn prefill_chunk_paged(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        offset: &[i32],
        tables: &BlockTables,
        kv: PagedKv,
    ) -> Result<PagedStepOutput> {
        let t0 = Instant::now();
        let kv = match &self.faults {
            Some(f) => f.check_prefill(kv)?,
            None => kv,
        };
        let b = tables.batch;
        let c = self.chunk_len;
        let bs = kv.block;
        let n = tables.n(bs);
        let p_blocks = kv.pool_blocks;
        if tokens.len() != b * c || lengths.len() != b || offset.len() != b {
            bail!(
                "mock prefill_chunk_paged: tokens {} / lengths {} / offset {} vs batch {b} chunk {c}",
                tokens.len(),
                lengths.len(),
                offset.len()
            );
        }
        if lengths.iter().any(|&l| l > 0) && !self.chunk_delay.is_zero() {
            std::thread::sleep(self.chunk_delay);
        }
        let was_resident = kv.is_resident();
        let mut t = kv.to_tensor()?;
        let (g, dh) = (self.cfg.n_kv_heads, self.cfg.d_head);
        let block_row = g * bs * dh;
        // TP mode fans the write across every head group: a KV-write
        // entry runs on every shard (even ones routing will later skip),
        // so each shard's group slice must carry the fingerprints
        let fan = if self.tp_shards.is_some() { g } else { 1 };
        let mut logits = Vec::with_capacity(b * self.cfg.vocab);
        {
            let d = t.as_f32_mut()?;
            for i in 0..b {
                let len = lengths[i] as usize;
                if len == 0 {
                    logits.extend(vec![0.0f32; self.cfg.vocab]);
                    continue;
                }
                let off = offset[i] as usize;
                if len > c || off + len > n {
                    bail!(
                        "mock prefill_chunk_paged: slot {i} window {off}+{len} vs chunk {c} bucket {n}"
                    );
                }
                for k in 0..len {
                    let pos = off + k;
                    let blk = tables.flat[i * tables.width + pos / bs];
                    // a prompt write aimed at the null block (or out of
                    // pool) is a scheduler bug, never a don't-care
                    if blk <= 0 || blk as usize >= p_blocks {
                        bail!(
                            "mock prefill_chunk_paged: slot {i} pos {pos} writes block {blk}"
                        );
                    }
                    for gi in 0..fan {
                        d[blk as usize * block_row + (gi * bs + pos % bs) * dh] =
                            tokens[i * c + k] as f32;
                    }
                }
                logits.extend(self.logits_for(tokens[i * c + len - 1]));
            }
        }
        // transfer accounting, mirroring the real engine's two paths:
        // the POOL crosses once (first upload) and then stays resident —
        // unlike the contiguous cache it never re-uploads on re-buckets
        let pool_bytes = (t.len() * 4) as u64;
        let payload = (tokens.len() * 4
            + lengths.len() * 4
            + offset.len() * 4
            + tables.flat.len() * 4) as u64;
        let logits_bytes = (b * self.cfg.vocab * 4) as u64;
        let kv_out = if self.host_kv_path {
            let mut p = lock_clean(&self.profile);
            p.h2d_bytes += payload + pool_bytes;
            p.d2h_bytes += logits_bytes + pool_bytes;
            PagedKv::from_tensor(&t, p_blocks, bs)?
        } else {
            if !was_resident {
                self.pool_uploads.fetch_add(1, Ordering::Relaxed);
            }
            let lit = t.to_literal()?;
            let buf = self.client.buffer_from_host_literal(None, &lit)?;
            let mut p = lock_clean(&self.profile);
            p.h2d_bytes += payload + if was_resident { 0 } else { pool_bytes };
            p.d2h_bytes += logits_bytes;
            PagedKv { store: KvStore::Buf(buf), pool_blocks: p_blocks, block: bs }
        };
        {
            // fused prefill: the graph resolves prior-context tiles through
            // the block table and writes the chunk's rows in place — no
            // dense view on either side, prefill_{gather,scatter}_bytes 0
            let mut p = lock_clean(&self.profile);
            p.prefill_ns += t0.elapsed().as_nanos() as u64;
            p.prefill_chunks += 1;
        }
        Ok(PagedStepOutput {
            logits: Tensor::f32(logits, vec![b, self.cfg.vocab])?,
            kv: kv_out,
        })
    }

    /// Paged decode: the contiguous mock's +1-chain logits, router
    /// validation and logits nudge, with the per-step `-1` sentinel write
    /// routed through the block table. Inactive (padding) slots aim at
    /// the null block by construction, so their blind writes are
    /// provably harmless — the fingerprint tests would catch any stray.
    fn decode_paged(
        &self,
        _tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        tables: &BlockTables,
        kv: PagedKv,
        routing: Option<&StepRouting>,
    ) -> Result<PagedStepOutput> {
        let t0 = Instant::now();
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let kv = match &self.faults {
            Some(f) => f.check_decode(tokens, kv)?,
            None => kv,
        };
        let b = tokens.len();
        if tables.batch != b || lengths.len() != b {
            bail!("mock decode_paged: tables batch {} vs tokens {b}", tables.batch);
        }
        let bs = kv.block;
        let n = tables.n(bs);
        let p_blocks = kv.pool_blocks;
        if let Some(&max) = lengths.iter().max() {
            if max as usize > n {
                bail!("mock decode_paged: length {max} exceeds logical bucket {n}");
            }
        }
        let head_sums = match routing {
            Some(r) => {
                let sums = self.check_routing(r, b)?;
                self.routed_steps.fetch_add(1, Ordering::Relaxed);
                Some(sums)
            }
            None => None,
        };
        // sharded-serving accounting: run this step's routing through the
        // same dispatch planner the sharded driver uses, and mirror its
        // analytic transfer profile — routing CUTS dispatched shards,
        // never logits, so sharded streams stay bit-identical
        if let Some(s) = self.tp_shards {
            let l = self.cfg.n_layers;
            let mlp_ks = routing
                .and_then(|r| r.mlp_idx.as_ref())
                .map(|m| m.shape()[1].min(self.cfg.d_ff / s))
                .unwrap_or(0);
            let plan = plan_shard_dispatch(
                &ShardPlanSpec {
                    n_shards: s,
                    n_layers: l,
                    n_groups: self.cfg.n_kv_heads,
                    d_ff: self.cfg.d_ff,
                    batch: b,
                    route_attn: routing.is_some(),
                    mlp_ks,
                },
                routing,
            )?;
            let mut p = lock_clean(&self.profile);
            p.shards_dispatched += plan.dispatched();
            p.shards_skipped += plan.skipped();
            // two all-reduces per layer (attention + MLP partials), each
            // combining S device-resident [B, d] f32 partials
            p.allreduce_bytes += (2 * l * s * b * self.cfg.d_model * 4) as u64;
        } else if self.pp2 {
            // two stage dispatches per step; stages are never skippable
            lock_clean(&self.profile).shards_dispatched += 2;
        }
        let mut logits = Vec::with_capacity(b * self.cfg.vocab);
        for (i, &tk) in tokens.iter().enumerate() {
            let mut row = self.logits_for(if tk == PAD { 0 } else { tk });
            if let Some(sums) = &head_sums {
                row[sums[i] as usize % self.cfg.vocab] += 0.5;
            }
            logits.extend(row);
        }
        if let Some(f) = &self.faults {
            f.corrupt_logits(tokens, &mut logits, self.cfg.vocab);
        }
        let was_resident = kv.is_resident();
        let mut t = kv.to_tensor()?;
        {
            let d = t.as_f32_mut()?;
            let (g, dh) = (self.cfg.n_kv_heads, self.cfg.d_head);
            let block_row = g * bs * dh;
            // TP mode: the sentinel lands in every group (KV-write-always)
            let fan = if self.tp_shards.is_some() { g } else { 1 };
            for (i, &len) in lengths.iter().enumerate() {
                let pos = (len.max(1) as usize) - 1;
                let blk = tables.flat[i * tables.width + pos / bs];
                if blk < 0 || blk as usize >= p_blocks {
                    bail!("mock decode_paged: slot {i} pos {pos} names block {blk}");
                }
                for gi in 0..fan {
                    d[blk as usize * block_row + (gi * bs + pos % bs) * dh] = -1.0;
                }
            }
        }
        let pool_bytes = (t.len() * 4) as u64;
        let io_bytes =
            (tokens.len() * 4 + lengths.len() * 4 + tables.flat.len() * 4) as u64;
        let logits_bytes = (b * self.cfg.vocab * 4) as u64;
        // fused decode: in-graph table indexing, one KV row written in
        // place — gather_bytes/scatter_bytes stay 0 by construction
        let kv_out = if self.host_kv_path {
            let mut p = lock_clean(&self.profile);
            p.h2d_bytes += io_bytes + pool_bytes;
            p.d2h_bytes += logits_bytes + pool_bytes;
            p.decode_steps += 1;
            PagedKv::from_tensor(&t, p_blocks, bs)?
        } else {
            let uploaded = if was_resident { 0 } else { pool_bytes };
            if !was_resident {
                self.pool_uploads.fetch_add(1, Ordering::Relaxed);
            }
            let lit = t.to_literal()?;
            let store = KvStore::Buf(self.client.buffer_from_host_literal(None, &lit)?);
            let mut p = lock_clean(&self.profile);
            p.h2d_bytes += io_bytes + uploaded;
            p.d2h_bytes += logits_bytes;
            p.decode_steps += 1;
            PagedKv { store, pool_blocks: p_blocks, block: bs }
        };
        lock_clean(&self.profile).compute_ns += t0.elapsed().as_nanos() as u64;
        Ok(PagedStepOutput {
            logits: Tensor::f32(logits, vec![b, self.cfg.vocab])?,
            kv: kv_out,
        })
    }

    /// COW block copies, fingerprints included — so a forked/diverging
    /// request's copied block carries the original prefix fingerprints,
    /// exactly like the real copy. Mirrors the AOT `copy_blocks` entry:
    /// a resident pool STAYS resident (the mock's host materialization is
    /// bookkeeping, not modeled traffic); only the bytes logically copied
    /// are accounted, as device-local `cow_bytes`, plus the tiny (src,
    /// dst) index uploads.
    fn copy_blocks(&self, kv: PagedKv, pairs: &[(u32, u32)]) -> Result<PagedKv> {
        if pairs.is_empty() {
            return Ok(kv);
        }
        let (p_blocks, bs) = (kv.pool_blocks, kv.block);
        let was_resident = kv.is_resident();
        let mut t = kv.to_tensor()?;
        copy_pool_blocks(&mut t, pairs)?;
        {
            let live = pairs.iter().filter(|&&(s, d)| s != d).count();
            // one fixed-width entry call per 8 pairs, two i32 index
            // vectors each (mirrors configs.COPY_BLOCKS_PAIRS)
            let calls = pairs.len().div_ceil(8) as u64;
            let mut p = lock_clean(&self.profile);
            p.cow_bytes += (live * self.cfg.kv_block_elems(bs) * 4) as u64;
            p.h2d_bytes += calls * 2 * 8 * 4;
        }
        if was_resident {
            let lit = t.to_literal()?;
            let buf = self.client.buffer_from_host_literal(None, &lit)?;
            return Ok(PagedKv { store: KvStore::Buf(buf), pool_blocks: p_blocks, block: bs });
        }
        PagedKv::from_tensor(&t, p_blocks, bs)
    }
}
