//! Deterministic mock [`StepEngine`](super::StepEngine) for scheduler and
//! protocol tests (and offline protocol development — the v2 streaming
//! server runs against it without any AOT artifacts).
//!
//! The "LM": for a prompt whose last id is `c`, it emits `c+1`, `c+2`, …
//! until the id leaves byte range, then the `'\n'` stop token. It
//! verifies scheduling and protocol behaviour, not numerics. KV carries a
//! per-slot fingerprint in position 0 so tests can detect slot aliasing.

use std::time::Duration;

use anyhow::Result;

use crate::runtime::{KvCache, ModelConfig, StepOutput, Tensor};
use crate::tokenizer::PAD;

use super::scheduler::StepEngine;

pub struct MockEngine {
    cfg: ModelConfig,
    batch_buckets: Vec<usize>,
    seq_buckets: Vec<usize>,
    /// Artificial per-decode-step delay, so tests can race cancellation
    /// against generation deterministically.
    step_delay: Duration,
}

impl Default for MockEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl MockEngine {
    pub fn new() -> Self {
        MockEngine {
            cfg: ModelConfig {
                name: "mock".into(),
                analogue: "mock".into(),
                d_model: 8,
                n_layers: 2,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 16,
                d_head: 2,
                vocab: 300,
                max_seq: 64,
                mlp: "relu".into(),
                pos: "learned".into(),
                critical_density: 0.5,
            },
            batch_buckets: vec![1, 2, 4, 8],
            seq_buckets: vec![16, 32, 64],
            step_delay: Duration::ZERO,
        }
    }

    /// Sleep this long inside every decode step.
    pub fn with_step_delay(mut self, d: Duration) -> Self {
        self.step_delay = d;
        self
    }

    fn logits_for(&self, token: i32) -> Vec<f32> {
        // next token = token + 1 (wrapping to '\n' outside byte range)
        let mut row = vec![0.0f32; self.cfg.vocab];
        let next = if token >= 255 { b'\n' as i32 } else { token + 1 };
        row[next as usize] = 10.0;
        row
    }
}

impl StepEngine for MockEngine {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn batch_buckets(&self) -> &[usize] {
        &self.batch_buckets
    }
    fn seq_buckets(&self) -> &[usize] {
        &self.seq_buckets
    }
    fn prefill_len(&self) -> usize {
        16
    }
    fn prefill(&self, tokens: &Tensor, lengths: &Tensor) -> Result<StepOutput> {
        let b = tokens.shape()[0];
        let s = tokens.shape()[1];
        let toks = tokens.as_i32()?;
        let lens = lengths.as_i32()?;
        let mut logits = Vec::with_capacity(b * self.cfg.vocab);
        for i in 0..b {
            let last = toks[i * s + (lens[i] as usize - 1).min(s - 1)];
            logits.extend(self.logits_for(last));
        }
        let mut kvt = Tensor::zeros_f32(self.cfg.kv_shape(b, 16));
        // fingerprint: first element per slot = first prompt token
        for i in 0..b {
            let block = self.cfg.n_kv_heads * 16 * self.cfg.d_head;
            kvt.as_f32_mut()?[i * block] = toks[i * s] as f32;
        }
        Ok(StepOutput {
            logits: Tensor::f32(logits, vec![b, self.cfg.vocab])?,
            kv: KvCache::from_tensor(&kvt, b, 16)?,
        })
    }
    fn decode(
        &self,
        _tag: &str,
        tokens: &[i32],
        _lengths: &[i32],
        kv: KvCache,
    ) -> Result<StepOutput> {
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let b = tokens.len();
        let mut logits = Vec::with_capacity(b * self.cfg.vocab);
        for &t in tokens {
            logits.extend(self.logits_for(if t == PAD { 0 } else { t }));
        }
        Ok(StepOutput {
            logits: Tensor::f32(logits, vec![b, self.cfg.vocab])?,
            kv,
        })
    }
}
