//! Deterministic mock [`StepEngine`](super::StepEngine) for scheduler and
//! protocol tests (and offline protocol development — the v2 streaming
//! server runs against it without any AOT artifacts).
//!
//! The "LM": for a prompt whose last id is `c`, it emits `c+1`, `c+2`, …
//! until the id leaves byte range, then the `'\n'` stop token. It
//! verifies scheduling and protocol behaviour, not numerics. KV carries a
//! per-slot fingerprint in position 0 so tests can detect slot aliasing.
//!
//! The mock also mirrors the engine's two KV paths for `bench
//! decode-breakdown --smoke`: in the default *resident* mode a host KV is
//! "uploaded" once and then flows step-to-step as a buffer; in
//! `with_host_kv_path` mode every step pays the full round trip. Byte
//! accounting is analytic (computed from the shapes the real paths would
//! move), so the breakdown is deterministic.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::{KvCache, KvStore, ModelConfig, StepOutput, StepProfile, Tensor};
use crate::tokenizer::PAD;

use super::scheduler::StepEngine;

pub struct MockEngine {
    cfg: ModelConfig,
    batch_buckets: Vec<usize>,
    seq_buckets: Vec<usize>,
    /// Artificial per-decode-step delay, so tests can race cancellation
    /// against generation deterministically.
    step_delay: Duration,
    /// A/B: model the legacy host-KV path (full cache both ways per step).
    host_kv_path: bool,
    client: xla::PjRtClient,
    profile: Mutex<StepProfile>,
}

impl Default for MockEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl MockEngine {
    pub fn new() -> Self {
        MockEngine {
            cfg: ModelConfig {
                name: "mock".into(),
                analogue: "mock".into(),
                d_model: 8,
                n_layers: 2,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 16,
                d_head: 2,
                vocab: 300,
                max_seq: 64,
                mlp: "relu".into(),
                pos: "learned".into(),
                critical_density: 0.5,
            },
            batch_buckets: vec![1, 2, 4, 8],
            seq_buckets: vec![16, 32, 64],
            step_delay: Duration::ZERO,
            host_kv_path: false,
            client: xla::PjRtClient::cpu().expect("shim client"),
            profile: Mutex::new(StepProfile::default()),
        }
    }

    /// Sleep this long inside every decode step.
    pub fn with_step_delay(mut self, d: Duration) -> Self {
        self.step_delay = d;
        self
    }

    /// Model the legacy host-KV decode path (the A/B baseline).
    pub fn with_host_kv_path(mut self, host: bool) -> Self {
        self.host_kv_path = host;
        self
    }

    fn logits_for(&self, token: i32) -> Vec<f32> {
        // next token = token + 1 (wrapping to '\n' outside byte range)
        let mut row = vec![0.0f32; self.cfg.vocab];
        let next = if token >= 255 { b'\n' as i32 } else { token + 1 };
        row[next as usize] = 10.0;
        row
    }
}

impl StepEngine for MockEngine {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn batch_buckets(&self) -> &[usize] {
        &self.batch_buckets
    }
    fn seq_buckets(&self) -> &[usize] {
        &self.seq_buckets
    }
    fn prefill_len(&self) -> usize {
        16
    }
    fn profile_snapshot(&self) -> StepProfile {
        *self.profile.lock().unwrap()
    }
    fn reset_profile(&self) {
        *self.profile.lock().unwrap() = StepProfile::default();
    }
    fn prefill(&self, tokens: &Tensor, lengths: &Tensor) -> Result<StepOutput> {
        let b = tokens.shape()[0];
        let s = tokens.shape()[1];
        let toks = tokens.as_i32()?;
        let lens = lengths.as_i32()?;
        let mut logits = Vec::with_capacity(b * self.cfg.vocab);
        for i in 0..b {
            let last = toks[i * s + (lens[i] as usize - 1).min(s - 1)];
            logits.extend(self.logits_for(last));
        }
        let mut kvt = Tensor::zeros_f32(self.cfg.kv_shape(b, 16));
        // fingerprint: first element per slot = first prompt token
        for i in 0..b {
            let block = self.cfg.n_kv_heads * 16 * self.cfg.d_head;
            kvt.as_f32_mut()?[i * block] = toks[i * s] as f32;
        }
        Ok(StepOutput {
            logits: Tensor::f32(logits, vec![b, self.cfg.vocab])?,
            kv: KvCache::from_tensor(&kvt, b, 16)?,
        })
    }
    fn decode(
        &self,
        _tag: &str,
        tokens: &[i32],
        lengths: &[i32],
        kv: KvCache,
    ) -> Result<StepOutput> {
        let t0 = Instant::now();
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let b = tokens.len();
        let mut logits = Vec::with_capacity(b * self.cfg.vocab);
        for &t in tokens {
            logits.extend(self.logits_for(if t == PAD { 0 } else { t }));
        }
        // transfer accounting, mirroring the real engine's two paths
        let kv_bytes = (self.cfg.kv_elems(kv.batch, kv.n) * 4) as u64;
        let io_bytes = (tokens.len() * 4 + lengths.len() * 4) as u64;
        let logits_bytes = (b * self.cfg.vocab * 4) as u64;
        let kv_out = if self.host_kv_path {
            // legacy path: cache crosses the boundary both ways each step
            let mut p = self.profile.lock().unwrap();
            p.h2d_bytes += io_bytes + kv_bytes;
            p.d2h_bytes += logits_bytes + kv_bytes;
            p.decode_steps += 1;
            kv
        } else {
            // resident path: the cache is uploaded once (when it arrives
            // as a host literal after surgery) and then stays put
            let (batch, n) = (kv.batch, kv.n);
            let (store, uploaded) = match kv.store {
                KvStore::Buf(buf) => (KvStore::Buf(buf), 0),
                KvStore::Lit(lit) => (
                    KvStore::Buf(self.client.buffer_from_host_literal(None, &lit)?),
                    kv_bytes,
                ),
            };
            let mut p = self.profile.lock().unwrap();
            p.h2d_bytes += io_bytes + uploaded;
            p.d2h_bytes += logits_bytes;
            p.decode_steps += 1;
            KvCache { store, batch, n }
        };
        self.profile.lock().unwrap().compute_ns += t0.elapsed().as_nanos() as u64;
        Ok(StepOutput {
            logits: Tensor::f32(logits, vec![b, self.cfg.vocab])?,
            kv: kv_out,
        })
    }
}
