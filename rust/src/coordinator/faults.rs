//! Deterministic fault injection + the retry policy the scheduler runs
//! under.
//!
//! A production step loop has to survive the engine: transient PJRT
//! execute errors, one poisoned request that fails every batch it rides
//! in, non-finite logits, stalls, and pool-allocation failures. None of
//! these are reproducible on demand from real hardware, so this module
//! scripts them: a [`FaultScript`] names *which* engine calls (by
//! ordinal) and *which* requests (by token band — the engine never sees
//! request ids, but disjoint prompt bands make requests identifiable
//! from the decode inputs) misbehave, and a [`FaultInjector`] replays
//! that script from inside the engine hooks ([`MockEngine::with_faults`]
//! and the real engine's validation bails share the same recovery
//! contract).
//!
//! The contract that makes faults *recoverable*: paged entry calls take
//! the pool by value, so an `Err` would otherwise lose the only KV
//! handle. Every injection (and every real-engine validation bail)
//! stashes the pool first; the scheduler drains it back via
//! [`StepEngine::recover_kv`](super::scheduler::StepEngine::recover_kv)
//! before retrying. A fault with no recoverable pool is fatal.
//!
//! [`MockEngine::with_faults`]: super::mock::MockEngine::with_faults

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::Result;

use crate::runtime::PagedKv;
use crate::substrate::sync::lock_clean;
use crate::tokenizer::PAD;

/// A classified engine failure, surfaced through `anyhow` so the
/// scheduler can `downcast_ref` it back out of an error chain.
///
/// * `transient: true` — worth retrying in place (execute hiccup, stall
///   converted by the watchdog, allocation race).
/// * `transient: false` — persistent for this batch composition; retry
///   is pointless, go straight to blame isolation.
///
/// Errors that are *not* a `StepFault` (anything the engine's own
/// validation produced) get retry-then-bisect treatment too: unknown
/// failures are assumed transient until retries exhaust, then treated
/// as request-dependent.
#[derive(Debug, Clone)]
pub struct StepFault {
    pub transient: bool,
    pub msg: String,
}

impl StepFault {
    /// Build a transient fault as an `anyhow::Error`.
    pub fn transient(msg: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(StepFault { transient: true, msg: msg.into() })
    }

    /// Build a persistent (request-dependent) fault as an `anyhow::Error`.
    pub fn persistent(msg: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(StepFault { transient: false, msg: msg.into() })
    }

    /// Classify an error chain: `Some(true)` transient, `Some(false)`
    /// persistent, `None` unclassified (not injected/classified by the
    /// engine).
    pub fn classify(err: &anyhow::Error) -> Option<bool> {
        err.downcast_ref::<StepFault>().map(|f| f.transient)
    }
}

impl fmt::Display for StepFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} engine fault: {}",
            if self.transient { "transient" } else { "persistent" },
            self.msg
        )
    }
}

impl std::error::Error for StepFault {}

/// Bounded-retry policy for engine step calls.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Retries per engine call before escalating (transient faults) or
    /// bisecting (persistent/unclassified faults).
    pub max_retries: u32,
    /// First backoff sleep, milliseconds.
    pub backoff_ms: f64,
    /// Exponential backoff multiplier per attempt.
    pub multiplier: f64,
    /// Engine calls slower than this count as stalls in
    /// `stats.faults.watchdog_stalls` (telemetry — a blocking call
    /// cannot be aborted, so injected stalls *return* a transient error
    /// after sleeping and ride the normal retry path).
    pub watchdog_ms: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            backoff_ms: 2.0,
            multiplier: 2.0,
            watchdog_ms: 500.0,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (0-based): `backoff_ms * multiplier^attempt`.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let ms = self.backoff_ms * self.multiplier.powi(attempt as i32);
        Duration::from_secs_f64(ms.max(0.0) / 1000.0)
    }
}

/// A deterministic schedule of engine misbehavior. Call ordinals are
/// 0-based and counted per entry point at the engine (retries advance
/// them — the script addresses *calls*, not scheduler steps). Token
/// bands are inclusive and keyed on the decode inputs, so a script can
/// target "the request generating in [120, 129]" without the engine
/// knowing request ids. `PAD` never matches a band, so bisection probes
/// that mask the poisoned slot out succeed.
#[derive(Debug, Clone, Default)]
pub struct FaultScript {
    /// Decode calls that fail once each with a transient error.
    pub transient_decode_calls: Vec<u64>,
    /// Prefill-chunk calls that fail once each with a transient error.
    pub transient_prefill_calls: Vec<u64>,
    /// Any decode whose inputs contain a token in this inclusive band
    /// fails persistently (the poisoned request).
    pub poison_token_range: Option<(i32, i32)>,
    /// Logits rows of slots whose input token lands in this inclusive
    /// band are corrupted to NaN (the quarantine target).
    pub nan_token_range: Option<(i32, i32)>,
    /// Decode calls that sleep `stall` and then fail transiently — the
    /// watchdog-visible stall-turned-retryable-fault.
    pub stall_decode_calls: Vec<u64>,
    pub stall: Duration,
    /// Fail the first `n` pool allocations.
    pub pool_alloc_failures: u32,
}

fn in_band(token: i32, band: Option<(i32, i32)>) -> bool {
    match band {
        Some((lo, hi)) => token != PAD && token >= lo && token <= hi,
        None => false,
    }
}

/// Replays a [`FaultScript`] from inside an engine's step entry points.
/// All state is interior (atomic counters + a pool stash) so a shared
/// reference from inside `&self` engine methods suffices.
#[derive(Debug, Default)]
pub struct FaultInjector {
    script: FaultScript,
    decode_calls: AtomicU64,
    prefill_calls: AtomicU64,
    pool_calls: AtomicU64,
    injected: AtomicU64,
    stash: Mutex<Option<PagedKv>>,
}

impl FaultInjector {
    pub fn new(script: FaultScript) -> FaultInjector {
        FaultInjector { script, ..Default::default() }
    }

    /// Total faults injected so far (all classes).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Park the pool so the scheduler can recover it after an `Err`.
    pub fn stash_kv(&self, kv: PagedKv) {
        *lock_clean(&self.stash) = Some(kv);
    }

    /// Drain the parked pool (the engine's `recover_kv` hook).
    pub fn take_stash(&self) -> Option<PagedKv> {
        lock_clean(&self.stash).take()
    }

    /// Gate one decode call: returns the pool untouched when this call
    /// is clean, otherwise stashes it and returns the scripted fault.
    pub fn check_decode(&self, tokens: &[i32], kv: PagedKv) -> Result<PagedKv> {
        let call = self.decode_calls.fetch_add(1, Ordering::Relaxed);
        if self.script.stall_decode_calls.contains(&call) {
            std::thread::sleep(self.script.stall);
            self.injected.fetch_add(1, Ordering::Relaxed);
            self.stash_kv(kv);
            return Err(StepFault::transient(format!(
                "injected stall ({}ms) at decode call {call}",
                self.script.stall.as_millis()
            )));
        }
        if self.script.transient_decode_calls.contains(&call) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            self.stash_kv(kv);
            return Err(StepFault::transient(format!(
                "injected transient execute error at decode call {call}"
            )));
        }
        if let Some(&bad) = tokens
            .iter()
            .find(|&&t| in_band(t, self.script.poison_token_range))
        {
            self.injected.fetch_add(1, Ordering::Relaxed);
            self.stash_kv(kv);
            return Err(StepFault::persistent(format!(
                "injected poisoned-request fault (token {bad}) at decode call {call}"
            )));
        }
        Ok(kv)
    }

    /// Gate one prefill-chunk call (transient-ordinal faults only).
    pub fn check_prefill(&self, kv: PagedKv) -> Result<PagedKv> {
        let call = self.prefill_calls.fetch_add(1, Ordering::Relaxed);
        if self.script.transient_prefill_calls.contains(&call) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            self.stash_kv(kv);
            return Err(StepFault::transient(format!(
                "injected transient execute error at prefill call {call}"
            )));
        }
        Ok(kv)
    }

    /// Gate one pool allocation (no pool exists yet, so nothing to stash).
    pub fn check_pool_alloc(&self) -> Result<()> {
        let call = self.pool_calls.fetch_add(1, Ordering::Relaxed);
        if call < self.script.pool_alloc_failures as u64 {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return Err(StepFault::transient(format!(
                "injected pool-allocation failure {call}"
            )));
        }
        Ok(())
    }

    /// Corrupt the logits rows of every slot whose input token falls in
    /// the scripted NaN band. `logits` is row-major `[b, vocab]`.
    pub fn corrupt_logits(&self, tokens: &[i32], logits: &mut [f32], vocab: usize) {
        if self.script.nan_token_range.is_none() {
            return;
        }
        for (i, &t) in tokens.iter().enumerate() {
            if in_band(t, self.script.nan_token_range) {
                self.injected.fetch_add(1, Ordering::Relaxed);
                for v in &mut logits[i * vocab..(i + 1) * vocab] {
                    *v = f32::NAN;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;

    fn tiny_kv() -> PagedKv {
        PagedKv::from_tensor(&Tensor::zeros_f32(vec![1, 2, 2, 1, 2, 1]), 2, 2)
            .expect("tiny pool")
    }

    #[test]
    fn classify_roundtrip() {
        let t = StepFault::transient("hiccup");
        assert_eq!(StepFault::classify(&t), Some(true));
        let p = StepFault::persistent("poisoned");
        assert_eq!(StepFault::classify(&p), Some(false));
        let other = anyhow::anyhow!("engine validation");
        assert_eq!(StepFault::classify(&other), None);
        // classification survives an anyhow context chain
        let wrapped = t.context("decode step 7");
        assert_eq!(StepFault::classify(&wrapped), Some(true));
    }

    #[test]
    fn transient_decode_fails_once_and_stashes() {
        let inj = FaultInjector::new(FaultScript {
            transient_decode_calls: vec![1],
            ..Default::default()
        });
        // call 0 clean
        assert!(inj.check_decode(&[5], tiny_kv()).is_ok());
        // call 1 faults and parks the pool
        let err = inj.check_decode(&[5], tiny_kv()).unwrap_err();
        assert_eq!(StepFault::classify(&err), Some(true));
        assert!(inj.take_stash().is_some());
        assert!(inj.take_stash().is_none(), "stash drains");
        // call 2 clean again — the ordinal advanced past the script
        assert!(inj.check_decode(&[5], tiny_kv()).is_ok());
        assert_eq!(inj.injected(), 1);
    }

    #[test]
    fn poison_band_is_persistent_and_ignores_pad() {
        let inj = FaultInjector::new(FaultScript {
            poison_token_range: Some((120, 129)),
            ..Default::default()
        });
        let err = inj.check_decode(&[30, 125, 40], tiny_kv()).unwrap_err();
        assert_eq!(StepFault::classify(&err), Some(false));
        assert!(inj.take_stash().is_some());
        // a probe excluding the poisoned slot (PAD in its place) is clean
        assert!(inj.check_decode(&[30, PAD, 40], tiny_kv()).is_ok());
    }

    #[test]
    fn pool_alloc_fails_first_n() {
        let inj = FaultInjector::new(FaultScript {
            pool_alloc_failures: 2,
            ..Default::default()
        });
        assert!(inj.check_pool_alloc().is_err());
        assert!(inj.check_pool_alloc().is_err());
        assert!(inj.check_pool_alloc().is_ok());
    }

    #[test]
    fn nan_band_corrupts_only_matching_rows() {
        let inj = FaultInjector::new(FaultScript {
            nan_token_range: Some((50, 59)),
            ..Default::default()
        });
        let mut logits = vec![1.0f32; 3 * 4];
        inj.corrupt_logits(&[10, 55, 20], &mut logits, 4);
        assert!(logits[0..4].iter().all(|v| v.is_finite()));
        assert!(logits[4..8].iter().all(|v| v.is_nan()));
        assert!(logits[8..12].iter().all(|v| v.is_finite()));
    }

    #[test]
    fn backoff_grows_exponentially() {
        let p = RetryPolicy { backoff_ms: 2.0, multiplier: 2.0, ..Default::default() };
        assert_eq!(p.backoff(0), Duration::from_millis(2));
        assert_eq!(p.backoff(1), Duration::from_millis(4));
        assert_eq!(p.backoff(2), Duration::from_millis(8));
    }
}
