//! SLO-aware overload control: admission by predicted KV block demand,
//! victim selection for preemption under block-pool pressure, host swap
//! of a victim's KV blocks, and the deadline-slack urgency heuristic the
//! planner uses to bias the prefill/decode token split.
//!
//! The scheduler threads these pieces together: `predicted_blocks` +
//! the block pool's reservation ledger gate admission, `Rank` decides
//! who preempts whom, `HostSwap` + `read_block`/`write_block` carry a
//! long victim's KV to host memory and back, and `deadline_slack_urgent`
//! marks requests whose slack is shrinking so the planner favors them.

use std::cmp::Ordering;

/// What happens to a request whose predicted block demand exceeds the
/// unreserved free pool (and preemption cannot make room).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PressurePolicy {
    /// Leave it queued; retry next step when blocks free up.
    Defer,
    /// Fail it immediately with `FinishReason::Rejected`.
    Reject,
}

/// Overload-control policy knobs, carried by `SchedulerConfig`.
#[derive(Debug, Clone, Copy)]
pub struct OverloadConfig {
    /// Gate admission on predicted KV block demand vs the unreserved
    /// free pool, instead of slot availability alone.
    pub admission: bool,
    pub on_pressure: PressurePolicy,
    /// Preempt lowest-priority/latest-deadline running requests when a
    /// strictly higher-ranked arrival cannot otherwise be admitted.
    pub preemption: bool,
    /// Victims holding at least this many complete KV blocks have them
    /// swapped to host memory and restored on resume instead of being
    /// recomputed (0 disables the swap path).
    pub swap_min_blocks: usize,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        OverloadConfig {
            admission: true,
            on_pressure: PressurePolicy::Defer,
            preemption: true,
            swap_min_blocks: 4,
        }
    }
}

impl OverloadConfig {
    /// The reject-at-admission baseline: same block-demand gate, but no
    /// preemption and pressure rejects instead of deferring. Used as the
    /// control arm of the overload bench.
    pub fn reject_only() -> Self {
        OverloadConfig {
            admission: true,
            on_pressure: PressurePolicy::Reject,
            preemption: false,
            swap_min_blocks: 0,
        }
    }

    pub fn policy_name(&self) -> &'static str {
        if !self.admission {
            "off"
        } else if self.preemption {
            "preempt_resume"
        } else if self.on_pressure == PressurePolicy::Reject {
            "reject_only"
        } else {
            "defer_only"
        }
    }
}

/// KV blocks a request will need over its whole lifetime: prompt plus
/// budgeted new tokens, clamped to the model's context window.
pub fn predicted_blocks(
    prompt_len: usize,
    max_new: usize,
    block: usize,
    max_total: usize,
) -> usize {
    let tokens = (prompt_len + max_new).min(max_total).max(1);
    tokens.div_ceil(block)
}

/// Scheduling rank, used both to order preemption victims and to decide
/// whether an arrival is allowed to preempt at all.
#[derive(Debug, Clone, Copy)]
pub struct Rank {
    pub priority: i32,
    /// Seconds until the deadline at ranking time (None = no deadline).
    pub slack: Option<f64>,
}

impl Rank {
    /// True when `self` strictly outranks `other`: strictly higher
    /// priority, or equal priority with a strictly earlier deadline (no
    /// deadline counts as latest). Arrivals may only preempt victims
    /// they strictly outrank, which rules out equal-rank ping-pong.
    pub fn outranks(&self, other: &Rank) -> bool {
        if self.priority != other.priority {
            return self.priority > other.priority;
        }
        match (self.slack, other.slack) {
            (Some(a), Some(b)) => a < b,
            (Some(_), None) => true,
            _ => false,
        }
    }
}

/// Victim order over `(rank, admission_seq)`: the first element under
/// this ordering is preempted first — lowest priority, then latest
/// deadline (no deadline = latest of all), then youngest admission.
pub fn victim_cmp(a: &(Rank, u64), b: &(Rank, u64)) -> Ordering {
    a.0.priority
        .cmp(&b.0.priority)
        .then_with(|| cmp_slack_latest_first(a.0.slack, b.0.slack))
        .then_with(|| b.1.cmp(&a.1))
}

fn cmp_slack_latest_first(a: Option<f64>, b: Option<f64>) -> Ordering {
    match (a, b) {
        (None, None) => Ordering::Equal,
        (None, Some(_)) => Ordering::Less,
        (Some(_), None) => Ordering::Greater,
        (Some(x), Some(y)) => y.partial_cmp(&x).unwrap_or(Ordering::Equal),
    }
}

/// A running request is urgent when its remaining deadline slack no
/// longer covers its remaining decode steps at the observed inter-token
/// latency, with a 2x safety factor.
pub fn deadline_slack_urgent(slack_s: f64, itl_s: f64, remaining_tokens: usize) -> bool {
    slack_s < 2.0 * itl_s * remaining_tokens as f64
}

/// Host-resident copy of a preempted request's complete KV blocks, in
/// table order. Restored into freshly allocated private blocks on
/// resume so the tail recompute starts past them.
#[derive(Debug, Clone, Default)]
pub struct HostSwap {
    pub blocks: Vec<Vec<f32>>,
}

impl HostSwap {
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.len() * 4).sum()
    }
}

/// Floats of pool block `blk` across all layers and K/V planes. The
/// pool tensor is laid out `[L, 2, P, G, bs, dh]`; `block_row` is the
/// per-plane block stride `G * bs * dh` and `pool_blocks` is `P`.
pub fn read_block(
    pool: &[f32],
    layers: usize,
    pool_blocks: usize,
    block_row: usize,
    blk: usize,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(layers * 2 * block_row);
    for l in 0..layers {
        for c in 0..2 {
            let base = ((l * 2 + c) * pool_blocks + blk) * block_row;
            out.extend_from_slice(&pool[base..base + block_row]);
        }
    }
    out
}

/// Inverse of `read_block`: write one block's saved floats back into
/// the pool tensor at (possibly different) block index `blk`.
pub fn write_block(
    pool: &mut [f32],
    layers: usize,
    pool_blocks: usize,
    block_row: usize,
    blk: usize,
    data: &[f32],
) {
    assert_eq!(data.len(), layers * 2 * block_row, "swap block size mismatch");
    for l in 0..layers {
        for c in 0..2 {
            let base = ((l * 2 + c) * pool_blocks + blk) * block_row;
            let src = (l * 2 + c) * block_row;
            pool[base..base + block_row].copy_from_slice(&data[src..src + block_row]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predicted_blocks_rounds_up_and_clamps_to_context() {
        assert_eq!(predicted_blocks(16, 0, 16, 1024), 1);
        assert_eq!(predicted_blocks(17, 0, 16, 1024), 2);
        assert_eq!(predicted_blocks(10, 10, 16, 1024), 2);
        // clamped: prompt+max_new past the window costs only window blocks
        assert_eq!(predicted_blocks(60, 100, 16, 64), 4);
        // degenerate empty request still needs one block
        assert_eq!(predicted_blocks(0, 0, 16, 64), 1);
    }

    #[test]
    fn outranks_requires_strictly_higher_rank() {
        let hi = Rank { priority: 5, slack: None };
        let lo = Rank { priority: 0, slack: Some(0.1) };
        assert!(hi.outranks(&lo));
        assert!(!lo.outranks(&hi));
        // equal priority: earlier deadline wins, None loses to Some
        let tight = Rank { priority: 0, slack: Some(0.1) };
        let loose = Rank { priority: 0, slack: Some(5.0) };
        let none = Rank { priority: 0, slack: None };
        assert!(tight.outranks(&loose));
        assert!(!loose.outranks(&tight));
        assert!(tight.outranks(&none));
        assert!(!none.outranks(&tight));
        // equal rank never preempts (no ping-pong)
        assert!(!tight.outranks(&tight));
        assert!(!none.outranks(&none));
    }

    #[test]
    fn victim_order_prefers_low_priority_late_deadline_young() {
        let mut v = vec![
            (Rank { priority: 5, slack: Some(0.5) }, 1u64),
            (Rank { priority: 0, slack: Some(0.2) }, 2),
            (Rank { priority: 0, slack: None }, 3),
            (Rank { priority: 0, slack: Some(9.0) }, 4),
            (Rank { priority: 0, slack: None }, 5),
        ];
        v.sort_by(victim_cmp);
        let seqs: Vec<u64> = v.iter().map(|x| x.1).collect();
        // no-deadline victims go first (youngest of them first), then the
        // loosest deadline, then the tightest; high priority last
        assert_eq!(seqs, vec![5, 3, 4, 2, 1]);
    }

    #[test]
    fn urgency_tracks_remaining_work() {
        // 10 tokens left at 10ms/token needs 0.2s of slack under the 2x factor
        assert!(deadline_slack_urgent(0.15, 0.01, 10));
        assert!(!deadline_slack_urgent(0.25, 0.01, 10));
        // nothing left to decode is never urgent
        assert!(!deadline_slack_urgent(0.0, 0.01, 0));
    }

    #[test]
    fn block_swap_roundtrips_through_a_host_copy() {
        let (layers, pool_blocks, block_row) = (2usize, 4usize, 6usize);
        let n = layers * 2 * pool_blocks * block_row;
        let mut pool: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let saved = read_block(&pool, layers, pool_blocks, block_row, 2);
        assert_eq!(saved.len(), layers * 2 * block_row);
        let swap = HostSwap { blocks: vec![saved.clone()] };
        assert_eq!(swap.bytes(), saved.len() * 4);
        // restoring into a different block index lands the same floats
        write_block(&mut pool, layers, pool_blocks, block_row, 3, &saved);
        let back = read_block(&pool, layers, pool_blocks, block_row, 3);
        assert_eq!(back, saved);
        // other blocks untouched
        let untouched = read_block(&pool, layers, pool_blocks, block_row, 1);
        for (i, x) in untouched.iter().enumerate() {
            let (lc, rem) = (i / block_row, i % block_row);
            assert_eq!(*x, (lc * pool_blocks * block_row + block_row + rem) as f32);
        }
    }
}
