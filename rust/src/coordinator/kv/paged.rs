//! Paged KV block manager: the allocator behind the serving scheduler.
//!
//! Physical KV memory is ONE pool tensor `[L, 2, P, G, bs, dh]` that
//! lives on the engine for the whole process (shape fixed at compile
//! time — the CUDA-graph analogue of vLLM's preallocated block pool).
//! This module manages the *metadata*: which of the `P` blocks each
//! request's logical cache maps to.
//!
//! * **Ref-counted blocks + free list.** A block may back several
//!   requests at once (shared prompt prefix, forked sequences); it
//!   returns to the allocator when the last reference drops. Block 0 is
//!   reserved as the *null block*: padding slots aim every table entry
//!   at it, so their blind per-step writes can never land in a live
//!   request's memory.
//! * **Hash-keyed prefix cache.** A *full* block whose content is
//!   determined by a token prefix is published under the chain hash of
//!   that prefix ([`chain_hash`]). A later request whose prompt starts
//!   with the same tokens re-uses the physical block (ref-count bump, no
//!   prefill compute) — across co-resident requests AND across time:
//!   freed published blocks are retained in a cached-free list and only
//!   evicted (oldest first) under pool pressure. Generated tokens
//!   publish too, so a multi-turn follow-up whose prompt embeds the
//!   previous turn's output also hits.
//! * **Copy-on-write.** Writing into a block another table still
//!   references would corrupt the neighbour; [`BlockPool::make_private`]
//!   detects sharing and hands the caller a `(src, dst)` pair to copy on
//!   the engine before the write proceeds. Publication is only ever
//!   content-truthful: blocks publish strictly after their last position
//!   is written, and shared blocks are never written (the single benign
//!   exception — re-computing the final token of a fully-cached prompt —
//!   rewrites bit-identical content).
//!
//! The pool never moves KV bytes itself; it returns block ids and COW
//! pairs, and the scheduler drives the engine's block-granular copies.
//! Invariants (no double free, no aliasing across non-sharing requests,
//! reclaim-to-empty) are enforced by the property tests below.

use std::collections::{HashMap, VecDeque};

use anyhow::{bail, Result};

/// Index into the physical pool (`0` = the reserved null block).
pub type BlockId = u32;

/// FNV-1a chain hash over token ids: the key of a full block is the
/// hash of its own `block_size` tokens chained onto its predecessor's
/// key, so equal keys imply equal token *prefixes*, not just equal
/// block content — position sensitivity for free.
pub fn chain_hash(parent: u64, tokens: &[i32]) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut h: u64 = 0xcbf29ce484222325;
    for b in parent.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(PRIME);
    }
    for &t in tokens {
        for b in t.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(PRIME);
        }
    }
    h
}

/// One request's logical-to-physical mapping. Block `i` backs token
/// positions `[i * bs, (i + 1) * bs)`.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    pub blocks: Vec<BlockId>,
    /// Blocks `[0, published)` have been offered to the prefix cache
    /// (published, or skipped on hash collision with an earlier twin).
    published: usize,
    /// Chain-hash state covering the first `published` blocks.
    chain: u64,
    /// Set when the table COW-diverged INSIDE its hashed prefix: the
    /// chain state no longer describes this table's actual stream, so
    /// publishing further blocks would index them under a lying prefix.
    /// Frozen tables simply stop publishing (correct, just less cached).
    publish_frozen: bool,
}

impl BlockTable {
    /// Tokens the table can hold before another block is needed.
    pub fn capacity(&self, block_size: usize) -> usize {
        self.blocks.len() * block_size
    }

    /// Physical block backing logical position `pos`.
    pub fn block_of(&self, pos: usize, block_size: usize) -> Option<BlockId> {
        self.blocks.get(pos / block_size).copied()
    }

    /// Flatten into an i32 row of `width` entries, padding with the null
    /// block — the per-slot row of the engines' `block_table` input.
    pub fn row(&self, width: usize) -> Vec<i32> {
        let mut r: Vec<i32> = self.blocks.iter().map(|&b| b as i32).collect();
        r.resize(width, 0);
        r
    }
}

/// Outcome of a [`BlockPool::make_private`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MakePrivate {
    /// Sole owner already — write straight in.
    Private,
    /// Shared: the table now maps `dst`; the caller must copy block
    /// `src` -> `dst` on the engine before any write.
    Cow { src: BlockId, dst: BlockId },
    /// No block left to copy into.
    Exhausted,
}

/// Allocator telemetry — the replacement for the retired contiguous-era
/// `kv_rebuilds`/`regroups`/`slot_copies` counters (`stats.kv`).
#[derive(Debug, Default, Clone)]
pub struct BlockStats {
    /// Full-block prefix-cache lookups during prompt allocation.
    pub prefix_queries: u64,
    /// Lookups that re-used a cached physical block.
    pub prefix_hits: u64,
    /// Prompt tokens those hits made skippable (hits * block size).
    pub prefix_tokens_reused: u64,
    /// Copy-on-write block copies (divergent write into a shared block).
    pub cow_copies: u64,
    /// Published blocks evicted from the cached-free list under pressure.
    pub evictions: u64,
    /// Fresh block grants (prompt allocation + decode growth + COW).
    pub block_allocs: u64,
    /// High-water mark of referenced blocks.
    pub peak_in_use: usize,
}

pub struct BlockPool {
    block: usize,
    n_blocks: usize,
    ref_count: Vec<u32>,
    /// The published hash a block is indexed under (only the `by_hash`
    /// winner carries it; collision losers stay unpublished).
    hash_of: Vec<Option<u64>>,
    by_hash: HashMap<u64, BlockId>,
    /// Unpublished free blocks (LIFO).
    free: Vec<BlockId>,
    /// Ref-count-0 blocks still serving the prefix cache; evicted oldest
    /// first when `free` runs dry.
    cached_free: VecDeque<BlockId>,
    in_use: usize,
    /// Inflight admission reservations by request id: blocks a running
    /// request is predicted to still need (prompt remainder + decode
    /// growth). Purely advisory — grants never consult it; the admission
    /// controller gates new requests on [`BlockPool::available_unreserved`]
    /// so already-admitted requests keep their room to grow.
    reservations: HashMap<u64, usize>,
    pub stats: BlockStats,
}

impl BlockPool {
    /// `n_blocks` physical blocks of `block` token positions each; block
    /// 0 is reserved as the null block and never granted.
    pub fn new(n_blocks: usize, block: usize) -> Result<BlockPool> {
        if n_blocks < 2 || block == 0 {
            bail!("kv pool needs >= 2 blocks (got {n_blocks}) and a nonzero block size");
        }
        Ok(BlockPool {
            block,
            n_blocks,
            // null block pinned with a permanent self-reference
            ref_count: std::iter::once(1u32)
                .chain(std::iter::repeat(0).take(n_blocks - 1))
                .collect(),
            hash_of: vec![None; n_blocks],
            by_hash: HashMap::new(),
            free: (1..n_blocks as BlockId).rev().collect(),
            cached_free: VecDeque::new(),
            in_use: 0,
            reservations: HashMap::new(),
            stats: BlockStats::default(),
        })
    }

    pub fn block_size(&self) -> usize {
        self.block
    }

    pub fn total_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Blocks currently referenced by at least one table (null excluded).
    pub fn blocks_in_use(&self) -> usize {
        self.in_use
    }

    /// Ref-count-0 blocks retained for the prefix cache.
    pub fn cached_blocks(&self) -> usize {
        self.cached_free.len()
    }

    /// Never-published / evicted free blocks (the raw free list —
    /// disjoint from [`BlockPool::cached_blocks`]).
    pub fn free_list_len(&self) -> usize {
        self.free.len()
    }

    /// Blocks immediately grantable (free list + evictable cached).
    pub fn available(&self) -> usize {
        self.free.len() + self.cached_free.len()
    }

    pub fn utilization(&self) -> f64 {
        self.in_use as f64 / (self.n_blocks - 1).max(1) as f64
    }

    /// Record (or update) request `id`'s outstanding block reservation;
    /// 0 clears the entry.
    pub fn set_reservation(&mut self, id: u64, blocks: usize) {
        if blocks == 0 {
            self.reservations.remove(&id);
        } else {
            self.reservations.insert(id, blocks);
        }
    }

    /// Drop request `id`'s reservation (finish / cancel / preempt).
    pub fn release_reservation(&mut self, id: u64) {
        self.reservations.remove(&id);
    }

    /// Sum of all outstanding reservations.
    pub fn reserved_total(&self) -> usize {
        self.reservations.values().sum()
    }

    /// Blocks grantable to a NEW request once every admitted request's
    /// reserved growth is honoured — the admission controller's gate.
    pub fn available_unreserved(&self) -> usize {
        self.available().saturating_sub(self.reserved_total())
    }

    fn note_retained(&mut self) {
        self.in_use += 1;
        self.stats.peak_in_use = self.stats.peak_in_use.max(self.in_use);
    }

    /// Bump an existing block's ref count (prefix hit / fork), reviving
    /// it from the cached-free list when necessary.
    fn retain(&mut self, b: BlockId) {
        if self.ref_count[b as usize] == 0 {
            self.cached_free.retain(|&x| x != b);
            self.note_retained();
        }
        self.ref_count[b as usize] += 1;
    }

    /// Grant a fresh (content-don't-care) block, evicting from the
    /// prefix cache if the free list is dry. `None` = truly exhausted.
    fn take_fresh(&mut self) -> Option<BlockId> {
        let b = match self.free.pop() {
            Some(b) => b,
            None => {
                let b = self.cached_free.pop_front()?;
                if let Some(h) = self.hash_of[b as usize].take() {
                    if self.by_hash.get(&h) == Some(&b) {
                        self.by_hash.remove(&h);
                    }
                }
                self.stats.evictions += 1;
                b
            }
        };
        debug_assert_eq!(self.ref_count[b as usize], 0);
        self.ref_count[b as usize] = 1;
        self.stats.block_allocs += 1;
        self.note_retained();
        Some(b)
    }

    /// Allocate a table covering `prompt`, re-using cached prefix blocks
    /// where the chain hash matches. Returns `None` (with nothing leaked)
    /// when the pool cannot cover the prompt; otherwise the table plus
    /// the number of prompt tokens whose KV is already physically present
    /// (a multiple of the block size — the prefill chunks to skip).
    pub fn alloc_prompt(&mut self, prompt: &[i32]) -> Result<Option<(BlockTable, usize)>> {
        let bs = self.block;
        let mut table = BlockTable::default();
        let full = prompt.len() / bs;
        let mut chain = 0u64;
        for i in 0..full {
            self.stats.prefix_queries += 1;
            let h = chain_hash(chain, &prompt[i * bs..(i + 1) * bs]);
            match self.by_hash.get(&h).copied() {
                Some(b) => {
                    self.retain(b);
                    table.blocks.push(b);
                    chain = h;
                    self.stats.prefix_hits += 1;
                    self.stats.prefix_tokens_reused += bs as u64;
                }
                None => break,
            }
        }
        table.published = table.blocks.len();
        table.chain = chain;
        let cached = table.published * bs;
        let need = prompt.len().div_ceil(bs);
        while table.blocks.len() < need {
            match self.take_fresh() {
                Some(b) => table.blocks.push(b),
                None => {
                    // roll back: nothing may leak on a failed admission
                    self.free_table(table);
                    return Ok(None);
                }
            }
        }
        Ok(Some((table, cached)))
    }

    /// Grow a table by one block (decode past the current capacity).
    /// `false` = pool exhausted (caller decides the policy).
    pub fn append_block(&mut self, table: &mut BlockTable) -> bool {
        match self.take_fresh() {
            Some(b) => {
                table.blocks.push(b);
                true
            }
            None => false,
        }
    }

    /// Ensure the block backing `table.blocks[idx]` is exclusively owned
    /// before a divergent write. On sharing, allocates a replacement and
    /// remaps the table; the caller must perform the returned engine copy.
    pub fn make_private(&mut self, table: &mut BlockTable, idx: usize) -> Result<MakePrivate> {
        let Some(&src) = table.blocks.get(idx) else {
            bail!("make_private: block index {idx} out of table ({})", table.blocks.len());
        };
        if self.ref_count[src as usize] <= 1 {
            return Ok(MakePrivate::Private);
        }
        let Some(dst) = self.take_fresh() else {
            return Ok(MakePrivate::Exhausted);
        };
        self.ref_count[src as usize] -= 1;
        table.blocks[idx] = dst;
        if idx < table.published {
            // divergence inside the hashed prefix: the chain no longer
            // matches this table's stream — never publish from it again
            table.publish_frozen = true;
        }
        self.stats.cow_copies += 1;
        Ok(MakePrivate::Cow { src, dst })
    }

    /// Share every block of `table` with a new table (beam/n-best forks).
    /// The fork inherits the publish chain (valid while the streams still
    /// agree); the moment either table COW-diverges inside the hashed
    /// prefix, [`BlockPool::make_private`] freezes that table's
    /// publishing so no block is ever indexed under a lying prefix.
    pub fn fork(&mut self, table: &BlockTable) -> BlockTable {
        for &b in &table.blocks {
            self.retain(b);
        }
        BlockTable {
            blocks: table.blocks.clone(),
            published: table.published,
            chain: table.chain,
            publish_frozen: table.publish_frozen,
        }
    }

    /// Publish any newly-completed full blocks of `table` into the prefix
    /// cache. `tokens` is the request's full known token stream (prompt +
    /// generated); only blocks whose every position is written — i.e.
    /// `tokens.len() / block_size` blocks — are eligible. On a hash
    /// collision with an already-published twin the twin wins and this
    /// block simply stays out of the index.
    pub fn publish_full_blocks(&mut self, table: &mut BlockTable, tokens: &[i32]) {
        if table.publish_frozen {
            return;
        }
        let bs = self.block;
        let full = (tokens.len() / bs).min(table.blocks.len());
        while table.published < full {
            let i = table.published;
            let h = chain_hash(table.chain, &tokens[i * bs..(i + 1) * bs]);
            let b = table.blocks[i];
            if !self.by_hash.contains_key(&h) && self.hash_of[b as usize].is_none() {
                self.by_hash.insert(h, b);
                self.hash_of[b as usize] = Some(h);
            }
            table.chain = h;
            table.published += 1;
        }
    }

    /// Drop every reference the table holds. Published blocks whose last
    /// reference drops are RETAINED in the cached-free list (the prefix
    /// cache outliving the request is the multi-turn win); unpublished
    /// ones return to the free list.
    pub fn free_table(&mut self, table: BlockTable) {
        for b in table.blocks {
            let rc = &mut self.ref_count[b as usize];
            assert!(*rc > 0, "double free of kv block {b}");
            *rc -= 1;
            if *rc == 0 {
                self.in_use -= 1;
                if self.hash_of[b as usize].is_some() {
                    self.cached_free.push_back(b);
                } else {
                    self.free.push(b);
                }
            }
        }
    }

    /// Test/diagnostic invariant sweep: every block is in exactly one
    /// state, ref counts equal live references, the hash index is sound.
    #[cfg(test)]
    fn check_invariants(&self, live: &[&BlockTable]) -> Result<(), String> {
        let mut refs = vec![0u32; self.n_blocks];
        for t in live {
            for &b in &t.blocks {
                refs[b as usize] += 1;
            }
        }
        for b in 1..self.n_blocks {
            if refs[b] != self.ref_count[b] {
                return Err(format!(
                    "block {b}: {} table refs but ref_count {}",
                    refs[b], self.ref_count[b]
                ));
            }
            let in_free = self.free.contains(&(b as BlockId));
            let in_cached = self.cached_free.contains(&(b as BlockId));
            let held = self.ref_count[b] > 0;
            if (held as u8 + in_free as u8 + in_cached as u8) != 1 {
                return Err(format!(
                    "block {b} state corrupt: held={held} free={in_free} cached={in_cached}"
                ));
            }
        }
        if self.in_use != (1..self.n_blocks).filter(|&b| self.ref_count[b] > 0).count() {
            return Err(format!("in_use gauge {} out of sync", self.in_use));
        }
        for (&h, &b) in &self.by_hash {
            if self.hash_of[b as usize] != Some(h) {
                return Err(format!("hash index maps {h:#x} to block {b} without back-link"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::substrate::prop::check;

    fn toks(seed: i32, n: usize) -> Vec<i32> {
        (0..n as i32).map(|i| seed * 1000 + i).collect()
    }

    #[test]
    fn alloc_covers_prompt_and_reclaims_to_empty() {
        let mut p = BlockPool::new(9, 4).unwrap();
        let (t, cached) = p.alloc_prompt(&toks(1, 10)).unwrap().unwrap();
        assert_eq!(cached, 0);
        assert_eq!(t.blocks.len(), 3); // ceil(10/4)
        assert_eq!(p.blocks_in_use(), 3);
        assert!(t.blocks.iter().all(|&b| b != 0), "null block granted");
        p.free_table(t);
        assert_eq!(p.blocks_in_use(), 0);
        assert_eq!(p.available(), 8);
    }

    #[test]
    fn prefix_hits_share_published_blocks_live_and_after_free() {
        let mut p = BlockPool::new(17, 4).unwrap();
        let prompt_a: Vec<i32> = toks(7, 12); // 3 full blocks
        let (mut ta, cached) = p.alloc_prompt(&prompt_a).unwrap().unwrap();
        assert_eq!(cached, 0);
        p.publish_full_blocks(&mut ta, &prompt_a);

        // co-resident: same 8-token prefix, different tail
        let mut prompt_b = prompt_a[..8].to_vec();
        prompt_b.extend(toks(9, 4));
        let (tb, cached_b) = p.alloc_prompt(&prompt_b).unwrap().unwrap();
        assert_eq!(cached_b, 8, "two full prefix blocks should hit");
        assert_eq!(&tb.blocks[..2], &ta.blocks[..2], "must share physical blocks");
        assert_ne!(tb.blocks[2], ta.blocks[2], "divergent tail must not alias");
        assert_eq!(p.stats.prefix_hits, 2);

        // across time: A finishes; its first two blocks stay held by B,
        // its third drops to ref 0 and is RETAINED in the prefix cache
        let a_blocks = ta.blocks.clone();
        p.free_table(ta);
        assert_eq!(p.cached_blocks(), 1);
        let (tc, cached_c) = p.alloc_prompt(&prompt_a).unwrap().unwrap();
        assert_eq!(cached_c, 12, "full prompt cached after A's lifetime");
        assert_eq!(tc.blocks, a_blocks);
        p.free_table(tb);
        p.free_table(tc);
        assert_eq!(p.blocks_in_use(), 0);
    }

    #[test]
    fn cow_on_shared_block_write() {
        let mut p = BlockPool::new(9, 4).unwrap();
        let prompt = toks(3, 8);
        let (mut ta, _) = p.alloc_prompt(&prompt).unwrap().unwrap();
        p.publish_full_blocks(&mut ta, &prompt);
        let (mut tb, cached) = p.alloc_prompt(&prompt).unwrap().unwrap();
        assert_eq!(cached, 8);
        assert_eq!(ta.blocks, tb.blocks);
        // B must not write into the shared final block without a copy
        match p.make_private(&mut tb, 1).unwrap() {
            MakePrivate::Cow { src, dst } => {
                assert_eq!(src, ta.blocks[1]);
                assert_eq!(tb.blocks[1], dst);
                assert_ne!(dst, src);
            }
            other => panic!("expected Cow, got {other:?}"),
        }
        // now exclusive: a second call is a no-op
        assert_eq!(p.make_private(&mut tb, 1).unwrap(), MakePrivate::Private);
        assert_eq!(p.stats.cow_copies, 1);
        p.free_table(ta);
        p.free_table(tb);
        assert_eq!(p.blocks_in_use(), 0);
    }

    #[test]
    fn exhaustion_is_clean_and_eviction_recycles_cache() {
        let mut p = BlockPool::new(5, 4).unwrap(); // 4 usable blocks
        let (mut ta, _) = p.alloc_prompt(&toks(1, 8)).unwrap().unwrap(); // 2 blocks
        p.publish_full_blocks(&mut ta, &toks(1, 8));
        let (tb, _) = p.alloc_prompt(&toks(2, 8)).unwrap().unwrap(); // 2 more
        // pool full: a third distinct prompt cannot be covered, and the
        // failed allocation leaks nothing
        assert!(p.alloc_prompt(&toks(3, 8)).unwrap().is_none());
        assert_eq!(p.blocks_in_use(), 4);
        // free A -> its published blocks become cached-free, and a new
        // distinct prompt EVICTS them (oldest first) rather than failing
        p.free_table(ta);
        assert_eq!(p.cached_blocks(), 2);
        let (tc, cached) = p.alloc_prompt(&toks(4, 8)).unwrap().unwrap();
        assert_eq!(cached, 0);
        assert_eq!(p.stats.evictions, 2);
        // the evicted hashes are gone: prompt 1 no longer hits
        p.free_table(tc);
        let (td, cached) = p.alloc_prompt(&toks(1, 8)).unwrap().unwrap();
        assert_eq!(cached, 0, "evicted prefix must not hit");
        p.free_table(tb);
        p.free_table(td);
        assert_eq!(p.blocks_in_use(), 0);
    }

    #[test]
    fn generated_tokens_publish_for_multi_turn_reuse() {
        let mut p = BlockPool::new(9, 4).unwrap();
        let prompt = toks(5, 4); // exactly one block
        let (mut t, _) = p.alloc_prompt(&prompt).unwrap().unwrap();
        p.publish_full_blocks(&mut t, &prompt);
        // generation fills a second block
        assert!(p.append_block(&mut t));
        let mut stream = prompt.clone();
        stream.extend([900, 901, 902, 903]);
        p.publish_full_blocks(&mut t, &stream);
        p.free_table(t);
        // a follow-up turn embedding prompt + generation hits both blocks
        let mut follow = stream.clone();
        follow.extend(toks(6, 3));
        let (tf, cached) = p.alloc_prompt(&follow).unwrap().unwrap();
        assert_eq!(cached, 8, "prompt AND generated blocks should be cached");
        p.free_table(tf);
    }

    #[test]
    fn fork_shares_everything_and_cow_isolates() {
        let mut p = BlockPool::new(9, 4).unwrap();
        let (t, _) = p.alloc_prompt(&toks(8, 6)).unwrap().unwrap();
        let mut f = p.fork(&t);
        assert_eq!(f.blocks, t.blocks);
        assert_eq!(p.blocks_in_use(), 2);
        // the fork diverges at the partial tail block
        match p.make_private(&mut f, 1).unwrap() {
            MakePrivate::Cow { dst, .. } => assert_ne!(dst, t.blocks[1]),
            other => panic!("expected Cow, got {other:?}"),
        }
        p.check_invariants(&[&t, &f]).unwrap();
        p.free_table(t);
        p.free_table(f);
        assert_eq!(p.blocks_in_use(), 0);
    }

    /// A fork that COW-diverges INSIDE its hashed prefix must never
    /// publish again: its chain state describes the parent's tokens, so
    /// publishing a later block would index it under a lying prefix and
    /// a future prompt would be served wrong KV.
    #[test]
    fn cow_inside_published_prefix_freezes_publishing() {
        let mut p = BlockPool::new(17, 4).unwrap();
        let prompt = toks(4, 8); // 2 full blocks
        let (mut t, _) = p.alloc_prompt(&prompt).unwrap().unwrap();
        p.publish_full_blocks(&mut t, &prompt);
        let mut f = p.fork(&t);
        // diverge inside the published prefix (block 1)
        match p.make_private(&mut f, 1).unwrap() {
            MakePrivate::Cow { .. } => {}
            other => panic!("expected Cow, got {other:?}"),
        }
        // the fork extends with its own block; its stream diverged at
        // block 1, so publishing block 2 under the parent's chain would
        // be a lie — it must be silently skipped
        assert!(p.append_block(&mut f));
        let mut divergent = prompt.clone();
        divergent.extend([700, 701, 702, 703]);
        let cached_before = p.by_hash.len();
        p.publish_full_blocks(&mut f, &divergent);
        assert_eq!(p.by_hash.len(), cached_before, "frozen table published");
        // a prompt matching the PARENT's stream + the fork's tail must
        // NOT hit the fork's unpublished block
        let (tq, cached) = p.alloc_prompt(&divergent).unwrap().unwrap();
        assert_eq!(cached, 8, "only the true shared prefix may hit");
        p.free_table(t);
        p.free_table(f);
        p.free_table(tq);
        assert_eq!(p.blocks_in_use(), 0);
    }

    #[test]
    fn table_row_pads_with_null() {
        let mut p = BlockPool::new(9, 4).unwrap();
        let (t, _) = p.alloc_prompt(&toks(2, 6)).unwrap().unwrap();
        let row = t.row(4);
        assert_eq!(row.len(), 4);
        assert_eq!(&row[2..], &[0, 0]);
        assert!(row[0] > 0 && row[1] > 0);
        p.free_table(t);
    }

    #[test]
    fn reservation_ledger_tracks_unreserved_headroom() {
        let mut p = BlockPool::new(9, 4).unwrap(); // 8 usable
        assert_eq!(p.available_unreserved(), 8);
        p.set_reservation(1, 3);
        p.set_reservation(2, 2);
        assert_eq!(p.reserved_total(), 5);
        assert_eq!(p.available_unreserved(), 3);
        // shrinking as blocks materialize
        p.set_reservation(1, 1);
        assert_eq!(p.reserved_total(), 3);
        // a real allocation reduces available(); reservations stack on top
        let (t, _) = p.alloc_prompt(&toks(1, 8)).unwrap().unwrap(); // 2 blocks
        assert_eq!(p.available(), 6);
        assert_eq!(p.available_unreserved(), 3);
        // reservations can exceed what's physically left: saturates to 0
        p.set_reservation(3, 100);
        assert_eq!(p.available_unreserved(), 0);
        p.release_reservation(3);
        p.set_reservation(2, 0); // 0 clears
        p.release_reservation(1);
        assert_eq!(p.reserved_total(), 0);
        p.free_table(t);
        assert_eq!(p.available_unreserved(), 8);
    }

    #[test]
    fn chain_hash_is_position_sensitive() {
        let a = chain_hash(0, &[1, 2, 3, 4]);
        let b = chain_hash(0, &[5, 6, 7, 8]);
        assert_ne!(a, b);
        // same second block under different first blocks -> different keys
        assert_ne!(chain_hash(a, &[9, 9, 9, 9]), chain_hash(b, &[9, 9, 9, 9]));
        // deterministic
        assert_eq!(a, chain_hash(0, &[1, 2, 3, 4]));
    }

    /// The satellite property: random interleavings of
    /// alloc/free/fork(COW)/prefix-share never double-free, never alias
    /// blocks across non-sharing requests, and always reclaim to empty.
    #[test]
    fn prop_allocator_interleavings_hold_invariants() {
        check("kv-paged-allocator", 40, |g| {
            let bs = g.usize_in(1, 5);
            let n_blocks = g.usize_in(6, 40);
            let mut p = BlockPool::new(n_blocks, bs).map_err(|e| e.to_string())?;
            // small prompt alphabet so prefix collisions actually happen
            let mut live: Vec<(BlockTable, Vec<i32>)> = Vec::new();
            let ops = g.usize_in(10, 60);
            for _ in 0..ops {
                match g.usize_in(0, 5) {
                    // alloc a prompt (maybe sharing a prefix with history)
                    0 | 1 => {
                        let blocks = g.usize_in(1, 4);
                        let seed = g.usize_in(0, 3) as i32;
                        let mut prompt: Vec<i32> = Vec::new();
                        for b in 0..blocks {
                            // low-entropy block content keyed by (seed, b)
                            prompt.extend((0..bs).map(|k| seed * 7 + b as i32 * 31 + k as i32));
                        }
                        if g.bool() {
                            prompt.push(999); // partial tail
                        }
                        if let Some((mut t, cached)) =
                            p.alloc_prompt(&prompt).map_err(|e| e.to_string())?
                        {
                            prop_assert!(
                                cached % bs == 0 && cached <= prompt.len(),
                                "cached {cached} not block-aligned under {}",
                                prompt.len()
                            );
                            p.publish_full_blocks(&mut t, &prompt);
                            live.push((t, prompt));
                        }
                    }
                    // free a random live table
                    2 => {
                        if !live.is_empty() {
                            let i = g.usize_in(0, live.len());
                            let (t, _) = live.swap_remove(i);
                            p.free_table(t);
                        }
                    }
                    // fork one, then COW-diverge the fork's tail
                    3 => {
                        if !live.is_empty() {
                            let i = g.usize_in(0, live.len());
                            let (src_t, src_p) = (live[i].0.clone(), live[i].1.clone());
                            let mut f = p.fork(&src_t);
                            if !f.blocks.is_empty() {
                                let idx = f.blocks.len() - 1;
                                match p.make_private(&mut f, idx).map_err(|e| e.to_string())? {
                                    MakePrivate::Cow { src, dst } => {
                                        prop_assert!(src != dst, "cow to itself");
                                        prop_assert!(
                                            src_t.blocks[idx] == src && f.blocks[idx] == dst,
                                            "cow remap wrong"
                                        );
                                    }
                                    MakePrivate::Exhausted => {
                                        // fork stays shared; still valid
                                    }
                                    MakePrivate::Private => {
                                        // only legal if the source block
                                        // was freed meanwhile — it wasn't
                                        // (src_t is live), so this is a bug
                                        return Err("shared block reported private".into());
                                    }
                                }
                            }
                            live.push((f, src_p));
                        }
                    }
                    // grow a random table by a block
                    _ => {
                        if !live.is_empty() {
                            let i = g.usize_in(0, live.len());
                            let _ = p.append_block(&mut live[i].0);
                        }
                    }
                }
                let refs: Vec<&BlockTable> = live.iter().map(|(t, _)| t).collect();
                p.check_invariants(&refs).map_err(|e| format!("after op: {e}"))?;
                // no aliasing across non-sharing requests: any block shared
                // by two tables must be a common PUBLISHED prefix block or
                // a fork remnant — in both cases ref_count covers it; a
                // block referenced twice with ref_count 1 is corruption
                // (covered by check_invariants' exact ref accounting).
            }
            // drain: everything reclaims, nothing double-frees
            for (t, _) in live.drain(..) {
                p.free_table(t);
            }
            prop_assert!(p.blocks_in_use() == 0, "leaked {} blocks", p.blocks_in_use());
            p.check_invariants(&[]).map_err(|e| format!("after drain: {e}"))?;
            Ok(())
        });
    }

    /// Prefix sharing must never hand out a block whose content the new
    /// request's prompt does not match (hash chaining soundness at the
    /// allocator level: equal chains <=> equal prefixes for these inputs).
    #[test]
    fn prop_prefix_hits_imply_equal_prefixes() {
        check("kv-paged-prefix-soundness", 30, |g| {
            let bs = g.usize_in(2, 5);
            let mut p = BlockPool::new(64, bs).map_err(|e| e.to_string())?;
            let mut history: Vec<(Vec<i32>, BlockTable)> = Vec::new();
            for _ in 0..g.usize_in(3, 10) {
                let nb = g.usize_in(1, 4);
                let mut prompt = Vec::new();
                for b in 0..nb {
                    let variant = g.usize_in(0, 2) as i32;
                    prompt.extend((0..bs).map(|k| variant * 100 + b as i32 * 10 + k as i32));
                }
                let Some((mut t, cached)) = p.alloc_prompt(&prompt).map_err(|e| e.to_string())?
                else {
                    continue;
                };
                // every cached block must map to a historical table whose
                // prompt agrees on that whole prefix
                for (hp, ht) in &history {
                    for i in 0..cached / bs {
                        if ht.blocks.get(i) == Some(&t.blocks[i]) {
                            prop_assert!(
                                hp.len() >= (i + 1) * bs
                                    && hp[..(i + 1) * bs] == prompt[..(i + 1) * bs],
                                "shared block {i} with mismatched prefix"
                            );
                        }
                    }
                }
                p.publish_full_blocks(&mut t, &prompt);
                history.push((prompt, t));
            }
            for (_, t) in history.drain(..) {
                p.free_table(t);
            }
            prop_assert!(p.blocks_in_use() == 0, "leak");
            Ok(())
        });
    }
}
