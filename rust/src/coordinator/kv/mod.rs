//! KV-cache management.
//!
//! Two layers live here:
//!
//! * [`paged`] — the serving substrate: a fixed-size **block pool** with
//!   ref-counted physical blocks, per-request block tables, copy-on-write
//!   on divergence and hash-keyed prefix caching. The scheduler allocates
//!   every request's KV here; composition changes (admission, finish,
//!   batch/seq bucket changes) move **no cache bytes at all** — only
//!   table entries. This file's contiguous-surgery era (`regroup`,
//!   `shrink_patience`, the pooled rebuild buffers) is retired.
//! * Contiguous host-tensor surgery on the `[L, 2, B, G, N, dh]` layout
//!   ([`copy_slot`], [`append_chunk`], [`pad_n`]) — still used by the
//!   contiguous A/B engine path, the mock's fingerprint bookkeeping and
//!   eval. The PP/TP splits moved to pool-slice form in
//!   [`crate::runtime::shard`] (`split_pool_layers` / `split_pool_groups`):
//!   sharded serving slices the paged pool, not dense caches.

use anyhow::{bail, Result};

use crate::runtime::{ModelConfig, Tensor};

pub mod paged;

pub use paged::{chain_hash, BlockId, BlockPool, BlockStats, BlockTable, MakePrivate};

/// Shape helper for one sequence's cache (B == 1).
pub fn seq_kv_shape(cfg: &ModelConfig, n: usize) -> Vec<usize> {
    cfg.kv_shape(1, n)
}

fn dims6(t: &Tensor) -> Result<(usize, usize, usize, usize, usize, usize)> {
    let s = t.shape();
    if s.len() != 6 || s[1] != 2 {
        bail!("expected KV shape [L,2,B,G,N,dh], got {:?}", s);
    }
    Ok((s[0], s[1], s[2], s[3], s[4], s[5]))
}

/// Copy slot `sb` of `src` into slot `db` of `dst` — the incremental
/// surgery primitive. Caches must agree on (L, G, dh); the source's
/// position count may be smaller (the destination's tail is zeroed, so a
/// pooled/reused destination never leaks stale positions).
pub fn copy_slot(dst: &mut Tensor, db: usize, src: &Tensor, sb: usize) -> Result<()> {
    let (l, two, b_dst, g, n_dst, dh) = dims6(dst)?;
    let (l2, _, b_src, g2, n_src, dh2) = dims6(src)?;
    if l2 != l || g2 != g || dh2 != dh {
        bail!(
            "copy_slot: src {:?} incompatible with dst {:?}",
            src.shape(),
            dst.shape()
        );
    }
    if n_src > n_dst {
        bail!("copy_slot: n_src {n_src} > n_dst {n_dst}");
    }
    if db >= b_dst || sb >= b_src {
        bail!("copy_slot: slot {db} >= {b_dst} or {sb} >= {b_src}");
    }
    let s = src.as_f32()?;
    let d = dst.as_f32_mut()?;
    let row = dh;
    for li in 0..l {
        for c in 0..two {
            for gi in 0..g {
                let sbase = ((((li * two + c) * b_src + sb) * g) + gi) * n_src * row;
                let dbase = ((((li * two + c) * b_dst + db) * g) + gi) * n_dst * row;
                d[dbase..dbase + n_src * row]
                    .copy_from_slice(&s[sbase..sbase + n_src * row]);
                for x in &mut d[dbase + n_src * row..dbase + n_dst * row] {
                    *x = 0.0;
                }
            }
        }
    }
    Ok(())
}

/// Copy one slot out of a batch cache -> [L,2,1,G,N,dh].
pub fn extract_slot(kv: &Tensor, b: usize) -> Result<Tensor> {
    let (l, two, bsz, g, n, dh) = dims6(kv)?;
    if b >= bsz {
        bail!("slot {b} out of range (B={bsz})");
    }
    let src = kv.as_f32()?;
    let block = g * n * dh;
    let mut out = vec![0f32; l * two * block];
    for li in 0..l {
        for c in 0..two {
            let s0 = ((li * two + c) * bsz + b) * block;
            let d0 = (li * two + c) * block;
            out[d0..d0 + block].copy_from_slice(&src[s0..s0 + block]);
        }
    }
    Tensor::f32(out, vec![l, two, 1, g, n, dh])
}

/// Write a single-sequence cache (n_src <= n_dst positions) into slot `b`
/// of a batch cache. Extra positions in the destination are zeroed.
pub fn write_slot(kv: &mut Tensor, slot_kv: &Tensor, b: usize) -> Result<()> {
    let (_, _, one, _, _, _) = dims6(slot_kv)?;
    if one != 1 {
        bail!("write_slot: source is not a single-slot cache");
    }
    copy_slot(kv, b, slot_kv, 0)
}

/// Append a chunk's KV into one slot of a batch cache at a position
/// offset: positions `[offset, offset + c_len)` of slot `b` are
/// overwritten from the first `c_len` positions of `chunk` (a
/// single-slot cache `[L,2,1,G,C,dh]`); everything else — other slots,
/// the slot's own prefix and tail — is untouched. The host-side mirror
/// of the chunked-prefill entries' on-device masked writes, used for
/// composition surgery and by the mock engine.
pub fn append_chunk(
    dst: &mut Tensor,
    b: usize,
    chunk: &Tensor,
    offset: usize,
    c_len: usize,
) -> Result<()> {
    let (l, two, bsz, g, n, dh) = dims6(dst)?;
    let (l2, _, one, g2, c, dh2) = dims6(chunk)?;
    if l2 != l || g2 != g || dh2 != dh {
        bail!(
            "append_chunk: chunk {:?} incompatible with dst {:?}",
            chunk.shape(),
            dst.shape()
        );
    }
    if one != 1 {
        bail!("append_chunk: chunk is not a single-slot cache");
    }
    if c_len > c {
        bail!("append_chunk: c_len {c_len} > chunk positions {c}");
    }
    if offset + c_len > n {
        bail!("append_chunk: offset {offset} + len {c_len} > bucket {n}");
    }
    if b >= bsz {
        bail!("append_chunk: slot {b} out of range (B={bsz})");
    }
    let s = chunk.as_f32()?;
    let d = dst.as_f32_mut()?;
    for li in 0..l {
        for ch in 0..two {
            for gi in 0..g {
                let sbase = ((((li * two + ch) * 1) * g) + gi) * c * dh;
                let dbase = (((((li * two + ch) * bsz + b) * g) + gi) * n + offset) * dh;
                d[dbase..dbase + c_len * dh]
                    .copy_from_slice(&s[sbase..sbase + c_len * dh]);
            }
        }
    }
    Ok(())
}

/// Zero a slot (freed sequence) so stale KV never leaks into attention.
pub fn clear_slot(kv: &mut Tensor, b: usize) -> Result<()> {
    let (l, two, bsz, g, n, dh) = dims6(kv)?;
    if b >= bsz {
        bail!("slot {b} out of range");
    }
    let dst = kv.as_f32_mut()?;
    let block = g * n * dh;
    for li in 0..l {
        for c in 0..two {
            let d0 = ((li * two + c) * bsz + b) * block;
            for x in &mut dst[d0..d0 + block] {
                *x = 0.0;
            }
        }
    }
    Ok(())
}

/// Copy `src` into a same-batch, wider-position `dst` (bucket promotion
/// into a preallocated/pooled buffer). The destination tail is zeroed.
pub fn pad_n_into(src: &Tensor, dst: &mut Tensor) -> Result<()> {
    let (l, two, bsz, g, n, dh) = dims6(src)?;
    let (l2, _, b2, g2, n_new, dh2) = dims6(dst)?;
    if l2 != l || b2 != bsz || g2 != g || dh2 != dh {
        bail!(
            "pad_n_into: src {:?} incompatible with dst {:?}",
            src.shape(),
            dst.shape()
        );
    }
    if n_new < n {
        bail!("pad_n_into: destination bucket {n_new} < source {n}");
    }
    let s = src.as_f32()?;
    let d = dst.as_f32_mut()?;
    let row = dh;
    for li in 0..l {
        for c in 0..two {
            for b in 0..bsz {
                for gi in 0..g {
                    let sbase = ((((li * two + c) * bsz + b) * g) + gi) * n * row;
                    let dbase = ((((li * two + c) * bsz + b) * g) + gi) * n_new * row;
                    d[dbase..dbase + n * row].copy_from_slice(&s[sbase..sbase + n * row]);
                    for x in &mut d[dbase + n * row..dbase + n_new * row] {
                        *x = 0.0;
                    }
                }
            }
        }
    }
    Ok(())
}

/// Grow the position axis to a larger bucket (zero-padded).
pub fn pad_n(kv: &Tensor, n_new: usize) -> Result<Tensor> {
    let (l, two, bsz, g, n, dh) = dims6(kv)?;
    if n_new < n {
        bail!("pad_n: {n_new} < current {n}");
    }
    if n_new == n {
        return Ok(kv.clone());
    }
    let mut out = Tensor::zeros_f32(vec![l, two, bsz, g, n_new, dh]);
    pad_n_into(kv, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::substrate::prop::check;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            analogue: "t".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 16,
            d_head: 4,
            vocab: 10,
            max_seq: 16,
            mlp: "relu".into(),
            pos: "learned".into(),
            critical_density: 0.5,
        }
    }

    fn filled(shape: Vec<usize>, seed: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::f32((0..n).map(|i| seed + i as f32).collect(), shape).unwrap()
    }

    /// [L,2,B,G,N,dh] shape from generated dims.
    fn shape(l: usize, b: usize, g: usize, n: usize, dh: usize) -> Vec<usize> {
        vec![l, 2, b, g, n, dh]
    }

    #[test]
    fn extract_write_roundtrip() {
        let c = cfg();
        let mut kv = filled(c.kv_shape(3, 8), 0.0);
        let slot1 = extract_slot(&kv, 1).unwrap();
        let mut kv2 = Tensor::zeros_f32(c.kv_shape(3, 8));
        write_slot(&mut kv2, &slot1, 1).unwrap();
        let back = extract_slot(&kv2, 1).unwrap();
        assert_eq!(slot1, back);
        // other slots untouched (zero)
        assert!(extract_slot(&kv2, 0).unwrap().as_f32().unwrap().iter().all(|&x| x == 0.0));
        // clear works
        clear_slot(&mut kv, 1).unwrap();
        assert!(extract_slot(&kv, 1).unwrap().as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pad_preserves_prefix() {
        let c = cfg();
        let kv = filled(c.kv_shape(2, 4), 1.0);
        let padded = pad_n(&kv, 8).unwrap();
        assert_eq!(padded.shape(), &[2, 2, 2, 2, 8, 4]);
        // spot check: first row of each (l,c,b,g) group survives
        let s = extract_slot(&kv, 0).unwrap();
        let p = extract_slot(&padded, 0).unwrap();
        let (sn, pn) = (s.as_f32().unwrap(), p.as_f32().unwrap());
        // row 0 of group 0, layer 0, k
        assert_eq!(&sn[0..4], &pn[0..4]);
    }

    #[test]
    fn copy_slot_moves_one_slot_and_zero_pads() {
        let c = cfg();
        let src = filled(c.kv_shape(3, 4), 5.0);
        let mut dst = filled(c.kv_shape(2, 8), 9.0);
        copy_slot(&mut dst, 0, &src, 2).unwrap();
        // moved slot matches the source slot padded to the wider bucket
        let want = pad_n(&extract_slot(&src, 2).unwrap(), 8).unwrap();
        assert_eq!(extract_slot(&dst, 0).unwrap(), want);
        // the other destination slot is untouched
        let before = filled(c.kv_shape(2, 8), 9.0);
        assert_eq!(
            extract_slot(&dst, 1).unwrap(),
            extract_slot(&before, 1).unwrap()
        );
    }

    #[test]
    fn prop_write_then_extract_identity() {
        check("kv-write-extract", 30, |g| {
            let c = cfg();
            let b = g.usize_in(1, 5);
            let n_src = g.usize_in(1, 5);
            let n_dst = g.usize_in(n_src, 9);
            let slot = g.usize_in(0, b);
            let data = g.vec_f32(c.kv_elems(1, n_src), -1.0, 1.0);
            let s = Tensor::f32(data, c.kv_shape(1, n_src)).unwrap();
            let mut kv = Tensor::zeros_f32(c.kv_shape(b, n_dst));
            write_slot(&mut kv, &s, slot).unwrap();
            let out = extract_slot(&kv, slot).unwrap();
            // prefix must match the source; suffix zero
            let padded = pad_n(&s, n_dst).unwrap();
            prop_assert!(out == padded, "slot roundtrip mismatch");
            Ok(())
        });
    }

    /// write_slot-based rebuild (the old `assemble` helper, now test-only:
    /// production regroup is slot-incremental via copy_slot).
    fn assemble_via_write_slot(
        c: &ModelConfig,
        slots: &[Option<Tensor>],
        n_bucket: usize,
    ) -> Tensor {
        let mut kv = Tensor::zeros_f32(c.kv_shape(slots.len(), n_bucket));
        for (i, s) in slots.iter().enumerate() {
            if let Some(t) = s {
                write_slot(&mut kv, t, i).unwrap();
            }
        }
        kv
    }

    #[test]
    fn prop_assemble_no_aliasing() {
        check("kv-assemble", 20, |g| {
            let c = cfg();
            let b = g.usize_in(2, 5);
            let n = 4;
            let slots: Vec<Option<Tensor>> = (0..b)
                .map(|i| {
                    if g.bool() {
                        Some(
                            Tensor::f32(
                                vec![i as f32 + 1.0; c.kv_elems(1, n)],
                                c.kv_shape(1, n),
                            )
                            .unwrap(),
                        )
                    } else {
                        None
                    }
                })
                .collect();
            let kv = assemble_via_write_slot(&c, &slots, n);
            for (i, s) in slots.iter().enumerate() {
                let got = extract_slot(&kv, i).unwrap();
                match s {
                    Some(t) => prop_assert!(got == *t, "slot {i} clobbered"),
                    None => prop_assert!(
                        got.as_f32().unwrap().iter().all(|&x| x == 0.0),
                        "empty slot {i} non-zero"
                    ),
                }
            }
            Ok(())
        });
    }

    /// Slot-incremental regroup over a random permutation: every surviving
    /// slot must land bit-exactly, across random (L,B,G,N,dh) shapes.
    #[test]
    fn prop_copy_slot_permutation_preserves_slots() {
        check("kv-permute-slots", 30, |g| {
            let (l, gg, dh) = (g.usize_in(1, 4), g.usize_in(1, 4), g.usize_in(1, 5));
            let n_src = g.usize_in(1, 6);
            let n_dst = g.usize_in(n_src, 8);
            let b_src = g.usize_in(1, 6);
            let b_dst = g.usize_in(b_src, 8);
            let elems: usize = shape(l, b_src, gg, n_src, dh).iter().product();
            let src = Tensor::f32(g.vec_f32(elems, -2.0, 2.0), shape(l, b_src, gg, n_src, dh))
                .unwrap();
            // random injective old-slot -> new-slot mapping
            let keep = g.usize_in(0, b_src + 1);
            let from = g.distinct(keep, b_src);
            let to = g.distinct(keep, b_dst);
            let mut dst = Tensor::zeros_f32(shape(l, b_dst, gg, n_dst, dh));
            for (&f, &t) in from.iter().zip(to.iter()) {
                copy_slot(&mut dst, t, &src, f).map_err(|e| e.to_string())?;
            }
            let mut moved = vec![false; b_dst];
            for (&f, &t) in from.iter().zip(to.iter()) {
                moved[t] = true;
                let got = extract_slot(&dst, t).unwrap();
                let want = pad_n(&extract_slot(&src, f).unwrap(), n_dst).unwrap();
                prop_assert!(got == want, "slot {f}->{t} not preserved");
            }
            for (t, m) in moved.iter().enumerate() {
                if !m {
                    let got = extract_slot(&dst, t).unwrap();
                    prop_assert!(
                        got.as_f32().unwrap().iter().all(|&x| x == 0.0),
                        "untouched slot {t} non-zero"
                    );
                }
            }
            Ok(())
        });
    }

    /// Chunk-append must touch exactly `[offset, offset+len)` of the
    /// target slot: other slots, the slot's prefix and its tail survive
    /// bit-exactly, and successive chunks reassemble a full sequence.
    #[test]
    fn prop_append_chunk_touches_only_the_window() {
        check("kv-append-chunk", 30, |g| {
            let (l, gg, dh) = (g.usize_in(1, 3), g.usize_in(1, 3), g.usize_in(1, 4));
            let b = g.usize_in(1, 4);
            let n = g.usize_in(2, 10);
            let c = g.usize_in(1, n + 1);
            let slot = g.usize_in(0, b);
            let offset = g.usize_in(0, n - c + 2).min(n - c);
            let c_len = g.usize_in(0, c + 1);
            if offset + c_len > n {
                return Ok(());
            }
            let delems: usize = shape(l, b, gg, n, dh).iter().product();
            let before =
                Tensor::f32(g.vec_f32(delems, -1.0, 1.0), shape(l, b, gg, n, dh)).unwrap();
            let celems: usize = shape(l, 1, gg, c, dh).iter().product();
            let chunk =
                Tensor::f32(g.vec_f32(celems, 2.0, 3.0), shape(l, 1, gg, c, dh)).unwrap();
            let mut dst = before.clone();
            append_chunk(&mut dst, slot, &chunk, offset, c_len)
                .map_err(|e| e.to_string())?;
            for bi in 0..b {
                let got = extract_slot(&dst, bi).unwrap();
                let was = extract_slot(&before, bi).unwrap();
                if bi != slot {
                    prop_assert!(got == was, "foreign slot {bi} touched");
                    continue;
                }
                let (gv, wv) = (got.as_f32().unwrap(), was.as_f32().unwrap());
                let cv = chunk.as_f32().unwrap();
                for li in 0..l {
                    for ch in 0..2 {
                        for gi in 0..gg {
                            for p in 0..n {
                                let di = ((((li * 2 + ch) * 1) * gg + gi) * n + p) * dh;
                                let inside = p >= offset && p < offset + c_len;
                                for x in 0..dh {
                                    let want = if inside {
                                        let si = ((((li * 2 + ch) * 1) * gg + gi) * c
                                            + (p - offset))
                                            * dh;
                                        cv[si + x]
                                    } else {
                                        wv[di + x]
                                    };
                                    prop_assert!(
                                        gv[di + x] == want,
                                        "pos {p} dim {x} wrong (inside={inside})"
                                    );
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// Pooled promotion must equal the allocating path bit-exactly, even
    /// when the pooled destination held stale data.
    #[test]
    fn prop_pad_n_into_matches_pad_n() {
        check("kv-pad-into", 30, |g| {
            let (l, b, gg, dh) = (
                g.usize_in(1, 3),
                g.usize_in(1, 4),
                g.usize_in(1, 3),
                g.usize_in(1, 4),
            );
            let n = g.usize_in(1, 5);
            let n_new = g.usize_in(n, 8);
            let elems: usize = shape(l, b, gg, n, dh).iter().product();
            let src = Tensor::f32(g.vec_f32(elems, -1.0, 1.0), shape(l, b, gg, n, dh)).unwrap();
            let want = pad_n(&src, n_new).unwrap();
            // stale destination: promotion must overwrite every position
            let delems: usize = shape(l, b, gg, n_new, dh).iter().product();
            let mut dst =
                Tensor::f32(vec![42.0; delems], shape(l, b, gg, n_new, dh)).unwrap();
            pad_n_into(&src, &mut dst).map_err(|e| e.to_string())?;
            prop_assert!(dst == want, "pooled promotion diverged");
            Ok(())
        });
    }
}
