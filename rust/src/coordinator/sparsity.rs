//! Sparsity controller: which decode-entry variant the scheduler executes.
//!
//! The policy object maps (model, operator intent) -> entry mode tag.
//! `polar` uses SHA head/group sparsity at the model's critical density
//! (Table 1) plus calibrated dynamic MLP top-k for ReLU models; `dejavu`
//! is the MLP-only baseline (§5.2); `dense` disables sparsity.

use anyhow::{bail, Result};

use crate::runtime::Manifest;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    Dense,
    DejaVu,
    Polar { density: f64 },
}

impl Mode {
    pub fn parse(s: &str, critical: f64) -> Result<Mode> {
        match s {
            "dense" => Ok(Mode::Dense),
            "dejavu" => Ok(Mode::DejaVu),
            "polar" => Ok(Mode::Polar { density: critical }),
            other => {
                if let Some(d) = other.strip_prefix("polar@") {
                    let density: f64 = d
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad density in {other:?}"))?;
                    Ok(Mode::Polar { density })
                } else {
                    bail!("unknown mode {other:?} (dense|dejavu|polar|polar@<d>)")
                }
            }
        }
    }

    pub fn tag(&self) -> String {
        match self {
            Mode::Dense => "dense".to_string(),
            Mode::DejaVu => "dejavu".to_string(),
            Mode::Polar { density } => Manifest::mode_tag("polar", *density),
        }
    }
}

/// Controller consulted each scheduling step. Density is fixed per serving
/// session in this release (the paper fixes top-k per layer too; adaptive
/// per-step density is its future-work §6).
#[derive(Debug, Clone)]
pub struct SparsityController {
    mode: Mode,
}

impl SparsityController {
    pub fn new(mode: Mode) -> Self {
        SparsityController { mode }
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }

    pub fn decode_tag(&self) -> String {
        self.mode.tag()
    }

    /// Check the manifest actually has the chosen variant at every
    /// (batch, seq) bucket so the scheduler never faults mid-flight.
    pub fn validate(&self, m: &Manifest) -> Result<()> {
        let tag = self.decode_tag();
        for &b in &m.batch_buckets {
            for &n in &m.seq_buckets {
                let name = m.decode_entry_name(&tag, b, n);
                if m.entries.get(&name).is_none() {
                    bail!("manifest missing {name} (mode {:?})", self.mode);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_modes() {
        assert_eq!(Mode::parse("dense", 0.5).unwrap(), Mode::Dense);
        assert_eq!(Mode::parse("dejavu", 0.5).unwrap(), Mode::DejaVu);
        assert_eq!(
            Mode::parse("polar", 0.25).unwrap(),
            Mode::Polar { density: 0.25 }
        );
        assert_eq!(
            Mode::parse("polar@0.625", 0.5).unwrap(),
            Mode::Polar { density: 0.625 }
        );
        assert!(Mode::parse("nope", 0.5).is_err());
    }

    #[test]
    fn tags() {
        assert_eq!(Mode::Dense.tag(), "dense");
        assert_eq!(Mode::Polar { density: 0.5 }.tag(), "polar_d0500");
    }
}
